//! Integration tests: multi-module scenarios over the full engine -
//! the spot lifecycle of paper Figs. 2-4 driven end to end under every
//! allocation policy, plus experiment-level shape checks.

use cloudmarket::allocation::{AllocationPolicy, BestFit, FirstFit, HlemVmp, RoundRobin, WorstFit};
use cloudmarket::cloudlet::{Cloudlet, CloudletState};
use cloudmarket::config::scenario::{build_comparison_workload, ComparisonConfig};
use cloudmarket::engine::{Engine, EngineConfig};
use cloudmarket::infra::HostSpec;
use cloudmarket::metrics::LifecycleKind;
use cloudmarket::vm::{SpotConfig, Vm, VmSpec, VmState, VmType};

fn all_policies() -> Vec<Box<dyn AllocationPolicy>> {
    vec![
        Box::new(FirstFit::new()),
        Box::new(BestFit::new()),
        Box::new(WorstFit::new()),
        Box::new(RoundRobin::new()),
        Box::new(HlemVmp::plain()),
        Box::new(HlemVmp::adjusted()),
    ]
}

/// The canonical hibernate/resume scenario must work under every policy.
#[test]
fn spot_lifecycle_under_every_policy() {
    for policy in all_policies() {
        let name = policy.name();
        let mut cfg = EngineConfig::default();
        cfg.vm_destruction_delay = 0.0;
        let mut e = Engine::new(cfg, policy);
        let dc = e.add_datacenter("dc", 1.0);
        e.add_host(dc, HostSpec::new(4, 1000.0, 8_192.0, 10_000.0, 500_000.0));

        let spot_cfg = SpotConfig::hibernate()
            .with_min_running(0.0)
            .with_warning(0.0)
            .with_hibernation_timeout(500.0);
        let spot =
            e.submit_vm(Vm::spot(0, VmSpec::new(1000.0, 4), spot_cfg).with_persistent(500.0));
        e.submit_cloudlet(Cloudlet::new(0, 40_000.0, 4).with_vm(spot)); // 10 s
        let od = e.submit_vm(Vm::on_demand(0, VmSpec::new(1000.0, 4)).with_delay(5.0));
        e.submit_cloudlet(Cloudlet::new(0, 20_000.0, 4).with_vm(od)); // 5 s
        e.terminate_at(300.0);
        let report = e.run();

        assert_eq!(e.world.vms[od].state, VmState::Finished, "[{name}] od");
        assert_eq!(e.world.vms[spot].state, VmState::Finished, "[{name}] spot");
        assert_eq!(report.spot.interruptions, 1, "[{name}]");
        assert_eq!(report.spot.redeployments, 1, "[{name}]");
        assert_eq!(e.world.vms[spot].history.intervals().len(), 2, "[{name}]");
    }
}

/// Terminate-behavior spots die and release capacity for the on-demand VM.
#[test]
fn terminate_behavior_under_every_policy() {
    for policy in all_policies() {
        let name = policy.name();
        let mut e = Engine::new(EngineConfig::default(), policy);
        let dc = e.add_datacenter("dc", 1.0);
        e.add_host(dc, HostSpec::new(2, 1000.0, 4_096.0, 10_000.0, 500_000.0));
        let spot_cfg = SpotConfig::terminate().with_min_running(0.0).with_warning(1.0);
        let spot = e.submit_vm(Vm::spot(0, VmSpec::new(1000.0, 2), spot_cfg));
        e.submit_cloudlet(Cloudlet::new(0, 1_000_000.0, 2).with_vm(spot));
        let od = e.submit_vm(Vm::on_demand(0, VmSpec::new(1000.0, 2)).with_delay(3.0));
        e.submit_cloudlet(Cloudlet::new(0, 2_000.0, 2).with_vm(od));
        e.terminate_at(100.0);
        e.run();
        assert_eq!(e.world.vms[spot].state, VmState::Terminated, "[{name}]");
        assert_eq!(e.world.vms[od].state, VmState::Finished, "[{name}]");
        // The spot's cloudlet was canceled, not finished.
        let spot_cl = e.world.vms[spot].cloudlets[0];
        assert_eq!(e.world.cloudlets[spot_cl].state, CloudletState::Canceled, "[{name}]");
    }
}

/// Post-run conservation invariants on the full comparison scenario.
#[test]
fn comparison_scenario_conservation() {
    let cfg = ComparisonConfig { terminate_at: 1_200.0, ..Default::default() };
    let mut e = Engine::new(EngineConfig::default(), Box::new(FirstFit::new()));
    build_comparison_workload(&mut e, &cfg);
    let report = e.run();

    // VM states partition the population.
    let w = &e.world;
    let total = w.vms.len() as u64;
    assert_eq!(
        report.finished + report.terminated + report.failed + report.still_active,
        total
    );

    // Host accounting: used resources equal the sum of resident VM specs.
    for host in &w.hosts {
        let mut pes = 0u32;
        let mut ram = 0.0;
        for &v in &host.vms {
            assert!(w.vms[v].state.on_host(), "vm {v} on host but state {:?}", w.vms[v].state);
            pes += w.vms[v].spec.pes;
            ram += w.vms[v].spec.ram;
        }
        assert_eq!(host.used_pes, pes, "host {} PE accounting", host.id);
        assert!((host.used_ram - ram).abs() < 1e-6, "host {} RAM accounting", host.id);
        assert!(host.used_pes <= host.spec.pes, "host {} oversubscribed", host.id);
    }

    // Interruption bookkeeping is consistent.
    let vm_interruptions: u64 = w.vms.iter().map(|v| v.interruptions as u64).sum();
    assert_eq!(vm_interruptions, report.spot.interruptions);

    // Histories are well-formed.
    for vm in &w.vms {
        let ivs = vm.history.intervals();
        for pair in ivs.windows(2) {
            let stop = pair[0].stop.expect("non-final interval must be closed");
            assert!(pair[1].start + 1e-9 >= stop, "vm {} intervals overlap", vm.id);
        }
        for iv in ivs {
            if let Some(stop) = iv.stop {
                assert!(stop + 1e-9 >= iv.start);
            }
        }
    }

    // Cloudlet states partition the population.
    let mut by_state = std::collections::HashMap::new();
    for cl in &w.cloudlets {
        *by_state.entry(cl.state).or_insert(0usize) += 1;
    }
    let sum: usize = by_state.values().sum();
    assert_eq!(sum, w.cloudlets.len());
}

/// The same workload under two different policies differs only in
/// placement, never in workload composition.
#[test]
fn workload_identical_across_policies() {
    let cfg = ComparisonConfig::default();
    let snapshot = |policy: Box<dyn AllocationPolicy>| {
        let mut e = Engine::new(EngineConfig::default(), policy);
        build_comparison_workload(&mut e, &cfg);
        e.world
            .vms
            .iter()
            .map(|v| (v.spec.pes, v.spec.ram as u64, v.is_spot(), (v.submission_delay * 1e6) as u64))
            .collect::<Vec<_>>()
    };
    assert_eq!(snapshot(Box::new(FirstFit::new())), snapshot(Box::new(HlemVmp::adjusted())));
}

/// Lifecycle log is ordered and consistent with terminal states.
#[test]
fn lifecycle_log_consistency() {
    let mut e = Engine::new(EngineConfig::default(), Box::new(FirstFit::new()));
    let dc = e.add_datacenter("dc", 1.0);
    e.add_host(dc, HostSpec::new(4, 1000.0, 8_192.0, 10_000.0, 500_000.0));
    let spot_cfg = SpotConfig::hibernate().with_min_running(0.0).with_warning(2.0);
    let spot = e.submit_vm(Vm::spot(0, VmSpec::new(1000.0, 4), spot_cfg).with_persistent(400.0));
    e.submit_cloudlet(Cloudlet::new(0, 60_000.0, 4).with_vm(spot));
    let od = e.submit_vm(Vm::on_demand(0, VmSpec::new(1000.0, 4)).with_delay(4.0));
    e.submit_cloudlet(Cloudlet::new(0, 16_000.0, 4).with_vm(od));
    e.terminate_at(200.0);
    e.run();

    let events = e.recorder.events_of(spot);
    let kinds: Vec<LifecycleKind> = events.iter().map(|ev| ev.kind).collect();
    // Submitted -> Allocated -> InterruptWarned -> Hibernated -> Resumed -> Finished
    assert_eq!(kinds[0], LifecycleKind::Submitted);
    assert!(kinds.contains(&LifecycleKind::InterruptWarned));
    let warn_pos = kinds.iter().position(|k| *k == LifecycleKind::InterruptWarned).unwrap();
    let hib_pos = kinds.iter().position(|k| *k == LifecycleKind::Hibernated).unwrap();
    let res_pos = kinds.iter().position(|k| *k == LifecycleKind::Resumed).unwrap();
    assert!(warn_pos < hib_pos && hib_pos < res_pos);
    // Warning time respected: >= 2 s between warn and hibernate.
    let warn_t = events[warn_pos].time;
    let hib_t = events[hib_pos].time;
    assert!(hib_t - warn_t >= 2.0 - 1e-6, "warning period violated: {warn_t} -> {hib_t}");
    // Times are non-decreasing.
    for pair in events.windows(2) {
        assert!(pair[1].time + 1e-9 >= pair[0].time);
    }
}

/// min_running_time blocks preemption until satisfied.
#[test]
fn min_running_time_delays_interruption() {
    let mut e = Engine::new(EngineConfig::default(), Box::new(FirstFit::new()));
    let dc = e.add_datacenter("dc", 1.0);
    e.add_host(dc, HostSpec::new(2, 1000.0, 4_096.0, 10_000.0, 500_000.0));
    let spot_cfg = SpotConfig::hibernate()
        .with_min_running(20.0)
        .with_warning(0.0)
        .with_hibernation_timeout(500.0);
    let spot = e.submit_vm(Vm::spot(0, VmSpec::new(1000.0, 2), spot_cfg).with_persistent(500.0));
    e.submit_cloudlet(Cloudlet::new(0, 200_000.0, 2).with_vm(spot));
    // OD arrives at t=5 but the spot is protected until t=20.
    let od = e.submit_vm(
        Vm::on_demand(0, VmSpec::new(1000.0, 2)).with_persistent(500.0).with_delay(5.0),
    );
    e.submit_cloudlet(Cloudlet::new(0, 10_000.0, 2).with_vm(od));
    e.terminate_at(300.0);
    e.run();

    let first_iv = e.world.vms[spot].history.intervals()[0];
    let stop = first_iv.stop.expect("spot must eventually be interrupted");
    assert!(stop >= 20.0 - 1e-6, "interrupted at {stop} before min running time");
    assert_eq!(e.world.vms[od].state, VmState::Finished);
}

/// Spot VMs never trigger preemption of other spots.
#[test]
fn spots_do_not_preempt_spots() {
    let mut e = Engine::new(EngineConfig::default(), Box::new(FirstFit::new()));
    let dc = e.add_datacenter("dc", 1.0);
    e.add_host(dc, HostSpec::new(2, 1000.0, 4_096.0, 10_000.0, 500_000.0));
    let cfg0 = SpotConfig::hibernate().with_min_running(0.0).with_warning(0.0);
    let s1 = e.submit_vm(Vm::spot(0, VmSpec::new(1000.0, 2), cfg0));
    e.submit_cloudlet(Cloudlet::new(0, 50_000.0, 2).with_vm(s1));
    let s2 = e.submit_vm(Vm::spot(0, VmSpec::new(1000.0, 2), cfg0).with_delay(2.0));
    e.submit_cloudlet(Cloudlet::new(0, 50_000.0, 2).with_vm(s2));
    e.terminate_at(100.0);
    let report = e.run();
    assert_eq!(report.spot.interruptions, 0, "a spot arrival must never interrupt a spot");
    assert_eq!(e.world.vms[s1].state, VmState::Finished);
    // s2 was not persistent -> failed fast.
    assert_eq!(e.world.vms[s2].state, VmState::Failed);
}

/// The multi-seed comparison keeps the paper's headline shape: adjusted
/// HLEM averages fewer interruptions than First-Fit.
#[test]
fn comparison_shape_adjusted_beats_first_fit() {
    use cloudmarket::experiments::compare;
    let cfg = ComparisonConfig { terminate_at: 2_400.0, ..Default::default() };
    let aggs = compare::run_multi(&cfg, 3);
    let get = |n: &str| aggs.iter().find(|a| a.policy == n).unwrap();
    let ff = get("first-fit").mean_interruptions;
    let adj = get("hlem-vmp-adjusted").mean_interruptions;
    assert!(
        adj < ff * 1.02,
        "adjusted ({adj:.1}) should not exceed first-fit ({ff:.1}) interruptions"
    );
}

/// Report JSON export round-trips through the JSON parser.
#[test]
fn report_json_roundtrip() {
    let mut e = Engine::new(EngineConfig::default(), Box::new(FirstFit::new()));
    let dc = e.add_datacenter("dc", 1.0);
    e.add_host(dc, HostSpec::new(4, 1000.0, 8_192.0, 10_000.0, 500_000.0));
    let vm = e.submit_vm(Vm::on_demand(0, VmSpec::new(1000.0, 2)));
    e.submit_cloudlet(Cloudlet::new(0, 5_000.0, 2).with_vm(vm));
    let report = e.run();
    let json = report.to_json().to_string_pretty();
    let parsed = cloudmarket::util::json::parse(&json).unwrap();
    assert_eq!(
        parsed.path(&["vms_finished"]).unwrap().as_f64(),
        Some(report.finished as f64)
    );
    assert_eq!(parsed.path(&["spot", "total"]).unwrap().as_f64(), Some(0.0));
}

/// VmType / dispatch sanity for the table builders on a finished world.
#[test]
fn tables_render_on_finished_world() {
    let mut e = Engine::new(EngineConfig::default(), Box::new(FirstFit::new()));
    let dc = e.add_datacenter("dc", 1.0);
    e.add_host(dc, HostSpec::new(8, 1000.0, 16_384.0, 10_000.0, 500_000.0));
    for i in 0..4 {
        let spec = VmSpec::new(1000.0, 2);
        let vm = if i % 2 == 0 {
            e.submit_vm(Vm::spot(0, spec, SpotConfig::hibernate()))
        } else {
            e.submit_vm(Vm::on_demand(0, spec))
        };
        e.submit_cloudlet(Cloudlet::new(0, 4_000.0, 2).with_vm(vm));
    }
    e.run();
    let all: Vec<usize> = (0..e.world.vms.len()).collect();
    let dyn_table = cloudmarket::metrics::tables::dynamic_vm_table(&e.world, &all);
    assert_eq!(dyn_table.row_count(), 4);
    let spot_table = cloudmarket::metrics::tables::spot_vm_table(&e.world, &all);
    assert_eq!(spot_table.row_count(), 2);
    let spot_count = e.world.vms.iter().filter(|v| v.vm_type == VmType::Spot).count();
    assert_eq!(spot_count, 2);
}

/// Satellite regression (displaced-gauge leak): a VM that is terminated,
/// failed, or finished while displaced must always return the `displaced`
/// gauge to zero. Drives one displaced VM through each terminal path and
/// cross-checks the incremental sample against the walking oracle.
#[test]
fn displaced_gauge_returns_to_zero_on_every_terminal_path() {
    // Path 1: hibernated-while-displaced -> hibernation timeout -> Terminated.
    // First stop mid-hibernation to prove the gauge actually went up.
    for (stop_at, want_displaced, want_state) in
        [(15.0, 1u64, VmState::Hibernated), (100.0, 0u64, VmState::Terminated)]
    {
        let mut cfg = EngineConfig::default();
        cfg.vm_destruction_delay = 0.0;
        let mut e = Engine::new(cfg, Box::new(FirstFit::new()));
        let dc = e.add_datacenter("dc", 1.0);
        e.add_host(dc, HostSpec::new(4, 1000.0, 8_192.0, 10_000.0, 500_000.0));
        let spot_cfg = SpotConfig::hibernate()
            .with_min_running(0.0)
            .with_warning(0.0)
            .with_hibernation_timeout(20.0);
        let spot =
            e.submit_vm(Vm::spot(0, VmSpec::new(1000.0, 4), spot_cfg).with_persistent(500.0));
        e.submit_cloudlet(Cloudlet::new(0, 1_000_000.0, 4).with_vm(spot));
        // The on-demand arrival at t=5 preempts (displaces) the spot VM and
        // then keeps the host full past the hibernation timeout at t=25.
        let od = e.submit_vm(Vm::on_demand(0, VmSpec::new(1000.0, 4)).with_delay(5.0));
        e.submit_cloudlet(Cloudlet::new(0, 400_000.0, 4).with_vm(od));
        e.terminate_at(stop_at);
        let report = e.run();

        assert_eq!(e.world.vms[spot].state, want_state, "[hibernate t={stop_at}]");
        let s = e.world.state_sample();
        assert_eq!(s.displaced, want_displaced, "[hibernate t={stop_at}]");
        assert_eq!(
            e.world.vms[spot].displaced_at.is_some(),
            want_displaced > 0,
            "[hibernate t={stop_at}] gauge and Option must agree"
        );
        assert!(s.bits_eq(&e.world.state_sample_scan()), "[hibernate t={stop_at}]");
        e.world.check_index().expect("consistent after hibernate-timeout path");
        assert_eq!(report.spot.interruptions, 1, "[hibernate t={stop_at}]");
    }

    // Path 2: on-demand evicted by host removal -> Waiting (displaced) ->
    // WaitingExpired -> Failed. The requeue window is 3600 s for
    // non-persistent on-demand VMs, so the deadline lands at t=3610.
    for (stop_at, want_displaced, want_state) in
        [(1_000.0, 1u64, VmState::Waiting), (4_000.0, 0u64, VmState::Failed)]
    {
        let mut cfg = EngineConfig::default();
        cfg.vm_destruction_delay = 0.0;
        let mut e = Engine::new(cfg, Box::new(FirstFit::new()));
        let dc = e.add_datacenter("dc", 1.0);
        e.add_host(dc, HostSpec::new(4, 1000.0, 8_192.0, 10_000.0, 500_000.0));
        let od = e.submit_vm(Vm::on_demand(0, VmSpec::new(1000.0, 4)));
        e.submit_cloudlet(Cloudlet::new(0, 1_000_000.0, 4).with_vm(od));
        e.remove_host_at(0, 10.0);
        e.terminate_at(stop_at);
        e.run();

        assert_eq!(e.world.vms[od].state, want_state, "[evict t={stop_at}]");
        let s = e.world.state_sample();
        assert_eq!(s.displaced, want_displaced, "[evict t={stop_at}]");
        assert_eq!(
            e.world.vms[od].displaced_at.is_some(),
            want_displaced > 0,
            "[evict t={stop_at}] gauge and Option must agree"
        );
        assert!(s.bits_eq(&e.world.state_sample_scan()), "[evict t={stop_at}]");
        e.world.check_index().expect("consistent after eviction path");
    }

    // Path 3: displaced -> resumed -> Finished (the recovery path clears
    // the gauge on re-placement, not at the terminal transition).
    {
        let mut cfg = EngineConfig::default();
        cfg.vm_destruction_delay = 0.0;
        let mut e = Engine::new(cfg, Box::new(FirstFit::new()));
        let dc = e.add_datacenter("dc", 1.0);
        e.add_host(dc, HostSpec::new(4, 1000.0, 8_192.0, 10_000.0, 500_000.0));
        let spot_cfg = SpotConfig::hibernate()
            .with_min_running(0.0)
            .with_warning(0.0)
            .with_hibernation_timeout(500.0);
        let spot =
            e.submit_vm(Vm::spot(0, VmSpec::new(1000.0, 4), spot_cfg).with_persistent(500.0));
        e.submit_cloudlet(Cloudlet::new(0, 40_000.0, 4).with_vm(spot));
        let od = e.submit_vm(Vm::on_demand(0, VmSpec::new(1000.0, 4)).with_delay(5.0));
        e.submit_cloudlet(Cloudlet::new(0, 20_000.0, 4).with_vm(od));
        e.terminate_at(300.0);
        let report = e.run();

        assert_eq!(e.world.vms[spot].state, VmState::Finished, "[resume]");
        assert_eq!(report.spot.redeployments, 1, "[resume]");
        let s = e.world.state_sample();
        assert_eq!(s.displaced, 0, "[resume] gauge must clear on re-placement");
        assert!(e.world.vms[spot].displaced_at.is_none(), "[resume]");
        assert!(s.bits_eq(&e.world.state_sample_scan()), "[resume]");
        e.world.check_index().expect("consistent after resume path");
    }
}
