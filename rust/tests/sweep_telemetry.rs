//! The two-channel rule, end to end: turning telemetry on must leave the
//! primary sweep artifacts (`sweep_cells.csv`, aggregate JSON, retained
//! series) **byte-identical** — in-process at 1/2/4 threads and through
//! real `--workers` subprocesses — while the sidecar
//! (`<out-dir>/telemetry/`) fills with schema-valid JSONL events and
//! per-shard heartbeat files. Also covers the hung-worker detection path:
//! a fake worker that beats once and then hangs (alive but silent) is
//! flagged by [`StallTracker`] exactly once per silence episode.

use std::path::{Path, PathBuf};
use std::process::Command;
use std::time::{Duration, Instant};

use cloudmarket::config::scenario::ComparisonConfig;
use cloudmarket::obs::{self, telemetry as tel, StallTracker, Telemetry};
use cloudmarket::sweep::{self, PolicySpec, SeriesFilter, SweepReport, SweepSpec};
use cloudmarket::util::json::{parse, Json};

const BIN: &str = env!("CARGO_BIN_EXE_cloudmarket");

fn test_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("cloudmarket_sweep_telemetry_{}_{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// The serialized artifact set of a report: exactly what the CLI writes.
fn render(report: &SweepReport) -> (String, String, Vec<(usize, String)>) {
    (
        report.cells_csv().to_string(),
        report.aggregate_json().to_string_pretty(),
        report
            .retained_series_csvs()
            .into_iter()
            .map(|(id, csv)| (id, csv.to_string()))
            .collect(),
    )
}

/// A small comparison-substrate grid: 2 seeds x 2 policies = 4 cells,
/// first-fit series retained.
fn small_spec() -> SweepSpec {
    let scenario = ComparisonConfig { terminate_at: 400.0, ..Default::default() };
    SweepSpec::new(scenario)
        .with_seeds(vec![20_250_710, 20_250_711])
        .with_policies(vec![PolicySpec::FirstFit, PolicySpec::Hlem { adjusted: true, alpha: -0.5 }])
        .with_series_retention(SeriesFilter::parse("policy=first-fit").unwrap())
}

/// Count schema-validated events of one kind in a run log.
fn count(lines: &[Json], name: &str) -> usize {
    lines.iter().filter(|l| obs::validate_event(l) == Ok(name)).count()
}

/// In-process: `run_observed` with a sidecar produces byte-identical
/// artifacts to the unobserved `run` at 1, 2 and 4 threads, and every
/// sidecar line validates against the schema with the expected per-cell
/// span structure.
#[test]
fn observed_run_artifacts_byte_identical_at_any_thread_count() {
    let spec = small_spec();
    let reference = sweep::run(&spec, 2);
    assert_eq!(reference.failed(), 0, "no cell may fail");
    let want = render(&reference);

    for threads in [1usize, 2, 4] {
        let dir = test_dir(&format!("inproc_{threads}t"));
        let t = Telemetry::create(&dir).unwrap();
        t.emit(tel::run_start("test", spec.cell_count(), 2, 2, "threads", threads));
        let (report, timing) = sweep::run_observed(&spec, threads, None, Some(&t));
        t.emit(tel::run_end(
            report.failed() == 0,
            timing.wall,
            timing.prebuild_busy,
            timing.cell_busy,
            timing.merge,
            timing.first_cell_done,
            timing.prebuilds_built,
        ));
        drop(t);
        assert_eq!(
            render(&report),
            want,
            "{threads}-thread observed artifacts differ from the unobserved run"
        );

        let lines = obs::read_jsonl(&obs::telemetry_dir(&dir).join(obs::RUN_LOG)).unwrap();
        for (i, line) in lines.iter().enumerate() {
            obs::validate_event(line).unwrap_or_else(|e| panic!("line {}: {e}", i + 1));
        }
        assert_eq!(count(&lines, "cell_start"), spec.cell_count());
        assert_eq!(count(&lines, "cell_end"), spec.cell_count());
        assert_eq!(count(&lines, "run_start"), 1);
        assert_eq!(count(&lines, "run_end"), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// Flags for a tiny trace-substrate grid (2 seeds x 2 policies = 4
/// cells), mirroring the cross-process CLI test in `sweep_process.rs`.
const CLI_GRID: &[&str] = &[
    "--seeds",
    "2",
    "--seed",
    "42",
    "--policies",
    "first-fit,hlem-vmp",
    "--substrate",
    "trace",
    "--machines",
    "10",
    "--days",
    "0.05",
    "--spots",
    "20",
    "--max-vms",
    "50",
    "--retain-series",
    "policy=first-fit",
];

fn run_cli(args: &[&str]) -> std::process::Output {
    Command::new(BIN)
        .arg("sweep")
        .args(CLI_GRID)
        .args(args)
        .env_remove("CLOUDMARKET_SWEEP_FAULT")
        .output()
        .expect("running cloudmarket sweep")
}

/// Every top-level artifact file (name + bytes), excluding the sidecar.
fn artifact_files(dir: &Path) -> Vec<(String, Vec<u8>)> {
    let mut out: Vec<(String, Vec<u8>)> = std::fs::read_dir(dir)
        .unwrap()
        .flatten()
        .filter(|e| e.file_name().to_string_lossy() != obs::TELEMETRY_DIR)
        .map(|e| {
            (e.file_name().to_string_lossy().into_owned(), std::fs::read(e.path()).unwrap())
        })
        .collect();
    out.sort();
    out
}

/// Through real subprocesses: `--telemetry` (single-process and
/// `--workers 2`) leaves every artifact byte-identical to the
/// telemetry-off run, fills the sidecar with valid events including one
/// heartbeat file per shard, and `sweep status` renders the result.
#[test]
fn cli_telemetry_keeps_artifacts_byte_identical_and_writes_sidecar() {
    let off = test_dir("cli_off");
    let out = run_cli(&["--threads", "1", "--out-dir", off.to_str().unwrap()]);
    assert!(out.status.success(), "telemetry-off sweep failed: {out:?}");
    let want = artifact_files(&off);
    assert!(!obs::telemetry_dir(&off).exists(), "no sidecar may appear without --telemetry");

    // Single-process with telemetry (and the phase table on stderr).
    let tp = test_dir("cli_tp");
    let out =
        run_cli(&["--threads", "2", "--telemetry", "--verbose", "--out-dir", tp.to_str().unwrap()]);
    assert!(out.status.success(), "telemetry sweep failed: {out:?}");
    assert_eq!(artifact_files(&tp), want, "telemetry-on artifacts differ (threads mode)");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("Sweep phase breakdown"), "--verbose phase table missing:\n{stderr}");
    let lines = obs::read_jsonl(&obs::telemetry_dir(&tp).join(obs::RUN_LOG)).unwrap();
    for line in &lines {
        obs::validate_event(line).unwrap();
    }
    assert_eq!(count(&lines, "cell_end"), 4);

    // Coordinator with telemetry: shard lifecycle events plus one
    // heartbeat file per shard, each ending on a completed final beat.
    let mp = test_dir("cli_mp");
    let out = run_cli(&["--workers", "2", "--telemetry", "--out-dir", mp.to_str().unwrap()]);
    assert!(out.status.success(), "coordinator telemetry sweep failed: {out:?}");
    assert_eq!(artifact_files(&mp), want, "telemetry-on artifacts differ (workers mode)");
    let tdir = obs::telemetry_dir(&mp);
    let lines = obs::read_jsonl(&tdir.join(obs::RUN_LOG)).unwrap();
    for line in &lines {
        obs::validate_event(line).unwrap();
    }
    assert_eq!(count(&lines, "run_start"), 1);
    assert_eq!(count(&lines, "shard_assign"), 2);
    assert_eq!(count(&lines, "shard_exit"), 2);
    assert_eq!(count(&lines, "merge"), 1);
    assert_eq!(count(&lines, "run_end"), 1);
    for shard in 0..2 {
        let path = obs::heartbeat_file(&tdir, shard);
        assert!(path.exists(), "missing heartbeat file for shard {shard}");
        let last = obs::read_last_heartbeat(&path)
            .unwrap_or_else(|| panic!("no valid beat in {}", path.display()));
        assert_eq!(last.shard, shard);
        assert_eq!(last.done, last.total, "final beat must report a completed shard");
        assert!(last.cell.is_none(), "the end beat carries no cell id");
    }

    // `sweep status` renders the sidecar.
    let out = Command::new(BIN)
        .args(["sweep", "status", mp.to_str().unwrap()])
        .output()
        .expect("running sweep status");
    assert!(out.status.success(), "sweep status failed: {out:?}");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("sweep status"), "{stdout}");
    assert!(stdout.contains("run finished: ok"), "{stdout}");
    assert!(stdout.contains("shards: 2 assigned"), "{stdout}");
    assert!(stdout.contains("Shard heartbeats"), "{stdout}");
    assert!(stdout.contains("Engine counter totals"), "{stdout}");

    for dir in [off, tp, mp] {
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// Schema round-trip property: heartbeat events built from a spread of
/// pseudo-random values survive serialize -> parse -> validate -> read
/// back with every field intact (including the `None` encodings).
#[test]
fn heartbeat_schema_roundtrip_property() {
    let dir = test_dir("roundtrip");
    let path = dir.join("beats.jsonl");
    let mut state = 0x2545_f491_4f6c_dd1du64;
    let mut next = move || {
        // xorshift64* - deterministic spread, no external crates.
        state ^= state >> 12;
        state ^= state << 25;
        state ^= state >> 27;
        state = state.wrapping_mul(0x2545_f491_4f6c_dd1d);
        state
    };
    let mut wrote = Vec::new();
    let mut text = String::new();
    for _ in 0..100 {
        let shard = (next() % 64) as usize;
        let done = (next() % 1000) as usize;
        let total = done + (next() % 1000) as usize;
        let cell = (next() % 2 == 0).then(|| (next() % 4096) as usize);
        let rss = (next() % 2 == 0).then(|| (next() % 10_000) as f64 / 10.0);
        let event = tel::heartbeat_event(shard, done, total, cell, rss);
        text.push_str(&Json::Obj(event).to_string_compact());
        text.push('\n');
        wrote.push((shard, done, total, cell, rss));
    }
    std::fs::write(&path, &text).unwrap();

    let lines = obs::read_jsonl(&path).unwrap();
    assert_eq!(lines.len(), wrote.len());
    for (line, (shard, done, total, cell, rss)) in lines.iter().zip(&wrote) {
        assert_eq!(obs::validate_event(line), Ok("heartbeat"));
        // Round-trip a second time through the compact writer: the schema
        // must be stable under re-serialization.
        let twice = parse(&Json::to_string_compact(line)).unwrap();
        assert_eq!(obs::validate_event(&twice), Ok("heartbeat"));
        let o = line.as_obj().unwrap();
        let num = |k: &str| o.get(k).and_then(Json::as_f64);
        assert_eq!(num("shard"), Some(*shard as f64));
        assert_eq!(num("done"), Some(*done as f64));
        assert_eq!(num("total"), Some(*total as f64));
        assert_eq!(num("cell"), cell.map(|c| c as f64));
        assert_eq!(num("rss_mb"), *rss);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// A worker that is alive but hung: the fake worker writes one heartbeat
/// and then sleeps forever. The stall tracker must flag it (once per
/// silence episode) while the process is demonstrably still running -
/// this is the case exit-code reaping can never catch.
#[cfg(unix)]
#[test]
fn stall_tracker_flags_alive_but_silent_fake_worker() {
    use std::os::unix::fs::PermissionsExt;

    let dir = test_dir("hung");
    let hb_path = obs::heartbeat_file(&dir, 0);
    let line = Json::Obj(tel::heartbeat_event(0, 1, 8, Some(3), Some(10.0))).to_string_compact();
    let exe = dir.join("fake_worker.sh");
    std::fs::write(
        &exe,
        format!("#!/bin/sh\nprintf '%s\\n' '{line}' > {}\nsleep 60\n", hb_path.display()),
    )
    .unwrap();
    std::fs::set_permissions(&exe, std::fs::Permissions::from_mode(0o755)).unwrap();
    let mut child = Command::new(&exe).spawn().expect("spawning fake worker");

    // Wait for the single beat to land.
    let deadline = Instant::now() + Duration::from_secs(10);
    let beat = loop {
        if let Some(b) = obs::read_last_heartbeat(&hb_path) {
            break b;
        }
        assert!(Instant::now() < deadline, "fake worker never wrote its beat");
        std::thread::sleep(Duration::from_millis(10));
    };
    assert_eq!((beat.shard, beat.done, beat.cell), (0, 1, Some(3)));

    let mut tracker = StallTracker::new(Duration::from_millis(100));
    tracker.watch(0, Instant::now());
    // First observation registers the beat as progress - no warning.
    assert!(tracker.observe(0, Some(beat), Instant::now()).is_none());

    // The worker stays silent past the threshold while provably alive.
    std::thread::sleep(Duration::from_millis(150));
    assert!(
        child.try_wait().expect("try_wait").is_none(),
        "fake worker must still be running - that is the whole point"
    );
    let warn = tracker
        .observe(0, obs::read_last_heartbeat(&hb_path), Instant::now())
        .expect("silent-but-alive worker must be flagged");
    assert_eq!(warn.shard, 0);
    assert!(warn.silent >= Duration::from_millis(100));
    assert_eq!(warn.last.expect("last progress recorded").done, 1);
    // Once per episode: the same silence does not warn again.
    assert!(tracker.observe(0, obs::read_last_heartbeat(&hb_path), Instant::now()).is_none());

    let _ = child.kill();
    let _ = child.wait();
    let _ = std::fs::remove_dir_all(&dir);
}
