//! Integration: the PJRT-executed AOT artifacts agree with the pure-rust
//! implementations (the cross-implementation correctness contract of
//! DESIGN.md §5). Skips (with a notice) when `make artifacts` has not run.
//! Compiled only with `--features pjrt` (the default build is std-only
//! and carries no PJRT runtime).
#![cfg(feature = "pjrt")]

use std::rc::Rc;

use cloudmarket::allocation::scorer::{HostScorer, RustScorer, ScoreInput};
use cloudmarket::engine::progress::{BatchedBackend, ProgressBackend};
use cloudmarket::runtime::{artifacts, PjrtBackend, PjrtEngine, PjrtScorer, PjrtStep};
use cloudmarket::stats::Rng;

fn engine_or_skip() -> Option<Rc<PjrtEngine>> {
    if !artifacts::artifacts_available() {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    Some(Rc::new(PjrtEngine::load_default().expect("loading artifacts")))
}

fn random_hosts(rng: &mut Rng, n: usize) -> (Vec<[f64; 4]>, Vec<[f64; 4]>, Vec<[f64; 4]>, Vec<bool>) {
    let mut caps = Vec::new();
    let mut free = Vec::new();
    let mut spot = Vec::new();
    let mut mask = Vec::new();
    for _ in 0..n {
        let mut c = [0.0; 4];
        let mut f = [0.0; 4];
        let mut s = [0.0; 4];
        for d in 0..4 {
            c[d] = rng.uniform(1.0, 1e5);
            f[d] = c[d] * rng.next_f64();
            s[d] = f[d] * rng.next_f64();
        }
        caps.push(c);
        free.push(f);
        spot.push(s);
        mask.push(rng.chance(0.85));
    }
    if !mask.iter().any(|&m| m) {
        mask[0] = true;
    }
    (caps, free, spot, mask)
}

#[test]
fn pjrt_engine_loads_and_reports_platform() {
    let Some(engine) = engine_or_skip() else { return };
    assert!(engine.platform().to_lowercase().contains("cpu"));
    assert_eq!(engine.manifest.dims, 4);
}

#[test]
fn pjrt_scorer_matches_rust_scorer() {
    let Some(engine) = engine_or_skip() else { return };
    let mut pjrt = PjrtScorer::new(engine.clone());
    let mut rust = RustScorer::new();
    let mut rng = Rng::new(2024);
    for case in 0..20 {
        let n = 1 + (rng.below(engine.manifest.max_hosts as u64) as usize);
        let (caps, free, spot, mask) = random_hosts(&mut rng, n);
        let alpha = rng.uniform(-1.0, 1.0);
        let input =
            ScoreInput { caps: &caps, free: &free, spot_used: &spot, mask: &mask, alpha };
        let (hs_p, ahs_p) = pjrt.scores(&input);
        let (hs_r, ahs_r) = rust.scores(&input);
        for i in 0..n {
            if !mask[i] {
                assert!(hs_p[i] < -1e29 && hs_r[i] < -1e29);
                continue;
            }
            // f32 artifact vs f64 oracle: 1e-4 absolute on [0,1]-scaled scores.
            assert!(
                (hs_p[i] - hs_r[i]).abs() < 1e-4,
                "case {case} host {i}: hs {} vs {}",
                hs_p[i],
                hs_r[i]
            );
            assert!(
                (ahs_p[i] - ahs_r[i]).abs() < 1e-3,
                "case {case} host {i}: ahs {} vs {}",
                ahs_p[i],
                ahs_r[i]
            );
        }
    }
    assert!(pjrt.pjrt_calls >= 20);
    assert_eq!(pjrt.fallback_calls, 0);
}

#[test]
fn pjrt_scorer_falls_back_beyond_max_hosts() {
    let Some(engine) = engine_or_skip() else { return };
    let n = engine.manifest.max_hosts + 7;
    let mut pjrt = PjrtScorer::new(engine);
    let mut rng = Rng::new(7);
    let (caps, free, spot, mask) = random_hosts(&mut rng, n);
    let input =
        ScoreInput { caps: &caps, free: &free, spot_used: &spot, mask: &mask, alpha: -0.5 };
    let (hs, _) = pjrt.scores(&input);
    assert_eq!(hs.len(), n);
    assert_eq!(pjrt.fallback_calls, 1);
    assert_eq!(pjrt.pjrt_calls, 0);
}

#[test]
fn pjrt_progress_backend_matches_batched() {
    let Some(engine) = engine_or_skip() else { return };
    let mut pjrt = PjrtBackend(PjrtStep::new(engine.clone()));
    let mut rng = Rng::new(11);
    // Larger than one artifact batch to exercise chunking.
    let n = engine.manifest.max_cloudlets + 123;
    let rem0: Vec<f64> = (0..n)
        .map(|_| if rng.chance(0.2) { 0.0 } else { rng.uniform(1.0, 1e6) })
        .collect();
    let mips: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 5e3)).collect();
    let dt = 2.5;

    let mut rem_p = rem0.clone();
    let mut fin_p = Vec::new();
    pjrt.step(&mut rem_p, &mips, dt, &mut fin_p);

    let mut rem_b = rem0.clone();
    let mut fin_b = Vec::new();
    BatchedBackend.step(&mut rem_b, &mips, dt, &mut fin_b);

    let scale = 1e6_f64;
    let mut boundary = 0;
    for i in 0..n {
        assert!(
            (rem_p[i] - rem_b[i]).abs() < 1e-6 * scale + 1e-3,
            "slot {i}: {} vs {}",
            rem_p[i],
            rem_b[i]
        );
    }
    // finished sets may differ only on float-boundary slots
    fin_p.sort_unstable();
    fin_b.sort_unstable();
    let set_p: std::collections::HashSet<_> = fin_p.iter().collect();
    let set_b: std::collections::HashSet<_> = fin_b.iter().collect();
    for i in set_p.symmetric_difference(&set_b) {
        boundary += 1;
        assert!(rem_b[**i] < 1e-6 * scale + 1e-3, "non-boundary finished mismatch at {i}");
    }
    assert!(boundary <= 3, "too many boundary mismatches: {boundary}");
    assert!(pjrt.0.calls >= 2, "expected chunked execution");
}
