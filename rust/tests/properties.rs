//! Property tests over randomized workloads (testkit harness; proptest is
//! unavailable offline - see DESIGN.md §7).
//!
//! Each property builds a random cluster + random spot/on-demand workload
//! and checks engine invariants that must hold for *every* input:
//! capacity accounting, state partitioning, history well-formedness,
//! interruption bookkeeping and scorer semantics.

use cloudmarket::allocation::scorer::{HostScorer, RustScorer, ScoreInput, NEG};
use cloudmarket::allocation::{AllocationPolicy, BestFit, FirstFit, HlemVmp, RoundRobin, WorstFit};
use cloudmarket::cloudlet::Cloudlet;
use cloudmarket::core::{EntityId, EventQueue, HeapEventQueue, SimEvent};
use cloudmarket::engine::{Engine, EngineConfig, World};
use cloudmarket::infra::HostSpec;
use cloudmarket::stats::Rng;
use cloudmarket::testkit::{forall, gen};
use cloudmarket::vm::{Vm, VmSpec, VmState};

/// The slab/index-heap event queue pops the exact (time, seq) order of
/// the retained `BinaryHeap` oracle over randomized op sequences -
/// schedules, single pops, batch drains and terminate-style clears
/// (~10k ops across the cases).
#[test]
fn prop_slab_event_queue_matches_heap_oracle() {
    forall(8, 0x51AB, |rng| {
        let mut q: EventQueue<u32> = EventQueue::new();
        let mut oracle: HeapEventQueue<u32> = HeapEventQueue::new();
        let mut payload: u32 = 0;
        let mut horizon: f64 = 0.0;
        for _ in 0..1_250 {
            match rng.below(10) {
                // Schedule a burst (duplicate timestamps on purpose: the
                // FIFO tiebreak is the subtle part).
                0..=4 => {
                    let t = if rng.chance(0.3) {
                        horizon // exact duplicate of an earlier time
                    } else {
                        rng.uniform(0.0, 1e6)
                    };
                    horizon = t;
                    let burst = rng.range_u64(1, 4);
                    for _ in 0..burst {
                        let ev = SimEvent::new(t, EntityId::Kernel, EntityId::Kernel, payload);
                        q.push(ev.clone());
                        oracle.push(ev);
                        payload += 1;
                    }
                }
                // Pop one event from both; everything must agree.
                5..=7 => {
                    match (q.pop(), oracle.pop()) {
                        (None, None) => {}
                        (Some(a), Some(b)) => {
                            assert_eq!((a.time, a.seq, a.data), (b.time, b.seq, b.data));
                        }
                        (a, b) => panic!(
                            "queue lengths diverged: slab={:?} oracle={:?}",
                            a.is_some(),
                            b.is_some()
                        ),
                    }
                    assert_eq!(q.next_time(), oracle.next_time());
                    assert_eq!(q.len(), oracle.len());
                }
                // Batch-drain everything due by a random deadline.
                8 => {
                    let t = rng.uniform(0.0, 1.2e6);
                    let (mut a, mut b) = (Vec::new(), Vec::new());
                    q.pop_due_into(t, &mut a);
                    oracle.pop_due_into(t, &mut b);
                    assert_eq!(
                        a.iter().map(|e| (e.time.to_bits(), e.seq, e.data)).collect::<Vec<_>>(),
                        b.iter().map(|e| (e.time.to_bits(), e.seq, e.data)).collect::<Vec<_>>()
                    );
                }
                // Terminate-style clear (sequence numbering continues).
                _ => {
                    q.clear();
                    oracle.clear();
                    assert!(q.is_empty() && oracle.is_empty());
                }
            }
        }
        // Drain the remainder in lockstep.
        loop {
            match (q.pop(), oracle.pop()) {
                (None, None) => break,
                (Some(a), Some(b)) => {
                    assert_eq!((a.time, a.seq, a.data), (b.time, b.seq, b.data));
                }
                (a, b) => panic!(
                    "queue lengths diverged at drain: slab={:?} oracle={:?}",
                    a.is_some(),
                    b.is_some()
                ),
            }
        }
    });
}

/// Random engine with hosts, spot + on-demand VMs, and cloudlets.
fn random_engine(rng: &mut Rng) -> Engine {
    let policy: Box<dyn AllocationPolicy> = match rng.below(5) {
        0 => Box::new(FirstFit::new()),
        1 => Box::new(BestFit::new()),
        2 => Box::new(WorstFit::new()),
        3 => Box::new(RoundRobin::new()),
        _ => {
            if rng.chance(0.5) {
                Box::new(HlemVmp::plain())
            } else {
                Box::new(HlemVmp::adjusted())
            }
        }
    };
    let mut cfg = EngineConfig::default();
    cfg.vm_destruction_delay = rng.uniform(0.0, 2.0);
    cfg.scheduling_interval = rng.uniform(0.5, 5.0);
    let mut e = Engine::new(cfg, policy);
    let dc = e.add_datacenter("dc", 1.0);
    for _ in 0..rng.range_u64(1, 8) {
        e.add_host(dc, gen::host_spec(rng));
    }
    let n_vms = rng.range_u64(2, 30);
    for _ in 0..n_vms {
        let spec = gen::vm_spec(rng);
        let delay = rng.uniform(0.0, 60.0);
        let vm = if rng.chance(0.4) {
            let mut v = Vm::spot(0, spec, gen::spot_config(rng)).with_delay(delay);
            if rng.chance(0.7) {
                v = v.with_persistent(rng.uniform(10.0, 200.0));
            }
            e.submit_vm(v)
        } else {
            let mut v = Vm::on_demand(0, spec).with_delay(delay);
            if rng.chance(0.5) {
                v = v.with_persistent(rng.uniform(10.0, 200.0));
            }
            e.submit_vm(v)
        };
        for _ in 0..rng.range_u64(0, 3) {
            let pes = rng.range_u64(1, spec.pes as u64) as u32;
            let length = rng.uniform(1_000.0, 200_000.0);
            e.submit_cloudlet(Cloudlet::new(0, length, pes).with_vm(vm));
        }
    }
    e.terminate_at(rng.uniform(100.0, 400.0));
    e
}

#[test]
fn prop_host_accounting_never_violated() {
    forall(60, 0xACC0, |rng| {
        let mut e = random_engine(rng);
        e.run();
        // The incremental placement index survived the whole run.
        e.world.check_index().expect("index matches recompute oracle after run");
        for host in &e.world.hosts {
            assert!(host.used_pes <= host.spec.pes, "host {} PEs oversubscribed", host.id);
            assert!(host.used_ram <= host.spec.ram + 1e-6, "host {} RAM", host.id);
            assert!(host.used_bw <= host.spec.bw + 1e-6, "host {} BW", host.id);
            assert!(host.used_storage <= host.spec.storage + 1e-6, "host {} storage", host.id);
            let mut pes = 0;
            for &v in &host.vms {
                assert!(e.world.vms[v].state.on_host());
                assert_eq!(e.world.vms[v].host, Some(host.id));
                pes += e.world.vms[v].spec.pes;
            }
            assert_eq!(pes, host.used_pes);
        }
    });
}

#[test]
fn prop_vm_states_and_hosts_consistent() {
    forall(60, 0x57A7E, |rng| {
        let mut e = random_engine(rng);
        let report = e.run();
        let mut on_host = 0u64;
        for vm in &e.world.vms {
            match vm.state {
                VmState::Running | VmState::InterruptWarned => {
                    assert!(vm.host.is_some(), "vm {} running without host", vm.id);
                    assert!(vm.history.is_running(), "vm {} open interval missing", vm.id);
                    on_host += 1;
                }
                VmState::Hibernated | VmState::Waiting => {
                    assert!(vm.host.is_none(), "vm {} parked but on host", vm.id);
                }
                VmState::Finished | VmState::Terminated | VmState::Failed => {
                    assert!(vm.host.is_none());
                    assert!(vm.stopped_at.is_some(), "vm {} final without stop time", vm.id);
                    assert!(!vm.history.is_running(), "vm {} final with open interval", vm.id);
                }
            }
        }
        assert_eq!(report.still_active + report.finished + report.terminated + report.failed,
            e.world.vms.len() as u64);
        let _ = on_host;
    });
}

#[test]
fn prop_histories_well_formed() {
    forall(60, 0x415709, |rng| {
        let mut e = random_engine(rng);
        e.run();
        let end = e.sim.clock();
        for vm in &e.world.vms {
            let ivs = vm.history.intervals();
            for iv in ivs {
                assert!(iv.start >= -1e-9 && iv.start <= end + 1e-6);
                if let Some(stop) = iv.stop {
                    assert!(stop + 1e-9 >= iv.start, "vm {} negative interval", vm.id);
                    assert!(stop <= end + 1e-6);
                }
            }
            for pair in ivs.windows(2) {
                assert!(pair[0].stop.is_some(), "vm {} non-final open interval", vm.id);
                assert!(pair[1].start + 1e-9 >= pair[0].stop.unwrap());
            }
            for gap in vm.history.interruption_durations() {
                assert!(gap >= -1e-9);
            }
        }
    });
}

#[test]
fn prop_interruption_bookkeeping_consistent() {
    forall(60, 0x1717, |rng| {
        let mut e = random_engine(rng);
        let report = e.run();
        let per_vm: u64 = e.world.vms.iter().map(|v| v.interruptions as u64).sum();
        assert_eq!(per_vm, report.spot.interruptions);
        // Every interruption resolves to hibernation or termination;
        // spot_terminations additionally counts hibernation timeouts, so:
        assert!(e.recorder.hibernations <= report.spot.interruptions);
        assert!(
            report.spot.interruptions <= e.recorder.hibernations + e.recorder.spot_terminations,
            "interruptions {} > hibernations {} + terminations {}",
            report.spot.interruptions,
            e.recorder.hibernations,
            e.recorder.spot_terminations
        );
        // Redeployments never exceed hibernations.
        assert!(report.spot.redeployments <= e.recorder.hibernations);
        // On-demand VMs never count interruptions.
        for vm in &e.world.vms {
            if !vm.is_spot() {
                assert_eq!(vm.interruptions, 0, "od vm {} interrupted", vm.id);
            }
        }
    });
}

#[test]
fn prop_cloudlet_progress_monotone_and_bounded() {
    forall(60, 0xC10D, |rng| {
        let mut e = random_engine(rng);
        e.run();
        for cl in &e.world.cloudlets {
            assert!(cl.remaining_mi >= -1e-6, "negative remaining");
            assert!(cl.remaining_mi <= cl.length_mi + 1e-6, "remaining grew");
            if cl.state == cloudmarket::cloudlet::CloudletState::Finished {
                assert!(cl.remaining_mi <= 1e-6);
                assert!(cl.finished_at.is_some());
                if let (Some(s), Some(f)) = (cl.started_at, cl.finished_at) {
                    assert!(f + 1e-9 >= s);
                }
            }
        }
    });
}

#[test]
fn prop_simulation_is_deterministic() {
    forall(20, 0xDE7E, |rng| {
        let seed = rng.next_u64();
        let run = |seed: u64| {
            let mut r = Rng::new(seed);
            let mut e = random_engine(&mut r);
            let report = e.run();
            (
                report.events_processed,
                report.finished,
                report.spot.interruptions,
                (report.clock_end * 1e6) as u64,
            )
        };
        assert_eq!(run(seed), run(seed));
    });
}

// ---------------------------------------------------------------------
// placement-index properties
// ---------------------------------------------------------------------

/// Drive a randomized sequence of commit / release / host-add /
/// host-remove / host-reactivate operations directly against the world.
/// When `check_each_step` is set, the incremental index is compared to
/// the recompute-from-scratch oracle after *every* mutation.
fn random_index_workout(rng: &mut Rng, check_each_step: bool) -> World {
    let mut w = World::new();
    let dc = w.add_datacenter("dc", 1.0);
    for _ in 0..rng.range_u64(1, 10) {
        w.add_host(dc, gen::host_spec(rng), 0.0);
    }
    let mut placed: Vec<(usize, usize)> = Vec::new(); // (vm, host)
    let steps = rng.range_u64(20, 120);
    for step in 0..steps {
        match rng.below(100) {
            0..=44 => {
                // Commit a fresh VM on the first host where it fits.
                let spec = gen::vm_spec(rng);
                let vm = if rng.chance(0.5) {
                    w.add_vm(Vm::spot(0, spec, gen::spot_config(rng)))
                } else {
                    w.add_vm(Vm::on_demand(0, spec))
                };
                if let Some(h) = w.first_fit_host_scan(&w.vms[vm]) {
                    w.commit_vm(h, vm);
                    placed.push((vm, h));
                }
            }
            45..=74 => {
                // Release a random placed VM (deallocation / interrupt).
                if !placed.is_empty() {
                    let i = rng.below(placed.len() as u64) as usize;
                    let (vm, h) = placed.swap_remove(i);
                    w.release_vm(h, vm);
                }
            }
            75..=84 => {
                // Trace ADD: a new host joins mid-run.
                w.add_host(dc, gen::host_spec(rng), step as f64);
            }
            85..=92 => {
                // Trace REMOVE: evict a random active host.
                let active: Vec<usize> = w.active_hosts().map(|h| h.id).collect();
                if !active.is_empty() {
                    let h = active[rng.below(active.len() as u64) as usize];
                    let vms: Vec<usize> = w.hosts[h].vms.clone();
                    for vm in vms {
                        w.release_vm(h, vm);
                        placed.retain(|&(v, _)| v != vm);
                    }
                    w.deactivate_host(h, Some(step as f64));
                }
            }
            _ => {
                // Reactivate a previously removed host.
                let removed: Vec<usize> =
                    w.hosts.iter().filter(|h| !h.is_active()).map(|h| h.id).collect();
                if !removed.is_empty() {
                    let h = removed[rng.below(removed.len() as u64) as usize];
                    w.activate_host(h, step as f64);
                }
            }
        }
        if check_each_step {
            w.check_index().expect("index matches recompute oracle after mutation");
        }
    }
    w
}

#[test]
fn prop_placement_index_matches_recompute_oracle() {
    forall(40, 0x1D3C5, |rng| {
        let w = random_index_workout(rng, true);
        w.check_index().unwrap();
    });
}

#[test]
fn prop_indexed_queries_match_scan_oracles() {
    forall(40, 0x5CA9D, |rng| {
        let w = random_index_workout(rng, false);
        w.check_index().unwrap();
        // Placement decisions: the indexed queries must reproduce the
        // pre-index linear scans exactly for arbitrary probe requests.
        for _ in 0..8 {
            let probe = Vm::on_demand(0, gen::vm_spec(rng));
            assert_eq!(w.first_fit_host(&probe), w.first_fit_host_scan(&probe), "first-fit");
            assert_eq!(w.best_fit_host(&probe), w.best_fit_host_scan(&probe), "best-fit");
            assert_eq!(w.worst_fit_host(&probe), w.worst_fit_host_scan(&probe), "worst-fit");
            let (mut a, mut b) = (Vec::new(), Vec::new());
            w.feasible_host_ids(&probe, &mut a);
            w.feasible_host_ids_scan(&probe, &mut b);
            assert_eq!(a, b, "feasible candidate list (order-sensitive)");
        }
        // Degenerate probes: 1-PE requests whose RAM demand makes every
        // host feasible (ram=0), most hosts infeasible (the bounded-probe
        // first-fit exhausts its probe budget and falls back to the
        // linear tail scan), or no host feasible at all.
        for ram in [0.0, 60_000.0, 200_000.0, 1e9] {
            let mut probe = Vm::on_demand(0, gen::vm_spec(rng));
            probe.spec.pes = 1;
            probe.spec.ram = ram;
            probe.spec.bw = 1.0;
            probe.spec.storage = 1.0;
            assert_eq!(
                w.first_fit_host(&probe),
                w.first_fit_host_scan(&probe),
                "first-fit degenerate ram={ram}"
            );
            assert_eq!(
                w.best_fit_host(&probe),
                w.best_fit_host_scan(&probe),
                "best-fit degenerate ram={ram}"
            );
            assert_eq!(
                w.worst_fit_host(&probe),
                w.worst_fit_host_scan(&probe),
                "worst-fit degenerate ram={ram}"
            );
            let (mut a, mut b) = (Vec::new(), Vec::new());
            w.feasible_host_ids(&probe, &mut a);
            w.feasible_host_ids_scan(&probe, &mut b);
            assert_eq!(a, b, "feasible list degenerate ram={ram}");
        }
        // Spot-usage vectors: O(1) reads bitwise equal to the walk.
        for h in w.active_hosts() {
            assert_eq!(w.spot_used_vec(h), w.spot_used_vec_scan(h), "host {}", h.id);
        }
        // Spot-host set == recompute.
        let oracle: Vec<usize> = w
            .active_hosts()
            .filter(|h| h.vms.iter().any(|&v| w.vms[v].is_spot()))
            .map(|h| h.id)
            .collect();
        assert_eq!(w.spot_host_ids().collect::<Vec<_>>(), oracle);
    });
}

/// The O(1) incremental `state_sample` is *bitwise* equal to the walking
/// oracle after every single world mutation - commit, release, state
/// transition, displacement mark/clear and host lifecycle churn
/// (including duplicate activations/deactivations, which must be inert).
/// Runs both on integral-MB (dyadic) RAM values, where the counters must
/// never leave the exact O(1) path, and on non-dyadic values from the
/// default generators, which exercise the used/total-RAM fallback walk.
#[test]
fn prop_state_sample_matches_scan_after_every_op() {
    fn dyadic_host(rng: &mut Rng) -> HostSpec {
        // Power-of-two RAM (4 GB .. 256 GB): always exactly summable.
        HostSpec::new(
            rng.range_u64(1, 32) as u32,
            1000.0,
            (1u64 << rng.range_u64(12, 18)) as f64,
            10_000.0,
            500_000.0,
        )
    }
    fn dyadic_vm(rng: &mut Rng) -> VmSpec {
        VmSpec::new(1000.0, rng.range_u64(1, 8) as u32)
            .with_ram((1u64 << rng.range_u64(8, 13)) as f64)
            .with_bw(100.0)
            .with_storage(1_000.0)
    }

    forall(24, 0x5A3D1E, |rng| {
        let dyadic = rng.chance(0.5);
        let mut w = World::new();
        let dc = w.add_datacenter("dc", 1.0);
        for _ in 0..rng.range_u64(2, 8) {
            let spec = if dyadic { dyadic_host(rng) } else { gen::host_spec(rng) };
            w.add_host(dc, spec, 0.0);
        }
        // VMs currently on a host (Running / InterruptWarned) and VMs
        // parked off-host (Waiting / Hibernated); terminal VMs drop out.
        let mut placed: Vec<(usize, usize)> = Vec::new();
        let mut parked: Vec<usize> = Vec::new();
        let steps = rng.range_u64(40, 160);
        for step in 0..steps {
            let t = step as f64;
            match rng.below(100) {
                // Submit a fresh VM; place it first-fit or park it.
                0..=29 => {
                    let spec = if dyadic { dyadic_vm(rng) } else { gen::vm_spec(rng) };
                    let vm = if rng.chance(0.4) {
                        w.add_vm(Vm::spot(0, spec, gen::spot_config(rng)))
                    } else {
                        w.add_vm(Vm::on_demand(0, spec))
                    };
                    if let Some(h) = w.first_fit_host_scan(&w.vms[vm]) {
                        w.commit_vm(h, vm);
                        w.transition_vm(vm, VmState::Running);
                        placed.push((vm, h));
                    } else {
                        parked.push(vm); // stays Waiting
                    }
                }
                // Warn a running VM, or finish one off its host.
                30..=44 => {
                    if !placed.is_empty() {
                        let i = rng.below(placed.len() as u64) as usize;
                        let (vm, h) = placed[i];
                        if w.vms[vm].state == VmState::Running && rng.chance(0.5) {
                            w.transition_vm(vm, VmState::InterruptWarned);
                        } else {
                            placed.swap_remove(i);
                            w.transition_vm(vm, VmState::Finished);
                            w.release_vm(h, vm);
                        }
                    }
                }
                // Displace: off the host into Hibernated (spot interrupt)
                // or Waiting (on-demand requeue), gauge raised.
                45..=59 => {
                    if !placed.is_empty() {
                        let i = rng.below(placed.len() as u64) as usize;
                        let (vm, h) = placed.swap_remove(i);
                        w.release_vm(h, vm);
                        if w.vms[vm].state == VmState::InterruptWarned || rng.chance(0.5) {
                            w.transition_vm(vm, VmState::Hibernated);
                            w.set_hibernated_at(vm, Some(t));
                        } else {
                            w.transition_vm(vm, VmState::Waiting);
                        }
                        w.mark_displaced(vm, t);
                        parked.push(vm);
                    }
                }
                // Terminal path for a parked (possibly displaced) VM: the
                // displaced gauge must auto-clear on the transition.
                60..=69 => {
                    if !parked.is_empty() {
                        let i = rng.below(parked.len() as u64) as usize;
                        let vm = parked.swap_remove(i);
                        match w.vms[vm].state {
                            VmState::Hibernated => w.transition_vm(vm, VmState::Terminated),
                            _ => w.transition_vm(vm, VmState::Failed),
                        }
                        assert!(
                            w.vms[vm].displaced_at.is_none(),
                            "terminal transition must clear displaced_at"
                        );
                    }
                }
                // Resume / first placement of a parked VM.
                70..=79 => {
                    if !parked.is_empty() {
                        let i = rng.below(parked.len() as u64) as usize;
                        let vm = parked[i];
                        if let Some(h) = w.first_fit_host_scan(&w.vms[vm]) {
                            parked.swap_remove(i);
                            w.commit_vm(h, vm);
                            w.transition_vm(vm, VmState::Running);
                            w.set_hibernated_at(vm, None);
                            let _ = w.take_displaced(vm);
                            placed.push((vm, h));
                        }
                    }
                }
                // Trace ADD: a new host joins mid-run.
                80..=85 => {
                    let spec = if dyadic { dyadic_host(rng) } else { gen::host_spec(rng) };
                    w.add_host(dc, spec, t);
                }
                // Trace REMOVE / crash: evict residents, deactivate -
                // sometimes twice (the duplicate must be inert).
                86..=92 => {
                    let active: Vec<usize> = w.active_hosts().map(|h| h.id).collect();
                    if !active.is_empty() {
                        let h = active[rng.below(active.len() as u64) as usize];
                        let vms: Vec<usize> = w.hosts[h].vms.clone();
                        for vm in vms {
                            w.release_vm(h, vm);
                            placed.retain(|&(v, _)| v != vm);
                            if w.vms[vm].state == VmState::Running && rng.chance(0.5) {
                                w.transition_vm(vm, VmState::Waiting);
                            } else {
                                w.transition_vm(vm, VmState::Hibernated);
                                w.set_hibernated_at(vm, Some(t));
                            }
                            w.mark_displaced(vm, t);
                            parked.push(vm);
                        }
                        let removed_at = rng.chance(0.7).then_some(t);
                        w.deactivate_host(h, removed_at);
                        if rng.chance(0.25) {
                            w.deactivate_host(h, Some(t + 0.5));
                        }
                    }
                }
                // Reactivate a down host - sometimes twice (idempotent).
                _ => {
                    let inactive: Vec<usize> =
                        w.hosts.iter().filter(|h| !h.is_active()).map(|h| h.id).collect();
                    if !inactive.is_empty() {
                        let h = inactive[rng.below(inactive.len() as u64) as usize];
                        w.activate_host(h, t);
                        if rng.chance(0.25) {
                            w.activate_host(h, t + 0.5);
                        }
                    }
                }
            }
            assert!(
                w.state_sample().bits_eq(&w.state_sample_scan()),
                "incremental sample diverged from scan oracle at step {step} (dyadic={dyadic})"
            );
            if step % 8 == 0 {
                w.check_index().expect("index + SoA mirrors consistent mid-workout");
            }
        }
        w.check_index().unwrap();
        if dyadic {
            assert!(
                w.sample_is_incremental(),
                "integral-MB workload must never trip the RAM exactness guard"
            );
        }
    });
}

// ---------------------------------------------------------------------
// scorer properties
// ---------------------------------------------------------------------

fn random_score_input(rng: &mut Rng, n: usize) -> (Vec<[f64; 4]>, Vec<[f64; 4]>, Vec<[f64; 4]>, Vec<bool>) {
    let mut caps = Vec::new();
    let mut free = Vec::new();
    let mut spot = Vec::new();
    let mut mask = Vec::new();
    for _ in 0..n {
        let mut c = [0.0; 4];
        let mut f = [0.0; 4];
        let mut s = [0.0; 4];
        for d in 0..4 {
            c[d] = rng.uniform(1.0, 1e4);
            f[d] = c[d] * rng.next_f64();
            s[d] = f[d] * rng.next_f64();
        }
        caps.push(c);
        free.push(f);
        spot.push(s);
        mask.push(rng.chance(0.8));
    }
    if !mask.iter().any(|&m| m) {
        mask[0] = true;
    }
    (caps, free, spot, mask)
}

#[test]
fn prop_scorer_permutation_equivariant() {
    forall(40, 0x5C03E, |rng| {
        let n = 2 + rng.below(20) as usize;
        let (caps, free, spot, mask) = random_score_input(rng, n);
        let mut scorer = RustScorer::new();
        let (hs, ahs) = scorer.scores(&ScoreInput {
            caps: &caps, free: &free, spot_used: &spot, mask: &mask, alpha: -0.5,
        });
        // Apply a random permutation.
        let mut perm: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut perm);
        let pc: Vec<_> = perm.iter().map(|&i| caps[i]).collect();
        let pf: Vec<_> = perm.iter().map(|&i| free[i]).collect();
        let ps: Vec<_> = perm.iter().map(|&i| spot[i]).collect();
        let pm: Vec<_> = perm.iter().map(|&i| mask[i]).collect();
        let (hs_p, ahs_p) = scorer.scores(&ScoreInput {
            caps: &pc, free: &pf, spot_used: &ps, mask: &pm, alpha: -0.5,
        });
        for (j, &i) in perm.iter().enumerate() {
            assert!((hs_p[j] - hs[i]).abs() < 1e-9, "hs not equivariant");
            assert!((ahs_p[j] - ahs[i]).abs() < 1e-9, "ahs not equivariant");
        }
    });
}

#[test]
fn prop_scorer_masked_rows_inert() {
    forall(40, 0x111A5, |rng| {
        let n = 3 + rng.below(16) as usize;
        let (caps, free, spot, mut mask) = random_score_input(rng, n);
        mask[0] = false;
        let mut scorer = RustScorer::new();
        let base = scorer.scores(&ScoreInput {
            caps: &caps, free: &free, spot_used: &spot, mask: &mask, alpha: -0.3,
        });
        // Garbage in the masked row must not change anything.
        let mut caps2 = caps.clone();
        let mut free2 = free.clone();
        let mut spot2 = spot.clone();
        caps2[0] = [9e9; 4];
        free2[0] = [8e9; 4];
        spot2[0] = [7e9; 4];
        let alt = scorer.scores(&ScoreInput {
            caps: &caps2, free: &free2, spot_used: &spot2, mask: &mask, alpha: -0.3,
        });
        for i in 1..n {
            assert!((base.0[i] - alt.0[i]).abs() < 1e-9);
            assert!((base.1[i] - alt.1[i]).abs() < 1e-9);
        }
        assert_eq!(base.0[0], NEG);
    });
}

#[test]
fn prop_scorer_scores_bounded() {
    forall(40, 0xB0B, |rng| {
        let n = 1 + rng.below(32) as usize;
        let (caps, free, spot, mask) = random_score_input(rng, n);
        let alpha = rng.uniform(-1.0, 1.0);
        let (hs, ahs) = RustScorer::new().scores(&ScoreInput {
            caps: &caps, free: &free, spot_used: &spot, mask: &mask, alpha,
        });
        for i in 0..n {
            if mask[i] {
                assert!((-1e-9..=1.0 + 1e-9).contains(&hs[i]), "hs[{i}]={}", hs[i]);
                assert!(ahs[i].is_finite());
                // |AHS| <= |HS| * (1 + |alpha|) since SL in [0,1].
                assert!(ahs[i].abs() <= hs[i].abs() * (1.0 + alpha.abs()) + 1e-9);
            } else {
                assert_eq!(hs[i], NEG);
                assert_eq!(ahs[i], NEG);
            }
        }
    });
}

/// Shard partitioning over randomized multi-axis grids: shards are
/// disjoint, their union is exactly the enumerated cell set, greedy-LPT
/// balance holds (`max <= min + heaviest cell`), and both the spec and a
/// shard job file survive the serialize/deserialize round trip unchanged
/// (the cluster fan-out protocol of `sweep::shard`).
#[test]
fn prop_shard_partition_covers_disjointly_and_round_trips() {
    use cloudmarket::chaos::{BrokerOutage, DemandSurge, HostMtbf, ReclaimStorm};
    use cloudmarket::config::scenario::ComparisonConfig;
    use cloudmarket::engine::VictimPolicy;
    use cloudmarket::sweep::{
        shard, PolicySpec, ScenarioAxis, SeriesFilter, Substrate, SweepSpec,
    };
    use cloudmarket::util::json::parse;

    forall(24, 0x5AAD, |rng| {
        let scenario = ComparisonConfig {
            seed: rng.range_u64(1, 1u64 << 40),
            terminate_at: rng.uniform(100.0, 5_000.0),
            ..Default::default()
        };
        let mut policies = vec![
            PolicySpec::FirstFit,
            PolicySpec::BestFit,
            PolicySpec::WorstFit,
            PolicySpec::RoundRobin,
            PolicySpec::Hlem { adjusted: false, alpha: 0.0 },
            PolicySpec::Hlem { adjusted: true, alpha: rng.uniform(-1.0, 0.0) },
        ];
        rng.shuffle(&mut policies);
        policies.truncate(1 + rng.below(3) as usize);
        let n_seeds = 1 + rng.below(3) as usize;
        let mut spec = SweepSpec::new(scenario)
            .with_seeds((0..n_seeds).map(|_| rng.next_u64()).collect())
            .with_policies(policies);
        if rng.chance(0.5) {
            let n = 1 + rng.below(3);
            spec = spec.with_axis(ScenarioAxis::SpotWarning(
                (0..n).map(|_| rng.uniform(0.0, 300.0)).collect(),
            ));
        }
        if rng.chance(0.5) {
            spec = spec.with_axis(ScenarioAxis::Substrate(if rng.chance(0.5) {
                vec![Substrate::Comparison, Substrate::Trace]
            } else {
                vec![Substrate::Trace]
            }));
        }
        if rng.chance(0.3) {
            spec = spec.with_axis(ScenarioAxis::Victim(vec![VictimPolicy::Youngest]));
        }
        if rng.chance(0.4) {
            let n = 1 + rng.below(2);
            spec = spec.with_axis(ScenarioAxis::ChaosReclaimStorm(
                (0..n)
                    .map(|_| {
                        if rng.chance(0.5) {
                            ReclaimStorm {
                                at: rng.uniform(0.0, 4_000.0),
                                frac: 0.25 + 0.5 * rng.uniform(0.0, 1.0),
                                count: 1,
                                every: 0.0,
                            }
                        } else {
                            ReclaimStorm {
                                at: rng.uniform(0.0, 4_000.0),
                                frac: 0.25 + 0.5 * rng.uniform(0.0, 1.0),
                                count: 2 + rng.below(3) as u32,
                                every: rng.uniform(10.0, 400.0),
                            }
                        }
                    })
                    .collect(),
            ));
        }
        if rng.chance(0.3) {
            spec = spec.with_axis(ScenarioAxis::ChaosHostMtbf(vec![HostMtbf {
                mtbf: rng.uniform(50.0, 2_000.0),
                mttr: rng.uniform(5.0, 500.0),
            }]));
        }
        if rng.chance(0.3) {
            spec = spec.with_axis(ScenarioAxis::ChaosBrokerOutage(vec![BrokerOutage {
                at: rng.uniform(0.0, 3_000.0),
                dur: rng.uniform(1.0, 600.0),
            }]));
        }
        if rng.chance(0.3) {
            spec = spec.with_axis(ScenarioAxis::ChaosDemandSurge(vec![DemandSurge {
                at: rng.uniform(0.0, 3_000.0),
                vms: 1 + rng.below(30) as u32,
                pes: 1 + rng.below(4) as u32,
                dur: rng.uniform(10.0, 600.0),
            }]));
        }
        if rng.chance(0.4) {
            let n = 1 + rng.below(3);
            spec = spec.with_axis(ScenarioAxis::MarketVolatility(
                (0..n).map(|_| rng.uniform(0.0, 0.5)).collect(),
            ));
        }
        if rng.chance(0.3) {
            spec = spec.with_axis(ScenarioAxis::MarketMeanReversion(vec![rng
                .uniform(1e-5, 1e-2)]));
        }
        if rng.chance(0.3) {
            spec = spec.with_axis(ScenarioAxis::MarketDailyAmplitude(vec![rng
                .uniform(0.0, 1.0)]));
        }
        if rng.chance(0.3) {
            spec = spec.with_axis(ScenarioAxis::MarketBidMargin(vec![rng.uniform(0.1, 2.0)]));
        }
        if rng.chance(0.4) {
            use cloudmarket::recovery::RecoveryMode;
            let modes = [
                RecoveryMode::None,
                RecoveryMode::Restart,
                RecoveryMode::Checkpoint,
                RecoveryMode::MigrateGreedy,
                RecoveryMode::MigrateOptimal,
            ];
            let n = 1 + rng.below(3);
            spec = spec.with_axis(ScenarioAxis::RecoveryMode(
                (0..n).map(|_| modes[rng.below(5) as usize]).collect(),
            ));
        }
        if rng.chance(0.3) {
            spec = spec
                .with_axis(ScenarioAxis::RecoveryBandwidth(vec![rng.uniform(1.0, 500.0)]));
        }
        if rng.chance(0.3) {
            spec = spec.with_axis(ScenarioAxis::RecoveryCheckpointThreshold(vec![rng
                .uniform(0.0, 1.0)]));
        }
        if rng.chance(0.3) {
            spec = spec.with_cell(rng.next_u64(), PolicySpec::BestFit);
        }
        if rng.chance(0.5) {
            spec = spec
                .with_series_retention(SeriesFilter::parse("policy=first-fit,seed=3").unwrap());
        }

        // The spec round-trips through its wire form unchanged - and so
        // does the grid it enumerates.
        let text = shard::spec_to_json(&spec).to_string_pretty();
        let back = shard::spec_from_json(&parse(&text).unwrap()).unwrap();
        assert_eq!(back, spec, "spec changed across serialize/deserialize");
        assert_eq!(back.cells(), spec.cells());
        assert_eq!(shard::spec_digest(&back), shard::spec_digest(&spec));

        let total = spec.cell_count();
        let cells = spec.cells();
        let shards = 1 + rng.below(8) as usize;
        let parts = shard::partition(&spec, shards);
        assert_eq!(parts.len(), shards.min(total.max(1)));

        let mut seen = vec![false; total];
        for (i, p) in parts.iter().enumerate() {
            assert_eq!(p.index, i);
            assert_eq!(p.of, parts.len());
            let mut weight = 0;
            for pair in p.cell_ids.windows(2) {
                assert!(pair[0] < pair[1], "shard ids must ascend");
            }
            for &id in &p.cell_ids {
                assert!(id < total, "cell id {id} out of range");
                assert!(!seen[id], "cell {id} assigned to two shards");
                seen[id] = true;
                weight += shard::cell_weight(&cells[id]);
            }
            assert_eq!(weight, p.weight, "stored shard weight disagrees with its cells");
        }
        assert!(seen.iter().all(|&s| s), "a cell is missing from every shard");

        // Greedy-LPT balance: within one heaviest cell.
        if total > 0 {
            let max = parts.iter().map(|p| p.weight).max().unwrap();
            let min = parts.iter().map(|p| p.weight).min().unwrap();
            assert!(
                max <= min + shard::TRACE_CELL_WEIGHT,
                "weight imbalance: max {max} min {min}"
            );
        }

        // A shard job file round-trips unchanged through disk.
        let dir = std::env::temp_dir()
            .join(format!("cloudmarket_prop_shard_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("prop_shard.json");
        shard::write_shard_file(&path, &spec, &parts[0]).unwrap();
        let (file_spec, file_shard) = shard::read_shard_file(&path).unwrap();
        assert_eq!(file_spec, spec);
        assert_eq!(&file_shard, &parts[0]);
        let _ = std::fs::remove_file(&path);
    });
}

/// Randomized cell results (reports, error rows, retained series with
/// arbitrary finite floats and full-range u64 counters) survive the
/// partial-artifact wire format bit-exactly: encode-decode-encode is the
/// identity on the serialized text, and every float round-trips to the
/// same bits.
#[test]
fn prop_partial_results_round_trip_bit_exact() {
    use cloudmarket::config::scenario::ComparisonConfig;
    use cloudmarket::engine::{Report, SpotStats};
    use cloudmarket::metrics::TimeSeries;
    use cloudmarket::sweep::{shard, CellResult, PolicySpec, SweepSpec};
    use cloudmarket::util::json::parse;

    const POLICY_NAMES: [&str; 6] = [
        "first-fit",
        "best-fit",
        "worst-fit",
        "round-robin",
        "hlem-vmp",
        "hlem-vmp-adjusted",
    ];

    forall(16, 0xB17E, |rng| {
        let spec = SweepSpec::new(ComparisonConfig::default())
            .with_seeds(vec![rng.next_u64(), rng.next_u64()])
            .with_policies(vec![PolicySpec::FirstFit, PolicySpec::BestFit]);
        let cells = spec.cells();
        let results: Vec<CellResult> = cells
            .iter()
            .map(|&cell| {
                if rng.chance(0.25) {
                    return CellResult {
                        cell,
                        outcome: Err("boom\n\"quoted\", with commas".to_string()),
                        series: None,
                    };
                }
                let series = rng.chance(0.5).then(|| {
                    let mut s = TimeSeries::new(&["spot_running", "weird \"col\",name"]);
                    let mut t = 0.0;
                    for _ in 0..rng.range_u64(1, 6) {
                        t += rng.uniform(0.0, 100.0);
                        s.push(t, &[rng.uniform(0.0, 1e6), rng.uniform(0.0, 1.0)]);
                    }
                    s
                });
                CellResult {
                    cell,
                    outcome: Ok(Report {
                        policy: POLICY_NAMES[rng.below(6) as usize],
                        clock_end: rng.uniform(0.0, 1e7),
                        events_processed: rng.next_u64(),
                        wall: std::time::Duration::from_nanos(rng.next_u64() >> 32),
                        finished: rng.next_u64(),
                        terminated: rng.next_u64(),
                        failed: rng.next_u64(),
                        still_active: rng.next_u64(),
                        cloudlets_finished: rng.next_u64(),
                        cloudlets_canceled: rng.next_u64(),
                        alloc_attempts: rng.next_u64(),
                        alloc_failures: rng.next_u64(),
                        spot: SpotStats {
                            total_spot: rng.next_u64(),
                            interruptions: rng.next_u64(),
                            interrupted_vms: rng.next_u64(),
                            uninterrupted_completions: rng.next_u64(),
                            redeployments: rng.next_u64(),
                            completed_after_interruption: rng.next_u64(),
                            terminated: rng.next_u64(),
                            max_interruptions_per_vm: rng.below(u32::MAX as u64 + 1) as u32,
                            avg_interruption_secs: rng.uniform(0.0, 1e5),
                            max_interruption_secs: rng.uniform(0.0, 1e9),
                            min_interruption_secs: rng.uniform(0.0, 1.0),
                        },
                        resilience: cloudmarket::engine::ResilienceStats {
                            storms: rng.next_u64(),
                            storm_reclaims: rng.next_u64(),
                            host_failures: rng.next_u64(),
                            recoveries: rng.next_u64(),
                            interruptions_per_storm: rng.uniform(0.0, 1e4),
                            p95_interruption_secs: rng.uniform(0.0, 1e6),
                            avg_recovery_secs: rng.uniform(0.0, 1e5),
                            max_recovery_secs: rng.uniform(0.0, 1e6),
                            work_lost_mi: rng.uniform(0.0, 1e12),
                            work_recovered_mi: rng.uniform(0.0, 1e12),
                        },
                        market: cloudmarket::engine::MarketStats {
                            spot_cost_usd: rng.uniform(0.0, 1e6),
                            on_demand_cost_usd: rng.uniform(0.0, 1e6),
                            savings_ratio: rng.uniform(-1.0, 1.0),
                            price_reclaims: rng.next_u64(),
                            mean_price_paid: rng.uniform(0.0, 2.0),
                            max_price_paid: rng.uniform(0.0, 2.0),
                        },
                        recovery: cloudmarket::engine::RecoveryStats {
                            checkpoints: rng.next_u64(),
                            checkpoint_mb: rng.uniform(0.0, 1e6),
                            migrations: rng.next_u64(),
                            failed_migrations: rng.next_u64(),
                            work_recovered_mi: rng.uniform(0.0, 1e12),
                            work_lost_mi: rng.uniform(0.0, 1e12),
                            recovered_fraction: rng.uniform(0.0, 1.0),
                            requeue_p50_s: rng.uniform(0.0, 1e4),
                            requeue_p95_s: rng.uniform(0.0, 1e4),
                            requeue_max_s: rng.uniform(0.0, 1e4),
                        },
                    }),
                    series,
                }
            })
            .collect();

        let text = shard::results_to_json(&results).to_string_compact();
        let back = shard::results_from_json(&parse(&text).unwrap()).unwrap();
        assert_eq!(
            shard::results_to_json(&back).to_string_compact(),
            text,
            "encode . decode . encode must be the identity"
        );
        assert_eq!(back.len(), results.len());
        for (a, b) in results.iter().zip(&back) {
            assert_eq!(a.cell, b.cell);
            match (&a.outcome, &b.outcome) {
                (Ok(x), Ok(y)) => {
                    assert_eq!(x.policy, y.policy);
                    assert_eq!(x.clock_end.to_bits(), y.clock_end.to_bits());
                    assert_eq!(x.events_processed, y.events_processed);
                    assert_eq!(x.finished, y.finished);
                    assert_eq!(x.spot.total_spot, y.spot.total_spot);
                    assert_eq!(
                        x.spot.avg_interruption_secs.to_bits(),
                        y.spot.avg_interruption_secs.to_bits()
                    );
                    assert_eq!(
                        x.spot.min_interruption_secs.to_bits(),
                        y.spot.min_interruption_secs.to_bits()
                    );
                    assert_eq!(
                        x.spot.max_interruptions_per_vm,
                        y.spot.max_interruptions_per_vm
                    );
                    assert_eq!(x.resilience.storms, y.resilience.storms);
                    assert_eq!(x.resilience.storm_reclaims, y.resilience.storm_reclaims);
                    assert_eq!(
                        x.resilience.p95_interruption_secs.to_bits(),
                        y.resilience.p95_interruption_secs.to_bits()
                    );
                    assert_eq!(
                        x.resilience.work_lost_mi.to_bits(),
                        y.resilience.work_lost_mi.to_bits()
                    );
                    assert_eq!(
                        x.market.spot_cost_usd.to_bits(),
                        y.market.spot_cost_usd.to_bits()
                    );
                    assert_eq!(
                        x.market.savings_ratio.to_bits(),
                        y.market.savings_ratio.to_bits()
                    );
                    assert_eq!(
                        x.market.max_price_paid.to_bits(),
                        y.market.max_price_paid.to_bits()
                    );
                    assert_eq!(x.market.price_reclaims, y.market.price_reclaims);
                    assert_eq!(x.recovery.checkpoints, y.recovery.checkpoints);
                    assert_eq!(x.recovery.migrations, y.recovery.migrations);
                    assert_eq!(x.recovery.failed_migrations, y.recovery.failed_migrations);
                    assert_eq!(
                        x.recovery.checkpoint_mb.to_bits(),
                        y.recovery.checkpoint_mb.to_bits()
                    );
                    assert_eq!(
                        x.recovery.work_recovered_mi.to_bits(),
                        y.recovery.work_recovered_mi.to_bits()
                    );
                    assert_eq!(
                        x.recovery.recovered_fraction.to_bits(),
                        y.recovery.recovered_fraction.to_bits()
                    );
                    assert_eq!(
                        x.recovery.requeue_p95_s.to_bits(),
                        y.recovery.requeue_p95_s.to_bits()
                    );
                    assert_eq!(y.wall, std::time::Duration::ZERO, "wall must not survive");
                }
                (Err(x), Err(y)) => assert_eq!(x, y),
                _ => panic!("outcome kind changed across the wire"),
            }
            match (&a.series, &b.series) {
                (Some(x), Some(y)) => {
                    assert_eq!(x.columns(), y.columns());
                    assert_eq!(x.times(), y.times());
                    for col in x.columns() {
                        let xa = x.column(col).unwrap();
                        let ya = y.column(col).unwrap();
                        assert_eq!(xa.len(), ya.len());
                        for (va, vb) in xa.iter().zip(ya) {
                            assert_eq!(va.to_bits(), vb.to_bits());
                        }
                    }
                }
                (None, None) => {}
                _ => panic!("series presence changed across the wire"),
            }
        }
    });
}

/// Compiled chaos schedules are a pure function of (spec, seed, horizon,
/// n_hosts): the bytes are identical no matter which thread compiles
/// them, how many compiles run concurrently, or what other compiles (for
/// other seeds) happen in between. This is the foundation of the sweep's
/// byte-identity contract once `chaos.*` axes are in the grid - lazy
/// `ChaosSlots` may compile a schedule from any worker thread at any
/// point in the run.
#[test]
fn prop_chaos_schedule_compile_is_thread_and_order_invariant() {
    use cloudmarket::chaos::{
        self, BrokerOutage, ChaosSpec, DemandSurge, HostMtbf, ReclaimStorm,
    };

    forall(12, 0xC405, |rng| {
        let spec = ChaosSpec {
            host_mtbf: rng.chance(0.7).then(|| HostMtbf {
                mtbf: rng.uniform(50.0, 2_000.0),
                mttr: rng.uniform(5.0, 500.0),
            }),
            reclaim_storm: rng.chance(0.7).then(|| ReclaimStorm {
                at: rng.uniform(0.0, 4_000.0),
                frac: 0.25 + 0.5 * rng.uniform(0.0, 1.0),
                count: 1 + rng.below(3) as u32,
                every: rng.uniform(10.0, 400.0),
            }),
            broker_outage: rng.chance(0.5).then(|| BrokerOutage {
                at: rng.uniform(0.0, 3_000.0),
                dur: rng.uniform(1.0, 600.0),
            }),
            demand_surge: rng.chance(0.5).then(|| DemandSurge {
                at: rng.uniform(0.0, 3_000.0),
                vms: 1 + rng.below(30) as u32,
                pes: 1 + rng.below(4) as u32,
                dur: rng.uniform(10.0, 600.0),
            }),
        };
        let seed = rng.next_u64();
        let horizon = rng.uniform(500.0, 6_000.0);
        let n_hosts = 1 + rng.below(200) as usize;

        let reference = format!("{:?}", chaos::compile(&spec, seed, horizon, n_hosts));
        // Interleave a compile for a different seed: per-family streams
        // must have no hidden shared state that the extra compile shifts.
        let _ = chaos::compile(&spec, seed ^ 0xDEAD_BEEF, horizon, n_hosts);
        assert_eq!(
            format!("{:?}", chaos::compile(&spec, seed, horizon, n_hosts)),
            reference,
            "recompiling after an unrelated compile changed the schedule"
        );

        let handles: Vec<_> = (0..4)
            .map(|i| {
                std::thread::spawn(move || {
                    // Each thread compiles a different number of times;
                    // only the last result is compared, so any order- or
                    // count-dependence would show up as a mismatch.
                    let mut last = String::new();
                    for _ in 0..=(i % 3) {
                        last = format!("{:?}", chaos::compile(&spec, seed, horizon, n_hosts));
                    }
                    last
                })
            })
            .collect();
        for h in handles {
            assert_eq!(
                h.join().unwrap(),
                reference,
                "chaos compile must be thread-invariant"
            );
        }
    });
}

/// Compiled price paths are a pure function of (spec, seed, horizon):
/// identical bytes no matter which thread compiles them, how many
/// compiles run concurrently, or what other compiles (for other seeds)
/// happen in between - the `MarketSlots` analogue of the chaos property
/// above.
#[test]
fn prop_market_schedule_compile_is_thread_and_order_invariant() {
    use cloudmarket::market::{self, MarketSpec};

    forall(12, 0xFA51, |rng| {
        let spec = MarketSpec {
            volatility: rng.chance(0.8).then(|| rng.uniform(0.0, 0.5)),
            mean_reversion: rng.chance(0.5).then(|| rng.uniform(1e-5, 1e-2)),
            daily_amplitude: rng.chance(0.5).then(|| rng.uniform(0.0, 1.0)),
            bid_margin: rng.chance(0.5).then(|| rng.uniform(0.1, 2.0)),
        };
        let seed = rng.next_u64();
        let horizon = rng.uniform(500.0, 200_000.0);

        let reference = format!("{:?}", market::compile(&spec, seed, horizon));
        // Interleave a compile for a different seed: the price stream must
        // have no hidden shared state that the extra compile shifts.
        let _ = market::compile(&spec, seed ^ 0xDEAD_BEEF, horizon);
        assert_eq!(
            format!("{:?}", market::compile(&spec, seed, horizon)),
            reference,
            "recompiling after an unrelated compile changed the path"
        );

        let handles: Vec<_> = (0..4)
            .map(|i| {
                std::thread::spawn(move || {
                    let mut last = String::new();
                    for _ in 0..=(i % 3) {
                        last = format!("{:?}", market::compile(&spec, seed, horizon));
                    }
                    last
                })
            })
            .collect();
        for h in handles {
            assert_eq!(
                h.join().unwrap(),
                reference,
                "market compile must be thread-invariant"
            );
        }
    });
}

/// For arbitrary in-range OU parameters the compiled path is well-formed:
/// every price is finite and >= the floor, the tick count matches the
/// horizon, and the crossing list is exactly the sign changes of
/// `price > bid` (ascending, alternating, starting consistent with the
/// first tick).
#[test]
fn prop_market_price_paths_finite_positive_and_crossings_consistent() {
    use cloudmarket::market::{self, MarketSpec, PRICE_FLOOR, TICK_SECS};

    forall(32, 0x0FAB, |rng| {
        let spec = MarketSpec {
            volatility: Some(rng.uniform(0.0, 2.0)),
            mean_reversion: rng.chance(0.7).then(|| rng.uniform(1e-6, 1e-1)),
            daily_amplitude: rng.chance(0.7).then(|| rng.uniform(0.0, 1.0)),
            bid_margin: rng.chance(0.7).then(|| rng.uniform(0.05, 3.0)),
        };
        let seed = rng.next_u64();
        let horizon = rng.uniform(100.0, 300_000.0);
        let sched = market::compile(&spec, seed, horizon);

        assert_eq!(sched.prices.len(), (horizon / TICK_SECS).ceil() as usize);
        for &p in &sched.prices {
            assert!(p.is_finite() && p >= PRICE_FLOOR, "price {p} escaped the floor");
        }
        // Reconstruct the crossing list from the path and compare.
        let mut expect = Vec::new();
        if sched.prices[0] > sched.bid {
            expect.push((0.0f64, true));
        }
        for k in 1..sched.prices.len() {
            let was = sched.prices[k - 1] > sched.bid;
            let is = sched.prices[k] > sched.bid;
            if is != was {
                expect.push((k as f64 * TICK_SECS, is));
            }
        }
        let got: Vec<(f64, bool)> = sched.crossings.iter().map(|c| (c.at, c.up)).collect();
        assert_eq!(got, expect, "crossings must be exactly the bid sign changes");
        for w in sched.crossings.windows(2) {
            assert!(w[0].at < w[1].at);
            assert_ne!(w[0].up, w[1].up, "crossing directions must alternate");
        }
    });
}

/// `market.*` axis labels round-trip exactly: formatting a random
/// in-range value with the shortest-Display label and re-parsing the
/// axis string reproduces the original bits (the contract that makes
/// `sweep_cells.csv` axis columns greppable back into `--axis` flags).
#[test]
fn prop_market_axis_labels_round_trip_exactly() {
    use cloudmarket::market::label_f64;
    use cloudmarket::sweep::ScenarioAxis;

    forall(40, 0x1AB31, |rng| {
        let n = 1 + rng.below(4) as usize;
        let vol: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 3.0)).collect();
        let rev: Vec<f64> = (0..n).map(|_| rng.uniform(1e-7, 1.0)).collect();
        let amp: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 1.0)).collect();
        let bid: Vec<f64> = (0..n).map(|_| rng.uniform(1e-3, 4.0)).collect();
        for (name, vals) in [
            ("market.volatility", &vol),
            ("market.mean-reversion", &rev),
            ("market.daily-amplitude", &amp),
            ("market.bid-margin", &bid),
        ] {
            for &v in vals.iter() {
                let back: f64 = label_f64(v).parse().unwrap();
                assert_eq!(back.to_bits(), v.to_bits(), "label_f64 must invert exactly");
            }
            let joined: Vec<String> = vals.iter().map(|&v| label_f64(v)).collect();
            let axis = ScenarioAxis::parse(&format!("{name}={}", joined.join(","))).unwrap();
            let got = match &axis {
                ScenarioAxis::MarketVolatility(v)
                | ScenarioAxis::MarketMeanReversion(v)
                | ScenarioAxis::MarketDailyAmplitude(v)
                | ScenarioAxis::MarketBidMargin(v) => v,
                other => panic!("parsed into the wrong axis: {other:?}"),
            };
            assert_eq!(
                got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                vals.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "{name} values changed across label round-trip"
            );
        }
    });
}

// ---------------------------------------------------------------------
// recovery properties
// ---------------------------------------------------------------------

/// The warning-window checkpoint decision is monotone in both bandwidth
/// and window length, never saves more than the accumulated progress,
/// and never transfers more than the image holds - for arbitrary
/// progress/bandwidth/window/threshold combinations.
#[test]
fn prop_checkpoint_decision_monotone_and_bounded() {
    use cloudmarket::recovery::{checkpoint_decision, CheckpointKind, CHECKPOINT_MB_PER_MI};

    forall(60, 0xC4EC, |rng| {
        let progress = rng.uniform(0.0, 1e6);
        let threshold = rng.uniform(0.0, 1.0);
        let (b1, b2) = {
            let a = rng.uniform(0.0, 500.0);
            let b = rng.uniform(0.0, 500.0);
            (a.min(b), a.max(b))
        };
        let (w1, w2) = {
            let a = rng.uniform(0.0, 600.0);
            let b = rng.uniform(0.0, 600.0);
            (a.min(b), a.max(b))
        };
        for (b, w) in [(b1, w1), (b1, w2), (b2, w1), (b2, w2)] {
            let d = checkpoint_decision(progress, b, w, threshold);
            assert!(d.saved_mi >= 0.0 && d.bytes_mb >= 0.0);
            assert!(d.saved_mi <= progress + 1e-9, "saved more than progress");
            assert!(
                d.bytes_mb <= progress * CHECKPOINT_MB_PER_MI + 1e-9,
                "transferred more than the image holds"
            );
            match d.kind {
                CheckpointKind::Full => assert!((d.saved_mi - progress).abs() < 1e-9),
                CheckpointKind::Partial => assert!(
                    d.saved_mi + 1e-6 >= threshold * progress,
                    "partial save below the threshold fraction"
                ),
                CheckpointKind::Restart => assert_eq!(d.saved_mi, 0.0),
            }
        }
        // Monotone in bandwidth (window fixed) and in window (bandwidth
        // fixed): more transfer capacity never loses work.
        let saved = |b: f64, w: f64| checkpoint_decision(progress, b, w, threshold).saved_mi;
        assert!(saved(b1, w1) <= saved(b2, w1) + 1e-9, "not monotone in bandwidth");
        assert!(saved(b1, w2) <= saved(b2, w2) + 1e-9, "not monotone in bandwidth");
        assert!(saved(b1, w1) <= saved(b1, w2) + 1e-9, "not monotone in window");
        assert!(saved(b2, w1) <= saved(b2, w2) + 1e-9, "not monotone in window");
    });
}

/// The Kuhn-Munkres reassignment never costs more than the greedy
/// first-fit baseline on fully-feasible matrices (both place every
/// displaced VM when hosts suffice), agrees with greedy exactly for a
/// single displaced VM, and with infeasible pairs in the mix it stays
/// injective, never assigns an infeasible pair, and places at least as
/// many VMs as greedy does.
#[test]
fn prop_optimal_reassignment_never_worse_than_greedy() {
    use cloudmarket::recovery::{assign_greedy, assign_optimal, assignment_total};

    fn check_injective_and_feasible(costs: &[Vec<f64>], assign: &[Option<usize>]) {
        let m = costs.first().map(Vec::len).unwrap_or(0);
        let mut taken = vec![false; m];
        for (i, a) in assign.iter().enumerate() {
            if let Some(j) = *a {
                assert!(j < m, "assigned column out of range");
                assert!(!taken[j], "two VMs assigned to one host");
                taken[j] = true;
                assert!(costs[i][j].is_finite() && costs[i][j] < 1e14, "infeasible pair assigned");
            }
        }
    }

    forall(40, 0x6B4D, |rng| {
        let n = 1 + rng.below(6) as usize;
        let m = n + rng.below(5) as usize;

        // Fully feasible, hosts >= VMs: both algorithms place everyone, so
        // the totals are directly comparable.
        let costs: Vec<Vec<f64>> =
            (0..n).map(|_| (0..m).map(|_| rng.uniform(0.0, 100.0)).collect()).collect();
        let greedy = assign_greedy(&costs);
        let optimal = assign_optimal(&costs);
        check_injective_and_feasible(&costs, &greedy);
        check_injective_and_feasible(&costs, &optimal);
        assert!(greedy.iter().all(Option::is_some), "greedy must place all (feasible, m>=n)");
        assert!(optimal.iter().all(Option::is_some), "optimal must place all (feasible, m>=n)");
        let g = assignment_total(&costs, &greedy);
        let o = assignment_total(&costs, &optimal);
        assert!(o <= g + 1e-6, "optimal total {o} exceeds greedy total {g}");
        if n == 1 {
            assert_eq!(optimal, greedy, "single displaced VM: exact parity");
        }

        // Sprinkle infeasible pairs: the matching must stay valid, and the
        // min-cost matching (sentinel-padded) never strands a VM greedy
        // could have placed.
        let mut sparse = costs.clone();
        for row in sparse.iter_mut() {
            for c in row.iter_mut() {
                if rng.chance(0.4) {
                    *c = 1e15;
                }
            }
        }
        let greedy = assign_greedy(&sparse);
        let optimal = assign_optimal(&sparse);
        check_injective_and_feasible(&sparse, &greedy);
        check_injective_and_feasible(&sparse, &optimal);
        let placed = |a: &[Option<usize>]| a.iter().filter(|x| x.is_some()).count();
        assert!(
            placed(&optimal) >= placed(&greedy),
            "optimal placed {} VMs, greedy {}",
            placed(&optimal),
            placed(&greedy)
        );
    });
}

/// Compiled recovery schedules are a pure function of (spec, seed,
/// horizon): identical no matter which thread compiles them or what
/// other compiles happen in between - the `RecoverySlots` analogue of
/// the chaos/market compile-invariance properties above.
#[test]
fn prop_recovery_schedule_compile_is_thread_and_order_invariant() {
    use cloudmarket::recovery::{self, RecoveryMode, RecoverySpec};

    forall(12, 0x4EC0, |rng| {
        let modes = [
            RecoveryMode::None,
            RecoveryMode::Restart,
            RecoveryMode::Checkpoint,
            RecoveryMode::MigrateGreedy,
            RecoveryMode::MigrateOptimal,
        ];
        let spec = RecoverySpec {
            mode: rng.chance(0.8).then(|| modes[rng.below(5) as usize]),
            bandwidth: rng.chance(0.6).then(|| rng.uniform(1.0, 500.0)),
            checkpoint_threshold: rng.chance(0.6).then(|| rng.uniform(0.0, 1.0)),
        };
        let seed = rng.next_u64();
        let horizon = rng.uniform(500.0, 200_000.0);

        let reference = format!("{:?}", recovery::compile(&spec, seed, horizon));
        // Interleave a compile for a different seed: resolved parameters
        // must have no hidden shared state the extra compile shifts.
        let _ = recovery::compile(&spec, seed ^ 0xDEAD_BEEF, horizon);
        assert_eq!(
            format!("{:?}", recovery::compile(&spec, seed, horizon)),
            reference,
            "recompiling after an unrelated compile changed the schedule"
        );
        // The schedule carries no randomness at all: a different seed
        // resolves to the identical parameter block.
        assert_eq!(
            format!("{:?}", recovery::compile(&spec, seed ^ 1, horizon)),
            reference,
            "recovery schedules must be seed-independent"
        );

        let handles: Vec<_> = (0..4)
            .map(|i| {
                std::thread::spawn(move || {
                    let mut last = String::new();
                    for _ in 0..=(i % 3) {
                        last = format!("{:?}", recovery::compile(&spec, seed, horizon));
                    }
                    last
                })
            })
            .collect();
        for h in handles {
            assert_eq!(
                h.join().unwrap(),
                reference,
                "recovery compile must be thread-invariant"
            );
        }
    });
}

/// `recovery.*` axis labels round-trip exactly, mode labels included:
/// formatting values with the shortest-Display label and re-parsing the
/// axis string reproduces the original bits/variants.
#[test]
fn prop_recovery_axis_labels_round_trip_exactly() {
    use cloudmarket::recovery::{label_f64, RecoveryMode};
    use cloudmarket::sweep::ScenarioAxis;

    forall(40, 0x4EC1AB, |rng| {
        let n = 1 + rng.below(4) as usize;
        let bw: Vec<f64> = (0..n).map(|_| rng.uniform(1e-3, 1e4)).collect();
        let th: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 1.0)).collect();
        for (name, vals) in
            [("recovery.bandwidth", &bw), ("recovery.checkpoint-threshold", &th)]
        {
            for &v in vals.iter() {
                let back: f64 = label_f64(v).parse().unwrap();
                assert_eq!(back.to_bits(), v.to_bits(), "label_f64 must invert exactly");
            }
            let joined: Vec<String> = vals.iter().map(|&v| label_f64(v)).collect();
            let axis = ScenarioAxis::parse(&format!("{name}={}", joined.join(","))).unwrap();
            let got = match &axis {
                ScenarioAxis::RecoveryBandwidth(v)
                | ScenarioAxis::RecoveryCheckpointThreshold(v) => v,
                other => panic!("parsed into the wrong axis: {other:?}"),
            };
            assert_eq!(
                got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                vals.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "{name} values changed across label round-trip"
            );
        }
        let modes = [
            RecoveryMode::None,
            RecoveryMode::Restart,
            RecoveryMode::Checkpoint,
            RecoveryMode::MigrateGreedy,
            RecoveryMode::MigrateOptimal,
        ];
        let picked: Vec<RecoveryMode> =
            (0..n).map(|_| modes[rng.below(5) as usize]).collect();
        let joined: Vec<&str> = picked.iter().map(|m| m.label()).collect();
        let axis =
            ScenarioAxis::parse(&format!("recovery.mode={}", joined.join(","))).unwrap();
        match axis {
            ScenarioAxis::RecoveryMode(v) => {
                assert_eq!(v, picked, "mode labels changed across round-trip")
            }
            other => panic!("parsed into the wrong axis: {other:?}"),
        }
    });
}
