//! Placement parity: for every policy, the indexed decision hot path and
//! the pre-index linear scan must produce the *identical* sequence of
//! `select_host` / `select_preemption` decisions - verified end to end by
//! running the same randomized workload (hosts joining/leaving, spot
//! interruptions, hibernation/resubmission) under both modes and
//! comparing bit-exact per-VM outcomes.
//!
//! Together with the per-query oracles in `tests/properties.rs` this pins
//! the acceptance contract of the placement index: identical decisions
//! with deterministic tie-breaks on host id.

use cloudmarket::allocation::{AllocationPolicy, BestFit, FirstFit, HlemVmp, WorstFit};
use cloudmarket::cloudlet::Cloudlet;
use cloudmarket::engine::{Engine, EngineConfig};
use cloudmarket::stats::Rng;
use cloudmarket::testkit::{forall, gen};
use cloudmarket::vm::Vm;

/// Random contended scenario: small cluster, mixed spot/on-demand VMs,
/// optional mid-run host add/remove. Deterministic in `rng`.
fn build_engine(rng: &mut Rng, policy: Box<dyn AllocationPolicy>) -> Engine {
    let mut cfg = EngineConfig::default();
    cfg.vm_destruction_delay = rng.uniform(0.0, 2.0);
    cfg.scheduling_interval = rng.uniform(0.5, 5.0);
    let mut e = Engine::new(cfg, policy);
    let dc = e.add_datacenter("dc", 1.0);
    for _ in 0..rng.range_u64(1, 8) {
        e.add_host(dc, gen::host_spec(rng));
    }
    if rng.chance(0.5) {
        // A machine that joins mid-run (trace ADD path).
        let spec = gen::host_spec(rng);
        let t = rng.uniform(20.0, 80.0);
        e.add_host_at(dc, spec, t);
    }
    if rng.chance(0.3) {
        // A machine that leaves mid-run (trace REMOVE path).
        let t = rng.uniform(30.0, 120.0);
        e.remove_host_at(0, t);
    }
    for _ in 0..rng.range_u64(4, 30) {
        let spec = gen::vm_spec(rng);
        let delay = rng.uniform(0.0, 60.0);
        let vm = if rng.chance(0.5) {
            let mut v = Vm::spot(0, spec, gen::spot_config(rng)).with_delay(delay);
            if rng.chance(0.7) {
                v = v.with_persistent(rng.uniform(10.0, 200.0));
            }
            e.submit_vm(v)
        } else {
            let mut v = Vm::on_demand(0, spec).with_delay(delay);
            if rng.chance(0.5) {
                v = v.with_persistent(rng.uniform(10.0, 200.0));
            }
            e.submit_vm(v)
        };
        for _ in 0..rng.range_u64(0, 3) {
            let pes = rng.range_u64(1, spec.pes as u64) as u32;
            let length = rng.uniform(1_000.0, 200_000.0);
            e.submit_cloudlet(Cloudlet::new(0, length, pes).with_vm(vm));
        }
    }
    e.terminate_at(rng.uniform(100.0, 400.0));
    e
}

/// Bit-exact per-VM outcome: every placement, interruption and timing
/// difference between two runs shows up here.
fn fingerprint(e: &Engine) -> Vec<(String, u32, Option<usize>, Vec<(usize, u64, u64)>)> {
    e.world
        .vms
        .iter()
        .map(|v| {
            (
                format!("{:?}", v.state),
                v.interruptions,
                v.host,
                v.history
                    .intervals()
                    .iter()
                    .map(|iv| {
                        (iv.host, iv.start.to_bits(), iv.stop.map(f64::to_bits).unwrap_or(u64::MAX))
                    })
                    .collect(),
            )
        })
        .collect()
}

fn parity_for(make: fn(bool) -> Box<dyn AllocationPolicy>, cases: u64, seed: u64) {
    forall(cases, seed, move |rng| {
        let wl_seed = rng.next_u64();
        let mut scan = build_engine(&mut Rng::new(wl_seed), make(true));
        let mut indexed = build_engine(&mut Rng::new(wl_seed), make(false));
        let r_scan = scan.run();
        let r_indexed = indexed.run();
        assert_eq!(
            r_scan.events_processed, r_indexed.events_processed,
            "event streams diverged"
        );
        assert_eq!(
            scan.policy().decisions(),
            indexed.policy().decisions(),
            "decision counts diverged"
        );
        assert_eq!(fingerprint(&scan), fingerprint(&indexed), "per-VM outcomes diverged");
        indexed.world.check_index().expect("index consistent after parity run");
    });
}

#[test]
fn first_fit_index_matches_scan() {
    parity_for(|scan| Box::new(FirstFit::new().with_scan_mode(scan)), 12, 0xFF01);
}

#[test]
fn best_fit_index_matches_scan() {
    parity_for(|scan| Box::new(BestFit::new().with_scan_mode(scan)), 12, 0xBF02);
}

#[test]
fn worst_fit_index_matches_scan() {
    parity_for(|scan| Box::new(WorstFit::new().with_scan_mode(scan)), 12, 0x3F03);
}

#[test]
fn hlem_plain_index_matches_scan() {
    parity_for(|scan| Box::new(HlemVmp::plain().with_scan_mode(scan)), 12, 0x41EA);
}

#[test]
fn hlem_adjusted_index_matches_scan() {
    parity_for(|scan| Box::new(HlemVmp::adjusted().with_scan_mode(scan)), 12, 0xAD05);
}

/// Degenerate many-feasible-hosts case: a uniform fleet where every host
/// can take every request, so the bounded-probe first-fit accepts its
/// first probe each time while the scan oracle walks from id 0 - any
/// probe-order bug shows up as a placement divergence. A handful of
/// oversized requests is mixed in so the probe budget also exhausts and
/// the fallback tail scan is exercised end to end.
#[test]
fn first_fit_many_feasible_hosts_parity() {
    fn build(rng: &mut Rng, policy: Box<dyn AllocationPolicy>) -> Engine {
        let mut cfg = EngineConfig::default();
        cfg.vm_destruction_delay = 0.0;
        let mut e = Engine::new(cfg, policy);
        let dc = e.add_datacenter("dc", 1.0);
        let n_hosts = rng.range_u64(16, 48);
        for _ in 0..n_hosts {
            e.add_host(dc, cloudmarket::infra::HostSpec::new(16, 1000.0, 65_536.0, 20_000.0, 1_000_000.0));
        }
        // One high-id machine with extra RAM: the only feasible target
        // for the oversized requests below, past the probe budget.
        e.add_host(dc, cloudmarket::infra::HostSpec::new(16, 1000.0, 1_048_576.0, 20_000.0, 1_000_000.0));
        for i in 0..rng.range_u64(20, 60) {
            let oversized = i % 7 == 0;
            let spec = if oversized {
                cloudmarket::vm::VmSpec::new(1000.0, 1).with_ram(100_000.0)
            } else {
                cloudmarket::vm::VmSpec::new(1000.0, rng.range_u64(1, 4) as u32)
            };
            let vm = e.submit_vm(Vm::on_demand(0, spec).with_delay(rng.uniform(0.0, 40.0)));
            e.submit_cloudlet(Cloudlet::new(0, rng.uniform(1_000.0, 60_000.0), 1).with_vm(vm));
        }
        e.terminate_at(200.0);
        e
    }
    forall(8, 0xFFDE6E, |rng| {
        let wl_seed = rng.next_u64();
        let mut scan = build(&mut Rng::new(wl_seed), Box::new(FirstFit::new().with_scan_mode(true)));
        let mut indexed =
            build(&mut Rng::new(wl_seed), Box::new(FirstFit::new().with_scan_mode(false)));
        let r_scan = scan.run();
        let r_indexed = indexed.run();
        assert_eq!(r_scan.events_processed, r_indexed.events_processed, "event streams diverged");
        assert_eq!(fingerprint(&scan), fingerprint(&indexed), "per-VM outcomes diverged");
        indexed.world.check_index().expect("index consistent after many-feasible parity run");
    });
}
