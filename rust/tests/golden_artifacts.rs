//! Golden-artifact corpus: the serialized sweep artifact formats
//! (`sweep_cells.csv` column order and float formatting,
//! `sweep_aggregate.json` field set and number rendering) are pinned to
//! committed fixtures under `tests/golden/`. Format drift in
//! `sweep::report` - a reordered column, a changed float format, a new
//! axis field - fails this test loudly instead of silently changing
//! published numbers downstream.
//!
//! The pinned report is hand-constructed (no engine run), with values
//! chosen to exercise every formatting path: whole numbers, two-decimal
//! floats, axis columns, a failed cell (empty columns + `null`
//! aggregates), and per-variant grouping across substrates. All values
//! are exact dyadic rationals so the aggregate moments (mean/stddev) are
//! bit-exact and the fixtures are stable on every platform.
//!
//! To update after an *intentional* format change:
//! `CLOUDMARKET_UPDATE_GOLDEN=1 cargo test --test golden_artifacts`
//! then review and commit the rewritten fixtures.

use std::path::PathBuf;

use cloudmarket::chaos::{ChaosSpec, ReclaimStorm};
use cloudmarket::engine::{
    MarketStats, RecoveryStats, Report, ResilienceStats, SpotStats, VictimPolicy,
};
use cloudmarket::market::MarketSpec;
use cloudmarket::recovery::{RecoveryMode, RecoverySpec};
use cloudmarket::sweep::{
    Cell, CellResult, CellSpec, PolicySpec, SpotOverride, Substrate, SweepReport,
};
use cloudmarket::vm::InterruptionBehavior;

#[allow(clippy::too_many_arguments)]
fn ok_report(
    policy: &'static str,
    clock_end: f64,
    events: u64,
    finished: u64,
    terminated: u64,
    failed: u64,
    total_spot: u64,
    interruptions: u64,
    interrupted_vms: u64,
    max_per_vm: u32,
    avg_s: f64,
    max_s: f64,
    min_s: f64,
    resilience: ResilienceStats,
    market: MarketStats,
    recovery: RecoveryStats,
) -> Report {
    Report {
        policy,
        clock_end,
        events_processed: events,
        wall: std::time::Duration::ZERO,
        finished,
        terminated,
        failed,
        still_active: 0,
        cloudlets_finished: finished,
        cloudlets_canceled: 0,
        alloc_attempts: 0,
        alloc_failures: 0,
        spot: SpotStats {
            total_spot,
            interruptions,
            interrupted_vms,
            max_interruptions_per_vm: max_per_vm,
            avg_interruption_secs: avg_s,
            max_interruption_secs: max_s,
            min_interruption_secs: min_s,
            ..Default::default()
        },
        resilience,
        market,
        recovery,
    }
}

/// The pinned 4-cell report: two comparison first-fit cells (a 2-run
/// aggregate group), one failed adjusted-HLEM cell (a 0-run group with
/// `null` moments), and one trace-substrate cell with every axis column
/// set - including a `chaos.reclaim-storm` label, a full dyadic
/// `market.*` spec with cost stats, and a `recovery.*` spec with
/// work-survival stats - (a 1-run group). All resilience, market, and
/// recovery values are dyadic so the aggregate moments stay bit-exact.
fn pinned_report() -> SweepReport {
    let ff = CellSpec::comparison(PolicySpec::FirstFit);
    let adj = CellSpec::comparison(PolicySpec::Hlem { adjusted: true, alpha: -0.5 });
    let trace = CellSpec {
        substrate: Substrate::Trace,
        policy: PolicySpec::FirstFit,
        spot: SpotOverride {
            warning_time: Some(60.0),
            hibernation_timeout: Some(900.0),
            behavior: Some(InterruptionBehavior::Terminate),
        },
        victim: Some(VictimPolicy::Youngest),
        chaos: ChaosSpec {
            reclaim_storm: Some(ReclaimStorm::parse("at1200-frac0.5").unwrap()),
            ..ChaosSpec::NONE
        },
        market: MarketSpec {
            volatility: Some(0.25),
            mean_reversion: Some(0.5),
            daily_amplitude: Some(0.5),
            bid_margin: Some(0.5),
        },
        recovery: RecoverySpec {
            mode: Some(RecoveryMode::Checkpoint),
            bandwidth: Some(128.0),
            checkpoint_threshold: Some(0.25),
        },
    };
    SweepReport {
        cells: vec![
            CellResult {
                cell: Cell { id: 0, seed: 1, spec: ff },
                outcome: Ok(ok_report(
                    "first-fit",
                    4800.0,
                    123_456,
                    950,
                    30,
                    0,
                    400,
                    3,
                    3,
                    2,
                    10.25,
                    20.5,
                    1.25,
                    ResilienceStats {
                        storms: 1,
                        storm_reclaims: 3,
                        recoveries: 2,
                        interruptions_per_storm: 3.0,
                        p95_interruption_secs: 20.5,
                        avg_recovery_secs: 30.25,
                        max_recovery_secs: 60.5,
                        work_lost_mi: 1000.0,
                        work_recovered_mi: 750.0,
                        ..Default::default()
                    },
                    MarketStats::default(),
                    RecoveryStats::default(),
                )),
                series: None,
            },
            CellResult {
                cell: Cell { id: 1, seed: 1, spec: adj },
                outcome: Err("engine panicked: boom".to_string()),
                series: None,
            },
            CellResult {
                cell: Cell { id: 2, seed: 2, spec: ff },
                outcome: Ok(ok_report(
                    "first-fit",
                    4800.0,
                    123_789,
                    940,
                    35,
                    1,
                    400,
                    5,
                    4,
                    3,
                    10.75,
                    21.5,
                    1.75,
                    ResilienceStats {
                        storms: 1,
                        storm_reclaims: 5,
                        recoveries: 3,
                        interruptions_per_storm: 5.0,
                        p95_interruption_secs: 21.5,
                        avg_recovery_secs: 32.75,
                        max_recovery_secs: 64.5,
                        work_lost_mi: 1500.0,
                        work_recovered_mi: 1250.0,
                        ..Default::default()
                    },
                    MarketStats::default(),
                    RecoveryStats::default(),
                )),
                series: None,
            },
            CellResult {
                cell: Cell { id: 3, seed: 2, spec: trace },
                outcome: Ok(ok_report(
                    "first-fit",
                    4320.0,
                    54_321,
                    120,
                    7,
                    0,
                    20,
                    7,
                    6,
                    4,
                    32.25,
                    48.5,
                    2.5,
                    ResilienceStats {
                        storms: 2,
                        storm_reclaims: 7,
                        recoveries: 4,
                        interruptions_per_storm: 3.5,
                        p95_interruption_secs: 48.5,
                        avg_recovery_secs: 12.25,
                        max_recovery_secs: 24.5,
                        work_lost_mi: 500.25,
                        work_recovered_mi: 250.5,
                        ..Default::default()
                    },
                    MarketStats {
                        spot_cost_usd: 12.25,
                        on_demand_cost_usd: 24.5,
                        savings_ratio: 0.5,
                        price_reclaims: 2,
                        mean_price_paid: 0.25,
                        max_price_paid: 0.75,
                    },
                    RecoveryStats {
                        checkpoints: 2,
                        checkpoint_mb: 512.25,
                        migrations: 1,
                        failed_migrations: 1,
                        work_recovered_mi: 250.5,
                        work_lost_mi: 500.25,
                        recovered_fraction: 0.25,
                        requeue_p50_s: 10.5,
                        requeue_p95_s: 20.25,
                        requeue_max_s: 24.5,
                    },
                )),
                series: None,
            },
        ],
        threads: 1,
    }
}

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden")
}

#[test]
fn sweep_artifact_formats_match_golden_corpus() {
    let report = pinned_report();
    let cells = report.cells_csv().to_string();
    let aggregate = report.aggregate_json().to_string_pretty();
    let dir = golden_dir();

    if std::env::var("CLOUDMARKET_UPDATE_GOLDEN").is_ok() {
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("sweep_cells.csv"), &cells).unwrap();
        std::fs::write(dir.join("sweep_aggregate.json"), &aggregate).unwrap();
        panic!(
            "golden fixtures regenerated under {}; review the diff and commit them",
            dir.display()
        );
    }

    let want_cells = std::fs::read_to_string(dir.join("sweep_cells.csv")).unwrap();
    let want_aggregate = std::fs::read_to_string(dir.join("sweep_aggregate.json")).unwrap();
    assert_eq!(
        cells, want_cells,
        "sweep_cells.csv format drifted (column order / float formatting / axis \
         columns). If the change is intentional, regenerate with \
         CLOUDMARKET_UPDATE_GOLDEN=1 and commit the fixture - downstream published \
         numbers change shape with it."
    );
    assert_eq!(
        aggregate, want_aggregate,
        "sweep_aggregate.json format drifted (field set / number rendering). If \
         intentional, regenerate with CLOUDMARKET_UPDATE_GOLDEN=1 and commit the \
         fixture."
    );
}

/// The cells-CSV column order is pinned verbatim, independent of the
/// fixture files: appending a column is a visible (reviewable) change,
/// but *reordering* or renaming existing columns silently breaks every
/// downstream consumer that indexes by position or header name.
#[test]
fn cells_csv_column_order_is_pinned() {
    let text = pinned_report().cells_csv().to_string();
    let header = text.lines().next().unwrap();
    assert_eq!(
        header,
        "cell,policy,alpha,seed,substrate,victim,spot_warning,spot_hib_timeout,\
         spot_behavior,chaos_host_mtbf,chaos_reclaim_storm,chaos_broker_outage,\
         chaos_demand_surge,market_volatility,market_mean_reversion,\
         market_daily_amplitude,market_bid_margin,recovery_mode,recovery_bandwidth,\
         recovery_checkpoint_threshold,status,error,clock_end,events,\
         vms_finished,vms_terminated,vms_failed,spot_total,interruptions,\
         interrupted_vms,max_per_vm,avg_interruption_s,max_interruption_s,\
         min_interruption_s,storms,storm_reclaims,interruptions_per_storm,\
         p95_interruption_s,recoveries,avg_recovery_s,max_recovery_s,work_lost_mi,\
         work_recovered_mi,spot_cost_usd,od_cost_usd,savings_ratio,price_reclaims,\
         mean_price_paid,max_price_paid,checkpoints,checkpoint_mb,migrations,\
         failed_migrations,recovered_fraction,requeue_p50_s,requeue_p95_s,\
         requeue_max_s",
        "cells CSV column order drifted"
    );
    // Every row carries the full column count (57), including error rows.
    for line in text.lines() {
        assert_eq!(line.split(',').count(), 57, "ragged row: {line}");
    }
}
