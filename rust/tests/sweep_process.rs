//! Cross-process determinism + fault injection for the sweep shard
//! protocol: real `cloudmarket sweep worker` subprocesses (spawned from
//! `CARGO_BIN_EXE_cloudmarket`) must produce partials that merge into
//! artifacts **byte-identical** to the in-process `sweep::run` output on
//! a mixed-axis dual-substrate grid - at 1, 2 and 4 workers, through the
//! `--workers` coordinator CLI, and after one worker is killed mid-shard
//! and its shard reassigned.
//!
//! The paper's headline numbers come from wide experiment fan-outs; these
//! tests are what makes the byte-identical-artifact guarantee trustworthy
//! once that fan-out crosses process (and eventually host) boundaries.

use std::path::{Path, PathBuf};
use std::process::{Command, Stdio};

use cloudmarket::config::scenario::ComparisonConfig;
use cloudmarket::sweep::{
    self, shard, PolicySpec, ScenarioAxis, SeriesFilter, Substrate, SweepReport, SweepSpec,
};

const BIN: &str = env!("CARGO_BIN_EXE_cloudmarket");

fn test_dir(name: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("cloudmarket_sweep_process_{}_{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// The serialized artifact set of a report: exactly what the CLI writes
/// (cells CSV, aggregate JSON, retained series CSVs in id order).
fn render(report: &SweepReport) -> (String, String, Vec<(usize, String)>) {
    (
        report.cells_csv().to_string(),
        report.aggregate_json().to_string_pretty(),
        report
            .retained_series_csvs()
            .into_iter()
            .map(|(id, csv)| (id, csv.to_string()))
            .collect(),
    )
}

/// A mixed-axis dual-substrate grid, small enough for debug-mode test
/// runs: 1 seed x [first-fit, adjusted-HLEM] x 2 spot warnings x
/// [comparison, trace] = 8 cells, first-fit series retained.
fn mixed_spec() -> SweepSpec {
    let scenario = ComparisonConfig { terminate_at: 600.0, ..Default::default() };
    let mut spec = SweepSpec::new(scenario)
        .with_seeds(vec![20_250_710])
        .with_policies(vec![
            PolicySpec::FirstFit,
            PolicySpec::Hlem { adjusted: true, alpha: -0.5 },
        ])
        .with_axis(ScenarioAxis::SpotWarning(vec![2.0, 120.0]))
        .with_axis(ScenarioAxis::Substrate(vec![Substrate::Comparison, Substrate::Trace]))
        .with_series_retention(SeriesFilter::parse("policy=first-fit").unwrap());
    spec.trace.synth.machines = 10;
    spec.trace.synth.days = 0.05;
    spec.trace.synth.tasks_per_hour = 120.0;
    spec.trace.workload.spot_instances = 20;
    spec.trace.workload.spot_durations = vec![300.0, 600.0];
    spec.trace.workload.max_trace_vms = 50;
    spec
}

/// Partition -> real worker subprocesses -> merge, byte-compared against
/// the in-process run at 1, 2 and 4 workers.
#[test]
fn merged_worker_partials_byte_identical_to_in_process_run() {
    let spec = mixed_spec();
    assert_eq!(spec.cell_count(), 8);
    let reference = sweep::run(&spec, 2);
    assert_eq!(reference.failed(), 0, "no cell may fail");
    let want = render(&reference);
    assert_eq!(want.2.len(), 4, "first-fit cells across substrates retain series");

    for workers in [1usize, 2, 4] {
        let dir = test_dir(&format!("lib_{workers}w"));
        let shards = shard::partition(&spec, workers);
        assert_eq!(shards.len(), workers.min(8));

        // All workers run concurrently, like the coordinator would run
        // them.
        let mut children = Vec::new();
        for s in &shards {
            let shard_file = dir.join(format!("sweep_shard{:04}.json", s.index));
            let partial_file = dir.join(format!("sweep_partial{:04}.json", s.index));
            shard::write_shard_file(&shard_file, &spec, s).unwrap();
            let child = Command::new(BIN)
                .args(["sweep", "worker", "--shard"])
                .arg(&shard_file)
                .arg("--out")
                .arg(&partial_file)
                .args(["--threads", "1"])
                .env_remove("CLOUDMARKET_SWEEP_FAULT")
                .stdout(Stdio::null())
                .stderr(Stdio::null())
                .spawn()
                .expect("spawning sweep worker");
            children.push((s.index, partial_file, child));
        }
        let mut partials = Vec::new();
        for (index, partial_file, mut child) in children {
            let status = child.wait().unwrap();
            assert!(status.success(), "worker for shard {index} failed: {status}");
            partials.push(shard::read_partial(&partial_file).unwrap());
        }

        let (merged_spec, merged) = shard::merge_partials(partials).unwrap();
        assert_eq!(merged_spec, spec, "spec survives the process boundary");
        assert_eq!(merged.failed(), 0);
        let got = render(&merged);
        assert_eq!(
            got, want,
            "{workers}-worker merged artifacts differ from the in-process run"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}

fn read_artifacts(dir: &Path) -> (String, String, Vec<(String, String)>) {
    let cells = std::fs::read_to_string(dir.join("sweep_cells.csv")).unwrap();
    let agg = std::fs::read_to_string(dir.join("sweep_aggregate.json")).unwrap();
    let mut series: Vec<(String, String)> = std::fs::read_dir(dir)
        .unwrap()
        .flatten()
        .filter_map(|e| {
            let name = e.file_name().to_string_lossy().into_owned();
            (name.starts_with("sweep_series_cell") && name.ends_with(".csv"))
                .then(|| (name, std::fs::read_to_string(e.path()).unwrap()))
        })
        .collect();
    series.sort();
    (cells, agg, series)
}

/// Flags for a tiny trace-substrate grid every section of the CLI test
/// shares (2 seeds x 2 policies = 4 cells; the comparison template is not
/// CLI-shrinkable, so the cross-process CLI check runs trace-only).
const CLI_GRID: &[&str] = &[
    "--seeds",
    "2",
    "--seed",
    "42",
    "--policies",
    "first-fit,hlem-vmp",
    "--substrate",
    "trace",
    "--machines",
    "10",
    "--days",
    "0.05",
    "--spots",
    "20",
    "--max-vms",
    "50",
    "--retain-series",
    "policy=first-fit",
];

fn run_cli(args: &[&str], envs: &[(&str, String)]) -> std::process::Output {
    let mut cmd = Command::new(BIN);
    cmd.arg("sweep").args(CLI_GRID).args(args).env_remove("CLOUDMARKET_SWEEP_FAULT");
    for (k, v) in envs {
        cmd.env(k, v);
    }
    cmd.output().expect("running cloudmarket sweep")
}

/// End-to-end `--workers` coordinator vs single-process CLI run: byte
/// identical artifacts, stale work/series files cleaned, no shard/partial
/// intermediates left behind - and with a fault injected, one worker dies
/// mid-shard (SIGABRT), its shard is reassigned, and the bytes still
/// match.
#[test]
fn coordinator_cli_matches_single_process_and_survives_worker_kill() {
    // Reference: single-process, single-thread run of the same flags.
    let sp = test_dir("cli_sp");
    let out = run_cli(&["--threads", "1", "--out-dir", sp.to_str().unwrap()], &[]);
    assert!(out.status.success(), "single-process sweep failed: {out:?}");
    let want = read_artifacts(&sp);
    assert!(!want.2.is_empty(), "retained series expected");

    // Coordinator run, with stale files from a "previous aborted run"
    // dropped in first: they must not survive into the results.
    let mp = test_dir("cli_mp");
    std::fs::write(mp.join("sweep_shard9999.json"), "stale").unwrap();
    std::fs::write(mp.join("sweep_partial9999.json"), "stale").unwrap();
    std::fs::write(mp.join("sweep_partial9999.json.tmp"), "stale").unwrap();
    std::fs::write(mp.join("sweep_series_cell9999.csv"), "stale").unwrap();
    let out = run_cli(&["--workers", "2", "--out-dir", mp.to_str().unwrap()], &[]);
    assert!(out.status.success(), "coordinator sweep failed: {out:?}");
    assert_eq!(read_artifacts(&mp), want, "multi-process artifacts differ");
    for leftover in [
        "sweep_shard9999.json",
        "sweep_partial9999.json",
        "sweep_partial9999.json.tmp",
        "sweep_series_cell9999.csv",
        "sweep_shard0000.json",
        "sweep_partial0000.json",
    ] {
        assert!(
            !mp.join(leftover).exists(),
            "stale/intermediate file {leftover} survived the coordinator run"
        );
    }

    // Fault injection: the worker that takes shard 0 aborts right after
    // its first completed cell (once - the marker file disarms the
    // retry). The coordinator must reassign the shard and still produce
    // identical bytes.
    let ft = test_dir("cli_fault");
    let marker = ft.join("fault_marker");
    let out = run_cli(
        &["--workers", "2", "--out-dir", ft.to_str().unwrap()],
        &[("CLOUDMARKET_SWEEP_FAULT", format!("0:{}", marker.display()))],
    );
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "fault-injected sweep failed:\n{stderr}");
    assert!(marker.exists(), "the injected fault never fired");
    assert!(
        stderr.contains("reassigning"),
        "coordinator did not report the reassignment:\n{stderr}"
    );
    assert!(stderr.contains("1 reassigned"), "unexpected reassignment count:\n{stderr}");
    assert_eq!(
        read_artifacts(&ft),
        want,
        "artifacts after a mid-shard worker kill differ from the clean run"
    );

    for dir in [sp, mp, ft] {
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// A worker run on a shard file written by hand (the cluster recipe) and
/// merged via `sweep merge` equals the same cells from `sweep::run` - the
/// manual shard/worker/merge path stays honest, not just the coordinator.
#[test]
fn manual_shard_worker_merge_recipe_works() {
    let spec = mixed_spec();
    let reference = sweep::run(&spec, 2);
    let want = render(&reference);

    let dir = test_dir("manual");
    let shards = shard::partition(&spec, 2);
    let mut partial_args: Vec<String> = Vec::new();
    for s in &shards {
        let shard_file = dir.join(format!("sweep_shard{:04}.json", s.index));
        let partial_file = dir.join(format!("sweep_partial{:04}.json", s.index));
        shard::write_shard_file(&shard_file, &spec, s).unwrap();
        let out = Command::new(BIN)
            .args(["sweep", "worker", "--shard"])
            .arg(&shard_file)
            .arg("--out")
            .arg(&partial_file)
            .env_remove("CLOUDMARKET_SWEEP_FAULT")
            .output()
            .unwrap();
        assert!(out.status.success(), "worker failed: {out:?}");
        partial_args.push(partial_file.to_string_lossy().into_owned());
    }
    let merged_dir = dir.join("merged");
    let out = Command::new(BIN)
        .args(["sweep", "merge"])
        .args(&partial_args)
        .arg("--out-dir")
        .arg(&merged_dir)
        .output()
        .unwrap();
    assert!(out.status.success(), "merge failed: {out:?}");
    let (cells, agg, series) = read_artifacts(&merged_dir);
    assert_eq!(cells, want.0);
    assert_eq!(agg, want.1);
    let want_series: Vec<(String, String)> = want
        .2
        .iter()
        .map(|(id, text)| (format!("sweep_series_cell{id:04}.csv"), text.clone()))
        .collect();
    assert_eq!(series, want_series);
    let _ = std::fs::remove_dir_all(&dir);
}

/// A chaos grid (reclaim-storm severity x policy) through real worker
/// subprocesses: the coordinator's merged artifacts are byte-identical
/// to the in-process run at 1 and 2 workers, with the resilience columns
/// populated and the chaos labels in the cells CSV.
#[test]
fn chaos_storm_grid_byte_identical_across_processes() {
    use cloudmarket::chaos::ReclaimStorm;

    let scenario = ComparisonConfig { terminate_at: 400.0, ..Default::default() };
    let spec = SweepSpec::new(scenario)
        .with_seeds(vec![20_250_710])
        .with_policies(vec![PolicySpec::FirstFit, PolicySpec::BestFit])
        .with_axis(ScenarioAxis::ChaosReclaimStorm(vec![
            ReclaimStorm::parse("at150-frac0.5").unwrap(),
            ReclaimStorm::parse("at150-frac1").unwrap(),
        ]));
    assert_eq!(spec.cell_count(), 4);

    let reference = sweep::run(&spec, 2);
    assert_eq!(reference.failed(), 0, "no chaos cell may fail");
    let r0 = reference.cells[0].report().unwrap();
    assert_eq!(r0.resilience.storms, 1, "the storm must have fired");
    assert!(r0.resilience.storm_reclaims > 0, "the storm reclaimed nothing");
    let want = render(&reference);
    assert!(want.0.contains("at150-frac0.5"), "chaos label missing from cells CSV");
    assert!(want.1.contains("chaos_reclaim_storm"), "chaos key missing from aggregate");

    for workers in [1usize, 2] {
        let dir = test_dir(&format!("chaos_{workers}w"));
        let outcome =
            shard::coordinate(&spec, &shard::CoordinateOptions::new(workers, &dir, BIN))
                .unwrap();
        assert_eq!(
            render(&outcome.report),
            want,
            "{workers}-worker chaos artifacts differ from the in-process run"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// A market x chaos grid (spot-price volatility x bid margin crossed
/// with a reclaim storm) through real worker subprocesses: the
/// coordinator's merged artifacts - including retained series - are
/// byte-identical to the in-process single-thread run at 1 and 2
/// workers, with the cost columns populated and the market labels in
/// the cells CSV. This is the cross-process leg of the market
/// determinism contract: lazily compiled price paths must not let
/// worker count leak into any artifact byte.
#[test]
fn market_chaos_grid_byte_identical_across_processes() {
    use cloudmarket::chaos::ReclaimStorm;

    let scenario = ComparisonConfig { terminate_at: 400.0, ..Default::default() };
    let spec = SweepSpec::new(scenario)
        .with_seeds(vec![20_250_710])
        .with_policies(vec![PolicySpec::FirstFit, PolicySpec::BestFit])
        .with_axis(ScenarioAxis::ChaosReclaimStorm(vec![
            ReclaimStorm::parse("at150-frac0.5").unwrap(),
        ]))
        .with_axis(ScenarioAxis::MarketVolatility(vec![0.05, 2.0]))
        .with_axis(ScenarioAxis::MarketBidMargin(vec![1.5]))
        .with_series_retention(SeriesFilter::parse("policy=first-fit").unwrap());
    assert_eq!(spec.cell_count(), 4);

    let reference = sweep::run(&spec, 1);
    assert_eq!(reference.failed(), 0, "no market cell may fail");
    for c in &reference.cells {
        let r = c.report().unwrap();
        assert!(r.market.spot_cost_usd > 0.0, "cell {} accrued no spot cost", c.cell.id);
        assert!(r.market.on_demand_cost_usd > 0.0, "cell {} has no od reference", c.cell.id);
    }
    let want = render(&reference);
    assert!(!want.2.is_empty(), "retained first-fit series expected");
    assert!(want.0.contains("market_volatility"), "market columns missing from cells CSV");
    assert!(want.0.contains(",1.5,"), "bid-margin label missing from cells CSV");
    assert!(want.1.contains("market_bid_margin"), "market key missing from aggregate");
    assert!(want.1.contains("savings_ratio"), "cost moments missing from aggregate");

    for workers in [1usize, 2] {
        let dir = test_dir(&format!("market_{workers}w"));
        let outcome =
            shard::coordinate(&spec, &shard::CoordinateOptions::new(workers, &dir, BIN))
                .unwrap();
        assert_eq!(
            render(&outcome.report),
            want,
            "{workers}-worker market artifacts differ from the in-process run"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// A recovery-mode ablation under a full reclaim storm with
/// terminate-behavior spots, through real worker subprocesses: the
/// checkpointing and migrating cells must show surviving work
/// (`recovered_fraction > 0`) while `none`/`restart` recover nothing,
/// and the coordinator's merged artifacts are byte-identical to the
/// in-process run at 1 and 2 workers. Lazily compiled recovery
/// schedules must not let worker count leak into any artifact byte.
#[test]
fn recovery_storm_grid_byte_identical_across_processes() {
    use cloudmarket::chaos::ReclaimStorm;
    use cloudmarket::recovery::RecoveryMode;
    use cloudmarket::vm::InterruptionBehavior;

    let scenario = ComparisonConfig { terminate_at: 600.0, ..Default::default() };
    let spec = SweepSpec::new(scenario)
        .with_seeds(vec![20_250_710])
        .with_policies(vec![PolicySpec::FirstFit])
        .with_axis(ScenarioAxis::SpotBehavior(vec![InterruptionBehavior::Terminate]))
        .with_axis(ScenarioAxis::ChaosReclaimStorm(vec![
            ReclaimStorm::parse("at150-frac1").unwrap(),
        ]))
        .with_axis(ScenarioAxis::RecoveryMode(vec![
            RecoveryMode::None,
            RecoveryMode::Restart,
            RecoveryMode::Checkpoint,
            RecoveryMode::MigrateGreedy,
            RecoveryMode::MigrateOptimal,
        ]));
    assert_eq!(spec.cell_count(), 5);

    let reference = sweep::run(&spec, 1);
    assert_eq!(reference.failed(), 0, "no recovery cell may fail");
    for c in &reference.cells {
        let r = c.report().unwrap();
        let mode = c.cell.spec.recovery.mode.unwrap_or(RecoveryMode::None);
        if mode.checkpoints() {
            assert!(r.recovery.checkpoints > 0, "cell {} took no checkpoints", c.cell.id);
            assert!(
                r.recovery.recovered_fraction > 0.0,
                "cell {} ({mode:?}) salvaged no warned work",
                c.cell.id
            );
        } else {
            assert_eq!(
                r.recovery.recovered_fraction, 0.0,
                "cell {} ({mode:?}) must recover nothing",
                c.cell.id
            );
            assert!(r.recovery.work_lost_mi > 0.0, "the storm lost no work in cell {}", c.cell.id);
        }
    }
    let want = render(&reference);
    assert!(want.0.contains("migrate-optimal"), "recovery label missing from cells CSV");
    assert!(want.0.contains("recovered_fraction"), "recovery columns missing from cells CSV");
    assert!(want.1.contains("recovered_fraction"), "recovery moments missing from aggregate");

    for workers in [1usize, 2] {
        let dir = test_dir(&format!("recovery_{workers}w"));
        let outcome =
            shard::coordinate(&spec, &shard::CoordinateOptions::new(workers, &dir, BIN))
                .unwrap();
        assert_eq!(
            render(&outcome.report),
            want,
            "{workers}-worker recovery artifacts differ from the in-process run"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// A corrupt or foreign shard file makes the worker exit with the
/// dedicated bad-shard code, distinct from generic runtime failures, and
/// write no partial.
#[test]
fn worker_exits_bad_shard_code_on_corrupt_shard_file() {
    let dir = test_dir("badshard");
    let shard_file = dir.join("sweep_shard0000.json");
    let partial_file = dir.join("sweep_partial0000.json");
    for bad in ["{ not json", "{\"format\":\"something-else\",\"version\":1}"] {
        std::fs::write(&shard_file, bad).unwrap();
        let out = Command::new(BIN)
            .args(["sweep", "worker", "--shard"])
            .arg(&shard_file)
            .arg("--out")
            .arg(&partial_file)
            .output()
            .unwrap();
        assert!(!out.status.success());
        assert_eq!(
            out.status.code(),
            Some(shard::EXIT_BAD_SHARD),
            "bad shard file must map to the permanent exit code: {out:?}"
        );
        assert!(!partial_file.exists(), "no partial may be written for a bad shard");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// The coordinator's retry policy follows the worker exit-code taxonomy:
/// a runtime failure gets the shard reassigned (up to `max_attempts`),
/// while the bad-shard code fails the sweep on first sight - re-reading
/// a corrupt job file can never succeed, so retries would only burn time.
#[cfg(unix)]
#[test]
fn coordinator_retries_runtime_failures_but_not_bad_shards() {
    use std::os::unix::fs::PermissionsExt;

    // A stand-in worker that logs each spawn and exits with a fixed code.
    let write_fake_worker = |dir: &Path, code: i32| -> (PathBuf, PathBuf) {
        let exe = dir.join(format!("fake_worker_{code}.sh"));
        let count = dir.join(format!("spawn_count_{code}"));
        std::fs::write(
            &exe,
            format!("#!/bin/sh\necho x >> {}\nexit {code}\n", count.display()),
        )
        .unwrap();
        std::fs::set_permissions(&exe, std::fs::Permissions::from_mode(0o755)).unwrap();
        (exe, count)
    };
    let spec = SweepSpec::new(ComparisonConfig::default())
        .with_seeds(vec![1])
        .with_policies(vec![PolicySpec::FirstFit, PolicySpec::BestFit]);

    // Runtime failures (EXIT_RUNTIME) are retried before giving up.
    let dir = test_dir("taxonomy_runtime");
    let (exe, count) = write_fake_worker(&dir, shard::EXIT_RUNTIME);
    let mut opts = shard::CoordinateOptions::new(2, &dir, &exe);
    opts.max_attempts = 2;
    let err = shard::coordinate(&spec, &opts).unwrap_err();
    assert!(err.contains("giving up"), "{err}");
    let spawns = std::fs::read_to_string(&count).unwrap().lines().count();
    assert!(spawns >= 3, "expected at least one reassignment before failing ({spawns} spawns)");
    let _ = std::fs::remove_dir_all(&dir);

    // Bad-shard exits are permanent: both shards spawn once, the first
    // reap kills the run, and nothing is reassigned.
    let dir = test_dir("taxonomy_badshard");
    let (exe, count) = write_fake_worker(&dir, shard::EXIT_BAD_SHARD);
    let mut opts = shard::CoordinateOptions::new(2, &dir, &exe);
    opts.max_attempts = 3;
    let err = shard::coordinate(&spec, &opts).unwrap_err();
    assert!(err.contains("permanent"), "{err}");
    assert!(err.contains("not reassigning"), "{err}");
    let spawns = std::fs::read_to_string(&count).unwrap().lines().count();
    assert_eq!(spawns, 2, "a permanent failure must never respawn a worker");
    let _ = std::fs::remove_dir_all(&dir);
}
