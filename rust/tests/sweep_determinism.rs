//! Determinism parity for the sweep subsystem: the same `SweepSpec` run
//! with 1, 2 and 8 threads must produce byte-identical serialized sweep
//! reports - including mixed-axis grids spanning both substrates - and
//! `compare::run_multi` (now implemented on the sweep driver) must match
//! the pre-sweep sequential loop bit-for-bit.

use std::sync::Arc;

use cloudmarket::config::scenario::ComparisonConfig;
use cloudmarket::experiments::compare;
use cloudmarket::sweep::{
    self, PolicySpec, Prebuilt, PrebuildCache, PrebuildSlots, ScenarioAxis, SeriesFilter,
    Substrate, SweepSpec,
};

/// The §VII-E scenario with a shortened horizon so the grid stays cheap
/// in debug-mode test runs (interruptions still occur well before 600 s).
fn small_cfg() -> ComparisonConfig {
    ComparisonConfig { terminate_at: 600.0, ..Default::default() }
}

fn small_spec() -> SweepSpec {
    SweepSpec::new(small_cfg())
        .with_seed_range(20_250_710, 2)
        .with_policies(PolicySpec::paper())
}

#[test]
fn sweep_artifacts_byte_identical_across_thread_counts() {
    let render = |threads: usize| {
        let report = sweep::run(&small_spec(), threads);
        assert_eq!(report.total(), 6);
        assert_eq!(report.failed(), 0, "no cell may fail");
        (report.cells_csv().to_string(), report.aggregate_json().to_string_pretty())
    };
    let single = render(1);
    assert_eq!(single, render(2), "2-thread sweep output differs from single-threaded");
    assert_eq!(single, render(8), "8-thread sweep output differs from single-threaded");
}

/// `run_multi` on the sweep driver reproduces the pre-sweep sequential
/// behavior exactly (same float-accumulation order, so `==` on f64s).
#[test]
fn run_multi_matches_presweep_sequential_loop() {
    let base_cfg = small_cfg();
    let runs = 2;

    // The pre-sweep implementation, verbatim: seed-major loop, policies
    // rebuilt per seed, aggregates accumulated with `+= x / runs`.
    let mut expected: Vec<compare::Aggregate> = compare::paper_policies()
        .iter()
        .map(|(name, _)| compare::Aggregate {
            policy: name,
            runs,
            mean_interruptions: 0.0,
            mean_interrupted_vms: 0.0,
            mean_avg_duration: 0.0,
            mean_max_duration: 0.0,
            max_per_vm: 0,
        })
        .collect();
    for r in 0..runs {
        let cfg = ComparisonConfig { seed: base_cfg.seed + r as u64, ..base_cfg.clone() };
        for (i, (_, make)) in compare::paper_policies().into_iter().enumerate() {
            let o = compare::run_policy(make, &cfg);
            let a = &mut expected[i];
            a.mean_interruptions += o.report.spot.interruptions as f64 / runs as f64;
            a.mean_interrupted_vms += o.report.spot.interrupted_vms as f64 / runs as f64;
            a.mean_avg_duration += o.report.spot.avg_interruption_secs / runs as f64;
            a.mean_max_duration += o.report.spot.max_interruption_secs / runs as f64;
            a.max_per_vm = a.max_per_vm.max(o.report.spot.max_interruptions_per_vm);
        }
    }

    let actual = compare::run_multi_threaded(&base_cfg, runs, 4);
    assert_eq!(actual.len(), expected.len());
    for (a, e) in actual.iter().zip(&expected) {
        assert_eq!(a.policy, e.policy);
        assert_eq!(a.runs, e.runs);
        assert_eq!(a.mean_interruptions.to_bits(), e.mean_interruptions.to_bits(), "{}", a.policy);
        assert_eq!(
            a.mean_interrupted_vms.to_bits(),
            e.mean_interrupted_vms.to_bits(),
            "{}",
            a.policy
        );
        assert_eq!(a.mean_avg_duration.to_bits(), e.mean_avg_duration.to_bits(), "{}", a.policy);
        assert_eq!(a.mean_max_duration.to_bits(), e.mean_max_duration.to_bits(), "{}", a.policy);
        assert_eq!(a.max_per_vm, e.max_per_vm, "{}", a.policy);
    }
}

/// Cells of the same seed share one workload prebuild (built once, not
/// per cell).
#[test]
fn prebuilds_are_shared_per_seed() {
    let template = small_cfg();
    let mut cache = PrebuildCache::new();
    let spec = small_spec();
    let plans: Vec<_> =
        spec.cells().iter().map(|c| cache.get_or_build(&template, c.seed)).collect();
    assert_eq!(plans.len(), 6);
    assert_eq!(cache.len(), 2, "two distinct seeds -> two prebuilds");
    // Seed-major cells: the first three cells share seed 20250710's plan.
    assert!(Arc::ptr_eq(&plans[0], &plans[1]));
    assert!(Arc::ptr_eq(&plans[0], &plans[2]));
    assert!(!Arc::ptr_eq(&plans[0], &plans[3]));
    assert!(Arc::ptr_eq(&plans[3], &plans[5]));
}

/// Eight workers racing to lazily prebuild the *same* (substrate, seed)
/// pair share exactly one build - and a single-seed grid (every cell
/// contends on one slot) stays byte-identical across thread counts.
#[test]
fn racing_workers_share_one_lazy_prebuild() {
    let spec = SweepSpec::new(small_cfg())
        .with_seeds(vec![20_250_710])
        .with_policies(PolicySpec::paper());
    let cells = spec.cells();
    let slots = PrebuildSlots::for_cells(&cells);
    assert_eq!(slots.slot_count(), 1, "one (substrate, seed) pair -> one slot");
    assert_eq!(slots.built(), 0, "nothing is built before a worker asks");

    let ptrs: Vec<usize> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..8)
            .map(|w| {
                let (slots, spec, cells) = (&slots, &spec, &cells);
                scope.spawn(move || {
                    let i = w % cells.len();
                    match slots.get(spec, i, &cells[i]) {
                        Ok(Prebuilt::Comparison(plan)) => Arc::as_ptr(plan) as usize,
                        other => panic!("unexpected prebuild: {other:?}"),
                    }
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("racer panicked")).collect()
    });
    assert!(
        ptrs.windows(2).all(|w| w[0] == w[1]),
        "racing workers must share one prebuild Arc"
    );
    assert_eq!(slots.built(), 1, "the contended pair was built exactly once");

    // Full-driver determinism while 8 workers contend on the single slot.
    let render = |threads: usize| {
        let report = sweep::run(&spec, threads);
        assert_eq!(report.failed(), 0, "no cell may fail");
        (report.cells_csv().to_string(), report.aggregate_json().to_string_pretty())
    };
    assert_eq!(render(1), render(8), "racing lazy prebuilds changed the artifacts");
}

/// A mixed-axis grid (spot-config × alpha × substrate) with per-cell
/// series retention: 1/2/8-thread runs serialize byte-identically, cell
/// enumeration covers the full cartesian product, and retained series are
/// themselves thread-count-independent.
#[test]
fn mixed_axis_grid_byte_identical_across_thread_counts() {
    let spec = || {
        let mut spec = SweepSpec::new(small_cfg())
            .with_seeds(vec![20_250_710])
            .with_policies(vec![
                PolicySpec::FirstFit,
                PolicySpec::Hlem { adjusted: true, alpha: -0.5 },
            ])
            .with_axis(ScenarioAxis::HlemAlpha(vec![-0.5, -0.2]))
            .with_axis(ScenarioAxis::SpotWarning(vec![2.0, 120.0]))
            .with_axis(ScenarioAxis::Substrate(vec![
                Substrate::Comparison,
                Substrate::Trace,
            ]))
            .with_series_retention(SeriesFilter::parse("policy=first-fit").unwrap());
        // Tiny trace substrate so the grid stays cheap in debug runs.
        spec.trace.synth.machines = 10;
        spec.trace.synth.days = 0.05;
        spec.trace.synth.tasks_per_hour = 120.0;
        spec.trace.workload.spot_instances = 20;
        spec.trace.workload.spot_durations = vec![300.0, 600.0];
        spec.trace.workload.max_trace_vms = 50;
        spec
    };
    // Variants: [ff, adj(-0.5), adj(-0.2)] x 2 warnings x 2 substrates.
    assert_eq!(spec().cell_count(), 12);

    let render = |threads: usize| {
        let report = sweep::run(&spec(), threads);
        assert_eq!(report.total(), 12);
        assert_eq!(report.failed(), 0, "no cell may fail");
        let series: Vec<(usize, String)> = report
            .retained_series_csvs()
            .into_iter()
            .map(|(id, csv)| (id, csv.to_string()))
            .collect();
        assert_eq!(series.len(), 4, "first-fit cells across substrates retain series");
        (report.cells_csv().to_string(), report.aggregate_json().to_string_pretty(), series)
    };
    let single = render(1);
    // Axis values reach the artifacts: both substrates and both warning
    // values appear as their own CSV columns.
    assert!(single.0.contains(",trace,"), "trace substrate rows missing:\n{}", single.0);
    assert!(single.0.contains(",comparison,"), "comparison rows missing");
    assert!(single.0.contains(",120,"), "warning axis value missing");
    assert_eq!(single, render(2), "2-thread sweep output differs from single-threaded");
    assert_eq!(single, render(8), "8-thread sweep output differs from single-threaded");
}

/// Explicit-list cells run too and land after the grid in id order.
#[test]
fn explicit_cells_run_after_grid() {
    let spec = SweepSpec::new(small_cfg())
        .with_seeds(vec![20_250_710])
        .with_policies(vec![PolicySpec::FirstFit])
        .with_cell(20_250_711, PolicySpec::Hlem { adjusted: true, alpha: -0.5 });
    let report = sweep::run(&spec, 2);
    assert_eq!(report.total(), 2);
    assert_eq!(report.failed(), 0);
    assert_eq!(report.cells[1].cell.seed, 20_250_711);
    assert_eq!(report.cells[1].cell.policy().name(), "hlem-vmp-adjusted");
}
