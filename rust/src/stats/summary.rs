//! Streaming summary statistics (count/mean/min/max/stddev/percentiles)
//! used by the metrics recorder and the bench harness.

/// Accumulates samples; percentiles require keeping values (kept by default,
/// call [`Summary::reservoir`] for bounded memory on huge streams).
#[derive(Debug, Clone)]
pub struct Summary {
    values: Vec<f64>,
    count: u64,
    sum: f64,
    sum_sq: f64,
    min: f64,
    max: f64,
    cap: Option<usize>,
    seen_for_reservoir: u64,
    rng_state: u64,
}

impl Default for Summary {
    fn default() -> Self {
        Self::new()
    }
}

impl Summary {
    pub fn new() -> Self {
        Summary {
            values: Vec::new(),
            count: 0,
            sum: 0.0,
            sum_sq: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            cap: None,
            seen_for_reservoir: 0,
            rng_state: 0x853c49e6748fea9b,
        }
    }

    /// Bound kept values to `cap` via reservoir sampling (Algorithm R);
    /// moments stay exact, percentiles become approximate.
    pub fn reservoir(cap: usize) -> Self {
        let mut s = Self::new();
        s.cap = Some(cap);
        s
    }

    fn next_rand(&mut self) -> u64 {
        // xorshift64*; only used for reservoir replacement decisions.
        let mut x = self.rng_state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.rng_state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    pub fn add(&mut self, v: f64) {
        self.count += 1;
        self.sum += v;
        self.sum_sq += v * v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        match self.cap {
            None => self.values.push(v),
            Some(cap) => {
                self.seen_for_reservoir += 1;
                if self.values.len() < cap {
                    self.values.push(v);
                } else {
                    let j = self.next_rand() % self.seen_for_reservoir;
                    if (j as usize) < cap {
                        self.values[j as usize] = v;
                    }
                }
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 { f64::NAN } else { self.sum / self.count as f64 }
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 { f64::NAN } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 { f64::NAN } else { self.max }
    }

    /// Population standard deviation.
    pub fn stddev(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let n = self.count as f64;
        let var = (self.sum_sq / n) - (self.sum / n) * (self.sum / n);
        var.max(0.0).sqrt()
    }

    /// Percentile in [0, 100], nearest-rank on the kept sample.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.values.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((p / 100.0) * (sorted.len() as f64 - 1.0)).round() as usize;
        sorted[rank.min(sorted.len() - 1)]
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let mut s = Summary::new();
        for v in [1.0, 2.0, 3.0, 4.0] {
            s.add(v);
        }
        assert_eq!(s.count(), 4);
        assert_eq!(s.mean(), 2.5);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert!((s.stddev() - 1.118033988749895).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let mut s = Summary::new();
        for v in 1..=100 {
            s.add(v as f64);
        }
        assert!((s.median() - 50.5).abs() <= 0.5); // nearest-rank: 50 or 51
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 100.0);
        assert!((s.percentile(95.0) - 95.0).abs() <= 1.0);
    }

    #[test]
    fn empty_is_nan() {
        let s = Summary::new();
        assert!(s.mean().is_nan());
        assert!(s.percentile(50.0).is_nan());
    }

    #[test]
    fn reservoir_keeps_exact_moments_bounded_memory() {
        let mut s = Summary::reservoir(100);
        for v in 0..10_000 {
            s.add(v as f64);
        }
        assert_eq!(s.count(), 10_000);
        assert_eq!(s.mean(), 4999.5);
        assert_eq!(s.max(), 9999.0);
        // approximate median within 15% of true
        let med = s.median();
        assert!((med - 5000.0).abs() < 1500.0, "median {med}");
    }
}
