//! Samplable distributions for workload synthesis.
//!
//! The synthetic Google-trace generator (DESIGN.md §6) uses lognormal task
//! durations, Zipf per-user task counts and exponential interarrivals -
//! shapes reported for the 2011 Borg trace by Reiss et al. and Tirmazi et
//! al. (paper refs [41], [42]).

use super::rng::Rng;

/// A samplable univariate distribution.
#[derive(Debug, Clone, PartialEq)]
pub enum Dist {
    /// Always `value`.
    Constant(f64),
    /// Uniform on [lo, hi).
    Uniform { lo: f64, hi: f64 },
    /// Exponential with rate lambda (mean 1/lambda).
    Exp { lambda: f64 },
    /// Normal(mu, sigma).
    Normal { mu: f64, sigma: f64 },
    /// Lognormal: exp(Normal(mu, sigma)).
    LogNormal { mu: f64, sigma: f64 },
    /// Zipf over ranks 1..=n with exponent s (returned as f64 rank).
    Zipf { n: u64, s: f64 },
}

impl Dist {
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        match *self {
            Dist::Constant(v) => v,
            Dist::Uniform { lo, hi } => rng.uniform(lo, hi),
            Dist::Exp { lambda } => {
                debug_assert!(lambda > 0.0);
                // Inverse CDF; 1-u to avoid ln(0).
                -(1.0 - rng.next_f64()).ln() / lambda
            }
            Dist::Normal { mu, sigma } => mu + sigma * sample_standard_normal(rng),
            Dist::LogNormal { mu, sigma } => (mu + sigma * sample_standard_normal(rng)).exp(),
            Dist::Zipf { n, s } => sample_zipf(rng, n, s) as f64,
        }
    }

    /// Sample, clamped to [lo, hi].
    pub fn sample_clamped(&self, rng: &mut Rng, lo: f64, hi: f64) -> f64 {
        self.sample(rng).clamp(lo, hi)
    }

    /// Theoretical mean where closed-form (panics for Zipf; use empirics).
    pub fn mean(&self) -> f64 {
        match *self {
            Dist::Constant(v) => v,
            Dist::Uniform { lo, hi } => 0.5 * (lo + hi),
            Dist::Exp { lambda } => 1.0 / lambda,
            Dist::Normal { mu, .. } => mu,
            Dist::LogNormal { mu, sigma } => (mu + 0.5 * sigma * sigma).exp(),
            Dist::Zipf { .. } => panic!("Zipf mean not supported"),
        }
    }
}

/// Marsaglia polar method.
fn sample_standard_normal(rng: &mut Rng) -> f64 {
    loop {
        let u = 2.0 * rng.next_f64() - 1.0;
        let v = 2.0 * rng.next_f64() - 1.0;
        let s = u * u + v * v;
        if s > 0.0 && s < 1.0 {
            return u * (-2.0 * s.ln() / s).sqrt();
        }
    }
}

/// Zipf via rejection-inversion (Hörmann & Derflinger), valid for s > 0,
/// s != 1 handled via the generalized harmonic inverse-CDF fallback for
/// small n (n <= 1024) which is exact.
fn sample_zipf(rng: &mut Rng, n: u64, s: f64) -> u64 {
    debug_assert!(n >= 1);
    if n == 1 {
        return 1;
    }
    // Exact inverse-CDF for modest n (the generator uses n <= few thousand).
    let mut weights = Vec::with_capacity(n as usize);
    let mut total = 0.0;
    for k in 1..=n {
        let w = 1.0 / (k as f64).powf(s);
        total += w;
        weights.push(total);
    }
    let x = rng.next_f64() * total;
    match weights.binary_search_by(|w| w.partial_cmp(&x).unwrap()) {
        Ok(i) => i as u64 + 1,
        Err(i) => (i as u64 + 1).min(n),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn empirical_mean(d: &Dist, seed: u64, n: usize) -> f64 {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| d.sample(&mut rng)).sum::<f64>() / n as f64
    }

    #[test]
    fn constant_and_uniform() {
        let mut rng = Rng::new(1);
        assert_eq!(Dist::Constant(4.2).sample(&mut rng), 4.2);
        let d = Dist::Uniform { lo: 2.0, hi: 6.0 };
        for _ in 0..1000 {
            let x = d.sample(&mut rng);
            assert!((2.0..6.0).contains(&x));
        }
        assert!((empirical_mean(&d, 2, 50_000) - 4.0).abs() < 0.05);
    }

    #[test]
    fn exponential_mean_matches() {
        let d = Dist::Exp { lambda: 0.5 };
        assert!((empirical_mean(&d, 3, 100_000) - 2.0).abs() < 0.05);
    }

    #[test]
    fn normal_moments_match() {
        let d = Dist::Normal { mu: 10.0, sigma: 3.0 };
        let mut rng = Rng::new(4);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| d.sample(&mut rng)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!((mean - 10.0).abs() < 0.05, "mean {mean}");
        assert!((var - 9.0).abs() < 0.2, "var {var}");
    }

    #[test]
    fn lognormal_is_positive_and_heavy_tailed() {
        let d = Dist::LogNormal { mu: 1.0, sigma: 1.0 };
        let mut rng = Rng::new(5);
        let xs: Vec<f64> = (0..50_000).map(|_| d.sample(&mut rng)).collect();
        assert!(xs.iter().all(|&x| x > 0.0));
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((mean - d.mean()).abs() / d.mean() < 0.1, "mean {mean} vs {}", d.mean());
    }

    #[test]
    fn zipf_rank_one_dominates() {
        let d = Dist::Zipf { n: 100, s: 1.2 };
        let mut rng = Rng::new(6);
        let mut counts = vec![0u32; 101];
        for _ in 0..20_000 {
            counts[d.sample(&mut rng) as usize] += 1;
        }
        assert!(counts[1] > counts[2]);
        assert!(counts[2] > counts[10]);
        assert!(counts.iter().skip(2).all(|&c| c < counts[1]));
    }

    #[test]
    fn clamped_sampling() {
        let d = Dist::Normal { mu: 0.0, sigma: 100.0 };
        let mut rng = Rng::new(7);
        for _ in 0..1000 {
            let x = d.sample_clamped(&mut rng, -1.0, 1.0);
            assert!((-1.0..=1.0).contains(&x));
        }
    }
}
