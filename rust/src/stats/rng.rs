//! xoshiro256++ PRNG seeded via SplitMix64.
//!
//! Reference: Blackman & Vigna, "Scrambled linear pseudorandom number
//! generators" (2019). Not cryptographic; chosen for speed, equidistribution
//! and reproducibility across platforms.

/// Deterministic PRNG (xoshiro256++).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that similar seeds yield decorrelated states.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        let s = [next_sm(), next_sm(), next_sm(), next_sm()];
        Rng { s }
    }

    /// Derive an independent child stream (for per-subsystem rngs that must
    /// not perturb each other's sequences when call counts change).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xa0761d6478bd642f))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        debug_assert!(hi >= lo);
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in [0, n) (Lemire's method, unbiased).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Pick an index by unnormalized weights.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted_index with non-positive total");
        let mut x = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(12345);
        let mut b = Rng::new(12345);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_is_unbiased_at_small_n() {
        let mut r = Rng::new(9);
        let mut counts = [0u32; 3];
        for _ in 0..30_000 {
            counts[r.below(3) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "counts {counts:?}");
        }
    }

    #[test]
    fn fork_streams_are_decorrelated() {
        let mut base = Rng::new(11);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut r = Rng::new(5);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0u32; 3];
        for _ in 0..10_000 {
            counts[r.weighted_index(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }
}
