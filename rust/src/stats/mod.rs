//! Deterministic randomness and statistics substrate.
//!
//! The `rand` crate family does not resolve in the offline crate set
//! (DESIGN.md §7); simulation science additionally *wants* a fully
//! deterministic, explicitly-seeded generator so that the paper's
//! "same randomized values reused across all simulation runs" methodology
//! (§VII-E.2) is enforced by construction.

pub mod dist;
pub mod rng;
pub mod summary;

pub use dist::Dist;
pub use rng::Rng;
pub use summary::Summary;
