//! Deterministic chaos injection: correlated failures & capacity shocks.
//!
//! The paper evaluates spot policies only against *independent* per-VM
//! reclaims, but real spot markets fail in correlated bursts (capacity
//! crunches reclaim whole AZs at once). This module turns seeded,
//! declarative fault specs - host crash/recovery (MTBF/MTTR), AZ-wide
//! reclaim storms, broker outage windows and demand surges - into a
//! pre-scheduled [`ChaosSchedule`] consumed by the existing engine event
//! loop through four `Tag::Chaos*` events. [`compile`] is a pure function
//! of `(spec, seed, horizon, n_hosts)`: no wall clock, no global RNG,
//! per-host derived streams - so compiled schedules are byte-identical at
//! any thread/worker count and in any compile order, and the sweep's
//! deterministic-artifact contract (pinned by `tests/sweep_determinism.rs`
//! and `tests/properties.rs`) is untouched.
//!
//! Fault values use a dash-separated `key<number>` grammar. The canonical
//! [`ChaosSpec`] labels round-trip exactly through the parsers because
//! every number is emitted with Rust's shortest-round-trip `f64` Display:
//!
//! - `chaos.host-mtbf=mtbf20000-mttr600`
//! - `chaos.reclaim-storm=at1200-frac0.5` (one storm) or
//!   `at600-frac0.25-x3-every900` (a storm train)
//! - `chaos.broker-outage=at900-for300`
//! - `chaos.demand-surge=at600-vms40-pes4-for600`

use crate::cloudlet::Cloudlet;
use crate::core::EntityId;
use crate::engine::{Engine, Tag};
use crate::infra::HostId;
use crate::stats::Rng;
use crate::vm::{Vm, VmSpec};

/// Host crash/recovery process: exponential inter-crash times with mean
/// `mtbf` and exponential repair times with mean `mttr`, drawn per host
/// from an independent derived RNG stream.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HostMtbf {
    /// Mean time between failures (seconds).
    pub mtbf: f64,
    /// Mean time to recovery (seconds).
    pub mttr: f64,
}

/// AZ-wide spot reclaim storm: at each storm timestamp, a fraction of all
/// currently interruptible spot VMs receives the interruption warning at
/// once (correlated reclaim, vs the engine's per-VM preemptions).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReclaimStorm {
    /// First storm timestamp (seconds).
    pub at: f64,
    /// Fraction of interruptible spot VMs reclaimed per storm, in (0, 1].
    pub frac: f64,
    /// Number of storms in the train (`x` segment; default 1).
    pub count: u32,
    /// Spacing between storms (`every` segment; 0 when `count` is 1).
    pub every: f64,
}

/// Broker outage window `[at, at+for)`: pending-request retries are
/// deferred while the window is open, then drained just after it closes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BrokerOutage {
    pub at: f64,
    /// Window duration (seconds; the `for` segment).
    pub dur: f64,
}

/// On-demand demand surge: `vms` extra persistent on-demand VMs of `pes`
/// PEs each arrive at `at` and run for `for` seconds, shrinking the spot
/// headroom (and preempting spots) for the surge duration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DemandSurge {
    pub at: f64,
    pub vms: u32,
    pub pes: u32,
    /// Surge duration (seconds; the `for` segment).
    pub dur: f64,
}

/// Declarative per-cell chaos configuration: at most one spec per fault
/// family. [`ChaosSpec::NONE`] (the default) injects nothing and leaves
/// the engine behavior bit-identical to a chaos-free build.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct ChaosSpec {
    pub host_mtbf: Option<HostMtbf>,
    pub reclaim_storm: Option<ReclaimStorm>,
    pub broker_outage: Option<BrokerOutage>,
    pub demand_surge: Option<DemandSurge>,
}

impl ChaosSpec {
    /// The no-chaos spec (every family absent).
    pub const NONE: ChaosSpec = ChaosSpec {
        host_mtbf: None,
        reclaim_storm: None,
        broker_outage: None,
        demand_surge: None,
    };

    pub fn is_none(&self) -> bool {
        *self == ChaosSpec::NONE
    }
}

// ----------------------------------------------------------------------
// value grammar: dash-separated `key<number>` segments
// ----------------------------------------------------------------------

/// Split one `key<number>` segment. All grammar numbers are non-negative,
/// so splitting the value on `-` beforehand is unambiguous.
fn segment(s: &str) -> Result<(&str, f64), String> {
    let i = s
        .find(|c: char| c.is_ascii_digit() || c == '.')
        .ok_or_else(|| format!("bad chaos segment '{s}' (expected key<number>)"))?;
    let (key, num) = s.split_at(i);
    if key.is_empty() {
        return Err(format!("bad chaos segment '{s}' (missing key)"));
    }
    let v: f64 = num
        .parse()
        .map_err(|_| format!("bad number '{num}' in chaos segment '{s}'"))?;
    if !v.is_finite() || v < 0.0 {
        return Err(format!("chaos segment '{s}' must be a finite non-negative number"));
    }
    Ok((key, v))
}

fn segments(s: &str) -> Result<Vec<(&str, f64)>, String> {
    s.trim().split('-').map(segment).collect()
}

/// Check a segment value is a whole number representable as `u32`.
fn whole(key: &str, v: f64) -> Result<u32, String> {
    if v.fract() != 0.0 || v > u32::MAX as f64 {
        return Err(format!("chaos segment '{key}{v}' must be a whole number"));
    }
    Ok(v as u32)
}

impl HostMtbf {
    /// Canonical value string (`mtbf<secs>-mttr<secs>`).
    pub fn label(&self) -> String {
        format!("mtbf{}-mttr{}", self.mtbf, self.mttr)
    }

    pub fn parse(s: &str) -> Result<HostMtbf, String> {
        match segments(s)?.as_slice() {
            [("mtbf", mtbf), ("mttr", mttr)] if *mtbf > 0.0 && *mttr > 0.0 => {
                Ok(HostMtbf { mtbf: *mtbf, mttr: *mttr })
            }
            _ => Err(format!(
                "bad chaos.host-mtbf value '{s}' (expected mtbf<secs>-mttr<secs>, both > 0)"
            )),
        }
    }
}

impl ReclaimStorm {
    /// Canonical value string (`at<t>-frac<f>[-x<n>-every<secs>]`; the
    /// train segments are omitted for a single storm).
    pub fn label(&self) -> String {
        if self.count > 1 {
            format!("at{}-frac{}-x{}-every{}", self.at, self.frac, self.count, self.every)
        } else {
            format!("at{}-frac{}", self.at, self.frac)
        }
    }

    pub fn parse(s: &str) -> Result<ReclaimStorm, String> {
        let err = || {
            format!(
                "bad chaos.reclaim-storm value '{s}' (expected \
                 at<t>-frac<f> or at<t>-frac<f>-x<n>-every<secs>, \
                 frac in (0,1], n >= 1, every > 0)"
            )
        };
        match segments(s)?.as_slice() {
            [("at", at), ("frac", frac)] if *frac > 0.0 && *frac <= 1.0 => {
                Ok(ReclaimStorm { at: *at, frac: *frac, count: 1, every: 0.0 })
            }
            [("at", at), ("frac", frac), ("x", n), ("every", every)]
                if *frac > 0.0 && *frac <= 1.0 && *every > 0.0 =>
            {
                let count = whole("x", *n)?;
                if count == 0 {
                    return Err(err());
                }
                if count == 1 {
                    // Canonical single-storm form omits the train segments.
                    return Ok(ReclaimStorm { at: *at, frac: *frac, count: 1, every: 0.0 });
                }
                Ok(ReclaimStorm { at: *at, frac: *frac, count, every: *every })
            }
            _ => Err(err()),
        }
    }
}

impl BrokerOutage {
    /// Canonical value string (`at<t>-for<secs>`).
    pub fn label(&self) -> String {
        format!("at{}-for{}", self.at, self.dur)
    }

    pub fn parse(s: &str) -> Result<BrokerOutage, String> {
        match segments(s)?.as_slice() {
            [("at", at), ("for", dur)] if *dur > 0.0 => {
                Ok(BrokerOutage { at: *at, dur: *dur })
            }
            _ => Err(format!(
                "bad chaos.broker-outage value '{s}' (expected at<t>-for<secs>, for > 0)"
            )),
        }
    }
}

impl DemandSurge {
    /// Canonical value string (`at<t>-vms<n>-pes<p>-for<secs>`).
    pub fn label(&self) -> String {
        format!("at{}-vms{}-pes{}-for{}", self.at, self.vms, self.pes, self.dur)
    }

    pub fn parse(s: &str) -> Result<DemandSurge, String> {
        match segments(s)?.as_slice() {
            [("at", at), ("vms", vms), ("pes", pes), ("for", dur)] if *dur > 0.0 => {
                let vms = whole("vms", *vms)?;
                let pes = whole("pes", *pes)?;
                if vms == 0 || pes == 0 {
                    return Err(format!(
                        "bad chaos.demand-surge value '{s}' (vms and pes must be >= 1)"
                    ));
                }
                Ok(DemandSurge { at: *at, vms, pes, dur: *dur })
            }
            _ => Err(format!(
                "bad chaos.demand-surge value '{s}' (expected \
                 at<t>-vms<n>-pes<p>-for<secs>, for > 0)"
            )),
        }
    }
}

// ----------------------------------------------------------------------
// compiled schedule
// ----------------------------------------------------------------------

/// One compiled host fault: crash at `crash_at`, recover at `recover_at`
/// (`None` when the repair completes past the horizon).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HostFault {
    pub host: HostId,
    pub crash_at: f64,
    pub recover_at: Option<f64>,
}

/// One compiled reclaim storm occurrence.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Storm {
    pub at: f64,
    pub frac: f64,
}

/// One compiled demand-surge occurrence.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Surge {
    pub at: f64,
    pub vms: u32,
    pub pes: u32,
    pub dur: f64,
}

/// A fully-resolved fault schedule: every random draw consumed, every
/// event timestamped. Pure data - applying it ([`apply`]) only schedules
/// engine events, so the same schedule always produces the same run.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ChaosSchedule {
    /// Host crash/recover pairs, host-major then time-ascending per host.
    pub host_faults: Vec<HostFault>,
    pub storms: Vec<Storm>,
    /// Broker outage windows as half-open `[start, end)` intervals.
    pub outages: Vec<(f64, f64)>,
    pub surges: Vec<Surge>,
}

impl ChaosSchedule {
    pub fn is_empty(&self) -> bool {
        self.host_faults.is_empty()
            && self.storms.is_empty()
            && self.outages.is_empty()
            && self.surges.is_empty()
    }
}

/// Derive an independent RNG stream for `(seed, family, stream)`. Each
/// host gets its own stream so the compiled fault list is independent of
/// host iteration order.
fn stream_rng(seed: u64, family: u64, stream: u64) -> Rng {
    Rng::new(
        seed ^ family.wrapping_mul(0x9e37_79b9_7f4a_7c15)
            ^ stream.wrapping_mul(0xa076_1d64_78bd_642f),
    )
}

/// Exponential draw with the given mean (inverse-CDF on a [0,1) uniform).
fn draw_exp(rng: &mut Rng, mean: f64) -> f64 {
    -mean * (1.0 - rng.next_f64()).ln()
}

const FAMILY_HOST: u64 = 1;

/// Compile `spec` into a [`ChaosSchedule`] - a pure function of the
/// arguments. `horizon` bounds every event timestamp; `n_hosts` is the
/// host population the MTBF process runs over.
pub fn compile(spec: &ChaosSpec, seed: u64, horizon: f64, n_hosts: usize) -> ChaosSchedule {
    let mut sched = ChaosSchedule::default();
    if let Some(m) = spec.host_mtbf {
        for host in 0..n_hosts {
            let mut rng = stream_rng(seed, FAMILY_HOST, host as u64);
            let mut t = draw_exp(&mut rng, m.mtbf);
            while t < horizon {
                let recover = t + draw_exp(&mut rng, m.mttr);
                sched.host_faults.push(HostFault {
                    host,
                    crash_at: t,
                    recover_at: if recover < horizon { Some(recover) } else { None },
                });
                t = recover + draw_exp(&mut rng, m.mtbf);
            }
        }
    }
    if let Some(s) = spec.reclaim_storm {
        for i in 0..s.count {
            let at = s.at + i as f64 * s.every;
            if at < horizon {
                sched.storms.push(Storm { at, frac: s.frac });
            }
        }
    }
    if let Some(o) = spec.broker_outage {
        if o.at < horizon {
            sched.outages.push((o.at, o.at + o.dur));
        }
    }
    if let Some(s) = spec.demand_surge {
        if s.at < horizon {
            sched.surges.push(Surge { at: s.at, vms: s.vms, pes: s.pes, dur: s.dur });
        }
    }
    sched
}

/// Inject a compiled schedule into a freshly-built engine (after the
/// workload is submitted, before `run`). Only schedules events and
/// submits surge VMs - the engine core stays unmodified; the chaos event
/// handlers live behind the new `Tag::Chaos*` dispatch arms.
pub fn apply(engine: &mut Engine, sched: &ChaosSchedule) {
    for f in &sched.host_faults {
        if f.host >= engine.world.hosts.len() {
            continue; // spec compiled for a larger cluster than built
        }
        let dc = engine.world.hosts[f.host].dc;
        engine.sim.schedule_at(
            f.crash_at,
            EntityId::Kernel,
            EntityId::Datacenter(dc),
            Tag::ChaosHostCrash(f.host),
        );
        if let Some(r) = f.recover_at {
            engine.sim.schedule_at(
                r,
                EntityId::Kernel,
                EntityId::Datacenter(dc),
                Tag::ChaosHostRecover(f.host),
            );
        }
    }
    for (k, s) in sched.storms.iter().enumerate() {
        engine.chaos_storms.push(s.frac);
        engine.sim.schedule_at(
            s.at,
            EntityId::Kernel,
            EntityId::Broker(0),
            Tag::ChaosStorm(k),
        );
    }
    for &(start, end) in &sched.outages {
        engine.chaos_outages.push((start, end));
        // Drain strictly after the half-open window closes: one min_dt
        // step past `end` survives the kernel's time quantization.
        let drain = end + engine.config.min_dt.max(1e-9);
        engine.sim.schedule_at(
            drain,
            EntityId::Kernel,
            EntityId::Broker(0),
            Tag::ChaosRetryDrain,
        );
    }
    for s in &sched.surges {
        // Surges reuse the ordinary submission machinery: persistent
        // on-demand VMs that arrive at `at` and hold capacity for `dur`.
        // On-demand arrivals preempt spots through the normal policy
        // path, so the surge shrinks spot headroom exactly like organic
        // demand would.
        let mips = 1_000.0;
        for _ in 0..s.vms {
            let vm = engine.submit_vm(
                Vm::on_demand(0, VmSpec::new(mips, s.pes))
                    .with_persistent(s.dur)
                    .with_delay(s.at),
            );
            engine.submit_cloudlet(
                Cloudlet::new(0, s.dur * mips * s.pes as f64, s.pes).with_vm(vm),
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allocation::FirstFit;
    use crate::engine::EngineConfig;
    use crate::infra::HostSpec;
    use crate::vm::SpotConfig;

    fn spec_all() -> ChaosSpec {
        ChaosSpec {
            host_mtbf: Some(HostMtbf { mtbf: 2_000.0, mttr: 300.0 }),
            reclaim_storm: Some(ReclaimStorm { at: 600.0, frac: 0.5, count: 3, every: 900.0 }),
            broker_outage: Some(BrokerOutage { at: 900.0, dur: 300.0 }),
            demand_surge: Some(DemandSurge { at: 600.0, vms: 4, pes: 2, dur: 600.0 }),
        }
    }

    #[test]
    fn labels_round_trip_through_parsers() {
        let s = spec_all();
        let m = s.host_mtbf.unwrap();
        assert_eq!(HostMtbf::parse(&m.label()).unwrap(), m);
        let st = s.reclaim_storm.unwrap();
        assert_eq!(ReclaimStorm::parse(&st.label()).unwrap(), st);
        let single = ReclaimStorm { at: 120.0, frac: 0.25, count: 1, every: 0.0 };
        assert_eq!(single.label(), "at120-frac0.25");
        assert_eq!(ReclaimStorm::parse(&single.label()).unwrap(), single);
        let o = s.broker_outage.unwrap();
        assert_eq!(BrokerOutage::parse(&o.label()).unwrap(), o);
        let d = s.demand_surge.unwrap();
        assert_eq!(DemandSurge::parse(&d.label()).unwrap(), d);
    }

    #[test]
    fn parsers_reject_bad_grammar() {
        assert!(HostMtbf::parse("mtbf0-mttr60").is_err());
        assert!(HostMtbf::parse("mtbf100").is_err());
        assert!(ReclaimStorm::parse("at100-frac1.5").is_err());
        assert!(ReclaimStorm::parse("at100-frac0.5-every60").is_err(), "every without x");
        assert!(ReclaimStorm::parse("at100-frac0.5-x0-every60").is_err());
        assert!(ReclaimStorm::parse("at100-frac0.5-x2.5-every60").is_err());
        assert!(BrokerOutage::parse("at100-for0").is_err());
        assert!(BrokerOutage::parse("at100").is_err());
        assert!(DemandSurge::parse("at100-vms0-pes2-for60").is_err());
        assert!(DemandSurge::parse("at100-vms2-pes2").is_err());
        assert!(segment("frac").is_err());
        assert!(segment("0.5").is_err());
    }

    /// An `x1` train parses to the canonical single-storm form, so label
    /// round-trips stay exact.
    #[test]
    fn single_storm_train_canonicalizes() {
        let s = ReclaimStorm::parse("at100-frac0.5-x1-every60").unwrap();
        assert_eq!(s, ReclaimStorm { at: 100.0, frac: 0.5, count: 1, every: 0.0 });
        assert_eq!(s.label(), "at100-frac0.5");
    }

    /// Compilation is a pure function: identical inputs give identical
    /// schedules (byte-compared through Debug), different seeds differ.
    #[test]
    fn compile_is_seed_deterministic() {
        let spec = spec_all();
        let a = compile(&spec, 7, 4_800.0, 20);
        let b = compile(&spec, 7, 4_800.0, 20);
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
        let c = compile(&spec, 8, 4_800.0, 20);
        assert_ne!(a, c);
    }

    /// Every compiled event timestamp respects the horizon, and repair
    /// completions past the horizon compile to `recover_at: None`.
    #[test]
    fn compile_respects_horizon() {
        let spec = spec_all();
        let horizon = 2_000.0;
        let sched = compile(&spec, 3, horizon, 50);
        for f in &sched.host_faults {
            assert!(f.crash_at < horizon);
            if let Some(r) = f.recover_at {
                assert!(r < horizon && r > f.crash_at);
            }
        }
        for s in &sched.storms {
            assert!(s.at < horizon);
        }
        // Storm train: 600 and 1500 fire, 2400 is clipped.
        assert_eq!(sched.storms.len(), 2);
        for &(start, end) in &sched.outages {
            assert!(start < horizon && end > start);
        }
        assert!(compile(&ChaosSpec::NONE, 3, horizon, 50).is_empty());
    }

    /// Per-host RNG streams: a host's fault sequence does not depend on
    /// how many hosts come before it.
    #[test]
    fn host_streams_are_independent() {
        let spec = ChaosSpec { host_mtbf: spec_all().host_mtbf, ..ChaosSpec::NONE };
        let small = compile(&spec, 11, 10_000.0, 5);
        let large = compile(&spec, 11, 10_000.0, 50);
        let faults_of = |s: &ChaosSchedule, h: HostId| {
            s.host_faults.iter().filter(|f| f.host == h).copied().collect::<Vec<_>>()
        };
        for h in 0..5 {
            assert_eq!(faults_of(&small, h), faults_of(&large, h));
        }
    }

    fn engine() -> Engine {
        let mut cfg = EngineConfig::default();
        cfg.min_dt = 0.1;
        cfg.vm_destruction_delay = 0.0;
        let mut e = Engine::new(cfg, Box::new(FirstFit::new()));
        let dc = e.add_datacenter("dc0", 1.0);
        for _ in 0..2 {
            e.add_host(dc, HostSpec::new(8, 1000.0, 16_384.0, 10_000.0, 1_000_000.0));
        }
        e
    }

    /// A reclaim storm warns the configured fraction of interruptible
    /// spots at the storm timestamp and the recorder counts it.
    #[test]
    fn storm_reclaims_fraction_of_spots() {
        let mut e = engine();
        let cfg = SpotConfig::terminate().with_min_running(0.0).with_warning(1.0);
        for _ in 0..4 {
            let v = e.submit_vm(Vm::spot(0, VmSpec::new(1000.0, 2), cfg));
            e.submit_cloudlet(Cloudlet::new(0, 1_000_000.0, 2).with_vm(v));
        }
        let sched = ChaosSchedule {
            storms: vec![Storm { at: 50.0, frac: 0.5 }],
            ..Default::default()
        };
        apply(&mut e, &sched);
        e.terminate_at(100.0);
        let report = e.run();
        assert_eq!(e.recorder.storms, 1);
        assert_eq!(e.recorder.storm_reclaims, 2, "ceil(4 * 0.5) victims");
        assert_eq!(report.spot.interruptions, 2);
        assert_eq!(report.resilience.storms, 1);
        assert_eq!(report.resilience.interruptions_per_storm, 2.0);
    }

    /// Host crash evicts and the paired recovery brings the host back;
    /// a displaced persistent VM recovers and the report times it.
    #[test]
    fn host_crash_and_recovery_round_trip() {
        let mut e = engine();
        let od = e.submit_vm(Vm::on_demand(0, VmSpec::new(1000.0, 8)).with_persistent(5_000.0));
        e.submit_cloudlet(Cloudlet::new(0, 800_000.0, 8).with_vm(od));
        let od2 = e.submit_vm(Vm::on_demand(0, VmSpec::new(1000.0, 8)).with_persistent(5_000.0));
        e.submit_cloudlet(Cloudlet::new(0, 800_000.0, 8).with_vm(od2));
        let sched = ChaosSchedule {
            host_faults: vec![HostFault { host: 0, crash_at: 10.0, recover_at: Some(40.0) }],
            ..Default::default()
        };
        apply(&mut e, &sched);
        e.terminate_at(300.0);
        let report = e.run();
        assert_eq!(e.recorder.host_failures, 1);
        assert_eq!(report.resilience.host_failures, 1);
        // The evicted VM waited out the crash and was re-placed.
        assert_eq!(report.resilience.recoveries, 1);
        assert!(report.resilience.max_recovery_secs >= 29.0, "{report:?}");
        assert!(report.resilience.work_recovered_mi > 0.0);
    }

    /// Crash on a dormant host is a no-op, and a chaos recovery never
    /// reactivates a host the chaos crash didn't take down.
    #[test]
    fn crash_guards_respect_host_state() {
        let mut e = engine();
        // Dormant trace-style host: added at t=50, crash scheduled at t=10.
        let h = e.add_host_at(0, HostSpec::new(8, 1000.0, 16_384.0, 10_000.0, 1_000_000.0), 50.0);
        let sched = ChaosSchedule {
            host_faults: vec![HostFault { host: h, crash_at: 10.0, recover_at: Some(20.0) }],
            ..Default::default()
        };
        apply(&mut e, &sched);
        e.terminate_at(30.0);
        e.run();
        assert_eq!(e.recorder.host_failures, 0);
        // Still dormant at t=30: the stray ChaosHostRecover didn't fire it up.
        assert!(!e.world.hosts[h].is_active());
    }

    /// During a broker outage, freed capacity is not handed to waiting
    /// VMs; the drain event places them right after the window closes.
    #[test]
    fn broker_outage_defers_and_drains_retries() {
        let mut cfg = EngineConfig::default();
        cfg.min_dt = 0.1;
        cfg.vm_destruction_delay = 0.0;
        let mut e = Engine::new(cfg, Box::new(FirstFit::new()));
        let dc = e.add_datacenter("dc0", 1.0);
        e.add_host(dc, HostSpec::new(8, 1000.0, 16_384.0, 10_000.0, 1_000_000.0));
        // Occupy the host for 10 s, then a waiter needs the freed space.
        let od = e.submit_vm(Vm::on_demand(0, VmSpec::new(1000.0, 8)));
        e.submit_cloudlet(Cloudlet::new(0, 80_000.0, 8).with_vm(od));
        let waiter =
            e.submit_vm(Vm::on_demand(0, VmSpec::new(1000.0, 8)).with_persistent(200.0));
        e.submit_cloudlet(Cloudlet::new(0, 8_000.0, 8).with_vm(waiter));
        let sched =
            ChaosSchedule { outages: vec![(5.0, 30.0)], ..Default::default() };
        apply(&mut e, &sched);
        e.terminate_at(100.0);
        e.run();
        let start = e.world.vms[waiter].history.first_start().unwrap();
        assert!(start >= 30.0, "placed during the outage window: {start}");
        assert!(start < 40.0, "drain event never placed the waiter: {start}");
    }

    /// A demand surge submits the configured VM fleet and preempts spots
    /// through the ordinary on-demand path.
    #[test]
    fn demand_surge_preempts_spots() {
        let mut e = engine();
        let cfg = SpotConfig::terminate().with_min_running(0.0).with_warning(1.0);
        for _ in 0..2 {
            let v = e.submit_vm(Vm::spot(0, VmSpec::new(1000.0, 8), cfg));
            e.submit_cloudlet(Cloudlet::new(0, 1_000_000.0, 8).with_vm(v));
        }
        let sched = ChaosSchedule {
            surges: vec![Surge { at: 20.0, vms: 2, pes: 8, dur: 30.0 }],
            ..Default::default()
        };
        let before = e.world.vms.len();
        apply(&mut e, &sched);
        assert_eq!(e.world.vms.len(), before + 2);
        e.terminate_at(200.0);
        let report = e.run();
        assert!(report.spot.interruptions >= 1, "{report:?}");
    }
}
