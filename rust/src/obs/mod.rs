//! Deterministic-safe observability: the telemetry sidecar.
//!
//! The paper's §IV-B treats "simulation output and monitoring" as a
//! first-class contribution (execution history, interruption counts, the
//! simulator's own CPU/RAM in Figs. 10-11). This module is that substrate
//! for the sweep/shard stack, built around a hard **two-channel rule**:
//!
//! 1. The primary artifacts (`sweep_cells.csv`, aggregates, partials,
//!    retained series) stay byte-identical with telemetry on or off, at
//!    any `--threads`/`--workers` count.
//! 2. Everything wall-clock or host-specific — run logs, phase timings,
//!    worker heartbeats, RSS — goes to `<out-dir>/telemetry/` and only
//!    there.
//!
//! Submodules:
//!
//! - [`counters`] — [`EngineCounters`]: cheap deterministic per-cell
//!   engine counters threaded through `EngineScratch`.
//! - [`telemetry`] — [`Telemetry`]: the versioned JSONL run-log sink plus
//!   the event builders and [`validate_event`] schema checker.
//! - [`heartbeat`] — [`HeartbeatWriter`]/[`StallTracker`]: per-shard
//!   worker liveness files and coordinator-side stall detection.
//!
//! `cloudmarket sweep status <out-dir>` renders a human summary from
//! these files; see `docs/observability.md` for the schema.

pub mod counters;
pub mod heartbeat;
pub mod telemetry;

pub use counters::EngineCounters;
pub use heartbeat::{
    heartbeat_file, read_last_heartbeat, Heartbeat, HeartbeatWriter, StallTracker, StallWarning,
};
pub use telemetry::{
    read_jsonl, telemetry_dir, validate_event, Telemetry, RUN_LOG, SCHEMA_VERSION, TELEMETRY_DIR,
};
