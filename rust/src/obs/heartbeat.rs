//! Worker heartbeats and coordinator-side stall detection.
//!
//! Each `sweep worker` process appends one JSONL line to its own
//! `heartbeat-<shard>.jsonl` (sidecar channel) when it starts, after every
//! completed cell, and when it finishes — so *silence during a cell* is
//! exactly the signal a stalled worker emits. The coordinator polls the
//! files with [`StallTracker`]: a worker that is alive but has not beaten
//! for longer than the threshold gets a one-shot stall warning, and the
//! last-known progress enriches shard-reassignment events when a worker
//! dies. Per-shard files (rather than one shared log) keep the protocol
//! append-only with a single writer, so no cross-process locking is needed.

use std::collections::HashMap;
use std::fs::File;
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use super::telemetry::{heartbeat_event, read_jsonl, validate_event};
use crate::metrics::selfprof::rss_mb_now;
use crate::util::json::Json;

/// Heartbeat file name for one shard (lives in the telemetry dir).
pub fn heartbeat_file(telemetry_dir: &Path, shard: usize) -> PathBuf {
    telemetry_dir.join(format!("heartbeat-{shard:04}.jsonl"))
}

/// One parsed heartbeat line.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Heartbeat {
    pub shard: usize,
    /// Cells completed so far in this shard.
    pub done: usize,
    /// Cells in this shard.
    pub total: usize,
    /// Cell id this beat refers to (the most recently completed cell;
    /// `None` on the start/end beats).
    pub cell: Option<usize>,
    /// Wall-clock ms since the unix epoch when the beat was written.
    pub ts_ms: u64,
    /// Worker RSS in MB at beat time (from the /proc self-profiler
    /// reader); `None` where /proc is unavailable (off Linux) - readers
    /// render a placeholder, never fail.
    pub rss_mb: Option<f64>,
}

impl Heartbeat {
    fn from_json(v: &Json) -> Option<Heartbeat> {
        if validate_event(v) != Ok("heartbeat") {
            return None;
        }
        let num = |k: &str| v.path(&[k]).and_then(Json::as_f64);
        Some(Heartbeat {
            shard: num("shard")? as usize,
            done: num("done")? as usize,
            total: num("total")? as usize,
            cell: num("cell").map(|c| c as usize),
            ts_ms: num("ts_ms")? as u64,
            rss_mb: num("rss_mb"),
        })
    }
}

/// Worker-side heartbeat emitter. Truncates the shard's file on creation
/// (a respawned worker starts a fresh beat history) and appends one line
/// per beat; IO errors are swallowed — heartbeats must never fail a shard.
pub struct HeartbeatWriter {
    file: Mutex<File>,
    shard: usize,
    total: usize,
}

impl HeartbeatWriter {
    pub fn create(path: &Path, shard: usize, total: usize) -> std::io::Result<HeartbeatWriter> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        Ok(HeartbeatWriter { file: Mutex::new(File::create(path)?), shard, total })
    }

    /// Append one beat: progress so far plus current RSS.
    pub fn beat(&self, done: usize, cell: Option<usize>) {
        let event = heartbeat_event(self.shard, done, self.total, cell, rss_mb_now());
        let mut line = Json::Obj(event).to_string_compact();
        line.push('\n');
        if let Ok(mut f) = self.file.lock() {
            let _ = f.write_all(line.as_bytes());
        }
    }
}

/// Read the most recent well-formed heartbeat from a shard's file.
/// `None` if the file does not exist yet or holds no valid beat.
pub fn read_last_heartbeat(path: &Path) -> Option<Heartbeat> {
    let lines = read_jsonl(path).ok()?;
    lines.iter().rev().find_map(Heartbeat::from_json)
}

/// A one-shot warning that a live worker has gone silent.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StallWarning {
    pub shard: usize,
    /// How long the worker has been silent.
    pub silent: Duration,
    /// Last-known progress, if any beat was ever observed.
    pub last: Option<Heartbeat>,
}

struct ShardWatch {
    last: Option<Heartbeat>,
    /// Coordinator-side instant when progress was last observed to change
    /// (worker and coordinator clocks are never compared).
    last_change: Instant,
    warned: bool,
}

/// Coordinator-side staleness detector over per-shard heartbeats.
///
/// Feed it every poll via [`StallTracker::observe`]; it fires a
/// [`StallWarning`] once per silence episode (re-arming as soon as the
/// worker beats again) and remembers each shard's last-known progress for
/// reassignment enrichment. Staleness is judged purely by coordinator-side
/// [`Instant`]s between observations, so worker clock skew cannot cause
/// false stalls.
pub struct StallTracker {
    threshold: Duration,
    state: HashMap<usize, ShardWatch>,
}

impl StallTracker {
    pub fn new(threshold: Duration) -> StallTracker {
        StallTracker { threshold, state: HashMap::new() }
    }

    /// Start (or restart, on worker respawn) watching a shard.
    pub fn watch(&mut self, shard: usize, now: Instant) {
        self.state.insert(shard, ShardWatch { last: None, last_change: now, warned: false });
    }

    /// Stop watching a shard (its worker exited).
    pub fn unwatch(&mut self, shard: usize) {
        self.state.remove(&shard);
    }

    /// Report the latest heartbeat (or lack of one) for a watched shard.
    /// Returns a warning the first poll after the shard crosses the
    /// silence threshold; beats re-arm the warning.
    pub fn observe(
        &mut self,
        shard: usize,
        beat: Option<Heartbeat>,
        now: Instant,
    ) -> Option<StallWarning> {
        let watch = self
            .state
            .entry(shard)
            .or_insert(ShardWatch { last: None, last_change: now, warned: false });
        if beat.is_some() && beat != watch.last {
            watch.last = beat;
            watch.last_change = now;
            watch.warned = false;
            return None;
        }
        let silent = now.duration_since(watch.last_change);
        if silent >= self.threshold && !watch.warned {
            watch.warned = true;
            return Some(StallWarning { shard, silent, last: watch.last });
        }
        None
    }

    /// Last-known progress for a shard, surviving `unwatch` only until the
    /// next `watch` (a respawn starts a fresh history).
    pub fn last_progress(&self, shard: usize) -> Option<Heartbeat> {
        self.state.get(&shard).and_then(|w| w.last)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn beat(done: usize, ts_ms: u64) -> Heartbeat {
        Heartbeat { shard: 0, done, total: 8, cell: Some(done), ts_ms, rss_mb: Some(10.0) }
    }

    #[test]
    fn writer_emits_readable_beats() {
        let dir = std::env::temp_dir().join(format!("cloudmarket_hb_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let path = heartbeat_file(&dir, 3);
        let w = HeartbeatWriter::create(&path, 3, 8).unwrap();
        w.beat(0, None);
        w.beat(1, Some(5));
        let last = read_last_heartbeat(&path).expect("beats readable");
        assert_eq!((last.shard, last.done, last.total, last.cell), (3, 1, 8, Some(5)));
        // RSS rides along only where /proc exists; elsewhere the beat is
        // still valid with rss_mb = None (graceful degradation).
        #[cfg(target_os = "linux")]
        assert!(last.rss_mb.unwrap_or(0.0) > 0.0, "RSS should come from /proc");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stall_fires_once_then_rearms_on_progress() {
        let t0 = Instant::now();
        let mut tracker = StallTracker::new(Duration::from_secs(30));
        tracker.watch(0, t0);
        assert!(tracker.observe(0, Some(beat(1, 100)), t0 + Duration::from_secs(1)).is_none());
        // Same beat repeated: silence accumulates from the last change.
        assert!(tracker.observe(0, Some(beat(1, 100)), t0 + Duration::from_secs(20)).is_none());
        let warn = tracker
            .observe(0, Some(beat(1, 100)), t0 + Duration::from_secs(40))
            .expect("crosses threshold");
        assert_eq!(warn.shard, 0);
        assert!(warn.silent >= Duration::from_secs(30));
        assert_eq!(warn.last.unwrap().done, 1);
        // Fires once per episode.
        assert!(tracker.observe(0, Some(beat(1, 100)), t0 + Duration::from_secs(60)).is_none());
        // Progress re-arms; a later silence warns again.
        assert!(tracker.observe(0, Some(beat(2, 200)), t0 + Duration::from_secs(61)).is_none());
        assert!(tracker.observe(0, Some(beat(2, 200)), t0 + Duration::from_secs(100)).is_some());
    }

    #[test]
    fn stall_warns_for_workers_that_never_beat() {
        let t0 = Instant::now();
        let mut tracker = StallTracker::new(Duration::from_secs(30));
        tracker.watch(1, t0);
        assert!(tracker.observe(1, None, t0 + Duration::from_secs(10)).is_none());
        let warn = tracker.observe(1, None, t0 + Duration::from_secs(31)).expect("silent from birth");
        assert!(warn.last.is_none());
        assert_eq!(tracker.last_progress(1), None);
    }

    #[test]
    fn respawn_resets_history() {
        let t0 = Instant::now();
        let mut tracker = StallTracker::new(Duration::from_secs(30));
        tracker.watch(0, t0);
        tracker.observe(0, Some(beat(3, 100)), t0 + Duration::from_secs(1));
        assert_eq!(tracker.last_progress(0).unwrap().done, 3);
        tracker.watch(0, t0 + Duration::from_secs(2));
        assert_eq!(tracker.last_progress(0), None, "respawn starts fresh");
    }
}
