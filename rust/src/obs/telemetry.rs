//! The telemetry sidecar: versioned JSONL run logs.
//!
//! # The two-channel rule
//!
//! The repo's core invariant is that the primary sweep artifacts
//! (`sweep_cells.csv`, aggregates, partials, retained series) are
//! **byte-identical** at any `--threads`/`--workers` count. Telemetry must
//! never weaken that, so observability is split into two channels:
//!
//! - **Primary channel** — the existing artifacts. Deterministic only; no
//!   wall-clock, host, pid, or scheduling data may ever reach them.
//! - **Sidecar channel** — `<out-dir>/telemetry/`: `run.jsonl` (one event
//!   per line, written by this module), per-shard `heartbeat-*.jsonl`
//!   files, and the optional self-profile series. Everything wall-clock or
//!   host-specific lives here and **only** here.
//!
//! Every sidecar line is a JSON object carrying the schema version (`"v"`),
//! an event name (`"event"`) and a wall-clock timestamp (`"ts_ms"`, ms
//! since the unix epoch). [`validate_event`] is the single source of truth
//! for the per-event required fields; the schema is documented for humans
//! in `docs/observability.md`.

use std::fs::{self, File};
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::{Duration, SystemTime, UNIX_EPOCH};

use super::counters::EngineCounters;
use super::heartbeat::Heartbeat;
use crate::util::json::{parse, Json, JsonObj};

/// Version stamped into every sidecar line; bump on any breaking change to
/// an event's fields.
pub const SCHEMA_VERSION: u64 = 1;

/// Sidecar directory name under the sweep `--out-dir`.
pub const TELEMETRY_DIR: &str = "telemetry";

/// Run-log file name inside the sidecar directory.
pub const RUN_LOG: &str = "run.jsonl";

/// `<out_dir>/telemetry` — the sidecar channel for a sweep output dir.
pub fn telemetry_dir(out_dir: &Path) -> PathBuf {
    out_dir.join(TELEMETRY_DIR)
}

/// Wall-clock milliseconds since the unix epoch (sidecar-only data).
pub fn now_ms() -> u64 {
    SystemTime::now().duration_since(UNIX_EPOCH).map(|d| d.as_millis() as u64).unwrap_or(0)
}

/// Append-only JSONL event sink. `Sync`: sweep worker threads share one
/// sink and each event is a single `write_all`, so concurrent lines never
/// interleave mid-byte.
pub struct Telemetry {
    file: Mutex<File>,
}

impl std::fmt::Debug for Telemetry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Telemetry").finish_non_exhaustive()
    }
}

impl Telemetry {
    /// Create `<out_dir>/telemetry/run.jsonl`, truncating a previous run's
    /// log (the sidecar describes *this* run only).
    pub fn create(out_dir: &Path) -> std::io::Result<Telemetry> {
        let dir = telemetry_dir(out_dir);
        fs::create_dir_all(&dir)?;
        let file = File::create(dir.join(RUN_LOG))?;
        Ok(Telemetry { file: Mutex::new(file) })
    }

    /// Append one event line. IO errors are swallowed: telemetry must never
    /// fail a run that would otherwise succeed.
    pub fn emit(&self, event: JsonObj) {
        let mut line = Json::Obj(event).to_string_compact();
        line.push('\n');
        if let Ok(mut f) = self.file.lock() {
            let _ = f.write_all(line.as_bytes());
        }
    }
}

fn base(event: &str) -> JsonObj {
    let mut o = JsonObj::new();
    o.set("v", Json::Num(SCHEMA_VERSION as f64));
    o.set("event", Json::Str(event.to_string()));
    o.set("ts_ms", Json::Num(now_ms() as f64));
    o
}

fn num(n: f64) -> Json {
    Json::Num(n)
}

fn opt_num(n: Option<f64>) -> Json {
    n.map(Json::Num).unwrap_or(Json::Null)
}

/// Run manifest, first line of every run log. The spec digest is the
/// same hex string the shard wire format embeds
/// ([`crate::sweep::shard::spec_digest`]), so sidecar and artifacts can
/// be cross-checked.
pub fn run_start(
    spec_digest: &str,
    cells: usize,
    variants: usize,
    seeds: usize,
    mode: &str,
    parallelism: usize,
) -> JsonObj {
    let mut o = base("run_start");
    o.set("spec_digest", Json::Str(spec_digest.to_string()));
    o.set("cells", num(cells as f64));
    o.set("variants", num(variants as f64));
    o.set("seeds", num(seeds as f64));
    o.set("mode", Json::Str(mode.to_string()));
    o.set("parallelism", num(parallelism as f64));
    o
}

/// A worker thread picked up a cell.
pub fn cell_start(cell: usize, seed: u64, variant: &str) -> JsonObj {
    let mut o = base("cell_start");
    o.set("cell", num(cell as f64));
    o.set("seed", Json::Str(seed.to_string()));
    o.set("variant", Json::Str(variant.to_string()));
    o
}

/// A cell finished (ok or failed/panicked) with its wall time and the
/// deterministic engine counters it accumulated.
pub fn cell_end(cell: usize, ok: bool, ms: f64, counters: &EngineCounters) -> JsonObj {
    let mut o = base("cell_end");
    o.set("cell", num(cell as f64));
    o.set("ok", Json::Bool(ok));
    o.set("ms", num(ms));
    o.set("counters", Json::Obj(counters.to_json()));
    o
}

/// A lazy prebuild slot was actually built; `cell` is the id of the cell
/// whose claim triggered the build.
pub fn prebuild(cell: usize, ms: f64) -> JsonObj {
    let mut o = base("prebuild");
    o.set("cell", num(cell as f64));
    o.set("ms", num(ms));
    o
}

/// Coordinator handed a shard to a freshly spawned worker process.
pub fn shard_assign(shard: usize, attempt: usize, pid: u32) -> JsonObj {
    let mut o = base("shard_assign");
    o.set("shard", num(shard as f64));
    o.set("attempt", num(attempt as f64));
    o.set("pid", num(pid as f64));
    o
}

/// A worker process exited; `detail` carries the exit taxonomy
/// (`completed`, `runtime`, `parent-gone`, `bad-shard`, `signal`, ...).
pub fn shard_exit(shard: usize, ok: bool, code: Option<i32>, detail: &str) -> JsonObj {
    let mut o = base("shard_exit");
    o.set("shard", num(shard as f64));
    o.set("ok", Json::Bool(ok));
    o.set("code", opt_num(code.map(|c| c as f64)));
    o.set("detail", Json::Str(detail.to_string()));
    o
}

/// A failed shard goes back on the queue, enriched with the crashed
/// worker's last-known heartbeat progress.
pub fn shard_reassign(shard: usize, attempt: usize, last: Option<&Heartbeat>) -> JsonObj {
    let mut o = base("shard_reassign");
    o.set("shard", num(shard as f64));
    o.set("attempt", num(attempt as f64));
    o.set("last_done", opt_num(last.map(|h| h.done as f64)));
    o.set("last_total", opt_num(last.map(|h| h.total as f64)));
    o
}

/// A live worker has gone silent past the stall threshold.
pub fn stall(shard: usize, silent_ms: u64, last: Option<&Heartbeat>) -> JsonObj {
    let mut o = base("stall");
    o.set("shard", num(shard as f64));
    o.set("silent_ms", num(silent_ms as f64));
    o.set("last_done", opt_num(last.map(|h| h.done as f64)));
    o.set("last_total", opt_num(last.map(|h| h.total as f64)));
    o
}

/// Partial-merge validation outcome.
pub fn merge(shards: usize, cells: usize, ok: bool) -> JsonObj {
    let mut o = base("merge");
    o.set("shards", num(shards as f64));
    o.set("cells", num(cells as f64));
    o.set("ok", Json::Bool(ok));
    o
}

/// Final line of a run log: the `SweepTiming` phase breakdown.
#[allow(clippy::too_many_arguments)]
pub fn run_end(
    ok: bool,
    wall: Duration,
    prebuild_busy: Duration,
    cell_busy: Duration,
    merge: Duration,
    first_cell_done: Duration,
    prebuilds_built: usize,
) -> JsonObj {
    let ms = |d: Duration| num(d.as_secs_f64() * 1e3);
    let mut o = base("run_end");
    o.set("ok", Json::Bool(ok));
    o.set("wall_ms", ms(wall));
    o.set("prebuild_busy_ms", ms(prebuild_busy));
    o.set("cell_busy_ms", ms(cell_busy));
    o.set("merge_ms", ms(merge));
    o.set("first_cell_done_ms", ms(first_cell_done));
    o.set("prebuilds_built", num(prebuilds_built as f64));
    o
}

/// One worker heartbeat line (lives in `heartbeat-<shard>.jsonl`, same
/// schema family as the run log).
pub fn heartbeat_event(
    shard: usize,
    done: usize,
    total: usize,
    cell: Option<usize>,
    rss_mb: Option<f64>,
) -> JsonObj {
    let mut o = base("heartbeat");
    o.set("shard", num(shard as f64));
    o.set("done", num(done as f64));
    o.set("total", num(total as f64));
    o.set("cell", opt_num(cell.map(|c| c as f64)));
    o.set("rss_mb", opt_num(rss_mb));
    o
}

enum Kind {
    Num,
    Str,
    Bool,
    NumOrNull,
    Counters,
}

fn check_field(o: &JsonObj, name: &str, kind: &Kind) -> Result<(), String> {
    let v = o.get(name).ok_or_else(|| format!("missing field '{name}'"))?;
    let ok = match kind {
        Kind::Num => matches!(v, Json::Num(_)),
        Kind::Str => matches!(v, Json::Str(_)),
        Kind::Bool => matches!(v, Json::Bool(_)),
        Kind::NumOrNull => matches!(v, Json::Num(_) | Json::Null),
        Kind::Counters => EngineCounters::from_json(v).is_some(),
    };
    if ok {
        Ok(())
    } else {
        Err(format!("field '{name}' has the wrong type"))
    }
}

/// Validate one sidecar line against the versioned schema; returns the
/// event name. This is the machine-checkable definition of the schema that
/// `docs/observability.md` documents, used by the round-trip tests, the CI
/// smoke, and `sweep status`.
pub fn validate_event(v: &Json) -> Result<&str, String> {
    use Kind::*;
    let o = v.as_obj().ok_or_else(|| "event is not a JSON object".to_string())?;
    let ver = o
        .get("v")
        .and_then(Json::as_f64)
        .ok_or_else(|| "missing numeric 'v'".to_string())? as u64;
    if ver != SCHEMA_VERSION {
        return Err(format!("unsupported schema version {ver} (expected {SCHEMA_VERSION})"));
    }
    let event = o
        .get("event")
        .and_then(Json::as_str)
        .ok_or_else(|| "missing string 'event'".to_string())?;
    check_field(o, "ts_ms", &Num)?;
    let required: &[(&str, Kind)] = match event {
        "run_start" => &[
            ("spec_digest", Str),
            ("cells", Num),
            ("variants", Num),
            ("seeds", Num),
            ("mode", Str),
            ("parallelism", Num),
        ],
        "cell_start" => &[("cell", Num), ("seed", Str), ("variant", Str)],
        "cell_end" => &[("cell", Num), ("ok", Bool), ("ms", Num), ("counters", Counters)],
        "prebuild" => &[("cell", Num), ("ms", Num)],
        "shard_assign" => &[("shard", Num), ("attempt", Num), ("pid", Num)],
        "shard_exit" => &[("shard", Num), ("ok", Bool), ("code", NumOrNull), ("detail", Str)],
        "shard_reassign" => {
            &[("shard", Num), ("attempt", Num), ("last_done", NumOrNull), ("last_total", NumOrNull)]
        }
        "stall" => {
            &[("shard", Num), ("silent_ms", Num), ("last_done", NumOrNull), ("last_total", NumOrNull)]
        }
        "merge" => &[("shards", Num), ("cells", Num), ("ok", Bool)],
        "run_end" => &[
            ("ok", Bool),
            ("wall_ms", Num),
            ("prebuild_busy_ms", Num),
            ("cell_busy_ms", Num),
            ("merge_ms", Num),
            ("first_cell_done_ms", Num),
            ("prebuilds_built", Num),
        ],
        "heartbeat" => &[
            ("shard", Num),
            ("done", Num),
            ("total", Num),
            ("cell", NumOrNull),
            ("rss_mb", NumOrNull),
        ],
        other => return Err(format!("unknown event '{other}'")),
    };
    for (name, kind) in required {
        check_field(o, name, kind)?;
    }
    Ok(event)
}

/// Read a JSONL sidecar file. A torn **final** line (a live writer caught
/// mid-append) is tolerated and skipped; a malformed earlier line is
/// corruption and errors loudly.
pub fn read_jsonl(path: &Path) -> std::io::Result<Vec<Json>> {
    let text = fs::read_to_string(path)?;
    let lines: Vec<&str> = text.lines().filter(|l| !l.trim().is_empty()).collect();
    let mut out = Vec::with_capacity(lines.len());
    for (i, line) in lines.iter().enumerate() {
        match parse(line) {
            Ok(v) => out.push(v),
            Err(_) if i + 1 == lines.len() => {}
            Err(e) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("{} line {}: {e}", path.display(), i + 1),
                ))
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("cloudmarket_tel_{}_{name}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn every_builder_validates() {
        let hb = Heartbeat { shard: 1, done: 3, total: 8, cell: Some(5), ts_ms: 1, rss_mb: Some(12.5) };
        let c = EngineCounters { events_popped: 10, ..Default::default() };
        let events = vec![
            run_start("00bebfa81eefea11", 48, 6, 8, "workers", 2),
            cell_start(7, 20250710, "policy=first-fit"),
            cell_end(7, true, 12.25, &c),
            prebuild(1, 80.5),
            shard_assign(0, 1, 4242),
            shard_exit(0, false, Some(2), "runtime"),
            shard_reassign(0, 2, Some(&hb)),
            stall(1, 30_000, None),
            merge(2, 48, true),
            run_end(
                true,
                Duration::from_millis(900),
                Duration::from_millis(100),
                Duration::from_millis(700),
                Duration::from_millis(5),
                Duration::from_millis(40),
                3,
            ),
            heartbeat_event(1, 3, 8, Some(5), Some(12.5)),
        ];
        for e in events {
            let text = Json::Obj(e).to_string_compact();
            let v = parse(&text).unwrap();
            validate_event(&v).unwrap_or_else(|err| panic!("{err}: {text}"));
        }
    }

    #[test]
    fn validate_rejects_bad_events() {
        for (line, why) in [
            (r#"{"event":"merge","ts_ms":1}"#, "missing version"),
            (r#"{"v":99,"event":"merge","ts_ms":1,"shards":1,"cells":1,"ok":true}"#, "bad version"),
            (r#"{"v":1,"event":"nope","ts_ms":1}"#, "unknown event"),
            (r#"{"v":1,"event":"merge","ts_ms":1,"shards":1,"cells":1}"#, "missing field"),
            (r#"{"v":1,"event":"merge","ts_ms":1,"shards":"x","cells":1,"ok":true}"#, "wrong type"),
            (r#"{"v":1,"event":"cell_end","ts_ms":1,"cell":0,"ok":true,"ms":1,"counters":{}}"#, "bad counters"),
            (r#"[1,2]"#, "not an object"),
        ] {
            let v = parse(line).unwrap();
            assert!(validate_event(&v).is_err(), "should reject ({why}): {line}");
        }
    }

    #[test]
    fn sink_writes_one_valid_line_per_event() {
        let dir = test_dir("sink");
        let t = Telemetry::create(&dir).unwrap();
        t.emit(merge(2, 48, true));
        t.emit(shard_exit(1, true, Some(0), "completed"));
        drop(t);
        let lines = read_jsonl(&telemetry_dir(&dir).join(RUN_LOG)).unwrap();
        assert_eq!(lines.len(), 2);
        assert_eq!(validate_event(&lines[0]).unwrap(), "merge");
        assert_eq!(validate_event(&lines[1]).unwrap(), "shard_exit");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn read_jsonl_tolerates_torn_tail_only() {
        let dir = test_dir("torn");
        let p = dir.join("x.jsonl");
        fs::write(&p, "{\"a\":1}\n{\"b\":2}\n{\"tor").unwrap();
        let lines = read_jsonl(&p).unwrap();
        assert_eq!(lines.len(), 2);
        fs::write(&p, "{\"a\":1}\n{\"tor\n{\"b\":2}\n").unwrap();
        assert!(read_jsonl(&p).is_err(), "mid-file corruption must error");
        let _ = fs::remove_dir_all(&dir);
    }
}
