//! Cheap per-cell engine counters.
//!
//! `EngineCounters` rides inside [`crate::engine::EngineScratch`] so the
//! engine can bump plain `u64`s on its hot paths (one add per event batch,
//! one per placement probe, ...) without any allocation or synchronization.
//! The counts are **deterministic** — they depend only on the cell spec and
//! seed — but they are still emitted exclusively through the telemetry
//! sidecar, never into `sweep_cells.csv`/aggregates, so the byte-identity
//! contract of the primary artifacts stays trivially intact.

use crate::util::json::{Json, JsonObj};

/// Per-cell engine activity counters, reset at the start of every cell.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct EngineCounters {
    /// Events drained from the queue by the main loop.
    pub events_popped: u64,
    /// High-water mark of the event-queue depth (pending events).
    pub queue_high_water: u64,
    /// Placement probes: calls into `AllocationPolicy::select_host`.
    pub placement_probes: u64,
    /// Placement probes that returned a host.
    pub placement_hits: u64,
    /// Preemption scans: calls into `AllocationPolicy::select_preemption`.
    pub preemption_scans: u64,
    /// Chaos events applied (host crashes/recoveries, storms, outages).
    pub chaos_events: u64,
    /// Market price-crossing events applied (up and down crossings).
    pub market_events: u64,
    /// Recovery events applied (checkpoint snapshots, reassignment
    /// matchings, migration arrivals, checkpoint-converted requeues).
    pub recovery_events: u64,
}

impl EngineCounters {
    /// Zero every counter (start of a cell).
    pub fn reset(&mut self) {
        *self = EngineCounters::default();
    }

    /// Accumulate another cell's counters into a running total.
    pub fn add(&mut self, other: &EngineCounters) {
        self.events_popped += other.events_popped;
        self.queue_high_water = self.queue_high_water.max(other.queue_high_water);
        self.placement_probes += other.placement_probes;
        self.placement_hits += other.placement_hits;
        self.preemption_scans += other.preemption_scans;
        self.chaos_events += other.chaos_events;
        self.market_events += other.market_events;
        self.recovery_events += other.recovery_events;
    }

    /// Serialize for the telemetry sidecar. Counter magnitudes stay far
    /// below 2^53 in practice, so plain JSON numbers are exact.
    pub fn to_json(&self) -> JsonObj {
        let mut o = JsonObj::new();
        o.set("events_popped", Json::Num(self.events_popped as f64));
        o.set("queue_high_water", Json::Num(self.queue_high_water as f64));
        o.set("placement_probes", Json::Num(self.placement_probes as f64));
        o.set("placement_hits", Json::Num(self.placement_hits as f64));
        o.set("preemption_scans", Json::Num(self.preemption_scans as f64));
        o.set("chaos_events", Json::Num(self.chaos_events as f64));
        o.set("market_events", Json::Num(self.market_events as f64));
        o.set("recovery_events", Json::Num(self.recovery_events as f64));
        o
    }

    /// Parse the sidecar representation back (used by `sweep status` and
    /// the schema round-trip tests).
    pub fn from_json(v: &Json) -> Option<EngineCounters> {
        let o = v.as_obj()?;
        let num = |k: &str| o.get(k).and_then(Json::as_f64).map(|n| n as u64);
        Some(EngineCounters {
            events_popped: num("events_popped")?,
            queue_high_water: num("queue_high_water")?,
            placement_probes: num("placement_probes")?,
            placement_hits: num("placement_hits")?,
            preemption_scans: num("preemption_scans")?,
            chaos_events: num("chaos_events")?,
            market_events: num("market_events")?,
            recovery_events: num("recovery_events")?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip_is_exact() {
        let c = EngineCounters {
            events_popped: 12345,
            queue_high_water: 99,
            placement_probes: 400,
            placement_hits: 398,
            preemption_scans: 7,
            chaos_events: 3,
            market_events: 11,
            recovery_events: 6,
        };
        let text = Json::Obj(c.to_json()).to_string_compact();
        let back = EngineCounters::from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn add_sums_counts_and_maxes_high_water() {
        let mut total = EngineCounters { queue_high_water: 5, events_popped: 10, ..Default::default() };
        total.add(&EngineCounters { queue_high_water: 3, events_popped: 4, ..Default::default() });
        assert_eq!(total.events_popped, 14);
        assert_eq!(total.queue_high_water, 5);
        total.add(&EngineCounters { queue_high_water: 8, ..Default::default() });
        assert_eq!(total.queue_high_water, 8);
    }

    #[test]
    fn reset_zeroes_everything() {
        let mut c = EngineCounters { events_popped: 1, chaos_events: 2, ..Default::default() };
        c.reset();
        assert_eq!(c, EngineCounters::default());
    }
}
