//! Physical host: PEs (cores x MIPS), RAM, bandwidth, storage, and the
//! resource accounting the allocation policies operate on.
//!
//! Four resource dimensions (CPU, RAM, BW, storage) matching the paper's
//! host-filtering phase ("All resource types - CPU, memory, bandwidth, and
//! storage - are considered", §VI-A) and the `DIMS = 4` artifact contract.

use crate::vm::VmId;

/// Static host configuration (paper Table II row).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HostSpec {
    /// Number of processing elements (cores).
    pub pes: u32,
    /// MIPS capacity of each PE.
    pub mips_per_pe: f64,
    /// RAM in MB.
    pub ram: f64,
    /// Bandwidth in Mbps.
    pub bw: f64,
    /// Storage in MB.
    pub storage: f64,
}

impl HostSpec {
    pub fn new(pes: u32, mips_per_pe: f64, ram: f64, bw: f64, storage: f64) -> Self {
        HostSpec { pes, mips_per_pe, ram, bw, storage }
    }

    /// Total CPU capacity in MIPS.
    pub fn total_mips(&self) -> f64 {
        self.pes as f64 * self.mips_per_pe
    }
}

/// Host lifecycle (trace machine events can add/remove hosts mid-run).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HostState {
    /// Accepting and running VMs.
    Active,
    /// Removed (trace REMOVE event); holds no VMs.
    Removed,
}

/// A physical server with live resource accounting.
#[derive(Debug, Clone)]
pub struct Host {
    pub id: super::HostId,
    pub dc: super::DcId,
    pub spec: HostSpec,
    pub state: HostState,
    /// Allocated VMs in allocation order (the paper's victim-selection
    /// order for spot interruption is exactly this list order, §IX).
    pub vms: Vec<VmId>,
    pub used_pes: u32,
    pub used_ram: f64,
    pub used_bw: f64,
    pub used_storage: f64,
    /// Resources currently held by spot VMs, in artifact dimension order
    /// (CPU MIPS, RAM, BW, storage) - Eq. (10) numerator. Maintained by
    /// [`crate::engine::World::commit_vm`] / `release_vm` (refreshed from
    /// the VM list on every spot mutation, so reads are O(1) and bitwise
    /// equal to a from-scratch recompute); raw `commit`/`release` calls do
    /// not see VM types and leave it untouched.
    pub spot_used: [f64; 4],
    /// Number of spot VMs currently resident (same maintenance contract
    /// as `spot_used`).
    pub spot_vms: u32,
    /// Simulation time the host became active.
    pub created_at: f64,
    pub removed_at: Option<f64>,
}

impl Host {
    pub fn new(id: super::HostId, dc: super::DcId, spec: HostSpec, now: f64) -> Self {
        Host {
            id,
            dc,
            spec,
            state: HostState::Active,
            vms: Vec::new(),
            used_pes: 0,
            used_ram: 0.0,
            used_bw: 0.0,
            used_storage: 0.0,
            spot_used: [0.0; 4],
            spot_vms: 0,
            created_at: now,
            removed_at: None,
        }
    }

    pub fn is_active(&self) -> bool {
        self.state == HostState::Active
    }

    pub fn free_pes(&self) -> u32 {
        self.spec.pes.saturating_sub(self.used_pes)
    }

    pub fn free_ram(&self) -> f64 {
        (self.spec.ram - self.used_ram).max(0.0)
    }

    pub fn free_bw(&self) -> f64 {
        (self.spec.bw - self.used_bw).max(0.0)
    }

    pub fn free_storage(&self) -> f64 {
        (self.spec.storage - self.used_storage).max(0.0)
    }

    /// Free CPU capacity in MIPS (PE-granular allocation).
    pub fn free_mips(&self) -> f64 {
        self.free_pes() as f64 * self.spec.mips_per_pe
    }

    /// CPU utilization fraction `U_i(t)` used by the RsDiff filter (Eq. 1).
    pub fn cpu_utilization(&self) -> f64 {
        if self.spec.pes == 0 {
            return 0.0;
        }
        self.used_pes as f64 / self.spec.pes as f64
    }

    /// Whether a request of (pes, ram, bw, storage) fits right now.
    pub fn fits(&self, pes: u32, ram: f64, bw: f64, storage: f64) -> bool {
        self.is_active()
            && self.free_pes() >= pes
            && self.free_ram() + 1e-9 >= ram
            && self.free_bw() + 1e-9 >= bw
            && self.free_storage() + 1e-9 >= storage
    }

    /// Commit resources for a VM (engine-internal; panics on oversubscribe,
    /// which would indicate a policy bug - policies must check `fits`).
    ///
    /// The VM is appended at the END of `self.vms`: `World::commit_vm`
    /// relies on that order to extend the spot-usage fold incrementally
    /// while staying bitwise equal to the walking oracle.
    pub fn commit(&mut self, vm: VmId, pes: u32, ram: f64, bw: f64, storage: f64) {
        assert!(self.fits(pes, ram, bw, storage), "host {} oversubscribed by vm {}", self.id, vm);
        self.used_pes += pes;
        self.used_ram += ram;
        self.used_bw += bw;
        self.used_storage += storage;
        self.vms.push(vm);
    }

    /// Release a VM's resources.
    pub fn release(&mut self, vm: VmId, pes: u32, ram: f64, bw: f64, storage: f64) {
        let idx = self
            .vms
            .iter()
            .position(|&v| v == vm)
            .unwrap_or_else(|| panic!("vm {vm} not on host {}", self.id));
        self.vms.remove(idx);
        self.used_pes = self.used_pes.checked_sub(pes).expect("pe accounting underflow");
        self.used_ram = (self.used_ram - ram).max(0.0);
        self.used_bw = (self.used_bw - bw).max(0.0);
        self.used_storage = (self.used_storage - storage).max(0.0);
    }

    /// Capacity vector in the artifact's dimension order (CPU MIPS, RAM,
    /// BW, storage) - DESIGN.md §5.
    pub fn capacity_vec(&self) -> [f64; 4] {
        [self.spec.total_mips(), self.spec.ram, self.spec.bw, self.spec.storage]
    }

    /// Free-capacity vector `C_i^d(t)` in artifact dimension order.
    pub fn free_vec(&self) -> [f64; 4] {
        [self.free_mips(), self.free_ram(), self.free_bw(), self.free_storage()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn host() -> Host {
        Host::new(0, 0, HostSpec::new(8, 1000.0, 16_384.0, 5_000.0, 200_000.0), 0.0)
    }

    #[test]
    fn fresh_host_is_empty() {
        let h = host();
        assert_eq!(h.free_pes(), 8);
        assert_eq!(h.free_mips(), 8000.0);
        assert_eq!(h.cpu_utilization(), 0.0);
        assert!(h.fits(8, 16_384.0, 5_000.0, 200_000.0));
        assert!(!h.fits(9, 0.0, 0.0, 0.0));
    }

    #[test]
    fn commit_and_release_roundtrip() {
        let mut h = host();
        h.commit(7, 4, 8192.0, 1000.0, 50_000.0);
        assert_eq!(h.free_pes(), 4);
        assert_eq!(h.cpu_utilization(), 0.5);
        assert_eq!(h.vms, vec![7]);
        h.release(7, 4, 8192.0, 1000.0, 50_000.0);
        assert_eq!(h.free_pes(), 8);
        assert!(h.vms.is_empty());
    }

    #[test]
    #[should_panic(expected = "oversubscribed")]
    fn commit_rejects_oversubscription() {
        let mut h = host();
        h.commit(1, 8, 0.0, 0.0, 0.0);
        h.commit(2, 1, 0.0, 0.0, 0.0);
    }

    #[test]
    fn vectors_in_artifact_order() {
        let mut h = host();
        h.commit(3, 2, 1024.0, 500.0, 10_000.0);
        assert_eq!(h.capacity_vec(), [8000.0, 16_384.0, 5_000.0, 200_000.0]);
        assert_eq!(h.free_vec(), [6000.0, 15_360.0, 4_500.0, 190_000.0]);
    }

    #[test]
    fn removed_host_rejects_fits() {
        let mut h = host();
        h.state = HostState::Removed;
        assert!(!h.fits(1, 0.0, 0.0, 0.0));
    }
}
