//! Datacenter: a named collection of hosts plus scheduling parameters
//! (paper §V-B(a): `DatacenterSimple` = hosts + VM allocation policy;
//! the policy itself lives in the engine, see DESIGN.md §2/S7).

use super::HostId;

/// A datacenter groups hosts and carries the scheduling interval
/// (`datacenter0.setSchedulingInterval(1)` in the paper's Listing 4).
#[derive(Debug, Clone)]
pub struct Datacenter {
    pub id: super::DcId,
    pub name: String,
    pub hosts: Vec<HostId>,
    /// Period (seconds) of cloudlet progress updates.
    pub scheduling_interval: f64,
}

impl Datacenter {
    pub fn new(id: super::DcId, name: &str, scheduling_interval: f64) -> Self {
        assert!(scheduling_interval > 0.0);
        Datacenter { id, name: name.to_string(), hosts: Vec::new(), scheduling_interval }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction() {
        let dc = Datacenter::new(0, "dc0", 1.0);
        assert_eq!(dc.name, "dc0");
        assert!(dc.hosts.is_empty());
    }

    #[test]
    #[should_panic]
    fn rejects_zero_interval() {
        Datacenter::new(0, "dc0", 0.0);
    }
}
