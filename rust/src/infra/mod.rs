//! Physical infrastructure model: processing elements, hosts, datacenters
//! (paper §V-B: `HostSimple`, `DatacenterSimple`).

pub mod datacenter;
pub mod host;

pub use datacenter::Datacenter;
pub use host::{Host, HostSpec, HostState};

/// Index of a host in the world's host arena.
pub type HostId = usize;
/// Index of a datacenter.
pub type DcId = usize;
