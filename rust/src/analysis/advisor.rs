//! Spot-advisor dataset + Fig. 16 correlation analysis (paper §VII-F).
//!
//! The paper scraped the AWS Spot Instance Advisor (389 instance types,
//! interruption-frequency classes <5% / 5-10% / 10-15% / 15-20% / >20%)
//! plus the spot price feed and console metadata. Offline we synthesize a
//! dataset with the same statistical structure (DESIGN.md §6): a latent
//! per-family interruption risk plus type-level noise, so that exact
//! instance type carries more information about the interruption class
//! than family, which carries more than the coarse machine category -
//! the paper's headline ordering (0.38 / 0.33 / 0.18). A real advisor
//! JSON can be supplied instead via [`AdvisorDataset::from_json`].

use crate::stats::{Dist, Rng};
use crate::util::json::Json;

use super::correlation::{correlation_ratio, pearson, theils_u};

/// One instance-type row.
#[derive(Debug, Clone)]
pub struct AdvisorRow {
    /// Exact type, e.g. "m5.2xlarge" (encoded as dense id).
    pub instance_type: u32,
    /// Family, e.g. "m5".
    pub family: u32,
    /// Coarse category (general/compute/memory/storage/accelerated).
    pub category: u32,
    pub vcpus: f64,
    pub memory_gb: f64,
    /// Expected savings vs on-demand, percent.
    pub savings_pct: f64,
    pub spot_price: f64,
    pub on_demand_price: f64,
    /// Interruption-frequency class 0..=4 (the advisor's five ranges).
    pub interruption_class: u32,
    /// Region and OS (the advisor dataset is "region-specific and
    /// distinguished by operating system", §VII-F); a type appears once
    /// per (region, os) with regionally-varying interruption class.
    pub region: u32,
    pub os: u32,
    /// Nuisance columns the paper found uncorrelated.
    pub day: u32,
    pub free_tier: u32,
    pub dedicated_host: u32,
}

/// The dataset plus readable label maps.
#[derive(Debug, Clone, Default)]
pub struct AdvisorDataset {
    pub rows: Vec<AdvisorRow>,
    pub family_names: Vec<String>,
    pub category_names: Vec<String>,
    pub type_names: Vec<String>,
}

/// Association of each feature with the interruption class, Fig.16-style.
#[derive(Debug, Clone)]
pub struct Fig16Row {
    pub feature: &'static str,
    pub measure: &'static str,
    pub value: f64,
}

const CATEGORIES: [&str; 5] =
    ["general", "compute", "memory", "storage", "accelerated"];

/// Synthesize a 389-type dataset (the paper's count) with family-latent
/// interruption risk.
pub fn synth_dataset(seed: u64) -> AdvisorDataset {
    let mut rng = Rng::new(seed);
    let mut ds = AdvisorDataset::default();
    ds.category_names = CATEGORIES.iter().map(|s| s.to_string()).collect();

    // ~40 families spread over 5 categories; sizes within family.
    let sizes = ["medium", "large", "xlarge", "2xlarge", "4xlarge", "8xlarge", "12xlarge",
        "16xlarge", "24xlarge", "metal"];
    let family_letters = ["m", "c", "r", "i", "p", "t", "g", "d", "x", "z"];
    let mut type_id: u32 = 0;
    let target_types = 389; // paper's dataset size

    'outer: loop {
        let fam_idx = ds.family_names.len() as u32;
        let letter = family_letters[rng.below(family_letters.len() as u64) as usize];
        let gen = 3 + rng.below(5); // m3..m7
        let family_name = format!("{letter}{gen}");
        let category = match letter {
            "m" | "t" => 0u32,
            "c" => 1,
            "r" | "x" | "z" => 2,
            "i" | "d" => 3,
            _ => 4,
        };
        ds.family_names.push(family_name.clone());

        // Latent per-family interruption propensity in [0, 1].
        let family_risk = rng.next_f64();
        let n_sizes = 4 + rng.below(6) as usize;
        for s in 0..n_sizes.min(sizes.len()) {
            if type_id as usize >= target_types {
                break 'outer;
            }
            let vcpus = (2u64 << s.min(6)) as f64;
            let memory = vcpus * match category {
                1 => 2.0,
                2 => 8.0,
                _ => 4.0,
            };
            // Type-level risk = family latent + size drift + type noise.
            let type_risk = (family_risk
                + 0.08 * (s as f64 / n_sizes as f64 - 0.5)
                + Dist::Normal { mu: 0.0, sigma: 0.07 }.sample(&mut rng))
            .clamp(0.0, 0.999);
            ds.type_names.push(format!("{family_name}.{}", sizes[s]));

            // One row per (region, os): the class varies regionally around
            // the type risk, so knowing the exact type explains *most* but
            // not all of the class entropy (paper: U = 0.38, not 1.0).
            for region in 0..3u32 {
                for os in 0..2u32 {
                    let row_risk = (type_risk
                        + Dist::Normal { mu: 0.0, sigma: 0.16 }.sample(&mut rng))
                    .clamp(0.0, 0.999);
                    let class = (row_risk * 5.0).floor() as u32;
                    // Savings correlate mildly with risk (deeper discounts
                    // on frequently-reclaimed capacity).
                    let savings = 50.0 + 35.0 * row_risk + rng.uniform(-8.0, 8.0);
                    let od_price = 0.05 * vcpus * (1.0 + 0.2 * rng.next_f64());
                    let spot_price = od_price * (1.0 - savings / 100.0);
                    ds.rows.push(AdvisorRow {
                        instance_type: type_id,
                        family: fam_idx,
                        category,
                        vcpus,
                        memory_gb: memory,
                        savings_pct: savings.clamp(0.0, 95.0),
                        spot_price,
                        on_demand_price: od_price,
                        interruption_class: class,
                        region,
                        os,
                        day: rng.below(7) as u32,
                        free_tier: 0,
                        dedicated_host: rng.below(2) as u32,
                    });
                }
            }
            type_id += 1;
        }
    }
    ds
}

impl AdvisorDataset {
    /// Load from the AWS spot-advisor JSON layout
    /// (`spot-advisor-data.json`: `{"instance_types": {...}, "spot_advisor":
    /// {region: {os: {type: {"r": class, "s": savings}}}}}`).
    pub fn from_json(v: &Json, region: &str, os: &str) -> Option<AdvisorDataset> {
        let mut ds = AdvisorDataset::default();
        ds.category_names = CATEGORIES.iter().map(|s| s.to_string()).collect();
        let advisor = v.path(&["spot_advisor", region, os])?.as_obj()?;
        let itypes = v.path(&["instance_types"])?.as_obj()?;
        let mut fam_ids: std::collections::HashMap<String, u32> = Default::default();
        for (tname, entry) in advisor.iter() {
            let class = entry.path(&["r"]).and_then(|x| x.as_f64()).unwrap_or(0.0) as u32;
            let savings = entry.path(&["s"]).and_then(|x| x.as_f64()).unwrap_or(0.0);
            let family = tname.split('.').next().unwrap_or(tname).to_string();
            let fam_id = *fam_ids.entry(family.clone()).or_insert_with(|| {
                ds.family_names.push(family.clone());
                (ds.family_names.len() - 1) as u32
            });
            let meta = itypes.get(tname);
            let vcpus =
                meta.and_then(|m| m.path(&["cores"])).and_then(|x| x.as_f64()).unwrap_or(0.0);
            let mem = meta
                .and_then(|m| m.path(&["ram_gb"]))
                .and_then(|x| x.as_f64())
                .unwrap_or(0.0);
            let tid = ds.type_names.len() as u32;
            ds.type_names.push(tname.to_string());
            ds.rows.push(AdvisorRow {
                instance_type: tid,
                family: fam_id,
                category: category_of(&family),
                vcpus,
                memory_gb: mem,
                savings_pct: savings,
                spot_price: 0.0,
                on_demand_price: 0.0,
                interruption_class: class.min(4),
                region: 0,
                os: 0,
                day: 0,
                free_tier: 0,
                dedicated_host: 0,
            });
        }
        if ds.rows.is_empty() { None } else { Some(ds) }
    }

    /// The Fig. 16 association table: each feature vs interruption class.
    pub fn fig16_associations(&self) -> Vec<Fig16Row> {
        let class: Vec<u32> = self.rows.iter().map(|r| r.interruption_class).collect();
        let classf: Vec<f64> = class.iter().map(|&c| c as f64).collect();
        let cat = |f: fn(&AdvisorRow) -> u32| -> Vec<u32> { self.rows.iter().map(f).collect() };
        let num = |f: fn(&AdvisorRow) -> f64| -> Vec<f64> { self.rows.iter().map(f).collect() };

        vec![
            Fig16Row {
                feature: "instance_type",
                measure: "theils_u",
                value: theils_u(&cat(|r| r.instance_type), &class),
            },
            Fig16Row {
                feature: "instance_family",
                measure: "theils_u",
                value: theils_u(&cat(|r| r.family), &class),
            },
            Fig16Row {
                feature: "machine_category",
                measure: "theils_u",
                value: theils_u(&cat(|r| r.category), &class),
            },
            Fig16Row {
                feature: "vcpus",
                measure: "correlation_ratio",
                value: correlation_ratio(&class, &num(|r| r.vcpus)),
            },
            Fig16Row {
                feature: "memory_gb",
                measure: "correlation_ratio",
                value: correlation_ratio(&class, &num(|r| r.memory_gb)),
            },
            Fig16Row {
                feature: "savings_pct",
                measure: "pearson",
                value: pearson(&num(|r| r.savings_pct), &classf),
            },
            Fig16Row {
                feature: "day",
                measure: "theils_u",
                value: theils_u(&cat(|r| r.day), &class),
            },
            Fig16Row {
                feature: "free_tier",
                measure: "theils_u",
                value: theils_u(&cat(|r| r.free_tier), &class),
            },
            Fig16Row {
                feature: "dedicated_host",
                measure: "theils_u",
                value: theils_u(&cat(|r| r.dedicated_host), &class),
            },
        ]
    }
}

fn category_of(family: &str) -> u32 {
    match family.chars().next().unwrap_or('m') {
        'm' | 't' | 'a' => 0,
        'c' => 1,
        'r' | 'x' | 'z' | 'u' => 2,
        'i' | 'd' | 'h' => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_has_paper_scale() {
        let ds = synth_dataset(1);
        assert_eq!(ds.type_names.len(), 389);
        // one row per (type, region, os): 389 x 3 x 2
        assert_eq!(ds.rows.len(), 389 * 6);
        assert!(ds.family_names.len() >= 30);
        assert!(ds.rows.iter().all(|r| r.interruption_class <= 4));
    }

    #[test]
    fn fig16_ordering_matches_paper_shape() {
        // Paper: instance type (0.38) > family (0.33) > machine type (0.18),
        // nuisance features negligible. Absolute values differ (synthetic
        // data), the ordering must hold.
        let ds = synth_dataset(7);
        let assoc = ds.fig16_associations();
        let get = |name: &str| assoc.iter().find(|r| r.feature == name).unwrap().value;
        let t = get("instance_type");
        let f = get("instance_family");
        let c = get("machine_category");
        assert!(t > f, "type {t} !> family {f}");
        assert!(f > c, "family {f} !> category {c}");
        assert!(get("day") < 0.1, "day should be noise");
        assert!(get("free_tier") < 1e-9, "free_tier constant -> 0");
        // Savings correlate positively with risk by construction.
        assert!(get("savings_pct") > 0.3);
    }

    #[test]
    fn from_json_parses_advisor_layout() {
        let doc = crate::util::json::parse(
            r#"{
              "instance_types": {"m5.large": {"cores": 2, "ram_gb": 8}},
              "spot_advisor": {"us-east-1": {"Linux": {"m5.large": {"r": 2, "s": 70}}}}
            }"#,
        )
        .unwrap();
        let ds = AdvisorDataset::from_json(&doc, "us-east-1", "Linux").unwrap();
        assert_eq!(ds.rows.len(), 1);
        assert_eq!(ds.rows[0].interruption_class, 2);
        assert_eq!(ds.rows[0].vcpus, 2.0);
        assert_eq!(ds.family_names[0], "m5");
    }

    #[test]
    fn deterministic_per_seed() {
        let a = synth_dataset(3);
        let b = synth_dataset(3);
        assert_eq!(a.rows.len(), b.rows.len());
        assert_eq!(a.rows[10].interruption_class, b.rows[10].interruption_class);
    }
}
