//! Interruption-frequency analysis (paper §VII-F, Fig. 16): association
//! measures for mixed-type data and the spot-advisor dataset.

pub mod advisor;
pub mod correlation;

pub use advisor::{synth_dataset, AdvisorDataset, AdvisorRow};
pub use correlation::{correlation_ratio, pearson, theils_u};
