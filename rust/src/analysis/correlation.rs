//! Association measures for mixed-type data (paper §VII-F(a): "Theil's U
//! for nominal-nominal, correlation ratio (eta^2) for numeric-categorical,
//! and Pearson correlation for numeric-numeric" - the dython.nominal
//! measures re-implemented).

use std::collections::HashMap;

/// Pearson correlation coefficient of two numeric columns.
/// Returns 0 for degenerate (constant) columns.
pub fn pearson(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    if n < 2.0 {
        return 0.0;
    }
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (a, b) in x.iter().zip(y) {
        sxy += (a - mx) * (b - my);
        sxx += (a - mx) * (a - mx);
        syy += (b - my) * (b - my);
    }
    if sxx <= 0.0 || syy <= 0.0 {
        0.0
    } else {
        (sxy / (sxx.sqrt() * syy.sqrt())).clamp(-1.0, 1.0)
    }
}

/// Correlation ratio eta (in [0,1]): how much of a numeric variable's
/// variance is explained by a categorical grouping.
pub fn correlation_ratio(categories: &[u32], values: &[f64]) -> f64 {
    assert_eq!(categories.len(), values.len());
    if values.is_empty() {
        return 0.0;
    }
    let mut groups: HashMap<u32, (f64, f64)> = HashMap::new(); // (sum, n)
    for (&c, &v) in categories.iter().zip(values) {
        let e = groups.entry(c).or_insert((0.0, 0.0));
        e.0 += v;
        e.1 += 1.0;
    }
    let n = values.len() as f64;
    let grand_mean = values.iter().sum::<f64>() / n;
    let between: f64 = groups
        .values()
        .map(|&(sum, cnt)| cnt * (sum / cnt - grand_mean).powi(2))
        .sum();
    let total: f64 = values.iter().map(|v| (v - grand_mean).powi(2)).sum();
    if total <= 0.0 {
        0.0
    } else {
        (between / total).clamp(0.0, 1.0).sqrt()
    }
}

/// Shannon entropy of a categorical column (nats).
fn entropy(counts: &HashMap<u32, f64>, n: f64) -> f64 {
    counts
        .values()
        .map(|&c| {
            let p = c / n;
            if p > 0.0 { -p * p.ln() } else { 0.0 }
        })
        .sum()
}

/// Theil's uncertainty coefficient U(y | x) in [0,1]: the fraction of y's
/// entropy explained by knowing x. Asymmetric: U(y|x) != U(x|y).
pub fn theils_u(x: &[u32], y: &[u32]) -> f64 {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    if x.is_empty() {
        return 0.0;
    }
    let mut y_counts: HashMap<u32, f64> = HashMap::new();
    for &v in y {
        *y_counts.entry(v).or_insert(0.0) += 1.0;
    }
    let h_y = entropy(&y_counts, n);
    if h_y <= 0.0 {
        return 0.0; // y is constant: fully "explained"
    }
    // Conditional entropy H(y | x) = sum_x p(x) H(y | X = x).
    let mut x_groups: HashMap<u32, HashMap<u32, f64>> = HashMap::new();
    let mut x_counts: HashMap<u32, f64> = HashMap::new();
    for (&a, &b) in x.iter().zip(y) {
        *x_groups.entry(a).or_default().entry(b).or_insert(0.0) += 1.0;
        *x_counts.entry(a).or_insert(0.0) += 1.0;
    }
    let mut h_y_given_x = 0.0;
    for (a, group) in &x_groups {
        let nx = x_counts[a];
        h_y_given_x += (nx / n) * entropy(group, nx);
    }
    ((h_y - h_y_given_x) / h_y).clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::Rng;

    #[test]
    fn pearson_perfect_and_anti() {
        let x: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| 3.0 * v + 1.0).collect();
        let z: Vec<f64> = x.iter().map(|v| -v).collect();
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        assert!((pearson(&x, &z) + 1.0).abs() < 1e-12);
        assert_eq!(pearson(&x, &vec![5.0; 50]), 0.0); // constant column
    }

    #[test]
    fn pearson_independent_near_zero() {
        let mut rng = Rng::new(1);
        let x: Vec<f64> = (0..20_000).map(|_| rng.next_f64()).collect();
        let y: Vec<f64> = (0..20_000).map(|_| rng.next_f64()).collect();
        assert!(pearson(&x, &y).abs() < 0.03);
    }

    #[test]
    fn correlation_ratio_extremes() {
        // Perfectly determined by category.
        let cats = vec![0, 0, 1, 1, 2, 2];
        let vals = vec![1.0, 1.0, 5.0, 5.0, 9.0, 9.0];
        assert!((correlation_ratio(&cats, &vals) - 1.0).abs() < 1e-12);
        // Independent of category.
        let vals2 = vec![1.0, 9.0, 1.0, 9.0, 1.0, 9.0];
        assert!(correlation_ratio(&cats, &vals2) < 1e-9);
    }

    #[test]
    fn theils_u_extremes_and_asymmetry() {
        // y fully determined by x.
        let x = vec![0, 0, 1, 1, 2, 2];
        let y = vec![5, 5, 6, 6, 7, 7];
        assert!((theils_u(&x, &y) - 1.0).abs() < 1e-12);
        // independent
        let y2 = vec![0, 1, 0, 1, 0, 1];
        assert!(theils_u(&x, &y2) < 0.35); // small sample, not exactly 0
        // asymmetry: x (3 values) determines parity y2? no - but a finer x
        // explains a coarser y better than vice versa.
        let fine: Vec<u32> = (0..60).collect();
        let coarse: Vec<u32> = (0..60).map(|i| i / 10).collect();
        assert!((theils_u(&fine, &coarse) - 1.0).abs() < 1e-12);
        assert!(theils_u(&coarse, &fine) < 1.0);
    }

    #[test]
    fn theils_u_constant_target_is_zero() {
        let x = vec![1, 2, 3, 4];
        let y = vec![9, 9, 9, 9];
        assert_eq!(theils_u(&x, &y), 0.0);
    }
}
