//! Checkpoint- and migration-aware recovery: what survives a spot
//! interruption, and how displaced VMs get back onto hosts.
//!
//! The paper's comparison counts interruptions and their durations; this
//! module extends it to the *work-survival* question raised by the
//! fault-tolerance literature (Voorsluys & Buyya's checkpoint/migration
//! provisioning vs. Alourani & Kshemkalyani's no-fault-tolerance
//! baseline): the reclaim warning window is long enough to checkpoint
//! in-flight state, and displaced VMs can be reassigned to surviving
//! hosts instead of waiting in the retry queue.
//!
//! Three pieces, mirroring the `chaos`/`market` template:
//!
//! - [`RecoverySpec`]: the declarative per-cell knob set (`recovery.*`
//!   sweep axes) - a [`RecoveryMode`], a checkpoint transfer bandwidth,
//!   and the full/partial/restart decision threshold.
//! - [`compile`]: resolves a spec into an immutable [`RecoverySchedule`]
//!   parameter block, a pure function of `(spec, seed, horizon)` so
//!   sweep artifacts stay byte-identical at any thread/worker count
//!   (the recovery machinery is reactive - it consumes interruption
//!   events - so unlike chaos/market the schedule carries no event
//!   stream, just the resolved decision parameters).
//! - [`apply`]: hands the compiled schedule to an engine. The engine
//!   reacts through dedicated event tags (`RecoveryCheckpoint`,
//!   `RecoveryReassign`, `RecoveryMigrate`) outside the untouched core
//!   queue logic.
//!
//! The reassignment layer offers two strategies over the same
//! `displaced VMs x candidate hosts` cost matrix (cost = restart
//! penalty + checkpoint transfer time): [`assign_greedy`] (each VM in
//! displacement order takes its cheapest free host) and
//! [`assign_optimal`] (Kuhn-Munkres min-cost matching). The greedy path
//! is retained as a parity-comparable baseline: the optimal total cost
//! is never larger, and the two agree exactly when one VM is displaced
//! (`tests/properties.rs` pins both invariants).

/// Checkpoint image size per MI of executed work (MB). The image grows
/// with progress, so long-running work needs proportionally more of the
/// warning window to save.
pub const CHECKPOINT_MB_PER_MI: f64 = 0.001;

/// Default checkpoint transfer bandwidth (MB/s) when only other
/// `recovery.*` axes are set.
pub const DEFAULT_BANDWIDTH_MB_S: f64 = 100.0;

/// Default full/partial-vs-restart decision threshold: checkpoint only
/// when at least this fraction of the in-flight progress fits through
/// the warning window.
pub const DEFAULT_CHECKPOINT_THRESHOLD: f64 = 0.25;

/// Recovery strategy applied when a spot interruption fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecoveryMode {
    /// No recovery machinery at all - the engine's baseline behavior.
    None,
    /// Displaced VMs are requeued but restart from scratch (terminated
    /// work re-enters the queue carrying zero progress).
    Restart,
    /// Grace-window checkpointing: the warning window transfers
    /// `bandwidth x window` MB of state; the decision function keeps a
    /// full or partial image, or falls back to restart below the
    /// threshold.
    Checkpoint,
    /// Checkpointing plus displaced-VM migration via greedy first-fit
    /// reassignment (each displaced VM takes its cheapest free host).
    MigrateGreedy,
    /// Checkpointing plus displaced-VM migration via Kuhn-Munkres
    /// min-cost matching over displaced VMs x candidate hosts.
    MigrateOptimal,
}

impl RecoveryMode {
    /// Stable label (sweep-axis vocabulary and artifact column value).
    pub fn label(&self) -> &'static str {
        match self {
            RecoveryMode::None => "none",
            RecoveryMode::Restart => "restart",
            RecoveryMode::Checkpoint => "checkpoint",
            RecoveryMode::MigrateGreedy => "migrate-greedy",
            RecoveryMode::MigrateOptimal => "migrate-optimal",
        }
    }

    /// Parse one mode label (`--axis recovery.mode=...` vocabulary).
    pub fn parse(s: &str) -> Result<RecoveryMode, String> {
        match s.trim() {
            "none" => Ok(RecoveryMode::None),
            "restart" => Ok(RecoveryMode::Restart),
            "checkpoint" => Ok(RecoveryMode::Checkpoint),
            "migrate-greedy" => Ok(RecoveryMode::MigrateGreedy),
            "migrate-optimal" => Ok(RecoveryMode::MigrateOptimal),
            other => Err(format!(
                "unknown recovery mode '{other}' (expected none | restart | checkpoint | \
                 migrate-greedy | migrate-optimal)"
            )),
        }
    }

    /// Whether this mode takes checkpoints during the warning window.
    pub fn checkpoints(&self) -> bool {
        matches!(
            self,
            RecoveryMode::Checkpoint | RecoveryMode::MigrateGreedy | RecoveryMode::MigrateOptimal
        )
    }

    /// Whether this mode migrates displaced VMs through the matcher.
    pub fn migrates(&self) -> bool {
        matches!(self, RecoveryMode::MigrateGreedy | RecoveryMode::MigrateOptimal)
    }
}

/// Declarative recovery knob set of one sweep cell. Unset fields fall
/// back to the `DEFAULT_*` constants; a fully-unset spec (or an explicit
/// `mode=none`) leaves the engine's baseline behavior untouched.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct RecoverySpec {
    /// Recovery strategy (`recovery.mode` axis). Unset with other
    /// fields set defaults to [`RecoveryMode::Checkpoint`].
    pub mode: Option<RecoveryMode>,
    /// Checkpoint transfer bandwidth in MB/s (`recovery.bandwidth`).
    pub bandwidth: Option<f64>,
    /// Full/partial-vs-restart decision threshold in `[0, 1]`
    /// (`recovery.checkpoint-threshold`).
    pub checkpoint_threshold: Option<f64>,
}

impl RecoverySpec {
    /// The recovery-free spec (baseline engine behavior).
    pub const NONE: RecoverySpec = RecoverySpec { mode: None, bandwidth: None, checkpoint_threshold: None };

    /// Whether every knob is unset.
    pub fn is_none(&self) -> bool {
        self.mode.is_none() && self.bandwidth.is_none() && self.checkpoint_threshold.is_none()
    }

    /// Resolved mode (default: checkpoint, so setting only a numeric
    /// axis activates the checkpoint model it parameterizes).
    pub fn mode(&self) -> RecoveryMode {
        self.mode.unwrap_or(RecoveryMode::Checkpoint)
    }

    /// Resolved checkpoint transfer bandwidth (MB/s).
    pub fn bandwidth(&self) -> f64 {
        self.bandwidth.unwrap_or(DEFAULT_BANDWIDTH_MB_S)
    }

    /// Resolved checkpoint decision threshold.
    pub fn checkpoint_threshold(&self) -> f64 {
        self.checkpoint_threshold.unwrap_or(DEFAULT_CHECKPOINT_THRESHOLD)
    }
}

/// Exact round-trip rendering for numeric recovery axis values (same
/// contract as `market::label_f64`: shortest `Display` form, whose
/// `str::parse` inverse is the identity).
pub fn label_f64(v: f64) -> String {
    format!("{v}")
}

/// Compiled recovery parameters of one cell. Unlike the chaos/market
/// schedules this carries no event stream - recovery reacts to
/// interruptions - but it goes through the same compile/apply/`Arc`
/// slot machinery so the determinism story is identical.
#[derive(Debug, Clone, PartialEq)]
pub struct RecoverySchedule {
    pub mode: RecoveryMode,
    pub bandwidth_mb_s: f64,
    pub checkpoint_threshold: f64,
    pub horizon: f64,
}

impl RecoverySchedule {
    /// An empty schedule applies nothing to the engine.
    pub fn is_empty(&self) -> bool {
        self.mode == RecoveryMode::None
    }

    /// Checkpoint decision for `progress_mi` of in-flight work given a
    /// `window_secs` warning window (see [`checkpoint_decision`]).
    pub fn decide(&self, progress_mi: f64, window_secs: f64) -> CheckpointDecision {
        checkpoint_decision(progress_mi, self.bandwidth_mb_s, window_secs, self.checkpoint_threshold)
    }
}

/// What the warning-window checkpoint keeps.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CheckpointKind {
    /// The whole image fit through the window: all progress survives.
    Full,
    /// Only a prefix fit, but enough to beat the threshold.
    Partial,
    /// Too little would survive: don't bother transferring anything.
    Restart,
}

/// Outcome of the warning-window checkpoint decision.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CheckpointDecision {
    pub kind: CheckpointKind,
    /// Progress (MI) that survives the interruption.
    pub saved_mi: f64,
    /// Checkpoint bytes actually transferred (MB).
    pub bytes_mb: f64,
}

/// The warning-window checkpoint model: the image holds
/// `progress_mi x CHECKPOINT_MB_PER_MI` MB, the window transfers at most
/// `bandwidth_mb_s x window_secs` MB, and the decision keeps a full
/// image, a partial prefix (when the saveable fraction reaches
/// `threshold`), or nothing (restart). Monotone in both bandwidth and
/// window; never saves more than `progress_mi`.
pub fn checkpoint_decision(
    progress_mi: f64,
    bandwidth_mb_s: f64,
    window_secs: f64,
    threshold: f64,
) -> CheckpointDecision {
    let progress = progress_mi.max(0.0);
    if progress <= 0.0 {
        return CheckpointDecision { kind: CheckpointKind::Full, saved_mi: 0.0, bytes_mb: 0.0 };
    }
    let image_mb = progress * CHECKPOINT_MB_PER_MI;
    let transferable_mb = (bandwidth_mb_s.max(0.0) * window_secs.max(0.0)).max(0.0);
    if transferable_mb >= image_mb {
        return CheckpointDecision { kind: CheckpointKind::Full, saved_mi: progress, bytes_mb: image_mb };
    }
    let fraction = transferable_mb / image_mb;
    if fraction + 1e-12 >= threshold {
        CheckpointDecision {
            kind: CheckpointKind::Partial,
            saved_mi: progress * fraction,
            bytes_mb: transferable_mb,
        }
    } else {
        CheckpointDecision { kind: CheckpointKind::Restart, saved_mi: 0.0, bytes_mb: 0.0 }
    }
}

/// Compile a recovery spec into its immutable schedule. A pure function
/// of `(spec, seed, horizon)`: the `seed` is accepted for template
/// uniformity with chaos/market but the resolved parameters carry no
/// randomness, so identical specs compile identically on every thread.
pub fn compile(spec: &RecoverySpec, _seed: u64, horizon: f64) -> RecoverySchedule {
    if spec.is_none() || horizon <= 0.0 {
        return RecoverySchedule {
            mode: RecoveryMode::None,
            bandwidth_mb_s: DEFAULT_BANDWIDTH_MB_S,
            checkpoint_threshold: DEFAULT_CHECKPOINT_THRESHOLD,
            horizon: horizon.max(0.0),
        };
    }
    RecoverySchedule {
        mode: spec.mode(),
        bandwidth_mb_s: spec.bandwidth(),
        checkpoint_threshold: spec.checkpoint_threshold(),
        horizon,
    }
}

/// Hand a compiled schedule to an engine. Empty schedules (mode `none`)
/// leave the engine byte-identical to a recovery-free run.
pub fn apply(engine: &mut crate::engine::Engine, sched: &std::sync::Arc<RecoverySchedule>) {
    if sched.is_empty() {
        return;
    }
    engine.recovery = Some(std::sync::Arc::clone(sched));
}

/// Cost-matrix entries at or above this magnitude mean "infeasible"
/// (the matcher also treats non-finite entries this way).
const INFEASIBLE: f64 = 1e15;

/// Greedy first-fit reassignment baseline: each displaced VM, in
/// displacement order, takes the cheapest still-free feasible host
/// (ties break on the lower host index). Returns one `Option<host
/// column>` per row; `None` rows stay on the normal retry path.
pub fn assign_greedy(costs: &[Vec<f64>]) -> Vec<Option<usize>> {
    let m = costs.first().map_or(0, Vec::len);
    let mut taken = vec![false; m];
    costs
        .iter()
        .map(|row| {
            let mut best: Option<usize> = None;
            for (j, &c) in row.iter().enumerate() {
                if taken[j] || !c.is_finite() || c >= INFEASIBLE {
                    continue;
                }
                if best.map_or(true, |b| c < row[b]) {
                    best = Some(j);
                }
            }
            if let Some(j) = best {
                taken[j] = true;
            }
            best
        })
        .collect()
}

/// Kuhn-Munkres (Hungarian) min-cost reassignment: the matching over
/// displaced VMs x candidate hosts minimizing total cost. Infeasible
/// pairs (non-finite or >= the infeasible sentinel) are never assigned.
/// Total cost never exceeds [`assign_greedy`]'s, and the two agree
/// exactly for a single displaced VM.
pub fn assign_optimal(costs: &[Vec<f64>]) -> Vec<Option<usize>> {
    let n = costs.len();
    let m = costs.first().map_or(0, Vec::len);
    if n == 0 || m == 0 {
        return vec![None; n];
    }
    // Pad to square with the infeasible sentinel; dummy rows/columns
    // absorb the imbalance and infeasible-sentinel assignments are
    // dropped afterwards.
    let size = n.max(m);
    let padded: Vec<Vec<f64>> = (0..size)
        .map(|i| {
            (0..size)
                .map(|j| match costs.get(i).and_then(|row| row.get(j)) {
                    Some(&c) if c.is_finite() && c < INFEASIBLE => c,
                    _ => INFEASIBLE,
                })
                .collect()
        })
        .collect();
    let row_to_col = hungarian_square(&padded);
    (0..n)
        .map(|i| {
            let j = row_to_col[i];
            if j < m && costs[i][j].is_finite() && costs[i][j] < INFEASIBLE {
                Some(j)
            } else {
                None
            }
        })
        .collect()
}

/// Total cost of an assignment over the same cost matrix.
pub fn assignment_total(costs: &[Vec<f64>], assign: &[Option<usize>]) -> f64 {
    assign
        .iter()
        .enumerate()
        .filter_map(|(i, a)| a.map(|j| costs[i][j]))
        .sum()
}

/// Classic O(n^3) Hungarian algorithm on a square matrix (potentials
/// formulation, 1-indexed internals). Deterministic: iteration order is
/// fixed, so equal-cost matchings resolve identically on every run.
fn hungarian_square(cost: &[Vec<f64>]) -> Vec<usize> {
    let n = cost.len();
    let mut u = vec![0.0f64; n + 1];
    let mut v = vec![0.0f64; n + 1];
    // p[j] = row matched to column j (1-indexed; 0 = unmatched).
    let mut p = vec![0usize; n + 1];
    let mut way = vec![0usize; n + 1];
    for i in 1..=n {
        p[0] = i;
        let mut j0 = 0usize;
        let mut minv = vec![f64::INFINITY; n + 1];
        let mut used = vec![false; n + 1];
        loop {
            used[j0] = true;
            let i0 = p[j0];
            let mut delta = f64::INFINITY;
            let mut j1 = 0usize;
            for j in 1..=n {
                if !used[j] {
                    let cur = cost[i0 - 1][j - 1] - u[i0] - v[j];
                    if cur < minv[j] {
                        minv[j] = cur;
                        way[j] = j0;
                    }
                    if minv[j] < delta {
                        delta = minv[j];
                        j1 = j;
                    }
                }
            }
            for j in 0..=n {
                if used[j] {
                    u[p[j]] += delta;
                    v[j] -= delta;
                } else {
                    minv[j] -= delta;
                }
            }
            j0 = j1;
            if p[j0] == 0 {
                break;
            }
        }
        loop {
            let j1 = way[j0];
            p[j0] = p[j1];
            j0 = j1;
            if j0 == 0 {
                break;
            }
        }
    }
    let mut row_to_col = vec![usize::MAX; n];
    for j in 1..=n {
        if p[j] != 0 {
            row_to_col[p[j] - 1] = j - 1;
        }
    }
    row_to_col
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_labels_round_trip() {
        for m in [
            RecoveryMode::None,
            RecoveryMode::Restart,
            RecoveryMode::Checkpoint,
            RecoveryMode::MigrateGreedy,
            RecoveryMode::MigrateOptimal,
        ] {
            assert_eq!(RecoveryMode::parse(m.label()).unwrap(), m);
        }
        let err = RecoveryMode::parse("teleport").unwrap_err();
        assert!(err.contains("migrate-optimal"), "{err}");
    }

    #[test]
    fn spec_defaults_resolve() {
        let spec = RecoverySpec { bandwidth: Some(50.0), ..RecoverySpec::NONE };
        assert!(!spec.is_none());
        assert_eq!(spec.mode(), RecoveryMode::Checkpoint);
        assert_eq!(spec.bandwidth(), 50.0);
        assert_eq!(spec.checkpoint_threshold(), DEFAULT_CHECKPOINT_THRESHOLD);
        assert!(RecoverySpec::NONE.is_none());
    }

    #[test]
    fn compile_is_pure_and_gates_on_spec_and_horizon() {
        let spec = RecoverySpec {
            mode: Some(RecoveryMode::MigrateOptimal),
            bandwidth: Some(200.0),
            checkpoint_threshold: Some(0.5),
        };
        let a = compile(&spec, 1, 4800.0);
        let b = compile(&spec, 99, 4800.0);
        assert_eq!(a, b, "seed does not perturb the compiled parameters");
        assert_eq!(a.mode, RecoveryMode::MigrateOptimal);
        assert!(compile(&RecoverySpec::NONE, 1, 4800.0).is_empty());
        assert!(compile(&spec, 1, 0.0).is_empty());
        assert!(compile(&RecoverySpec { mode: Some(RecoveryMode::None), ..RecoverySpec::NONE }, 1, 4800.0)
            .is_empty());
    }

    #[test]
    fn checkpoint_decision_full_partial_restart() {
        // 1000 MI -> 1 MB image. Window moves 2 MB: full.
        let d = checkpoint_decision(1000.0, 1.0, 2.0, 0.25);
        assert_eq!(d.kind, CheckpointKind::Full);
        assert_eq!(d.saved_mi, 1000.0);
        assert_eq!(d.bytes_mb, 1.0);
        // Window moves 0.5 MB of a 1 MB image: partial at threshold 0.25.
        let d = checkpoint_decision(1000.0, 0.25, 2.0, 0.25);
        assert_eq!(d.kind, CheckpointKind::Partial);
        assert_eq!(d.saved_mi, 500.0);
        assert_eq!(d.bytes_mb, 0.5);
        // Window moves 0.1 MB of a 1 MB image: below threshold, restart.
        let d = checkpoint_decision(1000.0, 0.05, 2.0, 0.25);
        assert_eq!(d.kind, CheckpointKind::Restart);
        assert_eq!(d.saved_mi, 0.0);
        // Zero progress: trivially full, nothing moved.
        let d = checkpoint_decision(0.0, 100.0, 120.0, 0.25);
        assert_eq!(d.kind, CheckpointKind::Full);
        assert_eq!(d.saved_mi, 0.0);
    }

    #[test]
    fn greedy_takes_cheapest_free_host_in_row_order() {
        let costs = vec![vec![5.0, 1.0, 9.0], vec![2.0, 1.5, 9.0]];
        let a = assign_greedy(&costs);
        // Row 0 takes column 1 (cheapest); row 1's cheapest (1) is taken,
        // so it takes column 0.
        assert_eq!(a, vec![Some(1), Some(0)]);
    }

    #[test]
    fn greedy_skips_infeasible_entries() {
        let costs = vec![vec![f64::INFINITY, 3.0], vec![INFEASIBLE, f64::NAN]];
        let a = assign_greedy(&costs);
        assert_eq!(a, vec![Some(1), None]);
    }

    #[test]
    fn optimal_beats_greedy_on_conflict() {
        // Greedy: row 0 grabs column 0 (cost 1), forcing row 1 to column 1
        // (cost 10) -> total 11. Optimal crosses them for 2 + 2 = 4.
        let costs = vec![vec![1.0, 2.0], vec![2.0, 10.0]];
        let g = assign_greedy(&costs);
        let o = assign_optimal(&costs);
        assert_eq!(assignment_total(&costs, &g), 11.0);
        assert_eq!(assignment_total(&costs, &o), 4.0);
        assert_eq!(o, vec![Some(1), Some(0)]);
    }

    #[test]
    fn optimal_and_greedy_agree_on_single_row() {
        let costs = vec![vec![7.0, 3.0, 5.0]];
        assert_eq!(assign_greedy(&costs), assign_optimal(&costs));
        assert_eq!(assign_optimal(&costs), vec![Some(1)]);
    }

    #[test]
    fn optimal_handles_more_vms_than_hosts() {
        // Three displaced VMs, two hosts: the cheapest total pairing wins
        // and one VM stays unassigned.
        let costs = vec![vec![1.0, 4.0], vec![2.0, 2.0], vec![3.0, 1.0]];
        let o = assign_optimal(&costs);
        let assigned: Vec<usize> = o.iter().flatten().copied().collect();
        assert_eq!(assigned.len(), 2);
        assert_eq!(o[0], Some(0));
        assert_eq!(o[2], Some(1));
        assert_eq!(o[1], None);
    }

    #[test]
    fn optimal_leaves_fully_infeasible_rows_unassigned() {
        let costs = vec![vec![INFEASIBLE, f64::INFINITY], vec![1.0, 2.0]];
        assert_eq!(assign_optimal(&costs), vec![None, Some(0)]);
    }
}
