//! Tables II and III of the paper, verbatim.

use crate::infra::HostSpec;
use crate::util::table::{Align, TextTable};
use crate::vm::VmSpec;

/// One Table II row: a host type plus its count in the simulation.
#[derive(Debug, Clone, Copy)]
pub struct HostType {
    pub name: &'static str,
    pub cpu: u32,
    pub memory: f64,
    pub bandwidth: f64,
    pub storage: f64,
    /// Instances of this type in the §VII-E setup.
    pub count: usize,
}

impl HostType {
    pub fn spec(&self, mips_per_pe: f64) -> HostSpec {
        HostSpec::new(self.cpu, mips_per_pe, self.memory, self.bandwidth, self.storage)
    }
}

/// Table II: small/medium/large/x-large hosts; counts 20/30/30/20.
pub fn host_types() -> Vec<HostType> {
    vec![
        HostType { name: "Small", cpu: 8, memory: 16_384.0, bandwidth: 5_000.0, storage: 200_000.0, count: 20 },
        HostType { name: "Medium", cpu: 16, memory: 32_768.0, bandwidth: 10_000.0, storage: 400_000.0, count: 30 },
        HostType { name: "Large", cpu: 32, memory: 65_536.0, bandwidth: 20_000.0, storage: 800_000.0, count: 30 },
        HostType { name: "X-Large", cpu: 64, memory: 131_072.0, bandwidth: 40_000.0, storage: 1_600_000.0, count: 20 },
    ]
}

/// One Table III row: a VM profile plus its spot/on-demand counts.
#[derive(Debug, Clone, Copy)]
pub struct VmProfile {
    pub cpu: u32,
    pub memory: f64,
    pub bandwidth: f64,
    pub storage: f64,
    pub spot_count: usize,
    pub on_demand_count: usize,
}

impl VmProfile {
    pub fn spec(&self, mips_per_pe: f64) -> VmSpec {
        VmSpec::new(mips_per_pe, self.cpu)
            .with_ram(self.memory)
            .with_bw(self.bandwidth)
            .with_storage(self.storage)
    }
}

/// Table III: 10 profiles, 400 spot + 1600 on-demand VMs total.
pub fn vm_profiles() -> Vec<VmProfile> {
    vec![
        VmProfile { cpu: 1, memory: 1_024.0, bandwidth: 100.0, storage: 10_000.0, spot_count: 31, on_demand_count: 160 },
        VmProfile { cpu: 2, memory: 1_024.0, bandwidth: 100.0, storage: 10_000.0, spot_count: 42, on_demand_count: 175 },
        VmProfile { cpu: 1, memory: 2_048.0, bandwidth: 200.0, storage: 20_000.0, spot_count: 36, on_demand_count: 168 },
        VmProfile { cpu: 2, memory: 2_048.0, bandwidth: 200.0, storage: 20_000.0, spot_count: 44, on_demand_count: 146 },
        VmProfile { cpu: 4, memory: 2_048.0, bandwidth: 200.0, storage: 20_000.0, spot_count: 40, on_demand_count: 158 },
        VmProfile { cpu: 4, memory: 4_096.0, bandwidth: 500.0, storage: 50_000.0, spot_count: 40, on_demand_count: 145 },
        VmProfile { cpu: 6, memory: 4_096.0, bandwidth: 500.0, storage: 50_000.0, spot_count: 36, on_demand_count: 170 },
        VmProfile { cpu: 6, memory: 8_192.0, bandwidth: 1_000.0, storage: 80_000.0, spot_count: 51, on_demand_count: 155 },
        VmProfile { cpu: 8, memory: 8_192.0, bandwidth: 1_000.0, storage: 80_000.0, spot_count: 33, on_demand_count: 162 },
        VmProfile { cpu: 10, memory: 8_192.0, bandwidth: 1_000.0, storage: 80_000.0, spot_count: 47, on_demand_count: 168 },
    ]
}

/// Render Table II.
pub fn host_table() -> TextTable {
    let mut t = TextTable::new("TABLE II - HOST TYPES")
        .column("Size", Align::Left)
        .column("CPU", Align::Right)
        .column("Memory", Align::Right)
        .column("Bandwidth", Align::Right)
        .column("Storage", Align::Right)
        .column("Count", Align::Right);
    for h in host_types() {
        t.push(vec![
            h.name.to_string(),
            h.cpu.to_string(),
            format!("{}", h.memory as u64),
            format!("{}", h.bandwidth as u64),
            format!("{}", h.storage as u64),
            h.count.to_string(),
        ]);
    }
    t
}

/// Render Table III.
pub fn vm_table() -> TextTable {
    let mut t = TextTable::new("TABLE III - VM PROFILES")
        .column("CPU", Align::Right)
        .column("Memory", Align::Right)
        .column("Bandwidth", Align::Right)
        .column("Storage", Align::Right)
        .column("Spot #", Align::Right)
        .column("On-Demand #", Align::Right);
    for p in vm_profiles() {
        t.push(vec![
            p.cpu.to_string(),
            format!("{}", p.memory as u64),
            format!("{}", p.bandwidth as u64),
            format!("{}", p.storage as u64),
            p.spot_count.to_string(),
            p.on_demand_count.to_string(),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_totals() {
        let hosts = host_types();
        assert_eq!(hosts.len(), 4);
        assert_eq!(hosts.iter().map(|h| h.count).sum::<usize>(), 100);
        // Each successive type doubles CPU.
        for w in hosts.windows(2) {
            assert_eq!(w[1].cpu, w[0].cpu * 2);
        }
    }

    #[test]
    fn table3_totals_match_paper() {
        let profiles = vm_profiles();
        assert_eq!(profiles.len(), 10);
        let spot: usize = profiles.iter().map(|p| p.spot_count).sum();
        let od: usize = profiles.iter().map(|p| p.on_demand_count).sum();
        assert_eq!(spot, 400); // paper: 400 spot VMs
        assert_eq!(spot + od, 2_007); // paper: "a total of 2,000 VMs" (sums to 2,007 as printed)
    }

    #[test]
    fn tables_render() {
        assert!(host_table().render().contains("X-Large"));
        assert_eq!(vm_table().row_count(), 10);
    }
}
