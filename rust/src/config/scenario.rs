//! The §VII-E.2 comparison scenario: Table II hosts + Table III VMs with
//! randomized (but seed-reproducible) submission delays and execution
//! durations. "The same randomized values were reused across all
//! simulation runs to ensure consistency" - here enforced by seeding.

use crate::cloudlet::Cloudlet;
use crate::engine::{Engine, EngineConfig};
use crate::stats::Rng;
use crate::vm::{SpotConfig, Vm, VmSpec};

use super::catalog::{host_types, vm_profiles};

/// Scenario parameters (defaults follow §VII-E.2).
#[derive(Debug, Clone, PartialEq)]
pub struct ComparisonConfig {
    pub seed: u64,
    /// MIPS per PE for hosts and VMs.
    pub mips_per_pe: f64,
    /// Spot + on-demand VMs submitted immediately (400 + 600 in the paper);
    /// the rest get a random delay in (0, max_delay].
    pub immediate_on_demand: usize,
    pub max_delay: f64,
    /// Cloudlet execution time range (seconds) - "randomized values were
    /// used for ... total execution times".
    pub exec_time: (f64, f64),
    /// Spot instance settings for the scenario.
    pub spot: SpotConfig,
    /// Persistent-request waiting time for all VMs.
    pub waiting_time: f64,
    /// Simulation hard stop.
    pub terminate_at: f64,
}

impl Default for ComparisonConfig {
    fn default() -> Self {
        // Calibrated so that momentary demand oscillates around cluster
        // capacity (2,880 PEs): enough contention for spot interruptions,
        // without the permanent overload that would interrupt every spot
        // many times (the paper observes <= 2 interruptions per VM).
        ComparisonConfig {
            seed: 20_250_710,
            mips_per_pe: 1_000.0,
            immediate_on_demand: 600,
            max_delay: 2_400.0,
            exec_time: (100.0, 400.0),
            spot: SpotConfig::hibernate()
                .with_min_running(60.0)
                .with_warning(2.0)
                .with_hibernation_timeout(900.0),
            waiting_time: 1_200.0,
            terminate_at: 4_800.0,
        }
    }
}

/// Engine knobs of the §VII-E comparison experiment. Single source of
/// truth shared by `compare::run_policy` and `sweep::SweepSpec::new` -
/// the `run_multi` bit-parity guarantee depends on both using the same
/// settings.
pub fn comparison_engine_config() -> EngineConfig {
    let mut cfg = EngineConfig::default();
    cfg.sample_interval = 5.0;
    cfg.vm_destruction_delay = 1.0;
    cfg
}

/// What was submitted.
#[derive(Debug, Clone, Default)]
pub struct ScenarioStats {
    pub hosts: usize,
    pub spot_vms: usize,
    pub on_demand_vms: usize,
    pub cloudlets: usize,
}

/// One VM submission with every random draw already resolved.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlannedVm {
    pub spec: VmSpec,
    pub is_spot: bool,
    pub delay: f64,
    /// Length of the VM's single cloudlet, in MI.
    pub cloudlet_mi: f64,
}

/// A fully-materialized comparison workload: the RNG consumption of
/// [`build_comparison_workload`] resolved into plain data, so the plan can
/// be built once per seed and shared read-only (via `Arc`) across the
/// many engines of a parameter sweep (`sweep::prebuild`).
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadPlan {
    pub seed: u64,
    pub mips_per_pe: f64,
    pub spot: SpotConfig,
    pub waiting_time: f64,
    pub terminate_at: f64,
    pub vms: Vec<PlannedVm>,
}

/// Resolve `cfg` into a [`WorkloadPlan`] (pure: no engine interaction).
///
/// The RNG draw sequence is exactly the pre-split
/// `build_comparison_workload` order - shuffle, then per submission an
/// optional delay draw followed by the execution-time draw - so
/// plan-then-apply is byte-identical to the original single pass.
pub fn plan_comparison_workload(cfg: &ComparisonConfig) -> WorkloadPlan {
    let mut rng = Rng::new(cfg.seed);

    // Expand Table III into individual (spec, is_spot) submissions.
    let mut submissions: Vec<(VmSpec, bool)> = Vec::new();
    for p in vm_profiles() {
        for _ in 0..p.spot_count {
            submissions.push((p.spec(cfg.mips_per_pe), true));
        }
        for _ in 0..p.on_demand_count {
            submissions.push((p.spec(cfg.mips_per_pe), false));
        }
    }
    // Deterministic shuffle so profiles interleave in submission order.
    rng.shuffle(&mut submissions);

    // Paper: all 400 spot + 600 on-demand submitted immediately; the
    // remaining on-demand VMs get randomized delays.
    let mut immediate_od_left = cfg.immediate_on_demand;
    let mut vms = Vec::with_capacity(submissions.len());
    for (spec, is_spot) in submissions {
        let delay = if is_spot {
            0.0
        } else if immediate_od_left > 0 {
            immediate_od_left -= 1;
            0.0
        } else {
            rng.uniform(0.0, cfg.max_delay)
        };
        let exec = rng.uniform(cfg.exec_time.0, cfg.exec_time.1);
        vms.push(PlannedVm {
            spec,
            is_spot,
            delay,
            cloudlet_mi: exec * cfg.mips_per_pe * spec.pes as f64,
        });
    }

    WorkloadPlan {
        seed: cfg.seed,
        mips_per_pe: cfg.mips_per_pe,
        spot: cfg.spot,
        waiting_time: cfg.waiting_time,
        terminate_at: cfg.terminate_at,
        vms,
    }
}

impl WorkloadPlan {
    /// Submit the planned hosts, VMs and cloudlets into `engine`.
    pub fn apply(&self, engine: &mut Engine) -> ScenarioStats {
        self.apply_with_spot(engine, self.spot)
    }

    /// [`WorkloadPlan::apply`] with the spot-instance settings overridden.
    ///
    /// The spot config only affects interruption handling at run time, not
    /// the planned RNG draws, so a sweep's spot-config axis can share one
    /// plan per seed across all its spot variants (`sweep::prebuild`) and
    /// substitute the variant's config here.
    pub fn apply_with_spot(&self, engine: &mut Engine, spot: SpotConfig) -> ScenarioStats {
        let mut stats = ScenarioStats::default();

        let dc = engine.add_datacenter("dc0", 1.0);
        for ht in host_types() {
            for _ in 0..ht.count {
                engine.add_host(dc, ht.spec(self.mips_per_pe));
                stats.hosts += 1;
            }
        }

        for p in &self.vms {
            let vm = if p.is_spot {
                stats.spot_vms += 1;
                Vm::spot(0, p.spec, spot)
                    .with_persistent(self.waiting_time)
                    .with_delay(p.delay)
            } else {
                stats.on_demand_vms += 1;
                Vm::on_demand(0, p.spec)
                    .with_persistent(self.waiting_time)
                    .with_delay(p.delay)
            };
            let vm = engine.submit_vm(vm);
            engine.submit_cloudlet(Cloudlet::new(0, p.cloudlet_mi, p.spec.pes).with_vm(vm));
            stats.cloudlets += 1;
        }

        engine.terminate_at(self.terminate_at);
        stats
    }
}

/// Build Table II hosts and Table III VMs into `engine`.
///
/// The RNG consumption sequence is a pure function of `cfg.seed`, so runs
/// with different allocation policies see byte-identical workloads.
/// (Implemented as plan + apply; sweeps reuse one plan across cells.)
pub fn build_comparison_workload(engine: &mut Engine, cfg: &ComparisonConfig) -> ScenarioStats {
    plan_comparison_workload(cfg).apply(engine)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allocation::FirstFit;
    use crate::engine::EngineConfig;

    #[test]
    fn workload_matches_table_counts() {
        let mut e = Engine::new(EngineConfig::default(), Box::new(FirstFit::new()));
        let stats = build_comparison_workload(&mut e, &ComparisonConfig::default());
        assert_eq!(stats.hosts, 100);
        assert_eq!(stats.spot_vms, 400);
        assert_eq!(stats.on_demand_vms, 1_607);
        assert_eq!(stats.cloudlets, 2_007);
    }

    #[test]
    fn workload_is_seed_deterministic() {
        let build = || {
            let mut e = Engine::new(EngineConfig::default(), Box::new(FirstFit::new()));
            build_comparison_workload(&mut e, &ComparisonConfig::default());
            e.world
                .vms
                .iter()
                .map(|v| (v.spec.pes, v.is_spot(), (v.submission_delay * 1e6) as u64))
                .collect::<Vec<_>>()
        };
        assert_eq!(build(), build());
    }

    #[test]
    fn plan_is_pure_and_seed_deterministic() {
        let cfg = ComparisonConfig::default();
        assert_eq!(plan_comparison_workload(&cfg), plan_comparison_workload(&cfg));
        let other = ComparisonConfig { seed: cfg.seed + 1, ..cfg.clone() };
        assert_ne!(plan_comparison_workload(&cfg), plan_comparison_workload(&other));
    }

    #[test]
    fn plan_apply_matches_direct_build() {
        // Two engines: one via the public wrapper, one via an explicitly
        // pre-built (shareable) plan - identical worlds.
        let cfg = ComparisonConfig::default();
        let mut direct = Engine::new(EngineConfig::default(), Box::new(FirstFit::new()));
        let s1 = build_comparison_workload(&mut direct, &cfg);
        let plan = plan_comparison_workload(&cfg);
        let mut planned = Engine::new(EngineConfig::default(), Box::new(FirstFit::new()));
        let s2 = plan.apply(&mut planned);
        assert_eq!(s1.hosts, s2.hosts);
        assert_eq!(s1.cloudlets, s2.cloudlets);
        let snap = |e: &Engine| {
            e.world
                .vms
                .iter()
                .map(|v| (v.spec.pes, v.is_spot(), v.submission_delay.to_bits()))
                .collect::<Vec<_>>()
        };
        assert_eq!(snap(&direct), snap(&planned));
    }

    #[test]
    fn apply_with_spot_overrides_only_spot_config() {
        let cfg = ComparisonConfig::default();
        let plan = plan_comparison_workload(&cfg);
        let spot = cfg.spot.with_warning(60.0);
        let mut e = Engine::new(EngineConfig::default(), Box::new(FirstFit::new()));
        let stats = plan.apply_with_spot(&mut e, spot);
        assert_eq!(stats.spot_vms, 400);
        // Every spot VM carries the override; submission order and delays
        // are untouched (same planned draws).
        for v in e.world.vms.iter().filter(|v| v.is_spot()) {
            assert_eq!(v.spot.expect("spot vm has a config").warning_time, 60.0);
        }
    }

    #[test]
    fn immediate_counts_match_paper() {
        let mut e = Engine::new(EngineConfig::default(), Box::new(FirstFit::new()));
        build_comparison_workload(&mut e, &ComparisonConfig::default());
        let immediate =
            e.world.vms.iter().filter(|v| v.submission_delay == 0.0).count();
        // 400 spot + 600 on-demand submitted without delay.
        assert_eq!(immediate, 1_000);
    }
}
