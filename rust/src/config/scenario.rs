//! The §VII-E.2 comparison scenario: Table II hosts + Table III VMs with
//! randomized (but seed-reproducible) submission delays and execution
//! durations. "The same randomized values were reused across all
//! simulation runs to ensure consistency" - here enforced by seeding.

use crate::cloudlet::Cloudlet;
use crate::engine::Engine;
use crate::stats::Rng;
use crate::vm::{SpotConfig, Vm, VmSpec};

use super::catalog::{host_types, vm_profiles};

/// Scenario parameters (defaults follow §VII-E.2).
#[derive(Debug, Clone)]
pub struct ComparisonConfig {
    pub seed: u64,
    /// MIPS per PE for hosts and VMs.
    pub mips_per_pe: f64,
    /// Spot + on-demand VMs submitted immediately (400 + 600 in the paper);
    /// the rest get a random delay in (0, max_delay].
    pub immediate_on_demand: usize,
    pub max_delay: f64,
    /// Cloudlet execution time range (seconds) - "randomized values were
    /// used for ... total execution times".
    pub exec_time: (f64, f64),
    /// Spot instance settings for the scenario.
    pub spot: SpotConfig,
    /// Persistent-request waiting time for all VMs.
    pub waiting_time: f64,
    /// Simulation hard stop.
    pub terminate_at: f64,
}

impl Default for ComparisonConfig {
    fn default() -> Self {
        // Calibrated so that momentary demand oscillates around cluster
        // capacity (2,880 PEs): enough contention for spot interruptions,
        // without the permanent overload that would interrupt every spot
        // many times (the paper observes <= 2 interruptions per VM).
        ComparisonConfig {
            seed: 20_250_710,
            mips_per_pe: 1_000.0,
            immediate_on_demand: 600,
            max_delay: 2_400.0,
            exec_time: (100.0, 400.0),
            spot: SpotConfig::hibernate()
                .with_min_running(60.0)
                .with_warning(2.0)
                .with_hibernation_timeout(900.0),
            waiting_time: 1_200.0,
            terminate_at: 4_800.0,
        }
    }
}

/// What was submitted.
#[derive(Debug, Clone, Default)]
pub struct ScenarioStats {
    pub hosts: usize,
    pub spot_vms: usize,
    pub on_demand_vms: usize,
    pub cloudlets: usize,
}

/// Build Table II hosts and Table III VMs into `engine`.
///
/// The RNG consumption sequence is a pure function of `cfg.seed`, so runs
/// with different allocation policies see byte-identical workloads.
pub fn build_comparison_workload(engine: &mut Engine, cfg: &ComparisonConfig) -> ScenarioStats {
    let mut rng = Rng::new(cfg.seed);
    let mut stats = ScenarioStats::default();

    let dc = engine.add_datacenter("dc0", 1.0);
    for ht in host_types() {
        for _ in 0..ht.count {
            engine.add_host(dc, ht.spec(cfg.mips_per_pe));
            stats.hosts += 1;
        }
    }

    // Expand Table III into individual (spec, is_spot) submissions.
    let mut submissions: Vec<(VmSpec, bool)> = Vec::new();
    for p in vm_profiles() {
        for _ in 0..p.spot_count {
            submissions.push((p.spec(cfg.mips_per_pe), true));
        }
        for _ in 0..p.on_demand_count {
            submissions.push((p.spec(cfg.mips_per_pe), false));
        }
    }
    // Deterministic shuffle so profiles interleave in submission order.
    rng.shuffle(&mut submissions);

    // Paper: all 400 spot + 600 on-demand submitted immediately; the
    // remaining on-demand VMs get randomized delays.
    let mut immediate_od_left = cfg.immediate_on_demand;
    for (spec, is_spot) in submissions {
        let delay = if is_spot {
            0.0
        } else if immediate_od_left > 0 {
            immediate_od_left -= 1;
            0.0
        } else {
            rng.uniform(0.0, cfg.max_delay)
        };
        let vm = if is_spot {
            stats.spot_vms += 1;
            Vm::spot(0, spec, cfg.spot).with_persistent(cfg.waiting_time).with_delay(delay)
        } else {
            stats.on_demand_vms += 1;
            Vm::on_demand(0, spec).with_persistent(cfg.waiting_time).with_delay(delay)
        };
        let vm = engine.submit_vm(vm);

        let exec = rng.uniform(cfg.exec_time.0, cfg.exec_time.1);
        let length = exec * cfg.mips_per_pe * spec.pes as f64;
        engine.submit_cloudlet(Cloudlet::new(0, length, spec.pes).with_vm(vm));
        stats.cloudlets += 1;
    }

    engine.terminate_at(cfg.terminate_at);
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allocation::FirstFit;
    use crate::engine::EngineConfig;

    #[test]
    fn workload_matches_table_counts() {
        let mut e = Engine::new(EngineConfig::default(), Box::new(FirstFit::new()));
        let stats = build_comparison_workload(&mut e, &ComparisonConfig::default());
        assert_eq!(stats.hosts, 100);
        assert_eq!(stats.spot_vms, 400);
        assert_eq!(stats.on_demand_vms, 1_607);
        assert_eq!(stats.cloudlets, 2_007);
    }

    #[test]
    fn workload_is_seed_deterministic() {
        let build = || {
            let mut e = Engine::new(EngineConfig::default(), Box::new(FirstFit::new()));
            build_comparison_workload(&mut e, &ComparisonConfig::default());
            e.world
                .vms
                .iter()
                .map(|v| (v.spec.pes, v.is_spot(), (v.submission_delay * 1e6) as u64))
                .collect::<Vec<_>>()
        };
        assert_eq!(build(), build());
    }

    #[test]
    fn immediate_counts_match_paper() {
        let mut e = Engine::new(EngineConfig::default(), Box::new(FirstFit::new()));
        build_comparison_workload(&mut e, &ComparisonConfig::default());
        let immediate =
            e.world.vms.iter().filter(|v| v.submission_delay == 0.0).count();
        // 400 spot + 600 on-demand submitted without delay.
        assert_eq!(immediate, 1_000);
    }
}
