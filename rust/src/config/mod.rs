//! Experiment configuration: the host/VM catalogs of the paper's Tables
//! II-III and the comparison-scenario builder of §VII-E.2.

pub mod catalog;
pub mod scenario;

pub use catalog::{host_types, vm_profiles, HostType, VmProfile};
pub use scenario::{
    build_comparison_workload, comparison_engine_config, plan_comparison_workload,
    ComparisonConfig, PlannedVm, WorkloadPlan,
};
