//! The world: arena storage for all simulation entities.
//!
//! CloudSim Plus wires entities together with object references; in Rust an
//! arena (id-indexed vectors) gives the same topology without shared
//! mutable ownership, and the allocation policies get a cheap immutable
//! view (`&World`) while the engine mutates through it between policy
//! calls.

use crate::cloudlet::{Cloudlet, CloudletId};
use crate::infra::{Datacenter, DcId, Host, HostId, HostSpec};
use crate::vm::{Vm, VmId, VmState};

/// Arena of datacenters, hosts, VMs and cloudlets.
#[derive(Default)]
pub struct World {
    pub datacenters: Vec<Datacenter>,
    pub hosts: Vec<Host>,
    pub vms: Vec<Vm>,
    pub cloudlets: Vec<Cloudlet>,
}

impl World {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_datacenter(&mut self, name: &str, scheduling_interval: f64) -> DcId {
        let id = self.datacenters.len();
        self.datacenters.push(Datacenter::new(id, name, scheduling_interval));
        id
    }

    /// Register a host (active immediately at `now`).
    pub fn add_host(&mut self, dc: DcId, spec: HostSpec, now: f64) -> HostId {
        let id = self.hosts.len();
        self.hosts.push(Host::new(id, dc, spec, now));
        self.datacenters[dc].hosts.push(id);
        id
    }

    /// Register a VM; the caller (engine/broker) schedules its submission.
    pub fn add_vm(&mut self, mut vm: Vm) -> VmId {
        let id = self.vms.len();
        vm.id = id;
        self.vms.push(vm);
        id
    }

    /// Register a cloudlet bound to an existing VM.
    pub fn add_cloudlet(&mut self, mut cl: Cloudlet) -> CloudletId {
        assert!(cl.vm < self.vms.len(), "cloudlet bound to unknown vm {}", cl.vm);
        let id = self.cloudlets.len();
        cl.id = id;
        self.vms[cl.vm].cloudlets.push(id);
        self.cloudlets.push(cl);
        id
    }

    /// Active (placeable) hosts.
    pub fn active_hosts(&self) -> impl Iterator<Item = &Host> {
        self.hosts.iter().filter(|h| h.is_active())
    }

    /// Resources on `host` currently held by spot VMs, in artifact
    /// dimension order (CPU MIPS, RAM, BW, storage) - Eq. (10) numerator.
    pub fn spot_used_vec(&self, host: &Host) -> [f64; 4] {
        let mut acc = [0.0; 4];
        for &vid in &host.vms {
            let vm = &self.vms[vid];
            if vm.is_spot() {
                let r = vm.spec.request_vec();
                for d in 0..4 {
                    acc[d] += r[d];
                }
            }
        }
        acc
    }

    /// Spot VMs on `host` that may be interrupted at `now`
    /// (running, past min runtime, not already warned).
    pub fn interruptible_spots(&self, host: &Host, now: f64) -> Vec<VmId> {
        host.vms.iter().copied().filter(|&v| self.vms[v].interruptible(now)).collect()
    }

    /// Whether `vm` would fit on `host` if the given spot VMs were removed.
    pub fn fits_with_clearing(&self, host: &Host, vm: &Vm, cleared: &[VmId]) -> bool {
        let mut pes = host.free_pes();
        let mut ram = host.free_ram();
        let mut bw = host.free_bw();
        let mut st = host.free_storage();
        for &v in cleared {
            let s = &self.vms[v].spec;
            pes += s.pes;
            ram += s.ram;
            bw += s.bw;
            st += s.storage;
        }
        host.is_active()
            && pes >= vm.spec.pes
            && ram + 1e-9 >= vm.spec.ram
            && bw + 1e-9 >= vm.spec.bw
            && st + 1e-9 >= vm.spec.storage
    }

    /// Count of VMs in a given state, split (on-demand, spot).
    pub fn count_by_state(&self, state: VmState) -> (usize, usize) {
        let mut od = 0;
        let mut spot = 0;
        for vm in &self.vms {
            if vm.state == state {
                if vm.is_spot() {
                    spot += 1;
                } else {
                    od += 1;
                }
            }
        }
        (od, spot)
    }

    /// Aggregate (used, total) PEs over active hosts.
    pub fn pe_usage(&self) -> (u32, u32) {
        let mut used = 0;
        let mut total = 0;
        for h in self.active_hosts() {
            used += h.used_pes;
            total += h.spec.pes;
        }
        (used, total)
    }

    /// Aggregate (used, total) RAM over active hosts.
    pub fn ram_usage(&self) -> (f64, f64) {
        let mut used = 0.0;
        let mut total = 0.0;
        for h in self.active_hosts() {
            used += h.used_ram;
            total += h.spec.ram;
        }
        (used, total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vm::{SpotConfig, VmSpec};

    fn world_with_host() -> (World, HostId) {
        let mut w = World::new();
        let dc = w.add_datacenter("dc0", 1.0);
        let h = w.add_host(dc, HostSpec::new(8, 1000.0, 16_384.0, 5_000.0, 200_000.0), 0.0);
        (w, h)
    }

    #[test]
    fn arena_ids_are_dense() {
        let (mut w, h) = world_with_host();
        assert_eq!(h, 0);
        let v0 = w.add_vm(Vm::on_demand(0, VmSpec::new(1000.0, 2)));
        let v1 = w.add_vm(Vm::spot(0, VmSpec::new(1000.0, 1), SpotConfig::hibernate()));
        assert_eq!((v0, v1), (0, 1));
        let c = w.add_cloudlet(Cloudlet::new(0, 1000.0, 1).with_vm(v0));
        assert_eq!(c, 0);
        assert_eq!(w.vms[v0].cloudlets, vec![c]);
    }

    #[test]
    fn spot_used_only_counts_spot() {
        let (mut w, h) = world_with_host();
        let od = w.add_vm(Vm::on_demand(0, VmSpec::new(1000.0, 2)));
        let sp = w.add_vm(Vm::spot(0, VmSpec::new(1000.0, 1), SpotConfig::terminate()));
        let (od_spec, sp_spec) = (w.vms[od].spec, w.vms[sp].spec);
        w.hosts[h].commit(od, od_spec.pes, od_spec.ram, od_spec.bw, od_spec.storage);
        w.hosts[h].commit(sp, sp_spec.pes, sp_spec.ram, sp_spec.bw, sp_spec.storage);
        let spot_used = w.spot_used_vec(&w.hosts[h]);
        assert_eq!(spot_used, [1000.0, 512.0, 1000.0, 10_000.0]);
    }

    #[test]
    fn fits_with_clearing_accounts_released_resources() {
        let (mut w, h) = world_with_host();
        let sp = w.add_vm(Vm::spot(0, VmSpec::new(1000.0, 6), SpotConfig::terminate()));
        let sp_spec = w.vms[sp].spec;
        w.hosts[h].commit(sp, sp_spec.pes, sp_spec.ram, sp_spec.bw, sp_spec.storage);
        let big = Vm::on_demand(1, VmSpec::new(1000.0, 8));
        assert!(!w.hosts[h].fits(big.spec.pes, big.spec.ram, big.spec.bw, big.spec.storage));
        assert!(w.fits_with_clearing(&w.hosts[h], &big, &[sp]));
    }

    #[test]
    #[should_panic(expected = "unknown vm")]
    fn cloudlet_requires_valid_vm() {
        let (mut w, _) = world_with_host();
        w.add_cloudlet(Cloudlet::new(0, 100.0, 1).with_vm(5));
    }
}
