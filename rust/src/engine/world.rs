//! The world: arena storage for all simulation entities, plus the
//! incremental placement index the allocation hot path queries.
//!
//! CloudSim Plus wires entities together with object references; in Rust an
//! arena (id-indexed vectors) gives the same topology without shared
//! mutable ownership, and the allocation policies get a cheap immutable
//! view (`&World`) while the engine mutates through it between policy
//! calls.
//!
//! All placement-relevant mutation goes through [`World::commit_vm`],
//! [`World::release_vm`], [`World::activate_host`],
//! [`World::deactivate_host`], [`World::transition_vm`] and the
//! displacement/hibernation setters: these keep the [`PlacementIndex`]
//! (free-PE buckets, spot-host set), the struct-of-arrays hot columns and
//! the O(1) sampling counters ([`super::soa::HotState`]) consistent with
//! the arena. The raw [`Host::commit`] / [`Host::release`] accounting
//! primitives are still public for host-local unit tests but bypass the
//! index - production code and policies must use the `World` methods.
//! Every indexed query and the O(1) [`World::state_sample`] have a
//! `_scan` twin that recomputes the answer with the pre-index linear
//! walk; the property/parity tests pin the two together bitwise, and the
//! decision benches use the scans as the baseline.

use crate::cloudlet::{Cloudlet, CloudletId};
use crate::infra::{Datacenter, DcId, Host, HostId, HostSpec, HostState};
use crate::vm::{Vm, VmId, VmState};

use super::index::PlacementIndex;
use super::soa::HotState;

/// One-pass sampling snapshot (see [`World::state_sample`]).
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct StateSample {
    pub od_running: usize,
    pub spot_running: usize,
    pub od_warned: usize,
    pub spot_warned: usize,
    /// Spot VMs currently hibernated (on-demand VMs never hibernate).
    pub hibernated: usize,
    pub od_waiting: usize,
    pub spot_waiting: usize,
    pub used_pes: u32,
    pub total_pes: u32,
    pub used_ram: f64,
    pub total_ram: f64,
    /// Hosts currently down after having been active (trace removals and
    /// chaos crashes); dormant not-yet-added trace machines don't count.
    pub failed_hosts: usize,
    /// VMs currently displaced from a host (hibernated or requeued after
    /// an eviction) and not yet re-placed.
    pub displaced: usize,
}

impl StateSample {
    /// Bitwise equality (f64 fields compared via `to_bits`) - the
    /// contract the incremental counters must uphold against the scan
    /// oracle so sampled series stay byte-identical.
    pub fn bits_eq(&self, o: &StateSample) -> bool {
        self.od_running == o.od_running
            && self.spot_running == o.spot_running
            && self.od_warned == o.od_warned
            && self.spot_warned == o.spot_warned
            && self.hibernated == o.hibernated
            && self.od_waiting == o.od_waiting
            && self.spot_waiting == o.spot_waiting
            && self.used_pes == o.used_pes
            && self.total_pes == o.total_pes
            && self.used_ram.to_bits() == o.used_ram.to_bits()
            && self.total_ram.to_bits() == o.total_ram.to_bits()
            && self.failed_hosts == o.failed_hosts
            && self.displaced == o.displaced
    }
}

/// Arena of datacenters, hosts, VMs and cloudlets.
#[derive(Default)]
pub struct World {
    pub datacenters: Vec<Datacenter>,
    pub hosts: Vec<Host>,
    pub vms: Vec<Vm>,
    pub cloudlets: Vec<Cloudlet>,
    index: PlacementIndex,
    hot: HotState,
}

impl World {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_datacenter(&mut self, name: &str, scheduling_interval: f64) -> DcId {
        let id = self.datacenters.len();
        self.datacenters.push(Datacenter::new(id, name, scheduling_interval));
        id
    }

    /// Register a host (active immediately at `now`).
    pub fn add_host(&mut self, dc: DcId, spec: HostSpec, now: f64) -> HostId {
        let id = self.hosts.len();
        self.hosts.push(Host::new(id, dc, spec, now));
        self.datacenters[dc].hosts.push(id);
        self.index.insert(id, spec.pes);
        self.hot.push_host(&self.hosts[id]);
        self.add_host_contribution(id);
        id
    }

    /// Register a VM; the caller (engine/broker) schedules its submission.
    pub fn add_vm(&mut self, mut vm: Vm) -> VmId {
        let id = self.vms.len();
        vm.id = id;
        self.vms.push(vm);
        self.hot.push_vm(&self.vms[id]);
        id
    }

    /// Register a cloudlet bound to an existing VM.
    pub fn add_cloudlet(&mut self, mut cl: Cloudlet) -> CloudletId {
        assert!(cl.vm < self.vms.len(), "cloudlet bound to unknown vm {}", cl.vm);
        let id = self.cloudlets.len();
        cl.id = id;
        self.vms[cl.vm].cloudlets.push(id);
        self.cloudlets.push(cl);
        id
    }

    // ------------------------------------------------------------------
    // index- and counter-maintaining mutation API
    // ------------------------------------------------------------------

    /// Commit `vm`'s requested resources on `host`, keeping the placement
    /// index, the SoA columns, the sampling counters and the host's
    /// spot-usage vector in sync.
    pub fn commit_vm(&mut self, host: HostId, vm: VmId) {
        let spec = self.vms[vm].spec;
        let is_spot = self.vms[vm].is_spot();
        self.hosts[host].commit(vm, spec.pes, spec.ram, spec.bw, spec.storage);
        if self.hosts[host].is_active() {
            self.index.update_free(host, self.hosts[host].free_pes());
            // An inactive host contributes nothing to the sample, so its
            // usage joins the counters only while it is active (the
            // activate/deactivate contribution delta covers the rest).
            self.hot.add_pes(spec.pes, 0);
            self.hot.add_used_ram(spec.ram);
        }
        if is_spot {
            // Incremental O(1) update: `Host::commit` appended `vm` at
            // the END of the host's VM list, so adding its request vector
            // last extends the scan oracle's left fold bit-for-bit - no
            // rebuild and no exactness assumption needed (release is the
            // asymmetric case, see `release_vm`).
            let r = spec.request_vec();
            let h = &mut self.hosts[host];
            for d in 0..4 {
                h.spot_used[d] += r[d];
            }
            h.spot_vms += 1;
            self.index.set_spot(host, true);
        }
        self.hot.sync_host(&self.hosts[host]);
    }

    /// Release `vm`'s resources from `host` (deallocation, interruption,
    /// eviction), keeping the index, columns and counters in sync.
    pub fn release_vm(&mut self, host: HostId, vm: VmId) {
        let spec = self.vms[vm].spec;
        let is_spot = self.vms[vm].is_spot();
        self.hosts[host].release(vm, spec.pes, spec.ram, spec.bw, spec.storage);
        if self.hosts[host].is_active() {
            self.index.update_free(host, self.hosts[host].free_pes());
            self.hot.sub_pes(spec.pes, 0);
            self.hot.sub_used_ram(spec.ram);
        }
        if is_spot {
            // Rebuild (not subtract): f64 subtraction is not a bitwise
            // inverse of addition, and removing a VM from the middle of
            // the list changes every later partial sum of the oracle's
            // fold - so only a re-walk of this one host's VM list in
            // allocation order can preserve bitwise parity with
            // `spot_used_vec_scan`.
            self.refresh_spot(host);
        }
        self.hot.sync_host(&self.hosts[host]);
    }

    /// Mark a host active (host add / trace ADD event) and index it.
    ///
    /// Idempotent: a duplicate trace ADD (or chaos recovery racing one)
    /// for an already-active host is a no-op - re-running the body would
    /// clobber `created_at` and double-add the host's sampling
    /// contribution.
    pub fn activate_host(&mut self, h: HostId, now: f64) {
        if self.hosts[h].is_active() {
            return;
        }
        let was_failed = self.hosts[h].removed_at.is_some();
        let host = &mut self.hosts[h];
        host.state = HostState::Active;
        host.created_at = now;
        host.removed_at = None;
        let free = host.free_pes();
        let has_spot = host.spot_vms > 0;
        self.index.insert(h, free);
        self.index.set_spot(h, has_spot);
        if was_failed {
            // Down-after-active host coming back: no longer failed.
            self.hot.dec_failed_hosts();
        }
        self.add_host_contribution(h);
        self.hot.sync_host(&self.hosts[h]);
    }

    /// Mark a host removed/dormant and drop it from the index.
    /// `removed_at` is `None` for hosts that were never active (dormant
    /// trace machines awaiting their ADD event). Idempotent for repeated
    /// deactivations (the contribution delta is only applied once).
    pub fn deactivate_host(&mut self, h: HostId, removed_at: Option<f64>) {
        let was_active = self.hosts[h].is_active();
        let was_failed = !was_active && self.hosts[h].removed_at.is_some();
        {
            let host = &mut self.hosts[h];
            host.state = HostState::Removed;
            if removed_at.is_some() {
                host.removed_at = removed_at;
            }
        }
        self.index.remove(h);
        if was_active {
            self.remove_host_contribution(h);
        }
        let now_failed = self.hosts[h].removed_at.is_some();
        match (was_failed, now_failed) {
            (false, true) => self.hot.inc_failed_hosts(),
            (true, false) => self.hot.dec_failed_hosts(),
            _ => {}
        }
        self.hot.sync_host(&self.hosts[h]);
    }

    /// Transition `v` to `next`, keeping the per-state sampling counters
    /// and the SoA state column in sync. Terminal transitions also clear
    /// any pending displacement (a VM that dies while displaced must not
    /// inflate the `displaced` gauge forever) - engine code must use this
    /// instead of raw [`Vm::transition`].
    pub fn transition_vm(&mut self, v: VmId, next: VmState) {
        self.vms[v].transition(next);
        self.hot.vm_transition(v, next);
        if next.is_final() && self.vms[v].displaced_at.take().is_some() {
            self.hot.dec_displaced();
            self.hot.vm_displaced_at[v] = f64::NAN;
        }
    }

    /// Mark `v` displaced at `now` (evicted/hibernated off a host and not
    /// yet re-placed). Re-marking an already-displaced VM refreshes the
    /// timestamp without double-counting the gauge.
    pub fn mark_displaced(&mut self, v: VmId, now: f64) {
        if self.vms[v].displaced_at.is_none() {
            self.hot.inc_displaced();
        }
        self.vms[v].displaced_at = Some(now);
        self.hot.vm_displaced_at[v] = now;
    }

    /// Clear `v`'s displacement (re-placement or terminal state),
    /// returning the displacement timestamp for recovery metrics.
    pub fn take_displaced(&mut self, v: VmId) -> Option<f64> {
        let t = self.vms[v].displaced_at.take();
        if t.is_some() {
            self.hot.dec_displaced();
            self.hot.vm_displaced_at[v] = f64::NAN;
        }
        t
    }

    /// Set or clear `v`'s hibernation timestamp (struct + SoA column).
    pub fn set_hibernated_at(&mut self, v: VmId, at: Option<f64>) {
        self.vms[v].hibernated_at = at;
        self.hot.vm_hibernated_at[v] = at.unwrap_or(f64::NAN);
    }

    /// Whether `state_sample` currently serves the RAM aggregates from
    /// the O(1) counters (true for all quantized-RAM workloads) or falls
    /// back to a host walk for those two fields (see `engine::soa`).
    pub fn sample_is_incremental(&self) -> bool {
        self.hot.ram_exact()
    }

    /// Add an active host's current usage to the aggregate counters.
    fn add_host_contribution(&mut self, h: HostId) {
        let (used_pes, pes, used_ram, ram) = {
            let host = &self.hosts[h];
            (host.used_pes, host.spec.pes, host.used_ram, host.spec.ram)
        };
        self.hot.add_pes(used_pes, pes);
        self.hot.add_used_ram(used_ram);
        self.hot.add_total_ram(ram);
    }

    /// Remove a deactivating host's current usage from the counters.
    fn remove_host_contribution(&mut self, h: HostId) {
        let (used_pes, pes, used_ram, ram) = {
            let host = &self.hosts[h];
            (host.used_pes, host.spec.pes, host.used_ram, host.spec.ram)
        };
        self.hot.sub_pes(used_pes, pes);
        self.hot.sub_used_ram(used_ram);
        self.hot.sub_total_ram(ram);
    }

    /// Rebuild `host`'s spot-usage vector by walking its VM list in
    /// allocation order - the exact summation order of the scan oracle,
    /// so O(1) reads stay bitwise equal to a from-scratch recompute.
    /// Only the release path needs this; commits extend the fold
    /// incrementally (see `commit_vm`).
    fn refresh_spot(&mut self, host: HostId) {
        let mut acc = [0.0f64; 4];
        let mut n = 0u32;
        for &vid in &self.hosts[host].vms {
            let vm = &self.vms[vid];
            if vm.is_spot() {
                let r = vm.spec.request_vec();
                for d in 0..4 {
                    acc[d] += r[d];
                }
                n += 1;
            }
        }
        let h = &mut self.hosts[host];
        h.spot_used = acc;
        h.spot_vms = n;
        self.index.set_spot(host, n > 0);
        self.hot.sync_host(&self.hosts[host]);
    }

    // ------------------------------------------------------------------
    // indexed placement queries (with `_scan` oracles)
    // ------------------------------------------------------------------

    /// First-Fit: lowest-id active host where `vm` fits.
    ///
    /// Hybrid strategy: a few O(buckets x log H) index probes (the common
    /// case hits on the first one), then - if many PE-feasible hosts keep
    /// failing the RAM/BW/storage dimensions - a plain ordered walk over
    /// the remaining id range, so the degenerate case is never
    /// asymptotically worse than the pre-index linear scan. Feasibility
    /// checks read the SoA columns ([`HotState::host_fits`]), which the
    /// mutation API keeps bitwise in sync with [`Host::fits`].
    pub fn first_fit_host(&self, vm: &Vm) -> Option<HostId> {
        let s = vm.spec;
        const PROBE_LIMIT: usize = 8;
        let mut after: Option<HostId> = None;
        for _ in 0..PROBE_LIMIT {
            match self.index.first_feasible_after(s.pes, after) {
                None => return None,
                Some(id) if self.hot.host_fits(id, s.pes, s.ram, s.bw, s.storage) => {
                    return Some(id)
                }
                Some(id) => after = Some(id),
            }
        }
        // `after` was probed and rejected, so resume one past it; when
        // `after` is the last host this yields an empty range, not an
        // out-of-bounds slice.
        let start = after.map_or(0, |a| a + 1);
        (start..self.hosts.len()).find(|&h| self.hot.host_fits(h, s.pes, s.ram, s.bw, s.storage))
    }

    /// Pre-index First-Fit linear scan (oracle / bench baseline).
    pub fn first_fit_host_scan(&self, vm: &Vm) -> Option<HostId> {
        let s = vm.spec;
        self.active_hosts().find(|h| h.fits(s.pes, s.ram, s.bw, s.storage)).map(|h| h.id)
    }

    /// Best-Fit: feasible host with the fewest free PEs (ties: lowest id).
    pub fn best_fit_host(&self, vm: &Vm) -> Option<HostId> {
        let s = vm.spec;
        self.index.best_fit(s.pes, |id| self.hot.host_fits(id, s.pes, s.ram, s.bw, s.storage))
    }

    /// Pre-index Best-Fit linear scan (oracle / bench baseline).
    pub fn best_fit_host_scan(&self, vm: &Vm) -> Option<HostId> {
        let s = vm.spec;
        self.active_hosts()
            .filter(|h| h.fits(s.pes, s.ram, s.bw, s.storage))
            .min_by_key(|h| h.free_pes())
            .map(|h| h.id)
    }

    /// Worst-Fit: feasible host with the most free PEs (ties: highest id,
    /// matching `max_by_key` over the id-ascending scan).
    pub fn worst_fit_host(&self, vm: &Vm) -> Option<HostId> {
        let s = vm.spec;
        self.index.worst_fit(s.pes, |id| self.hot.host_fits(id, s.pes, s.ram, s.bw, s.storage))
    }

    /// Pre-index Worst-Fit linear scan (oracle / bench baseline).
    pub fn worst_fit_host_scan(&self, vm: &Vm) -> Option<HostId> {
        let s = vm.spec;
        self.active_hosts()
            .filter(|h| h.fits(s.pes, s.ram, s.bw, s.storage))
            .max_by_key(|h| h.free_pes())
            .map(|h| h.id)
    }

    /// All hosts where `vm` fits, ascending by id (HLEM phase-1 feasible
    /// list). Clears and fills `out`; only PE-feasible buckets are probed.
    pub fn feasible_host_ids(&self, vm: &Vm, out: &mut Vec<HostId>) {
        let s = vm.spec;
        self.index.feasible_into(
            s.pes,
            |id| self.hot.host_fits(id, s.pes, s.ram, s.bw, s.storage),
            out,
        );
    }

    /// Pre-index feasible-list linear scan (oracle / bench baseline).
    pub fn feasible_host_ids_scan(&self, vm: &Vm, out: &mut Vec<HostId>) {
        let s = vm.spec;
        out.clear();
        out.extend(
            self.active_hosts().filter(|h| h.fits(s.pes, s.ram, s.bw, s.storage)).map(|h| h.id),
        );
    }

    /// Active hosts carrying at least one spot VM, ascending by id - the
    /// only hosts the preemption scan can ever pick victims from.
    pub fn spot_host_ids(&self) -> impl Iterator<Item = HostId> + '_ {
        self.index.spot_host_ids()
    }

    /// Active (placeable) hosts.
    pub fn active_hosts(&self) -> impl Iterator<Item = &Host> {
        self.hosts.iter().filter(|h| h.is_active())
    }

    /// Resources on `host` currently held by spot VMs, in artifact
    /// dimension order (CPU MIPS, RAM, BW, storage) - Eq. (10) numerator.
    /// O(1): reads the incrementally maintained host vector.
    pub fn spot_used_vec(&self, host: &Host) -> [f64; 4] {
        host.spot_used
    }

    /// Pre-index spot-usage walk (oracle for [`Self::spot_used_vec`]).
    pub fn spot_used_vec_scan(&self, host: &Host) -> [f64; 4] {
        let mut acc = [0.0; 4];
        for &vid in &host.vms {
            let vm = &self.vms[vid];
            if vm.is_spot() {
                let r = vm.spec.request_vec();
                for d in 0..4 {
                    acc[d] += r[d];
                }
            }
        }
        acc
    }

    /// Spot VMs on `host` that may be interrupted at `now`
    /// (running, past min runtime, not already warned). Clears and fills
    /// `out` - the allocation-free twin of [`Self::interruptible_spots`].
    pub fn interruptible_spots_into(&self, host: &Host, now: f64, out: &mut Vec<VmId>) {
        out.clear();
        out.extend(host.vms.iter().copied().filter(|&v| self.vms[v].interruptible(now)));
    }

    /// Allocating convenience wrapper around
    /// [`Self::interruptible_spots_into`].
    pub fn interruptible_spots(&self, host: &Host, now: f64) -> Vec<VmId> {
        let mut out = Vec::new();
        self.interruptible_spots_into(host, now, &mut out);
        out
    }

    /// Whether `vm` would fit on `host` if the given spot VMs were removed.
    pub fn fits_with_clearing(&self, host: &Host, vm: &Vm, cleared: &[VmId]) -> bool {
        let mut pes = host.free_pes();
        let mut ram = host.free_ram();
        let mut bw = host.free_bw();
        let mut st = host.free_storage();
        for &v in cleared {
            let s = &self.vms[v].spec;
            pes += s.pes;
            ram += s.ram;
            bw += s.bw;
            st += s.storage;
        }
        host.is_active()
            && pes >= vm.spec.pes
            && ram + 1e-9 >= vm.spec.ram
            && bw + 1e-9 >= vm.spec.bw
            && st + 1e-9 >= vm.spec.storage
    }

    /// Verify the incremental index, the SoA columns and the sampling
    /// counters against recompute-from-scratch oracles (test/debug
    /// support; O(hosts x vms)). Checks bucket membership, spot-host
    /// membership, bitwise equality of every spot-usage vector, bitwise
    /// equality of every mirrored hot column, and bitwise equality of
    /// `state_sample` with `state_sample_scan`.
    pub fn check_index(&self) -> Result<(), String> {
        let mut indexed = 0usize;
        for host in &self.hosts {
            let h = host.id;
            if host.is_active() {
                indexed += 1;
                match self.index.free_pes_of(h) {
                    Some(f) if f == host.free_pes() => {}
                    got => {
                        return Err(format!(
                            "host {h}: bucket {got:?} != free_pes {}",
                            host.free_pes()
                        ))
                    }
                }
            } else if self.index.contains(h) {
                return Err(format!("host {h}: inactive but indexed"));
            }
            let oracle = self.spot_used_vec_scan(host);
            if oracle != host.spot_used {
                return Err(format!(
                    "host {h}: spot vector {:?} != oracle {oracle:?}",
                    host.spot_used
                ));
            }
            let n_spot =
                host.vms.iter().filter(|&&v| self.vms[v].is_spot()).count() as u32;
            if n_spot != host.spot_vms {
                return Err(format!("host {h}: spot_vms {} != oracle {n_spot}", host.spot_vms));
            }
            let in_spot_set = self.index.spot_host_ids().any(|id| id == h);
            let should = host.is_active() && n_spot > 0;
            if in_spot_set != should {
                return Err(format!("host {h}: spot-set membership {in_spot_set} != {should}"));
            }
            // SoA host columns mirror the struct's derived accessors.
            let hot = &self.hot;
            if hot.host_active[h] != host.is_active()
                || hot.host_free_pes[h] != host.free_pes()
                || hot.host_free_ram[h].to_bits() != host.free_ram().to_bits()
                || hot.host_free_bw[h].to_bits() != host.free_bw().to_bits()
                || hot.host_free_storage[h].to_bits() != host.free_storage().to_bits()
                || hot.host_spot_used[h] != host.spot_used
                || hot.host_spot_vms[h] != host.spot_vms
            {
                return Err(format!("host {h}: SoA columns diverged from struct"));
            }
        }
        if indexed != self.index.len() {
            return Err(format!(
                "index size {} != active host count {indexed}",
                self.index.len()
            ));
        }
        for vm in &self.vms {
            let v = vm.id;
            let hot = &self.hot;
            if hot.vm_state[v] != vm.state
                || hot.vm_spot[v] != vm.is_spot()
                || hot.vm_pes[v] != vm.spec.pes
                || hot.vm_request[v] != vm.spec.request_vec()
            {
                return Err(format!("vm {v}: SoA columns diverged from struct"));
            }
            let displaced_mirror = if hot.vm_displaced_at[v].is_nan() {
                None
            } else {
                Some(hot.vm_displaced_at[v])
            };
            if displaced_mirror != vm.displaced_at {
                return Err(format!(
                    "vm {v}: displaced mirror {displaced_mirror:?} != {:?}",
                    vm.displaced_at
                ));
            }
            let hibernated_mirror = if hot.vm_hibernated_at[v].is_nan() {
                None
            } else {
                Some(hot.vm_hibernated_at[v])
            };
            if hibernated_mirror != vm.hibernated_at {
                return Err(format!(
                    "vm {v}: hibernated mirror {hibernated_mirror:?} != {:?}",
                    vm.hibernated_at
                ));
            }
        }
        let inc = self.state_sample();
        let scan = self.state_sample_scan();
        if !inc.bits_eq(&scan) {
            return Err(format!("state_sample {inc:?} != scan oracle {scan:?}"));
        }
        Ok(())
    }

    /// Sampling snapshot for the engine's `Sample` tick: an O(1) read of
    /// counters maintained by every VM state transition and host
    /// activate/deactivate/commit/release. When a RAM value has violated
    /// the exactness guard (`engine::soa` module docs), only the two RAM
    /// aggregates fall back to the oracle's host walk; all other fields
    /// stay O(1). Pinned bitwise against [`Self::state_sample_scan`] by
    /// `check_index`, the property tests and a debug assertion on every
    /// engine sample.
    pub fn state_sample(&self) -> StateSample {
        let mut s = self.hot.sample_counts();
        if !self.hot.ram_exact() {
            let mut used = 0.0f64;
            let mut total = 0.0f64;
            for h in &self.hosts {
                if h.is_active() {
                    used += h.used_ram;
                    total += h.spec.ram;
                }
            }
            s.used_ram = used;
            s.total_ram = total;
        }
        s
    }

    /// The pre-SoA walking implementation, retained as the oracle: all
    /// the per-state VM counts plus aggregate host utilization in a
    /// single VM walk and a single host walk. The accumulation order per
    /// counter is identical to the individual queries
    /// ([`Self::count_by_state`] / [`Self::pe_usage`] /
    /// [`Self::ram_usage`]), so sampled series stay bit-identical across
    /// all three generations of the sampler.
    pub fn state_sample_scan(&self) -> StateSample {
        let mut s = StateSample::default();
        for vm in &self.vms {
            let spot = vm.is_spot();
            if vm.displaced_at.is_some() {
                s.displaced += 1;
            }
            match vm.state {
                VmState::Running => {
                    if spot {
                        s.spot_running += 1;
                    } else {
                        s.od_running += 1;
                    }
                }
                VmState::InterruptWarned => {
                    if spot {
                        s.spot_warned += 1;
                    } else {
                        s.od_warned += 1;
                    }
                }
                // The sampled series only charts spot hibernations (the
                // on-demand count of the old query was discarded).
                VmState::Hibernated => {
                    if spot {
                        s.hibernated += 1;
                    }
                }
                VmState::Waiting => {
                    if spot {
                        s.spot_waiting += 1;
                    } else {
                        s.od_waiting += 1;
                    }
                }
                _ => {}
            }
        }
        for h in &self.hosts {
            if h.is_active() {
                s.used_pes += h.used_pes;
                s.total_pes += h.spec.pes;
                s.used_ram += h.used_ram;
                s.total_ram += h.spec.ram;
            } else if h.removed_at.is_some() {
                s.failed_hosts += 1;
            }
        }
        s
    }

    /// Count of VMs in a given state, split (on-demand, spot).
    pub fn count_by_state(&self, state: VmState) -> (usize, usize) {
        let mut od = 0;
        let mut spot = 0;
        for vm in &self.vms {
            if vm.state == state {
                if vm.is_spot() {
                    spot += 1;
                } else {
                    od += 1;
                }
            }
        }
        (od, spot)
    }

    /// Aggregate (used, total) PEs over active hosts.
    pub fn pe_usage(&self) -> (u32, u32) {
        let mut used = 0;
        let mut total = 0;
        for h in self.active_hosts() {
            used += h.used_pes;
            total += h.spec.pes;
        }
        (used, total)
    }

    /// Aggregate (used, total) RAM over active hosts.
    pub fn ram_usage(&self) -> (f64, f64) {
        let mut used = 0.0;
        let mut total = 0.0;
        for h in self.active_hosts() {
            used += h.used_ram;
            total += h.spec.ram;
        }
        (used, total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vm::{SpotConfig, VmSpec};

    fn world_with_host() -> (World, HostId) {
        let mut w = World::new();
        let dc = w.add_datacenter("dc0", 1.0);
        let h = w.add_host(dc, HostSpec::new(8, 1000.0, 16_384.0, 5_000.0, 200_000.0), 0.0);
        (w, h)
    }

    #[test]
    fn arena_ids_are_dense() {
        let (mut w, h) = world_with_host();
        assert_eq!(h, 0);
        let v0 = w.add_vm(Vm::on_demand(0, VmSpec::new(1000.0, 2)));
        let v1 = w.add_vm(Vm::spot(0, VmSpec::new(1000.0, 1), SpotConfig::hibernate()));
        assert_eq!((v0, v1), (0, 1));
        let c = w.add_cloudlet(Cloudlet::new(0, 1000.0, 1).with_vm(v0));
        assert_eq!(c, 0);
        assert_eq!(w.vms[v0].cloudlets, vec![c]);
    }

    #[test]
    fn spot_used_only_counts_spot() {
        let (mut w, h) = world_with_host();
        let od = w.add_vm(Vm::on_demand(0, VmSpec::new(1000.0, 2)));
        let sp = w.add_vm(Vm::spot(0, VmSpec::new(1000.0, 1), SpotConfig::terminate()));
        w.commit_vm(h, od);
        w.commit_vm(h, sp);
        let spot_used = w.spot_used_vec(&w.hosts[h]);
        assert_eq!(spot_used, [1000.0, 512.0, 1000.0, 10_000.0]);
        assert_eq!(spot_used, w.spot_used_vec_scan(&w.hosts[h]));
        assert_eq!(w.spot_host_ids().collect::<Vec<_>>(), vec![h]);
        w.check_index().unwrap();
    }

    #[test]
    fn release_restores_index_state() {
        let (mut w, h) = world_with_host();
        let sp = w.add_vm(Vm::spot(0, VmSpec::new(1000.0, 3), SpotConfig::terminate()));
        w.commit_vm(h, sp);
        assert_eq!(w.hosts[h].free_pes(), 5);
        w.release_vm(h, sp);
        assert_eq!(w.hosts[h].free_pes(), 8);
        assert_eq!(w.spot_used_vec(&w.hosts[h]), [0.0; 4]);
        assert_eq!(w.spot_host_ids().count(), 0);
        w.check_index().unwrap();
    }

    #[test]
    fn fits_with_clearing_accounts_released_resources() {
        let (mut w, h) = world_with_host();
        let sp = w.add_vm(Vm::spot(0, VmSpec::new(1000.0, 6), SpotConfig::terminate()));
        w.commit_vm(h, sp);
        let big = Vm::on_demand(1, VmSpec::new(1000.0, 8));
        assert!(!w.hosts[h].fits(big.spec.pes, big.spec.ram, big.spec.bw, big.spec.storage));
        assert!(w.fits_with_clearing(&w.hosts[h], &big, &[sp]));
    }

    #[test]
    fn indexed_queries_match_scans() {
        let mut w = World::new();
        let dc = w.add_datacenter("dc", 1.0);
        for pes in [2u32, 8, 4, 8, 1] {
            w.add_host(dc, HostSpec::new(pes, 1000.0, 65_536.0, 40_000.0, 1_600_000.0), 0.0);
        }
        // Partially load host 1 so free-PE buckets shift.
        let filler = w.add_vm(Vm::on_demand(0, VmSpec::new(1000.0, 5)));
        w.commit_vm(1, filler);
        let probe = Vm::on_demand(0, VmSpec::new(1000.0, 2));
        assert_eq!(w.first_fit_host(&probe), w.first_fit_host_scan(&probe));
        assert_eq!(w.best_fit_host(&probe), w.best_fit_host_scan(&probe));
        assert_eq!(w.worst_fit_host(&probe), w.worst_fit_host_scan(&probe));
        let (mut a, mut b) = (Vec::new(), Vec::new());
        w.feasible_host_ids(&probe, &mut a);
        w.feasible_host_ids_scan(&probe, &mut b);
        assert_eq!(a, b);
        w.check_index().unwrap();
    }

    #[test]
    fn host_lifecycle_updates_index() {
        let (mut w, h) = world_with_host();
        let probe = Vm::on_demand(0, VmSpec::new(1000.0, 1));
        assert_eq!(w.first_fit_host(&probe), Some(h));
        w.deactivate_host(h, Some(5.0));
        assert_eq!(w.first_fit_host(&probe), None);
        assert_eq!(w.hosts[h].removed_at, Some(5.0));
        w.check_index().unwrap();
        w.activate_host(h, 9.0);
        assert_eq!(w.first_fit_host(&probe), Some(h));
        assert_eq!(w.hosts[h].created_at, 9.0);
        w.check_index().unwrap();
    }

    /// Satellite regression: a duplicate trace ADD (double-activate) must
    /// be a no-op - before the idempotency guard it clobbered
    /// `created_at` and (with incremental counters) would double-add the
    /// host's sampling contribution.
    #[test]
    fn activate_host_is_idempotent() {
        let (mut w, h) = world_with_host();
        let sp = w.add_vm(Vm::spot(0, VmSpec::new(1000.0, 2), SpotConfig::hibernate()));
        w.commit_vm(h, sp);

        // Double-activate on an already-active host.
        w.activate_host(h, 5.0);
        assert_eq!(w.hosts[h].created_at, 0.0, "duplicate ADD must not clobber created_at");
        w.check_index().unwrap();

        // Deactivate, then two ADDs in a row (chaos recovery racing a
        // trace ADD): the first wins, the second is a no-op.
        w.deactivate_host(h, Some(7.0));
        assert_eq!(w.state_sample().failed_hosts, 1);
        w.check_index().unwrap();
        w.activate_host(h, 9.0);
        w.activate_host(h, 11.0);
        assert_eq!(w.hosts[h].created_at, 9.0);
        assert_eq!(w.state_sample().failed_hosts, 0);
        w.check_index().unwrap();

        // Double-deactivate only counts the failure once (the later
        // timestamp wins, matching the pre-guard overwrite semantics).
        w.deactivate_host(h, Some(20.0));
        w.deactivate_host(h, Some(21.0));
        assert_eq!(w.hosts[h].removed_at, Some(21.0));
        assert_eq!(w.state_sample().failed_hosts, 1);
        w.check_index().unwrap();
    }

    /// Satellite regression: when PROBE_LIMIT index probes all fail the
    /// non-PE dimensions, the fallback ordered walk must agree with the
    /// full linear scan - including finding a feasible host past the
    /// probed prefix.
    #[test]
    fn first_fit_fallback_agrees_with_scan_when_probes_exhaust() {
        let mut w = World::new();
        let dc = w.add_datacenter("dc", 1.0);
        // Eleven PE-feasible hosts whose RAM is too small, then one that
        // fits: the 8 probes reject ids 0..=7, the fallback walk starts
        // at 8 and must find id 11 exactly like the scan does.
        for _ in 0..11 {
            w.add_host(dc, HostSpec::new(8, 1000.0, 1_024.0, 5_000.0, 200_000.0), 0.0);
        }
        let big = w.add_host(dc, HostSpec::new(8, 1000.0, 65_536.0, 5_000.0, 200_000.0), 0.0);
        let mut probe = Vm::on_demand(0, VmSpec::new(1000.0, 2));
        probe.spec.ram = 2_048.0;
        assert_eq!(w.first_fit_host(&probe), Some(big));
        assert_eq!(w.first_fit_host(&probe), w.first_fit_host_scan(&probe));

        // No host feasible at all: both sides agree on None.
        probe.spec.ram = 1_000_000.0;
        assert_eq!(w.first_fit_host(&probe), None);
        assert_eq!(w.first_fit_host_scan(&probe), None);
        w.check_index().unwrap();
    }

    /// Satellite regression (off-by-one): when the last rejected probe is
    /// the last active host, the fallback starts at `after + 1 ==
    /// hosts.len()` - an empty range, not a panic.
    #[test]
    fn first_fit_fallback_when_probes_exhaust_on_last_host() {
        let mut w = World::new();
        let dc = w.add_datacenter("dc", 1.0);
        for _ in 0..8 {
            w.add_host(dc, HostSpec::new(8, 1000.0, 1_024.0, 5_000.0, 200_000.0), 0.0);
        }
        let mut probe = Vm::on_demand(0, VmSpec::new(1000.0, 2));
        probe.spec.ram = 2_048.0;
        // Probes reject ids 0..=7; `after` is then the last active host.
        assert_eq!(w.first_fit_host(&probe), None);
        assert_eq!(w.first_fit_host_scan(&probe), None);
        w.check_index().unwrap();
    }

    /// The one-pass sampling snapshot agrees with the individual queries
    /// it replaces, and the O(1) counters agree with the walking oracle
    /// bitwise.
    #[test]
    fn state_sample_matches_individual_queries() {
        let mut w = World::new();
        let dc = w.add_datacenter("dc", 1.0);
        for pes in [8u32, 4, 16] {
            w.add_host(dc, HostSpec::new(pes, 1000.0, 16_384.0, 5_000.0, 200_000.0), 0.0);
        }
        let od = w.add_vm(Vm::on_demand(0, VmSpec::new(1000.0, 2)));
        let sp = w.add_vm(Vm::spot(0, VmSpec::new(1000.0, 1), SpotConfig::hibernate()));
        let hib = w.add_vm(Vm::spot(0, VmSpec::new(1000.0, 1), SpotConfig::hibernate()));
        w.commit_vm(0, od);
        w.commit_vm(1, sp);
        w.transition_vm(od, VmState::Running);
        w.transition_vm(sp, VmState::Running);
        w.transition_vm(sp, VmState::InterruptWarned);
        w.transition_vm(hib, VmState::Running);
        w.transition_vm(hib, VmState::InterruptWarned);
        w.transition_vm(hib, VmState::Hibernated);
        w.mark_displaced(hib, 1.0);
        w.deactivate_host(2, Some(1.0));

        assert!(w.sample_is_incremental(), "dyadic-RAM workload must stay on the O(1) path");
        let s = w.state_sample();
        assert!(s.bits_eq(&w.state_sample_scan()), "incremental sample != scan oracle");
        // Resilience gauges: host 2 is down-after-active, `hib` is
        // displaced and not yet re-placed.
        assert_eq!(s.failed_hosts, 1);
        assert_eq!(s.displaced, 1);
        let (od_run, spot_run) = w.count_by_state(VmState::Running);
        let (od_warn, spot_warn) = w.count_by_state(VmState::InterruptWarned);
        let (_, spot_hib) = w.count_by_state(VmState::Hibernated);
        let (od_wait, spot_wait) = w.count_by_state(VmState::Waiting);
        let (used_pes, total_pes) = w.pe_usage();
        let (used_ram, total_ram) = w.ram_usage();
        assert_eq!(
            (s.od_running, s.spot_running, s.od_warned, s.spot_warned),
            (od_run, spot_run, od_warn, spot_warn)
        );
        assert_eq!((s.hibernated, s.od_waiting, s.spot_waiting), (spot_hib, od_wait, spot_wait));
        assert_eq!((s.used_pes, s.total_pes), (used_pes, total_pes));
        assert_eq!((s.used_ram.to_bits(), s.total_ram.to_bits()), (used_ram.to_bits(), total_ram.to_bits()));
    }

    /// A terminal transition clears a pending displacement so the gauge
    /// cannot leak (world-level twin of the engine lifecycle test).
    #[test]
    fn terminal_transition_clears_displacement() {
        let (mut w, h) = world_with_host();
        let sp = w.add_vm(Vm::spot(0, VmSpec::new(1000.0, 1), SpotConfig::hibernate()));
        w.commit_vm(h, sp);
        w.transition_vm(sp, VmState::Running);
        w.transition_vm(sp, VmState::InterruptWarned);
        w.release_vm(h, sp);
        w.transition_vm(sp, VmState::Hibernated);
        w.mark_displaced(sp, 2.0);
        assert_eq!(w.state_sample().displaced, 1);
        w.transition_vm(sp, VmState::Terminated);
        assert_eq!(w.vms[sp].displaced_at, None);
        assert_eq!(w.state_sample().displaced, 0);
        w.check_index().unwrap();
    }

    /// Non-dyadic RAM values trip the exactness guard: the sample
    /// degrades to a host walk for the two RAM fields only and stays
    /// bitwise equal to the oracle.
    #[test]
    fn state_sample_falls_back_to_walk_on_non_dyadic_ram() {
        let mut w = World::new();
        let dc = w.add_datacenter("dc", 1.0);
        w.add_host(dc, HostSpec::new(8, 1000.0, 10_000.1, 5_000.0, 200_000.0), 0.0);
        assert!(!w.sample_is_incremental());
        let mut vm = Vm::on_demand(0, VmSpec::new(1000.0, 1));
        vm.spec.ram = 333.3;
        let v = w.add_vm(vm);
        w.commit_vm(0, v);
        w.transition_vm(v, VmState::Running);
        let s = w.state_sample();
        assert!(s.bits_eq(&w.state_sample_scan()));
        w.check_index().unwrap();
    }

    #[test]
    #[should_panic(expected = "unknown vm")]
    fn cloudlet_requires_valid_vm() {
        let (mut w, _) = world_with_host();
        w.add_cloudlet(Cloudlet::new(0, 100.0, 1).with_vm(5));
    }
}
