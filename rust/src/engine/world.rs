//! The world: arena storage for all simulation entities, plus the
//! incremental placement index the allocation hot path queries.
//!
//! CloudSim Plus wires entities together with object references; in Rust an
//! arena (id-indexed vectors) gives the same topology without shared
//! mutable ownership, and the allocation policies get a cheap immutable
//! view (`&World`) while the engine mutates through it between policy
//! calls.
//!
//! All placement-relevant mutation goes through [`World::commit_vm`],
//! [`World::release_vm`], [`World::activate_host`] and
//! [`World::deactivate_host`]: these keep the [`PlacementIndex`]
//! (free-PE buckets, spot-host set) and each host's O(1) spot-usage
//! vector consistent with the arena. The raw [`Host::commit`] /
//! [`Host::release`] accounting primitives are still public for
//! host-local unit tests but bypass the index - production code and
//! policies must use the `World` methods. Every indexed query has a
//! `_scan` twin that recomputes the answer with the pre-index linear
//! scan; the property/parity tests pin the two together, and the decision
//! benches use the scans as the baseline.

use crate::cloudlet::{Cloudlet, CloudletId};
use crate::infra::{Datacenter, DcId, Host, HostId, HostSpec, HostState};
use crate::vm::{Vm, VmId, VmState};

use super::index::PlacementIndex;

/// One-pass sampling snapshot (see [`World::state_sample`]).
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct StateSample {
    pub od_running: usize,
    pub spot_running: usize,
    pub od_warned: usize,
    pub spot_warned: usize,
    /// Spot VMs currently hibernated (on-demand VMs never hibernate).
    pub hibernated: usize,
    pub od_waiting: usize,
    pub spot_waiting: usize,
    pub used_pes: u32,
    pub total_pes: u32,
    pub used_ram: f64,
    pub total_ram: f64,
    /// Hosts currently down after having been active (trace removals and
    /// chaos crashes); dormant not-yet-added trace machines don't count.
    pub failed_hosts: usize,
    /// VMs currently displaced from a host (hibernated or requeued after
    /// an eviction) and not yet re-placed.
    pub displaced: usize,
}

/// Arena of datacenters, hosts, VMs and cloudlets.
#[derive(Default)]
pub struct World {
    pub datacenters: Vec<Datacenter>,
    pub hosts: Vec<Host>,
    pub vms: Vec<Vm>,
    pub cloudlets: Vec<Cloudlet>,
    index: PlacementIndex,
}

impl World {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_datacenter(&mut self, name: &str, scheduling_interval: f64) -> DcId {
        let id = self.datacenters.len();
        self.datacenters.push(Datacenter::new(id, name, scheduling_interval));
        id
    }

    /// Register a host (active immediately at `now`).
    pub fn add_host(&mut self, dc: DcId, spec: HostSpec, now: f64) -> HostId {
        let id = self.hosts.len();
        self.hosts.push(Host::new(id, dc, spec, now));
        self.datacenters[dc].hosts.push(id);
        self.index.insert(id, spec.pes);
        id
    }

    /// Register a VM; the caller (engine/broker) schedules its submission.
    pub fn add_vm(&mut self, mut vm: Vm) -> VmId {
        let id = self.vms.len();
        vm.id = id;
        self.vms.push(vm);
        id
    }

    /// Register a cloudlet bound to an existing VM.
    pub fn add_cloudlet(&mut self, mut cl: Cloudlet) -> CloudletId {
        assert!(cl.vm < self.vms.len(), "cloudlet bound to unknown vm {}", cl.vm);
        let id = self.cloudlets.len();
        cl.id = id;
        self.vms[cl.vm].cloudlets.push(id);
        self.cloudlets.push(cl);
        id
    }

    // ------------------------------------------------------------------
    // index-maintaining mutation API
    // ------------------------------------------------------------------

    /// Commit `vm`'s requested resources on `host`, keeping the placement
    /// index and the host's spot-usage vector in sync.
    pub fn commit_vm(&mut self, host: HostId, vm: VmId) {
        let spec = self.vms[vm].spec;
        let is_spot = self.vms[vm].is_spot();
        self.hosts[host].commit(vm, spec.pes, spec.ram, spec.bw, spec.storage);
        if self.hosts[host].is_active() {
            self.index.update_free(host, self.hosts[host].free_pes());
        }
        if is_spot {
            self.refresh_spot(host);
        }
    }

    /// Release `vm`'s resources from `host` (deallocation, interruption,
    /// eviction), keeping the index and spot vector in sync.
    pub fn release_vm(&mut self, host: HostId, vm: VmId) {
        let spec = self.vms[vm].spec;
        let is_spot = self.vms[vm].is_spot();
        self.hosts[host].release(vm, spec.pes, spec.ram, spec.bw, spec.storage);
        if self.hosts[host].is_active() {
            self.index.update_free(host, self.hosts[host].free_pes());
        }
        if is_spot {
            self.refresh_spot(host);
        }
    }

    /// Mark a host active (host add / trace ADD event) and index it.
    pub fn activate_host(&mut self, h: HostId, now: f64) {
        let host = &mut self.hosts[h];
        host.state = HostState::Active;
        host.created_at = now;
        host.removed_at = None;
        let free = host.free_pes();
        let has_spot = host.spot_vms > 0;
        self.index.insert(h, free);
        self.index.set_spot(h, has_spot);
    }

    /// Mark a host removed/dormant and drop it from the index.
    /// `removed_at` is `None` for hosts that were never active (dormant
    /// trace machines awaiting their ADD event).
    pub fn deactivate_host(&mut self, h: HostId, removed_at: Option<f64>) {
        let host = &mut self.hosts[h];
        host.state = HostState::Removed;
        if removed_at.is_some() {
            host.removed_at = removed_at;
        }
        self.index.remove(h);
    }

    /// Rebuild `host`'s spot-usage vector by walking its VM list in
    /// allocation order - the exact summation order of the scan oracle,
    /// so O(1) reads stay bitwise equal to a from-scratch recompute.
    fn refresh_spot(&mut self, host: HostId) {
        let mut acc = [0.0f64; 4];
        let mut n = 0u32;
        for &vid in &self.hosts[host].vms {
            let vm = &self.vms[vid];
            if vm.is_spot() {
                let r = vm.spec.request_vec();
                for d in 0..4 {
                    acc[d] += r[d];
                }
                n += 1;
            }
        }
        let h = &mut self.hosts[host];
        h.spot_used = acc;
        h.spot_vms = n;
        self.index.set_spot(host, n > 0);
    }

    // ------------------------------------------------------------------
    // indexed placement queries (with `_scan` oracles)
    // ------------------------------------------------------------------

    /// First-Fit: lowest-id active host where `vm` fits.
    ///
    /// Hybrid strategy: a few O(buckets x log H) index probes (the common
    /// case hits on the first one), then - if many PE-feasible hosts keep
    /// failing the RAM/BW/storage dimensions - a plain ordered walk over
    /// the remaining id range, so the degenerate case is never
    /// asymptotically worse than the pre-index linear scan.
    pub fn first_fit_host(&self, vm: &Vm) -> Option<HostId> {
        let s = vm.spec;
        const PROBE_LIMIT: usize = 8;
        let mut after: Option<HostId> = None;
        for _ in 0..PROBE_LIMIT {
            match self.index.first_feasible_after(s.pes, after) {
                None => return None,
                Some(id) if self.hosts[id].fits(s.pes, s.ram, s.bw, s.storage) => {
                    return Some(id)
                }
                Some(id) => after = Some(id),
            }
        }
        let start = after.map_or(0, |a| a + 1);
        self.hosts[start..]
            .iter()
            .find(|h| h.fits(s.pes, s.ram, s.bw, s.storage))
            .map(|h| h.id)
    }

    /// Pre-index First-Fit linear scan (oracle / bench baseline).
    pub fn first_fit_host_scan(&self, vm: &Vm) -> Option<HostId> {
        let s = vm.spec;
        self.active_hosts().find(|h| h.fits(s.pes, s.ram, s.bw, s.storage)).map(|h| h.id)
    }

    /// Best-Fit: feasible host with the fewest free PEs (ties: lowest id).
    pub fn best_fit_host(&self, vm: &Vm) -> Option<HostId> {
        let s = vm.spec;
        self.index.best_fit(s.pes, |id| self.hosts[id].fits(s.pes, s.ram, s.bw, s.storage))
    }

    /// Pre-index Best-Fit linear scan (oracle / bench baseline).
    pub fn best_fit_host_scan(&self, vm: &Vm) -> Option<HostId> {
        let s = vm.spec;
        self.active_hosts()
            .filter(|h| h.fits(s.pes, s.ram, s.bw, s.storage))
            .min_by_key(|h| h.free_pes())
            .map(|h| h.id)
    }

    /// Worst-Fit: feasible host with the most free PEs (ties: highest id,
    /// matching `max_by_key` over the id-ascending scan).
    pub fn worst_fit_host(&self, vm: &Vm) -> Option<HostId> {
        let s = vm.spec;
        self.index.worst_fit(s.pes, |id| self.hosts[id].fits(s.pes, s.ram, s.bw, s.storage))
    }

    /// Pre-index Worst-Fit linear scan (oracle / bench baseline).
    pub fn worst_fit_host_scan(&self, vm: &Vm) -> Option<HostId> {
        let s = vm.spec;
        self.active_hosts()
            .filter(|h| h.fits(s.pes, s.ram, s.bw, s.storage))
            .max_by_key(|h| h.free_pes())
            .map(|h| h.id)
    }

    /// All hosts where `vm` fits, ascending by id (HLEM phase-1 feasible
    /// list). Clears and fills `out`; only PE-feasible buckets are probed.
    pub fn feasible_host_ids(&self, vm: &Vm, out: &mut Vec<HostId>) {
        let s = vm.spec;
        self.index.feasible_into(
            s.pes,
            |id| self.hosts[id].fits(s.pes, s.ram, s.bw, s.storage),
            out,
        );
    }

    /// Pre-index feasible-list linear scan (oracle / bench baseline).
    pub fn feasible_host_ids_scan(&self, vm: &Vm, out: &mut Vec<HostId>) {
        let s = vm.spec;
        out.clear();
        out.extend(
            self.active_hosts().filter(|h| h.fits(s.pes, s.ram, s.bw, s.storage)).map(|h| h.id),
        );
    }

    /// Active hosts carrying at least one spot VM, ascending by id - the
    /// only hosts the preemption scan can ever pick victims from.
    pub fn spot_host_ids(&self) -> impl Iterator<Item = HostId> + '_ {
        self.index.spot_host_ids()
    }

    /// Active (placeable) hosts.
    pub fn active_hosts(&self) -> impl Iterator<Item = &Host> {
        self.hosts.iter().filter(|h| h.is_active())
    }

    /// Resources on `host` currently held by spot VMs, in artifact
    /// dimension order (CPU MIPS, RAM, BW, storage) - Eq. (10) numerator.
    /// O(1): reads the incrementally maintained host vector.
    pub fn spot_used_vec(&self, host: &Host) -> [f64; 4] {
        host.spot_used
    }

    /// Pre-index spot-usage walk (oracle for [`Self::spot_used_vec`]).
    pub fn spot_used_vec_scan(&self, host: &Host) -> [f64; 4] {
        let mut acc = [0.0; 4];
        for &vid in &host.vms {
            let vm = &self.vms[vid];
            if vm.is_spot() {
                let r = vm.spec.request_vec();
                for d in 0..4 {
                    acc[d] += r[d];
                }
            }
        }
        acc
    }

    /// Spot VMs on `host` that may be interrupted at `now`
    /// (running, past min runtime, not already warned). Clears and fills
    /// `out` - the allocation-free twin of [`Self::interruptible_spots`].
    pub fn interruptible_spots_into(&self, host: &Host, now: f64, out: &mut Vec<VmId>) {
        out.clear();
        out.extend(host.vms.iter().copied().filter(|&v| self.vms[v].interruptible(now)));
    }

    /// Allocating convenience wrapper around
    /// [`Self::interruptible_spots_into`].
    pub fn interruptible_spots(&self, host: &Host, now: f64) -> Vec<VmId> {
        let mut out = Vec::new();
        self.interruptible_spots_into(host, now, &mut out);
        out
    }

    /// Whether `vm` would fit on `host` if the given spot VMs were removed.
    pub fn fits_with_clearing(&self, host: &Host, vm: &Vm, cleared: &[VmId]) -> bool {
        let mut pes = host.free_pes();
        let mut ram = host.free_ram();
        let mut bw = host.free_bw();
        let mut st = host.free_storage();
        for &v in cleared {
            let s = &self.vms[v].spec;
            pes += s.pes;
            ram += s.ram;
            bw += s.bw;
            st += s.storage;
        }
        host.is_active()
            && pes >= vm.spec.pes
            && ram + 1e-9 >= vm.spec.ram
            && bw + 1e-9 >= vm.spec.bw
            && st + 1e-9 >= vm.spec.storage
    }

    /// Verify the incremental index against a recompute-from-scratch
    /// oracle (test/debug support; O(hosts x vms)). Checks bucket
    /// membership, spot-host membership and bitwise equality of every
    /// spot-usage vector.
    pub fn check_index(&self) -> Result<(), String> {
        let mut indexed = 0usize;
        for host in &self.hosts {
            let h = host.id;
            if host.is_active() {
                indexed += 1;
                match self.index.free_pes_of(h) {
                    Some(f) if f == host.free_pes() => {}
                    got => {
                        return Err(format!(
                            "host {h}: bucket {got:?} != free_pes {}",
                            host.free_pes()
                        ))
                    }
                }
            } else if self.index.contains(h) {
                return Err(format!("host {h}: inactive but indexed"));
            }
            let oracle = self.spot_used_vec_scan(host);
            if oracle != host.spot_used {
                return Err(format!(
                    "host {h}: spot vector {:?} != oracle {oracle:?}",
                    host.spot_used
                ));
            }
            let n_spot =
                host.vms.iter().filter(|&&v| self.vms[v].is_spot()).count() as u32;
            if n_spot != host.spot_vms {
                return Err(format!("host {h}: spot_vms {} != oracle {n_spot}", host.spot_vms));
            }
            let in_spot_set = self.index.spot_host_ids().any(|id| id == h);
            let should = host.is_active() && n_spot > 0;
            if in_spot_set != should {
                return Err(format!("host {h}: spot-set membership {in_spot_set} != {should}"));
            }
        }
        if indexed != self.index.len() {
            return Err(format!(
                "index size {} != active host count {indexed}",
                self.index.len()
            ));
        }
        Ok(())
    }

    /// One-pass sampling snapshot for the engine's `Sample` tick: all the
    /// per-state VM counts plus aggregate host utilization in a single VM
    /// walk and a single host walk. Replaces four [`Self::count_by_state`]
    /// walks + [`Self::pe_usage`] + [`Self::ram_usage`] per sample; the
    /// accumulation order per counter is identical to the individual
    /// queries, so sampled series stay bit-identical.
    pub fn state_sample(&self) -> StateSample {
        let mut s = StateSample::default();
        for vm in &self.vms {
            let spot = vm.is_spot();
            if vm.displaced_at.is_some() {
                s.displaced += 1;
            }
            match vm.state {
                VmState::Running => {
                    if spot {
                        s.spot_running += 1;
                    } else {
                        s.od_running += 1;
                    }
                }
                VmState::InterruptWarned => {
                    if spot {
                        s.spot_warned += 1;
                    } else {
                        s.od_warned += 1;
                    }
                }
                // The sampled series only charts spot hibernations (the
                // on-demand count of the old query was discarded).
                VmState::Hibernated => {
                    if spot {
                        s.hibernated += 1;
                    }
                }
                VmState::Waiting => {
                    if spot {
                        s.spot_waiting += 1;
                    } else {
                        s.od_waiting += 1;
                    }
                }
                _ => {}
            }
        }
        for h in &self.hosts {
            if h.is_active() {
                s.used_pes += h.used_pes;
                s.total_pes += h.spec.pes;
                s.used_ram += h.used_ram;
                s.total_ram += h.spec.ram;
            } else if h.removed_at.is_some() {
                s.failed_hosts += 1;
            }
        }
        s
    }

    /// Count of VMs in a given state, split (on-demand, spot).
    pub fn count_by_state(&self, state: VmState) -> (usize, usize) {
        let mut od = 0;
        let mut spot = 0;
        for vm in &self.vms {
            if vm.state == state {
                if vm.is_spot() {
                    spot += 1;
                } else {
                    od += 1;
                }
            }
        }
        (od, spot)
    }

    /// Aggregate (used, total) PEs over active hosts.
    pub fn pe_usage(&self) -> (u32, u32) {
        let mut used = 0;
        let mut total = 0;
        for h in self.active_hosts() {
            used += h.used_pes;
            total += h.spec.pes;
        }
        (used, total)
    }

    /// Aggregate (used, total) RAM over active hosts.
    pub fn ram_usage(&self) -> (f64, f64) {
        let mut used = 0.0;
        let mut total = 0.0;
        for h in self.active_hosts() {
            used += h.used_ram;
            total += h.spec.ram;
        }
        (used, total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vm::{SpotConfig, VmSpec};

    fn world_with_host() -> (World, HostId) {
        let mut w = World::new();
        let dc = w.add_datacenter("dc0", 1.0);
        let h = w.add_host(dc, HostSpec::new(8, 1000.0, 16_384.0, 5_000.0, 200_000.0), 0.0);
        (w, h)
    }

    #[test]
    fn arena_ids_are_dense() {
        let (mut w, h) = world_with_host();
        assert_eq!(h, 0);
        let v0 = w.add_vm(Vm::on_demand(0, VmSpec::new(1000.0, 2)));
        let v1 = w.add_vm(Vm::spot(0, VmSpec::new(1000.0, 1), SpotConfig::hibernate()));
        assert_eq!((v0, v1), (0, 1));
        let c = w.add_cloudlet(Cloudlet::new(0, 1000.0, 1).with_vm(v0));
        assert_eq!(c, 0);
        assert_eq!(w.vms[v0].cloudlets, vec![c]);
    }

    #[test]
    fn spot_used_only_counts_spot() {
        let (mut w, h) = world_with_host();
        let od = w.add_vm(Vm::on_demand(0, VmSpec::new(1000.0, 2)));
        let sp = w.add_vm(Vm::spot(0, VmSpec::new(1000.0, 1), SpotConfig::terminate()));
        w.commit_vm(h, od);
        w.commit_vm(h, sp);
        let spot_used = w.spot_used_vec(&w.hosts[h]);
        assert_eq!(spot_used, [1000.0, 512.0, 1000.0, 10_000.0]);
        assert_eq!(spot_used, w.spot_used_vec_scan(&w.hosts[h]));
        assert_eq!(w.spot_host_ids().collect::<Vec<_>>(), vec![h]);
        w.check_index().unwrap();
    }

    #[test]
    fn release_restores_index_state() {
        let (mut w, h) = world_with_host();
        let sp = w.add_vm(Vm::spot(0, VmSpec::new(1000.0, 3), SpotConfig::terminate()));
        w.commit_vm(h, sp);
        assert_eq!(w.hosts[h].free_pes(), 5);
        w.release_vm(h, sp);
        assert_eq!(w.hosts[h].free_pes(), 8);
        assert_eq!(w.spot_used_vec(&w.hosts[h]), [0.0; 4]);
        assert_eq!(w.spot_host_ids().count(), 0);
        w.check_index().unwrap();
    }

    #[test]
    fn fits_with_clearing_accounts_released_resources() {
        let (mut w, h) = world_with_host();
        let sp = w.add_vm(Vm::spot(0, VmSpec::new(1000.0, 6), SpotConfig::terminate()));
        w.commit_vm(h, sp);
        let big = Vm::on_demand(1, VmSpec::new(1000.0, 8));
        assert!(!w.hosts[h].fits(big.spec.pes, big.spec.ram, big.spec.bw, big.spec.storage));
        assert!(w.fits_with_clearing(&w.hosts[h], &big, &[sp]));
    }

    #[test]
    fn indexed_queries_match_scans() {
        let mut w = World::new();
        let dc = w.add_datacenter("dc", 1.0);
        for pes in [2u32, 8, 4, 8, 1] {
            w.add_host(dc, HostSpec::new(pes, 1000.0, 65_536.0, 40_000.0, 1_600_000.0), 0.0);
        }
        // Partially load host 1 so free-PE buckets shift.
        let filler = w.add_vm(Vm::on_demand(0, VmSpec::new(1000.0, 5)));
        w.commit_vm(1, filler);
        let probe = Vm::on_demand(0, VmSpec::new(1000.0, 2));
        assert_eq!(w.first_fit_host(&probe), w.first_fit_host_scan(&probe));
        assert_eq!(w.best_fit_host(&probe), w.best_fit_host_scan(&probe));
        assert_eq!(w.worst_fit_host(&probe), w.worst_fit_host_scan(&probe));
        let (mut a, mut b) = (Vec::new(), Vec::new());
        w.feasible_host_ids(&probe, &mut a);
        w.feasible_host_ids_scan(&probe, &mut b);
        assert_eq!(a, b);
        w.check_index().unwrap();
    }

    #[test]
    fn host_lifecycle_updates_index() {
        let (mut w, h) = world_with_host();
        let probe = Vm::on_demand(0, VmSpec::new(1000.0, 1));
        assert_eq!(w.first_fit_host(&probe), Some(h));
        w.deactivate_host(h, Some(5.0));
        assert_eq!(w.first_fit_host(&probe), None);
        assert_eq!(w.hosts[h].removed_at, Some(5.0));
        w.check_index().unwrap();
        w.activate_host(h, 9.0);
        assert_eq!(w.first_fit_host(&probe), Some(h));
        assert_eq!(w.hosts[h].created_at, 9.0);
        w.check_index().unwrap();
    }

    /// The one-pass sampling snapshot agrees with the individual queries
    /// it replaces.
    #[test]
    fn state_sample_matches_individual_queries() {
        let mut w = World::new();
        let dc = w.add_datacenter("dc", 1.0);
        for pes in [8u32, 4, 16] {
            w.add_host(dc, HostSpec::new(pes, 1000.0, 16_384.0, 5_000.0, 200_000.0), 0.0);
        }
        let od = w.add_vm(Vm::on_demand(0, VmSpec::new(1000.0, 2)));
        let sp = w.add_vm(Vm::spot(0, VmSpec::new(1000.0, 1), SpotConfig::hibernate()));
        let hib = w.add_vm(Vm::spot(0, VmSpec::new(1000.0, 1), SpotConfig::hibernate()));
        w.commit_vm(0, od);
        w.commit_vm(1, sp);
        w.vms[od].transition(VmState::Running);
        w.vms[sp].transition(VmState::Running);
        w.vms[sp].transition(VmState::InterruptWarned);
        w.vms[hib].transition(VmState::Running);
        w.vms[hib].transition(VmState::InterruptWarned);
        w.vms[hib].transition(VmState::Hibernated);
        w.vms[hib].displaced_at = Some(1.0);
        w.deactivate_host(2, Some(1.0));

        let s = w.state_sample();
        // Resilience gauges: host 2 is down-after-active, `hib` is
        // displaced and not yet re-placed.
        assert_eq!(s.failed_hosts, 1);
        assert_eq!(s.displaced, 1);
        let (od_run, spot_run) = w.count_by_state(VmState::Running);
        let (od_warn, spot_warn) = w.count_by_state(VmState::InterruptWarned);
        let (_, spot_hib) = w.count_by_state(VmState::Hibernated);
        let (od_wait, spot_wait) = w.count_by_state(VmState::Waiting);
        let (used_pes, total_pes) = w.pe_usage();
        let (used_ram, total_ram) = w.ram_usage();
        assert_eq!(
            (s.od_running, s.spot_running, s.od_warned, s.spot_warned),
            (od_run, spot_run, od_warn, spot_warn)
        );
        assert_eq!((s.hibernated, s.od_waiting, s.spot_waiting), (spot_hib, od_wait, spot_wait));
        assert_eq!((s.used_pes, s.total_pes), (used_pes, total_pes));
        assert_eq!((s.used_ram.to_bits(), s.total_ram.to_bits()), (used_ram.to_bits(), total_ram.to_bits()));
    }

    #[test]
    #[should_panic(expected = "unknown vm")]
    fn cloudlet_requires_valid_vm() {
        let (mut w, _) = world_with_host();
        w.add_cloudlet(Cloudlet::new(0, 100.0, 1).with_vm(5));
    }
}
