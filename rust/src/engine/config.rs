//! Engine configuration: the knobs the paper exposes on `CloudSim`,
//! `Datacenter` and `DatacenterBrokerDynamic` (Listings 2, 4, 5), plus the
//! victim-selection ablation flag (paper §IX future work).

use crate::cloudlet::SchedulerKind;

/// How interruption victims are chosen among a host's spot VMs.
///
/// The paper's implementation is "non-deterministic ... based solely on the
/// VM list associated with a host" (§IX) = [`VictimPolicy::ListOrder`].
/// The two alternatives implement the future-work suggestion of targeted
/// deallocation and are ablated in `benches/ablation_victim.rs`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VictimPolicy {
    /// Host VM-list order (allocation order) - the paper's behavior.
    ListOrder,
    /// Prefer the most recently started spot VMs (least sunk work lost).
    Youngest,
    /// Prefer the smallest VMs first (minimizes collateral interruptions
    /// only if small VMs suffice).
    SmallestFirst,
}

impl VictimPolicy {
    /// Stable kebab-case name (CLI vocabulary, sweep-axis values and
    /// artifact columns).
    pub fn name(&self) -> &'static str {
        match self {
            VictimPolicy::ListOrder => "list-order",
            VictimPolicy::Youngest => "youngest",
            VictimPolicy::SmallestFirst => "smallest-first",
        }
    }

    /// Parse one victim-policy name (`--axis victim=...` vocabulary).
    pub fn parse(s: &str) -> Result<VictimPolicy, String> {
        match s.trim() {
            "list-order" => Ok(VictimPolicy::ListOrder),
            "youngest" => Ok(VictimPolicy::Youngest),
            "smallest-first" => Ok(VictimPolicy::SmallestFirst),
            other => Err(format!(
                "unknown victim policy '{other}' (expected list-order | youngest | \
                 smallest-first)"
            )),
        }
    }
}

/// Engine-wide configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct EngineConfig {
    /// Minimal time between events (`new CloudSim(0.5)`).
    pub min_dt: f64,
    /// Cloudlet progress update period (`setSchedulingInterval(1)`).
    pub scheduling_interval: f64,
    /// Metrics sampling period (active-instance time series).
    pub sample_interval: f64,
    /// Delay between a VM going idle and its destruction
    /// (`setVmDestructionDelay(1)`).
    pub vm_destruction_delay: f64,
    /// Cloudlet scheduling discipline on every VM.
    pub scheduler: SchedulerKind,
    /// Broker retry period for waiting/hibernated VMs, in addition to
    /// deallocation-triggered retries (paper §VII-B(b): a clockTickListener
    /// "could be used for periodic checks").
    pub retry_interval: f64,
    /// Minimum time a hibernated spot stays parked before resubmission
    /// (paper §IV-B: "hibernated instances must be resubmitted
    /// *periodically*" - immediate same-instant resumption would make the
    /// interruption a no-op and ping-pong the same victim).
    pub resubmit_cooldown: f64,
    /// Cap on recorded per-VM lifecycle events (observability vs memory).
    pub max_log_events: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            min_dt: 0.1,
            scheduling_interval: 1.0,
            sample_interval: 10.0,
            vm_destruction_delay: 0.0,
            scheduler: SchedulerKind::TimeShared,
            retry_interval: 30.0,
            resubmit_cooldown: 30.0,
            max_log_events: 100_000,
        }
    }
}

impl EngineConfig {
    pub fn validate(&self) -> Result<(), String> {
        if self.min_dt < 0.0 || !self.min_dt.is_finite() {
            return Err("min_dt must be finite and >= 0".into());
        }
        if self.scheduling_interval <= 0.0 {
            return Err("scheduling_interval must be > 0".into());
        }
        if self.sample_interval <= 0.0 {
            return Err("sample_interval must be > 0".into());
        }
        if self.vm_destruction_delay < 0.0 {
            return Err("vm_destruction_delay must be >= 0".into());
        }
        if self.retry_interval <= 0.0 {
            return Err("retry_interval must be > 0".into());
        }
        if self.resubmit_cooldown < 0.0 {
            return Err("resubmit_cooldown must be >= 0".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        assert!(EngineConfig::default().validate().is_ok());
    }

    #[test]
    fn victim_policy_names_round_trip() {
        for p in [VictimPolicy::ListOrder, VictimPolicy::Youngest, VictimPolicy::SmallestFirst] {
            assert_eq!(VictimPolicy::parse(p.name()).unwrap(), p);
        }
        assert!(VictimPolicy::parse("oldest").is_err());
    }

    #[test]
    fn rejects_bad_intervals() {
        let mut c = EngineConfig::default();
        c.scheduling_interval = 0.0;
        assert!(c.validate().is_err());
        let mut c = EngineConfig::default();
        c.min_dt = f64::NAN;
        assert!(c.validate().is_err());
    }
}
