//! Event tags (the `CloudSimTags` role, paper §V-A(d)): every event type
//! the engine dispatches on, with its payload.

use crate::cloudlet::CloudletId;
use crate::infra::HostId;
use crate::vm::VmId;

/// Event type + payload processed by the engine.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Tag {
    /// Broker submits a VM (fires at its submission delay).
    SubmitVm(VmId),
    /// Attempt (or re-attempt) to place a VM.
    TryAllocate(VmId),
    /// A persistent request's waiting time elapsed.
    WaitingExpired(VmId),
    /// Warning period over: actually interrupt the spot VM.
    SpotInterrupt(VmId),
    /// A hibernated VM exceeded its hibernation timeout.
    HibernationTimeout(VmId),
    /// Destruction-delay check after a VM went idle.
    VmIdleCheck(VmId),
    /// Cloudlet submission (binds to its VM, may start immediately).
    SubmitCloudlet(CloudletId),
    /// Periodic cloudlet progress update (scheduling interval).
    ProgressTick,
    /// Periodic metrics sample.
    Sample,
    /// Trace machine event: host becomes active.
    HostAdd(HostId),
    /// Trace machine event: host removed (evicts its VMs).
    HostRemove(HostId),
    /// Chaos host fault: crash (evicts like a removal, but tracked so the
    /// paired recovery can reactivate exactly the crashed host).
    ChaosHostCrash(HostId),
    /// Chaos host fault: repair completed, host comes back.
    ChaosHostRecover(HostId),
    /// Chaos reclaim storm `k` (index into the engine's storm table).
    ChaosStorm(usize),
    /// Drain retries deferred by a broker outage window.
    ChaosRetryDrain,
    /// Spot price crossed the bid level: crossing `k` of the compiled
    /// market schedule (up = out-bid reclaims, down = retry drain).
    MarketCrossing(usize),
    /// Recovery checkpoint snapshot at the start of a warning window
    /// (captures the progress a later interruption can carry over).
    RecoveryCheckpoint(VmId),
    /// Batched reassignment matching over currently displaced VMs
    /// (coalesces one storm's victims into a single matching problem).
    RecoveryReassign,
    /// A displaced VM's checkpoint transfer to the chosen host finished:
    /// resume it there (or count a failed migration if it no longer fits).
    RecoveryMigrate(VmId, HostId),
    /// Hard stop marker.
    End,
}
