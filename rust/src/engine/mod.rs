//! The simulation engine: ties the DES kernel, the world, the broker, the
//! allocation policy and the progress backend together and implements the
//! full spot-instance lifecycle of the paper (Figs. 2-4, §V).
//!
//! Event flow (one placement attempt):
//!
//! ```text
//! SubmitVm ─► TryAllocate ─┬─ policy.select_host ──► place (Running)
//!                          └─ none:
//!                             ├─ on-demand? policy.select_preemption
//!                             │    ─► warn victims ─► SpotInterrupt
//!                             │        (warning_time later) ─► hibernate/
//!                             │        terminate ─► retry_pending
//!                             └─ persistent? wait (WaitingExpired armed)
//!                                else Failed
//! ```
//!
//! Cloudlet progress runs through a swappable [`progress::ProgressBackend`]
//! over parallel arrays (the paper's measured bottleneck, see §Perf).
//!
//! # The placement index (§Perf: decision hot path)
//!
//! Allocation decisions run on the world-level incremental index
//! ([`index::PlacementIndex`], maintained by [`World::commit_vm`] /
//! [`World::release_vm`] / host activate/deactivate) instead of
//! re-deriving cluster state per decision:
//!
//! - free-PE buckets answer First/Best/Worst-Fit and HLEM's phase-1
//!   feasibility filter by probing only PE-feasible hosts
//!   (O(log H) maintenance per commit/release);
//! - each host carries an O(1) spot-usage vector (`Host::spot_used`),
//!   refreshed on spot commit/release/interrupt by re-walking that one
//!   host's VM list - previously `World::spot_used_vec` walked every VM
//!   of every candidate on every HLEM decision;
//! - the preemption scan enumerates only hosts that actually carry spot
//!   VMs (`World::spot_host_ids`), with reusable victim-scratch buffers
//!   replacing the per-host `interruptible_spots` allocation.
//!
//! Query order reproduces the pre-index linear scans bit-identically
//! (deterministic tie-breaks on host id); the `_scan` oracles stay in
//! [`World`] and `tests/placement_parity.rs` + `tests/properties.rs` pin
//! indexed and scanned decisions together. Decision latency at
//! 100/1 000/10 000 hosts, scan vs. index, is measured by
//! `benches/perf_engine.rs`, which writes the trajectory to
//! `BENCH_engine.json` at the repo root (regenerate with
//! `cargo bench --bench perf_engine`; CI refreshes and validates it).
//!
//! # SoA hot state + O(1) sampling (§Perf: million-entity runs)
//!
//! The fields placement and sampling actually read are mirrored from the
//! arena structs into struct-of-arrays columns (`engine::soa`), and the
//! per-sample metrics are fully incremental: `World::state_sample` is an
//! O(1) read of counters maintained at every VM state transition
//! (`World::transition_vm`), displacement mark/clear and host
//! activate/deactivate/commit/release - no VM or host walks on the
//! sample path. The walking implementation survives as
//! `World::state_sample_scan`, pinned bitwise by `World::check_index`,
//! the property tests and a per-sample `debug_assert` here; RAM
//! aggregates carry an exactness guard (see `engine::soa`) so the
//! incremental sums match the oracle's fold bit-for-bit or degrade to a
//! two-field host walk. The 100k-host / 1M-VM scale tier in
//! `benches/perf_engine.rs` records cells/sec and max RSS into
//! `BENCH_engine.json`; `docs/perf.md` documents the counter invariants
//! and how CI gates those rows.
//!
//! # The zero-allocation hot loop (§Perf: kernel + recorder)
//!
//! The event loop drains the future queue in same-timestamp batches
//! through a reusable buffer (`EventQueue::pop_due_into`), and the queue
//! itself stores events once in a slab while its min-heap orders compact
//! `(time, seq, slot)` keys ([`crate::core::queue`]); (time, seq)
//! processing order is pinned against the retained `BinaryHeap` oracle.
//! Steady-state per-event work allocates nothing: the MIPS recompute, the
//! retry ordering, cloudlet state sweeps and the metrics sample all run
//! on engine-held scratch buffers, and the recorder appends samples into
//! a flat column-major [`crate::metrics::TimeSeries`]. Workers that run
//! many engines back to back (the sweep driver) recycle all of those
//! buffers across cells via [`EngineScratch`] /
//! [`Engine::with_scratch`] / [`Engine::into_scratch`]. The full hot-path
//! walk-through lives in `docs/perf.md`.
//!
//! The engine deliberately stays single-threaded (DES determinism);
//! multi-run parallelism lives one layer up in [`crate::sweep`], which
//! fans self-contained `Engine`/`World` instances out over a worker pool
//! with a deterministic merge.

pub mod broker;
pub mod config;
pub mod index;
pub mod progress;
pub mod report;
mod soa;
pub mod tag;
pub mod world;

use crate::allocation::AllocationPolicy;
use crate::cloudlet::{allocate_mips_into, Cloudlet, CloudletId, CloudletState};
use crate::core::{EntityId, EventQueue, SimEvent, Simulation};
use crate::infra::{DcId, HostId, HostSpec};
use crate::metrics::{LifecycleKind, Recorder};
use crate::obs::EngineCounters;
use crate::vm::{InterruptionBehavior, Vm, VmId, VmState};

pub use broker::Broker;
pub use config::{EngineConfig, VictimPolicy};
pub use report::{MarketStats, RecoveryStats, Report, ResilienceStats, SpotStats};
pub use tag::Tag;
pub use world::World;

/// Window an on-demand VM evicted by a host removal stays requeued.
const OD_REQUEUE_WINDOW: f64 = 3600.0;

/// Reusable engine buffers, threaded through consecutive engines by a
/// long-lived worker (§Perf: sweep workers reset these between cells
/// instead of reallocating them per cell).
///
/// [`Engine::with_scratch`] adopts the buffers (cleared; the recorder and
/// event queue are reset to their pristine state, keeping capacity) and
/// [`Engine::into_scratch`] hands them back after the run. A default
/// `EngineScratch` makes `with_scratch` equivalent to [`Engine::new`].
#[derive(Default)]
pub struct EngineScratch {
    recorder: Option<Recorder>,
    queue: Option<EventQueue<Tag>>,
    run_list: Vec<CloudletId>,
    remaining: Vec<f64>,
    mips: Vec<f64>,
    slot_of: Vec<usize>,
    running_vms: Vec<VmId>,
    finished: Vec<usize>,
    event_batch: Vec<SimEvent<Tag>>,
    active: Vec<(CloudletId, u32)>,
    shares: Vec<(CloudletId, f64)>,
    retry: Vec<VmId>,
    cloudlets: Vec<CloudletId>,
    /// Final counter values of the cell this scratch last ran (telemetry
    /// harvest; a fresh engine starts from zeroed counters regardless).
    counters: EngineCounters,
}

impl EngineScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Engine counters captured by the last [`Engine::into_scratch`] - how
    /// the sweep driver harvests per-cell counts for the telemetry sidecar.
    pub fn counters(&self) -> EngineCounters {
        self.counters
    }
}

/// The simulation engine (leader object of a run).
pub struct Engine {
    pub sim: Simulation<Tag>,
    pub world: World,
    pub broker: Broker,
    pub recorder: Recorder,
    pub config: EngineConfig,
    /// Cheap deterministic activity counters (telemetry sidecar only -
    /// never part of the report artifacts).
    pub counters: EngineCounters,
    policy: Box<dyn AllocationPolicy>,
    backend: Box<dyn progress::ProgressBackend>,

    // ---- progress state (parallel arrays over running cloudlets) ----
    run_list: Vec<CloudletId>,
    remaining: Vec<f64>,
    mips: Vec<f64>,
    /// cloudlet id -> slot in run_list (usize::MAX = absent).
    slot_of: Vec<usize>,
    arrays_dirty: bool,
    last_update: f64,
    next_tick_time: f64,
    /// VMs currently occupying hosts (placement order).
    running_vms: Vec<VmId>,
    next_sample: f64,
    finished_scratch: Vec<usize>,
    /// Reusable buffer for same-timestamp event batches (run loop).
    event_batch: Vec<SimEvent<Tag>>,
    /// Events of the in-flight batch still awaiting dispatch (counts as
    /// pending activity for the sampling keep-alive check).
    batch_pending: usize,
    /// Reusable (cloudlet, pes) buffer for the per-VM MIPS recompute.
    active_scratch: Vec<(CloudletId, u32)>,
    /// Reusable (cloudlet, mips) buffer for `allocate_mips_into` results.
    share_scratch: Vec<(CloudletId, f64)>,
    /// Reusable retry-order buffer (`retry_pending`).
    retry_scratch: Vec<VmId>,
    /// Reusable VM-cloudlet-list buffer (place/pause/cancel).
    cloudlet_scratch: Vec<CloudletId>,

    // ---- chaos-injection state (crate::chaos::apply fills these) ----
    /// Per-storm reclaim fractions; `Tag::ChaosStorm(k)` indexes this.
    pub(crate) chaos_storms: Vec<f64>,
    /// Broker outage windows as half-open `[start, end)` intervals;
    /// `retry_pending` defers while the clock is inside one.
    pub(crate) chaos_outages: Vec<(f64, f64)>,
    /// Hosts currently down due to a chaos crash - a chaos recovery only
    /// reactivates hosts this flags, never dormant/trace-removed ones.
    chaos_crashed: Vec<bool>,

    // ---- market state (crate::market::apply fills this) ----
    /// Compiled spot-price path: `Tag::MarketCrossing(k)` indexes its
    /// crossings, spot placement holds while the price sits above the
    /// bid, and report-time cost accounting integrates it.
    pub(crate) market: Option<std::sync::Arc<crate::market::MarketSchedule>>,

    // ---- recovery state (crate::recovery::apply fills this) ----
    /// Compiled recovery parameters: warning windows take checkpoint
    /// snapshots, terminate-behavior interruptions convert into
    /// checkpoint-carrying requeues, and displaced VMs flow through the
    /// batched reassignment matcher. `None` leaves every interruption
    /// path byte-identical to the recovery-free engine.
    pub(crate) recovery: Option<std::sync::Arc<crate::recovery::RecoverySchedule>>,
    /// Displaced VMs awaiting the next batched reassignment matching.
    recovery_displaced: Vec<VmId>,
    /// Whether a `RecoveryReassign` event is already scheduled
    /// (coalesces one storm's victims into a single matching problem).
    recovery_reassign_armed: bool,
}

impl Engine {
    pub fn new(config: EngineConfig, policy: Box<dyn AllocationPolicy>) -> Self {
        Self::with_scratch(config, policy, EngineScratch::new())
    }

    /// [`Engine::new`] adopting recycled buffers from a previous engine
    /// (see [`EngineScratch`]). Behavior is identical to a fresh engine -
    /// the buffers only carry capacity, never data.
    pub fn with_scratch(
        config: EngineConfig,
        policy: Box<dyn AllocationPolicy>,
        scratch: EngineScratch,
    ) -> Self {
        config.validate().expect("invalid engine config");
        let EngineScratch {
            recorder,
            queue,
            mut run_list,
            mut remaining,
            mut mips,
            mut slot_of,
            mut running_vms,
            mut finished,
            mut event_batch,
            mut active,
            mut shares,
            mut retry,
            mut cloudlets,
            counters: _,
        } = scratch;
        let recorder = match recorder {
            Some(mut r) => {
                r.reset(config.max_log_events);
                r
            }
            None => Recorder::new(config.max_log_events),
        };
        let sim = match queue {
            Some(q) => Simulation::with_queue(config.min_dt, q),
            None => Simulation::new(config.min_dt),
        };
        run_list.clear();
        remaining.clear();
        mips.clear();
        slot_of.clear();
        running_vms.clear();
        finished.clear();
        event_batch.clear();
        active.clear();
        shares.clear();
        retry.clear();
        cloudlets.clear();
        Engine {
            sim,
            world: World::new(),
            broker: Broker::new(),
            recorder,
            config,
            counters: EngineCounters::default(),
            policy,
            backend: Box::new(progress::BatchedBackend),
            run_list,
            remaining,
            mips,
            slot_of,
            arrays_dirty: true,
            last_update: 0.0,
            next_tick_time: f64::INFINITY,
            running_vms,
            next_sample: 0.0,
            finished_scratch: finished,
            event_batch,
            batch_pending: 0,
            active_scratch: active,
            share_scratch: shares,
            retry_scratch: retry,
            cloudlet_scratch: cloudlets,
            chaos_storms: Vec::new(),
            chaos_outages: Vec::new(),
            chaos_crashed: Vec::new(),
            market: None,
            recovery: None,
            recovery_displaced: Vec::new(),
            recovery_reassign_armed: false,
        }
    }

    /// Tear the engine down, handing its reusable buffers back for the
    /// next [`Engine::with_scratch`]. The final counter values (including
    /// the queue-depth high-water mark) ride along for telemetry harvest.
    pub fn into_scratch(mut self) -> EngineScratch {
        self.counters.queue_high_water = self.sim.queue_high_water() as u64;
        EngineScratch {
            recorder: Some(self.recorder),
            queue: Some(self.sim.into_queue()),
            run_list: self.run_list,
            remaining: self.remaining,
            mips: self.mips,
            slot_of: self.slot_of,
            running_vms: self.running_vms,
            finished: self.finished_scratch,
            event_batch: self.event_batch,
            active: self.active_scratch,
            shares: self.share_scratch,
            retry: self.retry_scratch,
            cloudlets: self.cloudlet_scratch,
            counters: self.counters,
        }
    }

    /// Swap the cloudlet-progress backend (§Perf ablation).
    pub fn set_backend(&mut self, backend: Box<dyn progress::ProgressBackend>) {
        self.backend = backend;
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    pub fn policy(&self) -> &dyn AllocationPolicy {
        self.policy.as_ref()
    }

    // ------------------------------------------------------------------
    // setup API (mirrors the paper's Listings 1-9)
    // ------------------------------------------------------------------

    pub fn add_datacenter(&mut self, name: &str, scheduling_interval: f64) -> DcId {
        self.world.add_datacenter(name, scheduling_interval)
    }

    /// Add a host that is active from time 0.
    pub fn add_host(&mut self, dc: DcId, spec: HostSpec) -> HostId {
        self.world.add_host(dc, spec, self.sim.clock())
    }

    /// Add a host that becomes active at absolute time `t` (trace machine
    /// ADD event).
    pub fn add_host_at(&mut self, dc: DcId, spec: HostSpec, t: f64) -> HostId {
        let h = self.world.add_host(dc, spec, t);
        if t > self.sim.clock() {
            self.world.deactivate_host(h, None); // dormant until HostAdd
            self.sim.schedule_at(t, EntityId::Kernel, EntityId::Datacenter(dc), Tag::HostAdd(h));
        }
        h
    }

    /// Schedule removal of a host at absolute time `t` (trace REMOVE event).
    pub fn remove_host_at(&mut self, host: HostId, t: f64) {
        let dc = self.world.hosts[host].dc;
        self.sim.schedule_at(t, EntityId::Kernel, EntityId::Datacenter(dc), Tag::HostRemove(host));
    }

    /// Submit a VM (fires at its submission delay). Mirrors
    /// `broker0.submitVm(vm)` + `setSubmissionDelay`.
    pub fn submit_vm(&mut self, vm: Vm) -> VmId {
        let delay = vm.submission_delay;
        let id = self.world.add_vm(vm);
        self.sim.schedule(delay, EntityId::Broker(0), EntityId::Broker(0), Tag::SubmitVm(id));
        id
    }

    /// Submit a cloudlet bound to an existing VM (`submitCloudlet`).
    pub fn submit_cloudlet(&mut self, cl: Cloudlet) -> CloudletId {
        let id = self.world.add_cloudlet(cl);
        self.sim.schedule(0.0, EntityId::Broker(0), EntityId::Broker(0), Tag::SubmitCloudlet(id));
        id
    }

    pub fn terminate_at(&mut self, t: f64) {
        self.sim.terminate_at(t);
    }

    // ------------------------------------------------------------------
    // run loop
    // ------------------------------------------------------------------

    /// Run to completion and build the report.
    ///
    /// Events are drained in same-timestamp batches through a reusable
    /// buffer (no per-tick allocation); dispatch order stays the exact
    /// (time, seq) order of the one-at-a-time loop because events a
    /// handler schedules at the current timestamp always carry a larger
    /// sequence number than the whole in-flight batch.
    pub fn run(&mut self) -> Report {
        let wall_start = std::time::Instant::now();
        self.sample(); // t = 0 snapshot
        let mut batch = std::mem::take(&mut self.event_batch);
        loop {
            batch.clear();
            if !self.sim.next_batch_into(&mut batch) {
                break;
            }
            let n = batch.len();
            self.counters.events_popped += n as u64;
            for (i, ev) in batch.drain(..).enumerate() {
                self.batch_pending = n - 1 - i;
                self.handle(ev.data);
            }
        }
        self.batch_pending = 0;
        self.event_batch = batch;
        // Close the books at the final clock.
        let end = self.sim.clock();
        self.apply_progress(end);
        report::build(self, wall_start.elapsed())
    }

    fn handle(&mut self, tag: Tag) {
        match tag {
            Tag::SubmitVm(v) => self.on_submit_vm(v),
            Tag::TryAllocate(v) => {
                self.world.vms[v].retry_armed = false;
                self.try_allocate(v, false);
            }
            Tag::WaitingExpired(v) => self.on_waiting_expired(v),
            Tag::SpotInterrupt(v) => self.on_spot_interrupt(v),
            Tag::HibernationTimeout(v) => self.on_hibernation_timeout(v),
            Tag::VmIdleCheck(v) => self.on_vm_idle_check(v),
            Tag::SubmitCloudlet(c) => self.on_submit_cloudlet(c),
            Tag::ProgressTick => self.on_progress_tick(),
            Tag::Sample => self.on_sample(),
            Tag::HostAdd(h) => self.on_host_add(h),
            Tag::HostRemove(h) => self.on_host_remove(h),
            Tag::ChaosHostCrash(h) => self.on_chaos_host_crash(h),
            Tag::ChaosHostRecover(h) => self.on_chaos_host_recover(h),
            Tag::ChaosStorm(k) => self.on_chaos_storm(k),
            Tag::ChaosRetryDrain => {
                self.counters.chaos_events += 1;
                self.retry_pending();
            }
            Tag::MarketCrossing(k) => self.on_market_crossing(k),
            Tag::RecoveryCheckpoint(v) => self.on_recovery_checkpoint(v),
            Tag::RecoveryReassign => self.on_recovery_reassign(),
            Tag::RecoveryMigrate(v, h) => self.on_recovery_migrate(v, h),
            Tag::End => {}
        }
    }

    // ------------------------------------------------------------------
    // VM lifecycle
    // ------------------------------------------------------------------

    fn on_submit_vm(&mut self, v: VmId) {
        let now = self.sim.clock();
        self.world.vms[v].submitted_at = Some(now);
        self.recorder.log(now, v, LifecycleKind::Submitted);
        self.try_allocate(v, true);
    }

    /// Attempt to place `v`. `first` marks the initial submission attempt
    /// (controls persistent-wait vs immediate failure semantics).
    fn try_allocate(&mut self, v: VmId, first: bool) -> bool {
        let now = self.sim.clock();
        let state = self.world.vms[v].state;
        if !matches!(state, VmState::Waiting | VmState::Hibernated) {
            return false; // stale retry event
        }
        // Market out-bid hold: while the spot price sits above the bid,
        // spot capacity is unavailable however idle the hosts are. The
        // request stays parked (waiting queue / resubmission list) until
        // the downward price crossing retries it.
        if self.market_holds_spot(v) {
            if state == VmState::Waiting && first {
                let vm = &self.world.vms[v];
                if vm.persistent && vm.waiting_time > 0.0 {
                    let deadline = now + vm.waiting_time;
                    self.broker.enqueue_waiting(v, deadline);
                    self.sim.schedule_at(
                        deadline,
                        EntityId::Broker(0),
                        EntityId::Broker(0),
                        Tag::WaitingExpired(v),
                    );
                } else {
                    self.fail(v, LifecycleKind::Failed);
                }
            }
            return false;
        }
        self.recorder.alloc_attempts += 1;
        self.counters.placement_probes += 1;

        if let Some(host) = self.policy.select_host(&self.world, v, now) {
            self.counters.placement_hits += 1;
            self.place(v, host);
            return true;
        }
        self.recorder.alloc_failures += 1;
        self.recorder.log(now, v, LifecycleKind::AllocationFailed);

        // On-demand contention: interrupt spot instances to make room
        // (paper §V-C). The VM then waits for the freed capacity.
        // Preemption is *armed* per VM: while a previously-triggered victim
        // set is still vacating (warning period), retries must not warn
        // further spots - otherwise every deallocation event cascades into
        // fresh interruptions (measured 20x over-interruption without this).
        let is_od = !self.world.vms[v].is_spot();
        let mut warned_any = false;
        let mut max_warning = 0.0f64;
        let can_arm = match self.world.vms[v].preempt_armed_at {
            None => true,
            Some(armed_at) => now >= armed_at + self.preempt_rearm_delay(),
        };
        if is_od && can_arm {
            self.counters.preemption_scans += 1;
            if let Some((_host, victims)) = self.policy.select_preemption(&self.world, v, now) {
                for victim in victims {
                    if let Some(w) = self.warn_spot(victim) {
                        warned_any = true;
                        max_warning = max_warning.max(w);
                    }
                }
                if warned_any {
                    self.world.vms[v].preempt_armed_at = Some(now);
                }
            }
        }

        match state {
            VmState::Waiting => {
                let vm = &self.world.vms[v];
                let can_wait = vm.persistent && vm.waiting_time > 0.0;
                if first && (can_wait || warned_any) {
                    // Persistent request (or one whose capacity is being
                    // cleared): park in the waiting queue.
                    let base = if can_wait { vm.waiting_time } else { 0.0 };
                    let deadline =
                        now + base.max(max_warning + 2.0 * self.config.min_dt.max(1e-3));
                    self.broker.enqueue_waiting(v, deadline);
                    self.sim.schedule_at(
                        deadline,
                        EntityId::Broker(0),
                        EntityId::Broker(0),
                        Tag::WaitingExpired(v),
                    );
                } else if first {
                    self.fail(v, LifecycleKind::Failed);
                }
                if warned_any {
                    // Backstop retry shortly after the victims vacate.
                    self.sim.schedule(
                        max_warning + self.config.min_dt.max(1e-3),
                        EntityId::Broker(0),
                        EntityId::Broker(0),
                        Tag::TryAllocate(v),
                    );
                }
            }
            VmState::Hibernated => {
                // Stays on the resubmitting list; HibernationTimeout is
                // armed, and one (deduplicated) periodic backstop retry
                // keeps probing even if no deallocation event fires
                // (paper §VII-B(b): periodic clock-tick checks).
                if !self.world.vms[v].retry_armed {
                    self.world.vms[v].retry_armed = true;
                    self.sim.schedule(
                        self.config.retry_interval,
                        EntityId::Broker(0),
                        EntityId::Broker(0),
                        Tag::TryAllocate(v),
                    );
                }
            }
            _ => unreachable!(),
        }
        false
    }

    /// Place `v` on `host` and start/resume its cloudlets.
    fn place(&mut self, v: VmId, host: HostId) {
        let now = self.sim.clock();
        self.apply_progress(now);

        self.world.commit_vm(host, v);

        let resumed = self.world.vms[v].state == VmState::Hibernated;
        self.world.transition_vm(v, VmState::Running);
        self.world.vms[v].preempt_armed_at = None;
        self.world.vms[v].host = Some(host);
        self.world.vms[v].history.record_start(host, now);
        self.world.set_hibernated_at(v, None);
        self.running_vms.push(v);

        if resumed {
            self.broker.remove_resubmitting(v);
            self.recorder.redeployments += 1;
            self.recorder.log(now, v, LifecycleKind::Resumed);
        } else {
            self.broker.remove_waiting(v);
            self.recorder.log(now, v, LifecycleKind::Allocated);
        }

        // A displaced VM made it back: record the time-to-recover and the
        // in-flight work it carried across the gap (resilience metrics).
        if let Some(t0) = self.world.take_displaced(v) {
            let dur = now - t0;
            self.recorder.recoveries += 1;
            self.recorder.recovery_secs_sum += dur;
            if dur > self.recorder.recovery_secs_max {
                self.recorder.recovery_secs_max = dur;
            }
            self.recorder.work_recovered_mi += self.vm_inflight_done_mi(v);
            self.recorder.requeue_latency.push(dur);
        }
        // Any checkpoint taken for the displacement is consumed by now.
        self.world.vms[v].checkpoint_mi = None;

        // Start queued cloudlets / resume paused ones (the VM's cloudlet
        // list is copied into reusable scratch, not cloned per placement).
        let mut cls = std::mem::take(&mut self.cloudlet_scratch);
        cls.clear();
        cls.extend_from_slice(&self.world.vms[v].cloudlets);
        let mut any_active = false;
        for &c in &cls {
            let cl = &mut self.world.cloudlets[c];
            match cl.state {
                CloudletState::Queued | CloudletState::Paused => {
                    cl.state = CloudletState::Running;
                    if cl.started_at.is_none() {
                        cl.started_at = Some(now);
                    }
                    any_active = true;
                }
                _ => {}
            }
        }
        self.cloudlet_scratch = cls;
        self.arrays_dirty = true;
        if any_active {
            self.arm_tick(now);
        } else if self.world.vms[v].cloudlets.is_empty() {
            // VM with no workload: subject to destruction delay directly.
            self.sim.schedule(
                self.config.vm_destruction_delay,
                EntityId::Broker(0),
                EntityId::Broker(0),
                Tag::VmIdleCheck(v),
            );
        } else {
            // All cloudlets already done (e.g. resubmitted after finish).
            self.sim.schedule(
                self.config.vm_destruction_delay,
                EntityId::Broker(0),
                EntityId::Broker(0),
                Tag::VmIdleCheck(v),
            );
        }
    }

    /// How long a VM's triggered preemption stays armed before it may warn
    /// additional spots (covers the longest plausible warning period).
    fn preempt_rearm_delay(&self) -> f64 {
        // One scheduling interval beyond the engine min_dt floor keeps
        // retries from cascading while victims vacate.
        self.config.scheduling_interval + 2.0 * self.config.min_dt.max(1e-3) + 120.0
    }

    /// Issue the interruption warning to a spot VM. Returns the warning
    /// time when a warning was issued.
    fn warn_spot(&mut self, v: VmId) -> Option<f64> {
        let now = self.sim.clock();
        let vm = &self.world.vms[v];
        if vm.state != VmState::Running || !vm.is_spot() {
            return None;
        }
        let cfg = vm.spot.expect("spot vm without config");
        self.world.transition_vm(v, VmState::InterruptWarned);
        self.recorder.log(now, v, LifecycleKind::InterruptWarned);
        self.sim.schedule(
            cfg.warning_time,
            EntityId::Datacenter(0),
            EntityId::Broker(0),
            Tag::SpotInterrupt(v),
        );
        // Checkpointing recovery modes snapshot at the start of the grace
        // window. Scheduled *after* SpotInterrupt at the same source, so a
        // zero-length window interrupts first and (correctly) saves nothing.
        if self.recovery.as_ref().map_or(false, |s| s.mode.checkpoints()) {
            self.sim.schedule(
                0.0,
                EntityId::Datacenter(0),
                EntityId::Broker(0),
                Tag::RecoveryCheckpoint(v),
            );
        }
        Some(cfg.warning_time)
    }

    /// The warning period elapsed: actually interrupt the spot VM.
    fn on_spot_interrupt(&mut self, v: VmId) {
        let now = self.sim.clock();
        if self.world.vms[v].state != VmState::InterruptWarned {
            return; // finished or destroyed during the grace period
        }
        self.apply_progress(now);
        self.recorder.interruptions += 1;
        self.world.vms[v].interruptions += 1;

        let cfg = self.world.vms[v].spot.expect("spot vm without config");
        self.remove_from_host(v);
        match cfg.behavior {
            InterruptionBehavior::Hibernate => {
                self.world.transition_vm(v, VmState::Hibernated);
                self.world.set_hibernated_at(v, Some(now));
                self.world.mark_displaced(v, now);
                self.pause_cloudlets(v);
                self.broker.enqueue_resubmitting(v);
                self.recorder.hibernations += 1;
                self.recorder.log(now, v, LifecycleKind::Hibernated);
                self.sim.schedule(
                    cfg.hibernation_timeout,
                    EntityId::Broker(0),
                    EntityId::Broker(0),
                    Tag::HibernationTimeout(v),
                );
            }
            InterruptionBehavior::Terminate => {
                if self.recovery.is_some() {
                    // Recovery substrate active: the grace-window
                    // checkpoint (if any) turns the kill into a requeue.
                    self.recovery_requeue(v, cfg.hibernation_timeout);
                } else {
                    // Terminal transition also clears any displacement.
                    self.world.transition_vm(v, VmState::Terminated);
                    self.world.vms[v].stopped_at = Some(now);
                    self.recorder.work_lost_mi += self.vm_inflight_done_mi(v);
                    self.cancel_cloudlets(v);
                    self.broker.finished.push(v);
                    self.recorder.spot_terminations += 1;
                    self.recorder.log(now, v, LifecycleKind::Terminated);
                }
            }
        }
        let migrates = self.recovery.as_ref().map_or(false, |s| s.mode.migrates());
        if migrates && self.world.vms[v].state == VmState::Hibernated {
            self.queue_displaced(v);
        }
        self.retry_pending();
    }

    fn on_hibernation_timeout(&mut self, v: VmId) {
        let now = self.sim.clock();
        let vm = &self.world.vms[v];
        if vm.state != VmState::Hibernated {
            return; // resumed (and possibly re-hibernated: new timeout armed)
        }
        let cfg = vm.spot.expect("spot vm without config");
        let hib_at = vm.hibernated_at.expect("hibernated without timestamp");
        if now + 1e-9 < hib_at + cfg.hibernation_timeout {
            return; // stale timeout from an earlier hibernation
        }
        // Terminal transition also clears any displacement (the VM dies
        // while displaced; the gauge must not leak).
        self.world.transition_vm(v, VmState::Terminated);
        self.world.vms[v].stopped_at = Some(now);
        self.recorder.work_lost_mi += self.vm_inflight_done_mi(v);
        self.cancel_cloudlets(v);
        self.broker.remove_resubmitting(v);
        self.broker.finished.push(v);
        self.recorder.spot_terminations += 1;
        self.recorder.log(now, v, LifecycleKind::HibernationTimedOut);
    }

    fn on_waiting_expired(&mut self, v: VmId) {
        let now = self.sim.clock();
        if self.world.vms[v].state != VmState::Waiting {
            return;
        }
        // Only expire if the *current* deadline passed (it may have been
        // extended by a preemption-wait).
        let deadline = self
            .broker
            .waiting
            .iter()
            .find(|&&(vm, _)| vm == v)
            .map(|&(_, d)| d);
        match deadline {
            Some(d) if now + 1e-9 >= d => {
                self.broker.remove_waiting(v);
                self.recorder.log(now, v, LifecycleKind::WaitingExpired);
                self.fail(v, LifecycleKind::Failed);
            }
            _ => {}
        }
    }

    fn fail(&mut self, v: VmId, kind: LifecycleKind) {
        let now = self.sim.clock();
        // Terminal transition also clears any displacement.
        self.world.transition_vm(v, VmState::Failed);
        self.world.vms[v].stopped_at = Some(now);
        self.recorder.work_lost_mi += self.vm_inflight_done_mi(v);
        self.cancel_cloudlets(v);
        self.broker.finished.push(v);
        self.recorder.log(now, v, kind);
    }

    /// Executed-but-unfinished work (MI) across `v`'s not-yet-done
    /// cloudlets: the progress a terminal state discards (work lost) or a
    /// re-placement preserves (work recovered).
    fn vm_inflight_done_mi(&self, v: VmId) -> f64 {
        self.world.vms[v]
            .cloudlets
            .iter()
            .filter(|&&c| !self.world.cloudlets[c].is_done())
            .map(|&c| {
                let cl = &self.world.cloudlets[c];
                (cl.length_mi - cl.remaining_mi).max(0.0)
            })
            .sum()
    }

    /// Destruction-delay check: destroy the VM if it is still idle.
    fn on_vm_idle_check(&mut self, v: VmId) {
        let now = self.sim.clock();
        let vm = &self.world.vms[v];
        if !vm.state.on_host() {
            return;
        }
        let all_done = vm.cloudlets.iter().all(|&c| self.world.cloudlets[c].is_done());
        if !all_done && !vm.cloudlets.is_empty() {
            return; // new work arrived during the delay
        }
        self.apply_progress(now);
        self.remove_from_host(v);
        self.world.transition_vm(v, VmState::Finished);
        self.world.vms[v].stopped_at = Some(now);
        self.broker.finished.push(v);
        self.recorder.log(now, v, LifecycleKind::Finished);
        self.retry_pending();
    }

    /// Release host resources and close the current history interval.
    fn remove_from_host(&mut self, v: VmId) {
        let now = self.sim.clock();
        let host = self.world.vms[v].host.take().expect("vm not on a host");
        self.world.release_vm(host, v);
        self.world.vms[v].history.record_stop(now);
        if let Some(i) = self.running_vms.iter().position(|&x| x == v) {
            self.running_vms.swap_remove(i);
        }
        self.arrays_dirty = true;
    }

    fn pause_cloudlets(&mut self, v: VmId) {
        let mut cls = std::mem::take(&mut self.cloudlet_scratch);
        cls.clear();
        cls.extend_from_slice(&self.world.vms[v].cloudlets);
        for &c in &cls {
            let cl = &mut self.world.cloudlets[c];
            if cl.state == CloudletState::Running {
                cl.state = CloudletState::Paused;
            }
        }
        self.cloudlet_scratch = cls;
        self.arrays_dirty = true;
    }

    fn cancel_cloudlets(&mut self, v: VmId) {
        let now = self.sim.clock();
        let mut cls = std::mem::take(&mut self.cloudlet_scratch);
        cls.clear();
        cls.extend_from_slice(&self.world.vms[v].cloudlets);
        for &c in &cls {
            let cl = &mut self.world.cloudlets[c];
            if !cl.is_done() {
                cl.state = CloudletState::Canceled;
                cl.finished_at = Some(now);
            }
        }
        self.cloudlet_scratch = cls;
        self.arrays_dirty = true;
    }

    /// Retry queued requests after capacity freed up. Order: waiting
    /// on-demand, hibernated spots, waiting spots (see [`Broker`]).
    /// Freshly hibernated spots are skipped until their resubmission
    /// cooldown elapses (periodic resubmission, paper §IV-B) - their
    /// backstop retry event picks them up.
    fn retry_pending(&mut self) {
        let now = self.sim.clock();
        // Broker outage window: retries defer until the scheduled
        // ChaosRetryDrain fires just past the window. Chaos-free runs have
        // an empty window list and never hit this.
        if self.chaos_outages.iter().any(|&(start, end)| now >= start && now < end) {
            return;
        }
        let cooldown = self.config.resubmit_cooldown;
        let mut order = std::mem::take(&mut self.retry_scratch);
        {
            let vms = &self.world.vms;
            self.broker.retry_order_into(|v| vms[v].is_spot(), &mut order);
        }
        for &v in &order {
            if let (VmState::Hibernated, Some(h)) =
                (self.world.vms[v].state, self.world.vms[v].hibernated_at)
            {
                if now < h + cooldown {
                    // Ensure a retry fires once the cooldown elapses.
                    if !self.world.vms[v].retry_armed {
                        self.world.vms[v].retry_armed = true;
                        self.sim.schedule(
                            (h + cooldown - now).max(self.sim.min_dt()),
                            EntityId::Broker(0),
                            EntityId::Broker(0),
                            Tag::TryAllocate(v),
                        );
                    }
                    continue;
                }
            }
            self.try_allocate(v, false);
        }
        self.retry_scratch = order;
    }

    // ------------------------------------------------------------------
    // cloudlets & progress
    // ------------------------------------------------------------------

    fn on_submit_cloudlet(&mut self, c: CloudletId) {
        let now = self.sim.clock();
        let v = self.world.cloudlets[c].vm;
        match self.world.vms[v].state {
            VmState::Running | VmState::InterruptWarned => {
                self.apply_progress(now);
                let cl = &mut self.world.cloudlets[c];
                cl.state = CloudletState::Running;
                cl.started_at = Some(now);
                self.arrays_dirty = true;
                self.arm_tick(now);
            }
            VmState::Finished | VmState::Terminated | VmState::Failed => {
                let cl = &mut self.world.cloudlets[c];
                cl.state = CloudletState::Canceled;
                cl.finished_at = Some(now);
            }
            _ => {} // stays Queued until the VM is placed
        }
    }

    /// Rebuild the parallel progress arrays from current world state.
    fn rebuild_arrays(&mut self) {
        self.run_list.clear();
        self.remaining.clear();
        if self.slot_of.len() < self.world.cloudlets.len() {
            self.slot_of.resize(self.world.cloudlets.len(), usize::MAX);
        }
        for s in self.slot_of.iter_mut() {
            *s = usize::MAX;
        }
        for &v in &self.running_vms {
            for &c in &self.world.vms[v].cloudlets {
                let cl = &self.world.cloudlets[c];
                if cl.state == CloudletState::Running {
                    self.slot_of[c] = self.run_list.len();
                    self.run_list.push(c);
                    self.remaining.push(cl.remaining_mi);
                }
            }
        }
        self.mips.resize(self.run_list.len(), 0.0);
        self.arrays_dirty = false;
    }

    /// Recompute per-cloudlet MIPS from each running VM's scheduler and the
    /// cloudlets' utilization models at time `t`. Runs on reusable scratch
    /// buffers - the pre-overhaul implementation allocated two `Vec`s per
    /// running VM on every progress tick.
    fn recompute_mips(&mut self, t: f64) {
        for m in self.mips.iter_mut() {
            *m = 0.0;
        }
        let kind = self.config.scheduler;
        let mut active = std::mem::take(&mut self.active_scratch);
        let mut shares = std::mem::take(&mut self.share_scratch);
        for &v in &self.running_vms {
            let vm = &self.world.vms[v];
            active.clear();
            active.extend(
                vm.cloudlets
                    .iter()
                    .filter(|&&c| self.world.cloudlets[c].state == CloudletState::Running)
                    .map(|&c| (c, self.world.cloudlets[c].pes)),
            );
            if active.is_empty() {
                continue;
            }
            allocate_mips_into(kind, vm.spec.total_mips(), vm.spec.pes, &active, &mut shares);
            for &(c, share) in &shares {
                let slot = self.slot_of[c];
                if slot != usize::MAX {
                    self.mips[slot] = share * self.world.cloudlets[c].utilization.at(t);
                }
            }
        }
        self.active_scratch = active;
        self.share_scratch = shares;
    }

    /// Advance all running cloudlets to `now`; handle completions.
    fn apply_progress(&mut self, now: f64) {
        if self.arrays_dirty {
            // Write back current remaining before rebuilding (slots may be
            // dropped).
            for (i, &c) in self.run_list.iter().enumerate() {
                if i < self.remaining.len() {
                    self.world.cloudlets[c].remaining_mi = self.remaining[i];
                }
            }
            self.rebuild_arrays();
        }
        let dt = now - self.last_update;
        self.last_update = now;
        if dt <= 0.0 || self.run_list.is_empty() {
            return;
        }
        self.recompute_mips(now - dt);
        self.finished_scratch.clear();
        let mut finished = std::mem::take(&mut self.finished_scratch);
        self.backend.step(&mut self.remaining, &self.mips, dt, &mut finished);

        // Write back remaining MI (cheap; keeps structs authoritative).
        for (i, &c) in self.run_list.iter().enumerate() {
            self.world.cloudlets[c].remaining_mi = self.remaining[i];
        }

        for &slot in &finished {
            let c = self.run_list[slot];
            let cl = &mut self.world.cloudlets[c];
            cl.state = CloudletState::Finished;
            cl.finished_at = Some(now);
            let v = cl.vm;
            let all_done =
                self.world.vms[v].cloudlets.iter().all(|&cc| self.world.cloudlets[cc].is_done());
            if all_done {
                self.sim.schedule(
                    self.config.vm_destruction_delay,
                    EntityId::Broker(0),
                    EntityId::Broker(0),
                    Tag::VmIdleCheck(v),
                );
            }
        }
        if !finished.is_empty() {
            self.arrays_dirty = true;
        }
        finished.clear();
        self.finished_scratch = finished;
    }

    /// Arm a progress tick no later than the earliest predicted completion
    /// (clamped to the scheduling interval).
    fn arm_tick(&mut self, now: f64) {
        if self.arrays_dirty {
            self.apply_progress(now); // rebuild + zero-dt bookkeeping
        }
        if self.run_list.is_empty() {
            return;
        }
        self.recompute_mips(now);
        let mut horizon = self.config.scheduling_interval;
        for (r, m) in self.remaining.iter().zip(&self.mips) {
            if *r > 0.0 && *m > 0.0 {
                horizon = horizon.min(r / m);
            }
        }
        let t = now + horizon.max(self.sim.min_dt().max(1e-6));
        if t < self.next_tick_time - 1e-9 {
            self.next_tick_time = t;
            self.sim.schedule_at(t, EntityId::Kernel, EntityId::Kernel, Tag::ProgressTick);
        }
    }

    fn on_progress_tick(&mut self) {
        let now = self.sim.clock();
        self.next_tick_time = f64::INFINITY;
        self.apply_progress(now);
        self.arm_tick(now);
    }

    // ------------------------------------------------------------------
    // hosts (trace machine events)
    // ------------------------------------------------------------------

    fn on_host_add(&mut self, h: HostId) {
        let now = self.sim.clock();
        self.world.activate_host(h, now);
        self.retry_pending();
    }

    fn on_host_remove(&mut self, h: HostId) {
        let now = self.sim.clock();
        if !self.world.hosts[h].is_active() {
            return;
        }
        self.apply_progress(now);
        let victims: Vec<VmId> = self.world.hosts[h].vms.clone();
        for v in victims {
            let state = self.world.vms[v].state;
            if !state.on_host() {
                continue;
            }
            self.remove_from_host(v);
            let is_spot = self.world.vms[v].is_spot();
            if is_spot {
                // Machine loss = interruption without warning.
                self.recorder.interruptions += 1;
                self.world.vms[v].interruptions += 1;
                let cfg = self.world.vms[v].spot.expect("spot vm without config");
                match cfg.behavior {
                    InterruptionBehavior::Hibernate => {
                        self.world.transition_vm(v, VmState::Hibernated);
                        self.world.set_hibernated_at(v, Some(now));
                        self.world.mark_displaced(v, now);
                        self.pause_cloudlets(v);
                        self.broker.enqueue_resubmitting(v);
                        self.recorder.hibernations += 1;
                        self.recorder.log(now, v, LifecycleKind::Hibernated);
                        self.sim.schedule(
                            cfg.hibernation_timeout,
                            EntityId::Broker(0),
                            EntityId::Broker(0),
                            Tag::HibernationTimeout(v),
                        );
                    }
                    InterruptionBehavior::Terminate => {
                        if self.recovery.is_some() {
                            // Unwarned loss: no checkpoint was taken, so
                            // the requeue restarts from zero progress, but
                            // the VM still survives for reassignment.
                            self.recovery_requeue(v, cfg.hibernation_timeout);
                        } else {
                            // Terminal transition clears any displacement.
                            self.world.transition_vm(v, VmState::Terminated);
                            self.world.vms[v].stopped_at = Some(now);
                            self.recorder.work_lost_mi += self.vm_inflight_done_mi(v);
                            self.cancel_cloudlets(v);
                            self.broker.finished.push(v);
                            self.recorder.spot_terminations += 1;
                            self.recorder.log(now, v, LifecycleKind::Terminated);
                        }
                    }
                }
                let migrates = self.recovery.as_ref().map_or(false, |s| s.mode.migrates());
                if migrates && self.world.vms[v].state == VmState::Hibernated {
                    self.queue_displaced(v);
                }
            } else {
                // On-demand: requeue and wait for capacity elsewhere.
                self.world.transition_vm(v, VmState::Waiting);
                self.world.mark_displaced(v, now);
                self.pause_cloudlets(v);
                let deadline = now + self.world.vms[v].waiting_time.max(OD_REQUEUE_WINDOW);
                self.broker.enqueue_waiting(v, deadline);
                self.sim.schedule_at(
                    deadline,
                    EntityId::Broker(0),
                    EntityId::Broker(0),
                    Tag::WaitingExpired(v),
                );
            }
        }
        self.world.deactivate_host(h, Some(now));
        self.retry_pending();
    }

    // ------------------------------------------------------------------
    // chaos faults (schedules compiled by crate::chaos)
    // ------------------------------------------------------------------

    /// Chaos host crash: evict like a removal, but flag the host so the
    /// paired recovery event knows it owns the reactivation.
    fn on_chaos_host_crash(&mut self, h: HostId) {
        if !self.world.hosts[h].is_active() {
            return; // dormant or already down: nothing to crash
        }
        if self.chaos_crashed.len() < self.world.hosts.len() {
            self.chaos_crashed.resize(self.world.hosts.len(), false);
        }
        self.chaos_crashed[h] = true;
        self.counters.chaos_events += 1;
        self.recorder.host_failures += 1;
        self.on_host_remove(h);
    }

    /// Chaos repair completed. Only reactivates hosts the chaos stream
    /// took down - never a dormant trace machine awaiting its ADD event
    /// or a host the trace removed for good.
    fn on_chaos_host_recover(&mut self, h: HostId) {
        if self.chaos_crashed.get(h) == Some(&true) {
            self.chaos_crashed[h] = false;
            self.counters.chaos_events += 1;
            self.on_host_add(h);
        }
    }

    /// AZ-wide reclaim storm: warn a fraction of all currently
    /// interruptible spot VMs at one timestamp (ascending VM id, so the
    /// victim set is deterministic).
    fn on_chaos_storm(&mut self, k: usize) {
        let now = self.sim.clock();
        let frac = self.chaos_storms[k];
        self.counters.chaos_events += 1;
        self.recorder.storms += 1;
        let eligible: Vec<VmId> = (0..self.world.vms.len())
            .filter(|&v| self.world.vms[v].interruptible(now))
            .collect();
        if eligible.is_empty() {
            return;
        }
        let take = ((eligible.len() as f64 * frac).ceil() as usize).min(eligible.len());
        for &v in eligible.iter().take(take) {
            if self.warn_spot(v).is_some() {
                self.recorder.storm_reclaims += 1;
            }
        }
    }

    // ------------------------------------------------------------------
    // market price events (schedules compiled by crate::market)
    // ------------------------------------------------------------------

    /// `v` is a spot VM whose bid is currently under the market price
    /// (its placement requests hold until the next downward crossing).
    fn market_holds_spot(&self, v: VmId) -> bool {
        if !self.world.vms[v].is_spot() {
            return false;
        }
        match self.market.as_ref() {
            Some(m) => !m.is_empty() && m.price_at(self.sim.clock()) > m.bid,
            None => false,
        }
    }

    /// The spot price crossed the bid level. An upward crossing out-bids
    /// every currently interruptible spot VM (ascending VM id, so the
    /// victim set is deterministic); a downward crossing drains the
    /// retry queue so held/hibernated spots get capacity back.
    fn on_market_crossing(&mut self, k: usize) {
        self.counters.market_events += 1;
        let up = match self.market.as_ref().and_then(|m| m.crossings.get(k)) {
            Some(c) => c.up,
            None => return,
        };
        if up {
            let now = self.sim.clock();
            let eligible: Vec<VmId> = (0..self.world.vms.len())
                .filter(|&v| self.world.vms[v].interruptible(now))
                .collect();
            for v in eligible {
                if self.warn_spot(v).is_some() {
                    self.recorder.price_reclaims += 1;
                }
            }
        } else {
            self.retry_pending();
        }
    }

    // ------------------------------------------------------------------
    // recovery (parameters compiled by crate::recovery)
    // ------------------------------------------------------------------

    /// Warning-window checkpoint: snapshot how much of the VM's in-flight
    /// progress fits through the grace window at the recovery bandwidth
    /// (full / partial / restart decision, see [`crate::recovery`]).
    fn on_recovery_checkpoint(&mut self, v: VmId) {
        let sched = match self.recovery.as_ref() {
            Some(s) => std::sync::Arc::clone(s),
            None => return,
        };
        if self.world.vms[v].state != VmState::InterruptWarned {
            return; // interruption already resolved during the window
        }
        let now = self.sim.clock();
        self.counters.recovery_events += 1;
        self.apply_progress(now);
        let progress = self.vm_inflight_done_mi(v);
        let cfg = self.world.vms[v].spot.expect("spot vm without config");
        let d = sched.decide(progress, cfg.warning_time);
        self.world.vms[v].checkpoint_mi = Some(d.saved_mi);
        if d.saved_mi > 0.0 {
            self.recorder.checkpoints += 1;
            self.recorder.checkpoint_mb += d.bytes_mb;
            self.recorder.log(now, v, LifecycleKind::Checkpointed);
        }
    }

    /// Convert a terminate-behavior interruption into a checkpoint-carrying
    /// requeue: progress beyond the saved checkpoint is lost, the remainder
    /// rides the hibernation path back through the allocator. The caller
    /// has already taken the VM off its host.
    fn recovery_requeue(&mut self, v: VmId, hibernation_timeout: f64) {
        let now = self.sim.clock();
        self.counters.recovery_events += 1;
        let progress = self.vm_inflight_done_mi(v);
        let retained = self.world.vms[v].checkpoint_mi.take().unwrap_or(0.0).min(progress);
        self.recorder.work_lost_mi += (progress - retained).max(0.0);
        self.truncate_progress(v, retained);
        self.world.transition_vm(v, VmState::Hibernated);
        self.world.set_hibernated_at(v, Some(now));
        self.world.mark_displaced(v, now);
        self.pause_cloudlets(v);
        self.broker.enqueue_resubmitting(v);
        self.recorder.hibernations += 1;
        self.recorder.log(now, v, LifecycleKind::Hibernated);
        self.sim.schedule(
            hibernation_timeout,
            EntityId::Broker(0),
            EntityId::Broker(0),
            Tag::HibernationTimeout(v),
        );
    }

    /// Rewrite `v`'s unfinished cloudlets so their total completed work
    /// equals `retained_mi` (allocated front to back), dropping the rest.
    /// Must run while `v` is off-host: the leading `apply_progress` flushes
    /// the parallel arrays and rebuilds them *without* this VM's cloudlets,
    /// so no later array writeback can clobber the truncation.
    fn truncate_progress(&mut self, v: VmId, retained_mi: f64) {
        let now = self.sim.clock();
        self.apply_progress(now);
        let mut budget = retained_mi.max(0.0);
        let mut cls = std::mem::take(&mut self.cloudlet_scratch);
        cls.clear();
        cls.extend_from_slice(&self.world.vms[v].cloudlets);
        for &c in &cls {
            let cl = &mut self.world.cloudlets[c];
            if cl.is_done() {
                continue;
            }
            let done = (cl.length_mi - cl.remaining_mi).max(0.0);
            let keep = done.min(budget);
            cl.remaining_mi = cl.length_mi - keep;
            budget -= keep;
        }
        self.cloudlet_scratch = cls;
    }

    /// Register a displaced VM for the next batched reassignment matching,
    /// arming a single `RecoveryReassign` event per batch window (one
    /// storm's victims become one matching problem).
    fn queue_displaced(&mut self, v: VmId) {
        if !self.recovery_displaced.contains(&v) {
            self.recovery_displaced.push(v);
        }
        if !self.recovery_reassign_armed {
            self.recovery_reassign_armed = true;
            self.sim.schedule(
                self.sim.min_dt().max(1e-3),
                EntityId::Broker(0),
                EntityId::Broker(0),
                Tag::RecoveryReassign,
            );
        }
    }

    /// Batched displaced-VM reassignment: build the VM x host cost matrix
    /// and dispatch checkpoint transfers per the configured matcher
    /// (greedy first-fit baseline or Kuhn-Munkres min-cost matching).
    /// Unmatched VMs stay on the resubmission list and fall back to the
    /// periodic retry path.
    fn on_recovery_reassign(&mut self) {
        self.recovery_reassign_armed = false;
        let sched = match self.recovery.as_ref() {
            Some(s) => std::sync::Arc::clone(s),
            None => {
                self.recovery_displaced.clear();
                return;
            }
        };
        let mut displaced = std::mem::take(&mut self.recovery_displaced);
        displaced.retain(|&v| self.world.vms[v].state == VmState::Hibernated);
        if displaced.is_empty() {
            self.recovery_displaced = displaced;
            return;
        }
        self.counters.recovery_events += 1;
        let hosts: Vec<HostId> =
            (0..self.world.hosts.len()).filter(|&h| self.world.hosts[h].is_active()).collect();
        if !hosts.is_empty() {
            let costs: Vec<Vec<f64>> = displaced
                .iter()
                .map(|&v| hosts.iter().map(|&h| self.migration_cost(v, h, &sched)).collect())
                .collect();
            let assign = match sched.mode {
                crate::recovery::RecoveryMode::MigrateOptimal => {
                    crate::recovery::assign_optimal(&costs)
                }
                _ => crate::recovery::assign_greedy(&costs),
            };
            for (i, slot) in assign.iter().enumerate() {
                if let Some(j) = *slot {
                    let v = displaced[i];
                    let delay = self.transfer_secs(v, &sched).max(self.sim.min_dt());
                    self.sim.schedule(
                        delay,
                        EntityId::Broker(0),
                        EntityId::Broker(0),
                        Tag::RecoveryMigrate(v, hosts[j]),
                    );
                }
            }
        }
        displaced.clear();
        self.recovery_displaced = displaced;
    }

    /// Reassignment cost of resuming displaced `v` on `host`: checkpoint
    /// transfer time plus the remaining-work redo time inflated by the
    /// target's current load, so the optimal matcher spreads victims over
    /// idle hosts where greedy piles them onto the first fit.
    fn migration_cost(
        &self,
        v: VmId,
        host: HostId,
        sched: &crate::recovery::RecoverySchedule,
    ) -> f64 {
        let vm = &self.world.vms[v];
        let h = &self.world.hosts[host];
        if !h.fits(vm.spec.pes, vm.spec.ram, vm.spec.bw, vm.spec.storage) {
            return f64::INFINITY;
        }
        let remaining: f64 = vm
            .cloudlets
            .iter()
            .filter(|&&c| !self.world.cloudlets[c].is_done())
            .map(|&c| self.world.cloudlets[c].remaining_mi.max(0.0))
            .sum();
        let redo = remaining / vm.spec.total_mips().max(1e-9);
        self.transfer_secs(v, sched) + redo * (1.0 + h.cpu_utilization())
    }

    /// Checkpoint-image transfer time of displaced `v` at the schedule's
    /// recovery bandwidth (image size scales with the retained progress).
    fn transfer_secs(&self, v: VmId, sched: &crate::recovery::RecoverySchedule) -> f64 {
        let image_mb = self.vm_inflight_done_mi(v) * crate::recovery::CHECKPOINT_MB_PER_MI;
        image_mb / sched.bandwidth_mb_s.max(1e-9)
    }

    /// A displaced VM's checkpoint transfer landed: resume it on the chosen
    /// host, or count a failed migration if the slot evaporated meanwhile.
    fn on_recovery_migrate(&mut self, v: VmId, host: HostId) {
        let now = self.sim.clock();
        if self.world.vms[v].state != VmState::Hibernated {
            return; // resumed elsewhere or timed out while transferring
        }
        self.counters.recovery_events += 1;
        let vm = &self.world.vms[v];
        let fits =
            self.world.hosts[host].fits(vm.spec.pes, vm.spec.ram, vm.spec.bw, vm.spec.storage);
        if !fits || self.market_holds_spot(v) {
            self.recorder.failed_migrations += 1;
            return; // falls back to the periodic retry path
        }
        self.recorder.migrations += 1;
        self.recorder.log(now, v, LifecycleKind::Migrated);
        self.place(v, host);
    }

    // ------------------------------------------------------------------
    // metrics
    // ------------------------------------------------------------------

    fn sample(&mut self) {
        let now = self.sim.clock();
        // O(1) counter read (`World::state_sample`), one stack row into
        // the column-major series: a sample walks nothing and allocates
        // nothing. Debug builds re-verify every sample of every test run
        // against the retained walking oracle, bitwise.
        let s = self.world.state_sample();
        debug_assert!(
            s.bits_eq(&self.world.state_sample_scan()),
            "incremental state_sample diverged from scan oracle at t={now}"
        );
        self.recorder.push_sample(now, &s);
        self.next_sample = now + self.config.sample_interval;
        self.sim.schedule_at(
            self.next_sample,
            EntityId::Kernel,
            EntityId::Kernel,
            Tag::Sample,
        );
    }

    fn on_sample(&mut self) {
        // Only keep sampling while there is activity left; otherwise the
        // self-rearming sample would keep the simulation alive forever.
        let active = !self.running_vms.is_empty()
            || self.broker.queue_depth() > 0
            || self.sim.pending_events() > 0
            || self.batch_pending > 0;
        if active {
            self.sample();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allocation::FirstFit;
    use crate::vm::{SpotConfig, VmSpec};

    fn engine() -> Engine {
        let mut cfg = EngineConfig::default();
        cfg.min_dt = 0.1;
        cfg.vm_destruction_delay = 0.0;
        cfg.resubmit_cooldown = 1.0; // tight timing expectations in tests
        let mut e = Engine::new(cfg, Box::new(FirstFit::new()));
        let dc = e.add_datacenter("dc0", 1.0);
        e.add_host(dc, HostSpec::new(8, 1000.0, 16_384.0, 10_000.0, 1_000_000.0));
        e
    }

    /// One on-demand VM with one cloudlet runs to completion.
    #[test]
    fn simple_run_finishes_cloudlet() {
        let mut e = engine();
        let vm = e.submit_vm(Vm::on_demand(0, VmSpec::new(1000.0, 2)));
        // 20_000 MI at 2000 MIPS -> 10 s.
        e.submit_cloudlet(Cloudlet::new(0, 20_000.0, 2).with_vm(vm));
        let report = e.run();
        assert_eq!(e.world.vms[vm].state, VmState::Finished);
        let cl = &e.world.cloudlets[0];
        assert_eq!(cl.state, CloudletState::Finished);
        assert!((cl.finished_at.unwrap() - 10.0).abs() < 0.2, "{:?}", cl.finished_at);
        assert_eq!(report.spot.total_spot, 0);
    }

    /// Spot VM is preempted by an on-demand VM and terminated.
    #[test]
    fn on_demand_preempts_spot_terminate() {
        let mut e = engine();
        let cfg = SpotConfig::terminate().with_min_running(0.0).with_warning(1.0);
        let spot = e.submit_vm(Vm::spot(0, VmSpec::new(1000.0, 8), cfg));
        e.submit_cloudlet(Cloudlet::new(0, 1_000_000.0, 8).with_vm(spot));
        // On-demand arrives at t=5 and needs the whole host.
        let od = e.submit_vm(Vm::on_demand(0, VmSpec::new(1000.0, 8)).with_delay(5.0));
        e.submit_cloudlet(Cloudlet::new(0, 8_000.0, 8).with_vm(od));
        e.terminate_at(100.0);
        let report = e.run();
        assert_eq!(e.world.vms[spot].state, VmState::Terminated);
        assert_eq!(e.world.vms[od].state, VmState::Finished);
        assert_eq!(report.spot.interruptions, 1);
        assert_eq!(e.world.vms[spot].interruptions, 1);
        // OD placed right after the 1 s warning.
        let od_start = e.world.vms[od].history.first_start().unwrap();
        assert!(od_start >= 6.0 - 1e-6 && od_start < 8.0, "od_start {od_start}");
    }

    /// Hibernated spot resumes when the on-demand VM finishes.
    #[test]
    fn spot_hibernates_and_resumes() {
        let mut e = engine();
        let cfg = SpotConfig::hibernate()
            .with_min_running(0.0)
            .with_warning(0.0)
            .with_hibernation_timeout(1_000.0);
        let spot = e.submit_vm(Vm::spot(0, VmSpec::new(1000.0, 8), cfg).with_persistent(1_000.0));
        // 80_000 MI at 8000 MIPS -> 10 s of work.
        e.submit_cloudlet(Cloudlet::new(0, 80_000.0, 8).with_vm(spot));
        // OD occupies the host from t=5 for 8 s (64_000 MI).
        let od = e.submit_vm(Vm::on_demand(0, VmSpec::new(1000.0, 8)).with_delay(5.0));
        e.submit_cloudlet(Cloudlet::new(0, 64_000.0, 8).with_vm(od));
        e.terminate_at(200.0);
        let report = e.run();

        assert_eq!(e.world.vms[od].state, VmState::Finished);
        assert_eq!(e.world.vms[spot].state, VmState::Finished, "spot resumed and finished");
        assert_eq!(report.spot.interruptions, 1);
        assert_eq!(report.spot.redeployments, 1);
        // The spot executed ~5 s, hibernated ~8 s, then finished remaining ~5 s.
        let gaps = e.world.vms[spot].history.interruption_durations();
        assert_eq!(gaps.len(), 1);
        assert!(gaps[0] >= 7.0 && gaps[0] <= 10.0, "gap {:?}", gaps);
        let avg = e.world.vms[spot].history.average_interruption_time().unwrap();
        assert!(avg > 0.0);
    }

    /// Hibernation timeout terminates a spot that never got capacity back.
    #[test]
    fn hibernation_timeout_terminates() {
        let mut e = engine();
        let cfg = SpotConfig::hibernate()
            .with_min_running(0.0)
            .with_warning(0.0)
            .with_hibernation_timeout(20.0);
        let spot = e.submit_vm(Vm::spot(0, VmSpec::new(1000.0, 8), cfg));
        e.submit_cloudlet(Cloudlet::new(0, 1_000_000.0, 8).with_vm(spot));
        // OD hogs the host for a very long time.
        let od = e.submit_vm(Vm::on_demand(0, VmSpec::new(1000.0, 8)).with_delay(5.0));
        e.submit_cloudlet(Cloudlet::new(0, 2_000_000.0, 8).with_vm(od));
        e.terminate_at(100.0);
        e.run();
        assert_eq!(e.world.vms[spot].state, VmState::Terminated);
        // Terminated at hibernation + timeout = 5 + 20 = 25.
        let stopped = e.world.vms[spot].stopped_at.unwrap();
        assert!((stopped - 25.0).abs() < 1.0, "stopped {stopped}");
    }

    /// Non-persistent VM fails immediately when nothing fits; persistent
    /// VM waits and then expires.
    #[test]
    fn waiting_and_expiry() {
        let mut e = engine();
        // Occupy the host fully with on-demand work for 50 s.
        let od = e.submit_vm(Vm::on_demand(0, VmSpec::new(1000.0, 8)));
        e.submit_cloudlet(Cloudlet::new(0, 400_000.0, 8).with_vm(od));

        let fail_fast = e.submit_vm(Vm::on_demand(0, VmSpec::new(1000.0, 4)).with_delay(1.0));
        let waits = e
            .submit_vm(Vm::spot(0, VmSpec::new(1000.0, 4), SpotConfig::hibernate())
                .with_persistent(10.0)
                .with_delay(1.0));
        e.terminate_at(200.0);
        e.run();
        // No preemption possible (no spot victims; the od VM is not
        // interruptible), so the od request fails fast.
        assert_eq!(e.world.vms[fail_fast].state, VmState::Failed);
        // The persistent spot waited 10 s (< 50) and expired.
        assert_eq!(e.world.vms[waits].state, VmState::Failed);
        let stopped = e.world.vms[waits].stopped_at.unwrap();
        assert!((stopped - 11.0).abs() < 1.0, "stopped {stopped}");
    }

    /// Persistent request is fulfilled when capacity frees up in time.
    #[test]
    fn persistent_request_fulfilled_later() {
        let mut e = engine();
        let od = e.submit_vm(Vm::on_demand(0, VmSpec::new(1000.0, 8)));
        // 10 s of work.
        e.submit_cloudlet(Cloudlet::new(0, 80_000.0, 8).with_vm(od));
        let late = e.submit_vm(
            Vm::spot(0, VmSpec::new(1000.0, 4), SpotConfig::hibernate())
                .with_persistent(60.0)
                .with_delay(1.0),
        );
        e.submit_cloudlet(Cloudlet::new(0, 4_000.0, 4).with_vm(late));
        e.terminate_at(100.0);
        e.run();
        assert_eq!(e.world.vms[late].state, VmState::Finished);
        let start = e.world.vms[late].history.first_start().unwrap();
        assert!(start >= 10.0 - 1e-6, "start {start}");
    }

    /// Host removal evicts VMs: spot per behavior, on-demand requeues.
    #[test]
    fn host_removal_evicts() {
        let mut e = engine();
        // Second host so the on-demand VM can land somewhere afterwards.
        let dc = 0;
        let h2 = e.add_host_at(dc, HostSpec::new(8, 1000.0, 16_384.0, 10_000.0, 1_000_000.0), 20.0);
        let od = e.submit_vm(Vm::on_demand(0, VmSpec::new(1000.0, 4)));
        e.submit_cloudlet(Cloudlet::new(0, 400_000.0, 4).with_vm(od));
        let spot = e.submit_vm(Vm::spot(
            0,
            VmSpec::new(1000.0, 2),
            SpotConfig::terminate().with_min_running(0.0),
        ));
        e.submit_cloudlet(Cloudlet::new(0, 400_000.0, 2).with_vm(spot));
        e.remove_host_at(0, 10.0);
        e.terminate_at(400.0);
        let report = e.run();

        assert_eq!(e.world.vms[spot].state, VmState::Terminated);
        // OD requeued at t=10, resumed on host 2 when it appears at t=20.
        assert_eq!(e.world.vms[od].state, VmState::Finished);
        let intervals = e.world.vms[od].history.intervals();
        assert_eq!(intervals.len(), 2);
        assert_eq!(intervals[1].host, h2);
        assert!(report.spot.interruptions >= 1);
    }

    /// An engine built on recycled scratch behaves exactly like a fresh
    /// one - even when the previous run left data in every buffer.
    #[test]
    fn scratch_reuse_is_behavior_neutral() {
        let run = |scratch: EngineScratch| {
            let mut cfg = EngineConfig::default();
            cfg.min_dt = 0.1;
            cfg.vm_destruction_delay = 0.0;
            cfg.resubmit_cooldown = 1.0;
            let mut e = Engine::with_scratch(cfg, Box::new(FirstFit::new()), scratch);
            let dc = e.add_datacenter("dc0", 1.0);
            e.add_host(dc, HostSpec::new(8, 1000.0, 16_384.0, 10_000.0, 1_000_000.0));
            let spot_cfg = SpotConfig::hibernate()
                .with_min_running(0.0)
                .with_warning(0.0)
                .with_hibernation_timeout(1_000.0);
            let spot = e
                .submit_vm(Vm::spot(0, VmSpec::new(1000.0, 8), spot_cfg).with_persistent(1_000.0));
            e.submit_cloudlet(Cloudlet::new(0, 80_000.0, 8).with_vm(spot));
            let od = e.submit_vm(Vm::on_demand(0, VmSpec::new(1000.0, 8)).with_delay(5.0));
            e.submit_cloudlet(Cloudlet::new(0, 64_000.0, 8).with_vm(od));
            e.terminate_at(200.0);
            let report = e.run();
            let series_csv = e.recorder.series.to_csv().to_string();
            let events = e.recorder.events.len();
            (report, series_csv, events, e.into_scratch())
        };
        let (r1, s1, ev1, scratch) = run(EngineScratch::new());
        let (r2, s2, ev2, _) = run(scratch);
        assert_eq!(r1.events_processed, r2.events_processed);
        assert_eq!(r1.clock_end, r2.clock_end);
        assert_eq!(r1.spot.interruptions, r2.spot.interruptions);
        assert_eq!(r1.spot.redeployments, r2.spot.redeployments);
        assert_eq!(s1, s2, "sampled series must be identical on recycled scratch");
        assert_eq!(ev1, ev2);
    }

    /// Engine counters are populated, internally consistent, and exactly
    /// reproducible (they depend only on the event stream).
    #[test]
    fn counters_track_activity_deterministically() {
        let run = || {
            let mut e = engine();
            let cfg = SpotConfig::terminate().with_min_running(0.0).with_warning(1.0);
            let spot = e.submit_vm(Vm::spot(0, VmSpec::new(1000.0, 8), cfg));
            e.submit_cloudlet(Cloudlet::new(0, 1_000_000.0, 8).with_vm(spot));
            let od = e.submit_vm(Vm::on_demand(0, VmSpec::new(1000.0, 8)).with_delay(5.0));
            e.submit_cloudlet(Cloudlet::new(0, 8_000.0, 8).with_vm(od));
            e.terminate_at(100.0);
            e.run();
            let popped = e.sim.processed_events();
            (e.into_scratch().counters(), popped)
        };
        let (c1, popped) = run();
        let (c2, _) = run();
        assert_eq!(c1, c2, "counters must be deterministic");
        assert_eq!(c1.events_popped, popped, "batch counting must match the kernel");
        assert!(c1.placement_probes >= c1.placement_hits, "{c1:?}");
        assert!(c1.placement_hits >= 2, "both VMs were placed: {c1:?}");
        assert!(c1.preemption_scans >= 1, "the od VM had to preempt: {c1:?}");
        assert!(c1.queue_high_water >= 2, "{c1:?}");
        assert_eq!(c1.chaos_events, 0, "chaos-free run");
        assert_eq!(c1.recovery_events, 0, "recovery-free run");
    }

    /// Install a compiled recovery schedule with a bandwidth high enough
    /// that every warned checkpoint is full.
    fn apply_recovery(e: &mut Engine, mode: crate::recovery::RecoveryMode) {
        let spec = crate::recovery::RecoverySpec {
            mode: Some(mode),
            bandwidth: Some(1_000_000.0),
            checkpoint_threshold: Some(0.25),
        };
        let sched = crate::recovery::compile(&spec, 0, 10_000.0);
        crate::recovery::apply(e, &std::sync::Arc::new(sched));
    }

    /// Checkpoint mode: a terminate-behavior interruption keeps the work
    /// saved at the start of the warning window and the VM survives as a
    /// requeue instead of dying.
    #[test]
    fn checkpoint_requeue_recovers_warned_work() {
        let mut e = engine();
        apply_recovery(&mut e, crate::recovery::RecoveryMode::Checkpoint);
        let cfg = SpotConfig::terminate().with_min_running(0.0).with_warning(2.0);
        let spot = e.submit_vm(Vm::spot(0, VmSpec::new(1000.0, 8), cfg));
        // 1_000_000 MI at 8000 MIPS; warned at t=5 with 40_000 MI done.
        e.submit_cloudlet(Cloudlet::new(0, 1_000_000.0, 8).with_vm(spot));
        let od = e.submit_vm(Vm::on_demand(0, VmSpec::new(1000.0, 8)).with_delay(5.0));
        e.submit_cloudlet(Cloudlet::new(0, 8_000.0, 8).with_vm(od));
        e.terminate_at(300.0);
        let report = e.run();

        assert_eq!(e.world.vms[spot].state, VmState::Finished, "spot survived the kill");
        assert_eq!(report.recovery.checkpoints, 1);
        assert!(report.recovery.checkpoint_mb > 0.0);
        // Checkpoint snapshot at warn time (t=5): 40_000 MI; the 2 s of
        // progress made during the warning window (16_000 MI) is lost.
        let rec = report.recovery.work_recovered_mi;
        assert!((39_000.0..41_000.0).contains(&rec), "recovered {rec}");
        assert!(report.recovery.work_lost_mi >= 15_000.0, "{report:?}");
        assert!(report.recovery.recovered_fraction > 0.5, "{report:?}");
        assert!(report.recovery.requeue_p50_s > 0.0);
        assert!(report.recovery.requeue_max_s >= report.recovery.requeue_p50_s);
    }

    /// Restart mode: the VM survives as a requeue but carries zero
    /// progress across the interruption (no checkpoint is ever taken).
    #[test]
    fn restart_requeue_loses_all_progress() {
        let mut e = engine();
        apply_recovery(&mut e, crate::recovery::RecoveryMode::Restart);
        let cfg = SpotConfig::terminate().with_min_running(0.0).with_warning(2.0);
        let spot = e.submit_vm(Vm::spot(0, VmSpec::new(1000.0, 8), cfg));
        e.submit_cloudlet(Cloudlet::new(0, 1_000_000.0, 8).with_vm(spot));
        let od = e.submit_vm(Vm::on_demand(0, VmSpec::new(1000.0, 8)).with_delay(5.0));
        e.submit_cloudlet(Cloudlet::new(0, 8_000.0, 8).with_vm(od));
        e.terminate_at(300.0);
        let report = e.run();

        assert_eq!(e.world.vms[spot].state, VmState::Finished, "spot survived the kill");
        assert_eq!(report.recovery.checkpoints, 0);
        assert_eq!(report.recovery.work_recovered_mi, 0.0);
        assert_eq!(report.recovery.recovered_fraction, 0.0);
        assert!(report.recovery.work_lost_mi >= 55_000.0, "{report:?}");
        assert_eq!(report.recovery.migrations, 0);
    }

    /// Migrate mode: the displaced VM's checkpoint is transferred to the
    /// other (feasible) host and it resumes there.
    #[test]
    fn migrate_moves_displaced_vm_to_feasible_host() {
        let mut e = engine();
        let h2 = e.add_host(0, HostSpec::new(4, 1000.0, 16_384.0, 10_000.0, 1_000_000.0));
        apply_recovery(&mut e, crate::recovery::RecoveryMode::MigrateGreedy);
        let cfg = SpotConfig::terminate().with_min_running(0.0).with_warning(2.0);
        // 4-PE spot on host 0; the 8-PE on-demand VM fits neither host
        // without preempting it, and host 0 is full once the OD lands, so
        // the matcher must route the displaced spot to host 2.
        let spot = e.submit_vm(Vm::spot(0, VmSpec::new(1000.0, 4), cfg));
        e.submit_cloudlet(Cloudlet::new(0, 1_000_000.0, 4).with_vm(spot));
        let od = e.submit_vm(Vm::on_demand(0, VmSpec::new(1000.0, 8)).with_delay(5.0));
        e.submit_cloudlet(Cloudlet::new(0, 2_000_000.0, 8).with_vm(od));
        e.terminate_at(400.0);
        let report = e.run();

        assert_eq!(report.recovery.checkpoints, 1);
        assert_eq!(report.recovery.migrations, 1, "{report:?}");
        assert_eq!(report.recovery.failed_migrations, 0, "{report:?}");
        assert_eq!(e.world.vms[spot].state, VmState::Finished);
        let intervals = e.world.vms[spot].history.intervals();
        assert_eq!(intervals.last().unwrap().host, h2, "resumed on the other host");
        assert!(report.recovery.recovered_fraction > 0.5, "{report:?}");
    }

    /// Regression: a hibernated VM that resumed and re-hibernated must not
    /// be killed by the *first* hibernation's leftover timeout event - only
    /// the second hibernation's own deadline may fire.
    #[test]
    fn stale_hibernation_timeout_does_not_kill_rehibernated_vm() {
        let mut e = engine();
        let cfg = SpotConfig::hibernate()
            .with_min_running(0.0)
            .with_warning(0.0)
            .with_hibernation_timeout(30.0);
        let spot = e.submit_vm(Vm::spot(0, VmSpec::new(1000.0, 8), cfg).with_persistent(1_000.0));
        e.submit_cloudlet(Cloudlet::new(0, 1_000_000.0, 8).with_vm(spot));
        // OD 1 displaces the spot at t=5 for ~1 s (timeout armed for t=35).
        let od1 = e.submit_vm(Vm::on_demand(0, VmSpec::new(1000.0, 8)).with_delay(5.0));
        e.submit_cloudlet(Cloudlet::new(0, 8_000.0, 8).with_vm(od1));
        // OD 2 displaces it again at t=20 and holds the host past t=50
        // (new timeout armed for t=50; the t=35 event is now stale).
        let od2 = e.submit_vm(Vm::on_demand(0, VmSpec::new(1000.0, 8)).with_delay(20.0));
        e.submit_cloudlet(Cloudlet::new(0, 2_000_000.0, 8).with_vm(od2));
        e.terminate_at(300.0);
        e.run();

        assert_eq!(e.world.vms[spot].state, VmState::Terminated);
        let stopped = e.world.vms[spot].stopped_at.unwrap();
        assert!(
            (stopped - 50.0).abs() < 1.0,
            "second hibernation must get its full window, stopped {stopped}"
        );
        let timeouts = e
            .recorder
            .events
            .iter()
            .filter(|ev| ev.kind == LifecycleKind::HibernationTimedOut)
            .count();
        assert_eq!(timeouts, 1, "exactly one (non-stale) timeout fired");
        assert_eq!(e.recorder.hibernations, 2);
    }

    /// Deterministic: identical seeds/config produce identical reports.
    #[test]
    fn deterministic_event_order() {
        let run = || {
            let mut e = engine();
            for i in 0..10 {
                let vm = e.submit_vm(
                    Vm::on_demand(0, VmSpec::new(1000.0, 1)).with_delay(i as f64 * 0.3),
                );
                e.submit_cloudlet(Cloudlet::new(0, 10_000.0, 1).with_vm(vm));
            }
            e.terminate_at(500.0);
            let r = e.run();
            (r.clock_end, r.events_processed, e.sim.processed_events())
        };
        assert_eq!(run(), run());
    }
}
