//! Cloudlet progress backends - the simulation's hot loop.
//!
//! The paper measured cloudlet execution updates as the dominant cost of
//! trace-scale runs and named parallelization as future work (§VII-D.1).
//! The engine therefore treats the per-tick progress update as a swappable
//! backend and ships three implementations ablated in
//! `benches/perf_progress.rs`:
//!
//! - [`NaiveBackend`]: per-object scalar walk (the CloudSim-style baseline),
//! - [`BatchedBackend`]: tight chunked loop over parallel arrays
//!   (autovectorizes; the pure-rust production default),
//! - `runtime::PjrtStep` via [`PjrtBackend`]: executes the AOT-compiled
//!   `cloudlet_step` artifact (the L1 pallas kernel) through PJRT.

/// Advances `remaining -= mips * dt` (clamped at 0) over parallel arrays;
/// pushes indices of slots that crossed to completion into `finished`.
pub trait ProgressBackend {
    fn name(&self) -> &'static str;
    fn step(&mut self, remaining: &mut [f64], mips: &[f64], dt: f64, finished: &mut Vec<usize>);
}

/// Per-element scalar walk with per-slot branches - mirrors the per-object
/// update loop of the Java original. Baseline for the §Perf ablation.
pub struct NaiveBackend;

impl ProgressBackend for NaiveBackend {
    fn name(&self) -> &'static str {
        "naive"
    }

    fn step(&mut self, remaining: &mut [f64], mips: &[f64], dt: f64, finished: &mut Vec<usize>) {
        for i in 0..remaining.len() {
            if remaining[i] > 0.0 {
                let done = mips[i] * dt;
                if done >= remaining[i] {
                    remaining[i] = 0.0;
                    finished.push(i);
                } else {
                    remaining[i] -= done;
                }
            }
        }
    }
}

/// Branch-light two-pass update over parallel arrays: pass 1 is a pure
/// mul-sub-max loop the compiler autovectorizes; pass 2 collects the (rare)
/// completions. This is the paper's "parallelization" realized with SIMD
/// instead of threads - same arithmetic as the pallas kernel.
pub struct BatchedBackend;

impl ProgressBackend for BatchedBackend {
    fn name(&self) -> &'static str {
        "batched"
    }

    fn step(&mut self, remaining: &mut [f64], mips: &[f64], dt: f64, finished: &mut Vec<usize>) {
        debug_assert_eq!(remaining.len(), mips.len());
        // Single fused pass over zipped slices: no bounds checks, no
        // temporary allocation, branchless arithmetic with a (rare)
        // completion push. ~2x the two-pass + scratch-Vec variant this
        // replaced (see EXPERIMENTS.md §Perf iteration log).
        for (i, (r, m)) in remaining.iter_mut().zip(mips.iter()).enumerate() {
            let old = *r;
            let nxt = (old - *m * dt).max(0.0);
            *r = nxt;
            if old > 0.0 && nxt <= 0.0 {
                finished.push(i);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backends() -> Vec<Box<dyn ProgressBackend>> {
        vec![Box::new(NaiveBackend), Box::new(BatchedBackend)]
    }

    #[test]
    fn all_backends_agree() {
        for mut b in backends() {
            let mut rem = vec![1000.0, 500.0, 0.0, 50.0];
            let mips = vec![100.0, 100.0, 100.0, 100.0];
            let mut fin = Vec::new();
            b.step(&mut rem, &mips, 1.0, &mut fin);
            assert_eq!(rem, vec![900.0, 400.0, 0.0, 0.0], "{}", b.name());
            assert_eq!(fin, vec![3], "{}", b.name());
        }
    }

    #[test]
    fn finished_slots_do_not_refire() {
        for mut b in backends() {
            let mut rem = vec![100.0];
            let mips = vec![200.0];
            let mut fin = Vec::new();
            b.step(&mut rem, &mips, 1.0, &mut fin);
            assert_eq!(fin, vec![0]);
            fin.clear();
            b.step(&mut rem, &mips, 1.0, &mut fin);
            assert!(fin.is_empty(), "{}", b.name());
        }
    }

    #[test]
    fn zero_dt_is_noop() {
        for mut b in backends() {
            let mut rem = vec![10.0, 20.0];
            let mips = vec![100.0, 100.0];
            let mut fin = Vec::new();
            b.step(&mut rem, &mips, 0.0, &mut fin);
            assert_eq!(rem, vec![10.0, 20.0]);
            assert!(fin.is_empty());
        }
    }

    #[test]
    fn backends_agree_on_random_input() {
        use crate::stats::Rng;
        let mut rng = Rng::new(99);
        let n = 2048;
        let rem0: Vec<f64> = (0..n)
            .map(|_| if rng.chance(0.2) { 0.0 } else { rng.uniform(1.0, 1e6) })
            .collect();
        let mips: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 5e3)).collect();
        let dt = 3.7;

        let mut rem_a = rem0.clone();
        let mut fin_a = Vec::new();
        NaiveBackend.step(&mut rem_a, &mips, dt, &mut fin_a);

        let mut rem_b = rem0.clone();
        let mut fin_b = Vec::new();
        BatchedBackend.step(&mut rem_b, &mips, dt, &mut fin_b);

        assert_eq!(rem_a, rem_b);
        fin_a.sort_unstable();
        fin_b.sort_unstable();
        assert_eq!(fin_a, fin_b);
    }
}
