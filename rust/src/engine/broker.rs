//! Broker state: the `DatacenterBrokerDynamic` of the paper (§V-E(a)) -
//! tracks waiting (persistent) requests and the *resubmittingList* of
//! interrupted/hibernated VMs awaiting reallocation.

use crate::vm::VmId;

/// User-side agent bookkeeping. The allocation *mechanics* live in the
/// engine; the broker holds the queues and retry ordering policy.
#[derive(Debug, Default)]
pub struct Broker {
    /// Persistent requests not yet placed (first allocation pending).
    /// Entries: (vm, deadline) - the request expires at `deadline`.
    pub waiting: Vec<(VmId, f64)>,
    /// Hibernated VMs awaiting reallocation (the paper's resubmittingList).
    pub resubmitting: Vec<VmId>,
    /// VMs that reached a final state, in completion order.
    pub finished: Vec<VmId>,
}

impl Broker {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn enqueue_waiting(&mut self, vm: VmId, deadline: f64) {
        debug_assert!(!self.waiting.iter().any(|&(v, _)| v == vm));
        self.waiting.push((vm, deadline));
    }

    pub fn remove_waiting(&mut self, vm: VmId) -> bool {
        if let Some(i) = self.waiting.iter().position(|&(v, _)| v == vm) {
            self.waiting.remove(i);
            true
        } else {
            false
        }
    }

    pub fn enqueue_resubmitting(&mut self, vm: VmId) {
        debug_assert!(!self.resubmitting.contains(&vm));
        self.resubmitting.push(vm);
    }

    pub fn remove_resubmitting(&mut self, vm: VmId) -> bool {
        if let Some(i) = self.resubmitting.iter().position(|&v| v == vm) {
            self.resubmitting.remove(i);
            true
        } else {
            false
        }
    }

    /// Retry order after capacity frees up: waiting on-demand first (they
    /// are the cause of interruptions and must not starve), then hibernated
    /// spots (resubmittingList), then waiting spots - each FIFO. Clears
    /// and fills `out` - the engine reuses one buffer across all
    /// `retry_pending` invocations (every deallocation fires one).
    ///
    /// `is_spot(vm)` is supplied by the engine to keep the broker free of
    /// world borrows.
    pub fn retry_order_into(&self, is_spot: impl Fn(VmId) -> bool, out: &mut Vec<VmId>) {
        out.clear();
        out.extend(self.waiting.iter().map(|&(v, _)| v).filter(|&v| !is_spot(v)));
        out.extend(self.resubmitting.iter().copied());
        out.extend(self.waiting.iter().map(|&(v, _)| v).filter(|&v| is_spot(v)));
    }

    /// Allocating convenience wrapper around [`Self::retry_order_into`].
    pub fn retry_order(&self, is_spot: impl Fn(VmId) -> bool) -> Vec<VmId> {
        let mut out = Vec::with_capacity(self.waiting.len() + self.resubmitting.len());
        self.retry_order_into(is_spot, &mut out);
        out
    }

    pub fn queue_depth(&self) -> usize {
        self.waiting.len() + self.resubmitting.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retry_order_prioritizes_on_demand_then_hibernated() {
        let mut b = Broker::new();
        b.enqueue_waiting(1, 10.0); // spot
        b.enqueue_waiting(2, 10.0); // od
        b.enqueue_waiting(3, 10.0); // spot
        b.enqueue_resubmitting(4);
        b.enqueue_resubmitting(5);
        let order = b.retry_order(|v| v != 2);
        assert_eq!(order, vec![2, 4, 5, 1, 3]);
    }

    #[test]
    fn remove_is_idempotent() {
        let mut b = Broker::new();
        b.enqueue_waiting(1, 5.0);
        assert!(b.remove_waiting(1));
        assert!(!b.remove_waiting(1));
        b.enqueue_resubmitting(2);
        assert!(b.remove_resubmitting(2));
        assert!(!b.remove_resubmitting(2));
        assert_eq!(b.queue_depth(), 0);
    }
}
