//! Incremental placement index: the world-level candidate structures the
//! allocation hot path runs on.
//!
//! Before this index every placement decision re-derived cluster state
//! from scratch: `FirstFit`/`BestFit`/`WorstFit` walked every active host
//! and HLEM-VMP additionally rebuilt per-host spot-usage vectors by
//! walking every VM on every candidate - O(hosts x vms-per-host) per
//! decision (the paper's measured simulator overhead, SVII-D). The index
//! turns the per-decision cost into a function of the *feasible* candidate
//! set only:
//!
//! - **Free-PE buckets**: `buckets[p]` holds the ids of active hosts with
//!   exactly `p` free PEs, ordered by id (`BTreeSet`). A placement query
//!   for a `k`-PE request touches only buckets `p >= k`. Updated O(log H)
//!   on every commit/release/host add/remove.
//! - **Spot-host set**: the ids of active hosts currently carrying at
//!   least one spot VM, ordered by id. The preemption scan enumerates
//!   only these (a host without spot VMs can never yield victims).
//!
//! Query order is chosen to reproduce the pre-index linear scans
//! *bit-identically* (deterministic tie-breaks on host id):
//!
//! - `first_fit`: lowest id over all feasible buckets = first hit of an
//!   id-ascending scan.
//! - `best_fit`: lowest bucket, id-ascending within = `min_by_key`
//!   (which keeps the **first** minimal element).
//! - `worst_fit`: highest bucket, id-**descending** within =
//!   `max_by_key` (which keeps the **last** maximal element).
//! - `feasible_into`: the union of feasible buckets sorted ascending =
//!   the id-ascending feasible list HLEM's phase-1 filter used to build
//!   by scanning; identical ordering keeps the entropy-weight float
//!   summation bit-identical.
//!
//! The per-host spot-usage vectors live on [`crate::infra::Host`]
//! (`spot_used` / `spot_vms`), refreshed by [`super::world::World`] on
//! every spot commit/release/interrupt by re-walking that host's VM list
//! in allocation order. The walk is bounded by VMs-per-host (itself
//! bounded by the host's PE count) and reproduces the old
//! `spot_used_vec` summation order exactly, so incremental reads are
//! bitwise equal to a recompute-from-scratch oracle - no floating-point
//! drift, which a running +=/-= accumulator could not guarantee.

use std::collections::BTreeSet;
use std::ops::Bound;

use crate::infra::HostId;

/// World-level incremental candidate index (see module docs).
#[derive(Debug, Default, Clone)]
pub struct PlacementIndex {
    /// `buckets[p]` = active hosts with exactly `p` free PEs, id-ordered.
    buckets: Vec<BTreeSet<HostId>>,
    /// Active hosts carrying at least one spot VM, id-ordered.
    spot_hosts: BTreeSet<HostId>,
    /// Bucket each indexed host currently sits in (`None` = not indexed,
    /// i.e. the host is inactive/removed).
    free_of: Vec<Option<u32>>,
}

impl PlacementIndex {
    pub fn new() -> Self {
        Self::default()
    }

    fn ensure_host_slot(&mut self, h: HostId) {
        if self.free_of.len() <= h {
            self.free_of.resize(h + 1, None);
        }
    }

    fn ensure_bucket(&mut self, p: u32) {
        if self.buckets.len() <= p as usize {
            self.buckets.resize(p as usize + 1, BTreeSet::new());
        }
    }

    /// Whether `h` is currently indexed (= active).
    pub fn contains(&self, h: HostId) -> bool {
        self.free_of.get(h).copied().flatten().is_some()
    }

    /// Tracked free-PE count of `h`, if indexed.
    pub fn free_pes_of(&self, h: HostId) -> Option<u32> {
        self.free_of.get(h).copied().flatten()
    }

    /// Index an (active) host with the given free-PE count. Idempotent:
    /// re-inserting moves the host to the right bucket (and re-inserting
    /// with the same count is a no-op). `World::activate_host` guards
    /// against duplicate activation before calling this, so the sampling
    /// counters never see a double-add even though the index itself
    /// would tolerate one.
    pub fn insert(&mut self, h: HostId, free_pes: u32) {
        self.ensure_host_slot(h);
        if let Some(old) = self.free_of[h] {
            if old == free_pes {
                return;
            }
            self.buckets[old as usize].remove(&h);
        }
        self.ensure_bucket(free_pes);
        self.buckets[free_pes as usize].insert(h);
        self.free_of[h] = Some(free_pes);
    }

    /// Drop a host from the index (host removal / deactivation). Also
    /// clears its spot-host membership. No-op if not indexed.
    pub fn remove(&mut self, h: HostId) {
        self.ensure_host_slot(h);
        if let Some(old) = self.free_of[h].take() {
            self.buckets[old as usize].remove(&h);
        }
        self.spot_hosts.remove(&h);
    }

    /// Move an indexed host to the bucket matching its new free-PE count.
    pub fn update_free(&mut self, h: HostId, free_pes: u32) {
        debug_assert!(self.contains(h), "update_free on unindexed host {h}");
        self.insert(h, free_pes);
    }

    /// Record whether `h` currently carries spot VMs. Only meaningful for
    /// indexed (active) hosts; removal clears membership regardless.
    pub fn set_spot(&mut self, h: HostId, has_spot: bool) {
        if has_spot && self.contains(h) {
            self.spot_hosts.insert(h);
        } else {
            self.spot_hosts.remove(&h);
        }
    }

    /// Active hosts with at least one spot VM, ascending by id.
    pub fn spot_host_ids(&self) -> impl Iterator<Item = HostId> + '_ {
        self.spot_hosts.iter().copied()
    }

    /// Number of indexed hosts (active cluster size).
    pub fn len(&self) -> usize {
        self.buckets.iter().map(|b| b.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.buckets.iter().all(|b| b.is_empty())
    }

    /// Lowest host id strictly greater than `after` (any id when `None`)
    /// with `free_pes >= min_pes`. Pure index query, one O(log H) probe
    /// per feasible bucket; the caller applies the full four-dimension
    /// fitness check and re-probes on rejection.
    pub fn first_feasible_after(&self, min_pes: u32, after: Option<HostId>) -> Option<HostId> {
        let lo = min_pes as usize;
        if lo >= self.buckets.len() {
            return None;
        }
        let mut best: Option<HostId> = None;
        for bucket in &self.buckets[lo..] {
            let next = match after {
                None => bucket.iter().next(),
                Some(a) => bucket.range((Bound::Excluded(a), Bound::Unbounded)).next(),
            };
            if let Some(&id) = next {
                if best.map_or(true, |b| id < b) {
                    best = Some(id);
                }
            }
        }
        best
    }

    /// Lowest host id with `free_pes >= min_pes` passing `ok` (the full
    /// four-dimension fitness check). Reproduces an id-ascending linear
    /// scan's first hit. Callers on the hot path should prefer the
    /// bounded-probe hybrid (`World::first_fit_host`), which caps the
    /// re-probe cost when `ok` rejects many PE-feasible hosts.
    pub fn first_fit(&self, min_pes: u32, mut ok: impl FnMut(HostId) -> bool) -> Option<HostId> {
        let mut after: Option<HostId> = None;
        loop {
            match self.first_feasible_after(min_pes, after) {
                None => return None,
                Some(id) if ok(id) => return Some(id),
                Some(id) => after = Some(id),
            }
        }
    }

    /// Feasible host with the fewest free PEs; ties to the lowest id
    /// (matches `min_by_key` over an id-ascending scan).
    pub fn best_fit(&self, min_pes: u32, mut ok: impl FnMut(HostId) -> bool) -> Option<HostId> {
        for p in (min_pes as usize)..self.buckets.len() {
            for &id in &self.buckets[p] {
                if ok(id) {
                    return Some(id);
                }
            }
        }
        None
    }

    /// Feasible host with the most free PEs; ties to the **highest** id
    /// (matches `max_by_key`, which keeps the last maximal element of an
    /// id-ascending scan).
    pub fn worst_fit(&self, min_pes: u32, mut ok: impl FnMut(HostId) -> bool) -> Option<HostId> {
        let lo = min_pes as usize;
        if lo >= self.buckets.len() {
            return None;
        }
        for p in (lo..self.buckets.len()).rev() {
            for &id in self.buckets[p].iter().rev() {
                if ok(id) {
                    return Some(id);
                }
            }
        }
        None
    }

    /// Append every host with `free_pes >= min_pes` passing `ok` to `out`
    /// in ascending id order (the pre-index scan order). `out` is cleared
    /// first.
    pub fn feasible_into(
        &self,
        min_pes: u32,
        mut ok: impl FnMut(HostId) -> bool,
        out: &mut Vec<HostId>,
    ) {
        out.clear();
        let lo = min_pes as usize;
        if lo >= self.buckets.len() {
            return;
        }
        for bucket in &self.buckets[lo..] {
            out.extend(bucket.iter().copied());
        }
        out.sort_unstable();
        out.retain(|&id| ok(id));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn index_with(frees: &[u32]) -> PlacementIndex {
        let mut ix = PlacementIndex::new();
        for (h, &f) in frees.iter().enumerate() {
            ix.insert(h, f);
        }
        ix
    }

    #[test]
    fn first_fit_lowest_id_across_buckets() {
        let ix = index_with(&[2, 8, 4, 8]);
        assert_eq!(ix.first_fit(1, |_| true), Some(0));
        assert_eq!(ix.first_fit(3, |_| true), Some(1));
        assert_eq!(ix.first_fit(3, |h| h != 1), Some(2));
        assert_eq!(ix.first_fit(9, |_| true), None);
    }

    #[test]
    fn best_fit_tightest_then_lowest_id() {
        let ix = index_with(&[8, 4, 4, 2]);
        assert_eq!(ix.best_fit(1, |_| true), Some(3));
        assert_eq!(ix.best_fit(3, |_| true), Some(1)); // first of the 4-free pair
        assert_eq!(ix.best_fit(3, |h| h != 1), Some(2));
    }

    #[test]
    fn worst_fit_emptiest_then_highest_id() {
        let ix = index_with(&[8, 4, 8, 2]);
        assert_eq!(ix.worst_fit(1, |_| true), Some(2)); // last of the 8-free pair
        assert_eq!(ix.worst_fit(1, |h| h != 2), Some(0));
        assert_eq!(ix.worst_fit(16, |_| true), None);
    }

    #[test]
    fn feasible_into_is_id_sorted() {
        let ix = index_with(&[8, 2, 4, 8, 1]);
        let mut out = Vec::new();
        ix.feasible_into(2, |_| true, &mut out);
        assert_eq!(out, vec![0, 1, 2, 3]);
        ix.feasible_into(5, |_| true, &mut out);
        assert_eq!(out, vec![0, 3]);
    }

    #[test]
    fn updates_move_between_buckets() {
        let mut ix = index_with(&[4, 4]);
        ix.update_free(0, 1);
        assert_eq!(ix.best_fit(1, |_| true), Some(0));
        ix.update_free(0, 6);
        assert_eq!(ix.worst_fit(1, |_| true), Some(0));
        assert_eq!(ix.free_pes_of(0), Some(6));
        assert_eq!(ix.len(), 2);
    }

    #[test]
    fn remove_clears_membership_and_spot() {
        let mut ix = index_with(&[4, 4]);
        ix.set_spot(0, true);
        assert_eq!(ix.spot_host_ids().collect::<Vec<_>>(), vec![0]);
        ix.remove(0);
        assert!(!ix.contains(0));
        assert_eq!(ix.spot_host_ids().count(), 0);
        assert_eq!(ix.first_fit(1, |_| true), Some(1));
        // Re-activation re-indexes.
        ix.insert(0, 2);
        assert!(ix.contains(0));
    }

    #[test]
    fn set_spot_ignores_unindexed_hosts() {
        let mut ix = index_with(&[4]);
        ix.remove(0);
        ix.set_spot(0, true);
        assert_eq!(ix.spot_host_ids().count(), 0);
    }
}
