//! Run report: the aggregate numbers the paper's evaluation section is
//! built from (§VII-D.2 "Final Simulation & Results", Figs. 14-15).

use crate::stats::Summary;
use crate::vm::{VmState, VmType};

use super::Engine;

/// Spot-instance outcome statistics (paper §VII-D.2).
#[derive(Debug, Clone, Default)]
pub struct SpotStats {
    pub total_spot: u64,
    /// Capacity-driven interruption events (Fig. 14 metric).
    pub interruptions: u64,
    /// Spot VMs that experienced >= 1 interruption.
    pub interrupted_vms: u64,
    /// Spot VMs that completed without any interruption.
    pub uninterrupted_completions: u64,
    /// Successful redeployments after hibernation.
    pub redeployments: u64,
    /// Spot VMs that finished *after* being interrupted at least once.
    pub completed_after_interruption: u64,
    /// Spot VMs terminated (interruption-terminate or hibernation timeout).
    pub terminated: u64,
    /// Max interruptions experienced by any single VM.
    pub max_interruptions_per_vm: u32,
    /// Interruption-duration stats over history gaps (seconds).
    pub avg_interruption_secs: f64,
    pub max_interruption_secs: f64,
    pub min_interruption_secs: f64,
}

/// Resilience statistics under injected chaos (crate::chaos): correlated
/// reclaim storms, host crash/recovery, and displacement recovery. All
/// zero for chaos-free runs except the interruption-duration percentile
/// and the work/recovery columns, which also cover organic interruptions.
#[derive(Debug, Clone, Default)]
pub struct ResilienceStats {
    /// Reclaim storms fired and the warnings they issued.
    pub storms: u64,
    pub storm_reclaims: u64,
    /// Chaos host crashes injected.
    pub host_failures: u64,
    /// Displaced VMs that made it back onto a host.
    pub recoveries: u64,
    /// `storm_reclaims / storms` (0 with no storms).
    pub interruptions_per_storm: f64,
    /// 95th-percentile interruption duration over history gaps (seconds).
    pub p95_interruption_secs: f64,
    /// Displacement-to-running latency (time-to-recover), avg and max.
    pub avg_recovery_secs: f64,
    pub max_recovery_secs: f64,
    /// Partially-executed work discarded by terminal states vs carried
    /// across a displacement back onto a host (MI).
    pub work_lost_mi: f64,
    pub work_recovered_mi: f64,
}

/// Cost statistics under the dynamic spot-price market
/// (crate::market). Prices are normalized to an on-demand price of
/// 1.0 $/PE-hour; costs integrate the compiled piecewise-constant price
/// path over each spot VM's host intervals, PE-weighted. All zero for
/// market-free runs.
#[derive(Debug, Clone, Default)]
pub struct MarketStats {
    /// Total spot spend over all spot-VM run intervals ($).
    pub spot_cost_usd: f64,
    /// What the same PE-hours would have cost on-demand ($).
    pub on_demand_cost_usd: f64,
    /// `1 - spot/on-demand` (0 with no on-demand cost).
    pub savings_ratio: f64,
    /// Spot reclaims caused by an upward price crossing.
    pub price_reclaims: u64,
    /// PE-hour-weighted mean spot price paid ($/PE-hour).
    pub mean_price_paid: f64,
    /// Highest tick price overlapping any paid run interval ($/PE-hour).
    pub max_price_paid: f64,
}

/// Work-survival statistics under the recovery subsystem
/// (crate::recovery): grace-window checkpointing and displaced-VM
/// migration. The work/latency columns also cover organic
/// hibernation-resume recoveries, so they are meaningful (and the
/// fraction well-defined) even for recovery-free runs; the
/// checkpoint/migration counts are zero without an active
/// `RecoverySchedule`.
#[derive(Debug, Clone, Default)]
pub struct RecoveryStats {
    /// Checkpoints taken (full or partial) and the MB they transferred
    /// through the warning window.
    pub checkpoints: u64,
    pub checkpoint_mb: f64,
    /// Displaced-VM migrations completed vs dropped at transfer end.
    pub migrations: u64,
    pub failed_migrations: u64,
    /// Work carried back onto a host vs discarded (MI) - same totals as
    /// [`ResilienceStats`], repeated here so the fraction has its parts.
    pub work_recovered_mi: f64,
    pub work_lost_mi: f64,
    /// `recovered / (recovered + lost)` (0 when no work was displaced).
    pub recovered_fraction: f64,
    /// Displacement-to-running latency percentiles (seconds).
    pub requeue_p50_s: f64,
    pub requeue_p95_s: f64,
    pub requeue_max_s: f64,
}

/// Summary of one engine run.
#[derive(Debug, Clone)]
pub struct Report {
    pub policy: &'static str,
    pub clock_end: f64,
    pub events_processed: u64,
    pub wall: std::time::Duration,
    /// VM counts by (type, final state).
    pub finished: u64,
    pub terminated: u64,
    pub failed: u64,
    pub still_active: u64,
    pub cloudlets_finished: u64,
    pub cloudlets_canceled: u64,
    pub alloc_attempts: u64,
    pub alloc_failures: u64,
    pub spot: SpotStats,
    pub resilience: ResilienceStats,
    pub market: MarketStats,
    pub recovery: RecoveryStats,
}

/// Build the report from a finished engine.
pub fn build(engine: &Engine, wall: std::time::Duration) -> Report {
    let w = &engine.world;
    let mut finished = 0;
    let mut terminated = 0;
    let mut failed = 0;
    let mut still_active = 0;

    let mut spot = SpotStats::default();
    let mut gap_stats = Summary::new();
    let mut gaps: Vec<f64> = Vec::new();

    for vm in &w.vms {
        match vm.state {
            VmState::Finished => finished += 1,
            VmState::Terminated => terminated += 1,
            VmState::Failed => failed += 1,
            _ => still_active += 1,
        }
        if vm.vm_type == VmType::Spot {
            spot.total_spot += 1;
            if vm.interruptions > 0 {
                spot.interrupted_vms += 1;
                spot.max_interruptions_per_vm =
                    spot.max_interruptions_per_vm.max(vm.interruptions);
                if vm.state == VmState::Finished {
                    spot.completed_after_interruption += 1;
                }
            } else if vm.state == VmState::Finished {
                spot.uninterrupted_completions += 1;
            }
            if vm.state == VmState::Terminated {
                spot.terminated += 1;
            }
            for gap in vm.history.interruption_durations() {
                gap_stats.add(gap);
                gaps.push(gap);
            }
        }
    }
    spot.interruptions = engine.recorder.interruptions;
    spot.redeployments = engine.recorder.redeployments;
    spot.avg_interruption_secs = if gap_stats.is_empty() { 0.0 } else { gap_stats.mean() };
    spot.max_interruption_secs = if gap_stats.is_empty() { 0.0 } else { gap_stats.max() };
    spot.min_interruption_secs = if gap_stats.is_empty() { 0.0 } else { gap_stats.min() };

    let r = &engine.recorder;
    let mut resilience = ResilienceStats {
        storms: r.storms,
        storm_reclaims: r.storm_reclaims,
        host_failures: r.host_failures,
        recoveries: r.recoveries,
        interruptions_per_storm: if r.storms > 0 {
            r.storm_reclaims as f64 / r.storms as f64
        } else {
            0.0
        },
        p95_interruption_secs: 0.0,
        avg_recovery_secs: if r.recoveries > 0 {
            r.recovery_secs_sum / r.recoveries as f64
        } else {
            0.0
        },
        max_recovery_secs: r.recovery_secs_max,
        work_lost_mi: r.work_lost_mi,
        work_recovered_mi: r.work_recovered_mi,
    };
    if !gaps.is_empty() {
        gaps.sort_by(|a, b| a.partial_cmp(b).expect("non-finite interruption gap"));
        let idx = ((0.95 * gaps.len() as f64).ceil() as usize)
            .saturating_sub(1)
            .min(gaps.len() - 1);
        resilience.p95_interruption_secs = gaps[idx];
    }

    // Market cost accounting: integrate the compiled price path over
    // every spot VM's host intervals (PE-weighted, $/PE-hour prices).
    let market = match engine.market.as_ref() {
        Some(sched) if !sched.is_empty() => {
            let clock_end = engine.sim.clock();
            let mut spot_cost = 0.0;
            let mut od_cost = 0.0;
            let mut pe_secs = 0.0;
            let mut max_price = 0.0f64;
            for vm in w.vms.iter().filter(|vm| vm.vm_type == VmType::Spot) {
                let pes = vm.spec.pes as f64;
                for iv in vm.history.intervals() {
                    let end = iv.stop.unwrap_or(clock_end);
                    if end <= iv.start {
                        continue;
                    }
                    spot_cost += pes * sched.cost_over(iv.start, end) / 3600.0;
                    od_cost += pes * sched.od_price * (end - iv.start) / 3600.0;
                    pe_secs += pes * (end - iv.start);
                    max_price = max_price.max(sched.max_price_over(iv.start, end));
                }
            }
            MarketStats {
                spot_cost_usd: spot_cost,
                on_demand_cost_usd: od_cost,
                savings_ratio: if od_cost > 0.0 { 1.0 - spot_cost / od_cost } else { 0.0 },
                price_reclaims: r.price_reclaims,
                mean_price_paid: if pe_secs > 0.0 { spot_cost * 3600.0 / pe_secs } else { 0.0 },
                max_price_paid: max_price,
            }
        }
        _ => MarketStats::default(),
    };

    // Work-survival accounting: percentiles over the recorded
    // displacement-to-running latency samples (same ceil-index
    // convention as the interruption-duration p95 above).
    let mut recovery = RecoveryStats {
        checkpoints: r.checkpoints,
        checkpoint_mb: r.checkpoint_mb,
        migrations: r.migrations,
        failed_migrations: r.failed_migrations,
        work_recovered_mi: r.work_recovered_mi,
        work_lost_mi: r.work_lost_mi,
        recovered_fraction: {
            let total = r.work_recovered_mi + r.work_lost_mi;
            if total > 0.0 { r.work_recovered_mi / total } else { 0.0 }
        },
        ..Default::default()
    };
    if !r.requeue_latency.is_empty() {
        let mut lat = r.requeue_latency.clone();
        lat.sort_by(|a, b| a.partial_cmp(b).expect("non-finite requeue latency"));
        let pct = |q: f64| {
            let idx = ((q * lat.len() as f64).ceil() as usize)
                .saturating_sub(1)
                .min(lat.len() - 1);
            lat[idx]
        };
        recovery.requeue_p50_s = pct(0.50);
        recovery.requeue_p95_s = pct(0.95);
        recovery.requeue_max_s = lat[lat.len() - 1];
    }

    let mut cl_fin = 0;
    let mut cl_can = 0;
    for cl in &w.cloudlets {
        match cl.state {
            crate::cloudlet::CloudletState::Finished => cl_fin += 1,
            crate::cloudlet::CloudletState::Canceled => cl_can += 1,
            _ => {}
        }
    }

    Report {
        policy: engine.policy_name(),
        clock_end: engine.sim.clock(),
        events_processed: engine.sim.processed_events(),
        wall,
        finished,
        terminated,
        failed,
        still_active,
        cloudlets_finished: cl_fin,
        cloudlets_canceled: cl_can,
        alloc_attempts: engine.recorder.alloc_attempts,
        alloc_failures: engine.recorder.alloc_failures,
        spot,
        resilience,
        market,
        recovery,
    }
}

impl Report {
    /// One-paragraph text rendering (examples print this).
    pub fn render(&self) -> String {
        let s = &self.spot;
        let r = &self.resilience;
        let m = &self.market;
        let rc = &self.recovery;
        format!(
            "policy={} clock_end={:.1}s events={} wall={:?}\n\
             vms: finished={} terminated={} failed={} active={}\n\
             cloudlets: finished={} canceled={}\n\
             alloc: attempts={} failures={}\n\
             spot: total={} interruptions={} interrupted_vms={} \
             uninterrupted_completions={} redeployed={} completed_after_interruption={} \
             terminated={} max_per_vm={}\n\
             interruption_secs: avg={:.2} max={:.2} min={:.2}\n\
             resilience: storms={} storm_reclaims={} per_storm={:.2} \
             p95_interruption_s={:.2} host_failures={} recoveries={} \
             avg_recovery_s={:.2} max_recovery_s={:.2} \
             work_lost_mi={:.0} work_recovered_mi={:.0}\n\
             market: spot_cost=${:.2} od_cost=${:.2} savings={:.2} \
             price_reclaims={} mean_price={:.3} max_price={:.3}\n\
             recovery: checkpoints={} checkpoint_mb={:.1} migrations={} \
             failed_migrations={} recovered_fraction={:.2} \
             requeue_s: p50={:.2} p95={:.2} max={:.2}",
            self.policy,
            self.clock_end,
            self.events_processed,
            self.wall,
            self.finished,
            self.terminated,
            self.failed,
            self.still_active,
            self.cloudlets_finished,
            self.cloudlets_canceled,
            self.alloc_attempts,
            self.alloc_failures,
            s.total_spot,
            s.interruptions,
            s.interrupted_vms,
            s.uninterrupted_completions,
            s.redeployments,
            s.completed_after_interruption,
            s.terminated,
            s.max_interruptions_per_vm,
            s.avg_interruption_secs,
            s.max_interruption_secs,
            s.min_interruption_secs,
            r.storms,
            r.storm_reclaims,
            r.interruptions_per_storm,
            r.p95_interruption_secs,
            r.host_failures,
            r.recoveries,
            r.avg_recovery_secs,
            r.max_recovery_secs,
            r.work_lost_mi,
            r.work_recovered_mi,
            m.spot_cost_usd,
            m.on_demand_cost_usd,
            m.savings_ratio,
            m.price_reclaims,
            m.mean_price_paid,
            m.max_price_paid,
            rc.checkpoints,
            rc.checkpoint_mb,
            rc.migrations,
            rc.failed_migrations,
            rc.recovered_fraction,
            rc.requeue_p50_s,
            rc.requeue_p95_s,
            rc.requeue_max_s,
        )
    }

    /// JSON export of the report (paper §V-E(f)).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{Json, JsonObj};
        let mut o = JsonObj::new();
        o.set("policy", Json::Str(self.policy.to_string()));
        o.set("clock_end", Json::Num(self.clock_end));
        o.set("events_processed", Json::Num(self.events_processed as f64));
        o.set("wall_ms", Json::Num(self.wall.as_secs_f64() * 1e3));
        o.set("vms_finished", Json::Num(self.finished as f64));
        o.set("vms_terminated", Json::Num(self.terminated as f64));
        o.set("vms_failed", Json::Num(self.failed as f64));
        o.set("vms_active", Json::Num(self.still_active as f64));
        o.set("cloudlets_finished", Json::Num(self.cloudlets_finished as f64));
        o.set("cloudlets_canceled", Json::Num(self.cloudlets_canceled as f64));
        o.set("alloc_attempts", Json::Num(self.alloc_attempts as f64));
        o.set("alloc_failures", Json::Num(self.alloc_failures as f64));
        let s = &self.spot;
        let mut sp = JsonObj::new();
        sp.set("total", Json::Num(s.total_spot as f64));
        sp.set("interruptions", Json::Num(s.interruptions as f64));
        sp.set("interrupted_vms", Json::Num(s.interrupted_vms as f64));
        sp.set("uninterrupted_completions", Json::Num(s.uninterrupted_completions as f64));
        sp.set("redeployments", Json::Num(s.redeployments as f64));
        sp.set(
            "completed_after_interruption",
            Json::Num(s.completed_after_interruption as f64),
        );
        sp.set("terminated", Json::Num(s.terminated as f64));
        sp.set("max_interruptions_per_vm", Json::Num(s.max_interruptions_per_vm as f64));
        sp.set("avg_interruption_secs", Json::Num(s.avg_interruption_secs));
        sp.set("max_interruption_secs", Json::Num(s.max_interruption_secs));
        sp.set("min_interruption_secs", Json::Num(s.min_interruption_secs));
        o.set("spot", Json::Obj(sp));
        let r = &self.resilience;
        let mut rs = JsonObj::new();
        rs.set("storms", Json::Num(r.storms as f64));
        rs.set("storm_reclaims", Json::Num(r.storm_reclaims as f64));
        rs.set("host_failures", Json::Num(r.host_failures as f64));
        rs.set("recoveries", Json::Num(r.recoveries as f64));
        rs.set("interruptions_per_storm", Json::Num(r.interruptions_per_storm));
        rs.set("p95_interruption_secs", Json::Num(r.p95_interruption_secs));
        rs.set("avg_recovery_secs", Json::Num(r.avg_recovery_secs));
        rs.set("max_recovery_secs", Json::Num(r.max_recovery_secs));
        rs.set("work_lost_mi", Json::Num(r.work_lost_mi));
        rs.set("work_recovered_mi", Json::Num(r.work_recovered_mi));
        o.set("resilience", Json::Obj(rs));
        let m = &self.market;
        let mut mk = JsonObj::new();
        mk.set("spot_cost_usd", Json::Num(m.spot_cost_usd));
        mk.set("on_demand_cost_usd", Json::Num(m.on_demand_cost_usd));
        mk.set("savings_ratio", Json::Num(m.savings_ratio));
        mk.set("price_reclaims", Json::Num(m.price_reclaims as f64));
        mk.set("mean_price_paid", Json::Num(m.mean_price_paid));
        mk.set("max_price_paid", Json::Num(m.max_price_paid));
        o.set("market", Json::Obj(mk));
        let rc = &self.recovery;
        let mut rv = JsonObj::new();
        rv.set("checkpoints", Json::Num(rc.checkpoints as f64));
        rv.set("checkpoint_mb", Json::Num(rc.checkpoint_mb));
        rv.set("migrations", Json::Num(rc.migrations as f64));
        rv.set("failed_migrations", Json::Num(rc.failed_migrations as f64));
        rv.set("work_recovered_mi", Json::Num(rc.work_recovered_mi));
        rv.set("work_lost_mi", Json::Num(rc.work_lost_mi));
        rv.set("recovered_fraction", Json::Num(rc.recovered_fraction));
        rv.set("requeue_p50_s", Json::Num(rc.requeue_p50_s));
        rv.set("requeue_p95_s", Json::Num(rc.requeue_p95_s));
        rv.set("requeue_max_s", Json::Num(rc.requeue_max_s));
        o.set("recovery", Json::Obj(rv));
        Json::Obj(o)
    }
}
