//! Struct-of-arrays hot state + incremental sampling counters (§Perf:
//! million-entity runs).
//!
//! The arena ([`super::world::World`]) keeps `Vm`/`Host` structs as the
//! authoritative store, but two hot paths used to walk them wholesale:
//!
//! - every placement decision touched `Host` structs scattered across a
//!   ~200-byte-stride `Vec<Host>` just to evaluate `fits` on four
//!   resource dimensions;
//! - every `Sample` tick walked **all** VMs and hosts
//!   (`World::state_sample`), which at 100k hosts / 1M+ VMs turns a
//!   10-column series row into milliseconds of pointer-chasing.
//!
//! [`HotState`] fixes both: the fields those paths read are mirrored into
//! dense id-indexed columns (state, spot flag, request vectors,
//! active/free resources, displaced/hibernation timestamps), and the
//! sample itself becomes an O(1) read of counters maintained at every VM
//! state transition and host activate/deactivate/commit/release. The
//! mirrors are written only by `World`'s mutation API - the same
//! choke-points that already maintain the placement index - and
//! `World::check_index` cross-validates columns, counters and the
//! retained `_scan` oracles after every step of the property tests.
//!
//! # Bitwise parity of the float aggregates
//!
//! Integer counters (per-state VM counts, used/total PEs, failed hosts,
//! displaced gauge) are trivially exact. The RAM sums are not: f64
//! addition is non-associative, so an incrementally maintained
//! `used_ram` can differ in the last bit from the scan oracle's
//! host-id-ordered left fold. Example: summing `{2^52, 0.5, 0.5}` as
//! `(0.5 + 0.5) + 2^52` gives `2^52 + 1` exactly, while the fold order
//! `(2^52 + 0.5) + 0.5` rounds to `2^52` twice (ties-to-even) - every
//! individual addition is exact, yet the totals differ.
//!
//! Instead of compensated summation (which changes the rounding of the
//! *oracle's* order, not just the error), the counters use an exactness
//! guard: a RAM value participates in the incremental sums only if it is
//! a non-negative multiple of 2^-10 MB no larger than 2^42 MB, and the
//! running totals stay below 2^43 MB. Under those bounds every partial
//! sum - in *any* association order, including the oracle's fold - is an
//! integer multiple of 2^-10 below 2^53 x 2^-10, hence exactly
//! representable; no addition ever rounds, so incremental == fold
//! bit-for-bit. Every in-repo host catalog and workload uses integral-MB
//! RAM, so the guard holds on the hot path. The first value that
//! violates it (e.g. randomized non-dyadic RAM in property tests) sets a
//! sticky `ram_dirty` flag and `state_sample` recomputes *only* the two
//! RAM fields with the oracle's own host walk - correctness never
//! depends on the guard, only the O(1) fast path does.
//!
//! The spot-usage vectors need no guard at all: `Host::commit` appends
//! the VM at the *end* of the host's VM list, so adding its request
//! vector last extends the scan oracle's left fold exactly (see
//! `World::commit_vm`); only release rebuilds (see
//! `World::release_vm`). The full invariant table lives in
//! `docs/perf.md`.

use crate::infra::Host;
use crate::vm::{Vm, VmState};

use super::world::StateSample;

/// Number of [`VmState`] variants (size of a per-state count bucket row).
const N_STATES: usize = 7;

/// Dense index of a [`VmState`] into the count buckets.
#[inline]
fn state_slot(s: VmState) -> usize {
    match s {
        VmState::Waiting => 0,
        VmState::Running => 1,
        VmState::InterruptWarned => 2,
        VmState::Hibernated => 3,
        VmState::Finished => 4,
        VmState::Terminated => 5,
        VmState::Failed => 6,
    }
}

/// Finest RAM granularity (2^-10 MB) the exactness guard admits.
const RAM_QUANTUM: f64 = 1024.0; // reciprocal: values are checked * 1024
/// Largest single RAM value the guard admits (2^42 MB = 4 EiB-ish).
const RAM_MAX_ADDEND: f64 = 4_398_046_511_104.0;
/// Ceiling on the running totals (2^43 MB): while every addend is a
/// multiple of 2^-10 and totals stay below this, all partial sums fit in
/// 53 significand bits and every f64 addition is exact.
const RAM_MAX_TOTAL: f64 = 8_796_093_022_208.0;

/// Whether `x` can join the incremental RAM sums without any f64
/// addition ever rounding (see module docs).
#[inline]
fn exactly_summable(x: f64) -> bool {
    x.is_finite() && x >= 0.0 && x <= RAM_MAX_ADDEND && (x * RAM_QUANTUM).fract() == 0.0
}

/// Struct-of-arrays mirror of the arena's hot fields plus the O(1)
/// sampling counters. Owned by `World`; all writes flow through the
/// `World` mutation API.
#[derive(Default)]
pub(crate) struct HotState {
    // --- VM columns (dense, indexed by VmId) --------------------------
    pub(crate) vm_state: Vec<VmState>,
    pub(crate) vm_spot: Vec<bool>,
    pub(crate) vm_pes: Vec<u32>,
    /// Request vectors in artifact dimension order (MIPS, RAM, BW,
    /// storage) - the HLEM scoring columns.
    pub(crate) vm_request: Vec<[f64; 4]>,
    /// Displacement timestamp; NaN = not displaced.
    pub(crate) vm_displaced_at: Vec<f64>,
    /// Hibernation timestamp; NaN = not hibernated.
    pub(crate) vm_hibernated_at: Vec<f64>,
    // --- host columns (dense, indexed by HostId) ----------------------
    pub(crate) host_active: Vec<bool>,
    pub(crate) host_free_pes: Vec<u32>,
    pub(crate) host_free_ram: Vec<f64>,
    pub(crate) host_free_bw: Vec<f64>,
    pub(crate) host_free_storage: Vec<f64>,
    pub(crate) host_spot_used: Vec<[f64; 4]>,
    pub(crate) host_spot_vms: Vec<u32>,
    // --- incremental sampling counters --------------------------------
    /// Per-(spot, state) VM counts; `counts[is_spot as usize][slot]`.
    counts: [[usize; N_STATES]; 2],
    displaced: usize,
    failed_hosts: usize,
    used_pes: u32,
    total_pes: u32,
    used_ram: f64,
    total_ram: f64,
    /// Sticky: a RAM value failed the exactness guard, so the
    /// incremental RAM sums can no longer promise bitwise parity and
    /// `state_sample` re-walks hosts for the two RAM fields only.
    ram_dirty: bool,
}

impl HotState {
    /// Append the columns for a freshly added VM and count its initial
    /// state.
    pub(crate) fn push_vm(&mut self, vm: &Vm) {
        debug_assert_eq!(vm.id, self.vm_state.len(), "VM ids must stay dense");
        self.vm_state.push(vm.state);
        self.vm_spot.push(vm.is_spot());
        self.vm_pes.push(vm.spec.pes);
        self.vm_request.push(vm.spec.request_vec());
        self.vm_displaced_at.push(vm.displaced_at.unwrap_or(f64::NAN));
        self.vm_hibernated_at.push(vm.hibernated_at.unwrap_or(f64::NAN));
        self.counts[vm.is_spot() as usize][state_slot(vm.state)] += 1;
        if vm.displaced_at.is_some() {
            self.displaced += 1;
        }
    }

    /// Append the columns for a freshly added host (contribution counters
    /// are handled by the caller, which knows the activation story).
    pub(crate) fn push_host(&mut self, host: &Host) {
        debug_assert_eq!(host.id, self.host_active.len(), "host ids must stay dense");
        self.host_active.push(host.is_active());
        self.host_free_pes.push(host.free_pes());
        self.host_free_ram.push(host.free_ram());
        self.host_free_bw.push(host.free_bw());
        self.host_free_storage.push(host.free_storage());
        self.host_spot_used.push(host.spot_used);
        self.host_spot_vms.push(host.spot_vms);
    }

    /// Re-copy one host's derived columns from its authoritative struct.
    /// Called after every struct mutation so SoA reads decide exactly as
    /// struct reads would.
    pub(crate) fn sync_host(&mut self, host: &Host) {
        let h = host.id;
        self.host_active[h] = host.is_active();
        self.host_free_pes[h] = host.free_pes();
        self.host_free_ram[h] = host.free_ram();
        self.host_free_bw[h] = host.free_bw();
        self.host_free_storage[h] = host.free_storage();
        self.host_spot_used[h] = host.spot_used;
        self.host_spot_vms[h] = host.spot_vms;
    }

    /// SoA twin of [`Host::fits`]: same comparisons over precomputed
    /// columns, so indexed placement decisions match struct-based scans
    /// bit-for-bit while reading contiguous memory.
    #[inline]
    pub(crate) fn host_fits(&self, h: usize, pes: u32, ram: f64, bw: f64, storage: f64) -> bool {
        self.host_active[h]
            && self.host_free_pes[h] >= pes
            && self.host_free_ram[h] + 1e-9 >= ram
            && self.host_free_bw[h] + 1e-9 >= bw
            && self.host_free_storage[h] + 1e-9 >= storage
    }

    /// Move one VM between state buckets and update its state column.
    /// Reads the previous state from the column itself, which the caller
    /// guarantees was in sync before the struct-side transition.
    pub(crate) fn vm_transition(&mut self, v: usize, next: VmState) {
        let spot = self.vm_spot[v] as usize;
        let prev = self.vm_state[v];
        self.counts[spot][state_slot(prev)] -= 1;
        self.counts[spot][state_slot(next)] += 1;
        self.vm_state[v] = next;
    }

    pub(crate) fn inc_displaced(&mut self) {
        self.displaced += 1;
    }

    pub(crate) fn dec_displaced(&mut self) {
        self.displaced -= 1;
    }

    pub(crate) fn inc_failed_hosts(&mut self) {
        self.failed_hosts += 1;
    }

    pub(crate) fn dec_failed_hosts(&mut self) {
        self.failed_hosts -= 1;
    }

    /// Add an active host's (used, total) PE contribution.
    pub(crate) fn add_pes(&mut self, used: u32, total: u32) {
        self.used_pes += used;
        self.total_pes += total;
    }

    /// Remove an active host's (used, total) PE contribution.
    pub(crate) fn sub_pes(&mut self, used: u32, total: u32) {
        self.used_pes -= used;
        self.total_pes -= total;
    }

    pub(crate) fn add_used_ram(&mut self, x: f64) {
        if self.ram_dirty {
            return;
        }
        if !exactly_summable(x) {
            self.ram_dirty = true;
            return;
        }
        self.used_ram += x;
        if self.used_ram > RAM_MAX_TOTAL {
            self.ram_dirty = true;
        }
    }

    pub(crate) fn sub_used_ram(&mut self, x: f64) {
        if self.ram_dirty {
            return;
        }
        if !exactly_summable(x) || x > self.used_ram {
            self.ram_dirty = true;
            return;
        }
        self.used_ram -= x;
    }

    pub(crate) fn add_total_ram(&mut self, x: f64) {
        if self.ram_dirty {
            return;
        }
        if !exactly_summable(x) {
            self.ram_dirty = true;
            return;
        }
        self.total_ram += x;
        if self.total_ram > RAM_MAX_TOTAL {
            self.ram_dirty = true;
        }
    }

    pub(crate) fn sub_total_ram(&mut self, x: f64) {
        if self.ram_dirty {
            return;
        }
        if !exactly_summable(x) || x > self.total_ram {
            self.ram_dirty = true;
            return;
        }
        self.total_ram -= x;
    }

    /// Whether the incremental RAM sums still carry the bitwise-parity
    /// guarantee (i.e. `state_sample` takes the O(1) path).
    pub(crate) fn ram_exact(&self) -> bool {
        !self.ram_dirty
    }

    /// Assemble a sample from the counters. When the RAM guard has
    /// tripped the caller overwrites the two RAM fields with a host walk.
    pub(crate) fn sample_counts(&self) -> StateSample {
        StateSample {
            od_running: self.counts[0][state_slot(VmState::Running)],
            spot_running: self.counts[1][state_slot(VmState::Running)],
            od_warned: self.counts[0][state_slot(VmState::InterruptWarned)],
            spot_warned: self.counts[1][state_slot(VmState::InterruptWarned)],
            // The sampled series only charts spot hibernations; the
            // on-demand bucket exists but is not reported (on-demand VMs
            // never hibernate in the engine).
            hibernated: self.counts[1][state_slot(VmState::Hibernated)],
            od_waiting: self.counts[0][state_slot(VmState::Waiting)],
            spot_waiting: self.counts[1][state_slot(VmState::Waiting)],
            used_pes: self.used_pes,
            total_pes: self.total_pes,
            used_ram: self.used_ram,
            total_ram: self.total_ram,
            failed_hosts: self.failed_hosts,
            displaced: self.displaced,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infra::HostSpec;

    #[test]
    fn exactness_guard_accepts_quantized_ram() {
        // Integral-MB values (every in-repo catalog) and sub-MB dyadics.
        for x in [0.0, 512.0, 16_384.0, 262_144.0, 0.5, 0.0009765625] {
            assert!(exactly_summable(x), "{x} should be summable");
        }
    }

    #[test]
    fn exactness_guard_rejects_unsafe_values() {
        // Non-dyadic fractions, negatives, non-finite, and magnitudes
        // whose partial sums could round.
        for x in [0.1, 1e-4, -1.0, f64::NAN, f64::INFINITY, RAM_MAX_ADDEND * 2.0] {
            assert!(!exactly_summable(x), "{x} should be rejected");
        }
        // The order-dependence counterexample from the module docs: each
        // addition of {2^52, 0.5, 0.5} is individually exact, yet the two
        // association orders disagree - which is exactly why the guard
        // bounds magnitude rather than checking per-op rounding.
        let big = 2f64.powi(52);
        assert_ne!((0.5 + 0.5) + big, (big + 0.5) + 0.5);
        assert!(!exactly_summable(big));
    }

    #[test]
    fn dirty_flag_is_sticky() {
        let mut h = HotState::default();
        h.add_used_ram(512.0);
        assert!(h.ram_exact());
        h.add_used_ram(0.1); // non-dyadic -> parity lost
        assert!(!h.ram_exact());
        h.add_used_ram(512.0); // exact values no longer help
        assert!(!h.ram_exact());
    }

    #[test]
    fn host_fits_matches_struct_fits() {
        let spec = HostSpec::new(8, 1000.0, 16_384.0, 5_000.0, 200_000.0);
        let host = Host::new(0, 0, spec, 0.0);
        let mut hot = HotState::default();
        hot.push_host(&host);
        for (pes, ram) in [(1u32, 512.0), (8, 16_384.0), (9, 512.0), (1, 20_000.0)] {
            assert_eq!(
                hot.host_fits(0, pes, ram, 100.0, 100.0),
                host.fits(pes, ram, 100.0, 100.0),
                "pes={pes} ram={ram}"
            );
        }
    }
}
