//! Shared read-only workload prebuilds, keyed per (substrate, seed).
//!
//! Every cell of a sweep re-runs the same scenario under a different
//! policy/seed/axis value; the expensive part that is identical across all
//! cells of one (substrate, seed) pair is done once here and shared via
//! `Arc`:
//!
//! - **Comparison substrate**: resolving the randomized Table II/III
//!   workload into concrete submissions (`config::scenario::WorkloadPlan`).
//!   Spot-config axis values do not consume RNG draws, so one plan per
//!   seed serves every spot variant of that seed
//!   (`WorkloadPlan::apply_with_spot`).
//! - **Trace substrate**: generating (and validating) the synthetic
//!   cluster [`Trace`]. The trace-to-workload conversion is cheap and
//!   depends on per-cell knobs, so it stays in the worker.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::config::scenario::{plan_comparison_workload, ComparisonConfig, WorkloadPlan};
use crate::trace::synth::{SynthConfig, TraceGenerator};
use crate::trace::Trace;

use super::grid::{Cell, Substrate, SweepSpec};

/// One shared prebuild: the comparison plan or the generated trace of the
/// cell's (substrate, seed) pair.
#[derive(Debug, Clone)]
pub enum Prebuilt {
    Comparison(Arc<WorkloadPlan>),
    Trace(Arc<Trace>),
}

/// (Substrate, seed)-keyed cache of workload prebuilds.
///
/// Within each substrate, prebuilds are keyed by seed alone, so one cache
/// serves exactly one scenario template per substrate; mixing templates is
/// a bug the cache catches by asserting template identity (seed aside) on
/// every lookup.
#[derive(Debug, Default)]
pub struct PrebuildCache {
    plans: BTreeMap<u64, Arc<WorkloadPlan>>,
    /// First comparison template seen, seed normalized to 0.
    template: Option<ComparisonConfig>,
    traces: BTreeMap<u64, Arc<Trace>>,
    /// First trace-generator template seen, seed normalized to 0.
    trace_template: Option<SynthConfig>,
}

impl PrebuildCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Plan the comparison workload for `seed` (with `template` supplying
    /// every other scenario knob), or return the already-built shared plan.
    ///
    /// Panics if called with a different template than earlier lookups:
    /// a seed-keyed hit for another scenario would be a silently wrong
    /// workload. Use one cache per sweep.
    pub fn get_or_build(&mut self, template: &ComparisonConfig, seed: u64) -> Arc<WorkloadPlan> {
        let normalized = ComparisonConfig { seed: 0, ..template.clone() };
        match &self.template {
            None => self.template = Some(normalized),
            Some(first) => assert_eq!(
                *first, normalized,
                "PrebuildCache reused across different scenario templates"
            ),
        }
        self.plans
            .entry(seed)
            .or_insert_with(|| {
                let cfg = ComparisonConfig { seed, ..template.clone() };
                Arc::new(plan_comparison_workload(&cfg))
            })
            .clone()
    }

    /// Generate (and validate) the synthetic trace for `seed`, or return
    /// the already-built shared trace. Same template-identity contract as
    /// [`PrebuildCache::get_or_build`].
    pub fn get_or_build_trace(&mut self, template: &SynthConfig, seed: u64) -> Arc<Trace> {
        let normalized = SynthConfig { seed: 0, ..template.clone() };
        match &self.trace_template {
            None => self.trace_template = Some(normalized),
            Some(first) => assert_eq!(
                *first, normalized,
                "PrebuildCache reused across different trace templates"
            ),
        }
        self.traces
            .entry(seed)
            .or_insert_with(|| {
                let cfg = SynthConfig { seed, ..template.clone() };
                let trace = TraceGenerator::new(cfg).generate();
                let issues = trace.validate();
                assert!(issues.is_empty(), "synthetic trace invalid: {issues:?}");
                Arc::new(trace)
            })
            .clone()
    }

    /// The prebuild for `cell` under `spec`'s templates, built on first
    /// request for its (substrate, seed) pair.
    pub fn get_or_build_cell(&mut self, spec: &SweepSpec, cell: &Cell) -> Prebuilt {
        match cell.spec.substrate {
            Substrate::Comparison => {
                Prebuilt::Comparison(self.get_or_build(&spec.scenario, cell.seed))
            }
            Substrate::Trace => {
                Prebuilt::Trace(self.get_or_build_trace(&spec.trace.synth, cell.seed))
            }
        }
    }

    /// Distinct (substrate, seed) prebuilds so far.
    pub fn len(&self) -> usize {
        self.plans.len() + self.traces.len()
    }

    pub fn is_empty(&self) -> bool {
        self.plans.is_empty() && self.traces.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::grid::{CellSpec, PolicySpec};

    #[test]
    fn cache_shares_one_plan_per_seed() {
        let template = ComparisonConfig::default();
        let mut cache = PrebuildCache::new();
        let a = cache.get_or_build(&template, 7);
        let b = cache.get_or_build(&template, 7);
        let c = cache.get_or_build(&template, 8);
        assert!(Arc::ptr_eq(&a, &b), "same seed must share one prebuild");
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(cache.len(), 2);
        assert_eq!(a.seed, 7);
        assert_eq!(c.seed, 8);
    }

    #[test]
    fn cached_plan_matches_fresh_plan() {
        let template = ComparisonConfig::default();
        let mut cache = PrebuildCache::new();
        let cached = cache.get_or_build(&template, template.seed);
        let fresh = plan_comparison_workload(&template);
        assert_eq!(*cached, fresh);
    }

    #[test]
    #[should_panic(expected = "different scenario templates")]
    fn cache_rejects_template_mixing() {
        let a = ComparisonConfig::default();
        let b = ComparisonConfig { terminate_at: a.terminate_at + 1.0, ..a.clone() };
        let mut cache = PrebuildCache::new();
        cache.get_or_build(&a, 1);
        cache.get_or_build(&b, 2);
    }

    #[test]
    fn cache_accepts_same_template_with_different_base_seed() {
        // Only the seed differs between lookups: that is the normal
        // per-cell pattern, not template mixing.
        let a = ComparisonConfig::default();
        let b = ComparisonConfig { seed: a.seed + 10, ..a.clone() };
        let mut cache = PrebuildCache::new();
        cache.get_or_build(&a, 1);
        cache.get_or_build(&b, 2);
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn trace_cache_shares_one_trace_per_seed() {
        let template =
            SynthConfig { machines: 10, days: 0.05, tasks_per_hour: 120.0, ..Default::default() };
        let mut cache = PrebuildCache::new();
        let a = cache.get_or_build_trace(&template, 1);
        let b = cache.get_or_build_trace(&template, 1);
        let c = cache.get_or_build_trace(&template, 2);
        assert!(Arc::ptr_eq(&a, &b));
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(cache.len(), 2);
        assert_eq!(a.machine_count(), 10);
    }

    #[test]
    #[should_panic(expected = "different trace templates")]
    fn trace_cache_rejects_template_mixing() {
        let a = SynthConfig { machines: 10, days: 0.05, ..Default::default() };
        let b = SynthConfig { machines: 12, ..a.clone() };
        let mut cache = PrebuildCache::new();
        cache.get_or_build_trace(&a, 1);
        cache.get_or_build_trace(&b, 2);
    }

    #[test]
    fn cell_lookup_dispatches_on_substrate() {
        let mut spec = crate::sweep::SweepSpec::new(ComparisonConfig::default())
            .with_seeds(vec![5])
            .with_policies(vec![PolicySpec::FirstFit]);
        spec.trace.synth =
            SynthConfig { machines: 10, days: 0.05, tasks_per_hour: 120.0, ..Default::default() };
        let mut cache = PrebuildCache::new();
        let comp_cell = Cell { id: 0, seed: 5, spec: CellSpec::comparison(PolicySpec::FirstFit) };
        let mut trace_spec = CellSpec::comparison(PolicySpec::FirstFit);
        trace_spec.substrate = Substrate::Trace;
        let trace_cell = Cell { id: 1, seed: 5, spec: trace_spec };
        assert!(matches!(
            cache.get_or_build_cell(&spec, &comp_cell),
            Prebuilt::Comparison(_)
        ));
        assert!(matches!(cache.get_or_build_cell(&spec, &trace_cell), Prebuilt::Trace(_)));
        // Same seed on different substrates -> two distinct prebuilds.
        assert_eq!(cache.len(), 2);
    }
}
