//! Shared read-only workload prebuilds, keyed per (substrate, seed).
//!
//! Every cell of a sweep re-runs the same scenario under a different
//! policy/seed/axis value; the expensive part that is identical across all
//! cells of one (substrate, seed) pair is done once here and shared via
//! `Arc`:
//!
//! - **Comparison substrate**: resolving the randomized Table II/III
//!   workload into concrete submissions (`config::scenario::WorkloadPlan`).
//!   Spot-config axis values do not consume RNG draws, so one plan per
//!   seed serves every spot variant of that seed
//!   (`WorkloadPlan::apply_with_spot`).
//! - **Trace substrate**: generating (and validating) the synthetic
//!   cluster [`Trace`]. The trace-to-workload conversion is cheap and
//!   depends on per-cell knobs, so it stays in the worker.
//!
//! Two sharing mechanisms live here:
//!
//! - [`PrebuildCache`]: the eager, single-threaded `&mut self` cache
//!   (tests, ad-hoc tooling).
//! - [`PrebuildSlots`]: the driver's lazy worker-side table - one
//!   `OnceLock` slot per distinct (substrate, seed) pair, sized from the
//!   grid up front, so the **first worker that needs a pair builds it
//!   while other workers keep running cells** instead of the whole pool
//!   waiting behind a serial prebuild prefix.

use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, OnceLock};

use crate::chaos::{self, ChaosSchedule, ChaosSpec};
use crate::config::scenario::{plan_comparison_workload, ComparisonConfig, WorkloadPlan};
use crate::market::{self, MarketSchedule, MarketSpec};
use crate::recovery::{self, RecoverySchedule, RecoverySpec};
use crate::trace::synth::{SynthConfig, TraceGenerator};
use crate::trace::Trace;

use super::grid::{Cell, Substrate, SweepSpec};

/// One shared prebuild: the comparison plan or the generated trace of the
/// cell's (substrate, seed) pair.
#[derive(Debug, Clone)]
pub enum Prebuilt {
    Comparison(Arc<WorkloadPlan>),
    Trace(Arc<Trace>),
}

/// Plan the comparison workload for (`template`, `seed`). The single
/// builder both the eager cache and the lazy slots call - one copy, so
/// the two prebuild paths cannot diverge.
fn build_plan(template: &ComparisonConfig, seed: u64) -> Arc<WorkloadPlan> {
    let cfg = ComparisonConfig { seed, ..template.clone() };
    Arc::new(plan_comparison_workload(&cfg))
}

/// Generate and validate the synthetic trace for (`template`, `seed`).
/// Shared by the eager cache and the lazy slots (see [`build_plan`]).
fn build_trace(template: &SynthConfig, seed: u64) -> Arc<Trace> {
    let cfg = SynthConfig { seed, ..template.clone() };
    let trace = TraceGenerator::new(cfg).generate();
    let issues = trace.validate();
    assert!(issues.is_empty(), "synthetic trace invalid: {issues:?}");
    Arc::new(trace)
}

/// Build the prebuild for `cell` under `spec`'s templates from scratch
/// (no cache). Deterministic in (substrate, seed): racing builders
/// produce identical values, which is what keeps lazily-prebuilt sweeps
/// byte-identical at any thread count.
pub fn build_prebuilt(spec: &SweepSpec, cell: &Cell) -> Prebuilt {
    match cell.spec.substrate {
        Substrate::Comparison => Prebuilt::Comparison(build_plan(&spec.scenario, cell.seed)),
        Substrate::Trace => Prebuilt::Trace(build_trace(&spec.trace.synth, cell.seed)),
    }
}

/// Render a `catch_unwind` payload as the failure message stored in cell
/// error rows.
pub(crate) fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "cell panicked (non-string payload)".to_string()
    }
}

/// Lazy worker-side prebuild table: one `OnceLock` slot per distinct
/// (substrate, seed) pair of a cell list, sized up front so workers share
/// `&self` with no locking beyond each slot's one-time initialization.
///
/// The first worker that needs a pair builds it; workers racing on the
/// *same* pair block only on that slot (other pairs keep executing).
/// Build panics are caught and stored as the slot's `Err`, so every cell
/// of a broken pair reports the same per-cell error row instead of
/// aborting the sweep. Because [`build_prebuilt`] is deterministic in
/// (substrate, seed), the winning worker's identity never leaks into the
/// merged artifacts.
pub struct PrebuildSlots {
    /// Slot index -> (substrate discriminant, seed) key (diagnostics).
    keys: Vec<(u8, u64)>,
    slots: Vec<OnceLock<Result<Prebuilt, String>>>,
    /// Cell index (enumeration order) -> slot index.
    cell_slot: Vec<usize>,
}

fn slot_key(cell: &Cell) -> (u8, u64) {
    let sub = match cell.spec.substrate {
        Substrate::Comparison => 0u8,
        Substrate::Trace => 1u8,
    };
    (sub, cell.seed)
}

impl PrebuildSlots {
    /// Size the slot table for `cells` (one slot per distinct pair; no
    /// prebuild is built yet).
    pub fn for_cells(cells: &[Cell]) -> Self {
        let mut index: BTreeMap<(u8, u64), usize> = BTreeMap::new();
        let mut keys: Vec<(u8, u64)> = Vec::new();
        let mut cell_slot = Vec::with_capacity(cells.len());
        for cell in cells {
            let key = slot_key(cell);
            let slot = *index.entry(key).or_insert_with(|| {
                keys.push(key);
                keys.len() - 1
            });
            cell_slot.push(slot);
        }
        let mut slots = Vec::new();
        slots.resize_with(keys.len(), OnceLock::new);
        PrebuildSlots { keys, slots, cell_slot }
    }

    /// Distinct (substrate, seed) pairs the table covers.
    pub fn slot_count(&self) -> usize {
        self.slots.len()
    }

    /// Prebuilds actually built so far.
    pub fn built(&self) -> usize {
        self.slots.iter().filter(|s| s.get().is_some()).count()
    }

    /// The prebuild for the cell at `cell_index` of the enumeration this
    /// table was sized for, building it on first use.
    pub fn get(&self, spec: &SweepSpec, cell_index: usize, cell: &Cell) -> &Result<Prebuilt, String> {
        self.get_with(spec, cell_index, cell, |_| {})
    }

    /// [`Self::get`], reporting the build duration to `on_build` when
    /// *this* call performed the build (driver phase instrumentation).
    pub fn get_with(
        &self,
        spec: &SweepSpec,
        cell_index: usize,
        cell: &Cell,
        on_build: impl FnOnce(std::time::Duration),
    ) -> &Result<Prebuilt, String> {
        let slot = self.cell_slot[cell_index];
        debug_assert_eq!(self.keys[slot], slot_key(cell), "cell/slot table mismatch");
        self.slots[slot].get_or_init(|| {
            let t0 = std::time::Instant::now();
            let built = catch_unwind(AssertUnwindSafe(|| build_prebuilt(spec, cell)))
                .map_err(|p| format!("workload prebuild failed: {}", panic_message(p)));
            on_build(t0.elapsed());
            built
        })
    }
}

/// The (horizon, host count) a chaos schedule is compiled against:
/// comparison cells end at `terminate_at` over the Table II fleet; trace
/// cells span the generated trace's horizon and machine population.
fn substrate_extent(spec: &SweepSpec, prebuilt: &Prebuilt) -> (f64, usize) {
    match prebuilt {
        Prebuilt::Comparison(_) => (
            spec.scenario.terminate_at,
            crate::config::catalog::host_types().iter().map(|t| t.count).sum(),
        ),
        Prebuilt::Trace(trace) => (trace.horizon, trace.machine_count()),
    }
}

/// Lazy worker-side chaos-schedule table, the [`PrebuildSlots`] pattern
/// keyed per distinct (substrate, seed, chaos spec) triple: every cell
/// sharing a triple reuses one compiled [`ChaosSchedule`].
/// [`chaos::compile`] is deterministic in the triple (plus the substrate
/// extent, itself a function of (substrate, seed)), so racing builders
/// produce identical values and the winning worker never leaks into the
/// merged artifacts. Chaos-free cells map to no slot at all.
pub struct ChaosSlots {
    /// Slot index -> key. `ChaosSpec` carries floats (no `Ord`), so dedup
    /// is a linear scan - grids stay small relative to compile cost.
    keys: Vec<(u8, u64, ChaosSpec)>,
    slots: Vec<OnceLock<Arc<ChaosSchedule>>>,
    /// Cell index (enumeration order) -> slot index; `usize::MAX` marks a
    /// chaos-free cell.
    cell_slot: Vec<usize>,
}

impl ChaosSlots {
    /// Size the slot table for `cells` (nothing is compiled yet).
    pub fn for_cells(cells: &[Cell]) -> Self {
        let mut keys: Vec<(u8, u64, ChaosSpec)> = Vec::new();
        let mut cell_slot = Vec::with_capacity(cells.len());
        for cell in cells {
            if cell.spec.chaos.is_none() {
                cell_slot.push(usize::MAX);
                continue;
            }
            let (sub, seed) = slot_key(cell);
            let key = (sub, seed, cell.spec.chaos);
            let slot = match keys.iter().position(|k| *k == key) {
                Some(i) => i,
                None => {
                    keys.push(key);
                    keys.len() - 1
                }
            };
            cell_slot.push(slot);
        }
        let mut slots = Vec::new();
        slots.resize_with(keys.len(), OnceLock::new);
        ChaosSlots { keys, slots, cell_slot }
    }

    /// Distinct (substrate, seed, chaos) triples the table covers.
    pub fn slot_count(&self) -> usize {
        self.slots.len()
    }

    /// Schedules actually compiled so far.
    pub fn built(&self) -> usize {
        self.slots.iter().filter(|s| s.get().is_some()).count()
    }

    /// The compiled schedule for the cell at `cell_index` of the
    /// enumeration this table was sized for (compiling it on first use),
    /// or `None` for a chaos-free cell. `prebuilt` anchors the compile to
    /// the cell's substrate extent, so it must be the cell's own prebuild.
    pub fn get(
        &self,
        spec: &SweepSpec,
        cell_index: usize,
        cell: &Cell,
        prebuilt: &Prebuilt,
    ) -> Option<&Arc<ChaosSchedule>> {
        let slot = self.cell_slot[cell_index];
        if slot == usize::MAX {
            return None;
        }
        debug_assert_eq!(self.keys[slot].2, cell.spec.chaos, "cell/slot table mismatch");
        Some(self.slots[slot].get_or_init(|| {
            let (horizon, n_hosts) = substrate_extent(spec, prebuilt);
            Arc::new(chaos::compile(&cell.spec.chaos, cell.seed, horizon, n_hosts))
        }))
    }
}

/// Lazy worker-side market-schedule table, the [`ChaosSlots`] pattern
/// keyed per distinct (substrate, seed, market spec) triple: every cell
/// sharing a triple reuses one compiled price path.
/// [`market::compile`] is deterministic in the triple (plus the substrate
/// horizon, itself a function of (substrate, seed)), so racing builders
/// produce identical values and the winning worker never leaks into the
/// merged artifacts. Market-free cells map to no slot at all.
pub struct MarketSlots {
    /// Slot index -> key. `MarketSpec` carries floats (no `Ord`), so dedup
    /// is a linear scan - grids stay small relative to compile cost.
    keys: Vec<(u8, u64, MarketSpec)>,
    slots: Vec<OnceLock<Arc<MarketSchedule>>>,
    /// Cell index (enumeration order) -> slot index; `usize::MAX` marks a
    /// market-free cell.
    cell_slot: Vec<usize>,
}

impl MarketSlots {
    /// Size the slot table for `cells` (nothing is compiled yet).
    pub fn for_cells(cells: &[Cell]) -> Self {
        let mut keys: Vec<(u8, u64, MarketSpec)> = Vec::new();
        let mut cell_slot = Vec::with_capacity(cells.len());
        for cell in cells {
            if cell.spec.market.is_none() {
                cell_slot.push(usize::MAX);
                continue;
            }
            let (sub, seed) = slot_key(cell);
            let key = (sub, seed, cell.spec.market);
            let slot = match keys.iter().position(|k| *k == key) {
                Some(i) => i,
                None => {
                    keys.push(key);
                    keys.len() - 1
                }
            };
            cell_slot.push(slot);
        }
        let mut slots = Vec::new();
        slots.resize_with(keys.len(), OnceLock::new);
        MarketSlots { keys, slots, cell_slot }
    }

    /// Distinct (substrate, seed, market) triples the table covers.
    pub fn slot_count(&self) -> usize {
        self.slots.len()
    }

    /// Schedules actually compiled so far.
    pub fn built(&self) -> usize {
        self.slots.iter().filter(|s| s.get().is_some()).count()
    }

    /// The compiled price schedule for the cell at `cell_index` of the
    /// enumeration this table was sized for (compiling it on first use),
    /// or `None` for a market-free cell. `prebuilt` anchors the compile to
    /// the cell's substrate horizon, so it must be the cell's own prebuild.
    pub fn get(
        &self,
        spec: &SweepSpec,
        cell_index: usize,
        cell: &Cell,
        prebuilt: &Prebuilt,
    ) -> Option<&Arc<MarketSchedule>> {
        let slot = self.cell_slot[cell_index];
        if slot == usize::MAX {
            return None;
        }
        debug_assert_eq!(self.keys[slot].2, cell.spec.market, "cell/slot table mismatch");
        Some(self.slots[slot].get_or_init(|| {
            let (horizon, _) = substrate_extent(spec, prebuilt);
            Arc::new(market::compile(&cell.spec.market, cell.seed, horizon))
        }))
    }
}

/// Lazy worker-side recovery-schedule table, the [`ChaosSlots`] pattern
/// keyed per distinct (substrate, seed, recovery spec) triple: every cell
/// sharing a triple reuses one compiled parameter block.
/// [`recovery::compile`] is a pure function of the triple (plus the
/// substrate horizon, itself a function of (substrate, seed)), so racing
/// builders produce identical values and the winning worker never leaks
/// into the merged artifacts. Recovery-free cells map to no slot at all.
pub struct RecoverySlots {
    /// Slot index -> key. `RecoverySpec` carries floats (no `Ord`), so
    /// dedup is a linear scan - grids stay small relative to compile cost.
    keys: Vec<(u8, u64, RecoverySpec)>,
    slots: Vec<OnceLock<Arc<RecoverySchedule>>>,
    /// Cell index (enumeration order) -> slot index; `usize::MAX` marks a
    /// recovery-free cell.
    cell_slot: Vec<usize>,
}

impl RecoverySlots {
    /// Size the slot table for `cells` (nothing is compiled yet).
    pub fn for_cells(cells: &[Cell]) -> Self {
        let mut keys: Vec<(u8, u64, RecoverySpec)> = Vec::new();
        let mut cell_slot = Vec::with_capacity(cells.len());
        for cell in cells {
            if cell.spec.recovery.is_none() {
                cell_slot.push(usize::MAX);
                continue;
            }
            let (sub, seed) = slot_key(cell);
            let key = (sub, seed, cell.spec.recovery);
            let slot = match keys.iter().position(|k| *k == key) {
                Some(i) => i,
                None => {
                    keys.push(key);
                    keys.len() - 1
                }
            };
            cell_slot.push(slot);
        }
        let mut slots = Vec::new();
        slots.resize_with(keys.len(), OnceLock::new);
        RecoverySlots { keys, slots, cell_slot }
    }

    /// Distinct (substrate, seed, recovery) triples the table covers.
    pub fn slot_count(&self) -> usize {
        self.slots.len()
    }

    /// Schedules actually compiled so far.
    pub fn built(&self) -> usize {
        self.slots.iter().filter(|s| s.get().is_some()).count()
    }

    /// The compiled recovery schedule for the cell at `cell_index` of the
    /// enumeration this table was sized for (compiling it on first use),
    /// or `None` for a recovery-free cell. `prebuilt` anchors the compile
    /// to the cell's substrate horizon, so it must be the cell's own
    /// prebuild.
    pub fn get(
        &self,
        spec: &SweepSpec,
        cell_index: usize,
        cell: &Cell,
        prebuilt: &Prebuilt,
    ) -> Option<&Arc<RecoverySchedule>> {
        let slot = self.cell_slot[cell_index];
        if slot == usize::MAX {
            return None;
        }
        debug_assert_eq!(self.keys[slot].2, cell.spec.recovery, "cell/slot table mismatch");
        Some(self.slots[slot].get_or_init(|| {
            let (horizon, _) = substrate_extent(spec, prebuilt);
            Arc::new(recovery::compile(&cell.spec.recovery, cell.seed, horizon))
        }))
    }
}

/// (Substrate, seed)-keyed cache of workload prebuilds.
///
/// Within each substrate, prebuilds are keyed by seed alone, so one cache
/// serves exactly one scenario template per substrate; mixing templates is
/// a bug the cache catches by asserting template identity (seed aside) on
/// every lookup.
#[derive(Debug, Default)]
pub struct PrebuildCache {
    plans: BTreeMap<u64, Arc<WorkloadPlan>>,
    /// First comparison template seen, seed normalized to 0.
    template: Option<ComparisonConfig>,
    traces: BTreeMap<u64, Arc<Trace>>,
    /// First trace-generator template seen, seed normalized to 0.
    trace_template: Option<SynthConfig>,
}

impl PrebuildCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Plan the comparison workload for `seed` (with `template` supplying
    /// every other scenario knob), or return the already-built shared plan.
    ///
    /// Panics if called with a different template than earlier lookups:
    /// a seed-keyed hit for another scenario would be a silently wrong
    /// workload. Use one cache per sweep.
    pub fn get_or_build(&mut self, template: &ComparisonConfig, seed: u64) -> Arc<WorkloadPlan> {
        let normalized = ComparisonConfig { seed: 0, ..template.clone() };
        match &self.template {
            None => self.template = Some(normalized),
            Some(first) => assert_eq!(
                *first, normalized,
                "PrebuildCache reused across different scenario templates"
            ),
        }
        self.plans.entry(seed).or_insert_with(|| build_plan(template, seed)).clone()
    }

    /// Generate (and validate) the synthetic trace for `seed`, or return
    /// the already-built shared trace. Same template-identity contract as
    /// [`PrebuildCache::get_or_build`].
    pub fn get_or_build_trace(&mut self, template: &SynthConfig, seed: u64) -> Arc<Trace> {
        let normalized = SynthConfig { seed: 0, ..template.clone() };
        match &self.trace_template {
            None => self.trace_template = Some(normalized),
            Some(first) => assert_eq!(
                *first, normalized,
                "PrebuildCache reused across different trace templates"
            ),
        }
        self.traces.entry(seed).or_insert_with(|| build_trace(template, seed)).clone()
    }

    /// The prebuild for `cell` under `spec`'s templates, built on first
    /// request for its (substrate, seed) pair.
    pub fn get_or_build_cell(&mut self, spec: &SweepSpec, cell: &Cell) -> Prebuilt {
        match cell.spec.substrate {
            Substrate::Comparison => {
                Prebuilt::Comparison(self.get_or_build(&spec.scenario, cell.seed))
            }
            Substrate::Trace => {
                Prebuilt::Trace(self.get_or_build_trace(&spec.trace.synth, cell.seed))
            }
        }
    }

    /// Distinct (substrate, seed) prebuilds so far.
    pub fn len(&self) -> usize {
        self.plans.len() + self.traces.len()
    }

    pub fn is_empty(&self) -> bool {
        self.plans.is_empty() && self.traces.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::grid::{CellSpec, PolicySpec};

    #[test]
    fn cache_shares_one_plan_per_seed() {
        let template = ComparisonConfig::default();
        let mut cache = PrebuildCache::new();
        let a = cache.get_or_build(&template, 7);
        let b = cache.get_or_build(&template, 7);
        let c = cache.get_or_build(&template, 8);
        assert!(Arc::ptr_eq(&a, &b), "same seed must share one prebuild");
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(cache.len(), 2);
        assert_eq!(a.seed, 7);
        assert_eq!(c.seed, 8);
    }

    #[test]
    fn cached_plan_matches_fresh_plan() {
        let template = ComparisonConfig::default();
        let mut cache = PrebuildCache::new();
        let cached = cache.get_or_build(&template, template.seed);
        let fresh = plan_comparison_workload(&template);
        assert_eq!(*cached, fresh);
    }

    #[test]
    #[should_panic(expected = "different scenario templates")]
    fn cache_rejects_template_mixing() {
        let a = ComparisonConfig::default();
        let b = ComparisonConfig { terminate_at: a.terminate_at + 1.0, ..a.clone() };
        let mut cache = PrebuildCache::new();
        cache.get_or_build(&a, 1);
        cache.get_or_build(&b, 2);
    }

    #[test]
    fn cache_accepts_same_template_with_different_base_seed() {
        // Only the seed differs between lookups: that is the normal
        // per-cell pattern, not template mixing.
        let a = ComparisonConfig::default();
        let b = ComparisonConfig { seed: a.seed + 10, ..a.clone() };
        let mut cache = PrebuildCache::new();
        cache.get_or_build(&a, 1);
        cache.get_or_build(&b, 2);
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn trace_cache_shares_one_trace_per_seed() {
        let template =
            SynthConfig { machines: 10, days: 0.05, tasks_per_hour: 120.0, ..Default::default() };
        let mut cache = PrebuildCache::new();
        let a = cache.get_or_build_trace(&template, 1);
        let b = cache.get_or_build_trace(&template, 1);
        let c = cache.get_or_build_trace(&template, 2);
        assert!(Arc::ptr_eq(&a, &b));
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(cache.len(), 2);
        assert_eq!(a.machine_count(), 10);
    }

    #[test]
    #[should_panic(expected = "different trace templates")]
    fn trace_cache_rejects_template_mixing() {
        let a = SynthConfig { machines: 10, days: 0.05, ..Default::default() };
        let b = SynthConfig { machines: 12, ..a.clone() };
        let mut cache = PrebuildCache::new();
        cache.get_or_build_trace(&a, 1);
        cache.get_or_build_trace(&b, 2);
    }

    /// The lazy slot table builds each (substrate, seed) pair exactly once
    /// and shares it across that pair's cells.
    #[test]
    fn lazy_slots_build_once_per_pair() {
        let spec = crate::sweep::SweepSpec::new(ComparisonConfig::default())
            .with_seeds(vec![1, 2])
            .with_policies(vec![PolicySpec::FirstFit, PolicySpec::BestFit]);
        let cells = spec.cells();
        let slots = PrebuildSlots::for_cells(&cells);
        assert_eq!(slots.slot_count(), 2, "two seeds, one substrate -> two slots");
        assert_eq!(slots.built(), 0, "slots are lazy: nothing built up front");
        let mut builds = 0usize;
        let a = slots.get_with(&spec, 0, &cells[0], |_| builds += 1).as_ref().unwrap().clone();
        assert_eq!((slots.built(), builds), (1, 1));
        let b = slots.get_with(&spec, 1, &cells[1], |_| builds += 1).as_ref().unwrap().clone();
        assert_eq!((slots.built(), builds), (1, 1), "second cell of the pair reuses the build");
        match (&a, &b) {
            (Prebuilt::Comparison(x), Prebuilt::Comparison(y)) => {
                assert!(Arc::ptr_eq(x, y), "same pair must share one Arc")
            }
            other => panic!("unexpected prebuilds: {other:?}"),
        }
        let c = slots.get(&spec, 2, &cells[2]).as_ref().unwrap().clone();
        assert_eq!(slots.built(), 2);
        match (&a, &c) {
            (Prebuilt::Comparison(x), Prebuilt::Comparison(y)) => assert!(!Arc::ptr_eq(x, y)),
            other => panic!("unexpected prebuilds: {other:?}"),
        }
    }

    /// A panicking build is stored once as the slot's `Err`; later cells
    /// of the pair see the same message without re-running the build.
    #[test]
    fn lazy_slot_stores_build_panic_as_err() {
        let mut spec = crate::sweep::SweepSpec::new(ComparisonConfig::default())
            .with_seeds(vec![1])
            .with_policies(vec![PolicySpec::FirstFit, PolicySpec::BestFit])
            .with_axis(crate::sweep::grid::ScenarioAxis::Substrate(vec![Substrate::Trace]));
        spec.trace.synth.machines = 0; // TraceGenerator::new asserts machines > 0
        let cells = spec.cells();
        let slots = PrebuildSlots::for_cells(&cells);
        assert_eq!(slots.slot_count(), 1);
        let e1 = slots.get(&spec, 0, &cells[0]).as_ref().unwrap_err().clone();
        assert!(e1.contains("workload prebuild failed"), "unexpected error: {e1}");
        assert_eq!(slots.built(), 1);
        let mut builds = 0usize;
        let e2 = slots.get_with(&spec, 1, &cells[1], |_| builds += 1).as_ref().unwrap_err().clone();
        assert_eq!(builds, 0, "cached Err must not re-run the build");
        assert_eq!(e1, e2);
    }

    /// Chaos slots dedup per (substrate, seed, chaos) triple, share one
    /// compiled schedule per triple, and skip chaos-free cells entirely.
    #[test]
    fn chaos_slots_compile_once_per_triple() {
        use crate::chaos::ReclaimStorm;
        use crate::sweep::grid::ScenarioAxis;
        let storm = ReclaimStorm::parse("at600-frac0.5").unwrap();
        let spec = crate::sweep::SweepSpec::new(ComparisonConfig::default())
            .with_seeds(vec![1, 2])
            .with_policies(vec![PolicySpec::FirstFit, PolicySpec::BestFit])
            .with_axis(ScenarioAxis::ChaosReclaimStorm(vec![storm]));
        let cells = spec.cells();
        assert_eq!(cells.len(), 4);
        let prebuilds = PrebuildSlots::for_cells(&cells);
        let chaos = ChaosSlots::for_cells(&cells);
        assert_eq!(chaos.slot_count(), 2, "two seeds, one chaos value -> two slots");
        assert_eq!(chaos.built(), 0, "slots are lazy");
        let pb0 = prebuilds.get(&spec, 0, &cells[0]).as_ref().unwrap().clone();
        let a = chaos.get(&spec, 0, &cells[0], &pb0).unwrap().clone();
        let b = chaos.get(&spec, 1, &cells[1], &pb0).unwrap().clone();
        assert!(Arc::ptr_eq(&a, &b), "same triple must share one schedule");
        assert_eq!(chaos.built(), 1);
        assert_eq!(a.storms.len(), 1);
        let pb2 = prebuilds.get(&spec, 2, &cells[2]).as_ref().unwrap().clone();
        let c = chaos.get(&spec, 2, &cells[2], &pb2).unwrap().clone();
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(chaos.built(), 2);

        // Chaos-free grids never compile anything and return None.
        let plain = crate::sweep::SweepSpec::new(ComparisonConfig::default())
            .with_seeds(vec![1])
            .with_policies(vec![PolicySpec::FirstFit]);
        let plain_cells = plain.cells();
        let none = ChaosSlots::for_cells(&plain_cells);
        assert_eq!(none.slot_count(), 0);
        assert!(none.get(&plain, 0, &plain_cells[0], &pb0).is_none());
    }

    /// Market slots dedup per (substrate, seed, market) triple, share one
    /// compiled price path per triple, and skip market-free cells.
    #[test]
    fn market_slots_compile_once_per_triple() {
        use crate::sweep::grid::ScenarioAxis;
        let spec = crate::sweep::SweepSpec::new(ComparisonConfig::default())
            .with_seeds(vec![1, 2])
            .with_policies(vec![PolicySpec::FirstFit, PolicySpec::BestFit])
            .with_axis(ScenarioAxis::MarketVolatility(vec![0.1]));
        let cells = spec.cells();
        assert_eq!(cells.len(), 4);
        let prebuilds = PrebuildSlots::for_cells(&cells);
        let market = MarketSlots::for_cells(&cells);
        assert_eq!(market.slot_count(), 2, "two seeds, one market value -> two slots");
        assert_eq!(market.built(), 0, "slots are lazy");
        let pb0 = prebuilds.get(&spec, 0, &cells[0]).as_ref().unwrap().clone();
        let a = market.get(&spec, 0, &cells[0], &pb0).unwrap().clone();
        let b = market.get(&spec, 1, &cells[1], &pb0).unwrap().clone();
        assert!(Arc::ptr_eq(&a, &b), "same triple must share one schedule");
        assert_eq!(market.built(), 1);
        assert!(!a.is_empty(), "an active spec compiles a non-empty price path");
        let pb2 = prebuilds.get(&spec, 2, &cells[2]).as_ref().unwrap().clone();
        let c = market.get(&spec, 2, &cells[2], &pb2).unwrap().clone();
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(market.built(), 2);
        assert_ne!(a.prices, c.prices, "different seeds walk different paths");

        // Market-free grids never compile anything and return None.
        let plain = crate::sweep::SweepSpec::new(ComparisonConfig::default())
            .with_seeds(vec![1])
            .with_policies(vec![PolicySpec::FirstFit]);
        let plain_cells = plain.cells();
        let none = MarketSlots::for_cells(&plain_cells);
        assert_eq!(none.slot_count(), 0);
        assert!(none.get(&plain, 0, &plain_cells[0], &pb0).is_none());
    }

    /// Recovery slots dedup per (substrate, seed, recovery) triple, share
    /// one compiled parameter block per triple, and skip recovery-free
    /// cells entirely.
    #[test]
    fn recovery_slots_compile_once_per_triple() {
        use crate::recovery::RecoveryMode;
        use crate::sweep::grid::ScenarioAxis;
        let spec = crate::sweep::SweepSpec::new(ComparisonConfig::default())
            .with_seeds(vec![1, 2])
            .with_policies(vec![PolicySpec::FirstFit, PolicySpec::BestFit])
            .with_axis(ScenarioAxis::RecoveryMode(vec![RecoveryMode::Checkpoint]));
        let cells = spec.cells();
        assert_eq!(cells.len(), 4);
        let prebuilds = PrebuildSlots::for_cells(&cells);
        let recovery = RecoverySlots::for_cells(&cells);
        assert_eq!(recovery.slot_count(), 2, "two seeds, one recovery value -> two slots");
        assert_eq!(recovery.built(), 0, "slots are lazy");
        let pb0 = prebuilds.get(&spec, 0, &cells[0]).as_ref().unwrap().clone();
        let a = recovery.get(&spec, 0, &cells[0], &pb0).unwrap().clone();
        let b = recovery.get(&spec, 1, &cells[1], &pb0).unwrap().clone();
        assert!(Arc::ptr_eq(&a, &b), "same triple must share one schedule");
        assert_eq!(recovery.built(), 1);
        assert!(!a.is_empty(), "an active spec compiles an active schedule");
        assert_eq!(a.mode, RecoveryMode::Checkpoint);

        // Recovery-free grids never compile anything and return None.
        let plain = crate::sweep::SweepSpec::new(ComparisonConfig::default())
            .with_seeds(vec![1])
            .with_policies(vec![PolicySpec::FirstFit]);
        let plain_cells = plain.cells();
        let none = RecoverySlots::for_cells(&plain_cells);
        assert_eq!(none.slot_count(), 0);
        assert!(none.get(&plain, 0, &plain_cells[0], &pb0).is_none());
    }

    #[test]
    fn cell_lookup_dispatches_on_substrate() {
        let mut spec = crate::sweep::SweepSpec::new(ComparisonConfig::default())
            .with_seeds(vec![5])
            .with_policies(vec![PolicySpec::FirstFit]);
        spec.trace.synth =
            SynthConfig { machines: 10, days: 0.05, tasks_per_hour: 120.0, ..Default::default() };
        let mut cache = PrebuildCache::new();
        let comp_cell = Cell { id: 0, seed: 5, spec: CellSpec::comparison(PolicySpec::FirstFit) };
        let mut trace_spec = CellSpec::comparison(PolicySpec::FirstFit);
        trace_spec.substrate = Substrate::Trace;
        let trace_cell = Cell { id: 1, seed: 5, spec: trace_spec };
        assert!(matches!(
            cache.get_or_build_cell(&spec, &comp_cell),
            Prebuilt::Comparison(_)
        ));
        assert!(matches!(cache.get_or_build_cell(&spec, &trace_cell), Prebuilt::Trace(_)));
        // Same seed on different substrates -> two distinct prebuilds.
        assert_eq!(cache.len(), 2);
    }
}
