//! Shared read-only workload prebuilds.
//!
//! Every cell of a sweep re-runs the same scenario under a different
//! policy/seed; the expensive part that is identical across all cells of
//! one seed - resolving the randomized Table II/III workload into concrete
//! submissions - is done once per seed here and shared across cells via
//! `Arc<WorkloadPlan>` (the plan is plain data, `Send + Sync`).

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::config::scenario::{plan_comparison_workload, ComparisonConfig, WorkloadPlan};

/// Seed-keyed cache of comparison-workload plans.
///
/// Plans are keyed by seed alone, so one cache serves exactly one
/// scenario template; mixing templates is a bug the cache catches by
/// asserting template identity (seed aside) on every lookup.
#[derive(Debug, Default)]
pub struct PrebuildCache {
    plans: BTreeMap<u64, Arc<WorkloadPlan>>,
    /// First template seen, seed normalized to 0 for comparison.
    template: Option<ComparisonConfig>,
}

impl PrebuildCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Plan the workload for `seed` (with `template` supplying every other
    /// scenario knob), or return the already-built shared plan.
    ///
    /// Panics if called with a different template than earlier lookups:
    /// a seed-keyed hit for another scenario would be a silently wrong
    /// workload. Use one cache per sweep.
    pub fn get_or_build(&mut self, template: &ComparisonConfig, seed: u64) -> Arc<WorkloadPlan> {
        let normalized = ComparisonConfig { seed: 0, ..template.clone() };
        match &self.template {
            None => self.template = Some(normalized),
            Some(first) => assert_eq!(
                *first, normalized,
                "PrebuildCache reused across different scenario templates"
            ),
        }
        self.plans
            .entry(seed)
            .or_insert_with(|| {
                let cfg = ComparisonConfig { seed, ..template.clone() };
                Arc::new(plan_comparison_workload(&cfg))
            })
            .clone()
    }

    /// Distinct seeds planned so far.
    pub fn len(&self) -> usize {
        self.plans.len()
    }

    pub fn is_empty(&self) -> bool {
        self.plans.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cache_shares_one_plan_per_seed() {
        let template = ComparisonConfig::default();
        let mut cache = PrebuildCache::new();
        let a = cache.get_or_build(&template, 7);
        let b = cache.get_or_build(&template, 7);
        let c = cache.get_or_build(&template, 8);
        assert!(Arc::ptr_eq(&a, &b), "same seed must share one prebuild");
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(cache.len(), 2);
        assert_eq!(a.seed, 7);
        assert_eq!(c.seed, 8);
    }

    #[test]
    fn cached_plan_matches_fresh_plan() {
        let template = ComparisonConfig::default();
        let mut cache = PrebuildCache::new();
        let cached = cache.get_or_build(&template, template.seed);
        let fresh = plan_comparison_workload(&template);
        assert_eq!(*cached, fresh);
    }

    #[test]
    #[should_panic(expected = "different scenario templates")]
    fn cache_rejects_template_mixing() {
        let a = ComparisonConfig::default();
        let b = ComparisonConfig { terminate_at: a.terminate_at + 1.0, ..a.clone() };
        let mut cache = PrebuildCache::new();
        cache.get_or_build(&a, 1);
        cache.get_or_build(&b, 2);
    }

    #[test]
    fn cache_accepts_same_template_with_different_base_seed() {
        // Only the seed differs between lookups: that is the normal
        // per-cell pattern, not template mixing.
        let a = ComparisonConfig::default();
        let b = ComparisonConfig { seed: a.seed + 10, ..a.clone() };
        let mut cache = PrebuildCache::new();
        cache.get_or_build(&a, 1);
        cache.get_or_build(&b, 2);
        assert_eq!(cache.len(), 2);
    }
}
