//! Sweep results: per-cell report rows plus grid-level aggregates grouped
//! by scenario variant, with CSV/JSON export through `util::csv` /
//! `util::json`.
//!
//! Everything serialized here is a pure function of the cell results in
//! cell-id order. Nondeterministic per-run data (wall time, thread count)
//! is deliberately excluded so a sweep's exported artifacts are
//! byte-identical regardless of how many worker threads produced them
//! (pinned by `tests/sweep_determinism.rs`). Axis values (substrate,
//! victim policy, spot overrides) appear as dedicated CSV columns and JSON
//! fields so downstream tooling can group by them directly.

use crate::engine::Report;
use crate::metrics::TimeSeries;
use crate::stats::Summary;
use crate::util::csv::{fmt_num, Csv};
use crate::util::json::{Json, JsonObj};
use crate::util::table::{Align, TextTable};

use super::grid::{Cell, CellSpec};

/// Outcome of one sweep cell: the run's [`Report`], or the panic/error
/// message of an isolated failure, plus the cell's sampled time series
/// when the sweep's retention filter matched it.
#[derive(Debug, Clone)]
pub struct CellResult {
    pub cell: Cell,
    pub outcome: Result<Report, String>,
    /// Fig-13-style active-instance series; `None` unless retained.
    pub series: Option<TimeSeries>,
}

impl CellResult {
    pub fn report(&self) -> Option<&Report> {
        self.outcome.as_ref().ok()
    }
}

/// Merged output of a sweep, cells in id order.
#[derive(Debug, Clone)]
pub struct SweepReport {
    pub cells: Vec<CellResult>,
    /// Worker threads used (observability only; never serialized).
    pub threads: usize,
}

/// Grid-level aggregate for one scenario variant (policy × axis values),
/// over its succeeded cells.
#[derive(Debug, Clone)]
pub struct VariantAggregate {
    pub spec: CellSpec,
    pub runs: usize,
    pub interruptions: Summary,
    pub interrupted_vms: Summary,
    pub avg_interruption_secs: Summary,
    pub max_interruption_secs: Summary,
    pub max_interruptions_per_vm: u32,
    /// Resilience moments (chaos sweeps; all-zero for chaos-free cells).
    pub interruptions_per_storm: Summary,
    pub max_recovery_secs: Summary,
    pub work_lost_mi: Summary,
    /// Market cost/reliability moments (market sweeps; all-zero for
    /// market-free cells).
    pub spot_cost_usd: Summary,
    pub savings_ratio: Summary,
    pub price_reclaims: Summary,
    /// Work-survival moments (recovery sweeps; all-zero for
    /// recovery-free cells).
    pub recovered_fraction: Summary,
    pub requeue_p95_s: Summary,
    pub migrations: Summary,
}

impl SweepReport {
    /// Assemble a report from unordered cell results (e.g. process-shard
    /// partials): sorts by cell id and verifies the ids are exactly
    /// `0..n` with no duplicates or holes, so a merge of partial
    /// artifacts can never silently drop or double-count a cell.
    /// `threads` is observability-only, like the field it fills.
    pub fn merged_from_cells(
        mut cells: Vec<CellResult>,
        threads: usize,
    ) -> Result<SweepReport, String> {
        cells.sort_by_key(|c| c.cell.id);
        for (i, pair) in cells.windows(2).enumerate() {
            if pair[0].cell.id == pair[1].cell.id {
                return Err(format!(
                    "overlapping cell id {} (cells {i} and {})",
                    pair[0].cell.id,
                    i + 1
                ));
            }
        }
        for (i, c) in cells.iter().enumerate() {
            if c.cell.id != i {
                return Err(format!("missing cell id {i} (next present id is {})", c.cell.id));
            }
        }
        Ok(SweepReport { cells, threads })
    }

    pub fn total(&self) -> usize {
        self.cells.len()
    }

    /// Cells whose run failed (panicked or errored).
    pub fn failed(&self) -> usize {
        self.cells.iter().filter(|c| c.outcome.is_err()).count()
    }

    /// Per-variant aggregates in first-appearance (cell-id) order. With no
    /// axes declared every variant is one policy, so this degenerates to
    /// the per-policy grouping of the pre-axis sweep.
    pub fn aggregates(&self) -> Vec<VariantAggregate> {
        let mut aggs: Vec<VariantAggregate> = Vec::new();
        for cell in &self.cells {
            let idx = match aggs.iter().position(|a| a.spec == cell.cell.spec) {
                Some(i) => i,
                None => {
                    aggs.push(VariantAggregate {
                        spec: cell.cell.spec,
                        runs: 0,
                        interruptions: Summary::new(),
                        interrupted_vms: Summary::new(),
                        avg_interruption_secs: Summary::new(),
                        max_interruption_secs: Summary::new(),
                        max_interruptions_per_vm: 0,
                        interruptions_per_storm: Summary::new(),
                        max_recovery_secs: Summary::new(),
                        work_lost_mi: Summary::new(),
                        spot_cost_usd: Summary::new(),
                        savings_ratio: Summary::new(),
                        price_reclaims: Summary::new(),
                        recovered_fraction: Summary::new(),
                        requeue_p95_s: Summary::new(),
                        migrations: Summary::new(),
                    });
                    aggs.len() - 1
                }
            };
            let Some(report) = cell.report() else { continue };
            let a = &mut aggs[idx];
            a.runs += 1;
            a.interruptions.add(report.spot.interruptions as f64);
            a.interrupted_vms.add(report.spot.interrupted_vms as f64);
            a.avg_interruption_secs.add(report.spot.avg_interruption_secs);
            a.max_interruption_secs.add(report.spot.max_interruption_secs);
            a.max_interruptions_per_vm =
                a.max_interruptions_per_vm.max(report.spot.max_interruptions_per_vm);
            a.interruptions_per_storm.add(report.resilience.interruptions_per_storm);
            a.max_recovery_secs.add(report.resilience.max_recovery_secs);
            a.work_lost_mi.add(report.resilience.work_lost_mi);
            a.spot_cost_usd.add(report.market.spot_cost_usd);
            a.savings_ratio.add(report.market.savings_ratio);
            a.price_reclaims.add(report.market.price_reclaims as f64);
            a.recovered_fraction.add(report.recovery.recovered_fraction);
            a.requeue_p95_s.add(report.recovery.requeue_p95_s);
            a.migrations.add(report.recovery.migrations as f64);
        }
        aggs
    }

    /// Per-cell rows (one line per cell, id order). Deterministic: no wall
    /// times, no thread counts. Axis values get their own columns
    /// (empty when the cell runs the substrate default).
    pub fn cells_csv(&self) -> Csv {
        let mut csv = Csv::new(&[
            "cell",
            "policy",
            "alpha",
            "seed",
            "substrate",
            "victim",
            "spot_warning",
            "spot_hib_timeout",
            "spot_behavior",
            "chaos_host_mtbf",
            "chaos_reclaim_storm",
            "chaos_broker_outage",
            "chaos_demand_surge",
            "market_volatility",
            "market_mean_reversion",
            "market_daily_amplitude",
            "market_bid_margin",
            "recovery_mode",
            "recovery_bandwidth",
            "recovery_checkpoint_threshold",
            "status",
            "error",
            "clock_end",
            "events",
            "vms_finished",
            "vms_terminated",
            "vms_failed",
            "spot_total",
            "interruptions",
            "interrupted_vms",
            "max_per_vm",
            "avg_interruption_s",
            "max_interruption_s",
            "min_interruption_s",
            "storms",
            "storm_reclaims",
            "interruptions_per_storm",
            "p95_interruption_s",
            "recoveries",
            "avg_recovery_s",
            "max_recovery_s",
            "work_lost_mi",
            "work_recovered_mi",
            "spot_cost_usd",
            "od_cost_usd",
            "savings_ratio",
            "price_reclaims",
            "mean_price_paid",
            "max_price_paid",
            "checkpoints",
            "checkpoint_mb",
            "migrations",
            "failed_migrations",
            "recovered_fraction",
            "requeue_p50_s",
            "requeue_p95_s",
            "requeue_max_s",
        ]);
        for c in &self.cells {
            let spec = &c.cell.spec;
            let mut row = vec![
                c.cell.id.to_string(),
                spec.policy.name().to_string(),
                spec.policy.alpha().map(fmt_num).unwrap_or_default(),
                c.cell.seed.to_string(),
                spec.substrate.name().to_string(),
                spec.victim.map(|v| v.name().to_string()).unwrap_or_default(),
                spec.spot.warning_time.map(fmt_num).unwrap_or_default(),
                spec.spot.hibernation_timeout.map(fmt_num).unwrap_or_default(),
                spec.spot.behavior.map(|b| b.name().to_string()).unwrap_or_default(),
                spec.chaos.host_mtbf.map(|x| x.label()).unwrap_or_default(),
                spec.chaos.reclaim_storm.map(|x| x.label()).unwrap_or_default(),
                spec.chaos.broker_outage.map(|x| x.label()).unwrap_or_default(),
                spec.chaos.demand_surge.map(|x| x.label()).unwrap_or_default(),
                spec.market.volatility.map(crate::market::label_f64).unwrap_or_default(),
                spec.market.mean_reversion.map(crate::market::label_f64).unwrap_or_default(),
                spec.market.daily_amplitude.map(crate::market::label_f64).unwrap_or_default(),
                spec.market.bid_margin.map(crate::market::label_f64).unwrap_or_default(),
                spec.recovery.mode.map(|m| m.label().to_string()).unwrap_or_default(),
                spec.recovery.bandwidth.map(crate::recovery::label_f64).unwrap_or_default(),
                spec.recovery
                    .checkpoint_threshold
                    .map(crate::recovery::label_f64)
                    .unwrap_or_default(),
            ];
            match &c.outcome {
                Ok(r) => row.extend(vec![
                    "ok".into(),
                    String::new(),
                    fmt_num(r.clock_end),
                    r.events_processed.to_string(),
                    r.finished.to_string(),
                    r.terminated.to_string(),
                    r.failed.to_string(),
                    r.spot.total_spot.to_string(),
                    r.spot.interruptions.to_string(),
                    r.spot.interrupted_vms.to_string(),
                    r.spot.max_interruptions_per_vm.to_string(),
                    fmt_num(r.spot.avg_interruption_secs),
                    fmt_num(r.spot.max_interruption_secs),
                    fmt_num(r.spot.min_interruption_secs),
                    r.resilience.storms.to_string(),
                    r.resilience.storm_reclaims.to_string(),
                    fmt_num(r.resilience.interruptions_per_storm),
                    fmt_num(r.resilience.p95_interruption_secs),
                    r.resilience.recoveries.to_string(),
                    fmt_num(r.resilience.avg_recovery_secs),
                    fmt_num(r.resilience.max_recovery_secs),
                    fmt_num(r.resilience.work_lost_mi),
                    fmt_num(r.resilience.work_recovered_mi),
                    fmt_num(r.market.spot_cost_usd),
                    fmt_num(r.market.on_demand_cost_usd),
                    fmt_num(r.market.savings_ratio),
                    r.market.price_reclaims.to_string(),
                    fmt_num(r.market.mean_price_paid),
                    fmt_num(r.market.max_price_paid),
                    r.recovery.checkpoints.to_string(),
                    fmt_num(r.recovery.checkpoint_mb),
                    r.recovery.migrations.to_string(),
                    r.recovery.failed_migrations.to_string(),
                    fmt_num(r.recovery.recovered_fraction),
                    fmt_num(r.recovery.requeue_p50_s),
                    fmt_num(r.recovery.requeue_p95_s),
                    fmt_num(r.recovery.requeue_max_s),
                ]),
                Err(e) => {
                    row.push("failed".into());
                    row.push(e.clone());
                    row.extend(std::iter::repeat(String::new()).take(35));
                }
            }
            csv.push(row);
        }
        csv
    }

    /// Grid-level aggregate document: per-variant `stats::Summary` moments
    /// keyed by policy plus every axis value.
    pub fn aggregate_json(&self) -> Json {
        let stat_obj = |s: &Summary| {
            let mut o = JsonObj::new();
            o.set("mean", Json::Num(s.mean()));
            o.set("min", Json::Num(s.min()));
            o.set("max", Json::Num(s.max()));
            o.set("stddev", Json::Num(s.stddev()));
            Json::Obj(o)
        };
        let opt_num = |v: Option<f64>| v.map(Json::Num).unwrap_or(Json::Null);
        let mut root = JsonObj::new();
        root.set("cells", Json::Num(self.total() as f64));
        root.set("failed", Json::Num(self.failed() as f64));
        let mut variants = Vec::new();
        for a in self.aggregates() {
            let spec = &a.spec;
            let mut o = JsonObj::new();
            o.set("policy", Json::Str(spec.policy.name().to_string()));
            o.set("alpha", opt_num(spec.policy.alpha()));
            o.set("substrate", Json::Str(spec.substrate.name().to_string()));
            o.set(
                "victim",
                spec.victim
                    .map(|v| Json::Str(v.name().to_string()))
                    .unwrap_or(Json::Null),
            );
            o.set("spot_warning", opt_num(spec.spot.warning_time));
            o.set("spot_hibernation_timeout", opt_num(spec.spot.hibernation_timeout));
            o.set(
                "spot_behavior",
                spec.spot
                    .behavior
                    .map(|b| Json::Str(b.name().to_string()))
                    .unwrap_or(Json::Null),
            );
            o.set(
                "chaos_host_mtbf",
                spec.chaos.host_mtbf.map(|x| Json::Str(x.label())).unwrap_or(Json::Null),
            );
            o.set(
                "chaos_reclaim_storm",
                spec.chaos
                    .reclaim_storm
                    .map(|x| Json::Str(x.label()))
                    .unwrap_or(Json::Null),
            );
            o.set(
                "chaos_broker_outage",
                spec.chaos
                    .broker_outage
                    .map(|x| Json::Str(x.label()))
                    .unwrap_or(Json::Null),
            );
            o.set(
                "chaos_demand_surge",
                spec.chaos
                    .demand_surge
                    .map(|x| Json::Str(x.label()))
                    .unwrap_or(Json::Null),
            );
            o.set("market_volatility", opt_num(spec.market.volatility));
            o.set("market_mean_reversion", opt_num(spec.market.mean_reversion));
            o.set("market_daily_amplitude", opt_num(spec.market.daily_amplitude));
            o.set("market_bid_margin", opt_num(spec.market.bid_margin));
            o.set(
                "recovery_mode",
                spec.recovery
                    .mode
                    .map(|m| Json::Str(m.label().to_string()))
                    .unwrap_or(Json::Null),
            );
            o.set("recovery_bandwidth", opt_num(spec.recovery.bandwidth));
            o.set(
                "recovery_checkpoint_threshold",
                opt_num(spec.recovery.checkpoint_threshold),
            );
            o.set("runs", Json::Num(a.runs as f64));
            o.set("interruptions", stat_obj(&a.interruptions));
            o.set("interrupted_vms", stat_obj(&a.interrupted_vms));
            o.set("avg_interruption_secs", stat_obj(&a.avg_interruption_secs));
            o.set("max_interruption_secs", stat_obj(&a.max_interruption_secs));
            o.set(
                "max_interruptions_per_vm",
                Json::Num(a.max_interruptions_per_vm as f64),
            );
            o.set("interruptions_per_storm", stat_obj(&a.interruptions_per_storm));
            o.set("max_recovery_secs", stat_obj(&a.max_recovery_secs));
            o.set("work_lost_mi", stat_obj(&a.work_lost_mi));
            o.set("spot_cost_usd", stat_obj(&a.spot_cost_usd));
            o.set("savings_ratio", stat_obj(&a.savings_ratio));
            o.set("price_reclaims", stat_obj(&a.price_reclaims));
            o.set("recovered_fraction", stat_obj(&a.recovered_fraction));
            o.set("requeue_p95_s", stat_obj(&a.requeue_p95_s));
            o.set("migrations", stat_obj(&a.migrations));
            variants.push(Json::Obj(o));
        }
        root.set("policies", Json::Arr(variants));
        Json::Obj(root)
    }

    /// Terminal rendering of the grid-level aggregates.
    pub fn aggregate_table(&self) -> TextTable {
        let mut t = TextTable::new("SWEEP AGGREGATE (per variant, over seeds)")
            .column("Policy", Align::Left)
            .column("Variant", Align::Left)
            .column("Runs", Align::Right)
            .column("Interruptions", Align::Right)
            .column("+/- sd", Align::Right)
            .column("Avg dur (s)", Align::Right)
            .column("Max dur (s)", Align::Right)
            .column("Max per VM", Align::Right);
        for a in self.aggregates() {
            t.push(vec![
                a.spec.policy.name().to_string(),
                a.spec.variant_label(),
                a.runs.to_string(),
                fmt_num(a.interruptions.mean()),
                fmt_num(a.interruptions.stddev()),
                fmt_num(a.avg_interruption_secs.mean()),
                fmt_num(a.max_interruption_secs.mean()),
                a.max_interruptions_per_vm.to_string(),
            ]);
        }
        t
    }

    /// Retained per-cell time series as `(cell_id, csv)` pairs in id
    /// order (cells that matched the sweep's retention filter).
    pub fn retained_series_csvs(&self) -> Vec<(usize, Csv)> {
        self.cells
            .iter()
            .filter_map(|c| c.series.as_ref().map(|s| (c.cell.id, s.to_csv())))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chaos::{ChaosSpec, ReclaimStorm};
    use crate::engine::{MarketStats, RecoveryStats, ResilienceStats, SpotStats, VictimPolicy};
    use crate::market::MarketSpec;
    use crate::recovery::{RecoveryMode, RecoverySpec};
    use crate::sweep::grid::{PolicySpec, SpotOverride, Substrate};

    fn fake_report(policy: &'static str, interruptions: u64) -> Report {
        Report {
            policy,
            clock_end: 100.0,
            events_processed: 42,
            wall: std::time::Duration::from_millis(5),
            finished: 10,
            terminated: 1,
            failed: 0,
            still_active: 0,
            cloudlets_finished: 10,
            cloudlets_canceled: 1,
            alloc_attempts: 12,
            alloc_failures: 2,
            spot: SpotStats {
                total_spot: 4,
                interruptions,
                interrupted_vms: interruptions.min(4),
                avg_interruption_secs: 10.0 + interruptions as f64,
                max_interruption_secs: 20.0 + interruptions as f64,
                min_interruption_secs: 1.0,
                max_interruptions_per_vm: interruptions as u32,
                ..Default::default()
            },
            resilience: ResilienceStats {
                storms: 1,
                storm_reclaims: interruptions,
                interruptions_per_storm: interruptions as f64,
                p95_interruption_secs: 20.0 + interruptions as f64,
                recoveries: 1,
                avg_recovery_secs: 5.0,
                max_recovery_secs: 8.0,
                work_lost_mi: 100.0 * interruptions as f64,
                work_recovered_mi: 50.0,
                ..Default::default()
            },
            market: MarketStats {
                spot_cost_usd: 2.0 * interruptions as f64,
                on_demand_cost_usd: 5.0 * interruptions as f64,
                savings_ratio: 0.6,
                price_reclaims: interruptions,
                mean_price_paid: 0.25,
                max_price_paid: 0.75,
            },
            recovery: RecoveryStats {
                checkpoints: interruptions,
                checkpoint_mb: 0.5 * interruptions as f64,
                migrations: 1,
                failed_migrations: 0,
                work_recovered_mi: 50.0,
                work_lost_mi: 100.0 * interruptions as f64,
                recovered_fraction: 0.25,
                requeue_p50_s: 4.0,
                requeue_p95_s: 9.0 + interruptions as f64,
                requeue_max_s: 12.0,
            },
        }
    }

    fn sample_report() -> SweepReport {
        let p = CellSpec::comparison(PolicySpec::FirstFit);
        let q = CellSpec::comparison(PolicySpec::Hlem { adjusted: true, alpha: -0.5 });
        SweepReport {
            cells: vec![
                CellResult {
                    cell: Cell { id: 0, seed: 1, spec: p },
                    outcome: Ok(fake_report("first-fit", 3)),
                    series: None,
                },
                CellResult {
                    cell: Cell { id: 1, seed: 1, spec: q },
                    outcome: Ok(fake_report("hlem-vmp-adjusted", 1)),
                    series: None,
                },
                CellResult {
                    cell: Cell { id: 2, seed: 2, spec: p },
                    outcome: Ok(fake_report("first-fit", 5)),
                    series: None,
                },
                CellResult {
                    cell: Cell { id: 3, seed: 2, spec: q },
                    outcome: Err("boom".into()),
                    series: None,
                },
            ],
            threads: 2,
        }
    }

    /// `merged_from_cells` restores id order and rejects overlapping or
    /// missing ids (the partial-merge safety contract).
    #[test]
    fn merged_from_cells_sorts_and_validates() {
        let rep = sample_report();
        let mut shuffled = rep.cells.clone();
        shuffled.swap(0, 3);
        shuffled.swap(1, 2);
        let merged = SweepReport::merged_from_cells(shuffled, 3).unwrap();
        assert_eq!(merged.threads, 3);
        for (i, c) in merged.cells.iter().enumerate() {
            assert_eq!(c.cell.id, i);
        }

        let mut dup = rep.cells.clone();
        dup[1].cell.id = 2;
        let err = SweepReport::merged_from_cells(dup, 1).unwrap_err();
        assert!(err.contains("overlapping cell id 2"), "{err}");

        let mut hole = rep.cells.clone();
        hole.remove(1);
        let err = SweepReport::merged_from_cells(hole, 1).unwrap_err();
        assert!(err.contains("missing cell id 1"), "{err}");
    }

    #[test]
    fn csv_has_one_row_per_cell_and_counts_failures() {
        let rep = sample_report();
        assert_eq!(rep.total(), 4);
        assert_eq!(rep.failed(), 1);
        let csv = rep.cells_csv();
        assert_eq!(csv.len(), 4);
        let text = csv.to_string();
        assert!(text.contains("failed,boom"));
        assert!(text.starts_with(
            "cell,policy,alpha,seed,substrate,victim,spot_warning,spot_hib_timeout,\
             spot_behavior,chaos_host_mtbf,chaos_reclaim_storm,chaos_broker_outage,\
             chaos_demand_surge,market_volatility,market_mean_reversion,\
             market_daily_amplitude,market_bid_margin,recovery_mode,recovery_bandwidth,\
             recovery_checkpoint_threshold,status"
        ));
        assert!(
            text.contains(
                "min_interruption_s,storms,storm_reclaims,interruptions_per_storm,\
                 p95_interruption_s,recoveries,avg_recovery_s,max_recovery_s,\
                 work_lost_mi,work_recovered_mi,spot_cost_usd,od_cost_usd,\
                 savings_ratio,price_reclaims,mean_price_paid,max_price_paid,\
                 checkpoints,checkpoint_mb,migrations,failed_migrations,\
                 recovered_fraction,requeue_p50_s,requeue_p95_s,requeue_max_s"
            ),
            "resilience/market/recovery columns missing: {text}"
        );
        // Default variants leave the axis columns empty but name the
        // substrate.
        assert!(text.contains(",comparison,,,,,,,,,,,,,,,,ok,"));
    }

    #[test]
    fn csv_axis_columns_carry_values() {
        let mut rep = sample_report();
        rep.cells[0].cell.spec = CellSpec {
            substrate: Substrate::Trace,
            policy: PolicySpec::FirstFit,
            spot: SpotOverride {
                warning_time: Some(60.0),
                hibernation_timeout: Some(900.0),
                behavior: Some(crate::vm::InterruptionBehavior::Terminate),
            },
            victim: Some(VictimPolicy::Youngest),
            chaos: ChaosSpec {
                reclaim_storm: Some(ReclaimStorm::parse("at1200-frac0.5").unwrap()),
                ..ChaosSpec::NONE
            },
            market: MarketSpec {
                volatility: Some(0.25),
                bid_margin: Some(0.5),
                ..MarketSpec::NONE
            },
            recovery: RecoverySpec {
                mode: Some(RecoveryMode::Checkpoint),
                bandwidth: Some(128.0),
                checkpoint_threshold: Some(0.25),
            },
        };
        let text = rep.cells_csv().to_string();
        assert!(
            text.contains(
                ",trace,youngest,60,900,terminate,,at1200-frac0.5,,,0.25,,,0.5,\
                 checkpoint,128,0.25,ok,"
            ),
            "axis columns missing: {text}"
        );
    }

    #[test]
    fn aggregates_group_by_variant_and_skip_failures() {
        let rep = sample_report();
        let aggs = rep.aggregates();
        assert_eq!(aggs.len(), 2);
        assert_eq!(aggs[0].spec.policy, PolicySpec::FirstFit);
        assert_eq!(aggs[0].runs, 2);
        assert_eq!(aggs[0].interruptions.mean(), 4.0);
        assert_eq!(aggs[0].max_interruptions_per_vm, 5);
        // The failed hlem cell is excluded from moments but keeps the group.
        assert_eq!(aggs[1].runs, 1);
        assert_eq!(aggs[1].interruptions.mean(), 1.0);
    }

    #[test]
    fn same_policy_different_axis_values_stay_separate_groups() {
        let mut rep = sample_report();
        // Cell 2 shares cell 0's policy but runs a different spot warning:
        // a distinct variant, so a distinct aggregate group.
        rep.cells[2].cell.spec.spot.warning_time = Some(60.0);
        let aggs = rep.aggregates();
        assert_eq!(aggs.len(), 3);
        assert_eq!(aggs[0].runs, 1);
        assert_eq!(aggs[2].runs, 1);
        assert_eq!(aggs[2].spec.spot.warning_time, Some(60.0));
    }

    #[test]
    fn aggregate_json_parses_and_excludes_wall() {
        let rep = sample_report();
        let text = rep.aggregate_json().to_string_pretty();
        let parsed = crate::util::json::parse(&text).unwrap();
        assert_eq!(parsed.path(&["cells"]).unwrap().as_f64(), Some(4.0));
        assert_eq!(parsed.path(&["failed"]).unwrap().as_f64(), Some(1.0));
        assert!(!text.contains("wall"), "wall time must not leak into sweep artifacts");
        assert!(!text.contains("thread"));
        let policies = parsed.path(&["policies"]).unwrap().as_arr().unwrap();
        assert_eq!(policies.len(), 2);
        assert_eq!(
            policies[0].path(&["interruptions", "mean"]).unwrap().as_f64(),
            Some(4.0)
        );
        assert_eq!(
            policies[0].path(&["substrate"]).unwrap().as_str(),
            Some("comparison")
        );
        assert!(policies[0].path(&["victim"]).is_some());
        assert!(policies[0].path(&["spot_warning"]).is_some());
        assert!(policies[0].path(&["chaos_reclaim_storm"]).is_some());
        // fake_report gives first-fit cells 3 and 5 interruptions, so the
        // per-storm moments follow (one storm per cell).
        assert_eq!(
            policies[0].path(&["interruptions_per_storm", "mean"]).unwrap().as_f64(),
            Some(4.0)
        );
        assert_eq!(
            policies[0].path(&["work_lost_mi", "max"]).unwrap().as_f64(),
            Some(500.0)
        );
        // Market axis keys are always present (null when market-free), and
        // cost moments follow fake_report's 2.0 * interruptions spot cost.
        assert!(policies[0].path(&["market_volatility"]).is_some());
        assert!(policies[0].path(&["market_bid_margin"]).is_some());
        assert_eq!(
            policies[0].path(&["spot_cost_usd", "mean"]).unwrap().as_f64(),
            Some(8.0)
        );
        assert_eq!(
            policies[0].path(&["price_reclaims", "max"]).unwrap().as_f64(),
            Some(5.0)
        );
        assert_eq!(
            policies[0].path(&["savings_ratio", "mean"]).unwrap().as_f64(),
            Some(0.6)
        );
        // Recovery axis keys are always present (null when recovery-free),
        // and the work-survival moments follow fake_report's values.
        assert!(policies[0].path(&["recovery_mode"]).is_some());
        assert!(policies[0].path(&["recovery_bandwidth"]).is_some());
        assert!(policies[0].path(&["recovery_checkpoint_threshold"]).is_some());
        assert_eq!(
            policies[0].path(&["recovered_fraction", "mean"]).unwrap().as_f64(),
            Some(0.25)
        );
        // first-fit cells have 3 and 5 interruptions -> p95 12 and 14.
        assert_eq!(
            policies[0].path(&["requeue_p95_s", "max"]).unwrap().as_f64(),
            Some(14.0)
        );
        assert_eq!(
            policies[0].path(&["migrations", "mean"]).unwrap().as_f64(),
            Some(1.0)
        );
    }

    #[test]
    fn aggregate_table_renders() {
        let t = sample_report().aggregate_table().render();
        assert!(t.contains("first-fit"));
        assert!(t.contains("hlem-vmp-adjusted"));
    }

    #[test]
    fn retained_series_export_in_id_order() {
        let mut rep = sample_report();
        let mut s = TimeSeries::new(&["spot_running"]);
        s.push(0.0, &[1.0]);
        s.push(10.0, &[2.0]);
        rep.cells[2].series = Some(s.clone());
        rep.cells[0].series = Some(s);
        let out = rep.retained_series_csvs();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].0, 0);
        assert_eq!(out[1].0, 2);
        assert!(out[0].1.to_string().starts_with("time,spot_running"));
    }
}
