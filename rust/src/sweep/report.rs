//! Sweep results: per-cell report rows plus grid-level aggregates, with
//! CSV/JSON export through `util::csv` / `util::json`.
//!
//! Everything serialized here is a pure function of the cell results in
//! cell-id order. Nondeterministic per-run data (wall time, thread count)
//! is deliberately excluded so a sweep's exported artifacts are
//! byte-identical regardless of how many worker threads produced them
//! (pinned by `tests/sweep_determinism.rs`).

use crate::engine::Report;
use crate::stats::Summary;
use crate::util::csv::{fmt_num, Csv};
use crate::util::json::{Json, JsonObj};
use crate::util::table::{Align, TextTable};

use super::grid::{Cell, PolicySpec};

/// Outcome of one sweep cell: the run's [`Report`], or the panic/error
/// message of an isolated failure.
#[derive(Debug, Clone)]
pub struct CellResult {
    pub cell: Cell,
    pub outcome: Result<Report, String>,
}

impl CellResult {
    pub fn report(&self) -> Option<&Report> {
        self.outcome.as_ref().ok()
    }
}

/// Merged output of a sweep, cells in id order.
#[derive(Debug, Clone)]
pub struct SweepReport {
    pub cells: Vec<CellResult>,
    /// Worker threads used (observability only; never serialized).
    pub threads: usize,
}

/// Grid-level aggregate for one policy spec, over its succeeded cells.
#[derive(Debug, Clone)]
pub struct PolicyAggregate {
    pub policy: PolicySpec,
    pub runs: usize,
    pub interruptions: Summary,
    pub interrupted_vms: Summary,
    pub avg_interruption_secs: Summary,
    pub max_interruption_secs: Summary,
    pub max_interruptions_per_vm: u32,
}

impl SweepReport {
    pub fn total(&self) -> usize {
        self.cells.len()
    }

    /// Cells whose run failed (panicked or errored).
    pub fn failed(&self) -> usize {
        self.cells.iter().filter(|c| c.outcome.is_err()).count()
    }

    /// Per-policy aggregates in first-appearance (cell-id) order.
    pub fn aggregates(&self) -> Vec<PolicyAggregate> {
        let mut aggs: Vec<PolicyAggregate> = Vec::new();
        for cell in &self.cells {
            let idx = match aggs.iter().position(|a| a.policy == cell.cell.policy) {
                Some(i) => i,
                None => {
                    aggs.push(PolicyAggregate {
                        policy: cell.cell.policy,
                        runs: 0,
                        interruptions: Summary::new(),
                        interrupted_vms: Summary::new(),
                        avg_interruption_secs: Summary::new(),
                        max_interruption_secs: Summary::new(),
                        max_interruptions_per_vm: 0,
                    });
                    aggs.len() - 1
                }
            };
            let Some(report) = cell.report() else { continue };
            let a = &mut aggs[idx];
            a.runs += 1;
            a.interruptions.add(report.spot.interruptions as f64);
            a.interrupted_vms.add(report.spot.interrupted_vms as f64);
            a.avg_interruption_secs.add(report.spot.avg_interruption_secs);
            a.max_interruption_secs.add(report.spot.max_interruption_secs);
            a.max_interruptions_per_vm =
                a.max_interruptions_per_vm.max(report.spot.max_interruptions_per_vm);
        }
        aggs
    }

    /// Per-cell rows (one line per cell, id order). Deterministic: no wall
    /// times, no thread counts.
    pub fn cells_csv(&self) -> Csv {
        let mut csv = Csv::new(&[
            "cell",
            "policy",
            "alpha",
            "seed",
            "status",
            "error",
            "clock_end",
            "events",
            "vms_finished",
            "vms_terminated",
            "vms_failed",
            "spot_total",
            "interruptions",
            "interrupted_vms",
            "max_per_vm",
            "avg_interruption_s",
            "max_interruption_s",
            "min_interruption_s",
        ]);
        for c in &self.cells {
            let alpha = c.cell.policy.alpha().map(fmt_num).unwrap_or_default();
            match &c.outcome {
                Ok(r) => csv.push(vec![
                    c.cell.id.to_string(),
                    c.cell.policy.name().to_string(),
                    alpha,
                    c.cell.seed.to_string(),
                    "ok".into(),
                    String::new(),
                    fmt_num(r.clock_end),
                    r.events_processed.to_string(),
                    r.finished.to_string(),
                    r.terminated.to_string(),
                    r.failed.to_string(),
                    r.spot.total_spot.to_string(),
                    r.spot.interruptions.to_string(),
                    r.spot.interrupted_vms.to_string(),
                    r.spot.max_interruptions_per_vm.to_string(),
                    fmt_num(r.spot.avg_interruption_secs),
                    fmt_num(r.spot.max_interruption_secs),
                    fmt_num(r.spot.min_interruption_secs),
                ]),
                Err(e) => {
                    let mut row = vec![
                        c.cell.id.to_string(),
                        c.cell.policy.name().to_string(),
                        alpha,
                        c.cell.seed.to_string(),
                        "failed".into(),
                        e.clone(),
                    ];
                    row.extend(std::iter::repeat(String::new()).take(12));
                    csv.push(row);
                }
            }
        }
        csv
    }

    /// Grid-level aggregate document (per-policy `stats::Summary` moments).
    pub fn aggregate_json(&self) -> Json {
        let stat_obj = |s: &Summary| {
            let mut o = JsonObj::new();
            o.set("mean", Json::Num(s.mean()));
            o.set("min", Json::Num(s.min()));
            o.set("max", Json::Num(s.max()));
            o.set("stddev", Json::Num(s.stddev()));
            Json::Obj(o)
        };
        let mut root = JsonObj::new();
        root.set("cells", Json::Num(self.total() as f64));
        root.set("failed", Json::Num(self.failed() as f64));
        let mut policies = Vec::new();
        for a in self.aggregates() {
            let mut o = JsonObj::new();
            o.set("policy", Json::Str(a.policy.name().to_string()));
            match a.policy.alpha() {
                Some(alpha) => o.set("alpha", Json::Num(alpha)),
                None => o.set("alpha", Json::Null),
            };
            o.set("runs", Json::Num(a.runs as f64));
            o.set("interruptions", stat_obj(&a.interruptions));
            o.set("interrupted_vms", stat_obj(&a.interrupted_vms));
            o.set("avg_interruption_secs", stat_obj(&a.avg_interruption_secs));
            o.set("max_interruption_secs", stat_obj(&a.max_interruption_secs));
            o.set(
                "max_interruptions_per_vm",
                Json::Num(a.max_interruptions_per_vm as f64),
            );
            policies.push(Json::Obj(o));
        }
        root.set("policies", Json::Arr(policies));
        Json::Obj(root)
    }

    /// Terminal rendering of the grid-level aggregates.
    pub fn aggregate_table(&self) -> TextTable {
        let mut t = TextTable::new("SWEEP AGGREGATE (per policy, over seeds)")
            .column("Policy", Align::Left)
            .column("Runs", Align::Right)
            .column("Interruptions", Align::Right)
            .column("+/- sd", Align::Right)
            .column("Avg dur (s)", Align::Right)
            .column("Max dur (s)", Align::Right)
            .column("Max per VM", Align::Right);
        for a in self.aggregates() {
            t.push(vec![
                a.policy.name().to_string(),
                a.runs.to_string(),
                fmt_num(a.interruptions.mean()),
                fmt_num(a.interruptions.stddev()),
                fmt_num(a.avg_interruption_secs.mean()),
                fmt_num(a.max_interruption_secs.mean()),
                a.max_interruptions_per_vm.to_string(),
            ]);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::SpotStats;

    fn fake_report(policy: &'static str, interruptions: u64) -> Report {
        Report {
            policy,
            clock_end: 100.0,
            events_processed: 42,
            wall: std::time::Duration::from_millis(5),
            finished: 10,
            terminated: 1,
            failed: 0,
            still_active: 0,
            cloudlets_finished: 10,
            cloudlets_canceled: 1,
            alloc_attempts: 12,
            alloc_failures: 2,
            spot: SpotStats {
                total_spot: 4,
                interruptions,
                interrupted_vms: interruptions.min(4),
                avg_interruption_secs: 10.0 + interruptions as f64,
                max_interruption_secs: 20.0 + interruptions as f64,
                min_interruption_secs: 1.0,
                max_interruptions_per_vm: interruptions as u32,
                ..Default::default()
            },
        }
    }

    fn sample_report() -> SweepReport {
        let p = PolicySpec::FirstFit;
        let q = PolicySpec::Hlem { adjusted: true, alpha: -0.5 };
        SweepReport {
            cells: vec![
                CellResult {
                    cell: Cell { id: 0, seed: 1, policy: p },
                    outcome: Ok(fake_report("first-fit", 3)),
                },
                CellResult {
                    cell: Cell { id: 1, seed: 1, policy: q },
                    outcome: Ok(fake_report("hlem-vmp-adjusted", 1)),
                },
                CellResult {
                    cell: Cell { id: 2, seed: 2, policy: p },
                    outcome: Ok(fake_report("first-fit", 5)),
                },
                CellResult {
                    cell: Cell { id: 3, seed: 2, policy: q },
                    outcome: Err("boom".into()),
                },
            ],
            threads: 2,
        }
    }

    #[test]
    fn csv_has_one_row_per_cell_and_counts_failures() {
        let rep = sample_report();
        assert_eq!(rep.total(), 4);
        assert_eq!(rep.failed(), 1);
        let csv = rep.cells_csv();
        assert_eq!(csv.len(), 4);
        let text = csv.to_string();
        assert!(text.contains("failed,boom"));
        assert!(text.starts_with("cell,policy,alpha,seed,status"));
    }

    #[test]
    fn aggregates_group_by_policy_and_skip_failures() {
        let rep = sample_report();
        let aggs = rep.aggregates();
        assert_eq!(aggs.len(), 2);
        assert_eq!(aggs[0].policy, PolicySpec::FirstFit);
        assert_eq!(aggs[0].runs, 2);
        assert_eq!(aggs[0].interruptions.mean(), 4.0);
        assert_eq!(aggs[0].max_interruptions_per_vm, 5);
        // The failed hlem cell is excluded from moments but keeps the group.
        assert_eq!(aggs[1].runs, 1);
        assert_eq!(aggs[1].interruptions.mean(), 1.0);
    }

    #[test]
    fn aggregate_json_parses_and_excludes_wall() {
        let rep = sample_report();
        let text = rep.aggregate_json().to_string_pretty();
        let parsed = crate::util::json::parse(&text).unwrap();
        assert_eq!(parsed.path(&["cells"]).unwrap().as_f64(), Some(4.0));
        assert_eq!(parsed.path(&["failed"]).unwrap().as_f64(), Some(1.0));
        assert!(!text.contains("wall"), "wall time must not leak into sweep artifacts");
        assert!(!text.contains("thread"));
        let policies = parsed.path(&["policies"]).unwrap().as_arr().unwrap();
        assert_eq!(policies.len(), 2);
        assert_eq!(
            policies[0].path(&["interruptions", "mean"]).unwrap().as_f64(),
            Some(4.0)
        );
    }

    #[test]
    fn aggregate_table_renders() {
        let t = sample_report().aggregate_table().render();
        assert!(t.contains("first-fit"));
        assert!(t.contains("hlem-vmp-adjusted"));
    }
}
