//! Process-level sweep fan-out: shard job files, self-contained partial
//! artifacts, a deterministic merge, and a subprocess coordinator.
//!
//! The thread-pool driver saturates one machine; 10k+ cell grids mixing
//! expensive trace-substrate cells with cheap comparison cells want
//! process pools (and, across hosts, a job-file protocol). This module is
//! that layer:
//!
//! - [`partition`] splits a [`SweepSpec`]'s enumerated cells into
//!   [`Shard`]s, **cost-weighted** so trace-substrate cells (which
//!   dominate runtime via per-seed trace generation + bigger event
//!   counts) spread across shards instead of clumping into one straggler.
//! - [`write_shard_file`] / [`read_shard_file`] serialize a shard job:
//!   the **full spec** plus the shard's cell ids, so a worker process -
//!   on this host or another - needs nothing but the file.
//! - `cloudmarket sweep worker --shard <file> --out <file>` (in
//!   `main.rs`, on [`super::driver::run_cells`]) runs one shard
//!   in-process and writes a **self-contained partial artifact**: the
//!   spec, the shard's cell rows and retained series - and, like every
//!   sweep artifact, no wall-clock or thread/process data.
//! - [`merge_partials`] recombines partials by cell id and rejects
//!   overlapping, missing, out-of-range or foreign (different-spec)
//!   cells, yielding a [`SweepReport`] whose serialized artifacts are
//!   **byte-identical to the single-process [`super::run`] output** -
//!   `tests/sweep_process.rs` pins this across real worker subprocesses
//!   at 1/2/4 workers, including after a worker is killed mid-shard.
//! - [`coordinate`] is the same-host orchestration (`cloudmarket sweep
//!   --workers N`): it spawns one worker subprocess per shard, monitors
//!   them, **reassigns the shard of a crashed/killed worker** to a fresh
//!   subprocess (bounded retries), and merges. Workers signal *why* they
//!   died through an exit-code taxonomy ([`EXIT_RUNTIME`],
//!   [`EXIT_PARENT_GONE`], [`EXIT_BAD_SHARD`]); a bad-shard exit means
//!   the job file itself is corrupt/foreign, so the coordinator fails
//!   fast instead of burning retries on it. For cluster use, run the
//!   shard/worker/merge steps by hand instead (`docs/sweep-cookbook.md`,
//!   "Cluster-scale sweeps").
//!
//! # Wire format
//!
//! Plain JSON through `util::json`. Exactness rules: `f64` values are
//! written with Rust's shortest-round-trip `Display` (and re-parsed with
//! `str::parse::<f64>`), so every finite float survives the process
//! boundary bit-for-bit; unbounded `u64` counters (seeds, event counts)
//! are written as decimal **strings** because JSON numbers are doubles
//! and would corrupt values above 2^53; small indices (cell ids, shard
//! indices) stay plain numbers. Both file kinds embed a format name,
//! version, and an FNV-1a [`spec_digest`] of the spec so partials from a
//! different sweep (or an edited/corrupt file) fail loudly at merge time
//! instead of blending into the artifacts.

use std::collections::VecDeque;
use std::path::{Path, PathBuf};
use std::process::{Command, Stdio};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::chaos::{BrokerOutage, ChaosSpec, DemandSurge, HostMtbf, ReclaimStorm};
use crate::obs::{heartbeat_file, read_last_heartbeat, telemetry as tel, StallTracker, Telemetry};
use crate::config::scenario::ComparisonConfig;
use crate::engine::{
    EngineConfig, MarketStats, RecoveryStats, Report, ResilienceStats, SpotStats, VictimPolicy,
};
use crate::cloudlet::SchedulerKind;
use crate::market::MarketSpec;
use crate::recovery::{RecoveryMode, RecoverySpec};
use crate::metrics::TimeSeries;
use crate::trace::synth::SynthConfig;
use crate::trace::workload::WorkloadConfig;
use crate::util::json::{parse, Json, JsonObj};
use crate::vm::{InterruptionBehavior, SpotConfig};

use super::grid::{
    Cell, CellSpec, PolicySpec, ScenarioAxis, SeriesFilter, SpotOverride, Substrate, SweepSpec,
    TraceSubstrate,
};
use super::report::{CellResult, SweepReport};

/// Wire-format version shared by shard and partial files; bump on any
/// incompatible schema change.
pub const WIRE_VERSION: u64 = 1;
const SHARD_FORMAT: &str = "cloudmarket-sweep-shard";
const PARTIAL_FORMAT: &str = "cloudmarket-sweep-partial";

/// `cloudmarket sweep worker` exit-code taxonomy. The coordinator uses
/// these to tell transient failures (worth reassigning the shard to a
/// fresh subprocess) from permanent ones (fail the sweep immediately).
///
/// Runtime cell/IO failure inside an otherwise valid shard - transient
/// from the coordinator's point of view (bounded retries).
pub const EXIT_RUNTIME: i32 = 2;
/// The worker noticed its parent coordinator was gone and stopped on its
/// own (orphan cleanup, not a shard problem).
pub const EXIT_PARENT_GONE: i32 = 3;
/// The shard job file itself is unreadable, corrupt, or foreign (wrong
/// format/digest). Re-running the same file can only fail the same way,
/// so the coordinator treats this as **permanent** and never retries.
pub const EXIT_BAD_SHARD: i32 = 4;

/// Relative cost of one trace-substrate cell vs one comparison cell for
/// partitioning. Trace cells pay per-seed trace generation plus a larger
/// event volume; the exact ratio only affects balance, never results.
pub const TRACE_CELL_WEIGHT: u64 = 8;
/// Relative cost of one comparison-substrate cell (the unit).
pub const COMPARISON_CELL_WEIGHT: u64 = 1;

/// Partitioning cost of one cell (see the weight constants).
pub fn cell_weight(cell: &Cell) -> u64 {
    match cell.spec.substrate {
        Substrate::Comparison => COMPARISON_CELL_WEIGHT,
        Substrate::Trace => TRACE_CELL_WEIGHT,
    }
}

/// One shard of a sweep: a subset of the spec's enumerated cell ids.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Shard {
    /// Position in the partition (`0..of`).
    pub index: usize,
    /// Total shards in the partition.
    pub of: usize,
    /// Assigned cell ids, ascending.
    pub cell_ids: Vec<usize>,
    /// Summed [`cell_weight`] of the assigned cells (diagnostics and the
    /// balance property in `tests/properties.rs`; not serialized -
    /// recomputed from the spec on read).
    pub weight: u64,
}

/// Split `spec`'s cells into at most `shards` shards (clamped to the cell
/// count, so no shard is empty unless the grid itself is) using greedy
/// LPT: cells are taken heaviest-first (stable id tiebreak) and each goes
/// to the currently lightest shard. Deterministic, and balanced to within
/// one cell: `max_weight <= min_weight + max(cell_weight)`.
///
/// The partition never affects results - the merge is by cell id - so the
/// shard count is free to differ from the worker count that runs them.
pub fn partition(spec: &SweepSpec, shards: usize) -> Vec<Shard> {
    let cells = spec.cells();
    let n = shards.max(1).min(cells.len().max(1));
    let mut order: Vec<usize> = (0..cells.len()).collect();
    order.sort_by_key(|&i| (std::cmp::Reverse(cell_weight(&cells[i])), i));
    let mut bins: Vec<(u64, Vec<usize>)> = vec![(0, Vec::new()); n];
    for i in order {
        // First minimum = lowest shard index on ties: deterministic.
        let lightest = (0..n).min_by_key(|&b| bins[b].0).unwrap();
        bins[lightest].0 += cell_weight(&cells[i]);
        bins[lightest].1.push(cells[i].id);
    }
    bins.into_iter()
        .enumerate()
        .map(|(index, (weight, mut cell_ids))| {
            cell_ids.sort_unstable();
            Shard { index, of: n, cell_ids, weight }
        })
        .collect()
}

/// FNV-1a 64 over the spec's compact serialization, hex-encoded. Embedded
/// in shard and partial files so a merge can refuse inputs produced from
/// a different sweep.
pub fn spec_digest(spec: &SweepSpec) -> String {
    let text = spec_to_json(spec).to_string_compact();
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in text.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    format!("{h:016x}")
}

// ---------------------------------------------------------------------
// Encoding helpers. u64 counters go through strings (exact beyond 2^53);
// finite f64 through JSON numbers (shortest-round-trip Display, exact);
// small indices through JSON numbers.
// ---------------------------------------------------------------------

fn enc_u64(v: u64) -> Json {
    Json::Str(v.to_string())
}

fn enc_usize(v: usize) -> Json {
    debug_assert!(v < (1usize << 53), "index too large for a JSON number");
    Json::Num(v as f64)
}

fn enc_f64(v: f64) -> Json {
    debug_assert!(v.is_finite(), "non-finite f64 in sweep wire format");
    Json::Num(v)
}

fn as_obj<'a>(v: &'a Json, what: &str) -> Result<&'a JsonObj, String> {
    v.as_obj().ok_or_else(|| format!("{what}: expected an object"))
}

fn field<'a>(o: &'a JsonObj, key: &str) -> Result<&'a Json, String> {
    o.get(key).ok_or_else(|| format!("missing field '{key}'"))
}

fn str_field<'a>(o: &'a JsonObj, key: &str) -> Result<&'a str, String> {
    field(o, key)?.as_str().ok_or_else(|| format!("field '{key}' is not a string"))
}

fn f64_field(o: &JsonObj, key: &str) -> Result<f64, String> {
    field(o, key)?.as_f64().ok_or_else(|| format!("field '{key}' is not a number"))
}

fn u64_field(o: &JsonObj, key: &str) -> Result<u64, String> {
    str_field(o, key)?
        .parse()
        .map_err(|_| format!("field '{key}' is not a u64 decimal string"))
}

fn usize_field(o: &JsonObj, key: &str) -> Result<usize, String> {
    let v = f64_field(o, key)?;
    if v < 0.0 || v.fract() != 0.0 || v >= (1u64 << 53) as f64 {
        return Err(format!("field '{key}' is not a non-negative integer"));
    }
    Ok(v as usize)
}

fn arr_field<'a>(o: &'a JsonObj, key: &str) -> Result<&'a [Json], String> {
    field(o, key)?.as_arr().ok_or_else(|| format!("field '{key}' is not an array"))
}

fn opt_json(v: &Json) -> Option<&Json> {
    match v {
        Json::Null => None,
        other => Some(other),
    }
}

fn num_of(v: &Json, what: &str) -> Result<f64, String> {
    v.as_f64().ok_or_else(|| format!("{what}: expected a number"))
}

fn str_of<'a>(v: &'a Json, what: &str) -> Result<&'a str, String> {
    v.as_str().ok_or_else(|| format!("{what}: expected a string"))
}

// ---------------------------------------------------------------------
// SweepSpec <-> JSON
// ---------------------------------------------------------------------

fn spot_config_to_json(s: &SpotConfig) -> Json {
    let mut o = JsonObj::new();
    o.set("behavior", Json::Str(s.behavior.name().to_string()));
    o.set("min_running_time", enc_f64(s.min_running_time));
    o.set("warning_time", enc_f64(s.warning_time));
    o.set("hibernation_timeout", enc_f64(s.hibernation_timeout));
    Json::Obj(o)
}

fn spot_config_from_json(v: &Json) -> Result<SpotConfig, String> {
    let o = as_obj(v, "spot config")?;
    Ok(SpotConfig {
        behavior: InterruptionBehavior::parse(str_field(o, "behavior")?)?,
        min_running_time: f64_field(o, "min_running_time")?,
        warning_time: f64_field(o, "warning_time")?,
        hibernation_timeout: f64_field(o, "hibernation_timeout")?,
    })
}

fn comparison_to_json(c: &ComparisonConfig) -> Json {
    let mut o = JsonObj::new();
    o.set("seed", enc_u64(c.seed));
    o.set("mips_per_pe", enc_f64(c.mips_per_pe));
    o.set("immediate_on_demand", enc_usize(c.immediate_on_demand));
    o.set("max_delay", enc_f64(c.max_delay));
    o.set("exec_time", Json::Arr(vec![enc_f64(c.exec_time.0), enc_f64(c.exec_time.1)]));
    o.set("spot", spot_config_to_json(&c.spot));
    o.set("waiting_time", enc_f64(c.waiting_time));
    o.set("terminate_at", enc_f64(c.terminate_at));
    Json::Obj(o)
}

fn comparison_from_json(v: &Json) -> Result<ComparisonConfig, String> {
    let o = as_obj(v, "comparison scenario")?;
    let exec = arr_field(o, "exec_time")?;
    if exec.len() != 2 {
        return Err("field 'exec_time' must be a [lo, hi] pair".into());
    }
    Ok(ComparisonConfig {
        seed: u64_field(o, "seed")?,
        mips_per_pe: f64_field(o, "mips_per_pe")?,
        immediate_on_demand: usize_field(o, "immediate_on_demand")?,
        max_delay: f64_field(o, "max_delay")?,
        exec_time: (num_of(&exec[0], "exec_time[0]")?, num_of(&exec[1], "exec_time[1]")?),
        spot: spot_config_from_json(field(o, "spot")?)?,
        waiting_time: f64_field(o, "waiting_time")?,
        terminate_at: f64_field(o, "terminate_at")?,
    })
}

fn scheduler_name(k: SchedulerKind) -> &'static str {
    match k {
        SchedulerKind::TimeShared => "time-shared",
        SchedulerKind::SpaceShared => "space-shared",
    }
}

fn scheduler_parse(s: &str) -> Result<SchedulerKind, String> {
    match s {
        "time-shared" => Ok(SchedulerKind::TimeShared),
        "space-shared" => Ok(SchedulerKind::SpaceShared),
        other => Err(format!("unknown scheduler '{other}'")),
    }
}

fn engine_to_json(e: &EngineConfig) -> Json {
    let mut o = JsonObj::new();
    o.set("min_dt", enc_f64(e.min_dt));
    o.set("scheduling_interval", enc_f64(e.scheduling_interval));
    o.set("sample_interval", enc_f64(e.sample_interval));
    o.set("vm_destruction_delay", enc_f64(e.vm_destruction_delay));
    o.set("scheduler", Json::Str(scheduler_name(e.scheduler).to_string()));
    o.set("retry_interval", enc_f64(e.retry_interval));
    o.set("resubmit_cooldown", enc_f64(e.resubmit_cooldown));
    o.set("max_log_events", enc_usize(e.max_log_events));
    Json::Obj(o)
}

fn engine_from_json(v: &Json) -> Result<EngineConfig, String> {
    let o = as_obj(v, "engine config")?;
    Ok(EngineConfig {
        min_dt: f64_field(o, "min_dt")?,
        scheduling_interval: f64_field(o, "scheduling_interval")?,
        sample_interval: f64_field(o, "sample_interval")?,
        vm_destruction_delay: f64_field(o, "vm_destruction_delay")?,
        scheduler: scheduler_parse(str_field(o, "scheduler")?)?,
        retry_interval: f64_field(o, "retry_interval")?,
        resubmit_cooldown: f64_field(o, "resubmit_cooldown")?,
        max_log_events: usize_field(o, "max_log_events")?,
    })
}

fn synth_to_json(s: &SynthConfig) -> Json {
    let mut o = JsonObj::new();
    o.set("seed", enc_u64(s.seed));
    o.set("machines", enc_usize(s.machines));
    o.set("days", enc_f64(s.days));
    o.set("tasks_per_hour", enc_f64(s.tasks_per_hour));
    o.set("diurnal_amplitude", enc_f64(s.diurnal_amplitude));
    o.set("peak_hour", enc_f64(s.peak_hour));
    o.set("users", enc_usize(s.users));
    o.set("machine_churn", enc_f64(s.machine_churn));
    o.set("evict_prob", enc_f64(s.evict_prob));
    o.set("fail_prob", enc_f64(s.fail_prob));
    o.set("median_duration", enc_f64(s.median_duration));
    o.set("duration_sigma", enc_f64(s.duration_sigma));
    Json::Obj(o)
}

fn synth_from_json(v: &Json) -> Result<SynthConfig, String> {
    let o = as_obj(v, "synth config")?;
    Ok(SynthConfig {
        seed: u64_field(o, "seed")?,
        machines: usize_field(o, "machines")?,
        days: f64_field(o, "days")?,
        tasks_per_hour: f64_field(o, "tasks_per_hour")?,
        diurnal_amplitude: f64_field(o, "diurnal_amplitude")?,
        peak_hour: f64_field(o, "peak_hour")?,
        users: usize_field(o, "users")?,
        machine_churn: f64_field(o, "machine_churn")?,
        evict_prob: f64_field(o, "evict_prob")?,
        fail_prob: f64_field(o, "fail_prob")?,
        median_duration: f64_field(o, "median_duration")?,
        duration_sigma: f64_field(o, "duration_sigma")?,
    })
}

fn workload_to_json(w: &WorkloadConfig) -> Json {
    let mut o = JsonObj::new();
    o.set("seed", enc_u64(w.seed));
    o.set("pes_per_unit", enc_usize(w.pes_per_unit as usize));
    o.set("mips_per_pe", enc_f64(w.mips_per_pe));
    o.set("ram_per_unit", enc_f64(w.ram_per_unit));
    o.set("group_size", enc_usize(w.group_size));
    o.set("spot_instances", enc_usize(w.spot_instances));
    o.set(
        "spot_durations",
        Json::Arr(w.spot_durations.iter().map(|&d| enc_f64(d)).collect()),
    );
    o.set("spot", spot_config_to_json(&w.spot));
    o.set("waiting_time", enc_f64(w.waiting_time));
    o.set("max_trace_vms", enc_usize(w.max_trace_vms));
    Json::Obj(o)
}

fn workload_from_json(v: &Json) -> Result<WorkloadConfig, String> {
    let o = as_obj(v, "workload config")?;
    let pes = usize_field(o, "pes_per_unit")?;
    let durations = arr_field(o, "spot_durations")?
        .iter()
        .map(|d| num_of(d, "spot_durations entry"))
        .collect::<Result<Vec<f64>, _>>()?;
    Ok(WorkloadConfig {
        seed: u64_field(o, "seed")?,
        pes_per_unit: u32::try_from(pes).map_err(|_| "pes_per_unit too large".to_string())?,
        mips_per_pe: f64_field(o, "mips_per_pe")?,
        ram_per_unit: f64_field(o, "ram_per_unit")?,
        group_size: usize_field(o, "group_size")?,
        spot_instances: usize_field(o, "spot_instances")?,
        spot_durations: durations,
        spot: spot_config_from_json(field(o, "spot")?)?,
        waiting_time: f64_field(o, "waiting_time")?,
        max_trace_vms: usize_field(o, "max_trace_vms")?,
    })
}

fn trace_substrate_to_json(t: &TraceSubstrate) -> Json {
    let mut o = JsonObj::new();
    o.set("synth", synth_to_json(&t.synth));
    o.set("workload", workload_to_json(&t.workload));
    o.set("sample_interval", enc_f64(t.sample_interval));
    Json::Obj(o)
}

fn trace_substrate_from_json(v: &Json) -> Result<TraceSubstrate, String> {
    let o = as_obj(v, "trace substrate")?;
    Ok(TraceSubstrate {
        synth: synth_from_json(field(o, "synth")?)?,
        workload: workload_from_json(field(o, "workload")?)?,
        sample_interval: f64_field(o, "sample_interval")?,
    })
}

fn policy_to_json(p: &PolicySpec) -> Json {
    let mut o = JsonObj::new();
    o.set("name", Json::Str(p.name().to_string()));
    if let PolicySpec::Hlem { alpha, .. } = p {
        o.set("alpha", enc_f64(*alpha));
    }
    Json::Obj(o)
}

fn policy_from_json(v: &Json) -> Result<PolicySpec, String> {
    let o = as_obj(v, "policy")?;
    // The name vocabulary lives in `PolicySpec::parse` (one source of
    // truth); the stored alpha is then restored exactly, since `parse`
    // zeroes it for plain HLEM and round-tripping must preserve it
    // bit-for-bit for both variants.
    match PolicySpec::parse(str_field(o, "name")?, 0.0)? {
        PolicySpec::Hlem { adjusted, .. } => {
            Ok(PolicySpec::Hlem { adjusted, alpha: f64_field(o, "alpha")? })
        }
        other => Ok(other),
    }
}

/// The `Report::policy` static-str vocabulary: resolve through
/// [`PolicySpec::parse`] (the single name registry) back to the interned
/// `&'static str` the engine would have reported.
fn static_policy_name(name: &str) -> Result<&'static str, String> {
    Ok(PolicySpec::parse(name, 0.0)?.name())
}

fn axis_to_json(a: &ScenarioAxis) -> Json {
    let mut o = JsonObj::new();
    o.set("name", Json::Str(a.name().to_string()));
    let values = match a {
        ScenarioAxis::SpotWarning(v) | ScenarioAxis::SpotHibernationTimeout(v) => {
            v.iter().map(|&x| enc_f64(x)).collect()
        }
        ScenarioAxis::HlemAlpha(v) => v.iter().map(|&x| enc_f64(x)).collect(),
        ScenarioAxis::SpotBehavior(v) => {
            v.iter().map(|b| Json::Str(b.name().to_string())).collect()
        }
        ScenarioAxis::Victim(v) => v.iter().map(|p| Json::Str(p.name().to_string())).collect(),
        ScenarioAxis::Substrate(v) => {
            v.iter().map(|s| Json::Str(s.name().to_string())).collect()
        }
        // Chaos axis values go over the wire as their compact labels:
        // labels embed f64 fields via shortest-round-trip `Display`, so
        // `parse(label)` reconstructs the exact same bits.
        ScenarioAxis::ChaosHostMtbf(v) => v.iter().map(|x| Json::Str(x.label())).collect(),
        ScenarioAxis::ChaosReclaimStorm(v) => {
            v.iter().map(|x| Json::Str(x.label())).collect()
        }
        ScenarioAxis::ChaosBrokerOutage(v) => {
            v.iter().map(|x| Json::Str(x.label())).collect()
        }
        ScenarioAxis::ChaosDemandSurge(v) => {
            v.iter().map(|x| Json::Str(x.label())).collect()
        }
        // Market axis values are plain finite f64s: JSON numbers are
        // exact via shortest-round-trip Display.
        ScenarioAxis::MarketVolatility(v)
        | ScenarioAxis::MarketMeanReversion(v)
        | ScenarioAxis::MarketDailyAmplitude(v)
        | ScenarioAxis::MarketBidMargin(v) => v.iter().map(|&x| enc_f64(x)).collect(),
        ScenarioAxis::RecoveryMode(v) => {
            v.iter().map(|m| Json::Str(m.label().to_string())).collect()
        }
        // Recovery numeric axes share the market exactness rule.
        ScenarioAxis::RecoveryBandwidth(v) | ScenarioAxis::RecoveryCheckpointThreshold(v) => {
            v.iter().map(|&x| enc_f64(x)).collect()
        }
    };
    o.set("values", Json::Arr(values));
    Json::Obj(o)
}

fn axis_from_json(v: &Json) -> Result<ScenarioAxis, String> {
    let o = as_obj(v, "axis")?;
    let name = str_field(o, "name")?;
    let values = arr_field(o, "values")?;
    let nums = || -> Result<Vec<f64>, String> {
        values.iter().map(|x| num_of(x, "axis value")).collect()
    };
    match name {
        "spot.warning" => Ok(ScenarioAxis::SpotWarning(nums()?)),
        "spot.hibernation-timeout" => Ok(ScenarioAxis::SpotHibernationTimeout(nums()?)),
        "hlem.alpha" => Ok(ScenarioAxis::HlemAlpha(nums()?)),
        "spot.behavior" => Ok(ScenarioAxis::SpotBehavior(
            values
                .iter()
                .map(|x| InterruptionBehavior::parse(str_of(x, "axis value")?))
                .collect::<Result<_, _>>()?,
        )),
        "victim" => Ok(ScenarioAxis::Victim(
            values
                .iter()
                .map(|x| VictimPolicy::parse(str_of(x, "axis value")?))
                .collect::<Result<_, _>>()?,
        )),
        "substrate" => Ok(ScenarioAxis::Substrate(
            values
                .iter()
                .map(|x| Substrate::parse(str_of(x, "axis value")?))
                .collect::<Result<_, _>>()?,
        )),
        "chaos.host-mtbf" => Ok(ScenarioAxis::ChaosHostMtbf(
            values
                .iter()
                .map(|x| HostMtbf::parse(str_of(x, "axis value")?))
                .collect::<Result<_, _>>()?,
        )),
        "chaos.reclaim-storm" => Ok(ScenarioAxis::ChaosReclaimStorm(
            values
                .iter()
                .map(|x| ReclaimStorm::parse(str_of(x, "axis value")?))
                .collect::<Result<_, _>>()?,
        )),
        "chaos.broker-outage" => Ok(ScenarioAxis::ChaosBrokerOutage(
            values
                .iter()
                .map(|x| BrokerOutage::parse(str_of(x, "axis value")?))
                .collect::<Result<_, _>>()?,
        )),
        "chaos.demand-surge" => Ok(ScenarioAxis::ChaosDemandSurge(
            values
                .iter()
                .map(|x| DemandSurge::parse(str_of(x, "axis value")?))
                .collect::<Result<_, _>>()?,
        )),
        "market.volatility" => Ok(ScenarioAxis::MarketVolatility(nums()?)),
        "market.mean-reversion" => Ok(ScenarioAxis::MarketMeanReversion(nums()?)),
        "market.daily-amplitude" => Ok(ScenarioAxis::MarketDailyAmplitude(nums()?)),
        "market.bid-margin" => Ok(ScenarioAxis::MarketBidMargin(nums()?)),
        "recovery.mode" => Ok(ScenarioAxis::RecoveryMode(
            values
                .iter()
                .map(|x| RecoveryMode::parse(str_of(x, "axis value")?))
                .collect::<Result<_, _>>()?,
        )),
        "recovery.bandwidth" => Ok(ScenarioAxis::RecoveryBandwidth(nums()?)),
        "recovery.checkpoint-threshold" => Ok(ScenarioAxis::RecoveryCheckpointThreshold(nums()?)),
        other => Err(format!("unknown axis '{other}'")),
    }
}

/// Serialize a full [`SweepSpec`] (every field - the decoded spec
/// enumerates the exact same cells and produces bit-identical runs).
pub fn spec_to_json(spec: &SweepSpec) -> Json {
    let mut o = JsonObj::new();
    o.set("scenario", comparison_to_json(&spec.scenario));
    o.set("engine", engine_to_json(&spec.engine));
    o.set("seeds", Json::Arr(spec.seeds.iter().map(|&s| enc_u64(s)).collect()));
    o.set("policies", Json::Arr(spec.policies.iter().map(policy_to_json).collect()));
    o.set("axes", Json::Arr(spec.axes.iter().map(axis_to_json).collect()));
    o.set("trace", trace_substrate_to_json(&spec.trace));
    o.set("retain", Json::Str(spec.retain.spec_string()));
    o.set(
        "explicit",
        Json::Arr(
            spec.explicit
                .iter()
                .map(|(seed, policy)| {
                    let mut e = JsonObj::new();
                    e.set("seed", enc_u64(*seed));
                    e.set("policy", policy_to_json(policy));
                    Json::Obj(e)
                })
                .collect(),
        ),
    );
    Json::Obj(o)
}

/// Inverse of [`spec_to_json`]; `spec_from_json(&spec_to_json(s)) == s`.
pub fn spec_from_json(v: &Json) -> Result<SweepSpec, String> {
    let o = as_obj(v, "sweep spec")?;
    let seeds = arr_field(o, "seeds")?
        .iter()
        .map(|s| {
            str_of(s, "seed")?
                .parse::<u64>()
                .map_err(|_| "seed is not a u64 decimal string".to_string())
        })
        .collect::<Result<Vec<u64>, _>>()?;
    let policies = arr_field(o, "policies")?
        .iter()
        .map(policy_from_json)
        .collect::<Result<Vec<_>, _>>()?;
    let axes = arr_field(o, "axes")?
        .iter()
        .map(axis_from_json)
        .collect::<Result<Vec<_>, _>>()?;
    let explicit = arr_field(o, "explicit")?
        .iter()
        .map(|e| {
            let eo = as_obj(e, "explicit cell")?;
            Ok((u64_field(eo, "seed")?, policy_from_json(field(eo, "policy")?)?))
        })
        .collect::<Result<Vec<_>, String>>()?;
    Ok(SweepSpec {
        scenario: comparison_from_json(field(o, "scenario")?)?,
        engine: engine_from_json(field(o, "engine")?)?,
        seeds,
        policies,
        axes,
        trace: trace_substrate_from_json(field(o, "trace")?)?,
        retain: SeriesFilter::parse(str_field(o, "retain")?)?,
        explicit,
    })
}

// ---------------------------------------------------------------------
// Cell results <-> JSON
// ---------------------------------------------------------------------

fn spot_override_to_json(s: &SpotOverride) -> Json {
    let opt_num = |v: Option<f64>| v.map(enc_f64).unwrap_or(Json::Null);
    let mut o = JsonObj::new();
    o.set("warning", opt_num(s.warning_time));
    o.set("hibernation_timeout", opt_num(s.hibernation_timeout));
    o.set(
        "behavior",
        s.behavior.map(|b| Json::Str(b.name().to_string())).unwrap_or(Json::Null),
    );
    Json::Obj(o)
}

fn spot_override_from_json(v: &Json) -> Result<SpotOverride, String> {
    let o = as_obj(v, "spot override")?;
    let opt_num = |key: &str| -> Result<Option<f64>, String> {
        opt_json(field(o, key)?).map(|x| num_of(x, key)).transpose()
    };
    Ok(SpotOverride {
        warning_time: opt_num("warning")?,
        hibernation_timeout: opt_num("hibernation_timeout")?,
        behavior: opt_json(field(o, "behavior")?)
            .map(|x| InterruptionBehavior::parse(str_of(x, "behavior")?))
            .transpose()?,
    })
}

fn cell_to_json(c: &Cell) -> Json {
    let mut spec = JsonObj::new();
    spec.set("substrate", Json::Str(c.spec.substrate.name().to_string()));
    spec.set("policy", policy_to_json(&c.spec.policy));
    spec.set("spot", spot_override_to_json(&c.spec.spot));
    spec.set(
        "victim",
        c.spec.victim.map(|v| Json::Str(v.name().to_string())).unwrap_or(Json::Null),
    );
    let mut ch = JsonObj::new();
    ch.set(
        "host_mtbf",
        c.spec.chaos.host_mtbf.map(|x| Json::Str(x.label())).unwrap_or(Json::Null),
    );
    ch.set(
        "reclaim_storm",
        c.spec.chaos.reclaim_storm.map(|x| Json::Str(x.label())).unwrap_or(Json::Null),
    );
    ch.set(
        "broker_outage",
        c.spec.chaos.broker_outage.map(|x| Json::Str(x.label())).unwrap_or(Json::Null),
    );
    ch.set(
        "demand_surge",
        c.spec.chaos.demand_surge.map(|x| Json::Str(x.label())).unwrap_or(Json::Null),
    );
    spec.set("chaos", Json::Obj(ch));
    let opt_num = |v: Option<f64>| v.map(enc_f64).unwrap_or(Json::Null);
    let mut mk = JsonObj::new();
    mk.set("volatility", opt_num(c.spec.market.volatility));
    mk.set("mean_reversion", opt_num(c.spec.market.mean_reversion));
    mk.set("daily_amplitude", opt_num(c.spec.market.daily_amplitude));
    mk.set("bid_margin", opt_num(c.spec.market.bid_margin));
    spec.set("market", Json::Obj(mk));
    let mut rc = JsonObj::new();
    rc.set(
        "mode",
        c.spec.recovery.mode.map(|m| Json::Str(m.label().to_string())).unwrap_or(Json::Null),
    );
    rc.set("bandwidth", opt_num(c.spec.recovery.bandwidth));
    rc.set("checkpoint_threshold", opt_num(c.spec.recovery.checkpoint_threshold));
    spec.set("recovery", Json::Obj(rc));
    let mut o = JsonObj::new();
    o.set("id", enc_usize(c.id));
    o.set("seed", enc_u64(c.seed));
    o.set("spec", Json::Obj(spec));
    Json::Obj(o)
}

fn cell_from_json(v: &Json) -> Result<Cell, String> {
    let o = as_obj(v, "cell")?;
    let so = as_obj(field(o, "spec")?, "cell spec")?;
    let co = as_obj(field(so, "chaos")?, "cell chaos spec")?;
    let mo = as_obj(field(so, "market")?, "cell market spec")?;
    let ro = as_obj(field(so, "recovery")?, "cell recovery spec")?;
    let mk_num = |key: &str| -> Result<Option<f64>, String> {
        opt_json(field(mo, key)?).map(|x| num_of(x, key)).transpose()
    };
    let rc_num = |key: &str| -> Result<Option<f64>, String> {
        opt_json(field(ro, key)?).map(|x| num_of(x, key)).transpose()
    };
    let spec = CellSpec {
        substrate: Substrate::parse(str_field(so, "substrate")?)?,
        policy: policy_from_json(field(so, "policy")?)?,
        spot: spot_override_from_json(field(so, "spot")?)?,
        victim: opt_json(field(so, "victim")?)
            .map(|x| VictimPolicy::parse(str_of(x, "victim")?))
            .transpose()?,
        chaos: ChaosSpec {
            host_mtbf: opt_json(field(co, "host_mtbf")?)
                .map(|x| HostMtbf::parse(str_of(x, "host_mtbf")?))
                .transpose()?,
            reclaim_storm: opt_json(field(co, "reclaim_storm")?)
                .map(|x| ReclaimStorm::parse(str_of(x, "reclaim_storm")?))
                .transpose()?,
            broker_outage: opt_json(field(co, "broker_outage")?)
                .map(|x| BrokerOutage::parse(str_of(x, "broker_outage")?))
                .transpose()?,
            demand_surge: opt_json(field(co, "demand_surge")?)
                .map(|x| DemandSurge::parse(str_of(x, "demand_surge")?))
                .transpose()?,
        },
        market: MarketSpec {
            volatility: mk_num("volatility")?,
            mean_reversion: mk_num("mean_reversion")?,
            daily_amplitude: mk_num("daily_amplitude")?,
            bid_margin: mk_num("bid_margin")?,
        },
        recovery: RecoverySpec {
            mode: opt_json(field(ro, "mode")?)
                .map(|x| RecoveryMode::parse(str_of(x, "mode")?))
                .transpose()?,
            bandwidth: rc_num("bandwidth")?,
            checkpoint_threshold: rc_num("checkpoint_threshold")?,
        },
    };
    Ok(Cell { id: usize_field(o, "id")?, seed: u64_field(o, "seed")?, spec })
}

fn report_to_json(r: &Report) -> Json {
    let mut o = JsonObj::new();
    o.set("policy", Json::Str(r.policy.to_string()));
    o.set("clock_end", enc_f64(r.clock_end));
    o.set("events_processed", enc_u64(r.events_processed));
    // `wall` is deliberately not serialized: partials carry no wall/
    // thread/process data (the byte-identity contract).
    o.set("finished", enc_u64(r.finished));
    o.set("terminated", enc_u64(r.terminated));
    o.set("failed", enc_u64(r.failed));
    o.set("still_active", enc_u64(r.still_active));
    o.set("cloudlets_finished", enc_u64(r.cloudlets_finished));
    o.set("cloudlets_canceled", enc_u64(r.cloudlets_canceled));
    o.set("alloc_attempts", enc_u64(r.alloc_attempts));
    o.set("alloc_failures", enc_u64(r.alloc_failures));
    let s = &r.spot;
    let mut sp = JsonObj::new();
    sp.set("total_spot", enc_u64(s.total_spot));
    sp.set("interruptions", enc_u64(s.interruptions));
    sp.set("interrupted_vms", enc_u64(s.interrupted_vms));
    sp.set("uninterrupted_completions", enc_u64(s.uninterrupted_completions));
    sp.set("redeployments", enc_u64(s.redeployments));
    sp.set("completed_after_interruption", enc_u64(s.completed_after_interruption));
    sp.set("terminated", enc_u64(s.terminated));
    sp.set("max_interruptions_per_vm", enc_u64(u64::from(s.max_interruptions_per_vm)));
    sp.set("avg_interruption_secs", enc_f64(s.avg_interruption_secs));
    sp.set("max_interruption_secs", enc_f64(s.max_interruption_secs));
    sp.set("min_interruption_secs", enc_f64(s.min_interruption_secs));
    o.set("spot", Json::Obj(sp));
    let rs = &r.resilience;
    let mut re = JsonObj::new();
    re.set("storms", enc_u64(rs.storms));
    re.set("storm_reclaims", enc_u64(rs.storm_reclaims));
    re.set("host_failures", enc_u64(rs.host_failures));
    re.set("recoveries", enc_u64(rs.recoveries));
    re.set("interruptions_per_storm", enc_f64(rs.interruptions_per_storm));
    re.set("p95_interruption_secs", enc_f64(rs.p95_interruption_secs));
    re.set("avg_recovery_secs", enc_f64(rs.avg_recovery_secs));
    re.set("max_recovery_secs", enc_f64(rs.max_recovery_secs));
    re.set("work_lost_mi", enc_f64(rs.work_lost_mi));
    re.set("work_recovered_mi", enc_f64(rs.work_recovered_mi));
    o.set("resilience", Json::Obj(re));
    let m = &r.market;
    let mut mk = JsonObj::new();
    mk.set("spot_cost_usd", enc_f64(m.spot_cost_usd));
    mk.set("on_demand_cost_usd", enc_f64(m.on_demand_cost_usd));
    mk.set("savings_ratio", enc_f64(m.savings_ratio));
    mk.set("price_reclaims", enc_u64(m.price_reclaims));
    mk.set("mean_price_paid", enc_f64(m.mean_price_paid));
    mk.set("max_price_paid", enc_f64(m.max_price_paid));
    o.set("market", Json::Obj(mk));
    let rc = &r.recovery;
    let mut rv = JsonObj::new();
    rv.set("checkpoints", enc_u64(rc.checkpoints));
    rv.set("checkpoint_mb", enc_f64(rc.checkpoint_mb));
    rv.set("migrations", enc_u64(rc.migrations));
    rv.set("failed_migrations", enc_u64(rc.failed_migrations));
    rv.set("work_recovered_mi", enc_f64(rc.work_recovered_mi));
    rv.set("work_lost_mi", enc_f64(rc.work_lost_mi));
    rv.set("recovered_fraction", enc_f64(rc.recovered_fraction));
    rv.set("requeue_p50_s", enc_f64(rc.requeue_p50_s));
    rv.set("requeue_p95_s", enc_f64(rc.requeue_p95_s));
    rv.set("requeue_max_s", enc_f64(rc.requeue_max_s));
    o.set("recovery", Json::Obj(rv));
    Json::Obj(o)
}

fn report_from_json(v: &Json) -> Result<Report, String> {
    let o = as_obj(v, "report")?;
    let sp = as_obj(field(o, "spot")?, "spot stats")?;
    let re = as_obj(field(o, "resilience")?, "resilience stats")?;
    let mk = as_obj(field(o, "market")?, "market stats")?;
    let rc = as_obj(field(o, "recovery")?, "recovery stats")?;
    let max_per_vm = u64_field(sp, "max_interruptions_per_vm")?;
    Ok(Report {
        policy: static_policy_name(str_field(o, "policy")?)?,
        clock_end: f64_field(o, "clock_end")?,
        events_processed: u64_field(o, "events_processed")?,
        // Wall time never crosses the wire; zero keeps the field honest
        // ("no per-process timing survives the merge").
        wall: Duration::ZERO,
        finished: u64_field(o, "finished")?,
        terminated: u64_field(o, "terminated")?,
        failed: u64_field(o, "failed")?,
        still_active: u64_field(o, "still_active")?,
        cloudlets_finished: u64_field(o, "cloudlets_finished")?,
        cloudlets_canceled: u64_field(o, "cloudlets_canceled")?,
        alloc_attempts: u64_field(o, "alloc_attempts")?,
        alloc_failures: u64_field(o, "alloc_failures")?,
        spot: SpotStats {
            total_spot: u64_field(sp, "total_spot")?,
            interruptions: u64_field(sp, "interruptions")?,
            interrupted_vms: u64_field(sp, "interrupted_vms")?,
            uninterrupted_completions: u64_field(sp, "uninterrupted_completions")?,
            redeployments: u64_field(sp, "redeployments")?,
            completed_after_interruption: u64_field(sp, "completed_after_interruption")?,
            terminated: u64_field(sp, "terminated")?,
            max_interruptions_per_vm: u32::try_from(max_per_vm)
                .map_err(|_| "max_interruptions_per_vm out of range".to_string())?,
            avg_interruption_secs: f64_field(sp, "avg_interruption_secs")?,
            max_interruption_secs: f64_field(sp, "max_interruption_secs")?,
            min_interruption_secs: f64_field(sp, "min_interruption_secs")?,
        },
        resilience: ResilienceStats {
            storms: u64_field(re, "storms")?,
            storm_reclaims: u64_field(re, "storm_reclaims")?,
            host_failures: u64_field(re, "host_failures")?,
            recoveries: u64_field(re, "recoveries")?,
            interruptions_per_storm: f64_field(re, "interruptions_per_storm")?,
            p95_interruption_secs: f64_field(re, "p95_interruption_secs")?,
            avg_recovery_secs: f64_field(re, "avg_recovery_secs")?,
            max_recovery_secs: f64_field(re, "max_recovery_secs")?,
            work_lost_mi: f64_field(re, "work_lost_mi")?,
            work_recovered_mi: f64_field(re, "work_recovered_mi")?,
        },
        market: MarketStats {
            spot_cost_usd: f64_field(mk, "spot_cost_usd")?,
            on_demand_cost_usd: f64_field(mk, "on_demand_cost_usd")?,
            savings_ratio: f64_field(mk, "savings_ratio")?,
            price_reclaims: u64_field(mk, "price_reclaims")?,
            mean_price_paid: f64_field(mk, "mean_price_paid")?,
            max_price_paid: f64_field(mk, "max_price_paid")?,
        },
        recovery: RecoveryStats {
            checkpoints: u64_field(rc, "checkpoints")?,
            checkpoint_mb: f64_field(rc, "checkpoint_mb")?,
            migrations: u64_field(rc, "migrations")?,
            failed_migrations: u64_field(rc, "failed_migrations")?,
            work_recovered_mi: f64_field(rc, "work_recovered_mi")?,
            work_lost_mi: f64_field(rc, "work_lost_mi")?,
            recovered_fraction: f64_field(rc, "recovered_fraction")?,
            requeue_p50_s: f64_field(rc, "requeue_p50_s")?,
            requeue_p95_s: f64_field(rc, "requeue_p95_s")?,
            requeue_max_s: f64_field(rc, "requeue_max_s")?,
        },
    })
}

fn series_to_json(s: &TimeSeries) -> Json {
    let mut o = JsonObj::new();
    o.set(
        "columns",
        Json::Arr(s.columns().iter().map(|c| Json::Str(c.clone())).collect()),
    );
    o.set("times", Json::Arr(s.times().iter().map(|&t| enc_f64(t)).collect()));
    let values: Vec<Json> = s
        .columns()
        .iter()
        .map(|name| {
            let col = s.column(name).expect("series column by its own name");
            Json::Arr(col.iter().map(|&v| enc_f64(v)).collect())
        })
        .collect();
    o.set("values", Json::Arr(values));
    Json::Obj(o)
}

fn series_from_json(v: &Json) -> Result<TimeSeries, String> {
    let o = as_obj(v, "series")?;
    let columns = arr_field(o, "columns")?
        .iter()
        .map(|c| str_of(c, "series column").map(str::to_string))
        .collect::<Result<Vec<_>, _>>()?;
    let times = arr_field(o, "times")?
        .iter()
        .map(|t| num_of(t, "series time"))
        .collect::<Result<Vec<f64>, _>>()?;
    let value_arrs = arr_field(o, "values")?;
    if value_arrs.len() != columns.len() {
        return Err("series value columns do not match the column names".into());
    }
    let mut cols: Vec<Vec<f64>> = Vec::with_capacity(value_arrs.len());
    for arr in value_arrs {
        let col = arr
            .as_arr()
            .ok_or_else(|| "series value column is not an array".to_string())?
            .iter()
            .map(|x| num_of(x, "series value"))
            .collect::<Result<Vec<f64>, _>>()?;
        if col.len() != times.len() {
            return Err("series value column length does not match the time column".into());
        }
        cols.push(col);
    }
    let names: Vec<&str> = columns.iter().map(String::as_str).collect();
    let mut series = TimeSeries::new(&names);
    let mut row = vec![0.0; cols.len()];
    for (i, &t) in times.iter().enumerate() {
        for (c, col) in cols.iter().enumerate() {
            row[c] = col[i];
        }
        series.push(t, &row);
    }
    Ok(series)
}

fn cell_result_to_json(r: &CellResult) -> Json {
    let mut o = JsonObj::new();
    o.set("cell", cell_to_json(&r.cell));
    match &r.outcome {
        Ok(report) => {
            o.set("report", report_to_json(report));
            o.set("error", Json::Null);
        }
        Err(e) => {
            o.set("report", Json::Null);
            o.set("error", Json::Str(e.clone()));
        }
    }
    o.set("series", r.series.as_ref().map(series_to_json).unwrap_or(Json::Null));
    Json::Obj(o)
}

fn cell_result_from_json(v: &Json) -> Result<CellResult, String> {
    let o = as_obj(v, "cell result")?;
    let outcome = match (opt_json(field(o, "report")?), opt_json(field(o, "error")?)) {
        (Some(report), None) => Ok(report_from_json(report)?),
        (None, Some(err)) => Err(str_of(err, "error")?.to_string()),
        _ => return Err("cell result must have exactly one of report/error".into()),
    };
    Ok(CellResult {
        cell: cell_from_json(field(o, "cell")?)?,
        outcome,
        series: opt_json(field(o, "series")?).map(series_from_json).transpose()?,
    })
}

/// Serialize a slice of cell results (one worker's shard output) -
/// exposed for the round-trip property in `tests/properties.rs`.
pub fn results_to_json(results: &[CellResult]) -> Json {
    Json::Arr(results.iter().map(cell_result_to_json).collect())
}

/// Inverse of [`results_to_json`].
pub fn results_from_json(v: &Json) -> Result<Vec<CellResult>, String> {
    v.as_arr()
        .ok_or_else(|| "cell results: expected an array".to_string())?
        .iter()
        .map(cell_result_from_json)
        .collect()
}

// ---------------------------------------------------------------------
// Shard and partial files
// ---------------------------------------------------------------------

fn check_header(o: &JsonObj, format: &str) -> Result<(), String> {
    let got = str_field(o, "format")?;
    if got != format {
        return Err(format!("wrong file format '{got}' (expected '{format}')"));
    }
    let version = usize_field(o, "version")? as u64;
    if version != WIRE_VERSION {
        return Err(format!("unsupported wire version {version} (expected {WIRE_VERSION})"));
    }
    Ok(())
}

/// Serialize one shard job: header, spec digest, full spec, cell ids.
pub fn shard_file_json(spec: &SweepSpec, shard: &Shard) -> Json {
    let mut s = JsonObj::new();
    s.set("index", enc_usize(shard.index));
    s.set("of", enc_usize(shard.of));
    s.set(
        "cell_ids",
        Json::Arr(shard.cell_ids.iter().map(|&id| enc_usize(id)).collect()),
    );
    let mut o = JsonObj::new();
    o.set("format", Json::Str(SHARD_FORMAT.to_string()));
    o.set("version", enc_usize(WIRE_VERSION as usize));
    o.set("spec_digest", Json::Str(spec_digest(spec)));
    o.set("shard", Json::Obj(s));
    o.set("spec", spec_to_json(spec));
    Json::Obj(o)
}

/// Write one shard job file (pretty JSON - shard files are the unit
/// cluster operators copy around and occasionally read).
pub fn write_shard_file(path: &Path, spec: &SweepSpec, shard: &Shard) -> Result<(), String> {
    std::fs::write(path, shard_file_json(spec, shard).to_string_pretty())
        .map_err(|e| format!("writing shard file {}: {e}", path.display()))
}

/// Read a shard job file back; validates the header, the embedded digest
/// (against the embedded spec) and the cell ids (in range, strictly
/// ascending).
pub fn read_shard_file(path: &Path) -> Result<(SweepSpec, Shard), String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("reading shard file {}: {e}", path.display()))?;
    let doc = parse(&text).map_err(|e| format!("shard file {}: {e}", path.display()))?;
    let ctx = |e: String| format!("shard file {}: {e}", path.display());
    let o = as_obj(&doc, "shard file").map_err(ctx)?;
    check_header(o, SHARD_FORMAT).map_err(ctx)?;
    let spec = spec_from_json(field(o, "spec").map_err(ctx)?).map_err(ctx)?;
    let stored = str_field(o, "spec_digest").map_err(ctx)?;
    if stored != spec_digest(&spec) {
        return Err(ctx("spec_digest does not match the embedded spec (edited or corrupt)".into()));
    }
    let so = as_obj(field(o, "shard").map_err(ctx)?, "shard").map_err(ctx)?;
    let cell_ids = arr_field(so, "cell_ids")
        .map_err(ctx)?
        .iter()
        .map(|x| {
            let v = num_of(x, "cell id")?;
            if v < 0.0 || v.fract() != 0.0 {
                return Err("cell id is not a non-negative integer".to_string());
            }
            Ok(v as usize)
        })
        .collect::<Result<Vec<usize>, _>>()
        .map_err(ctx)?;
    let total = spec.cell_count();
    let mut weight = 0;
    let cells = spec.cells();
    for (i, &id) in cell_ids.iter().enumerate() {
        if id >= total {
            return Err(ctx(format!("cell id {id} out of range (grid has {total} cells)")));
        }
        if i > 0 && cell_ids[i - 1] >= id {
            return Err(ctx(format!("cell ids not strictly ascending at {id}")));
        }
        weight += cell_weight(&cells[id]);
    }
    let shard = Shard {
        index: usize_field(so, "index").map_err(ctx)?,
        of: usize_field(so, "of").map_err(ctx)?,
        cell_ids,
        weight,
    };
    Ok((spec, shard))
}

/// A parsed partial artifact: one worker's shard output plus everything
/// needed to validate and merge it stand-alone.
#[derive(Debug)]
pub struct Partial {
    pub spec: SweepSpec,
    pub spec_digest: String,
    pub shard_index: usize,
    pub cells: Vec<CellResult>,
}

/// Serialize one worker's shard output as a self-contained partial.
pub fn partial_file_json(spec: &SweepSpec, shard_index: usize, results: &[CellResult]) -> Json {
    let mut o = JsonObj::new();
    o.set("format", Json::Str(PARTIAL_FORMAT.to_string()));
    o.set("version", enc_usize(WIRE_VERSION as usize));
    o.set("spec_digest", Json::Str(spec_digest(spec)));
    o.set("shard_index", enc_usize(shard_index));
    o.set("spec", spec_to_json(spec));
    o.set("cells", results_to_json(results));
    Json::Obj(o)
}

/// Write a partial artifact **atomically** (tmp + rename), so a worker
/// killed mid-write leaves a `.tmp` file, never a truncated partial the
/// coordinator could half-read.
pub fn write_partial(
    path: &Path,
    spec: &SweepSpec,
    shard_index: usize,
    results: &[CellResult],
) -> Result<(), String> {
    let file_name = path
        .file_name()
        .ok_or_else(|| format!("partial path {} has no file name", path.display()))?;
    let tmp = path.with_file_name(format!("{}.tmp", file_name.to_string_lossy()));
    let text = partial_file_json(spec, shard_index, results).to_string_compact();
    std::fs::write(&tmp, text).map_err(|e| format!("writing {}: {e}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .map_err(|e| format!("renaming {} into place: {e}", tmp.display()))
}

/// Read a partial artifact back; validates header and embedded digest.
pub fn read_partial(path: &Path) -> Result<Partial, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("reading partial {}: {e}", path.display()))?;
    let doc = parse(&text).map_err(|e| format!("partial {}: {e}", path.display()))?;
    let ctx = |e: String| format!("partial {}: {e}", path.display());
    let o = as_obj(&doc, "partial").map_err(ctx)?;
    check_header(o, PARTIAL_FORMAT).map_err(ctx)?;
    let spec = spec_from_json(field(o, "spec").map_err(ctx)?).map_err(ctx)?;
    let digest = str_field(o, "spec_digest").map_err(ctx)?.to_string();
    if digest != spec_digest(&spec) {
        return Err(ctx("spec_digest does not match the embedded spec (edited or corrupt)".into()));
    }
    let cells = results_from_json(field(o, "cells").map_err(ctx)?).map_err(ctx)?;
    Ok(Partial {
        spec,
        spec_digest: digest,
        shard_index: usize_field(o, "shard_index").map_err(ctx)?,
        cells,
    })
}

/// Merge partial artifacts into the full sweep report. Rejects partials
/// from different specs, out-of-range or unknown cells, overlapping cell
/// ids and incomplete coverage, so the merged artifacts either equal the
/// single-process run's bytes or the merge fails - never something in
/// between.
pub fn merge_partials(partials: Vec<Partial>) -> Result<(SweepSpec, SweepReport), String> {
    let Some(first) = partials.first() else {
        return Err("no partial artifacts to merge".into());
    };
    let digest = first.spec_digest.clone();
    let spec = first.spec.clone();
    for p in &partials {
        if p.spec_digest != digest {
            return Err(format!(
                "partial for shard {} comes from a different sweep spec \
                 (digest {} != {digest})",
                p.shard_index, p.spec_digest
            ));
        }
    }
    let expected = spec.cells();
    let mut all: Vec<CellResult> = Vec::with_capacity(expected.len());
    for p in partials {
        all.extend(p.cells);
    }
    for r in &all {
        let Some(want) = expected.get(r.cell.id) else {
            return Err(format!(
                "cell id {} out of range (grid has {} cells)",
                r.cell.id,
                expected.len()
            ));
        };
        if *want != r.cell {
            return Err(format!(
                "cell {} in the partials does not match the spec's enumeration \
                 (corrupt partial?)",
                r.cell.id
            ));
        }
    }
    if all.len() < expected.len() {
        return Err(format!(
            "partials cover {} of {} cells - a shard's output is missing",
            all.len(),
            expected.len()
        ));
    }
    // Observability-only field; process count is as good a stand-in for
    // "parallelism used" as any, and it never serializes.
    let report = SweepReport::merged_from_cells(all, 1)?;
    Ok((spec, report))
}

// ---------------------------------------------------------------------
// Same-host coordinator
// ---------------------------------------------------------------------

fn shard_path(dir: &Path, index: usize) -> PathBuf {
    dir.join(format!("sweep_shard{index:04}.json"))
}

fn partial_path(dir: &Path, index: usize) -> PathBuf {
    dir.join(format!("sweep_partial{index:04}.json"))
}

/// Kill and reap every still-running worker. Dropping a `Child` does
/// NOT kill it, so every error return out of [`coordinate`] must come
/// through here - an orphaned worker would keep burning CPU for the rest
/// of its (possibly hours-long) shard and could rename its partial into
/// the work dir mid-way through a *subsequent* coordinator run,
/// corrupting that run's view of its own partials. (Coordinator death by
/// signal is covered separately: workers poll `CLOUDMARKET_SWEEP_PARENT`
/// liveness between cells and exit on their own.)
fn kill_workers(running: &mut Vec<(usize, std::process::Child)>) {
    for (_, child) in running.iter_mut() {
        let _ = child.kill();
    }
    for (_, mut child) in running.drain(..) {
        let _ = child.wait();
    }
}

/// Remove shard/partial files (and their `.tmp` leftovers) from `dir`,
/// returning how many were deleted. The coordinator calls this before a
/// run - a re-run after an aborted coordinator must never mix old and
/// new partials - and after a successful merge to leave only the merged
/// artifacts behind.
pub fn clean_work_files(dir: &Path) -> Result<usize, String> {
    let Ok(entries) = std::fs::read_dir(dir) else { return Ok(0) };
    let mut removed = 0;
    for entry in entries.flatten() {
        let name = entry.file_name();
        let name = name.to_string_lossy();
        let is_work = (name.starts_with("sweep_shard") || name.starts_with("sweep_partial"))
            && (name.ends_with(".json") || name.ends_with(".json.tmp"));
        if is_work {
            std::fs::remove_file(entry.path())
                .map_err(|e| format!("removing stale {}: {e}", entry.path().display()))?;
            removed += 1;
        }
    }
    Ok(removed)
}

/// Options for [`coordinate`].
#[derive(Debug, Clone)]
pub struct CoordinateOptions {
    /// Worker subprocesses to run concurrently (also the shard count).
    pub workers: usize,
    /// Directory for shard/partial files (cleaned of stale ones first).
    pub work_dir: PathBuf,
    /// The `cloudmarket` binary to spawn workers from. The CLI passes
    /// `std::env::current_exe()`; tests pass `CARGO_BIN_EXE_cloudmarket`.
    pub worker_exe: PathBuf,
    /// In-process threads per worker (default 1: the process pool is the
    /// parallelism; nested thread pools only fight over cores).
    pub worker_threads: usize,
    /// Spawn attempts per shard before the whole sweep fails (>= 1).
    pub max_attempts: usize,
    /// Emit progress lines on stderr.
    pub verbose: bool,
    /// Sidecar sink for shard lifecycle events (assign/exit/reassign,
    /// stalls, merge). `None` disables telemetry; results are identical
    /// either way (the two-channel rule).
    pub telemetry: Option<Arc<Telemetry>>,
    /// Directory for per-shard worker heartbeat files (normally the
    /// telemetry dir). When set, workers are spawned with `--heartbeat`
    /// and the coordinator polls the files for stall detection and
    /// reassignment enrichment.
    pub heartbeat_dir: Option<PathBuf>,
    /// A live worker silent for this long earns a stall warning (one per
    /// silence episode). Only meaningful with `heartbeat_dir`.
    pub stall_after: Duration,
}

impl CoordinateOptions {
    pub fn new(
        workers: usize,
        work_dir: impl Into<PathBuf>,
        worker_exe: impl Into<PathBuf>,
    ) -> Self {
        CoordinateOptions {
            workers,
            work_dir: work_dir.into(),
            worker_exe: worker_exe.into(),
            worker_threads: 1,
            max_attempts: 3,
            verbose: false,
            telemetry: None,
            heartbeat_dir: None,
            stall_after: Duration::from_secs(30),
        }
    }
}

/// What a coordinated run did (the report plus fan-out observability;
/// none of this is serialized).
#[derive(Debug)]
pub struct CoordinateOutcome {
    pub report: SweepReport,
    /// Shards the grid was partitioned into.
    pub shards: usize,
    /// Worker subprocesses spawned in total (>= shards; each retry adds
    /// one).
    pub workers_spawned: usize,
    /// Shards that were reassigned to a fresh worker after a
    /// crash/kill/corrupt output.
    pub shards_reassigned: usize,
}

/// Run `spec` as worker subprocesses: partition, spawn, monitor, reassign
/// shards from dead workers, merge. The merged report serializes
/// byte-identically to the in-process [`super::run`] output.
///
/// Workers inherit this process's environment, so the (test-only)
/// `CLOUDMARKET_SWEEP_FAULT` fault-injection hook of `sweep worker`
/// reaches them - `tests/sweep_process.rs` uses that to kill a worker
/// mid-shard and pin the reassignment path.
pub fn coordinate(
    spec: &SweepSpec,
    opts: &CoordinateOptions,
) -> Result<CoordinateOutcome, String> {
    if opts.workers == 0 || opts.max_attempts == 0 {
        return Err("coordinate: workers and max_attempts must be >= 1".into());
    }
    std::fs::create_dir_all(&opts.work_dir)
        .map_err(|e| format!("creating {}: {e}", opts.work_dir.display()))?;
    let stale = clean_work_files(&opts.work_dir)?;
    if stale > 0 && opts.verbose {
        eprintln!("sweep: removed {stale} stale shard/partial file(s) from an earlier run");
    }

    let digest = spec_digest(spec);
    let shards = partition(spec, opts.workers);
    let n = shards.len();
    for shard in &shards {
        write_shard_file(&shard_path(&opts.work_dir, shard.index), spec, shard)?;
    }
    // Stale heartbeat files from an earlier run must not feed this run's
    // stall tracker before the fresh workers truncate them.
    if let Some(dir) = &opts.heartbeat_dir {
        for i in 0..n {
            let _ = std::fs::remove_file(heartbeat_file(dir, i));
        }
    }
    let emit = |event: JsonObj| {
        if let Some(t) = &opts.telemetry {
            t.emit(event);
        }
    };
    let mut stalls = StallTracker::new(opts.stall_after);
    let mut last_hb_poll = Instant::now();

    let mut pending: VecDeque<usize> = (0..n).collect();
    let mut running: Vec<(usize, std::process::Child)> = Vec::new();
    let mut attempts = vec![0usize; n];
    let mut results: Vec<Option<Vec<CellResult>>> = Vec::with_capacity(n);
    results.resize_with(n, || None);
    let mut workers_spawned = 0;
    let mut shards_reassigned = 0;

    while results.iter().any(Option::is_none) {
        // Fill free worker slots from the pending queue.
        while running.len() < opts.workers {
            let Some(idx) = pending.pop_front() else { break };
            attempts[idx] += 1;
            let mut cmd = Command::new(&opts.worker_exe);
            cmd.arg("sweep")
                .arg("worker")
                .arg("--shard")
                .arg(shard_path(&opts.work_dir, idx))
                .arg("--out")
                .arg(partial_path(&opts.work_dir, idx))
                .arg("--threads")
                .arg(opts.worker_threads.to_string());
            if let Some(dir) = &opts.heartbeat_dir {
                cmd.arg("--heartbeat").arg(heartbeat_file(dir, idx));
            }
            // Workers watch this PID between cells and exit when the
            // coordinator is gone (see `cmd_sweep_worker`), so a
            // Ctrl-C'd or SIGKILLed coordinator - paths no userspace
            // cleanup can cover - does not leave orphans running
            // their full shards and renaming partials into a later
            // run's work dir.
            cmd.env("CLOUDMARKET_SWEEP_PARENT", std::process::id().to_string())
                .stdout(Stdio::null());
            let child = match cmd.spawn() {
                Ok(child) => child,
                Err(e) => {
                    kill_workers(&mut running);
                    return Err(format!(
                        "spawning sweep worker ({}): {e}",
                        opts.worker_exe.display()
                    ));
                }
            };
            workers_spawned += 1;
            stalls.watch(idx, Instant::now());
            emit(tel::shard_assign(idx, attempts[idx], child.id()));
            if opts.verbose {
                eprintln!(
                    "sweep: worker pid {} took shard {idx}/{n} ({} cells, attempt {})",
                    child.id(),
                    shards[idx].cell_ids.len(),
                    attempts[idx]
                );
            }
            running.push((idx, child));
        }
        if running.is_empty() {
            return Err("sweep coordinator stalled with unfinished shards (internal bug)".into());
        }

        // Poll heartbeats (throttled: the reap loop spins at 5ms) for
        // workers that are alive but silent - a crash is detected by
        // try_wait, but a *hang* only shows up as heartbeat staleness.
        if let Some(dir) = &opts.heartbeat_dir {
            let now = Instant::now();
            if now.duration_since(last_hb_poll) >= Duration::from_millis(500) {
                last_hb_poll = now;
                for (idx, _) in &running {
                    let beat = read_last_heartbeat(&heartbeat_file(dir, *idx));
                    if let Some(w) = stalls.observe(*idx, beat, now) {
                        let progress = w
                            .last
                            .map(|h| format!(", last progress {}/{} cells", h.done, h.total))
                            .unwrap_or_else(|| ", no heartbeat seen yet".to_string());
                        eprintln!(
                            "sweep: warning: shard {} worker is alive but silent for \
                             {:.0}s{progress}",
                            w.shard,
                            w.silent.as_secs_f64()
                        );
                        emit(tel::stall(w.shard, w.silent.as_millis() as u64, w.last.as_ref()));
                    }
                }
            }
        }

        // Reap finished workers; a dead worker's shard goes back in the
        // queue (bounded by max_attempts) for the next free slot.
        let mut i = 0;
        while i < running.len() {
            let (idx, child) = &mut running[i];
            let idx = *idx;
            let waited = match child.try_wait() {
                Ok(waited) => waited,
                Err(e) => {
                    kill_workers(&mut running);
                    return Err(format!("waiting for sweep worker on shard {idx}: {e}"));
                }
            };
            match waited {
                None => i += 1,
                Some(status) => {
                    running.swap_remove(i);
                    let partial = partial_path(&opts.work_dir, idx);
                    let outcome = if status.success() {
                        read_partial(&partial).and_then(|p| {
                            if p.spec_digest != digest {
                                Err(format!(
                                    "partial for shard {idx} was produced by a different spec"
                                ))
                            } else if p.shard_index != idx {
                                Err(format!(
                                    "partial for shard {idx} reports shard index {}",
                                    p.shard_index
                                ))
                            } else {
                                Ok(p.cells)
                            }
                        })
                    } else {
                        Err(format!("worker exited with {status}"))
                    };
                    let detail = match status.code() {
                        Some(0) if outcome.is_ok() => "completed",
                        Some(0) => "bad-partial",
                        Some(EXIT_RUNTIME) => "runtime",
                        Some(EXIT_PARENT_GONE) => "parent-gone",
                        Some(EXIT_BAD_SHARD) => "bad-shard",
                        Some(_) => "unknown",
                        None => "signal",
                    };
                    emit(tel::shard_exit(idx, outcome.is_ok(), status.code(), detail));
                    match outcome {
                        Ok(cells) => {
                            stalls.unwatch(idx);
                            if opts.verbose {
                                eprintln!("sweep: shard {idx}/{n} done ({} cells)", cells.len());
                            }
                            results[idx] = Some(cells);
                        }
                        Err(why) => {
                            let _ = std::fs::remove_file(&partial);
                            if status.code() == Some(EXIT_BAD_SHARD) {
                                // The job file itself is corrupt/foreign;
                                // a fresh worker would read the same bytes
                                // and die the same way. Permanent.
                                kill_workers(&mut running);
                                return Err(format!(
                                    "shard {idx} job file rejected by worker (exit \
                                     {EXIT_BAD_SHARD}: corrupt or foreign shard); permanent \
                                     failure, not reassigning ({why})"
                                ));
                            }
                            if attempts[idx] >= opts.max_attempts {
                                kill_workers(&mut running);
                                return Err(format!(
                                    "shard {idx} failed {} time(s), giving up (last: {why})",
                                    attempts[idx]
                                ));
                            }
                            shards_reassigned += 1;
                            let last = stalls.last_progress(idx);
                            emit(tel::shard_reassign(idx, attempts[idx] + 1, last.as_ref()));
                            if opts.verbose {
                                let progress = last
                                    .map(|h| format!("; died at {}/{} cells", h.done, h.total))
                                    .unwrap_or_default();
                                eprintln!(
                                    "sweep: shard {idx}/{n} failed ({why}){progress}; \
                                     reassigning to a fresh worker (attempt {}/{})",
                                    attempts[idx] + 1,
                                    opts.max_attempts
                                );
                            }
                            pending.push_back(idx);
                        }
                    }
                }
            }
        }
        std::thread::sleep(Duration::from_millis(5));
    }

    let mut all: Vec<CellResult> = Vec::with_capacity(spec.cell_count());
    for cells in results.into_iter().flatten() {
        all.extend(cells);
    }
    let expected = spec.cells();
    let merged_cells = all.len();
    if merged_cells != expected.len() {
        emit(tel::merge(n, merged_cells, false));
        return Err(format!(
            "workers produced {merged_cells} of {} cells (coordinator bug)",
            expected.len()
        ));
    }
    let report = match SweepReport::merged_from_cells(all, n) {
        Ok(report) => {
            emit(tel::merge(n, merged_cells, true));
            report
        }
        Err(e) => {
            emit(tel::merge(n, merged_cells, false));
            return Err(e);
        }
    };
    // Success: the partials are merged, so drop the intermediates and
    // leave only the artifacts the caller writes from `report`.
    clean_work_files(&opts.work_dir)?;
    Ok(CoordinateOutcome { report, shards: n, workers_spawned, shards_reassigned })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mixed_spec() -> SweepSpec {
        let scenario =
            ComparisonConfig { seed: 99, terminate_at: 600.0, ..Default::default() };
        let mut spec = SweepSpec::new(scenario)
            .with_seeds(vec![1, 2, 18_000_000_000_000_000_001]) // > 2^53: string-encoded seeds
            .with_policies(vec![
                PolicySpec::FirstFit,
                PolicySpec::Hlem { adjusted: true, alpha: -0.5 },
            ])
            .with_axis(ScenarioAxis::HlemAlpha(vec![-0.3, -0.7]))
            .with_axis(ScenarioAxis::SpotWarning(vec![60.0, 120.0]))
            .with_axis(ScenarioAxis::Victim(vec![VictimPolicy::Youngest]))
            .with_axis(ScenarioAxis::SpotBehavior(vec![InterruptionBehavior::Terminate]))
            .with_axis(ScenarioAxis::Substrate(vec![
                Substrate::Comparison,
                Substrate::Trace,
            ]))
            .with_axis(ScenarioAxis::ChaosReclaimStorm(vec![
                ReclaimStorm::parse("at1200-frac0.5").unwrap(),
                ReclaimStorm::parse("at900-frac0.25-x2-every300.5").unwrap(),
            ]))
            .with_axis(ScenarioAxis::ChaosBrokerOutage(vec![BrokerOutage::parse(
                "at600-for120.25",
            )
            .unwrap()]))
            // Non-dyadic f64 axis values: exact only because JSON numbers
            // use shortest-round-trip Display.
            .with_axis(ScenarioAxis::MarketVolatility(vec![0.05, 0.2]))
            .with_axis(ScenarioAxis::MarketBidMargin(vec![0.1 + 0.7]))
            .with_axis(ScenarioAxis::RecoveryMode(vec![RecoveryMode::MigrateOptimal]))
            .with_axis(ScenarioAxis::RecoveryBandwidth(vec![0.1 + 0.2]))
            .with_axis(ScenarioAxis::RecoveryCheckpointThreshold(vec![0.25]))
            .with_series_retention(SeriesFilter::parse("policy=first-fit,seed=2").unwrap())
            .with_cell(77, PolicySpec::BestFit);
        spec.trace.synth.machines = 10;
        spec.trace.workload.spot_durations = vec![300.0, 600.5];
        spec
    }

    #[test]
    fn spec_round_trips_through_json_text() {
        let spec = mixed_spec();
        let text = spec_to_json(&spec).to_string_pretty();
        let back = spec_from_json(&parse(&text).unwrap()).unwrap();
        assert_eq!(back, spec);
        assert_eq!(spec_digest(&back), spec_digest(&spec));
        assert_eq!(back.cells(), spec.cells(), "decoded spec enumerates the same grid");
    }

    #[test]
    fn digest_changes_with_the_spec() {
        let a = mixed_spec();
        let mut b = mixed_spec();
        b.scenario.terminate_at += 1.0;
        assert_ne!(spec_digest(&a), spec_digest(&b));
    }

    #[test]
    fn partition_covers_cells_disjointly_and_balances_weight() {
        let spec = mixed_spec();
        let total = spec.cell_count();
        for shards in [1, 2, 3, 7, 100] {
            let parts = partition(&spec, shards);
            assert_eq!(parts.len(), shards.min(total));
            let mut seen = vec![false; total];
            for (i, p) in parts.iter().enumerate() {
                assert_eq!(p.index, i);
                assert_eq!(p.of, parts.len());
                assert!(!p.cell_ids.is_empty(), "clamped partitions have no empty shard");
                for w in p.cell_ids.windows(2) {
                    assert!(w[0] < w[1], "ids ascending");
                }
                for &id in &p.cell_ids {
                    assert!(!seen[id], "cell {id} in two shards");
                    seen[id] = true;
                }
            }
            assert!(seen.iter().all(|&s| s), "every cell is in some shard");
            let max = parts.iter().map(|p| p.weight).max().unwrap();
            let min = parts.iter().map(|p| p.weight).min().unwrap();
            assert!(
                max <= min + TRACE_CELL_WEIGHT,
                "LPT balance bound violated: max {max} min {min}"
            );
        }
        // Determinism.
        assert_eq!(partition(&spec, 3), partition(&spec, 3));
    }

    #[test]
    fn trace_cells_spread_across_shards() {
        // 2 seeds x (comparison, trace): without weighting, id-contiguous
        // chunking would put both trace cells in one shard.
        let mut spec = SweepSpec::new(ComparisonConfig::default())
            .with_seeds(vec![1, 2])
            .with_policies(vec![PolicySpec::FirstFit])
            .with_axis(ScenarioAxis::Substrate(vec![Substrate::Comparison, Substrate::Trace]));
        spec.trace.synth.machines = 10;
        let cells = spec.cells();
        let parts = partition(&spec, 2);
        for p in &parts {
            let trace_cells = p
                .cell_ids
                .iter()
                .filter(|&&id| cells[id].spec.substrate == Substrate::Trace)
                .count();
            assert_eq!(trace_cells, 1, "each shard gets one expensive trace cell: {parts:?}");
        }
    }

    fn fake_result(cell: Cell, ok: bool) -> CellResult {
        let outcome = if ok {
            Ok(Report {
                policy: "first-fit",
                clock_end: 600.125,
                events_processed: u64::MAX - 3, // string-encoded: survives > 2^53
                wall: Duration::from_millis(7), // must NOT survive the wire
                finished: 10,
                terminated: 2,
                failed: 0,
                still_active: 1,
                cloudlets_finished: 9,
                cloudlets_canceled: 1,
                alloc_attempts: 15,
                alloc_failures: 3,
                spot: SpotStats {
                    total_spot: 5,
                    interruptions: 4,
                    interrupted_vms: 3,
                    uninterrupted_completions: 2,
                    redeployments: 1,
                    completed_after_interruption: 1,
                    terminated: 1,
                    max_interruptions_per_vm: 2,
                    avg_interruption_secs: 0.1 + 0.2, // 0.30000000000000004
                    max_interruption_secs: 1e-300,
                    min_interruption_secs: 0.0,
                },
                resilience: ResilienceStats {
                    storms: 2,
                    storm_reclaims: 6,
                    host_failures: 1,
                    recoveries: 1,
                    interruptions_per_storm: 3.0,
                    p95_interruption_secs: 0.2 + 0.4, // 0.6000000000000001
                    avg_recovery_secs: 12.5,
                    max_recovery_secs: 30.25,
                    work_lost_mi: 1234.5,
                    work_recovered_mi: 987.0,
                },
                market: MarketStats {
                    spot_cost_usd: 0.1 + 0.2, // 0.30000000000000004
                    on_demand_cost_usd: 1.25,
                    savings_ratio: 1.0 - (0.1 + 0.2) / 1.25,
                    price_reclaims: u64::MAX - 9, // string-encoded: > 2^53
                    mean_price_paid: 0.4125,
                    max_price_paid: 1e-300,
                },
                recovery: RecoveryStats {
                    checkpoints: u64::MAX - 17, // string-encoded: > 2^53
                    checkpoint_mb: 0.1 + 0.2,   // 0.30000000000000004
                    migrations: 3,
                    failed_migrations: 1,
                    work_recovered_mi: 987.5,
                    work_lost_mi: 1e-300,
                    recovered_fraction: 987.5 / (987.5 + 1e-300),
                    requeue_p50_s: 0.2 + 0.4, // 0.6000000000000001
                    requeue_p95_s: 12.25,
                    requeue_max_s: 30.125,
                },
            })
        } else {
            Err("cell exploded".to_string())
        };
        let series = ok.then(|| {
            let mut s = TimeSeries::new(&["spot_running", "od_running"]);
            s.push(0.0, &[1.0, 0.1 + 0.7]);
            s.push(10.5, &[2.0, f64::MIN_POSITIVE]);
            s
        });
        CellResult { cell, outcome, series }
    }

    /// Cell results round-trip bit-exactly (encode . decode . encode is
    /// the identity on the wire text), wall time excluded by design.
    #[test]
    fn results_round_trip_bit_exact() {
        let spec = mixed_spec();
        let cells = spec.cells();
        let results = vec![fake_result(cells[0], true), fake_result(cells[1], false)];
        let text = results_to_json(&results).to_string_compact();
        let back = results_from_json(&parse(&text).unwrap()).unwrap();
        assert_eq!(results_to_json(&back).to_string_compact(), text);
        let r0 = back[0].report().unwrap();
        let want = results[0].report().unwrap();
        assert_eq!(r0.events_processed, want.events_processed);
        assert_eq!(
            r0.spot.avg_interruption_secs.to_bits(),
            want.spot.avg_interruption_secs.to_bits()
        );
        assert_eq!(
            r0.spot.max_interruption_secs.to_bits(),
            want.spot.max_interruption_secs.to_bits()
        );
        assert_eq!(r0.resilience.storm_reclaims, want.resilience.storm_reclaims);
        assert_eq!(
            r0.resilience.p95_interruption_secs.to_bits(),
            want.resilience.p95_interruption_secs.to_bits()
        );
        assert_eq!(
            r0.market.spot_cost_usd.to_bits(),
            want.market.spot_cost_usd.to_bits()
        );
        assert_eq!(
            r0.market.savings_ratio.to_bits(),
            want.market.savings_ratio.to_bits()
        );
        assert_eq!(
            r0.market.max_price_paid.to_bits(),
            want.market.max_price_paid.to_bits()
        );
        assert_eq!(r0.market.price_reclaims, want.market.price_reclaims);
        assert_eq!(r0.recovery.checkpoints, want.recovery.checkpoints);
        assert_eq!(
            r0.recovery.checkpoint_mb.to_bits(),
            want.recovery.checkpoint_mb.to_bits()
        );
        assert_eq!(
            r0.recovery.work_lost_mi.to_bits(),
            want.recovery.work_lost_mi.to_bits()
        );
        assert_eq!(
            r0.recovery.requeue_p50_s.to_bits(),
            want.recovery.requeue_p50_s.to_bits()
        );
        assert_eq!(r0.wall, Duration::ZERO, "wall time must not cross the wire");
        let s0 = back[0].series.as_ref().unwrap();
        let s_want = results[0].series.as_ref().unwrap();
        assert_eq!(s0.columns(), s_want.columns());
        assert_eq!(s0.times(), s_want.times());
        assert_eq!(s0.column("od_running"), s_want.column("od_running"));
        assert_eq!(back[1].outcome.as_ref().unwrap_err(), "cell exploded");
    }

    fn test_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("cloudmarket_shard_{}_{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn shard_files_round_trip_and_validate() {
        let dir = test_dir("roundtrip");
        let spec = mixed_spec();
        let shards = partition(&spec, 3);
        for shard in &shards {
            let path = dir.join(format!("sweep_shard{:04}.json", shard.index));
            write_shard_file(&path, &spec, shard).unwrap();
            let (back_spec, back_shard) = read_shard_file(&path).unwrap();
            assert_eq!(back_spec, spec);
            assert_eq!(&back_shard, shard, "incl. the recomputed weight");
        }
        // Corrupt / wrong-format files fail loudly.
        let bad = dir.join("bad.json");
        std::fs::write(&bad, "{ not json").unwrap();
        assert!(read_shard_file(&bad).is_err());
        std::fs::write(&bad, "{\"format\":\"something-else\",\"version\":1}").unwrap();
        let err = read_shard_file(&bad).unwrap_err();
        assert!(err.contains("wrong file format"), "{err}");
        let missing = dir.join("nope.json");
        let err = read_shard_file(&missing).unwrap_err();
        assert!(err.contains("reading shard file"), "{err}");
        // An edited spec no longer matches the stored digest.
        let path = dir.join("sweep_shard0000.json");
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, text.replace("\"terminate_at\": 600", "\"terminate_at\": 601"))
            .unwrap();
        let err = read_shard_file(&path).unwrap_err();
        assert!(err.contains("spec_digest"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn partials_merge_back_to_the_full_report() {
        let dir = test_dir("merge");
        let spec = mixed_spec();
        let cells = spec.cells();
        let shards = partition(&spec, 2);
        for shard in &shards {
            let results: Vec<CellResult> =
                shard.cell_ids.iter().map(|&id| fake_result(cells[id], id % 5 != 0)).collect();
            write_partial(
                &dir.join(format!("sweep_partial{:04}.json", shard.index)),
                &spec,
                shard.index,
                &results,
            )
            .unwrap();
        }
        let partials: Vec<Partial> = (0..2)
            .map(|i| read_partial(&dir.join(format!("sweep_partial{i:04}.json"))).unwrap())
            .collect();
        let (merged_spec, report) = merge_partials(partials).unwrap();
        assert_eq!(merged_spec, spec);
        assert_eq!(report.total(), spec.cell_count());
        for (i, c) in report.cells.iter().enumerate() {
            assert_eq!(c.cell.id, i);
            assert_eq!(c.cell, cells[i]);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn merge_rejects_overlap_missing_and_foreign_partials() {
        let spec = mixed_spec();
        let shards = partition(&spec, 2);
        let partial_for = |spec: &SweepSpec, shard: &Shard| Partial {
            spec: spec.clone(),
            spec_digest: spec_digest(spec),
            shard_index: shard.index,
            cells: shard.cell_ids.iter().map(|&id| fake_result(spec.cells()[id], true)).collect(),
        };

        assert!(merge_partials(Vec::new()).is_err());

        // Same shard twice: overlap.
        let err = merge_partials(vec![
            partial_for(&spec, &shards[0]),
            partial_for(&spec, &shards[0]),
            partial_for(&spec, &shards[1]),
        ])
        .unwrap_err();
        assert!(err.contains("overlapping cell id"), "{err}");

        // One shard missing.
        let err = merge_partials(vec![partial_for(&spec, &shards[0])]).unwrap_err();
        assert!(err.contains("missing"), "{err}");

        // Foreign spec: digest mismatch.
        let mut other = mixed_spec();
        other.scenario.terminate_at += 1.0;
        let other_shards = partition(&other, 2);
        let err = merge_partials(vec![
            partial_for(&spec, &shards[0]),
            partial_for(&other, &other_shards[1]),
        ])
        .unwrap_err();
        assert!(err.contains("different sweep spec"), "{err}");

        // A partial whose cell disagrees with the enumeration.
        let mut corrupt = partial_for(&spec, &shards[0]);
        corrupt.cells[0].cell.seed = corrupt.cells[0].cell.seed.wrapping_add(1);
        let err =
            merge_partials(vec![corrupt, partial_for(&spec, &shards[1])]).unwrap_err();
        assert!(err.contains("does not match the spec's enumeration"), "{err}");
    }

    #[test]
    fn clean_work_files_removes_only_work_files() {
        let dir = test_dir("clean");
        for name in [
            "sweep_shard0000.json",
            "sweep_partial0001.json",
            "sweep_partial0001.json.tmp",
            "sweep_cells.csv",
            "sweep_aggregate.json",
            "sweep_series_cell0001.csv",
        ] {
            std::fs::write(dir.join(name), "x").unwrap();
        }
        assert_eq!(clean_work_files(&dir).unwrap(), 3);
        assert!(dir.join("sweep_cells.csv").exists());
        assert!(dir.join("sweep_aggregate.json").exists());
        assert!(dir.join("sweep_series_cell0001.csv").exists());
        assert!(!dir.join("sweep_shard0000.json").exists());
        assert_eq!(clean_work_files(&dir).unwrap(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
