//! Sweep grids: declarative cell enumeration over scenario × seed ×
//! policy, with cartesian-product and explicit-list construction.
//!
//! A [`SweepSpec`] is plain data (`Clone + Send + Sync`), so the driver
//! can share one spec across its worker threads; policies are described by
//! [`PolicySpec`] values and only instantiated (as `Box<dyn
//! AllocationPolicy>`) inside the worker that runs the cell.

use crate::allocation::{
    AllocationPolicy, BestFit, FirstFit, HlemConfig, HlemVmp, RoundRobin, WorstFit,
};
use crate::config::scenario::{comparison_engine_config, ComparisonConfig};
use crate::engine::EngineConfig;

/// A policy described as data: buildable on any thread, comparable, and
/// cheap to store per cell.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PolicySpec {
    FirstFit,
    BestFit,
    WorstFit,
    RoundRobin,
    /// HLEM-VMP; `adjusted` selects the spot-load-adjusted score (Eqs.
    /// 10-11) and `alpha` is its spot-load factor (ignored when plain).
    Hlem { adjusted: bool, alpha: f64 },
}

impl PolicySpec {
    /// The three policies of the paper's §VII-E comparison (default
    /// adjusted-HLEM alpha, -0.5).
    pub fn paper() -> Vec<PolicySpec> {
        Self::paper_with_alpha(-0.5)
    }

    /// [`PolicySpec::paper`] with an explicit adjusted-HLEM alpha
    /// (`--alpha` applies to the default policy list too).
    pub fn paper_with_alpha(alpha: f64) -> Vec<PolicySpec> {
        vec![
            PolicySpec::FirstFit,
            PolicySpec::Hlem { adjusted: false, alpha: 0.0 },
            PolicySpec::Hlem { adjusted: true, alpha },
        ]
    }

    /// Parse one policy name (the `name()` vocabulary of the policies);
    /// `alpha` applies to `hlem-vmp-adjusted`.
    pub fn parse(name: &str, alpha: f64) -> Result<PolicySpec, String> {
        match name.trim() {
            "first-fit" => Ok(PolicySpec::FirstFit),
            "best-fit" => Ok(PolicySpec::BestFit),
            "worst-fit" => Ok(PolicySpec::WorstFit),
            "round-robin" => Ok(PolicySpec::RoundRobin),
            "hlem-vmp" => Ok(PolicySpec::Hlem { adjusted: false, alpha: 0.0 }),
            "hlem-vmp-adjusted" => Ok(PolicySpec::Hlem { adjusted: true, alpha }),
            other => Err(format!(
                "unknown policy '{other}' (expected first-fit | best-fit | worst-fit | \
                 round-robin | hlem-vmp | hlem-vmp-adjusted)"
            )),
        }
    }

    /// Parse a comma-separated policy list (`--policies` flag syntax).
    pub fn parse_list(list: &str, alpha: f64) -> Result<Vec<PolicySpec>, String> {
        let specs: Vec<PolicySpec> = list
            .split(',')
            .filter(|s| !s.trim().is_empty())
            .map(|s| PolicySpec::parse(s, alpha))
            .collect::<Result<_, _>>()?;
        if specs.is_empty() {
            return Err("empty policy list".into());
        }
        Ok(specs)
    }

    /// The name the built policy reports (`AllocationPolicy::name`).
    pub fn name(&self) -> &'static str {
        match self {
            PolicySpec::FirstFit => "first-fit",
            PolicySpec::BestFit => "best-fit",
            PolicySpec::WorstFit => "worst-fit",
            PolicySpec::RoundRobin => "round-robin",
            PolicySpec::Hlem { adjusted: false, .. } => "hlem-vmp",
            PolicySpec::Hlem { adjusted: true, .. } => "hlem-vmp-adjusted",
        }
    }

    /// The HLEM alpha knob, when this spec has one.
    pub fn alpha(&self) -> Option<f64> {
        match self {
            PolicySpec::Hlem { adjusted: true, alpha } => Some(*alpha),
            _ => None,
        }
    }

    /// Instantiate the policy (called inside the worker that runs the cell).
    pub fn build(&self) -> Box<dyn AllocationPolicy> {
        match self {
            PolicySpec::FirstFit => Box::new(FirstFit::new()),
            PolicySpec::BestFit => Box::new(BestFit::new()),
            PolicySpec::WorstFit => Box::new(WorstFit::new()),
            PolicySpec::RoundRobin => Box::new(RoundRobin::new()),
            PolicySpec::Hlem { adjusted: false, .. } => Box::new(HlemVmp::plain()),
            PolicySpec::Hlem { adjusted: true, alpha } => {
                Box::new(HlemVmp::new(HlemConfig::adjusted().with_alpha(*alpha)))
            }
        }
    }
}

/// One unit of sweep work: a (scenario seed, policy) pair with a dense id
/// that fixes its position in the merged output.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Cell {
    pub id: usize,
    pub seed: u64,
    pub policy: PolicySpec,
}

/// Declarative description of a sweep: the §VII-E scenario template, the
/// engine knobs every cell runs under, and the grid axes.
///
/// Cells are the cartesian product `seeds × policies` (seed-major, the
/// order of the pre-sweep `run_multi` loop) plus any explicitly listed
/// extra cells.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    /// Scenario template; each cell overrides `seed`.
    pub scenario: ComparisonConfig,
    /// Engine configuration shared by all cells (defaults to the §VII-E
    /// comparison-experiment settings of `compare::run_policy`).
    pub engine: EngineConfig,
    pub seeds: Vec<u64>,
    pub policies: Vec<PolicySpec>,
    /// Extra cells appended after the cartesian grid.
    pub explicit: Vec<(u64, PolicySpec)>,
}

impl SweepSpec {
    pub fn new(scenario: ComparisonConfig) -> Self {
        SweepSpec {
            scenario,
            engine: comparison_engine_config(),
            seeds: Vec::new(),
            policies: Vec::new(),
            explicit: Vec::new(),
        }
    }

    /// Grid axis: seeds `base..base + count`.
    pub fn with_seed_range(mut self, base: u64, count: usize) -> Self {
        self.seeds = (0..count).map(|r| base + r as u64).collect();
        self
    }

    /// Grid axis: an explicit seed list.
    pub fn with_seeds(mut self, seeds: Vec<u64>) -> Self {
        self.seeds = seeds;
        self
    }

    /// Grid axis: the policy list.
    pub fn with_policies(mut self, policies: Vec<PolicySpec>) -> Self {
        self.policies = policies;
        self
    }

    /// Explicit-list construction: append one extra cell outside the grid.
    pub fn with_cell(mut self, seed: u64, policy: PolicySpec) -> Self {
        self.explicit.push((seed, policy));
        self
    }

    /// Number of cells the spec enumerates.
    pub fn cell_count(&self) -> usize {
        self.seeds.len() * self.policies.len() + self.explicit.len()
    }

    /// Enumerate the cells in their deterministic merge order: cartesian
    /// product seed-major, then the explicit extras, with dense ids.
    pub fn cells(&self) -> Vec<Cell> {
        let mut cells = Vec::with_capacity(self.cell_count());
        for &seed in &self.seeds {
            for &policy in &self.policies {
                cells.push(Cell { id: cells.len(), seed, policy });
            }
        }
        for &(seed, policy) in &self.explicit {
            cells.push(Cell { id: cells.len(), seed, policy });
        }
        cells
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cartesian_cells_are_seed_major_with_dense_ids() {
        let spec = SweepSpec::new(ComparisonConfig::default())
            .with_seed_range(10, 2)
            .with_policies(PolicySpec::paper());
        let cells = spec.cells();
        assert_eq!(cells.len(), 6);
        assert_eq!(spec.cell_count(), 6);
        for (i, c) in cells.iter().enumerate() {
            assert_eq!(c.id, i);
        }
        assert_eq!(cells[0].seed, 10);
        assert_eq!(cells[2].seed, 10);
        assert_eq!(cells[3].seed, 11);
        assert_eq!(cells[0].policy.name(), "first-fit");
        assert_eq!(cells[1].policy.name(), "hlem-vmp");
        assert_eq!(cells[2].policy.name(), "hlem-vmp-adjusted");
    }

    #[test]
    fn explicit_cells_append_after_grid() {
        let spec = SweepSpec::new(ComparisonConfig::default())
            .with_seeds(vec![1])
            .with_policies(vec![PolicySpec::FirstFit])
            .with_cell(99, PolicySpec::BestFit);
        let cells = spec.cells();
        assert_eq!(cells.len(), 2);
        assert_eq!(cells[1].seed, 99);
        assert_eq!(cells[1].policy, PolicySpec::BestFit);
    }

    #[test]
    fn policy_spec_parses_names_and_alpha() {
        assert_eq!(PolicySpec::parse("first-fit", -0.5).unwrap(), PolicySpec::FirstFit);
        assert_eq!(
            PolicySpec::parse("hlem-vmp-adjusted", -0.7).unwrap().alpha(),
            Some(-0.7)
        );
        assert_eq!(PolicySpec::parse("hlem-vmp", -0.7).unwrap().alpha(), None);
        assert!(PolicySpec::parse("nope", 0.0).is_err());
    }

    #[test]
    fn policy_list_parses_and_rejects_empty() {
        let specs = PolicySpec::parse_list("first-fit, hlem-vmp,hlem-vmp-adjusted", -0.5).unwrap();
        assert_eq!(specs.len(), 3);
        assert!(PolicySpec::parse_list("", -0.5).is_err());
        assert!(PolicySpec::parse_list("first-fit,bogus", -0.5).is_err());
    }

    #[test]
    fn built_policies_report_spec_names() {
        for spec in [
            PolicySpec::FirstFit,
            PolicySpec::BestFit,
            PolicySpec::WorstFit,
            PolicySpec::RoundRobin,
            PolicySpec::Hlem { adjusted: false, alpha: 0.0 },
            PolicySpec::Hlem { adjusted: true, alpha: -0.5 },
        ] {
            assert_eq!(spec.build().name(), spec.name());
        }
    }
}
