//! Sweep grids: declarative cell enumeration over scenario × seed ×
//! policy × axis values, with cartesian-product and explicit-list
//! construction.
//!
//! A [`SweepSpec`] is plain data (`Clone + Send + Sync`), so the driver
//! can share one spec across its worker threads; policies are described by
//! [`PolicySpec`] values and only instantiated (as `Box<dyn
//! AllocationPolicy>`) inside the worker that runs the cell.
//!
//! # Scenario axes
//!
//! Beyond the seeds × policies grid, a spec can carry [`ScenarioAxis`]
//! values that multiply the policy list into [`CellSpec`] *variants*:
//! spot lifecycle settings (warning time, hibernation timeout,
//! terminate-vs-hibernate behavior), adjusted-HLEM alpha ranges,
//! victim-policy ablations, and the workload [`Substrate`] itself
//! (the §VII-E comparison template or the §VII-D cluster-trace
//! simulation). Expansion is deterministic: variants are expanded axis by
//! axis in declaration order, with the last-declared axis varying fastest;
//! cells are then the cartesian product `seeds × variants` (seed-major)
//! plus any explicitly listed extra cells. With no axes declared the
//! variants are exactly the policy list, so axis-free sweeps enumerate the
//! same grid as before the axis layer existed (the
//! `compare::run_multi` bit-parity guarantee rests on this).
//!
//! See `docs/sweep-cookbook.md` for runnable recipes per axis.

use crate::allocation::{
    AllocationPolicy, BestFit, FirstFit, HlemConfig, HlemVmp, RoundRobin, WorstFit,
};
use crate::chaos::{BrokerOutage, ChaosSpec, DemandSurge, HostMtbf, ReclaimStorm};
use crate::config::scenario::{comparison_engine_config, ComparisonConfig};
use crate::engine::{EngineConfig, VictimPolicy};
use crate::market::MarketSpec;
use crate::recovery::{RecoveryMode, RecoverySpec};
use crate::trace::synth::SynthConfig;
use crate::trace::workload::WorkloadConfig;
use crate::vm::{InterruptionBehavior, SpotConfig};

/// A policy described as data: buildable on any thread, comparable, and
/// cheap to store per cell.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PolicySpec {
    FirstFit,
    BestFit,
    WorstFit,
    RoundRobin,
    /// HLEM-VMP; `adjusted` selects the spot-load-adjusted score (Eqs.
    /// 10-11) and `alpha` is its spot-load factor (ignored when plain).
    Hlem { adjusted: bool, alpha: f64 },
}

impl PolicySpec {
    /// The three policies of the paper's §VII-E comparison (default
    /// adjusted-HLEM alpha, -0.5).
    pub fn paper() -> Vec<PolicySpec> {
        Self::paper_with_alpha(-0.5)
    }

    /// [`PolicySpec::paper`] with an explicit adjusted-HLEM alpha
    /// (`--alpha` applies to the default policy list too).
    pub fn paper_with_alpha(alpha: f64) -> Vec<PolicySpec> {
        vec![
            PolicySpec::FirstFit,
            PolicySpec::Hlem { adjusted: false, alpha: 0.0 },
            PolicySpec::Hlem { adjusted: true, alpha },
        ]
    }

    /// Parse one policy name (the `name()` vocabulary of the policies);
    /// `alpha` applies to `hlem-vmp-adjusted`.
    pub fn parse(name: &str, alpha: f64) -> Result<PolicySpec, String> {
        match name.trim() {
            "first-fit" => Ok(PolicySpec::FirstFit),
            "best-fit" => Ok(PolicySpec::BestFit),
            "worst-fit" => Ok(PolicySpec::WorstFit),
            "round-robin" => Ok(PolicySpec::RoundRobin),
            "hlem-vmp" => Ok(PolicySpec::Hlem { adjusted: false, alpha: 0.0 }),
            "hlem-vmp-adjusted" => Ok(PolicySpec::Hlem { adjusted: true, alpha }),
            other => Err(format!(
                "unknown policy '{other}' (expected first-fit | best-fit | worst-fit | \
                 round-robin | hlem-vmp | hlem-vmp-adjusted)"
            )),
        }
    }

    /// Parse a comma-separated policy list (`--policies` flag syntax).
    pub fn parse_list(list: &str, alpha: f64) -> Result<Vec<PolicySpec>, String> {
        let specs: Vec<PolicySpec> = list
            .split(',')
            .filter(|s| !s.trim().is_empty())
            .map(|s| PolicySpec::parse(s, alpha))
            .collect::<Result<_, _>>()?;
        if specs.is_empty() {
            return Err("empty policy list".into());
        }
        Ok(specs)
    }

    /// The name the built policy reports (`AllocationPolicy::name`).
    pub fn name(&self) -> &'static str {
        match self {
            PolicySpec::FirstFit => "first-fit",
            PolicySpec::BestFit => "best-fit",
            PolicySpec::WorstFit => "worst-fit",
            PolicySpec::RoundRobin => "round-robin",
            PolicySpec::Hlem { adjusted: false, .. } => "hlem-vmp",
            PolicySpec::Hlem { adjusted: true, .. } => "hlem-vmp-adjusted",
        }
    }

    /// The HLEM alpha knob, when this spec has one.
    pub fn alpha(&self) -> Option<f64> {
        match self {
            PolicySpec::Hlem { adjusted: true, alpha } => Some(*alpha),
            _ => None,
        }
    }

    /// Whether an alpha axis applies to this spec (adjusted HLEM only; the
    /// other policies ignore alpha, so multiplying them by an alpha range
    /// would just duplicate identical runs).
    pub fn alpha_sensitive(&self) -> bool {
        matches!(self, PolicySpec::Hlem { adjusted: true, .. })
    }

    /// This spec with its alpha substituted (no-op for alpha-insensitive
    /// policies).
    pub fn with_alpha(self, alpha: f64) -> PolicySpec {
        match self {
            PolicySpec::Hlem { adjusted: true, .. } => {
                PolicySpec::Hlem { adjusted: true, alpha }
            }
            other => other,
        }
    }

    /// Instantiate the policy (called inside the worker that runs the cell).
    pub fn build(&self) -> Box<dyn AllocationPolicy> {
        self.build_with_victim(None)
    }

    /// [`PolicySpec::build`] with an optional victim-policy override
    /// (`None` keeps each policy's default, the paper's list-order).
    pub fn build_with_victim(&self, victim: Option<VictimPolicy>) -> Box<dyn AllocationPolicy> {
        let v = victim.unwrap_or(VictimPolicy::ListOrder);
        match self {
            PolicySpec::FirstFit => Box::new(FirstFit::new().with_victim_policy(v)),
            PolicySpec::BestFit => Box::new(BestFit::new().with_victim_policy(v)),
            PolicySpec::WorstFit => Box::new(WorstFit::new().with_victim_policy(v)),
            PolicySpec::RoundRobin => Box::new(RoundRobin::new().with_victim_policy(v)),
            PolicySpec::Hlem { adjusted: false, .. } => {
                Box::new(HlemVmp::new(HlemConfig::plain().with_victim_policy(v)))
            }
            PolicySpec::Hlem { adjusted: true, alpha } => Box::new(HlemVmp::new(
                HlemConfig::adjusted().with_alpha(*alpha).with_victim_policy(v),
            )),
        }
    }
}

/// Which workload substrate a cell runs: the §VII-E randomized comparison
/// template or the §VII-D cluster-trace simulation (`trace_sim`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Substrate {
    Comparison,
    Trace,
}

impl Substrate {
    pub fn name(&self) -> &'static str {
        match self {
            Substrate::Comparison => "comparison",
            Substrate::Trace => "trace",
        }
    }

    /// Parse one substrate name (`--substrate` vocabulary).
    pub fn parse(s: &str) -> Result<Substrate, String> {
        match s.trim() {
            "comparison" => Ok(Substrate::Comparison),
            "trace" => Ok(Substrate::Trace),
            other => Err(format!(
                "unknown substrate '{other}' (expected comparison | trace)"
            )),
        }
    }

    /// Parse a comma-separated substrate list.
    pub fn parse_list(list: &str) -> Result<Vec<Substrate>, String> {
        let subs: Vec<Substrate> = list
            .split(',')
            .filter(|s| !s.trim().is_empty())
            .map(Substrate::parse)
            .collect::<Result<_, _>>()?;
        if subs.is_empty() {
            return Err("empty substrate list".into());
        }
        Ok(subs)
    }
}

/// Spot-lifecycle overrides a cell applies on top of its substrate's base
/// [`SpotConfig`]. Unset fields keep the base value, so an override is
/// exactly one axis value, not a full config.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct SpotOverride {
    pub warning_time: Option<f64>,
    pub hibernation_timeout: Option<f64>,
    pub behavior: Option<InterruptionBehavior>,
}

impl SpotOverride {
    pub const NONE: SpotOverride =
        SpotOverride { warning_time: None, hibernation_timeout: None, behavior: None };

    pub fn is_none(&self) -> bool {
        *self == Self::NONE
    }

    /// `base` with the set fields substituted.
    pub fn apply_to(&self, base: SpotConfig) -> SpotConfig {
        let mut cfg = base;
        if let Some(w) = self.warning_time {
            cfg = cfg.with_warning(w);
        }
        if let Some(t) = self.hibernation_timeout {
            cfg = cfg.with_hibernation_timeout(t);
        }
        if let Some(b) = self.behavior {
            cfg = cfg.with_behavior(b);
        }
        cfg
    }
}

/// Full description of one cell's scenario variant - everything that
/// distinguishes cells of the same seed. Plain data (`Copy + PartialEq`),
/// so reports can group aggregates by variant equality.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CellSpec {
    pub substrate: Substrate,
    pub policy: PolicySpec,
    pub spot: SpotOverride,
    /// Victim-selection override; `None` keeps the policy default
    /// (list-order, the paper's behavior).
    pub victim: Option<VictimPolicy>,
    /// Chaos-injection faults compiled per cell (`crate::chaos`); `NONE`
    /// keeps the run fault-free.
    pub chaos: ChaosSpec,
    /// Spot-price market model compiled per cell (`crate::market`);
    /// `NONE` keeps the run market-free.
    pub market: MarketSpec,
    /// Checkpoint/migration recovery model compiled per cell
    /// (`crate::recovery`); `NONE` keeps the run recovery-free.
    pub recovery: RecoverySpec,
}

impl CellSpec {
    /// The default variant: comparison substrate, no overrides.
    pub fn comparison(policy: PolicySpec) -> CellSpec {
        CellSpec {
            substrate: Substrate::Comparison,
            policy,
            spot: SpotOverride::NONE,
            victim: None,
            chaos: ChaosSpec::NONE,
            market: MarketSpec::NONE,
            recovery: RecoverySpec::NONE,
        }
    }

    /// Compact human-readable label of the non-default axis values
    /// (terminal tables); `"-"` when this is the plain comparison variant
    /// of an alpha-insensitive policy. The adjusted-HLEM alpha is always
    /// shown so `hlem.alpha` axis rows stay distinguishable.
    pub fn variant_label(&self) -> String {
        let mut parts: Vec<String> = Vec::new();
        if self.substrate != Substrate::Comparison {
            parts.push(self.substrate.name().to_string());
        }
        if let Some(a) = self.policy.alpha() {
            parts.push(format!("alpha={}", crate::util::csv::fmt_num(a)));
        }
        if let Some(w) = self.spot.warning_time {
            parts.push(format!("warn={}", crate::util::csv::fmt_num(w)));
        }
        if let Some(t) = self.spot.hibernation_timeout {
            parts.push(format!("hib={}", crate::util::csv::fmt_num(t)));
        }
        if let Some(b) = self.spot.behavior {
            parts.push(b.name().to_string());
        }
        if let Some(v) = self.victim {
            parts.push(format!("victim={}", v.name()));
        }
        if let Some(m) = self.chaos.host_mtbf {
            parts.push(format!("mtbf={}", m.label()));
        }
        if let Some(s) = self.chaos.reclaim_storm {
            parts.push(format!("storm={}", s.label()));
        }
        if let Some(o) = self.chaos.broker_outage {
            parts.push(format!("outage={}", o.label()));
        }
        if let Some(s) = self.chaos.demand_surge {
            parts.push(format!("surge={}", s.label()));
        }
        if let Some(v) = self.market.volatility {
            parts.push(format!("vol={v}"));
        }
        if let Some(v) = self.market.mean_reversion {
            parts.push(format!("rev={v}"));
        }
        if let Some(v) = self.market.daily_amplitude {
            parts.push(format!("amp={v}"));
        }
        if let Some(v) = self.market.bid_margin {
            parts.push(format!("bid={v}"));
        }
        if let Some(m) = self.recovery.mode {
            parts.push(format!("rec={}", m.label()));
        }
        if let Some(v) = self.recovery.bandwidth {
            parts.push(format!("bw={v}"));
        }
        if let Some(v) = self.recovery.checkpoint_threshold {
            parts.push(format!("ckpt={v}"));
        }
        if parts.is_empty() {
            "-".to_string()
        } else {
            parts.join(" ")
        }
    }
}

/// One scenario axis: a named grid dimension with its enumerated values.
///
/// Each axis multiplies the current variant list (value order preserved);
/// [`ScenarioAxis::HlemAlpha`] is the exception - it only multiplies
/// alpha-sensitive policies (adjusted HLEM) and passes every other variant
/// through once, so `first-fit` is not duplicated per alpha.
#[derive(Debug, Clone, PartialEq)]
pub enum ScenarioAxis {
    /// `SpotConfig::warning_time` values, seconds (`spot.warning`).
    SpotWarning(Vec<f64>),
    /// `SpotConfig::hibernation_timeout` values, seconds
    /// (`spot.hibernation-timeout`).
    SpotHibernationTimeout(Vec<f64>),
    /// Terminate-vs-hibernate interruption behavior (`spot.behavior`).
    SpotBehavior(Vec<InterruptionBehavior>),
    /// Adjusted-HLEM alpha values (`hlem.alpha`).
    HlemAlpha(Vec<f64>),
    /// Victim-selection ablation (`victim`).
    Victim(Vec<VictimPolicy>),
    /// Workload substrate (`substrate`).
    Substrate(Vec<Substrate>),
    /// Per-host crash/recovery fault processes (`chaos.host-mtbf`), values
    /// in the `mtbf<secs>-mttr<secs>` grammar of [`HostMtbf::parse`].
    ChaosHostMtbf(Vec<HostMtbf>),
    /// Correlated spot reclaim storms (`chaos.reclaim-storm`), values in
    /// the `at<secs>-frac<f>[-x<n>-every<secs>]` grammar of
    /// [`ReclaimStorm::parse`].
    ChaosReclaimStorm(Vec<ReclaimStorm>),
    /// Broker retry-outage windows (`chaos.broker-outage`), values in the
    /// `at<secs>-for<secs>` grammar of [`BrokerOutage::parse`].
    ChaosBrokerOutage(Vec<BrokerOutage>),
    /// On-demand demand surges (`chaos.demand-surge`), values in the
    /// `at<secs>-vms<n>-pes<n>-for<secs>` grammar of
    /// [`DemandSurge::parse`].
    ChaosDemandSurge(Vec<DemandSurge>),
    /// Spot-price OU volatility values (`market.volatility`), >= 0.
    MarketVolatility(Vec<f64>),
    /// Spot-price OU mean-reversion rates (`market.mean-reversion`),
    /// per-second, > 0.
    MarketMeanReversion(Vec<f64>),
    /// Daily periodic price-amplitude fractions
    /// (`market.daily-amplitude`), in [0, 1].
    MarketDailyAmplitude(Vec<f64>),
    /// Bid levels as a margin over the long-run spot mean
    /// (`market.bid-margin`), > 0; bid = on-demand price x margin.
    MarketBidMargin(Vec<f64>),
    /// Interruption-recovery mode ablation (`recovery.mode`), values in
    /// the [`RecoveryMode::parse`] vocabulary
    /// (none | restart | checkpoint | migrate-greedy | migrate-optimal).
    RecoveryMode(Vec<RecoveryMode>),
    /// Checkpoint-transfer bandwidths in MB/s (`recovery.bandwidth`), > 0.
    RecoveryBandwidth(Vec<f64>),
    /// Minimum transferable fraction for a partial checkpoint
    /// (`recovery.checkpoint-threshold`), in [0, 1].
    RecoveryCheckpointThreshold(Vec<f64>),
}

impl ScenarioAxis {
    /// Parse one `--axis` argument: `<name>=<v1,v2,...>` with names
    /// `spot.warning`, `spot.hibernation-timeout`, `spot.behavior`,
    /// `hlem.alpha`, `victim`, `substrate`, `chaos.host-mtbf`,
    /// `chaos.reclaim-storm`, `chaos.broker-outage`, `chaos.demand-surge`,
    /// `market.volatility`, `market.mean-reversion`,
    /// `market.daily-amplitude`, `market.bid-margin`, `recovery.mode`,
    /// `recovery.bandwidth`, `recovery.checkpoint-threshold`.
    pub fn parse(s: &str) -> Result<ScenarioAxis, String> {
        let (name, vals) = s
            .split_once('=')
            .ok_or_else(|| format!("axis '{s}' must be <name>=<v1,v2,...>"))?;
        match name.trim() {
            "spot.warning" => Ok(ScenarioAxis::SpotWarning(parse_secs_list(vals, "spot.warning")?)),
            "spot.hibernation-timeout" => Ok(ScenarioAxis::SpotHibernationTimeout(
                parse_secs_list(vals, "spot.hibernation-timeout")?,
            )),
            "spot.behavior" => {
                Ok(ScenarioAxis::SpotBehavior(parse_each(vals, InterruptionBehavior::parse)?))
            }
            "hlem.alpha" => Ok(ScenarioAxis::HlemAlpha(parse_f64_list(vals, "hlem.alpha")?)),
            "victim" => Ok(ScenarioAxis::Victim(parse_each(vals, VictimPolicy::parse)?)),
            "substrate" => Ok(ScenarioAxis::Substrate(Substrate::parse_list(vals)?)),
            "chaos.host-mtbf" => {
                Ok(ScenarioAxis::ChaosHostMtbf(parse_each(vals, HostMtbf::parse)?))
            }
            "chaos.reclaim-storm" => {
                Ok(ScenarioAxis::ChaosReclaimStorm(parse_each(vals, ReclaimStorm::parse)?))
            }
            "chaos.broker-outage" => {
                Ok(ScenarioAxis::ChaosBrokerOutage(parse_each(vals, BrokerOutage::parse)?))
            }
            "chaos.demand-surge" => {
                Ok(ScenarioAxis::ChaosDemandSurge(parse_each(vals, DemandSurge::parse)?))
            }
            "market.volatility" => Ok(ScenarioAxis::MarketVolatility(parse_market_list(
                vals,
                "market.volatility",
                MarketBound::NonNegative,
            )?)),
            "market.mean-reversion" => Ok(ScenarioAxis::MarketMeanReversion(parse_market_list(
                vals,
                "market.mean-reversion",
                MarketBound::Positive,
            )?)),
            "market.daily-amplitude" => Ok(ScenarioAxis::MarketDailyAmplitude(parse_market_list(
                vals,
                "market.daily-amplitude",
                MarketBound::UnitInterval,
            )?)),
            "market.bid-margin" => Ok(ScenarioAxis::MarketBidMargin(parse_market_list(
                vals,
                "market.bid-margin",
                MarketBound::Positive,
            )?)),
            "recovery.mode" => {
                Ok(ScenarioAxis::RecoveryMode(parse_each(vals, RecoveryMode::parse)?))
            }
            "recovery.bandwidth" => Ok(ScenarioAxis::RecoveryBandwidth(parse_market_list(
                vals,
                "recovery.bandwidth",
                MarketBound::Positive,
            )?)),
            "recovery.checkpoint-threshold" => {
                Ok(ScenarioAxis::RecoveryCheckpointThreshold(parse_market_list(
                    vals,
                    "recovery.checkpoint-threshold",
                    MarketBound::UnitInterval,
                )?))
            }
            other => Err(format!(
                "unknown axis '{other}' (expected spot.warning | spot.hibernation-timeout | \
                 spot.behavior | hlem.alpha | victim | substrate | chaos.host-mtbf | \
                 chaos.reclaim-storm | chaos.broker-outage | chaos.demand-surge | \
                 market.volatility | market.mean-reversion | market.daily-amplitude | \
                 market.bid-margin | recovery.mode | recovery.bandwidth | \
                 recovery.checkpoint-threshold)"
            )),
        }
    }

    /// The axis's flag-vocabulary name (the `--axis <name>=...` key).
    pub fn name(&self) -> &'static str {
        match self {
            ScenarioAxis::SpotWarning(_) => "spot.warning",
            ScenarioAxis::SpotHibernationTimeout(_) => "spot.hibernation-timeout",
            ScenarioAxis::SpotBehavior(_) => "spot.behavior",
            ScenarioAxis::HlemAlpha(_) => "hlem.alpha",
            ScenarioAxis::Victim(_) => "victim",
            ScenarioAxis::Substrate(_) => "substrate",
            ScenarioAxis::ChaosHostMtbf(_) => "chaos.host-mtbf",
            ScenarioAxis::ChaosReclaimStorm(_) => "chaos.reclaim-storm",
            ScenarioAxis::ChaosBrokerOutage(_) => "chaos.broker-outage",
            ScenarioAxis::ChaosDemandSurge(_) => "chaos.demand-surge",
            ScenarioAxis::MarketVolatility(_) => "market.volatility",
            ScenarioAxis::MarketMeanReversion(_) => "market.mean-reversion",
            ScenarioAxis::MarketDailyAmplitude(_) => "market.daily-amplitude",
            ScenarioAxis::MarketBidMargin(_) => "market.bid-margin",
            ScenarioAxis::RecoveryMode(_) => "recovery.mode",
            ScenarioAxis::RecoveryBandwidth(_) => "recovery.bandwidth",
            ScenarioAxis::RecoveryCheckpointThreshold(_) => "recovery.checkpoint-threshold",
        }
    }

    /// Number of values on this axis.
    pub fn len(&self) -> usize {
        match self {
            ScenarioAxis::SpotWarning(v) | ScenarioAxis::SpotHibernationTimeout(v) => v.len(),
            ScenarioAxis::SpotBehavior(v) => v.len(),
            ScenarioAxis::HlemAlpha(v) => v.len(),
            ScenarioAxis::Victim(v) => v.len(),
            ScenarioAxis::Substrate(v) => v.len(),
            ScenarioAxis::ChaosHostMtbf(v) => v.len(),
            ScenarioAxis::ChaosReclaimStorm(v) => v.len(),
            ScenarioAxis::ChaosBrokerOutage(v) => v.len(),
            ScenarioAxis::ChaosDemandSurge(v) => v.len(),
            ScenarioAxis::MarketVolatility(v)
            | ScenarioAxis::MarketMeanReversion(v)
            | ScenarioAxis::MarketDailyAmplitude(v)
            | ScenarioAxis::MarketBidMargin(v) => v.len(),
            ScenarioAxis::RecoveryMode(v) => v.len(),
            ScenarioAxis::RecoveryBandwidth(v)
            | ScenarioAxis::RecoveryCheckpointThreshold(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Multiply `variants` by this axis (variant-major, value-minor: the
    /// existing variant order is preserved and this axis varies fastest).
    fn expand(&self, variants: Vec<CellSpec>) -> Vec<CellSpec> {
        let mut out = Vec::with_capacity(variants.len() * self.len().max(1));
        for v in variants {
            match self {
                ScenarioAxis::SpotWarning(vals) => {
                    for &x in vals {
                        let mut s = v;
                        s.spot.warning_time = Some(x);
                        out.push(s);
                    }
                }
                ScenarioAxis::SpotHibernationTimeout(vals) => {
                    for &x in vals {
                        let mut s = v;
                        s.spot.hibernation_timeout = Some(x);
                        out.push(s);
                    }
                }
                ScenarioAxis::SpotBehavior(vals) => {
                    for &b in vals {
                        let mut s = v;
                        s.spot.behavior = Some(b);
                        out.push(s);
                    }
                }
                ScenarioAxis::HlemAlpha(vals) => {
                    if v.policy.alpha_sensitive() {
                        for &a in vals {
                            out.push(CellSpec { policy: v.policy.with_alpha(a), ..v });
                        }
                    } else {
                        out.push(v);
                    }
                }
                ScenarioAxis::Victim(vals) => {
                    for &p in vals {
                        out.push(CellSpec { victim: Some(p), ..v });
                    }
                }
                ScenarioAxis::Substrate(vals) => {
                    for &sub in vals {
                        out.push(CellSpec { substrate: sub, ..v });
                    }
                }
                ScenarioAxis::ChaosHostMtbf(vals) => {
                    for &m in vals {
                        let mut s = v;
                        s.chaos.host_mtbf = Some(m);
                        out.push(s);
                    }
                }
                ScenarioAxis::ChaosReclaimStorm(vals) => {
                    for &x in vals {
                        let mut s = v;
                        s.chaos.reclaim_storm = Some(x);
                        out.push(s);
                    }
                }
                ScenarioAxis::ChaosBrokerOutage(vals) => {
                    for &o in vals {
                        let mut s = v;
                        s.chaos.broker_outage = Some(o);
                        out.push(s);
                    }
                }
                ScenarioAxis::ChaosDemandSurge(vals) => {
                    for &x in vals {
                        let mut s = v;
                        s.chaos.demand_surge = Some(x);
                        out.push(s);
                    }
                }
                ScenarioAxis::MarketVolatility(vals) => {
                    for &x in vals {
                        let mut s = v;
                        s.market.volatility = Some(x);
                        out.push(s);
                    }
                }
                ScenarioAxis::MarketMeanReversion(vals) => {
                    for &x in vals {
                        let mut s = v;
                        s.market.mean_reversion = Some(x);
                        out.push(s);
                    }
                }
                ScenarioAxis::MarketDailyAmplitude(vals) => {
                    for &x in vals {
                        let mut s = v;
                        s.market.daily_amplitude = Some(x);
                        out.push(s);
                    }
                }
                ScenarioAxis::MarketBidMargin(vals) => {
                    for &x in vals {
                        let mut s = v;
                        s.market.bid_margin = Some(x);
                        out.push(s);
                    }
                }
                ScenarioAxis::RecoveryMode(vals) => {
                    for &m in vals {
                        let mut s = v;
                        s.recovery.mode = Some(m);
                        out.push(s);
                    }
                }
                ScenarioAxis::RecoveryBandwidth(vals) => {
                    for &x in vals {
                        let mut s = v;
                        s.recovery.bandwidth = Some(x);
                        out.push(s);
                    }
                }
                ScenarioAxis::RecoveryCheckpointThreshold(vals) => {
                    for &x in vals {
                        let mut s = v;
                        s.recovery.checkpoint_threshold = Some(x);
                        out.push(s);
                    }
                }
            }
        }
        out
    }
}

fn parse_each<T>(list: &str, parse: impl Fn(&str) -> Result<T, String>) -> Result<Vec<T>, String> {
    let items: Vec<T> = list
        .split(',')
        .filter(|s| !s.trim().is_empty())
        .map(|s| parse(s))
        .collect::<Result<_, _>>()?;
    if items.is_empty() {
        return Err("empty axis value list".into());
    }
    Ok(items)
}

fn parse_f64_list(list: &str, axis: &str) -> Result<Vec<f64>, String> {
    parse_each(list, |s| {
        let v: f64 = s
            .trim()
            .parse()
            .map_err(|_| format!("axis {axis}: '{s}' is not a number"))?;
        if !v.is_finite() {
            return Err(format!("axis {axis}: '{s}' is not finite"));
        }
        Ok(v)
    })
}

fn parse_secs_list(list: &str, axis: &str) -> Result<Vec<f64>, String> {
    let vals = parse_f64_list(list, axis)?;
    if let Some(bad) = vals.iter().find(|v| **v < 0.0) {
        return Err(format!("axis {axis}: {bad} is negative (seconds must be >= 0)"));
    }
    Ok(vals)
}

/// Domain constraint on one `market.*` axis's values.
#[derive(Clone, Copy)]
enum MarketBound {
    /// `>= 0` (volatility).
    NonNegative,
    /// `> 0` (mean-reversion rate, bid margin).
    Positive,
    /// `[0, 1]` (daily amplitude fraction).
    UnitInterval,
}

fn parse_market_list(list: &str, axis: &str, bound: MarketBound) -> Result<Vec<f64>, String> {
    let vals = parse_f64_list(list, axis)?;
    for v in &vals {
        match bound {
            MarketBound::NonNegative if *v < 0.0 => {
                return Err(format!("axis {axis}: {v} is negative (must be >= 0)"));
            }
            MarketBound::Positive if *v <= 0.0 => {
                return Err(format!("axis {axis}: {v} must be > 0"));
            }
            MarketBound::UnitInterval if !(0.0..=1.0).contains(v) => {
                return Err(format!("axis {axis}: {v} is outside [0, 1]"));
            }
            _ => {}
        }
    }
    Ok(vals)
}

/// Trace-substrate template for [`Substrate::Trace`] cells: the synthetic
/// trace generator plus the trace-to-workload conversion, at a scale small
/// enough that multi-cell grids stay runnable (the full Fig-12 scale lives
/// in `cloudmarket trace`). The per-cell seed overrides both the generator
/// seed and the workload seed; one generated trace is shared per seed
/// across that seed's cells (`sweep::prebuild`).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceSubstrate {
    pub synth: SynthConfig,
    pub workload: WorkloadConfig,
    /// Metrics sampling period for trace cells, seconds.
    pub sample_interval: f64,
}

impl Default for TraceSubstrate {
    fn default() -> Self {
        TraceSubstrate {
            synth: SynthConfig {
                machines: 40,
                days: 0.25,
                tasks_per_hour: 400.0,
                ..Default::default()
            },
            workload: WorkloadConfig {
                spot_instances: 200,
                spot_durations: vec![1_800.0, 3_600.0],
                max_trace_vms: 2_000,
                ..Default::default()
            },
            sample_interval: 120.0,
        }
    }
}

/// Which cells keep their sampled [`crate::metrics::TimeSeries`]
/// (Fig-13-style active-instance curves). Retaining every cell's series
/// multiplies artifact size by the sample count, so the default is none;
/// clauses are OR-ed, each matching on policy name, seed, cell id or
/// substrate.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct SeriesFilter {
    clauses: Vec<RetainClause>,
}

#[derive(Debug, Clone, PartialEq)]
enum RetainClause {
    All,
    Policy(String),
    Seed(u64),
    Id(usize),
    Substrate(Substrate),
}

impl SeriesFilter {
    /// Retain nothing (the default).
    pub fn none() -> SeriesFilter {
        SeriesFilter::default()
    }

    /// Retain every cell's series.
    pub fn all() -> SeriesFilter {
        SeriesFilter { clauses: vec![RetainClause::All] }
    }

    /// Parse a `--retain-series` filter: `none`, `all`, or a
    /// comma-separated OR of `policy=<name>`, `seed=<n>`, `id=<n>`,
    /// `substrate=<comparison|trace>` clauses.
    pub fn parse(s: &str) -> Result<SeriesFilter, String> {
        match s.trim() {
            "none" | "" => return Ok(SeriesFilter::none()),
            "all" => return Ok(SeriesFilter::all()),
            _ => {}
        }
        let clauses: Vec<RetainClause> = s
            .split(',')
            .filter(|c| !c.trim().is_empty())
            .map(|clause| {
                let (key, val) = clause
                    .split_once('=')
                    .ok_or_else(|| format!("retain clause '{clause}' must be <key>=<value>"))?;
                let val = val.trim();
                match key.trim() {
                    // Validate against the policy vocabulary so a typo
                    // fails loudly instead of silently retaining nothing.
                    "policy" => PolicySpec::parse(val, 0.0)
                        .map(|p| RetainClause::Policy(p.name().to_string())),
                    "seed" => val
                        .parse()
                        .map(RetainClause::Seed)
                        .map_err(|_| format!("retain seed '{val}' is not an integer")),
                    "id" => val
                        .parse()
                        .map(RetainClause::Id)
                        .map_err(|_| format!("retain id '{val}' is not an integer")),
                    "substrate" => Substrate::parse(val).map(RetainClause::Substrate),
                    other => Err(format!(
                        "unknown retain key '{other}' (expected policy | seed | id | substrate, \
                         or the literals all | none)"
                    )),
                }
            })
            .collect::<Result<_, _>>()?;
        if clauses.is_empty() {
            return Err("empty retain filter (use 'none' explicitly)".into());
        }
        Ok(SeriesFilter { clauses })
    }

    /// Whether nothing is retained.
    pub fn is_none(&self) -> bool {
        self.clauses.is_empty()
    }

    /// The filter in [`SeriesFilter::parse`] syntax, such that
    /// `parse(f.spec_string()) == f` (the shard-file serialization of a
    /// sweep spec stores this string). Every publicly-constructible
    /// filter is expressible: `parse` admits `all`/`none` only as whole
    /// inputs, so an `All` clause can never coexist with keyed clauses.
    pub fn spec_string(&self) -> String {
        if self.clauses.is_empty() {
            return "none".to_string();
        }
        if self.clauses.contains(&RetainClause::All) {
            debug_assert_eq!(self.clauses.len(), 1, "All never mixes with keyed clauses");
            return "all".to_string();
        }
        self.clauses
            .iter()
            .map(|c| match c {
                RetainClause::All => unreachable!("handled above"),
                RetainClause::Policy(name) => format!("policy={name}"),
                RetainClause::Seed(s) => format!("seed={s}"),
                RetainClause::Id(i) => format!("id={i}"),
                RetainClause::Substrate(sub) => format!("substrate={}", sub.name()),
            })
            .collect::<Vec<_>>()
            .join(",")
    }

    /// Whether `cell`'s series should be kept.
    pub fn matches(&self, cell: &Cell) -> bool {
        self.clauses.iter().any(|c| match c {
            RetainClause::All => true,
            RetainClause::Policy(name) => cell.spec.policy.name() == name,
            RetainClause::Seed(s) => cell.seed == *s,
            RetainClause::Id(i) => cell.id == *i,
            RetainClause::Substrate(sub) => cell.spec.substrate == *sub,
        })
    }
}

/// One unit of sweep work: a (seed, scenario variant) pair with a dense id
/// that fixes its position in the merged output.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Cell {
    pub id: usize,
    pub seed: u64,
    pub spec: CellSpec,
}

impl Cell {
    /// The cell's policy (shorthand for `self.spec.policy`).
    pub fn policy(&self) -> PolicySpec {
        self.spec.policy
    }
}

/// Declarative description of a sweep: the scenario templates, the engine
/// knobs every comparison cell runs under, and the grid axes.
///
/// Cells are the cartesian product `seeds × variants` (seed-major, the
/// order of the pre-sweep `run_multi` loop) plus any explicitly listed
/// extra cells, where the variants are the policy list multiplied by each
/// declared [`ScenarioAxis`] in order.
///
/// Equality is field-wise (`PartialEq`); `sweep::shard` relies on it to
/// pin that a spec survives the shard-file serialization round trip
/// unchanged.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepSpec {
    /// Comparison-substrate scenario template; each cell overrides `seed`
    /// (and its spot config, when a spot axis says so).
    pub scenario: ComparisonConfig,
    /// Engine configuration shared by all comparison cells (defaults to
    /// the §VII-E comparison-experiment settings of `compare::run_policy`;
    /// trace cells run `trace::workload::trace_engine_config` instead).
    pub engine: EngineConfig,
    pub seeds: Vec<u64>,
    pub policies: Vec<PolicySpec>,
    /// Scenario axes multiplied onto the policy list, in order.
    pub axes: Vec<ScenarioAxis>,
    /// Template for [`Substrate::Trace`] cells.
    pub trace: TraceSubstrate,
    /// Which cells keep their sampled time series.
    pub retain: SeriesFilter,
    /// Extra cells appended after the cartesian grid.
    pub explicit: Vec<(u64, PolicySpec)>,
}

impl SweepSpec {
    pub fn new(scenario: ComparisonConfig) -> Self {
        SweepSpec {
            scenario,
            engine: comparison_engine_config(),
            seeds: Vec::new(),
            policies: Vec::new(),
            axes: Vec::new(),
            trace: TraceSubstrate::default(),
            retain: SeriesFilter::none(),
            explicit: Vec::new(),
        }
    }

    /// Grid axis: seeds `base..base + count`.
    pub fn with_seed_range(mut self, base: u64, count: usize) -> Self {
        self.seeds = (0..count).map(|r| base + r as u64).collect();
        self
    }

    /// Grid axis: an explicit seed list.
    pub fn with_seeds(mut self, seeds: Vec<u64>) -> Self {
        self.seeds = seeds;
        self
    }

    /// Grid axis: the policy list.
    pub fn with_policies(mut self, policies: Vec<PolicySpec>) -> Self {
        self.policies = policies;
        self
    }

    /// Append one scenario axis (last-added varies fastest).
    ///
    /// Panics on an axis with no values: expanding by it would silently
    /// collapse the whole grid to zero cells (the CLI parsers reject
    /// empty value lists before getting here).
    pub fn with_axis(mut self, axis: ScenarioAxis) -> Self {
        assert!(
            !axis.is_empty(),
            "scenario axis '{}' has no values (would empty the grid)",
            axis.name()
        );
        self.axes.push(axis);
        self
    }

    /// Append several scenario axes in order.
    pub fn with_axes(mut self, axes: Vec<ScenarioAxis>) -> Self {
        for axis in axes {
            self = self.with_axis(axis);
        }
        self
    }

    /// Replace the trace-substrate template.
    pub fn with_trace_substrate(mut self, trace: TraceSubstrate) -> Self {
        self.trace = trace;
        self
    }

    /// Set the per-cell time-series retention filter.
    pub fn with_series_retention(mut self, retain: SeriesFilter) -> Self {
        self.retain = retain;
        self
    }

    /// Explicit-list construction: append one extra cell outside the grid
    /// (plain comparison variant).
    pub fn with_cell(mut self, seed: u64, policy: PolicySpec) -> Self {
        self.explicit.push((seed, policy));
        self
    }

    /// The scenario variants: policies multiplied by each axis in
    /// declaration order (the last-declared axis varies fastest).
    pub fn variants(&self) -> Vec<CellSpec> {
        let mut variants: Vec<CellSpec> =
            self.policies.iter().map(|&p| CellSpec::comparison(p)).collect();
        for axis in &self.axes {
            variants = axis.expand(variants);
        }
        variants
    }

    /// Number of cells the spec enumerates.
    pub fn cell_count(&self) -> usize {
        self.seeds.len() * self.variants().len() + self.explicit.len()
    }

    /// Enumerate the cells in their deterministic merge order: cartesian
    /// product `seeds × variants` seed-major, then the explicit extras,
    /// with dense ids.
    pub fn cells(&self) -> Vec<Cell> {
        let variants = self.variants();
        let mut cells = Vec::with_capacity(self.seeds.len() * variants.len());
        for &seed in &self.seeds {
            for &spec in &variants {
                cells.push(Cell { id: cells.len(), seed, spec });
            }
        }
        for &(seed, policy) in &self.explicit {
            cells.push(Cell { id: cells.len(), seed, spec: CellSpec::comparison(policy) });
        }
        cells
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cartesian_cells_are_seed_major_with_dense_ids() {
        let spec = SweepSpec::new(ComparisonConfig::default())
            .with_seed_range(10, 2)
            .with_policies(PolicySpec::paper());
        let cells = spec.cells();
        assert_eq!(cells.len(), 6);
        assert_eq!(spec.cell_count(), 6);
        for (i, c) in cells.iter().enumerate() {
            assert_eq!(c.id, i);
        }
        assert_eq!(cells[0].seed, 10);
        assert_eq!(cells[2].seed, 10);
        assert_eq!(cells[3].seed, 11);
        assert_eq!(cells[0].policy().name(), "first-fit");
        assert_eq!(cells[1].policy().name(), "hlem-vmp");
        assert_eq!(cells[2].policy().name(), "hlem-vmp-adjusted");
        // Axis-free grids produce plain comparison variants.
        assert!(cells.iter().all(|c| c.spec == CellSpec::comparison(c.spec.policy)));
    }

    #[test]
    fn explicit_cells_append_after_grid() {
        let spec = SweepSpec::new(ComparisonConfig::default())
            .with_seeds(vec![1])
            .with_policies(vec![PolicySpec::FirstFit])
            .with_cell(99, PolicySpec::BestFit);
        let cells = spec.cells();
        assert_eq!(cells.len(), 2);
        assert_eq!(cells[1].seed, 99);
        assert_eq!(cells[1].policy(), PolicySpec::BestFit);
    }

    #[test]
    fn policy_spec_parses_names_and_alpha() {
        assert_eq!(PolicySpec::parse("first-fit", -0.5).unwrap(), PolicySpec::FirstFit);
        assert_eq!(
            PolicySpec::parse("hlem-vmp-adjusted", -0.7).unwrap().alpha(),
            Some(-0.7)
        );
        assert_eq!(PolicySpec::parse("hlem-vmp", -0.7).unwrap().alpha(), None);
        assert!(PolicySpec::parse("nope", 0.0).is_err());
    }

    #[test]
    fn policy_list_parses_and_rejects_empty() {
        let specs = PolicySpec::parse_list("first-fit, hlem-vmp,hlem-vmp-adjusted", -0.5).unwrap();
        assert_eq!(specs.len(), 3);
        assert!(PolicySpec::parse_list("", -0.5).is_err());
        assert!(PolicySpec::parse_list("first-fit,bogus", -0.5).is_err());
    }

    #[test]
    fn built_policies_report_spec_names() {
        for spec in [
            PolicySpec::FirstFit,
            PolicySpec::BestFit,
            PolicySpec::WorstFit,
            PolicySpec::RoundRobin,
            PolicySpec::Hlem { adjusted: false, alpha: 0.0 },
            PolicySpec::Hlem { adjusted: true, alpha: -0.5 },
        ] {
            assert_eq!(spec.build().name(), spec.name());
            assert_eq!(
                spec.build_with_victim(Some(VictimPolicy::Youngest)).name(),
                spec.name()
            );
        }
    }

    /// Pins the axis-expansion cell ordering: axes expand in declaration
    /// order with the last-declared axis varying fastest, and the alpha
    /// axis multiplies only alpha-sensitive policies.
    #[test]
    fn axis_expansion_order_is_pinned() {
        let spec = SweepSpec::new(ComparisonConfig::default())
            .with_seeds(vec![7])
            .with_policies(vec![
                PolicySpec::FirstFit,
                PolicySpec::Hlem { adjusted: true, alpha: -0.5 },
            ])
            .with_axis(ScenarioAxis::HlemAlpha(vec![-0.3, -0.7]))
            .with_axis(ScenarioAxis::SpotWarning(vec![60.0, 120.0]));
        // Variants: [ff, adj(-0.3), adj(-0.7)] x warning [60, 120].
        let variants = spec.variants();
        let expected: Vec<(&str, Option<f64>, Option<f64>)> = vec![
            ("first-fit", None, Some(60.0)),
            ("first-fit", None, Some(120.0)),
            ("hlem-vmp-adjusted", Some(-0.3), Some(60.0)),
            ("hlem-vmp-adjusted", Some(-0.3), Some(120.0)),
            ("hlem-vmp-adjusted", Some(-0.7), Some(60.0)),
            ("hlem-vmp-adjusted", Some(-0.7), Some(120.0)),
        ];
        assert_eq!(variants.len(), expected.len());
        for (v, (name, alpha, warn)) in variants.iter().zip(&expected) {
            assert_eq!(v.policy.name(), *name);
            assert_eq!(v.policy.alpha(), *alpha);
            assert_eq!(v.spot.warning_time, *warn);
        }
        // Cells are seed-major over those variants with dense ids.
        let cells = spec.cells();
        assert_eq!(cells.len(), 6);
        for (i, c) in cells.iter().enumerate() {
            assert_eq!(c.id, i);
            assert_eq!(c.seed, 7);
            assert_eq!(c.spec, variants[i]);
        }
    }

    #[test]
    fn substrate_and_victim_axes_expand() {
        let spec = SweepSpec::new(ComparisonConfig::default())
            .with_seeds(vec![1])
            .with_policies(vec![PolicySpec::FirstFit])
            .with_axis(ScenarioAxis::Victim(vec![
                VictimPolicy::ListOrder,
                VictimPolicy::Youngest,
            ]))
            .with_axis(ScenarioAxis::Substrate(vec![
                Substrate::Comparison,
                Substrate::Trace,
            ]));
        let variants = spec.variants();
        assert_eq!(variants.len(), 4);
        assert_eq!(variants[0].victim, Some(VictimPolicy::ListOrder));
        assert_eq!(variants[0].substrate, Substrate::Comparison);
        assert_eq!(variants[1].substrate, Substrate::Trace);
        assert_eq!(variants[2].victim, Some(VictimPolicy::Youngest));
        assert_eq!(variants[3].substrate, Substrate::Trace);
        assert_eq!(spec.cell_count(), 4);
    }

    #[test]
    fn axis_parse_round_trips() {
        assert_eq!(
            ScenarioAxis::parse("spot.warning=60,120,300").unwrap(),
            ScenarioAxis::SpotWarning(vec![60.0, 120.0, 300.0])
        );
        assert_eq!(
            ScenarioAxis::parse("spot.hibernation-timeout=900").unwrap(),
            ScenarioAxis::SpotHibernationTimeout(vec![900.0])
        );
        assert_eq!(
            ScenarioAxis::parse("spot.behavior=terminate,hibernate").unwrap(),
            ScenarioAxis::SpotBehavior(vec![
                InterruptionBehavior::Terminate,
                InterruptionBehavior::Hibernate,
            ])
        );
        assert_eq!(
            ScenarioAxis::parse("hlem.alpha=-0.3,-0.7").unwrap(),
            ScenarioAxis::HlemAlpha(vec![-0.3, -0.7])
        );
        assert_eq!(
            ScenarioAxis::parse("victim=youngest").unwrap(),
            ScenarioAxis::Victim(vec![VictimPolicy::Youngest])
        );
        assert_eq!(
            ScenarioAxis::parse("substrate=comparison,trace").unwrap(),
            ScenarioAxis::Substrate(vec![Substrate::Comparison, Substrate::Trace])
        );
        assert_eq!(
            ScenarioAxis::parse("chaos.host-mtbf=mtbf20000-mttr600").unwrap(),
            ScenarioAxis::ChaosHostMtbf(vec![HostMtbf::parse("mtbf20000-mttr600").unwrap()])
        );
        assert_eq!(
            ScenarioAxis::parse("chaos.reclaim-storm=at1200-frac0.5,at600-frac0.25-x3-every900")
                .unwrap(),
            ScenarioAxis::ChaosReclaimStorm(vec![
                ReclaimStorm::parse("at1200-frac0.5").unwrap(),
                ReclaimStorm::parse("at600-frac0.25-x3-every900").unwrap(),
            ])
        );
        assert_eq!(
            ScenarioAxis::parse("chaos.broker-outage=at900-for300").unwrap(),
            ScenarioAxis::ChaosBrokerOutage(vec![BrokerOutage::parse("at900-for300").unwrap()])
        );
        assert_eq!(
            ScenarioAxis::parse("chaos.demand-surge=at600-vms40-pes4-for600").unwrap(),
            ScenarioAxis::ChaosDemandSurge(vec![
                DemandSurge::parse("at600-vms40-pes4-for600").unwrap()
            ])
        );
        assert_eq!(
            ScenarioAxis::parse("market.volatility=0,0.05,0.2").unwrap(),
            ScenarioAxis::MarketVolatility(vec![0.0, 0.05, 0.2])
        );
        assert_eq!(
            ScenarioAxis::parse("market.mean-reversion=0.0002,0.001").unwrap(),
            ScenarioAxis::MarketMeanReversion(vec![0.0002, 0.001])
        );
        assert_eq!(
            ScenarioAxis::parse("market.daily-amplitude=0,0.25,1").unwrap(),
            ScenarioAxis::MarketDailyAmplitude(vec![0.0, 0.25, 1.0])
        );
        assert_eq!(
            ScenarioAxis::parse("market.bid-margin=0.5,0.75").unwrap(),
            ScenarioAxis::MarketBidMargin(vec![0.5, 0.75])
        );
    }

    #[test]
    #[should_panic(expected = "has no values")]
    fn empty_axis_is_rejected() {
        let _ = SweepSpec::new(ComparisonConfig::default())
            .with_seeds(vec![1])
            .with_policies(vec![PolicySpec::FirstFit])
            .with_axis(ScenarioAxis::SpotWarning(vec![]));
    }

    #[test]
    fn axis_parse_rejects_bad_input() {
        assert!(ScenarioAxis::parse("spot.warning").is_err(), "missing =");
        assert!(ScenarioAxis::parse("spot.warning=").is_err(), "empty values");
        assert!(ScenarioAxis::parse("spot.warning=-5").is_err(), "negative seconds");
        assert!(ScenarioAxis::parse("spot.warning=abc").is_err(), "non-numeric");
        assert!(ScenarioAxis::parse("hlem.alpha=nan").is_err(), "non-finite");
        assert!(ScenarioAxis::parse("victim=oldest").is_err(), "unknown victim");
        assert!(ScenarioAxis::parse("substrate=cloud").is_err(), "unknown substrate");
        assert!(ScenarioAxis::parse("frobnicate=1").is_err(), "unknown axis");
        assert!(ScenarioAxis::parse("chaos.host-mtbf=mtbf0-mttr600").is_err(), "zero mtbf");
        assert!(ScenarioAxis::parse("chaos.reclaim-storm=at600").is_err(), "missing frac");
        assert!(
            ScenarioAxis::parse("chaos.reclaim-storm=at600-frac1.5").is_err(),
            "frac > 1"
        );
        assert!(ScenarioAxis::parse("chaos.broker-outage=at900-for0").is_err(), "zero dur");
        assert!(
            ScenarioAxis::parse("chaos.demand-surge=at600-vms0-pes4-for600").is_err(),
            "zero vms"
        );
        assert!(ScenarioAxis::parse("market.volatility=-0.1").is_err(), "negative vol");
        assert!(ScenarioAxis::parse("market.volatility=inf").is_err(), "non-finite vol");
        assert!(ScenarioAxis::parse("market.mean-reversion=0").is_err(), "zero reversion");
        assert!(ScenarioAxis::parse("market.daily-amplitude=1.5").is_err(), "amp > 1");
        assert!(ScenarioAxis::parse("market.daily-amplitude=-0.1").is_err(), "amp < 0");
        assert!(ScenarioAxis::parse("market.bid-margin=0").is_err(), "zero margin");
        assert!(ScenarioAxis::parse("market.bid-margin=abc").is_err(), "non-numeric");
    }

    /// Chaos axes expand variants like any other axis: variant-major,
    /// value-minor, fields composing across families.
    #[test]
    fn chaos_axes_expand_and_compose() {
        let storms = vec![
            ReclaimStorm::parse("at600-frac0.25").unwrap(),
            ReclaimStorm::parse("at600-frac1").unwrap(),
        ];
        let outage = BrokerOutage::parse("at900-for300").unwrap();
        let spec = SweepSpec::new(ComparisonConfig::default())
            .with_seeds(vec![1])
            .with_policies(vec![PolicySpec::FirstFit])
            .with_axis(ScenarioAxis::ChaosBrokerOutage(vec![outage]))
            .with_axis(ScenarioAxis::ChaosReclaimStorm(storms.clone()));
        let variants = spec.variants();
        assert_eq!(variants.len(), 2);
        for (v, storm) in variants.iter().zip(&storms) {
            assert_eq!(v.chaos.broker_outage, Some(outage));
            assert_eq!(v.chaos.reclaim_storm, Some(*storm));
            assert!(!v.chaos.is_none());
        }
        assert_eq!(spec.cell_count(), 2);
    }

    /// Market axes expand variants like the chaos axes: variant-major,
    /// value-minor, fields composing across `market.*` families (and with
    /// chaos axes on the same grid).
    #[test]
    fn market_axes_expand_and_compose() {
        let outage = BrokerOutage::parse("at900-for300").unwrap();
        let spec = SweepSpec::new(ComparisonConfig::default())
            .with_seeds(vec![1])
            .with_policies(vec![PolicySpec::FirstFit])
            .with_axis(ScenarioAxis::ChaosBrokerOutage(vec![outage]))
            .with_axis(ScenarioAxis::MarketBidMargin(vec![0.5]))
            .with_axis(ScenarioAxis::MarketVolatility(vec![0.05, 0.2]));
        let variants = spec.variants();
        assert_eq!(variants.len(), 2);
        for (v, vol) in variants.iter().zip(&[0.05, 0.2]) {
            assert_eq!(v.chaos.broker_outage, Some(outage));
            assert_eq!(v.market.bid_margin, Some(0.5));
            assert_eq!(v.market.volatility, Some(*vol));
            assert!(!v.market.is_none());
        }
        assert_eq!(spec.cell_count(), 2);
    }

    #[test]
    fn spot_override_applies_set_fields_only() {
        let base = SpotConfig::hibernate().with_warning(2.0).with_hibernation_timeout(900.0);
        let over = SpotOverride {
            warning_time: Some(60.0),
            hibernation_timeout: None,
            behavior: Some(InterruptionBehavior::Terminate),
        };
        let cfg = over.apply_to(base);
        assert_eq!(cfg.warning_time, 60.0);
        assert_eq!(cfg.hibernation_timeout, 900.0);
        assert_eq!(cfg.behavior, InterruptionBehavior::Terminate);
        assert!(SpotOverride::NONE.is_none());
        assert!(!over.is_none());
        assert_eq!(SpotOverride::NONE.apply_to(base), base);
    }

    #[test]
    fn series_filter_parses_and_matches() {
        let cell = Cell {
            id: 3,
            seed: 11,
            spec: CellSpec::comparison(PolicySpec::Hlem { adjusted: true, alpha: -0.5 }),
        };
        assert!(SeriesFilter::all().matches(&cell));
        assert!(!SeriesFilter::none().matches(&cell));
        assert!(SeriesFilter::none().is_none());
        let f = SeriesFilter::parse("policy=hlem-vmp-adjusted,seed=99").unwrap();
        assert!(f.matches(&cell), "policy clause matches");
        let f = SeriesFilter::parse("seed=11").unwrap();
        assert!(f.matches(&cell));
        let f = SeriesFilter::parse("id=4").unwrap();
        assert!(!f.matches(&cell));
        let f = SeriesFilter::parse("substrate=trace").unwrap();
        assert!(!f.matches(&cell));
        assert_eq!(SeriesFilter::parse("none").unwrap(), SeriesFilter::none());
        assert_eq!(SeriesFilter::parse("all").unwrap(), SeriesFilter::all());
        assert!(SeriesFilter::parse("bogus=1").is_err());
        assert!(SeriesFilter::parse("seed=abc").is_err());
        assert!(SeriesFilter::parse("policy").is_err(), "clause without =");
        assert!(
            SeriesFilter::parse("policy=hlem-adjusted").is_err(),
            "policy typos must fail at parse time, not retain nothing"
        );
    }

    /// Every publicly-constructible filter round-trips through its
    /// `spec_string` (the shard-file wire form).
    #[test]
    fn series_filter_spec_string_round_trips() {
        for src in [
            "none",
            "all",
            "policy=first-fit",
            "policy=hlem-vmp-adjusted,seed=99",
            "seed=11,id=4,substrate=trace",
            "substrate=comparison",
        ] {
            let f = SeriesFilter::parse(src).unwrap();
            assert_eq!(SeriesFilter::parse(&f.spec_string()).unwrap(), f, "via {src}");
        }
        assert_eq!(SeriesFilter::none().spec_string(), "none");
        assert_eq!(SeriesFilter::all().spec_string(), "all");
    }

    #[test]
    fn variant_labels_are_compact() {
        assert_eq!(CellSpec::comparison(PolicySpec::FirstFit).variant_label(), "-");
        let spec = CellSpec {
            substrate: Substrate::Trace,
            policy: PolicySpec::FirstFit,
            spot: SpotOverride { warning_time: Some(60.0), ..SpotOverride::NONE },
            victim: Some(VictimPolicy::Youngest),
            chaos: ChaosSpec::NONE,
            market: MarketSpec::NONE,
            recovery: RecoverySpec::NONE,
        };
        assert_eq!(spec.variant_label(), "trace warn=60 victim=youngest");
        // Chaos axis values label with their canonical parse grammar.
        let mut chaotic = CellSpec::comparison(PolicySpec::FirstFit);
        chaotic.chaos.reclaim_storm = Some(ReclaimStorm::parse("at1200-frac0.5").unwrap());
        assert_eq!(chaotic.variant_label(), "storm=at1200-frac0.5");
        // Market values label with shortest-f64 Display, so the label
        // parses back to the exact same value.
        let mut market = CellSpec::comparison(PolicySpec::FirstFit);
        market.market.volatility = Some(0.05);
        market.market.bid_margin = Some(0.5);
        assert_eq!(market.variant_label(), "vol=0.05 bid=0.5");
        // Adjusted-HLEM rows always carry their alpha, so an hlem.alpha
        // axis stays readable in the aggregate table and progress lines.
        let adj = CellSpec::comparison(PolicySpec::Hlem { adjusted: true, alpha: -0.3 });
        assert_eq!(adj.variant_label(), "alpha=-0.30");
        // Recovery values label like the market axes: mode vocabulary plus
        // shortest-f64 Display for the numeric knobs.
        let mut rec = CellSpec::comparison(PolicySpec::FirstFit);
        rec.recovery.mode = Some(RecoveryMode::MigrateOptimal);
        rec.recovery.bandwidth = Some(128.0);
        rec.recovery.checkpoint_threshold = Some(0.25);
        assert_eq!(rec.variant_label(), "rec=migrate-optimal bw=128 ckpt=0.25");
    }

    /// Recovery axes parse, expand and compose like the chaos/market axes.
    #[test]
    fn recovery_axes_parse_expand_and_compose() {
        assert_eq!(
            ScenarioAxis::parse("recovery.mode=none,restart,checkpoint,migrate-greedy,migrate-optimal")
                .unwrap(),
            ScenarioAxis::RecoveryMode(vec![
                RecoveryMode::None,
                RecoveryMode::Restart,
                RecoveryMode::Checkpoint,
                RecoveryMode::MigrateGreedy,
                RecoveryMode::MigrateOptimal,
            ])
        );
        assert_eq!(
            ScenarioAxis::parse("recovery.bandwidth=50,200").unwrap(),
            ScenarioAxis::RecoveryBandwidth(vec![50.0, 200.0])
        );
        assert_eq!(
            ScenarioAxis::parse("recovery.checkpoint-threshold=0,0.25,1").unwrap(),
            ScenarioAxis::RecoveryCheckpointThreshold(vec![0.0, 0.25, 1.0])
        );
        assert!(ScenarioAxis::parse("recovery.mode=teleport").is_err(), "unknown mode");
        assert!(ScenarioAxis::parse("recovery.bandwidth=0").is_err(), "zero bandwidth");
        assert!(ScenarioAxis::parse("recovery.bandwidth=-5").is_err(), "negative bandwidth");
        assert!(
            ScenarioAxis::parse("recovery.checkpoint-threshold=1.5").is_err(),
            "threshold > 1"
        );

        let spec = SweepSpec::new(ComparisonConfig::default())
            .with_seeds(vec![1])
            .with_policies(vec![PolicySpec::FirstFit])
            .with_axis(ScenarioAxis::RecoveryBandwidth(vec![100.0]))
            .with_axis(ScenarioAxis::RecoveryMode(vec![
                RecoveryMode::Restart,
                RecoveryMode::Checkpoint,
            ]));
        let variants = spec.variants();
        assert_eq!(variants.len(), 2);
        for (v, mode) in variants.iter().zip(&[RecoveryMode::Restart, RecoveryMode::Checkpoint]) {
            assert_eq!(v.recovery.bandwidth, Some(100.0));
            assert_eq!(v.recovery.mode, Some(*mode));
            assert!(!v.recovery.is_none());
        }
        assert_eq!(spec.cell_count(), 2);
    }
}
