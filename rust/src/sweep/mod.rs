//! Parallel multi-scenario sweeps: deterministic fan-out of a declarative
//! multi-axis cell grid (substrate × spot config × policy/HLEM-alpha ×
//! victim policy × seed) over a worker pool.
//!
//! The paper's §VII-E claims (fewer spot interruptions, shorter maximum
//! interruption duration under HLEM-VMP) are statistical - they only hold
//! across many seeds and configurations, and its sensitivity arguments
//! live in scenario variations (spot lifecycle settings, workload
//! substrates, alpha tuning). The engine itself is single-threaded by
//! design (DES determinism), so the scaling win is *across* runs: every
//! `Engine`/`World` is self-contained, which makes cells embarrassingly
//! parallel.
//!
//! # Module index
//!
//! - [`grid`]: [`SweepSpec`] → [`Cell`] enumeration. The policy list
//!   ([`PolicySpec`] values, built only inside the worker that runs the
//!   cell) is multiplied by declared [`ScenarioAxis`] values into
//!   [`CellSpec`] variants - spot warning/hibernation-timeout/behavior
//!   grids, adjusted-HLEM alpha ranges, victim-policy ablations, the
//!   workload [`Substrate`] (§VII-E comparison template or §VII-D trace
//!   simulation), and the four `chaos.*` fault families of
//!   [`crate::chaos`] (host MTBF/MTTR, reclaim storms, broker outages,
//!   demand surges) - then crossed with seeds (seed-major) plus explicit
//!   extra cells. A [`SeriesFilter`] says which cells keep their sampled
//!   time series.
//! - [`prebuild`]: shared read-only workload prebuilds keyed per
//!   (substrate, seed): the randomized Table II/III workload resolved once
//!   per seed (`config::scenario::WorkloadPlan`, shared across spot/alpha
//!   variants via `apply_with_spot`), and the generated synthetic
//!   cluster trace for `trace_sim` cells. Prebuilds are **lazy**: a
//!   [`PrebuildSlots`] table (one `OnceLock` per pair, sized from the
//!   grid up front) lets the first worker that needs a pair build it
//!   while the rest of the pool keeps running cells - no serial prebuild
//!   prefix.
//! - [`driver`]: the worker pool. A shared atomic cursor over the cell
//!   list distributes work (self-balancing, allocation-free); each cell
//!   runs inside `catch_unwind` so a panicking cell fails alone; an
//!   optional progress callback reports completed cells. Per-cell engines
//!   run the standard [`crate::engine::progress`] backend untouched, but
//!   recycle each worker's [`crate::engine::EngineScratch`] (recorder,
//!   event queue, progress arrays) across cells. [`run_with_timing`]
//!   exposes the phase breakdown (prebuild/cell/merge wall time) the perf
//!   benches record; see `docs/perf.md` for the full hot-path guide.
//! - [`report`]: per-cell `Report` rows plus grid-level aggregates grouped
//!   by scenario variant (reusing [`crate::stats::Summary`]), with axis
//!   values as dedicated CSV columns / JSON fields, exported through
//!   `util::csv` / `util::json`; retained per-cell series export for
//!   Fig-13-style curves across the grid.
//! - [`shard`]: process-level fan-out on top of the same Cell/merge
//!   contract - cost-weighted shard job files, a `cloudmarket sweep
//!   worker` subcommand emitting self-contained partial artifacts, a
//!   validating merge ([`merge_partials`], also `cloudmarket sweep
//!   merge` for cluster use), and a same-host [`coordinate`] that spawns
//!   worker subprocesses and reassigns shards from crashed workers
//!   (`cloudmarket sweep --workers N`). Merged artifacts stay
//!   byte-identical to the single-process run; `tests/sweep_process.rs`
//!   pins this across real subprocesses, including after a worker is
//!   killed mid-shard.
//!
//! # Determinism (§Perf: sweep fan-out)
//!
//! Results are merged by cell id, and the serialized artifacts exclude
//! everything nondeterministic (wall times, thread counts), so a sweep's
//! aggregate output is **bit-identical regardless of thread count**,
//! including `--threads 1` - and this holds for mixed-axis grids spanning
//! both substrates. `tests/sweep_determinism.rs` pins this, and
//! `experiments::compare::run_multi` is implemented on top of this driver
//! with the exact float-accumulation order of its pre-sweep sequential
//! loop (axis-free grids enumerate exactly the pre-axis seeds × policies
//! cells). Sweep throughput (cells/sec) at 1 vs N threads is measured by
//! `benches/perf_sweep.rs`, which writes `BENCH_sweep.json` at the repo
//! root (CI regenerates and validates it next to `BENCH_engine.json`).
//!
//! Entry points: `cloudmarket sweep --threads N --seeds K --policies ...
//! --axis spot.warning=60,120 --substrate comparison,trace
//! --retain-series policy=hlem-vmp-adjusted` on the CLI, or
//! [`driver::run`] / [`driver::run_with_progress`] from code.
//!
//! Runnable recipes for every axis - and which paper figure each
//! reproduces - live in `docs/sweep-cookbook.md`; the full flag reference
//! is `docs/cli.md`.

pub mod driver;
pub mod grid;
pub mod prebuild;
pub mod report;
pub mod shard;

pub use driver::{
    default_threads, run, run_cells, run_cells_observed, run_observed, run_with_progress,
    run_with_timing, SweepTiming,
};
pub use grid::{
    Cell, CellSpec, PolicySpec, ScenarioAxis, SeriesFilter, SpotOverride, Substrate, SweepSpec,
    TraceSubstrate,
};
pub use prebuild::{
    build_prebuilt, ChaosSlots, MarketSlots, Prebuilt, PrebuildCache, PrebuildSlots, RecoverySlots,
};
pub use report::{CellResult, SweepReport, VariantAggregate};
pub use shard::{
    coordinate, merge_partials, partition, CoordinateOptions, CoordinateOutcome, Partial, Shard,
    EXIT_BAD_SHARD, EXIT_PARENT_GONE, EXIT_RUNTIME,
};
