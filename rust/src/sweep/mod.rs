//! Parallel multi-scenario sweeps: deterministic fan-out of a declarative
//! cell grid (scenario × seed × policy/HLEM-knob) over a worker pool.
//!
//! The paper's §VII-E claims (fewer spot interruptions, shorter maximum
//! interruption duration under HLEM-VMP) are statistical - they only hold
//! across many seeds and configurations. The engine itself is
//! single-threaded by design (DES determinism), so the scaling win is
//! *across* runs: every `Engine`/`World` is self-contained, which makes
//! cells embarrassingly parallel.
//!
//! # Module index
//!
//! - [`grid`]: [`SweepSpec`] → [`Cell`] enumeration. Cartesian product
//!   `seeds × policies` (seed-major) plus explicit extra cells; policies
//!   are plain-data [`PolicySpec`] values built only inside the worker
//!   that runs the cell.
//! - [`prebuild`]: shared read-only workload prebuilds. The randomized
//!   Table II/III workload is resolved once per seed
//!   (`config::scenario::WorkloadPlan`) and shared across that seed's
//!   cells via `Arc` instead of being regenerated per cell.
//! - [`driver`]: the worker pool. A shared atomic cursor over the cell
//!   list distributes work (self-balancing, allocation-free); each cell
//!   runs inside `catch_unwind` so a panicking cell fails alone; an
//!   optional progress callback reports completed cells. Per-cell engines
//!   run the standard [`crate::engine::progress`] backend untouched.
//! - [`report`]: per-cell `Report` rows plus grid-level aggregates
//!   (reusing [`crate::stats::Summary`]), exported as CSV/JSON through
//!   `util::csv` / `util::json`.
//!
//! # Determinism (§Perf: sweep fan-out)
//!
//! Results are merged by cell id, and the serialized artifacts exclude
//! everything nondeterministic (wall times, thread counts), so a sweep's
//! aggregate output is **bit-identical regardless of thread count**,
//! including `--threads 1`. `tests/sweep_determinism.rs` pins this, and
//! `experiments::compare::run_multi` is implemented on top of this driver
//! with the exact float-accumulation order of its pre-sweep sequential
//! loop. Sweep throughput (cells/sec) at 1 vs N threads is measured by
//! `benches/perf_sweep.rs`, which writes `BENCH_sweep.json` at the repo
//! root (CI regenerates and validates it next to `BENCH_engine.json`).
//!
//! Entry points: `cloudmarket sweep --threads N --seeds K --policies ...`
//! on the CLI, or [`driver::run`] / [`driver::run_with_progress`] from
//! code.

pub mod driver;
pub mod grid;
pub mod prebuild;
pub mod report;

pub use driver::{default_threads, run, run_with_progress};
pub use grid::{Cell, PolicySpec, SweepSpec};
pub use prebuild::PrebuildCache;
pub use report::{CellResult, PolicyAggregate, SweepReport};
