//! The sweep driver: fans cells out over a fixed-size worker pool and
//! merges results deterministically.
//!
//! Work distribution is a shared atomic cursor over the cell list
//! (self-balancing: fast workers simply claim more cells), so no
//! work-queue allocation or channel is needed. Each worker builds its own
//! `Engine` per cell (policies and engines are thread-local; only the
//! `Arc`-shared workload prebuilds cross threads), runs it to completion
//! inside `catch_unwind`, and reports a [`CellResult`]. A panicking cell
//! therefore fails alone - the rest of the grid still completes.
//!
//! # Lazy worker-side prebuilds (§Perf)
//!
//! Workload prebuilds are **not** resolved up front: a
//! [`PrebuildSlots`](super::prebuild::PrebuildSlots) table (one `OnceLock`
//! slot per distinct (substrate, seed) pair, sized from the grid before
//! the pool starts) lets the first worker that needs a pair build it
//! while the other workers keep executing cells. The pre-overhaul driver
//! paid the whole prebuild cost as a serial prefix on the caller thread -
//! for trace-substrate grids, per-seed trace generation dominated the
//! run's start-up. Prebuilds are deterministic in (substrate, seed), so
//! which worker wins a race never shows in the artifacts.
//!
//! # Per-worker scratch (§Perf)
//!
//! Each worker threads one [`EngineScratch`] through its cells: recorder,
//! event queue, progress arrays and the engine's scratch buffers are
//! reset between cells instead of reallocated. A panicking cell forfeits
//! its scratch (it unwinds with the engine); the worker just starts a
//! fresh one.
//!
//! A cell's [`CellSpec`](super::grid::CellSpec) selects the substrate
//! (§VII-E comparison plan vs §VII-D trace simulation), the policy (with
//! per-cell victim-policy and adjusted-alpha values), and the spot-config
//! overrides applied on top of the substrate's base `SpotConfig`. Cells
//! matching the spec's retention filter additionally keep their sampled
//! time series (Fig-13-style curves) in the result.
//!
//! The merge is by cell id, so the assembled [`SweepReport`] - and every
//! artifact serialized from it - is bit-identical regardless of thread
//! count (including `threads == 1`). [`run_with_timing`] additionally
//! returns a [`SweepTiming`] phase breakdown (wall, prebuild-busy,
//! cell-busy, merge, first-cell-done) for the benches; timing never
//! enters the serialized artifacts.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::chaos::ChaosSchedule;
use crate::engine::{Engine, EngineScratch};
use crate::market::MarketSchedule;
use crate::obs::{telemetry as tel, EngineCounters, Telemetry};
use crate::recovery::RecoverySchedule;
use crate::trace::workload::{self, trace_engine_config};

use super::grid::{Cell, Substrate, SweepSpec};
use super::prebuild::{
    panic_message, ChaosSlots, MarketSlots, Prebuilt, PrebuildSlots, RecoverySlots,
};
use super::report::{CellResult, SweepReport};

/// Worker threads to use when the caller does not care: one per available
/// CPU (the engine itself stays single-threaded by design - DES
/// determinism - so the win is across cells).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Progress callback: `(cells_done, cells_total, just_finished_cell)`.
/// Invoked from worker threads (must be `Sync`).
pub type ProgressFn<'a> = &'a (dyn Fn(usize, usize, &CellResult) + Sync);

/// Wall-clock phase breakdown of one driver run. Observability only -
/// never serialized into sweep artifacts, which must stay byte-identical
/// across thread counts and machines.
#[derive(Debug, Clone, Copy)]
pub struct SweepTiming {
    /// End-to-end wall time of the whole run.
    pub wall: Duration,
    /// Summed worker time spent building lazy prebuilds (overlaps with
    /// cell execution on other workers, so this can exceed any serial
    /// prefix visible in `wall`).
    pub prebuild_busy: Duration,
    /// Summed worker time spent executing cells.
    pub cell_busy: Duration,
    /// Deterministic cell-id merge time (after the pool joins).
    pub merge: Duration,
    /// Wall time from run start until the first cell finished - the
    /// effective serial prefix. With lazy prebuilds this is roughly one
    /// prebuild plus one cell even on grids with hundreds of
    /// (substrate, seed) pairs.
    pub first_cell_done: Duration,
    /// Distinct (substrate, seed) prebuilds actually built.
    pub prebuilds_built: usize,
}

/// Run the sweep on `threads` workers (clamped to `1..=cells`).
pub fn run(spec: &SweepSpec, threads: usize) -> SweepReport {
    run_instrumented(spec, threads, None, None).0
}

/// [`run`], reporting each finished cell to `on_cell`.
pub fn run_with_progress(
    spec: &SweepSpec,
    threads: usize,
    on_cell: Option<ProgressFn<'_>>,
) -> SweepReport {
    run_instrumented(spec, threads, on_cell, None).0
}

/// [`run`], also returning the phase-timing breakdown (bench support).
pub fn run_with_timing(spec: &SweepSpec, threads: usize) -> (SweepReport, SweepTiming) {
    run_instrumented(spec, threads, None, None)
}

/// [`run_with_progress`] + [`run_with_timing`], additionally streaming
/// per-cell spans, prebuild events and engine counters to the telemetry
/// sidecar. The report is byte-identical to the unobserved entry points -
/// telemetry is written on the side, never threaded into results.
pub fn run_observed(
    spec: &SweepSpec,
    threads: usize,
    on_cell: Option<ProgressFn<'_>>,
    telemetry: Option<&Telemetry>,
) -> (SweepReport, SweepTiming) {
    run_instrumented(spec, threads, on_cell, telemetry)
}

/// Run exactly `cells` (a subset of `spec`'s enumeration, e.g. one
/// process shard from [`super::shard::partition`]) on `threads` workers,
/// returning one [`CellResult`] per input cell **in input order**. The
/// same pool, lazy-prebuild and panic-isolation machinery as [`run`];
/// `sweep worker` subprocesses are built on this.
pub fn run_cells(
    spec: &SweepSpec,
    cells: &[Cell],
    threads: usize,
    on_cell: Option<ProgressFn<'_>>,
) -> Vec<CellResult> {
    run_cells_instrumented(spec, cells, threads, on_cell, None).0
}

/// [`run_cells`] with a telemetry sidecar (see [`run_observed`]).
pub fn run_cells_observed(
    spec: &SweepSpec,
    cells: &[Cell],
    threads: usize,
    on_cell: Option<ProgressFn<'_>>,
    telemetry: Option<&Telemetry>,
) -> (Vec<CellResult>, SweepTiming) {
    run_cells_instrumented(spec, cells, threads, on_cell, telemetry)
}

fn run_instrumented(
    spec: &SweepSpec,
    threads: usize,
    on_cell: Option<ProgressFn<'_>>,
    telemetry: Option<&Telemetry>,
) -> (SweepReport, SweepTiming) {
    let cells = spec.cells();
    let threads = threads.max(1).min(cells.len().max(1));
    let (results, timing) = run_cells_instrumented(spec, &cells, threads, on_cell, telemetry);
    (SweepReport { cells: results, threads }, timing)
}

fn run_cells_instrumented(
    spec: &SweepSpec,
    cells: &[Cell],
    threads: usize,
    on_cell: Option<ProgressFn<'_>>,
    telemetry: Option<&Telemetry>,
) -> (Vec<CellResult>, SweepTiming) {
    let start = Instant::now();
    let total = cells.len();

    // Lazy shared prebuilds: the slot table is sized from the grid here;
    // the builds themselves happen worker-side, overlapped with cell
    // execution. Build panics are caught per slot and surface as each
    // affected cell's error row instead of aborting the sweep - the same
    // isolation contract the workers give running cells.
    let slots = PrebuildSlots::for_cells(cells);
    // Compiled chaos schedules share the same lazy-slot pattern, keyed
    // per (substrate, seed, chaos spec) triple; chaos-free grids size an
    // empty table and pay nothing.
    let chaos_slots = ChaosSlots::for_cells(cells);
    // Compiled spot-price paths likewise, keyed per
    // (substrate, seed, market spec) triple.
    let market_slots = MarketSlots::for_cells(cells);
    // Compiled recovery parameter blocks likewise, keyed per
    // (substrate, seed, recovery spec) triple.
    let recovery_slots = RecoverySlots::for_cells(cells);

    let threads = threads.max(1).min(total.max(1));
    let next = AtomicUsize::new(0);
    let done = AtomicUsize::new(0);
    let prebuild_ns = AtomicU64::new(0);
    let cell_ns = AtomicU64::new(0);
    let first_done_ns = AtomicU64::new(u64::MAX);

    let mut result_slots: Vec<Option<CellResult>> = Vec::with_capacity(total);
    result_slots.resize_with(total, || None);

    std::thread::scope(|scope| {
        let slots = &slots;
        let chaos_slots = &chaos_slots;
        let market_slots = &market_slots;
        let recovery_slots = &recovery_slots;
        let next = &next;
        let done = &done;
        let prebuild_ns = &prebuild_ns;
        let cell_ns = &cell_ns;
        let first_done_ns = &first_done_ns;
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                scope.spawn(move || {
                    let mut out: Vec<(usize, CellResult)> = Vec::new();
                    let mut scratch = EngineScratch::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= total {
                            break;
                        }
                        if let Some(t) = telemetry {
                            t.emit(tel::cell_start(
                                cells[i].id,
                                cells[i].seed,
                                &cells[i].spec.variant_label(),
                            ));
                        }
                        let prebuilt = slots.get_with(spec, i, &cells[i], |took| {
                            prebuild_ns
                                .fetch_add(took.as_nanos() as u64, Ordering::Relaxed);
                            if let Some(t) = telemetry {
                                t.emit(tel::prebuild(
                                    cells[i].id,
                                    took.as_secs_f64() * 1e3,
                                ));
                            }
                        });
                        let (result, counters, cell_ms) = match prebuilt {
                            Ok(prebuilt) => {
                                let chaos = chaos_slots
                                    .get(spec, i, &cells[i], prebuilt)
                                    .map(Arc::as_ref);
                                let market = market_slots.get(spec, i, &cells[i], prebuilt);
                                let recovery =
                                    recovery_slots.get(spec, i, &cells[i], prebuilt);
                                let t0 = Instant::now();
                                let (result, returned) = run_cell(
                                    spec, &cells[i], prebuilt, chaos, market, recovery, scratch,
                                );
                                scratch = returned;
                                let elapsed = t0.elapsed();
                                cell_ns.fetch_add(
                                    elapsed.as_nanos() as u64,
                                    Ordering::Relaxed,
                                );
                                (result, scratch.counters(), elapsed.as_secs_f64() * 1e3)
                            }
                            Err(e) => (
                                CellResult {
                                    cell: cells[i],
                                    outcome: Err(e.clone()),
                                    series: None,
                                },
                                EngineCounters::default(),
                                0.0,
                            ),
                        };
                        if let Some(t) = telemetry {
                            t.emit(tel::cell_end(
                                cells[i].id,
                                result.outcome.is_ok(),
                                cell_ms,
                                &counters,
                            ));
                        }
                        first_done_ns
                            .fetch_min(start.elapsed().as_nanos() as u64, Ordering::Relaxed);
                        let finished = done.fetch_add(1, Ordering::Relaxed) + 1;
                        if let Some(cb) = on_cell {
                            cb(finished, total, &result);
                        }
                        out.push((i, result));
                    }
                    out
                })
            })
            .collect();
        for handle in handles {
            let worker_results =
                handle.join().expect("sweep worker died outside cell isolation");
            for (i, result) in worker_results {
                debug_assert!(result_slots[i].is_none(), "cell {i} ran twice");
                result_slots[i] = Some(result);
            }
        }
    });

    let merge_start = Instant::now();
    let merged: Vec<CellResult> = result_slots
        .into_iter()
        .enumerate()
        .map(|(i, r)| r.unwrap_or_else(|| panic!("cell {i} produced no result")))
        .collect();
    let merge = merge_start.elapsed();
    let first = first_done_ns.load(Ordering::Relaxed);
    let timing = SweepTiming {
        wall: start.elapsed(),
        prebuild_busy: Duration::from_nanos(prebuild_ns.load(Ordering::Relaxed)),
        cell_busy: Duration::from_nanos(cell_ns.load(Ordering::Relaxed)),
        merge,
        first_cell_done: if first == u64::MAX { Duration::ZERO } else { Duration::from_nanos(first) },
        prebuilds_built: slots.built(),
    };
    (merged, timing)
}

/// Run one cell to completion on the worker's recycled scratch; panics
/// inside the cell become `Err` rows (and forfeit the scratch, which
/// unwinds with the engine - the caller gets a fresh one back).
fn run_cell(
    spec: &SweepSpec,
    cell: &Cell,
    prebuilt: &Prebuilt,
    chaos: Option<&ChaosSchedule>,
    market: Option<&Arc<MarketSchedule>>,
    recovery: Option<&Arc<RecoverySchedule>>,
    scratch: EngineScratch,
) -> (CellResult, EngineScratch) {
    let retain = spec.retain.matches(cell);
    let outcome = catch_unwind(AssertUnwindSafe(move || {
        let policy = cell.spec.policy.build_with_victim(cell.spec.victim);
        let mut engine = match (cell.spec.substrate, prebuilt) {
            (Substrate::Comparison, Prebuilt::Comparison(plan)) => {
                let mut engine = Engine::with_scratch(spec.engine.clone(), policy, scratch);
                plan.apply_with_spot(&mut engine, cell.spec.spot.apply_to(plan.spot));
                engine
            }
            (Substrate::Trace, Prebuilt::Trace(trace)) => {
                let mut engine = Engine::with_scratch(
                    trace_engine_config(spec.trace.sample_interval),
                    policy,
                    scratch,
                );
                let mut wl = spec.trace.workload.clone();
                wl.seed = cell.seed;
                wl.spot = cell.spec.spot.apply_to(wl.spot);
                workload::build(&mut engine, trace, &wl);
                engine.terminate_at(trace.horizon);
                engine
            }
            (substrate, _) => unreachable!(
                "prebuilt kind does not match cell substrate {substrate:?} (driver bug)"
            ),
        };
        // Inject after the workload is fully submitted: the schedule is
        // pure data, so this only enqueues events (plus surge VMs).
        if let Some(sched) = chaos {
            crate::chaos::apply(&mut engine, sched);
        }
        if let Some(sched) = market {
            crate::market::apply(&mut engine, sched);
        }
        if let Some(sched) = recovery {
            crate::recovery::apply(&mut engine, sched);
        }
        let report = engine.run();
        let series = if retain { Some(engine.recorder.take_series()) } else { None };
        (report, series, engine.into_scratch())
    }));
    match outcome {
        Ok((report, series, scratch)) => {
            (CellResult { cell: *cell, outcome: Ok(report), series }, scratch)
        }
        Err(payload) => (
            CellResult { cell: *cell, outcome: Err(panic_message(payload)), series: None },
            EngineScratch::new(),
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::scenario::ComparisonConfig;
    use crate::sweep::grid::{PolicySpec, ScenarioAxis, SeriesFilter};

    #[test]
    fn panicking_cells_fail_alone() {
        // An invalid engine config makes Engine::new panic inside every
        // cell; the driver must survive and report each failure.
        let mut spec = SweepSpec::new(ComparisonConfig::default())
            .with_seeds(vec![1])
            .with_policies(vec![PolicySpec::FirstFit, PolicySpec::BestFit]);
        spec.engine.scheduling_interval = 0.0;
        let report = run(&spec, 2);
        assert_eq!(report.total(), 2);
        assert_eq!(report.failed(), 2);
        for cell in &report.cells {
            let err = cell.outcome.as_ref().err().expect("cell must have failed");
            assert!(err.contains("invalid engine config"), "unexpected error: {err}");
        }
    }

    #[test]
    fn thread_count_is_clamped_and_recorded() {
        let mut spec = SweepSpec::new(ComparisonConfig::default())
            .with_seeds(vec![1])
            .with_policies(vec![PolicySpec::FirstFit]);
        // Keep the single cell cheap: it still fails fast on purpose.
        spec.engine.sample_interval = -1.0;
        let report = run(&spec, 64);
        assert_eq!(report.threads, 1, "threads are clamped to the cell count");
        assert_eq!(report.total(), 1);
    }

    /// Retention keeps series only for matching cells; failed cells never
    /// carry one.
    #[test]
    fn series_are_retained_per_filter() {
        let scenario = ComparisonConfig { terminate_at: 300.0, ..Default::default() };
        let spec = SweepSpec::new(scenario)
            .with_seeds(vec![20_250_710])
            .with_policies(vec![PolicySpec::FirstFit, PolicySpec::BestFit])
            .with_series_retention(SeriesFilter::parse("policy=first-fit").unwrap());
        let report = run(&spec, 2);
        assert_eq!(report.failed(), 0);
        let ff = &report.cells[0];
        assert_eq!(ff.cell.policy(), PolicySpec::FirstFit);
        let series = ff.series.as_ref().expect("first-fit cell retains its series");
        assert!(!series.is_empty(), "retained series has samples");
        assert!(report.cells[1].series.is_none(), "non-matching cell retains nothing");
    }

    /// A broken prebuild template (trace generator rejects 0 machines)
    /// becomes per-cell error rows, not a sweep-wide abort - including
    /// with lazy worker-side prebuilds.
    #[test]
    fn prebuild_panics_become_cell_errors() {
        let mut spec = SweepSpec::new(ComparisonConfig::default())
            .with_seeds(vec![1, 2])
            .with_policies(vec![PolicySpec::FirstFit])
            .with_axis(ScenarioAxis::Substrate(vec![crate::sweep::Substrate::Trace]));
        spec.trace.synth.machines = 0; // TraceGenerator::new asserts machines > 0
        let report = run(&spec, 2);
        assert_eq!(report.total(), 2);
        assert_eq!(report.failed(), 2);
        for cell in &report.cells {
            let err = cell.outcome.as_ref().err().expect("cell must have failed");
            assert!(err.contains("workload prebuild failed"), "unexpected error: {err}");
        }
    }

    /// A one-cell trace-substrate grid runs end to end on the driver.
    #[test]
    fn trace_substrate_cells_run() {
        let mut spec = SweepSpec::new(ComparisonConfig::default())
            .with_seeds(vec![42])
            .with_policies(vec![PolicySpec::FirstFit])
            .with_axis(ScenarioAxis::Substrate(vec![crate::sweep::Substrate::Trace]));
        spec.trace.synth.machines = 10;
        spec.trace.synth.days = 0.05;
        spec.trace.synth.tasks_per_hour = 120.0;
        spec.trace.workload.spot_instances = 20;
        spec.trace.workload.spot_durations = vec![300.0, 600.0];
        spec.trace.workload.max_trace_vms = 50;
        let report = run(&spec, 1);
        assert_eq!(report.total(), 1);
        assert_eq!(report.failed(), 0, "trace cell failed: {:?}", report.cells[0].outcome);
        let r = report.cells[0].report().unwrap();
        assert_eq!(r.spot.total_spot, 20);
        assert!(r.events_processed > 0);
    }

    /// `run_cells` runs exactly the given subset, returns results in
    /// input order, and each result bit-matches the same cell out of a
    /// full-grid `run` (the process-shard contract).
    #[test]
    fn run_cells_subset_matches_full_run() {
        let scenario = ComparisonConfig { terminate_at: 300.0, ..Default::default() };
        let spec = SweepSpec::new(scenario)
            .with_seeds(vec![20_250_710, 20_250_711])
            .with_policies(vec![PolicySpec::FirstFit, PolicySpec::BestFit]);
        let full = run(&spec, 2);
        let cells = spec.cells();
        let subset = [cells[3], cells[0]]; // deliberately out of id order
        let results = run_cells(&spec, &subset, 2, None);
        assert_eq!(results.len(), 2);
        assert_eq!(results[0].cell.id, 3, "results come back in input order");
        assert_eq!(results[1].cell.id, 0);
        for r in &results {
            let want = full.cells[r.cell.id].report().unwrap();
            let got = r.report().unwrap();
            assert_eq!(got.spot.interruptions, want.spot.interruptions);
            assert_eq!(got.clock_end.to_bits(), want.clock_end.to_bits());
            assert_eq!(got.events_processed, want.events_processed);
        }
    }

    /// A chaos axis threads through the driver end to end: the reclaim
    /// storm fires, resilience metrics land in the cell reports, and a
    /// frac=1 storm interrupts at least as much as a frac-0.25 one.
    #[test]
    fn chaos_axis_cells_run_with_resilience_metrics() {
        use crate::chaos::ReclaimStorm;
        let scenario = ComparisonConfig { terminate_at: 300.0, ..Default::default() };
        let spec = SweepSpec::new(scenario)
            .with_seeds(vec![20_250_710])
            .with_policies(vec![PolicySpec::FirstFit])
            .with_axis(ScenarioAxis::ChaosReclaimStorm(vec![
                ReclaimStorm::parse("at150-frac0.25").unwrap(),
                ReclaimStorm::parse("at150-frac1").unwrap(),
            ]));
        let report = run(&spec, 2);
        assert_eq!(report.total(), 2);
        assert_eq!(report.failed(), 0, "chaos cell failed: {:?}", report.cells);
        let quarter = report.cells[0].report().unwrap();
        let full = report.cells[1].report().unwrap();
        for r in [quarter, full] {
            assert_eq!(r.resilience.storms, 1, "{r:?}");
            assert!(r.resilience.storm_reclaims > 0, "{r:?}");
            assert_eq!(
                r.resilience.interruptions_per_storm,
                r.resilience.storm_reclaims as f64
            );
        }
        assert!(full.resilience.storm_reclaims >= quarter.resilience.storm_reclaims);
    }

    /// A market axis threads through the driver end to end: the price
    /// path compiles, crossings fire, and cost stats land in the cell
    /// reports (high volatility reclaims at least as often as zero
    /// volatility, which never crosses a bid above the mean).
    #[test]
    fn market_axis_cells_run_with_cost_metrics() {
        let scenario = ComparisonConfig { terminate_at: 600.0, ..Default::default() };
        let spec = SweepSpec::new(scenario)
            .with_seeds(vec![20_250_710])
            .with_policies(vec![PolicySpec::FirstFit])
            .with_axis(ScenarioAxis::MarketBidMargin(vec![1.5]))
            .with_axis(ScenarioAxis::MarketVolatility(vec![0.0, 2.0]));
        let report = run(&spec, 2);
        assert_eq!(report.total(), 2);
        assert_eq!(report.failed(), 0, "market cell failed: {:?}", report.cells);
        let calm = report.cells[0].report().unwrap();
        let wild = report.cells[1].report().unwrap();
        for r in [calm, wild] {
            assert!(r.market.spot_cost_usd > 0.0, "spots ran, so they accrued cost: {r:?}");
            assert!(r.market.on_demand_cost_usd > 0.0, "{r:?}");
            assert!(r.market.mean_price_paid > 0.0, "{r:?}");
            assert!(r.market.max_price_paid >= r.market.mean_price_paid, "{r:?}");
        }
        // A zero-volatility path follows the daily mean (peak 0.5), far
        // under both the 1.5x on-demand bid and the on-demand price.
        assert_eq!(calm.market.price_reclaims, 0, "flat path stays under a 1.5x on-demand bid");
        assert!(calm.market.on_demand_cost_usd > calm.market.spot_cost_usd);
        assert!(calm.market.savings_ratio > 0.0 && calm.market.savings_ratio < 1.0);
        assert!(wild.market.price_reclaims >= calm.market.price_reclaims);
    }

    /// A recovery axis threads through the driver end to end: under a
    /// reclaim storm with terminate-behavior spots, a checkpointing cell
    /// survives warned work while a `rec=none` cell loses everything the
    /// storm touched (no checkpoints, no requeues, zero recovery).
    #[test]
    fn recovery_axis_cells_run_with_work_survival_metrics() {
        use crate::chaos::ReclaimStorm;
        use crate::recovery::RecoveryMode;
        use crate::vm::InterruptionBehavior;
        let scenario = ComparisonConfig { terminate_at: 600.0, ..Default::default() };
        let spec = SweepSpec::new(scenario)
            .with_seeds(vec![20_250_710])
            .with_policies(vec![PolicySpec::FirstFit])
            .with_axis(ScenarioAxis::SpotBehavior(vec![InterruptionBehavior::Terminate]))
            .with_axis(ScenarioAxis::ChaosReclaimStorm(vec![
                ReclaimStorm::parse("at150-frac1").unwrap(),
            ]))
            .with_axis(ScenarioAxis::RecoveryMode(vec![
                RecoveryMode::None,
                RecoveryMode::Checkpoint,
            ]));
        let report = run(&spec, 2);
        assert_eq!(report.total(), 2);
        assert_eq!(report.failed(), 0, "recovery cell failed: {:?}", report.cells);
        let none = report.cells[0].report().unwrap();
        let ckpt = report.cells[1].report().unwrap();
        assert_eq!(none.recovery.checkpoints, 0, "{none:?}");
        assert_eq!(none.recovery.work_recovered_mi, 0.0, "terminated VMs never return");
        assert_eq!(none.recovery.recovered_fraction, 0.0);
        assert!(none.recovery.work_lost_mi > 0.0, "the storm killed in-flight work");
        assert_eq!(none.recovery.requeue_max_s, 0.0, "mode none never requeues");
        assert!(ckpt.recovery.checkpoints > 0, "{ckpt:?}");
        assert!(ckpt.recovery.checkpoint_mb > 0.0);
        assert!(ckpt.recovery.work_recovered_mi > 0.0);
        assert!(ckpt.recovery.recovered_fraction > 0.0);
        assert!(ckpt.recovery.requeue_max_s >= ckpt.recovery.requeue_p50_s);
    }

    /// Market state cannot leak across cells through a recycled worker
    /// scratch: a threads=1 run (one scratch threaded through every cell)
    /// bit-matches per-cell runs on fresh scratches, including the cell
    /// where a market cell is followed by a market-free one.
    #[test]
    fn recycled_scratch_keeps_market_cells_isolated() {
        let scenario = ComparisonConfig { terminate_at: 600.0, ..Default::default() };
        let spec = SweepSpec::new(scenario)
            .with_seeds(vec![20_250_710])
            .with_policies(vec![PolicySpec::FirstFit, PolicySpec::BestFit])
            .with_axis(ScenarioAxis::MarketVolatility(vec![2.0]))
            // Market-free cells after market ones exercise the reset path.
            .with_cell(20_250_710, PolicySpec::FirstFit);
        let recycled = run(&spec, 1);
        assert_eq!(recycled.failed(), 0, "{:?}", recycled.cells);
        let cells = spec.cells();
        for (i, cell) in cells.iter().enumerate() {
            let fresh = run_cells(&spec, &[*cell], 1, None);
            let want = fresh[0].report().unwrap();
            let got = recycled.cells[i].report().unwrap();
            assert_eq!(got.events_processed, want.events_processed, "cell {i}");
            assert_eq!(got.clock_end.to_bits(), want.clock_end.to_bits(), "cell {i}");
            assert_eq!(got.market.price_reclaims, want.market.price_reclaims, "cell {i}");
            assert_eq!(
                got.market.spot_cost_usd.to_bits(),
                want.market.spot_cost_usd.to_bits(),
                "cell {i}"
            );
        }
        // The market-free trailing cell reports zero market stats.
        let plain = recycled.cells.last().unwrap().report().unwrap();
        assert_eq!(plain.market.price_reclaims, 0);
        assert_eq!(plain.market.spot_cost_usd, 0.0);
        assert_eq!(plain.market.max_price_paid, 0.0);
    }

    /// `run_observed` streams a validating event stream to the sidecar
    /// (one cell_start + cell_end per cell, one prebuild per distinct
    /// (substrate, seed) pair) and its report bit-matches the unobserved
    /// run: telemetry is a pure side channel.
    #[test]
    fn run_observed_emits_valid_spans_without_touching_results() {
        let dir = std::env::temp_dir()
            .join(format!("cloudmarket_drv_obs_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let scenario = ComparisonConfig { terminate_at: 300.0, ..Default::default() };
        let spec = SweepSpec::new(scenario)
            .with_seeds(vec![20_250_710, 20_250_711])
            .with_policies(vec![PolicySpec::FirstFit, PolicySpec::BestFit]);
        let telemetry = Telemetry::create(&dir).unwrap();
        let (observed, _) = run_observed(&spec, 2, None, Some(&telemetry));
        drop(telemetry);
        let plain = run(&spec, 2);
        for (a, b) in observed.cells.iter().zip(&plain.cells) {
            let (a, b) = (a.report().unwrap(), b.report().unwrap());
            assert_eq!(a.events_processed, b.events_processed);
            assert_eq!(a.clock_end.to_bits(), b.clock_end.to_bits());
        }
        let log = crate::obs::telemetry_dir(&dir).join(tel::RUN_LOG);
        let lines = crate::obs::read_jsonl(&log).unwrap();
        let mut starts = 0;
        let mut ends = 0;
        let mut prebuilds = 0;
        for line in &lines {
            match crate::obs::validate_event(line).expect("every line validates") {
                "cell_start" => starts += 1,
                "cell_end" => {
                    ends += 1;
                    let counters = EngineCounters::from_json(
                        line.as_obj().unwrap().get("counters").unwrap(),
                    )
                    .unwrap();
                    assert!(counters.events_popped > 0, "cell ran events");
                }
                "prebuild" => prebuilds += 1,
                other => panic!("unexpected event {other}"),
            }
        }
        assert_eq!(starts, 4);
        assert_eq!(ends, 4);
        assert_eq!(prebuilds, 2, "one build per distinct seed");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The timing breakdown reports lazily-built prebuilds and a sane
    /// phase decomposition (no timing field leaks into the artifacts -
    /// that contract is pinned by `tests/sweep_determinism.rs`).
    #[test]
    fn run_with_timing_reports_lazy_prebuilds() {
        let scenario = ComparisonConfig { terminate_at: 300.0, ..Default::default() };
        let spec = SweepSpec::new(scenario)
            .with_seeds(vec![20_250_710, 20_250_711])
            .with_policies(vec![PolicySpec::FirstFit]);
        let (report, timing) = run_with_timing(&spec, 2);
        assert_eq!(report.total(), 2);
        assert_eq!(report.failed(), 0);
        assert_eq!(timing.prebuilds_built, 2, "both seeds' prebuilds were built");
        assert!(timing.prebuild_busy > Duration::ZERO);
        assert!(timing.cell_busy > Duration::ZERO);
        assert!(timing.first_cell_done <= timing.wall);
        assert!(timing.wall > Duration::ZERO);
    }
}
