//! The sweep driver: fans cells out over a fixed-size worker pool and
//! merges results deterministically.
//!
//! Work distribution is a shared atomic cursor over the cell list
//! (self-balancing: fast workers simply claim more cells), so no
//! work-queue allocation or channel is needed. Each worker builds its own
//! `Engine` per cell (policies and engines are thread-local; only the
//! `Arc`-shared workload prebuilds cross threads), runs it to completion
//! inside `catch_unwind`, and reports a [`CellResult`]. A panicking cell
//! therefore fails alone - the rest of the grid still completes.
//!
//! A cell's [`CellSpec`](super::grid::CellSpec) selects the substrate
//! (§VII-E comparison plan vs §VII-D trace simulation), the policy (with
//! per-cell victim-policy and adjusted-alpha values), and the spot-config
//! overrides applied on top of the substrate's base `SpotConfig`. Cells
//! matching the spec's retention filter additionally keep their sampled
//! time series (Fig-13-style curves) in the result.
//!
//! The merge is by cell id, so the assembled [`SweepReport`] - and every
//! artifact serialized from it - is bit-identical regardless of thread
//! count (including `threads == 1`).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};

use crate::engine::Engine;
use crate::trace::workload::{self, trace_engine_config};

use super::grid::{Cell, Substrate, SweepSpec};
use super::prebuild::{Prebuilt, PrebuildCache};
use super::report::{CellResult, SweepReport};

/// Worker threads to use when the caller does not care: one per available
/// CPU (the engine itself stays single-threaded by design - DES
/// determinism - so the win is across cells).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Progress callback: `(cells_done, cells_total, just_finished_cell)`.
/// Invoked from worker threads (must be `Sync`).
pub type ProgressFn<'a> = &'a (dyn Fn(usize, usize, &CellResult) + Sync);

/// Run the sweep on `threads` workers (clamped to `1..=cells`).
pub fn run(spec: &SweepSpec, threads: usize) -> SweepReport {
    run_with_progress(spec, threads, None)
}

/// [`run`], reporting each finished cell to `on_cell`.
pub fn run_with_progress(
    spec: &SweepSpec,
    threads: usize,
    on_cell: Option<ProgressFn<'_>>,
) -> SweepReport {
    let cells = spec.cells();
    let total = cells.len();

    // Shared read-only prebuilds: resolve each distinct (substrate, seed)
    // pair's workload once, up front, and hand every cell an Arc to it.
    // Prebuild panics (e.g. an invalid trace template) are caught per cell
    // so they surface as that cell's error row instead of aborting the
    // sweep - the same isolation contract the workers give running cells.
    let mut cache = PrebuildCache::new();
    let plans: Vec<Result<Prebuilt, String>> = cells
        .iter()
        .map(|c| {
            catch_unwind(AssertUnwindSafe(|| cache.get_or_build_cell(spec, c)))
                .map_err(|p| format!("workload prebuild failed: {}", panic_message(p)))
        })
        .collect();

    let threads = threads.max(1).min(total.max(1));
    let next = AtomicUsize::new(0);
    let done = AtomicUsize::new(0);

    let mut slots: Vec<Option<CellResult>> = Vec::with_capacity(total);
    slots.resize_with(total, || None);

    std::thread::scope(|scope| {
        let cells = &cells;
        let plans = &plans;
        let next = &next;
        let done = &done;
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                scope.spawn(move || {
                    let mut out: Vec<(usize, CellResult)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= total {
                            break;
                        }
                        let result = match &plans[i] {
                            Ok(prebuilt) => run_cell(spec, &cells[i], prebuilt),
                            Err(e) => CellResult {
                                cell: cells[i],
                                outcome: Err(e.clone()),
                                series: None,
                            },
                        };
                        let finished = done.fetch_add(1, Ordering::Relaxed) + 1;
                        if let Some(cb) = on_cell {
                            cb(finished, total, &result);
                        }
                        out.push((i, result));
                    }
                    out
                })
            })
            .collect();
        for handle in handles {
            let worker_results =
                handle.join().expect("sweep worker died outside cell isolation");
            for (i, result) in worker_results {
                debug_assert!(slots[i].is_none(), "cell {i} ran twice");
                slots[i] = Some(result);
            }
        }
    });

    let merged: Vec<CellResult> = slots
        .into_iter()
        .enumerate()
        .map(|(i, r)| r.unwrap_or_else(|| panic!("cell {i} produced no result")))
        .collect();
    SweepReport { cells: merged, threads }
}

/// Run one cell to completion; panics inside the cell become `Err` rows.
fn run_cell(spec: &SweepSpec, cell: &Cell, prebuilt: &Prebuilt) -> CellResult {
    let retain = spec.retain.matches(cell);
    let outcome = catch_unwind(AssertUnwindSafe(|| {
        let policy = cell.spec.policy.build_with_victim(cell.spec.victim);
        let mut engine = match (cell.spec.substrate, prebuilt) {
            (Substrate::Comparison, Prebuilt::Comparison(plan)) => {
                let mut engine = Engine::new(spec.engine.clone(), policy);
                plan.apply_with_spot(&mut engine, cell.spec.spot.apply_to(plan.spot));
                engine
            }
            (Substrate::Trace, Prebuilt::Trace(trace)) => {
                let mut engine =
                    Engine::new(trace_engine_config(spec.trace.sample_interval), policy);
                let mut wl = spec.trace.workload.clone();
                wl.seed = cell.seed;
                wl.spot = cell.spec.spot.apply_to(wl.spot);
                workload::build(&mut engine, trace, &wl);
                engine.terminate_at(trace.horizon);
                engine
            }
            (substrate, _) => unreachable!(
                "prebuilt kind does not match cell substrate {substrate:?} (driver bug)"
            ),
        };
        let report = engine.run();
        let series = if retain { Some(engine.recorder.take_series()) } else { None };
        (report, series)
    }));
    match outcome {
        Ok((report, series)) => CellResult { cell: *cell, outcome: Ok(report), series },
        Err(payload) => {
            CellResult { cell: *cell, outcome: Err(panic_message(payload)), series: None }
        }
    }
}

fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "cell panicked (non-string payload)".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::scenario::ComparisonConfig;
    use crate::sweep::grid::{PolicySpec, ScenarioAxis, SeriesFilter};

    #[test]
    fn panicking_cells_fail_alone() {
        // An invalid engine config makes Engine::new panic inside every
        // cell; the driver must survive and report each failure.
        let mut spec = SweepSpec::new(ComparisonConfig::default())
            .with_seeds(vec![1])
            .with_policies(vec![PolicySpec::FirstFit, PolicySpec::BestFit]);
        spec.engine.scheduling_interval = 0.0;
        let report = run(&spec, 2);
        assert_eq!(report.total(), 2);
        assert_eq!(report.failed(), 2);
        for cell in &report.cells {
            let err = cell.outcome.as_ref().err().expect("cell must have failed");
            assert!(err.contains("invalid engine config"), "unexpected error: {err}");
        }
    }

    #[test]
    fn thread_count_is_clamped_and_recorded() {
        let mut spec = SweepSpec::new(ComparisonConfig::default())
            .with_seeds(vec![1])
            .with_policies(vec![PolicySpec::FirstFit]);
        // Keep the single cell cheap: it still fails fast on purpose.
        spec.engine.sample_interval = -1.0;
        let report = run(&spec, 64);
        assert_eq!(report.threads, 1, "threads are clamped to the cell count");
        assert_eq!(report.total(), 1);
    }

    /// Retention keeps series only for matching cells; failed cells never
    /// carry one.
    #[test]
    fn series_are_retained_per_filter() {
        let scenario = ComparisonConfig { terminate_at: 300.0, ..Default::default() };
        let spec = SweepSpec::new(scenario)
            .with_seeds(vec![20_250_710])
            .with_policies(vec![PolicySpec::FirstFit, PolicySpec::BestFit])
            .with_series_retention(SeriesFilter::parse("policy=first-fit").unwrap());
        let report = run(&spec, 2);
        assert_eq!(report.failed(), 0);
        let ff = &report.cells[0];
        assert_eq!(ff.cell.policy(), PolicySpec::FirstFit);
        let series = ff.series.as_ref().expect("first-fit cell retains its series");
        assert!(!series.is_empty(), "retained series has samples");
        assert!(report.cells[1].series.is_none(), "non-matching cell retains nothing");
    }

    /// A broken prebuild template (trace generator rejects 0 machines)
    /// becomes per-cell error rows, not a sweep-wide abort.
    #[test]
    fn prebuild_panics_become_cell_errors() {
        let mut spec = SweepSpec::new(ComparisonConfig::default())
            .with_seeds(vec![1, 2])
            .with_policies(vec![PolicySpec::FirstFit])
            .with_axis(ScenarioAxis::Substrate(vec![crate::sweep::Substrate::Trace]));
        spec.trace.synth.machines = 0; // TraceGenerator::new asserts machines > 0
        let report = run(&spec, 2);
        assert_eq!(report.total(), 2);
        assert_eq!(report.failed(), 2);
        for cell in &report.cells {
            let err = cell.outcome.as_ref().err().expect("cell must have failed");
            assert!(err.contains("workload prebuild failed"), "unexpected error: {err}");
        }
    }

    /// A one-cell trace-substrate grid runs end to end on the driver.
    #[test]
    fn trace_substrate_cells_run() {
        let mut spec = SweepSpec::new(ComparisonConfig::default())
            .with_seeds(vec![42])
            .with_policies(vec![PolicySpec::FirstFit])
            .with_axis(ScenarioAxis::Substrate(vec![crate::sweep::Substrate::Trace]));
        spec.trace.synth.machines = 10;
        spec.trace.synth.days = 0.05;
        spec.trace.synth.tasks_per_hour = 120.0;
        spec.trace.workload.spot_instances = 20;
        spec.trace.workload.spot_durations = vec![300.0, 600.0];
        spec.trace.workload.max_trace_vms = 50;
        let report = run(&spec, 1);
        assert_eq!(report.total(), 1);
        assert_eq!(report.failed(), 0, "trace cell failed: {:?}", report.cells[0].outcome);
        let r = report.cells[0].report().unwrap();
        assert_eq!(r.spot.total_spot, 20);
        assert!(r.events_processed > 0);
    }
}
