//! The sweep driver: fans cells out over a fixed-size worker pool and
//! merges results deterministically.
//!
//! Work distribution is a shared atomic cursor over the cell list
//! (self-balancing: fast workers simply claim more cells), so no
//! work-queue allocation or channel is needed. Each worker builds its own
//! `Engine` per cell (policies and engines are thread-local; only the
//! `Arc`-shared workload prebuilds cross threads), runs it to completion
//! inside `catch_unwind`, and reports a [`CellResult`]. A panicking cell
//! therefore fails alone - the rest of the grid still completes.
//!
//! The merge is by cell id, so the assembled [`SweepReport`] - and every
//! artifact serialized from it - is bit-identical regardless of thread
//! count (including `threads == 1`).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use crate::config::scenario::WorkloadPlan;
use crate::engine::Engine;

use super::grid::{Cell, SweepSpec};
use super::prebuild::PrebuildCache;
use super::report::{CellResult, SweepReport};

/// Worker threads to use when the caller does not care: one per available
/// CPU (the engine itself stays single-threaded by design - DES
/// determinism - so the win is across cells).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Progress callback: `(cells_done, cells_total, just_finished_cell)`.
/// Invoked from worker threads (must be `Sync`).
pub type ProgressFn<'a> = &'a (dyn Fn(usize, usize, &CellResult) + Sync);

/// Run the sweep on `threads` workers (clamped to `1..=cells`).
pub fn run(spec: &SweepSpec, threads: usize) -> SweepReport {
    run_with_progress(spec, threads, None)
}

/// [`run`], reporting each finished cell to `on_cell`.
pub fn run_with_progress(
    spec: &SweepSpec,
    threads: usize,
    on_cell: Option<ProgressFn<'_>>,
) -> SweepReport {
    let cells = spec.cells();
    let total = cells.len();

    // Shared read-only prebuilds: resolve each distinct seed's workload
    // once, up front, and hand every cell an Arc to its seed's plan.
    let mut cache = PrebuildCache::new();
    let plans: Vec<Arc<WorkloadPlan>> =
        cells.iter().map(|c| cache.get_or_build(&spec.scenario, c.seed)).collect();

    let threads = threads.max(1).min(total.max(1));
    let next = AtomicUsize::new(0);
    let done = AtomicUsize::new(0);

    let mut slots: Vec<Option<CellResult>> = Vec::with_capacity(total);
    slots.resize_with(total, || None);

    std::thread::scope(|scope| {
        let cells = &cells;
        let plans = &plans;
        let next = &next;
        let done = &done;
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                scope.spawn(move || {
                    let mut out: Vec<(usize, CellResult)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= total {
                            break;
                        }
                        let result = run_cell(spec, &cells[i], &plans[i]);
                        let finished = done.fetch_add(1, Ordering::Relaxed) + 1;
                        if let Some(cb) = on_cell {
                            cb(finished, total, &result);
                        }
                        out.push((i, result));
                    }
                    out
                })
            })
            .collect();
        for handle in handles {
            let worker_results =
                handle.join().expect("sweep worker died outside cell isolation");
            for (i, result) in worker_results {
                debug_assert!(slots[i].is_none(), "cell {i} ran twice");
                slots[i] = Some(result);
            }
        }
    });

    let merged: Vec<CellResult> = slots
        .into_iter()
        .enumerate()
        .map(|(i, r)| r.unwrap_or_else(|| panic!("cell {i} produced no result")))
        .collect();
    SweepReport { cells: merged, threads }
}

/// Run one cell to completion; panics inside the cell become `Err` rows.
fn run_cell(spec: &SweepSpec, cell: &Cell, plan: &WorkloadPlan) -> CellResult {
    let outcome = catch_unwind(AssertUnwindSafe(|| {
        let mut engine = Engine::new(spec.engine.clone(), cell.policy.build());
        plan.apply(&mut engine);
        engine.run()
    }));
    match outcome {
        Ok(report) => CellResult { cell: *cell, outcome: Ok(report) },
        Err(payload) => CellResult { cell: *cell, outcome: Err(panic_message(payload)) },
    }
}

fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "cell panicked (non-string payload)".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::scenario::ComparisonConfig;
    use crate::sweep::grid::PolicySpec;

    #[test]
    fn panicking_cells_fail_alone() {
        // An invalid engine config makes Engine::new panic inside every
        // cell; the driver must survive and report each failure.
        let mut spec = SweepSpec::new(ComparisonConfig::default())
            .with_seeds(vec![1])
            .with_policies(vec![PolicySpec::FirstFit, PolicySpec::BestFit]);
        spec.engine.scheduling_interval = 0.0;
        let report = run(&spec, 2);
        assert_eq!(report.total(), 2);
        assert_eq!(report.failed(), 2);
        for cell in &report.cells {
            let err = cell.outcome.as_ref().err().expect("cell must have failed");
            assert!(err.contains("invalid engine config"), "unexpected error: {err}");
        }
    }

    #[test]
    fn thread_count_is_clamped_and_recorded() {
        let mut spec = SweepSpec::new(ComparisonConfig::default())
            .with_seeds(vec![1])
            .with_policies(vec![PolicySpec::FirstFit]);
        // Keep the single cell cheap: it still fails fast on purpose.
        spec.engine.sample_interval = -1.0;
        let report = run(&spec, 64);
        assert_eq!(report.threads, 1, "threads are clamped to the cell count");
        assert_eq!(report.total(), 1);
    }
}
