//! Fig. 12 + §VII-D: the cluster-trace simulation with injected spot
//! instances, plus Figs. 10-11 (simulator process CPU/memory) via the
//! self-profiler.

use std::time::Duration;

use crate::allocation::FirstFit;
use crate::engine::{Engine, Report};
use crate::metrics::selfprof::SelfProfiler;
use crate::metrics::TimeSeries;
use crate::trace::synth::{SynthConfig, TraceGenerator};
use crate::trace::workload::{self, trace_engine_config, WorkloadConfig, WorkloadStats};
use crate::trace::Trace;
use crate::util::csv::fmt_num;
use crate::util::table::{Align, TextTable};

/// Configuration of the trace experiment (scaled-down defaults; the
/// paper's full run used 12.6k machines / 2 days / 200k spots and took a
/// week of wall time on its testbed).
#[derive(Debug, Clone)]
pub struct TraceSimConfig {
    pub synth: SynthConfig,
    pub workload: WorkloadConfig,
    /// Record Figs. 10-11 with the /proc self-profiler.
    pub profile: bool,
    /// Metrics sampling period (Fig. 12 resolution), seconds.
    pub sample_interval: f64,
}

impl Default for TraceSimConfig {
    fn default() -> Self {
        TraceSimConfig {
            synth: SynthConfig::default(), // 200 machines, 2 days
            workload: WorkloadConfig {
                spot_instances: 2_000,
                // scaled spot durations: 20/40 "hours" compressed 10x so
                // completions occur inside the 2-day horizon at this scale
                spot_durations: vec![7_200.0, 14_400.0],
                max_trace_vms: 20_000,
                ..Default::default()
            },
            profile: true,
            sample_interval: 300.0,
        }
    }
}

/// Everything the trace experiment produces.
pub struct TraceSimOutcome {
    pub trace_machines: usize,
    pub trace_tasks: usize,
    pub workload: WorkloadStats,
    pub report: Report,
    /// Fig. 12 series: active VM instances over time.
    pub series: TimeSeries,
    /// Figs. 10-11 series (empty when profiling disabled).
    pub selfprof: Option<TimeSeries>,
}

/// Run the trace simulation end to end.
pub fn run(cfg: &TraceSimConfig) -> TraceSimOutcome {
    let trace: Trace = TraceGenerator::new(cfg.synth.clone()).generate();
    let issues = trace.validate();
    assert!(issues.is_empty(), "synthetic trace invalid: {issues:?}");

    let mut engine =
        Engine::new(trace_engine_config(cfg.sample_interval), Box::new(FirstFit::new()));
    let wl = workload::build(&mut engine, &trace, &cfg.workload);
    engine.terminate_at(trace.horizon);

    let profiler =
        if cfg.profile { Some(SelfProfiler::start(Duration::from_millis(100))) } else { None };
    let report = engine.run();
    let selfprof = profiler.map(|p| p.stop());

    TraceSimOutcome {
        trace_machines: trace.machine_count(),
        trace_tasks: trace.task_count(),
        workload: wl,
        report,
        series: engine.recorder.take_series(),
        selfprof,
    }
}

/// §VII-D.2 summary table (spot interruptions / completion stats).
pub fn results_table(out: &TraceSimOutcome) -> TextTable {
    let s = &out.report.spot;
    let pct = |num: u64, den: u64| {
        if den == 0 { "0".to_string() } else { format!("{:.1}%", 100.0 * num as f64 / den as f64) }
    };
    let mut t = TextTable::new("CLUSTER TRACE SIMULATION (paper SVII-D.2)")
        .column("Metric", Align::Left)
        .column("Value", Align::Right)
        .column("Paper (full scale)", Align::Right);
    let rows: Vec<(&str, String, &str)> = vec![
        ("trace machines", out.trace_machines.to_string(), "12,585"),
        ("trace tasks", out.trace_tasks.to_string(), "48.4M (30d)"),
        ("trace VMs created", out.workload.trace_vms.to_string(), "2.38M (2d)"),
        ("injected spot instances", out.workload.spot_vms.to_string(), "200,000"),
        ("spot uninterrupted completions", format!(
            "{} ({})",
            s.uninterrupted_completions,
            pct(s.uninterrupted_completions, s.total_spot)
        ), "16.5%"),
        ("spot VMs interrupted", s.interrupted_vms.to_string(), "166,918"),
        ("spot redeployments", s.redeployments.to_string(), "92,554"),
        ("completed after interruption", s.completed_after_interruption.to_string(), "43,878"),
        ("spot terminated", s.terminated.to_string(), "123,040"),
        ("max interruptions per VM", s.max_interruptions_per_vm.to_string(), "3"),
        ("avg interruption", format!("{:.0} s", s.avg_interruption_secs), "~1,910 s"),
        ("max interruption", format!("{:.0} s", s.max_interruption_secs), "7,711 s"),
        ("events processed", out.report.events_processed.to_string(), "-"),
        ("wall time", format!("{:.2?}", out.report.wall), "~7 days"),
    ];
    for (k, v, p) in rows {
        t.push(vec![k.to_string(), v, p.to_string()]);
    }
    t
}

/// Fig. 12 CSV: active instance counts over simulation time.
pub fn fig12_csv(out: &TraceSimOutcome) -> crate::util::csv::Csv {
    let mut csv = crate::util::csv::Csv::new(&[
        "time_s",
        "od_running",
        "spot_running",
        "hibernated",
        "waiting",
    ]);
    let s = &out.series;
    let od = s.column("od_running").unwrap();
    let spot = s.column("spot_running").unwrap();
    let hib = s.column("hibernated").unwrap();
    let wait = s.column("waiting").unwrap();
    for i in 0..s.len() {
        csv.push(vec![
            fmt_num(s.times()[i]),
            fmt_num(od[i]),
            fmt_num(spot[i]),
            fmt_num(hib[i]),
            fmt_num(wait[i]),
        ]);
    }
    csv
}
