//! Experiment drivers: one module per paper table/figure family, shared by
//! the CLI (`cargo run -- <cmd>`), the examples and the benches so every
//! artifact is regenerated from a single code path (DESIGN.md §3).

pub mod advisor;
pub mod compare;
pub mod trace_analysis;
pub mod trace_sim;
