//! Figs. 13-15: the allocation-algorithm comparison (paper §VII-E).
//!
//! Runs the identical Table II/III workload (same seed) under First-Fit,
//! HLEM-VMP and adjusted HLEM-VMP, and renders:
//!
//! - Fig. 13: active spot/on-demand instances over time, per algorithm,
//! - Fig. 14: total spot interruptions, per algorithm,
//! - Fig. 15: avg/max/min interruption durations, per algorithm.

use crate::allocation::{AllocationPolicy, FirstFit, HlemVmp};
use crate::config::scenario::{
    build_comparison_workload, comparison_engine_config, ComparisonConfig,
};
use crate::engine::{Engine, Report};
use crate::metrics::TimeSeries;
use crate::sweep::{self, PolicySpec, SweepSpec};
use crate::util::csv::{fmt_num, Csv};
use crate::util::table::{Align, TextTable};

/// Result of one policy run.
pub struct Outcome {
    pub policy: &'static str,
    pub report: Report,
    /// Sampled active-instance series (Fig. 13 raw data).
    pub series: TimeSeries,
}

/// Policies compared in the paper (§VII-E.2): First-Fit baseline, plain
/// HLEM-VMP, adjusted HLEM-VMP.
pub fn paper_policies() -> Vec<(&'static str, fn() -> Box<dyn AllocationPolicy>)> {
    vec![
        ("first-fit", || Box::new(FirstFit::new()) as Box<dyn AllocationPolicy>),
        ("hlem-vmp", || Box::new(HlemVmp::plain()) as Box<dyn AllocationPolicy>),
        ("hlem-vmp-adjusted", || Box::new(HlemVmp::adjusted()) as Box<dyn AllocationPolicy>),
    ]
}

/// Run one policy over the scenario.
pub fn run_policy(
    make_policy: impl FnOnce() -> Box<dyn AllocationPolicy>,
    cfg: &ComparisonConfig,
) -> Outcome {
    let mut engine = Engine::new(comparison_engine_config(), make_policy());
    build_comparison_workload(&mut engine, cfg);
    let report = engine.run();
    let policy = report.policy;
    // Move the sampled series out of the recorder (the engine is dropped
    // here anyway; cloning the full per-run time series was pure waste).
    let series = engine.recorder.take_series();
    Outcome { policy, report, series }
}

/// Run the full paper comparison.
pub fn run_all(cfg: &ComparisonConfig) -> Vec<Outcome> {
    paper_policies().into_iter().map(|(_, make)| run_policy(make, cfg)).collect()
}

/// Fig. 14 table: total spot interruptions per algorithm.
pub fn fig14_table(outcomes: &[Outcome]) -> TextTable {
    let mut t = TextTable::new("FIG 14 - TOTAL SPOT INSTANCE INTERRUPTIONS")
        .column("Algorithm", Align::Left)
        .column("Interruptions", Align::Right)
        .column("Interrupted VMs", Align::Right)
        .column("Max per VM", Align::Right);
    for o in outcomes {
        t.push(vec![
            o.policy.to_string(),
            o.report.spot.interruptions.to_string(),
            o.report.spot.interrupted_vms.to_string(),
            o.report.spot.max_interruptions_per_vm.to_string(),
        ]);
    }
    t
}

/// Fig. 15 table: interruption durations per algorithm.
pub fn fig15_table(outcomes: &[Outcome]) -> TextTable {
    let mut t = TextTable::new("FIG 15 - SPOT INTERRUPTION DURATIONS (s)")
        .column("Algorithm", Align::Left)
        .column("Average", Align::Right)
        .column("Maximum", Align::Right)
        .column("Minimum", Align::Right);
    for o in outcomes {
        t.push(vec![
            o.policy.to_string(),
            fmt_num(o.report.spot.avg_interruption_secs),
            fmt_num(o.report.spot.max_interruption_secs),
            fmt_num(o.report.spot.min_interruption_secs),
        ]);
    }
    t
}

/// Fig. 13 CSV: merged active-instance series
/// (`time,<policy>_od,<policy>_spot,...`).
pub fn fig13_csv(outcomes: &[Outcome]) -> Csv {
    let mut header: Vec<String> = vec!["time".into()];
    for o in outcomes {
        header.push(format!("{}_od", o.policy));
        header.push(format!("{}_spot", o.policy));
    }
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut csv = Csv::new(&header_refs);

    // Series share sampling config; align on the shortest.
    let rows = outcomes.iter().map(|o| o.series.len()).min().unwrap_or(0);
    // Columns are contiguous borrows into each outcome's series (the
    // column-major layout): no per-policy gather allocation.
    let od_cols: Vec<&[f64]> =
        outcomes.iter().map(|o| o.series.column("od_running").unwrap()).collect();
    let spot_cols: Vec<&[f64]> =
        outcomes.iter().map(|o| o.series.column("spot_running").unwrap()).collect();
    for i in 0..rows {
        let mut row = vec![fmt_num(outcomes[0].series.times()[i])];
        for (od, spot) in od_cols.iter().zip(&spot_cols) {
            row.push(fmt_num(od[i]));
            row.push(fmt_num(spot[i]));
        }
        csv.push(row);
    }
    csv
}

/// Aggregate over several seeds (the paper ran one randomization; we
/// report the mean across `runs` seeds to separate algorithm effect from
/// workload noise).
#[derive(Debug, Clone)]
pub struct Aggregate {
    pub policy: &'static str,
    pub runs: usize,
    pub mean_interruptions: f64,
    pub mean_interrupted_vms: f64,
    pub mean_avg_duration: f64,
    pub mean_max_duration: f64,
    pub max_per_vm: u32,
}

/// Run the comparison for seeds `base_seed..base_seed+runs`, fanned out
/// over all available CPUs via the sweep driver.
pub fn run_multi(base_cfg: &ComparisonConfig, runs: usize) -> Vec<Aggregate> {
    run_multi_threaded(base_cfg, runs, sweep::default_threads())
}

/// [`run_multi`] with an explicit worker-thread count.
///
/// Implemented on the sweep driver: one cell per (seed, policy), the
/// policy list built once (not reconstructed per seed), workload plans
/// shared per seed across the three policies. The merge accumulates per
/// policy over seeds in ascending order - the exact float-summation order
/// of the pre-sweep sequential loop - so the aggregates are bit-identical
/// to the old implementation at any thread count.
pub fn run_multi_threaded(
    base_cfg: &ComparisonConfig,
    runs: usize,
    threads: usize,
) -> Vec<Aggregate> {
    let policies = PolicySpec::paper();
    let spec = SweepSpec::new(base_cfg.clone())
        .with_seed_range(base_cfg.seed, runs)
        .with_policies(policies.clone());
    let sweep_report = sweep::run(&spec, threads);

    let mut aggs: Vec<Aggregate> = policies
        .iter()
        .map(|p| Aggregate {
            policy: p.name(),
            runs,
            mean_interruptions: 0.0,
            mean_interrupted_vms: 0.0,
            mean_avg_duration: 0.0,
            mean_max_duration: 0.0,
            max_per_vm: 0,
        })
        .collect();
    // Cells are seed-major in cell-id order, so this accumulates each
    // policy's seeds in ascending order.
    for cell in &sweep_report.cells {
        let i = policies
            .iter()
            .position(|p| *p == cell.cell.policy())
            .expect("sweep returned a policy outside the requested grid");
        let report = match &cell.outcome {
            Ok(r) => r,
            Err(e) => panic!(
                "sweep cell {} ({} seed {}) failed: {e}",
                cell.cell.id,
                cell.cell.policy().name(),
                cell.cell.seed
            ),
        };
        let a = &mut aggs[i];
        a.mean_interruptions += report.spot.interruptions as f64 / runs as f64;
        a.mean_interrupted_vms += report.spot.interrupted_vms as f64 / runs as f64;
        a.mean_avg_duration += report.spot.avg_interruption_secs / runs as f64;
        a.mean_max_duration += report.spot.max_interruption_secs / runs as f64;
        a.max_per_vm = a.max_per_vm.max(report.spot.max_interruptions_per_vm);
    }
    aggs
}

/// Render the multi-seed aggregate (Figs. 14-15 combined).
pub fn aggregate_table(aggs: &[Aggregate]) -> TextTable {
    let mut t = TextTable::new("FIGS 14-15 AGGREGATE (mean over seeds)")
        .column("Algorithm", Align::Left)
        .column("Runs", Align::Right)
        .column("Interruptions", Align::Right)
        .column("Interrupted VMs", Align::Right)
        .column("Avg dur (s)", Align::Right)
        .column("Max dur (s)", Align::Right)
        .column("Max per VM", Align::Right);
    for a in aggs {
        t.push(vec![
            a.policy.to_string(),
            a.runs.to_string(),
            fmt_num(a.mean_interruptions),
            fmt_num(a.mean_interrupted_vms),
            fmt_num(a.mean_avg_duration),
            fmt_num(a.mean_max_duration),
            a.max_per_vm.to_string(),
        ]);
    }
    t
}

/// Shape check used by tests and EXPERIMENTS.md: the paper's ordering is
/// FirstFit > HLEM > adjusted on interruption count, and adjusted has the
/// smallest maximum interruption duration.
pub fn shape_summary(outcomes: &[Outcome]) -> String {
    let get = |name: &str| outcomes.iter().find(|o| o.policy == name);
    let (Some(ff), Some(hl), Some(adj)) =
        (get("first-fit"), get("hlem-vmp"), get("hlem-vmp-adjusted"))
    else {
        return "incomplete outcome set".into();
    };
    format!(
        "interruptions: first-fit={} hlem={} adjusted={} (paper: 286/230/205)\n\
         max-duration:  first-fit={:.2}s hlem={:.2}s adjusted={:.2}s (paper: 64.87/49.49/45.65)\n\
         avg-duration:  first-fit={:.2}s hlem={:.2}s adjusted={:.2}s (paper: 22.81/21.12/25.20)",
        ff.report.spot.interruptions,
        hl.report.spot.interruptions,
        adj.report.spot.interruptions,
        ff.report.spot.max_interruption_secs,
        hl.report.spot.max_interruption_secs,
        adj.report.spot.max_interruption_secs,
        ff.report.spot.avg_interruption_secs,
        hl.report.spot.avg_interruption_secs,
        adj.report.spot.avg_interruption_secs,
    )
}
