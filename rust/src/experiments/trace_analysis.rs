//! Figs. 7-9: trace concurrency analysis (paper §VII-C.1).

use crate::trace::analysis::{
    fig7_daily_task_concurrency, fig8_daily_cloudlet_concurrency, fig9_hour_of_day_peaks,
};
use crate::trace::synth::{SynthConfig, TraceGenerator};
use crate::trace::Trace;
use crate::util::table::{Align, TextTable};

/// Generate the month-scale trace the figures are computed from.
pub fn month_trace(seed: u64, machines: usize) -> Trace {
    TraceGenerator::new(SynthConfig { seed, machines, ..SynthConfig::month_scale() }).generate()
}

/// Fig. 7 table: max/min concurrently active tasks per day.
pub fn fig7_table(trace: &Trace) -> TextTable {
    let mut t = TextTable::new("FIG 7 - CONCURRENT TASKS PER DAY")
        .column("Day", Align::Right)
        .column("Max", Align::Right)
        .column("Min", Align::Right);
    for (day, mx, mn) in fig7_daily_task_concurrency(trace) {
        t.push(vec![day.to_string(), mx.to_string(), mn.to_string()]);
    }
    t
}

/// Fig. 8 table: daily max concurrently running cloudlets (hourly res.).
pub fn fig8_table(trace: &Trace) -> TextTable {
    let mut t = TextTable::new("FIG 8 - CONCURRENT CLOUDLETS PER DAY (hourly resolution)")
        .column("Day", Align::Right)
        .column("Max", Align::Right)
        .column("Min", Align::Right);
    for (day, mx, mn) in fig8_daily_cloudlet_concurrency(trace) {
        t.push(vec![day.to_string(), mx.to_string(), mn.to_string()]);
    }
    t
}

/// Fig. 9 table: max concurrently running cloudlets by hour-of-day.
pub fn fig9_table(trace: &Trace) -> TextTable {
    let mut t = TextTable::new("FIG 9 - PEAK CONCURRENT CLOUDLETS BY HOUR OF DAY")
        .column("Hour", Align::Right)
        .column("Peak", Align::Right);
    for (hour, peak) in fig9_hour_of_day_peaks(trace).iter().enumerate() {
        t.push(vec![hour.to_string(), peak.to_string()]);
    }
    t
}
