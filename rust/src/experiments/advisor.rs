//! Fig. 16: spot-advisor feature correlation analysis (paper §VII-F).

use crate::analysis::advisor::{synth_dataset, AdvisorDataset};
use crate::util::csv::fmt_num;
use crate::util::table::{Align, TextTable};

/// Load the dataset: a real advisor JSON if provided, otherwise the
/// synthetic 389-type dataset (DESIGN.md §6 substitution).
pub fn dataset(advisor_json: Option<&std::path::Path>, seed: u64) -> AdvisorDataset {
    if let Some(path) = advisor_json {
        let text = std::fs::read_to_string(path).expect("reading advisor json");
        let doc = crate::util::json::parse(&text).expect("parsing advisor json");
        if let Some(ds) = AdvisorDataset::from_json(&doc, "us-east-1", "Linux") {
            return ds;
        }
        eprintln!("advisor json unusable; falling back to synthetic dataset");
    }
    synth_dataset(seed)
}

/// Fig. 16 table: association of each feature with interruption frequency.
pub fn fig16_table(ds: &AdvisorDataset) -> TextTable {
    let mut t = TextTable::new("FIG 16 - FEATURE vs INTERRUPTION FREQUENCY")
        .column("Feature", Align::Left)
        .column("Measure", Align::Left)
        .column("Association", Align::Right)
        .column("Paper", Align::Right);
    let paper: &[(&str, &str)] = &[
        ("instance_type", "0.38"),
        ("instance_family", "0.33"),
        ("machine_category", "0.18"),
        ("day", "~0"),
        ("free_tier", "~0"),
        ("dedicated_host", "~0"),
    ];
    for row in ds.fig16_associations() {
        let paper_val = paper
            .iter()
            .find(|(f, _)| *f == row.feature)
            .map(|(_, v)| v.to_string())
            .unwrap_or_else(|| "-".into());
        t.push(vec![
            row.feature.to_string(),
            row.measure.to_string(),
            fmt_num(row.value),
            paper_val,
        ]);
    }
    t
}

/// Class distribution table (the advisor's five interruption ranges).
pub fn class_distribution_table(ds: &AdvisorDataset) -> TextTable {
    let labels = ["<5%", "5-10%", "10-15%", "15-20%", ">20%"];
    let mut counts = [0usize; 5];
    for r in &ds.rows {
        counts[r.interruption_class.min(4) as usize] += 1;
    }
    let mut t = TextTable::new("INTERRUPTION FREQUENCY CLASS DISTRIBUTION")
        .column("Class", Align::Left)
        .column("Instance types", Align::Right);
    for (label, count) in labels.iter().zip(counts) {
        t.push(vec![label.to_string(), count.to_string()]);
    }
    t
}
