//! Lifecycle event log + aggregate counters (paper §IV-B: "log execution
//! history, interruption counts, and average interruption times").

use super::series::TimeSeries;
use crate::engine::world::StateSample;
use crate::vm::VmId;

/// Kind of lifecycle event recorded for observability.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LifecycleKind {
    Submitted,
    Allocated,
    AllocationFailed,
    InterruptWarned,
    Hibernated,
    Resumed,
    Terminated,
    Finished,
    Failed,
    WaitingExpired,
    HibernationTimedOut,
    Checkpointed,
    Migrated,
}

impl std::fmt::Display for LifecycleKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            LifecycleKind::Submitted => "SUBMITTED",
            LifecycleKind::Allocated => "ALLOCATED",
            LifecycleKind::AllocationFailed => "ALLOCATION_FAILED",
            LifecycleKind::InterruptWarned => "INTERRUPT_WARNED",
            LifecycleKind::Hibernated => "HIBERNATED",
            LifecycleKind::Resumed => "RESUMED",
            LifecycleKind::Terminated => "TERMINATED",
            LifecycleKind::Finished => "FINISHED",
            LifecycleKind::Failed => "FAILED",
            LifecycleKind::WaitingExpired => "WAITING_EXPIRED",
            LifecycleKind::HibernationTimedOut => "HIBERNATION_TIMED_OUT",
            LifecycleKind::Checkpointed => "CHECKPOINTED",
            LifecycleKind::Migrated => "MIGRATED",
        };
        f.write_str(s)
    }
}

/// One recorded lifecycle transition.
#[derive(Debug, Clone, Copy)]
pub struct LifecycleEvent {
    pub time: f64,
    pub vm: VmId,
    pub kind: LifecycleKind,
}

/// Collects everything the evaluation section needs from one run.
pub struct Recorder {
    /// Sampled state series: active counts + utilization (Figs. 12/13 +
    /// cluster-view of Figs. 10/11).
    pub series: TimeSeries,
    /// Per-VM lifecycle log, capped at `max_events`.
    pub events: Vec<LifecycleEvent>,
    max_events: usize,
    dropped_events: u64,
    /// Total capacity-driven spot interruptions (Fig. 14 metric).
    pub interruptions: u64,
    /// Interruptions resolved by hibernation vs termination.
    pub hibernations: u64,
    pub spot_terminations: u64,
    /// Successful redeployments of hibernated VMs.
    pub redeployments: u64,
    /// Allocation attempts / failures (engine health).
    pub alloc_attempts: u64,
    pub alloc_failures: u64,
    /// Chaos reclaim storms fired and the warnings they issued.
    pub storms: u64,
    pub storm_reclaims: u64,
    /// Spot reclaims caused by an upward market price crossing (the
    /// spot price rose above the bid level).
    pub price_reclaims: u64,
    /// Chaos host crashes injected.
    pub host_failures: u64,
    /// Displaced VMs that made it back onto a host, with their
    /// displacement-to-running latency (time-to-recover).
    pub recoveries: u64,
    pub recovery_secs_sum: f64,
    pub recovery_secs_max: f64,
    /// Work (MI) of partially-executed cloudlets discarded by terminal
    /// states vs carried across a displacement back to a host.
    pub work_lost_mi: f64,
    pub work_recovered_mi: f64,
    /// Recovery checkpoints taken (full or partial) and the bytes they
    /// transferred through the warning window (MB).
    pub checkpoints: u64,
    pub checkpoint_mb: f64,
    /// Displaced-VM migrations completed vs dropped at transfer end
    /// (target no longer fit / market hold).
    pub migrations: u64,
    pub failed_migrations: u64,
    /// Per-recovery displacement-to-running latency samples (seconds);
    /// feeds the requeue-latency percentiles in `RecoveryStats`.
    pub requeue_latency: Vec<f64>,
}

/// Column schema of the sampled state series - static, so a recorder's
/// schema is interned once and shared (via `Arc`) by every series taken
/// from it.
pub const SERIES_COLUMNS: [&str; 10] = [
    "od_running",
    "spot_running",
    "hibernated",
    "waiting",
    "used_pes",
    "total_pes",
    "ram_used_frac",
    "cpu_used_frac",
    "failed_hosts",
    "displaced",
];

impl Recorder {
    pub fn new(max_events: usize) -> Self {
        Recorder {
            series: TimeSeries::new(&SERIES_COLUMNS),
            events: Vec::new(),
            max_events,
            dropped_events: 0,
            interruptions: 0,
            hibernations: 0,
            spot_terminations: 0,
            redeployments: 0,
            alloc_attempts: 0,
            alloc_failures: 0,
            storms: 0,
            storm_reclaims: 0,
            price_reclaims: 0,
            host_failures: 0,
            recoveries: 0,
            recovery_secs_sum: 0.0,
            recovery_secs_max: 0.0,
            work_lost_mi: 0.0,
            work_recovered_mi: 0.0,
            checkpoints: 0,
            checkpoint_mb: 0.0,
            migrations: 0,
            failed_migrations: 0,
            requeue_latency: Vec::new(),
        }
    }

    /// Wipe all recorded data back to the `new` state while keeping the
    /// series/event buffers allocated - sweep workers reuse one recorder
    /// across consecutive cells instead of reallocating it per cell.
    ///
    /// Destructures `Recorder` exhaustively so a field added later fails
    /// to compile here instead of silently escaping the reset (which
    /// would leak state across recycled cells and break the sweep's
    /// byte-identical-artifacts contract).
    pub fn reset(&mut self, max_events: usize) {
        let Recorder {
            series,
            events,
            max_events: cap,
            dropped_events,
            interruptions,
            hibernations,
            spot_terminations,
            redeployments,
            alloc_attempts,
            alloc_failures,
            storms,
            storm_reclaims,
            price_reclaims,
            host_failures,
            recoveries,
            recovery_secs_sum,
            recovery_secs_max,
            work_lost_mi,
            work_recovered_mi,
            checkpoints,
            checkpoint_mb,
            migrations,
            failed_migrations,
            requeue_latency,
        } = self;
        series.clear();
        events.clear();
        *cap = max_events;
        *dropped_events = 0;
        *interruptions = 0;
        *hibernations = 0;
        *spot_terminations = 0;
        *redeployments = 0;
        *alloc_attempts = 0;
        *alloc_failures = 0;
        *storms = 0;
        *storm_reclaims = 0;
        *price_reclaims = 0;
        *host_failures = 0;
        *recoveries = 0;
        *recovery_secs_sum = 0.0;
        *recovery_secs_max = 0.0;
        *work_lost_mi = 0.0;
        *work_recovered_mi = 0.0;
        *checkpoints = 0;
        *checkpoint_mb = 0.0;
        *migrations = 0;
        *failed_migrations = 0;
        requeue_latency.clear();
    }

    /// Append one sampled-state row (schema: [`SERIES_COLUMNS`]) from an
    /// engine `Sample` tick. Pure projection of the snapshot - the row
    /// math lives here, next to the column schema it must match, so the
    /// engine's sampler stays a counter read plus this call.
    pub fn push_sample(&mut self, now: f64, s: &StateSample) {
        let row = [
            (s.od_running + s.od_warned) as f64,
            (s.spot_running + s.spot_warned) as f64,
            s.hibernated as f64,
            (s.od_waiting + s.spot_waiting) as f64,
            s.used_pes as f64,
            s.total_pes as f64,
            if s.total_ram > 0.0 { s.used_ram / s.total_ram } else { 0.0 },
            if s.total_pes > 0 { s.used_pes as f64 / s.total_pes as f64 } else { 0.0 },
            s.failed_hosts as f64,
            s.displaced as f64,
        ];
        self.series.push(now, &row);
    }

    pub fn log(&mut self, time: f64, vm: VmId, kind: LifecycleKind) {
        if self.events.len() < self.max_events {
            self.events.push(LifecycleEvent { time, vm, kind });
        } else {
            self.dropped_events += 1;
        }
    }

    /// Events dropped due to the cap (observability: no silent truncation).
    pub fn dropped_events(&self) -> u64 {
        self.dropped_events
    }

    /// Move the sampled series out of the recorder (leaving an empty series
    /// sharing the same interned column schema). Callers that outlive the
    /// engine take the data instead of cloning the full per-run time
    /// series; the replacement allocates no strings.
    pub fn take_series(&mut self) -> TimeSeries {
        let empty = TimeSeries::with_columns(self.series.columns_arc());
        std::mem::replace(&mut self.series, empty)
    }

    pub fn events_of(&self, vm: VmId) -> Vec<&LifecycleEvent> {
        self.events.iter().filter(|e| e.vm == vm).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_and_query() {
        let mut r = Recorder::new(10);
        r.log(1.0, 3, LifecycleKind::Submitted);
        r.log(2.0, 3, LifecycleKind::Allocated);
        r.log(2.0, 4, LifecycleKind::Submitted);
        assert_eq!(r.events_of(3).len(), 2);
        assert_eq!(r.events_of(4).len(), 1);
        assert_eq!(r.dropped_events(), 0);
    }

    #[test]
    fn take_series_moves_data_and_keeps_columns() {
        let mut r = Recorder::new(10);
        let width = r.series.columns().len();
        r.series.push(0.0, &vec![0.0; width]);
        let taken = r.take_series();
        assert_eq!(taken.len(), 1);
        assert!(r.series.is_empty());
        assert_eq!(r.series.columns().len(), width);
        // The recorder stays usable after the move.
        r.series.push(1.0, &vec![0.0; width]);
        assert_eq!(r.series.len(), 1);
    }

    /// `reset` returns the recorder to its pristine state (new cap
    /// included) without touching the column schema.
    #[test]
    fn reset_wipes_counters_and_series() {
        let mut r = Recorder::new(1);
        let width = r.series.columns().len();
        r.series.push(0.0, &vec![0.0; width]);
        r.log(0.0, 1, LifecycleKind::Submitted);
        r.log(0.5, 1, LifecycleKind::Allocated); // over cap -> dropped
        r.interruptions = 7;
        r.alloc_attempts = 9;
        r.storms = 3;
        r.storm_reclaims = 12;
        r.price_reclaims = 6;
        r.host_failures = 2;
        r.recoveries = 4;
        r.recovery_secs_sum = 55.0;
        r.recovery_secs_max = 30.0;
        r.work_lost_mi = 1_000.0;
        r.work_recovered_mi = 2_000.0;
        r.checkpoints = 3;
        r.checkpoint_mb = 48.5;
        r.migrations = 2;
        r.failed_migrations = 1;
        r.requeue_latency.push(12.5);
        r.reset(5);
        assert!(r.series.is_empty());
        assert!(r.events.is_empty());
        assert_eq!(r.dropped_events(), 0);
        assert_eq!(r.interruptions, 0);
        assert_eq!(r.alloc_attempts, 0);
        assert_eq!(r.storms, 0);
        assert_eq!(r.storm_reclaims, 0);
        assert_eq!(r.price_reclaims, 0);
        assert_eq!(r.host_failures, 0);
        assert_eq!(r.recoveries, 0);
        assert_eq!(r.recovery_secs_sum, 0.0);
        assert_eq!(r.recovery_secs_max, 0.0);
        assert_eq!(r.work_lost_mi, 0.0);
        assert_eq!(r.work_recovered_mi, 0.0);
        assert_eq!(r.checkpoints, 0);
        assert_eq!(r.checkpoint_mb, 0.0);
        assert_eq!(r.migrations, 0);
        assert_eq!(r.failed_migrations, 0);
        assert!(r.requeue_latency.is_empty());
        assert_eq!(r.series.columns().len(), width);
        for i in 0..5 {
            r.log(i as f64, 0, LifecycleKind::Submitted);
        }
        assert_eq!(r.events.len(), 5, "reset adopted the new event cap");
    }

    #[test]
    fn cap_drops_but_counts() {
        let mut r = Recorder::new(2);
        for i in 0..5 {
            r.log(i as f64, 0, LifecycleKind::Submitted);
        }
        assert_eq!(r.events.len(), 2);
        assert_eq!(r.dropped_events(), 3);
    }
}
