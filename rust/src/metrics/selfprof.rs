//! Self-profiler: samples this process's CPU and memory usage from /proc
//! while a simulation runs - reproducing the paper's Figs. 10-11 ("CPU /
//! memory utilization during one-day simulation"), which chart the
//! *simulator process*, not the simulated cluster.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::series::TimeSeries;

/// One /proc snapshot.
#[derive(Debug, Clone, Copy)]
struct Snapshot {
    /// Process CPU time (user+sys) in clock ticks.
    cpu_ticks: u64,
    /// Resident set size in MB.
    rss_mb: f64,
}

fn read_snapshot() -> Option<Snapshot> {
    let stat = std::fs::read_to_string("/proc/self/stat").ok()?;
    // Fields 14 (utime) and 15 (stime), 1-indexed, after the comm field
    // which may contain spaces - find the closing paren first.
    let rest = &stat[stat.rfind(')')? + 2..];
    let fields: Vec<&str> = rest.split_whitespace().collect();
    let utime: u64 = fields.get(11)?.parse().ok()?;
    let stime: u64 = fields.get(12)?.parse().ok()?;
    let statm = std::fs::read_to_string("/proc/self/statm").ok()?;
    let rss_pages: u64 = statm.split_whitespace().nth(1)?.parse().ok()?;
    let page_kb = 4; // x86-64/aarch64 default page size
    Some(Snapshot { cpu_ticks: utime + stime, rss_mb: (rss_pages * page_kb) as f64 / 1024.0 })
}

/// Background sampler thread producing a (cpu_pct, rss_mb) time series.
pub struct SelfProfiler {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<TimeSeries>>,
}

impl SelfProfiler {
    /// Start sampling every `period`.
    pub fn start(period: Duration) -> Self {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::spawn(move || {
            let mut series = TimeSeries::new(&["cpu_pct", "rss_mb"]);
            let ticks_per_sec = 100.0; // CLK_TCK on linux
            let t0 = Instant::now();
            let mut prev = read_snapshot();
            let mut prev_t = t0;
            while !stop2.load(Ordering::Relaxed) {
                std::thread::sleep(period);
                let now = Instant::now();
                if let (Some(p), Some(c)) = (prev, read_snapshot()) {
                    let dt = now.duration_since(prev_t).as_secs_f64();
                    let cpu_pct = if dt > 0.0 {
                        100.0 * (c.cpu_ticks.saturating_sub(p.cpu_ticks)) as f64
                            / ticks_per_sec
                            / dt
                    } else {
                        0.0
                    };
                    series.push(now.duration_since(t0).as_secs_f64(), &[cpu_pct, c.rss_mb]);
                    prev = Some(c);
                    prev_t = now;
                }
            }
            series
        });
        SelfProfiler { stop, handle: Some(handle) }
    }

    /// Stop sampling and return the collected series.
    pub fn stop(mut self) -> TimeSeries {
        self.stop.store(true, Ordering::Relaxed);
        self.handle.take().expect("profiler already stopped").join().expect("profiler panicked")
    }
}

impl Drop for SelfProfiler {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_reads_proc() {
        let s = read_snapshot().expect("should read /proc on linux");
        assert!(s.rss_mb > 0.0);
    }

    #[test]
    fn profiler_collects_samples() {
        let p = SelfProfiler::start(Duration::from_millis(20));
        // burn some cpu so the percentage is nonzero at least once
        let mut acc: u64 = 0;
        let t0 = Instant::now();
        while t0.elapsed() < Duration::from_millis(120) {
            acc = acc.wrapping_mul(6364136223846793005).wrapping_add(1);
        }
        std::hint::black_box(acc);
        let series = p.stop();
        assert!(series.len() >= 2, "got {} samples", series.len());
        assert!(series.max_of("rss_mb").unwrap() > 0.0);
    }
}
