//! Self-profiler: samples this process's CPU and memory usage from /proc
//! while a simulation runs - reproducing the paper's Figs. 10-11 ("CPU /
//! memory utilization during one-day simulation"), which chart the
//! *simulator process*, not the simulated cluster.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::series::TimeSeries;

/// One /proc snapshot.
#[derive(Debug, Clone, Copy)]
struct Snapshot {
    /// Process CPU time (user+sys) in clock ticks.
    cpu_ticks: u64,
    /// Resident set size in MB.
    rss_mb: f64,
}

/// Current resident set size in MB, read from /proc. Public so worker
/// heartbeats ([`crate::obs::heartbeat`]) can report memory without
/// spinning up a whole sampler thread.
///
/// Off Linux (or anywhere `/proc/self/{stat,statm}` is missing or
/// unparsable) this degrades gracefully to `None` - callers render a
/// placeholder instead of a number, mirroring the parent-watch probe in
/// `sweep worker`, which likewise disarms where `/proc` is unavailable.
/// The profiler then simply collects an empty series; nothing panics.
pub fn rss_mb_now() -> Option<f64> {
    read_snapshot().map(|s| s.rss_mb)
}

fn read_snapshot() -> Option<Snapshot> {
    let stat = std::fs::read_to_string("/proc/self/stat").ok()?;
    // Fields 14 (utime) and 15 (stime), 1-indexed, after the comm field
    // which may contain spaces - find the closing paren first.
    let rest = &stat[stat.rfind(')')? + 2..];
    let fields: Vec<&str> = rest.split_whitespace().collect();
    let utime: u64 = fields.get(11)?.parse().ok()?;
    let stime: u64 = fields.get(12)?.parse().ok()?;
    let statm = std::fs::read_to_string("/proc/self/statm").ok()?;
    let rss_pages: u64 = statm.split_whitespace().nth(1)?.parse().ok()?;
    let page_kb = 4; // x86-64/aarch64 default page size
    Some(Snapshot { cpu_ticks: utime + stime, rss_mb: (rss_pages * page_kb) as f64 / 1024.0 })
}

/// Append one (cpu_pct, rss_mb) sample covering the interval since the
/// previous snapshot. Zero-length intervals are skipped so the series
/// never carries duplicate time points.
fn sample_into(
    series: &mut TimeSeries,
    t0: Instant,
    prev: &mut Option<Snapshot>,
    prev_t: &mut Instant,
) {
    let ticks_per_sec = 100.0; // CLK_TCK on linux
    let now = Instant::now();
    if let (Some(p), Some(c)) = (*prev, read_snapshot()) {
        let dt = now.duration_since(*prev_t).as_secs_f64();
        if dt > 0.0 {
            let cpu_pct =
                100.0 * (c.cpu_ticks.saturating_sub(p.cpu_ticks)) as f64 / ticks_per_sec / dt;
            series.push(now.duration_since(t0).as_secs_f64(), &[cpu_pct, c.rss_mb]);
            *prev = Some(c);
            *prev_t = now;
        }
    }
}

/// Background sampler thread producing a (cpu_pct, rss_mb) time series.
pub struct SelfProfiler {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<TimeSeries>>,
}

impl SelfProfiler {
    /// Start sampling every `period`.
    pub fn start(period: Duration) -> Self {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::spawn(move || {
            let mut series = TimeSeries::new(&["cpu_pct", "rss_mb"]);
            let t0 = Instant::now();
            let mut prev = read_snapshot();
            let mut prev_t = t0;
            while !stop2.load(Ordering::Relaxed) {
                // Sleep in short slices so a stop request is honored
                // promptly even with a long sampling period.
                let mut slept = Duration::ZERO;
                while slept < period && !stop2.load(Ordering::Relaxed) {
                    let slice = (period - slept).min(Duration::from_millis(25));
                    std::thread::sleep(slice);
                    slept += slice;
                }
                if stop2.load(Ordering::Relaxed) {
                    break;
                }
                sample_into(&mut series, t0, &mut prev, &mut prev_t);
            }
            // The stop request almost always lands mid-sleep; without this
            // final sample the partial interval since the last tick (the
            // end of the profiled run) would be dropped entirely.
            sample_into(&mut series, t0, &mut prev, &mut prev_t);
            series
        });
        SelfProfiler { stop, handle: Some(handle) }
    }

    /// Stop sampling and return the collected series.
    pub fn stop(mut self) -> TimeSeries {
        self.stop.store(true, Ordering::Relaxed);
        self.handle.take().expect("profiler already stopped").join().expect("profiler panicked")
    }
}

impl Drop for SelfProfiler {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[cfg(target_os = "linux")]
    #[test]
    fn snapshot_reads_proc() {
        let s = read_snapshot().expect("should read /proc on linux");
        assert!(s.rss_mb > 0.0);
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn rss_reader_is_public_and_sane() {
        let rss = rss_mb_now().expect("should read /proc on linux");
        assert!(rss > 0.0 && rss < 1e6, "implausible RSS {rss} MB");
    }

    /// Off Linux the /proc reads fail; the contract is a graceful `None`
    /// (heartbeats render "-" for RSS) rather than a panic.
    #[cfg(not(target_os = "linux"))]
    #[test]
    fn rss_reader_degrades_to_none_without_proc() {
        if let Some(rss) = rss_mb_now() {
            // Some unixes do ship a compatible /proc; a parsed value must
            // still be sane.
            assert!(rss > 0.0 && rss < 1e6, "implausible RSS {rss} MB");
        }
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn stop_captures_final_partial_interval() {
        // Period far longer than the run: without the final flush sample,
        // stopping mid-first-interval would return an empty series.
        let p = SelfProfiler::start(Duration::from_secs(3600));
        std::thread::sleep(Duration::from_millis(40));
        let series = p.stop();
        assert!(series.len() >= 1, "final partial interval must be sampled");
        assert!(series.max_of("rss_mb").unwrap() > 0.0);
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn profiler_collects_samples() {
        let p = SelfProfiler::start(Duration::from_millis(20));
        // burn some cpu so the percentage is nonzero at least once
        let mut acc: u64 = 0;
        let t0 = Instant::now();
        while t0.elapsed() < Duration::from_millis(120) {
            acc = acc.wrapping_mul(6364136223846793005).wrapping_add(1);
        }
        std::hint::black_box(acc);
        let series = p.stop();
        assert!(series.len() >= 2, "got {} samples", series.len());
        assert!(series.max_of("rss_mb").unwrap() > 0.0);
    }

    /// The profiler must start and stop cleanly even where every /proc
    /// snapshot fails (the series just stays empty).
    #[cfg(not(target_os = "linux"))]
    #[test]
    fn profiler_stops_cleanly_without_proc() {
        let p = SelfProfiler::start(Duration::from_millis(10));
        std::thread::sleep(Duration::from_millis(30));
        let _series = p.stop();
    }
}
