//! Simulation output and monitoring (paper §IV-B "Simulation output and
//! monitoring" + §V-E(e,f)): time series, lifecycle log, table builders,
//! and a /proc-based self-profiler for the paper's Figs. 10-11.

pub mod recorder;
pub mod selfprof;
pub mod series;
pub mod tables;

pub use recorder::{LifecycleEvent, LifecycleKind, Recorder};
pub use series::TimeSeries;
