//! Table builders mirroring the paper's reporting classes (§V-E(f)):
//! `DynamicVmTableBuilder` (Fig. 5), `SpotVmTableBuilder` (Fig. 6) and
//! `ExecutionTableBuilder`, each renderable as text and exportable as CSV.

use crate::engine::world::World;
use crate::util::csv::fmt_num;
use crate::util::table::{Align, TextTable};
use crate::vm::{Vm, VmId, VmType};

/// Fig. 5: one row per VM with its lifecycle summary.
pub fn dynamic_vm_table(world: &World, vms: &[VmId]) -> TextTable {
    let mut t = TextTable::new("SIMULATION RESULTS")
        .column("Broker", Align::Right)
        .column("VM", Align::Right)
        .column("DC", Align::Right)
        .column("Host", Align::Right)
        .column("Host PEs", Align::Right)
        .column("VM PEs", Align::Right)
        .column("Start Time", Align::Right)
        .column("Stop Time", Align::Right)
        .column("Delay", Align::Right)
        .column("Type", Align::Left)
        .column("State", Align::Left);
    for &v in vms {
        let vm = &world.vms[v];
        let host = vm
            .history
            .intervals()
            .last()
            .map(|iv| iv.host)
            .or(vm.host);
        let host_pes = host.map(|h| world.hosts[h].spec.pes);
        t.push(vec![
            vm.broker.to_string(),
            vm.id.to_string(),
            host.map(|h| world.hosts[h].dc.to_string()).unwrap_or_else(|| "-".into()),
            host.map(|h| h.to_string()).unwrap_or_else(|| "-".into()),
            host_pes.map(|p| p.to_string()).unwrap_or_else(|| "-".into()),
            vm.spec.pes.to_string(),
            vm.history.first_start().map(fmt_num).unwrap_or_else(|| "-".into()),
            vm.stopped_at.or(vm.history.last_stop()).map(fmt_num).unwrap_or_else(|| "-".into()),
            fmt_num(vm.submission_delay),
            vm.vm_type.to_string(),
            vm.state.to_string(),
        ]);
    }
    t
}

/// Fig. 6: spot-only table including average interruption time.
pub fn spot_vm_table(world: &World, vms: &[VmId]) -> TextTable {
    let mut t = TextTable::new("SPOT INSTANCE RESULTS")
        .column("Broker", Align::Right)
        .column("VM", Align::Right)
        .column("Host", Align::Right)
        .column("VM PEs", Align::Right)
        .column("Start", Align::Right)
        .column("Stop", Align::Right)
        .column("Interruptions", Align::Right)
        .column("State", Align::Left)
        .column("Avg Interruption s", Align::Right);
    for &v in vms {
        let vm = &world.vms[v];
        if vm.vm_type != VmType::Spot {
            continue;
        }
        t.push(vec![
            vm.broker.to_string(),
            vm.id.to_string(),
            vm.history
                .intervals()
                .last()
                .map(|iv| iv.host.to_string())
                .unwrap_or_else(|| "-".into()),
            vm.spec.pes.to_string(),
            vm.history.first_start().map(fmt_num).unwrap_or_else(|| "-".into()),
            vm.stopped_at.or(vm.history.last_stop()).map(fmt_num).unwrap_or_else(|| "-".into()),
            vm.interruptions.to_string(),
            vm.state.to_string(),
            vm.history.average_interruption_time().map(fmt_num).unwrap_or_else(|| "-".into()),
        ]);
    }
    t
}

/// `ExecutionTableBuilder`: one row per execution interval of each VM.
pub fn execution_table(world: &World, vms: &[VmId]) -> TextTable {
    let mut t = TextTable::new("EXECUTION HISTORY")
        .column("VM", Align::Right)
        .column("Type", Align::Left)
        .column("Interval", Align::Right)
        .column("Host", Align::Right)
        .column("Start", Align::Right)
        .column("Stop", Align::Right)
        .column("Duration", Align::Right);
    for &v in vms {
        let vm: &Vm = &world.vms[v];
        for (i, iv) in vm.history.intervals().iter().enumerate() {
            t.push(vec![
                vm.id.to_string(),
                vm.vm_type.to_string(),
                i.to_string(),
                iv.host.to_string(),
                fmt_num(iv.start),
                iv.stop.map(fmt_num).unwrap_or_else(|| "-".into()),
                iv.stop.map(|s| fmt_num(s - iv.start)).unwrap_or_else(|| "-".into()),
            ]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infra::HostSpec;
    use crate::vm::{SpotConfig, VmSpec, VmState};

    fn world() -> (World, VmId, VmId) {
        let mut w = World::new();
        let dc = w.add_datacenter("dc", 1.0);
        let h = w.add_host(dc, HostSpec::new(8, 1000.0, 16_384.0, 5_000.0, 200_000.0), 0.0);
        let od = w.add_vm(Vm::on_demand(0, VmSpec::new(1000.0, 4)));
        let sp = w.add_vm(Vm::spot(0, VmSpec::new(1000.0, 2), SpotConfig::hibernate()));
        // Simulate lifecycles.
        w.transition_vm(od, VmState::Running);
        w.vms[od].history.record_start(h, 10.0);
        w.vms[od].history.record_stop(32.0);
        w.transition_vm(od, VmState::Finished);
        w.vms[od].stopped_at = Some(32.0);
        w.transition_vm(sp, VmState::Running);
        w.vms[sp].history.record_start(h, 0.0);
        w.vms[sp].history.record_stop(10.0);
        w.vms[sp].history.record_start(h, 32.0);
        w.vms[sp].history.record_stop(43.0);
        w.vms[sp].interruptions = 1;
        w.transition_vm(sp, VmState::Finished);
        w.vms[sp].stopped_at = Some(43.0);
        (w, od, sp)
    }

    #[test]
    fn dynamic_table_has_both_vms() {
        let (w, od, sp) = world();
        let t = dynamic_vm_table(&w, &[od, sp]);
        assert_eq!(t.row_count(), 2);
        let rendered = t.render();
        assert!(rendered.contains("On-Demand"));
        assert!(rendered.contains("Spot"));
        assert!(rendered.contains("FINISHED"));
    }

    #[test]
    fn spot_table_filters_and_reports_interruption() {
        let (w, od, sp) = world();
        let t = spot_vm_table(&w, &[od, sp]);
        assert_eq!(t.row_count(), 1); // only the spot VM
        let rendered = t.render();
        assert!(rendered.contains("22")); // 32 - 10 gap
    }

    #[test]
    fn execution_table_lists_intervals() {
        let (w, od, sp) = world();
        let t = execution_table(&w, &[od, sp]);
        assert_eq!(t.row_count(), 3); // 1 od interval + 2 spot intervals
        assert!(t.to_csv().to_string().contains("Spot"));
    }
}
