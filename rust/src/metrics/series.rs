//! Named multi-column time series with CSV export - the raw material of
//! the paper's Figs. 12 and 13 (active instances over time) and 10-11
//! (utilization during simulation).

use crate::util::csv::{fmt_num, Csv};

/// A time series: one time column plus N named value columns.
#[derive(Debug, Clone)]
pub struct TimeSeries {
    columns: Vec<String>,
    times: Vec<f64>,
    values: Vec<Vec<f64>>, // values[row][col]
}

impl TimeSeries {
    pub fn new(columns: &[&str]) -> Self {
        TimeSeries {
            columns: columns.iter().map(|s| s.to_string()).collect(),
            times: Vec::new(),
            values: Vec::new(),
        }
    }

    pub fn columns(&self) -> &[String] {
        &self.columns
    }

    pub fn len(&self) -> usize {
        self.times.len()
    }

    pub fn is_empty(&self) -> bool {
        self.times.is_empty()
    }

    /// Append a sample; `row` must match the column count and time must be
    /// non-decreasing.
    pub fn push(&mut self, t: f64, row: Vec<f64>) {
        assert_eq!(row.len(), self.columns.len(), "series row width mismatch");
        if let Some(&last) = self.times.last() {
            assert!(t + 1e-9 >= last, "series time went backwards: {t} < {last}");
        }
        self.times.push(t);
        self.values.push(row);
    }

    pub fn times(&self) -> &[f64] {
        &self.times
    }

    /// Column values by name.
    pub fn column(&self, name: &str) -> Option<Vec<f64>> {
        let idx = self.columns.iter().position(|c| c == name)?;
        Some(self.values.iter().map(|r| r[idx]).collect())
    }

    /// Peak value of a column.
    pub fn max_of(&self, name: &str) -> Option<f64> {
        self.column(name)?.into_iter().reduce(f64::max)
    }

    pub fn to_csv(&self) -> Csv {
        let mut header = vec!["time"];
        header.extend(self.columns.iter().map(|s| s.as_str()));
        let mut csv = Csv::new(&header);
        for (t, row) in self.times.iter().zip(&self.values) {
            let mut r = vec![fmt_num(*t)];
            r.extend(row.iter().map(|v| fmt_num(*v)));
            csv.push(r);
        }
        csv
    }

    /// Downsample to at most `n` evenly-spaced rows (for terminal plots).
    pub fn downsample(&self, n: usize) -> TimeSeries {
        if self.len() <= n || n == 0 {
            return self.clone();
        }
        let mut out = TimeSeries {
            columns: self.columns.clone(),
            times: Vec::with_capacity(n),
            values: Vec::with_capacity(n),
        };
        for i in 0..n {
            let idx = i * (self.len() - 1) / (n - 1).max(1);
            out.times.push(self.times[idx]);
            out.values.push(self.values[idx].clone());
        }
        out
    }

    /// Render an ASCII sparkline-style chart of one column (terminal
    /// stand-in for the paper's line figures).
    pub fn ascii_chart(&self, name: &str, width: usize, height: usize) -> String {
        let Some(vals) = self.column(name) else {
            return format!("(no column {name})");
        };
        if vals.is_empty() {
            return "(empty series)".into();
        }
        let ds: Vec<f64> = if vals.len() > width {
            (0..width).map(|i| vals[i * (vals.len() - 1) / (width - 1).max(1)]).collect()
        } else {
            vals.clone()
        };
        let lo = ds.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = ds.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let span = (hi - lo).max(1e-12);
        let mut grid = vec![vec![b' '; ds.len()]; height];
        for (x, v) in ds.iter().enumerate() {
            let y = (((v - lo) / span) * (height as f64 - 1.0)).round() as usize;
            grid[height - 1 - y][x] = b'*';
        }
        let mut out = format!("{name}  [{lo:.1} .. {hi:.1}]\n");
        for row in grid {
            out.push_str(std::str::from_utf8(&row).unwrap());
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> TimeSeries {
        let mut s = TimeSeries::new(&["a", "b"]);
        s.push(0.0, vec![1.0, 10.0]);
        s.push(1.0, vec![2.0, 20.0]);
        s.push(2.0, vec![3.0, 15.0]);
        s
    }

    #[test]
    fn push_and_column_access() {
        let s = sample();
        assert_eq!(s.len(), 3);
        assert_eq!(s.column("a").unwrap(), vec![1.0, 2.0, 3.0]);
        assert_eq!(s.max_of("b"), Some(20.0));
        assert!(s.column("zzz").is_none());
    }

    #[test]
    fn csv_export() {
        let csv = sample().to_csv();
        assert!(csv.to_string().starts_with("time,a,b\n0,1,10\n"));
    }

    #[test]
    fn downsample_keeps_endpoints() {
        let mut s = TimeSeries::new(&["v"]);
        for i in 0..100 {
            s.push(i as f64, vec![i as f64]);
        }
        let d = s.downsample(10);
        assert_eq!(d.len(), 10);
        assert_eq!(d.times()[0], 0.0);
        assert_eq!(*d.times().last().unwrap(), 99.0);
    }

    #[test]
    #[should_panic(expected = "backwards")]
    fn rejects_time_regression() {
        let mut s = TimeSeries::new(&["v"]);
        s.push(5.0, vec![0.0]);
        s.push(1.0, vec![0.0]);
    }

    #[test]
    fn ascii_chart_renders() {
        let c = sample().ascii_chart("a", 40, 5);
        assert!(c.contains('*'));
    }
}
