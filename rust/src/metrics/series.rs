//! Named multi-column time series with CSV export - the raw material of
//! the paper's Figs. 12 and 13 (active instances over time) and 10-11
//! (utilization during simulation).
//!
//! # Storage layout (§Perf: recorder hot path)
//!
//! Samples live in one flat **column-major** buffer: column `c` occupies
//! `values[c * cap .. c * cap + len]`. Appending a sample writes `width`
//! floats in place (no per-row `Vec` allocation, the pre-overhaul
//! row-of-`Vec<f64>` layout paid one heap allocation per sample), and
//! [`TimeSeries::column`] hands back a contiguous `&[f64]` borrow instead
//! of gathering a fresh `Vec`. Column names are interned in an
//! `Arc<[String]>`, so cloning the schema (recorder resets, `take_series`)
//! never re-allocates strings.

use std::borrow::Cow;
use std::sync::Arc;

use crate::util::csv::{fmt_num, Csv};

/// A time series: one time column plus N named value columns.
#[derive(Debug, Clone)]
pub struct TimeSeries {
    columns: Arc<[String]>,
    times: Vec<f64>,
    /// Flat column-major sample storage (see module docs).
    values: Vec<f64>,
    /// Row capacity per column in `values`.
    cap: usize,
}

impl TimeSeries {
    pub fn new(columns: &[&str]) -> Self {
        Self::with_columns(columns.iter().map(|s| s.to_string()).collect())
    }

    /// Construct from an already-interned column schema (cheap: recorder
    /// resets and `take_series` share one `Arc` instead of rebuilding the
    /// strings).
    pub fn with_columns(columns: Arc<[String]>) -> Self {
        TimeSeries { columns, times: Vec::new(), values: Vec::new(), cap: 0 }
    }

    pub fn columns(&self) -> &[String] {
        &self.columns
    }

    /// The interned column schema (shareable via [`Self::with_columns`]).
    pub fn columns_arc(&self) -> Arc<[String]> {
        self.columns.clone()
    }

    pub fn len(&self) -> usize {
        self.times.len()
    }

    pub fn is_empty(&self) -> bool {
        self.times.is_empty()
    }

    /// Drop all samples, keeping the column schema and the allocated
    /// buffers (a reused recorder clears its series between runs).
    pub fn clear(&mut self) {
        self.times.clear();
    }

    /// Append a sample; `row` must match the column count and time must be
    /// non-decreasing.
    pub fn push(&mut self, t: f64, row: &[f64]) {
        assert_eq!(row.len(), self.columns.len(), "series row width mismatch");
        if let Some(&last) = self.times.last() {
            assert!(t + 1e-9 >= last, "series time went backwards: {t} < {last}");
        }
        let len = self.times.len();
        if len == self.cap {
            self.grow();
        }
        for (c, &v) in row.iter().enumerate() {
            self.values[c * self.cap + len] = v;
        }
        self.times.push(t);
    }

    /// Double the per-column row capacity, re-laying the columns out in
    /// the new buffer.
    fn grow(&mut self) {
        let width = self.columns.len();
        let len = self.times.len();
        let new_cap = (self.cap * 2).max(16);
        let mut new_values = vec![0.0; width * new_cap];
        for c in 0..width {
            new_values[c * new_cap..c * new_cap + len]
                .copy_from_slice(&self.values[c * self.cap..c * self.cap + len]);
        }
        self.values = new_values;
        self.cap = new_cap;
    }

    pub fn times(&self) -> &[f64] {
        &self.times
    }

    /// Column values by index (contiguous borrow, no copy).
    fn col(&self, idx: usize) -> &[f64] {
        let len = self.times.len();
        &self.values[idx * self.cap..idx * self.cap + len]
    }

    /// Column values by name (contiguous borrow, no copy).
    pub fn column(&self, name: &str) -> Option<&[f64]> {
        let idx = self.columns.iter().position(|c| c == name)?;
        Some(self.col(idx))
    }

    /// Peak value of a column.
    pub fn max_of(&self, name: &str) -> Option<f64> {
        self.column(name)?.iter().copied().reduce(f64::max)
    }

    pub fn to_csv(&self) -> Csv {
        let mut header = vec!["time"];
        header.extend(self.columns.iter().map(|s| s.as_str()));
        let mut csv = Csv::new(&header);
        let width = self.columns.len();
        for (i, t) in self.times.iter().enumerate() {
            let mut r = vec![fmt_num(*t)];
            for c in 0..width {
                r.push(fmt_num(self.values[c * self.cap + i]));
            }
            csv.push(r);
        }
        csv
    }

    /// Downsample to at most `n` evenly-spaced rows (for terminal plots).
    /// The identity path (already small enough) borrows `self` instead of
    /// deep-copying the series.
    pub fn downsample(&self, n: usize) -> Cow<'_, TimeSeries> {
        if self.len() <= n || n == 0 {
            return Cow::Borrowed(self);
        }
        let width = self.columns.len();
        let mut out = TimeSeries::with_columns(self.columns_arc());
        let mut row = vec![0.0; width];
        for i in 0..n {
            let idx = i * (self.len() - 1) / (n - 1).max(1);
            for (c, slot) in row.iter_mut().enumerate() {
                *slot = self.values[c * self.cap + idx];
            }
            out.push(self.times[idx], &row);
        }
        Cow::Owned(out)
    }

    /// Render an ASCII sparkline-style chart of one column (terminal
    /// stand-in for the paper's line figures).
    pub fn ascii_chart(&self, name: &str, width: usize, height: usize) -> String {
        let Some(vals) = self.column(name) else {
            return format!("(no column {name})");
        };
        if vals.is_empty() {
            return "(empty series)".into();
        }
        let ds: Vec<f64> = if vals.len() > width {
            (0..width).map(|i| vals[i * (vals.len() - 1) / (width - 1).max(1)]).collect()
        } else {
            vals.to_vec()
        };
        let lo = ds.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = ds.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let span = (hi - lo).max(1e-12);
        let mut grid = vec![vec![b' '; ds.len()]; height];
        for (x, v) in ds.iter().enumerate() {
            let y = (((v - lo) / span) * (height as f64 - 1.0)).round() as usize;
            grid[height - 1 - y][x] = b'*';
        }
        let mut out = format!("{name}  [{lo:.1} .. {hi:.1}]\n");
        for row in grid {
            out.push_str(std::str::from_utf8(&row).unwrap());
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> TimeSeries {
        let mut s = TimeSeries::new(&["a", "b"]);
        s.push(0.0, &[1.0, 10.0]);
        s.push(1.0, &[2.0, 20.0]);
        s.push(2.0, &[3.0, 15.0]);
        s
    }

    #[test]
    fn push_and_column_access() {
        let s = sample();
        assert_eq!(s.len(), 3);
        assert_eq!(s.column("a").unwrap(), &[1.0, 2.0, 3.0][..]);
        assert_eq!(s.max_of("b"), Some(20.0));
        assert!(s.column("zzz").is_none());
    }

    #[test]
    fn csv_export() {
        let csv = sample().to_csv();
        assert!(csv.to_string().starts_with("time,a,b\n0,1,10\n"));
    }

    /// Growth across several capacity doublings keeps every column intact.
    #[test]
    fn columns_survive_growth() {
        let mut s = TimeSeries::new(&["x", "y"]);
        for i in 0..1000 {
            s.push(i as f64, &[i as f64, 2.0 * i as f64]);
        }
        let x = s.column("x").unwrap();
        let y = s.column("y").unwrap();
        for i in 0..1000 {
            assert_eq!(x[i], i as f64);
            assert_eq!(y[i], 2.0 * i as f64);
        }
    }

    #[test]
    fn clear_keeps_schema_and_capacity() {
        let mut s = sample();
        s.clear();
        assert!(s.is_empty());
        assert_eq!(s.columns().len(), 2);
        s.push(5.0, &[7.0, 8.0]);
        assert_eq!(s.column("a").unwrap(), &[7.0][..]);
    }

    #[test]
    fn downsample_keeps_endpoints() {
        let mut s = TimeSeries::new(&["v"]);
        for i in 0..100 {
            s.push(i as f64, &[i as f64]);
        }
        let d = s.downsample(10);
        assert_eq!(d.len(), 10);
        assert_eq!(d.times()[0], 0.0);
        assert_eq!(*d.times().last().unwrap(), 99.0);
    }

    /// The identity path borrows instead of deep-copying.
    #[test]
    fn downsample_identity_path_borrows() {
        let s = sample();
        assert!(matches!(s.downsample(100), Cow::Borrowed(_)));
        assert!(matches!(s.downsample(0), Cow::Borrowed(_)));
        assert!(matches!(s.downsample(2), Cow::Owned(_)));
    }

    #[test]
    #[should_panic(expected = "backwards")]
    fn rejects_time_regression() {
        let mut s = TimeSeries::new(&["v"]);
        s.push(5.0, &[0.0]);
        s.push(1.0, &[0.0]);
    }

    #[test]
    fn ascii_chart_renders() {
        let c = sample().ascii_chart("a", 40, 5);
        assert!(c.contains('*'));
    }
}
