//! Mini property-testing harness (proptest is unavailable offline,
//! DESIGN.md §7).
//!
//! `forall(cases, seed, |rng| ...)` runs a closure over `cases` derived
//! RNGs; on panic it reports the failing case index and per-case seed so
//! the exact input reproduces with `forall(1, <that seed>, ...)`. No
//! shrinking - generators should keep inputs small and readable instead.

use crate::stats::Rng;

/// Run `property` for `cases` independent seeded cases; panics with the
/// reproducing seed on failure.
pub fn forall<F: FnMut(&mut Rng) + std::panic::UnwindSafe + Copy>(
    cases: u64,
    seed: u64,
    property: F,
) {
    for case in 0..cases {
        let case_seed = seed ^ case.wrapping_mul(0x9e3779b97f4a7c15);
        let result = std::panic::catch_unwind(move || {
            let mut rng = Rng::new(case_seed);
            let mut p = property;
            p(&mut rng);
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property failed at case {case}/{cases} (reproduce with seed {case_seed:#x}): {msg}"
            );
        }
    }
}

/// Generators for common simulation inputs.
pub mod gen {
    use crate::infra::HostSpec;
    use crate::stats::Rng;
    use crate::vm::{SpotConfig, VmSpec};

    /// A host spec with sensible bounds (1-64 PEs etc.).
    pub fn host_spec(rng: &mut Rng) -> HostSpec {
        HostSpec::new(
            rng.range_u64(1, 64) as u32,
            rng.uniform(500.0, 3_000.0),
            rng.uniform(1_024.0, 262_144.0),
            rng.uniform(1_000.0, 40_000.0),
            rng.uniform(10_000.0, 2_000_000.0),
        )
    }

    /// A VM spec that fits on at least some reasonable host.
    pub fn vm_spec(rng: &mut Rng) -> VmSpec {
        VmSpec::new(rng.uniform(500.0, 2_000.0), rng.range_u64(1, 8) as u32)
            .with_ram(rng.uniform(256.0, 8_192.0))
            .with_bw(rng.uniform(50.0, 2_000.0))
            .with_storage(rng.uniform(1_000.0, 100_000.0))
    }

    /// Random spot configuration.
    pub fn spot_config(rng: &mut Rng) -> SpotConfig {
        let base =
            if rng.chance(0.5) { SpotConfig::hibernate() } else { SpotConfig::terminate() };
        base.with_min_running(rng.uniform(0.0, 60.0))
            .with_warning(rng.uniform(0.0, 30.0))
            .with_hibernation_timeout(rng.uniform(60.0, 1_200.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_runs_all_cases() {
        use std::sync::atomic::{AtomicU64, Ordering};
        static COUNT: AtomicU64 = AtomicU64::new(0);
        forall(25, 1, |_rng| {
            COUNT.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(COUNT.load(Ordering::Relaxed), 25);
    }

    #[test]
    fn forall_reports_failing_seed() {
        let result = std::panic::catch_unwind(|| {
            forall(50, 2, |rng| {
                // fails for roughly half the cases
                assert!(rng.next_f64() < 0.5, "too big");
            });
        });
        let msg = format!("{:?}", result.unwrap_err().downcast_ref::<String>());
        assert!(msg.contains("reproduce with seed"), "{msg}");
    }

    #[test]
    fn generators_produce_valid_specs() {
        forall(50, 3, |rng| {
            let h = gen::host_spec(rng);
            assert!(h.pes >= 1 && h.total_mips() > 0.0);
            let v = gen::vm_spec(rng);
            assert!(v.pes >= 1 && v.ram > 0.0);
            let s = gen::spot_config(rng);
            assert!(s.warning_time >= 0.0);
        });
    }
}
