//! `cloudmarket` CLI - the leader entrypoint.
//!
//! Subcommands map 1:1 onto the paper's evaluation artifacts (DESIGN.md §3):
//!
//! ```text
//! cloudmarket quickstart                     minimal spot lifecycle demo (SVII-A)
//! cloudmarket compare [...]                  Figs. 13-15 algorithm comparison
//! cloudmarket sweep [...]                    parallel multi-seed/policy sweep grid
//! cloudmarket trace [...]                    Fig. 12 + SVII-D trace simulation
//! cloudmarket trace-analysis [...]           Figs. 7-9 concurrency analysis
//! cloudmarket advisor [...]                  Fig. 16 correlation analysis
//! cloudmarket tables                         Tables II-III
//! ```
//!
//! `sweep` fans a multi-axis scenario grid out over worker threads
//! (`--threads`), one cell per (seed, scenario variant): `--seeds N` runs
//! seeds `--seed .. --seed+N-1` under every `--policies` entry, multiplied
//! by any `--axis <name>=<v1,v2,...>` dimensions (spot.warning,
//! spot.hibernation-timeout, spot.behavior, hlem.alpha, victim, substrate,
//! and the `chaos.*` fault families: chaos.host-mtbf, chaos.reclaim-storm,
//! chaos.broker-outage, chaos.demand-surge; and the `market.*` spot-price
//! axes: market.volatility, market.mean-reversion, market.daily-amplitude,
//! market.bid-margin; and the `recovery.*` work-survival axes:
//! recovery.mode, recovery.bandwidth, recovery.checkpoint-threshold)
//! and the `--substrate` list (comparison | trace). Artifacts go to
//! `--out-dir`: `sweep_cells.csv`, `sweep_aggregate.json`, and - for cells
//! matching `--retain-series` - per-cell `sweep_series_cell*.csv` time
//! series. The merged output is bit-identical at any thread count. See
//! `docs/sweep-cookbook.md` for recipes and `docs/cli.md` for the full
//! flag reference.
//!
//! `sweep --workers N` runs the same grid as worker **subprocesses**
//! instead of threads (cost-weighted shards, crashed workers'
//! shards reassigned; identical artifact bytes), and `sweep worker
//! --shard <file> --out <file>` / `sweep merge <partial>...` expose the
//! shard protocol directly for cluster fan-out - see the "Cluster-scale
//! sweeps" section of the cookbook.

use std::path::PathBuf;

use cloudmarket::allocation::{AllocationPolicy, FirstFit, HlemConfig, HlemVmp};
use cloudmarket::config::scenario::ComparisonConfig;
use cloudmarket::experiments::{advisor, compare, trace_analysis, trace_sim};
use cloudmarket::util::cli::{render_help, Args, Spec};

/// Prefix `cmd_sweep_worker` puts on shard-file read/validation errors so
/// `main` can map them to the permanent-failure exit code without the
/// command functions calling `process::exit` (untestable in-process).
const BAD_SHARD_PREFIX: &str = "bad shard: ";

/// Exit-code taxonomy for a failed invocation (see
/// `sweep::shard::EXIT_*`): a rejected shard job file is permanent (the
/// coordinator must not reassign it); everything else is a runtime error.
fn exit_code_for(err: &str) -> i32 {
    if err.starts_with(BAD_SHARD_PREFIX) {
        cloudmarket::sweep::EXIT_BAD_SHARD
    } else {
        cloudmarket::sweep::EXIT_RUNTIME
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            exit_code_for(&e)
        }
    };
    std::process::exit(code);
}

fn specs() -> Vec<Spec> {
    vec![
        Spec { name: "seed", takes_value: true, help: "rng seed (default 20250710)" },
        Spec { name: "runs", takes_value: true, help: "compare: aggregate over N seeds (default 1)" },
        Spec { name: "seeds", takes_value: true, help: "sweep: number of seeds (default 8)" },
        Spec { name: "threads", takes_value: true, help: "sweep: worker threads (default: all CPUs; with --workers: threads per worker process, default 1)" },
        Spec { name: "workers", takes_value: true, help: "sweep: process-level fan-out - spawn N worker subprocesses instead of threads" },
        Spec { name: "shard", takes_value: true, help: "sweep worker: shard job file to run" },
        Spec { name: "out", takes_value: true, help: "sweep worker: partial artifact output path" },
        Spec { name: "policies", takes_value: true, help: "sweep: comma-separated policy list" },
        Spec { name: "axis", takes_value: true, help: "sweep: scenario axis <name>=<v1,v2,...>, repeatable (spot.warning | spot.hibernation-timeout | spot.behavior | hlem.alpha | victim | substrate | chaos.host-mtbf | chaos.reclaim-storm | chaos.broker-outage | chaos.demand-surge | market.volatility | market.mean-reversion | market.daily-amplitude | market.bid-margin | recovery.mode | recovery.bandwidth | recovery.checkpoint-threshold)" },
        Spec { name: "substrate", takes_value: true, help: "sweep: workload substrate list: comparison | trace (default comparison)" },
        Spec { name: "retain-series", takes_value: true, help: "sweep: keep per-cell time series: all | none | policy=<p>,seed=<s>,id=<n>,substrate=<s> (OR; default none)" },
        Spec { name: "alpha", takes_value: true, help: "spot-load factor for adjusted HLEM (default -0.5)" },
        Spec { name: "scorer", takes_value: true, help: "hlem scorer backend: rust | pjrt" },
        Spec { name: "machines", takes_value: true, help: "trace machine count" },
        Spec { name: "days", takes_value: true, help: "trace horizon in days" },
        Spec { name: "spots", takes_value: true, help: "injected spot instances" },
        Spec { name: "max-vms", takes_value: true, help: "cap on trace VMs (scale knob)" },
        Spec { name: "no-profile", takes_value: false, help: "disable the /proc self-profiler" },
        Spec { name: "telemetry", takes_value: false, help: "sweep: write a JSONL telemetry sidecar to <out-dir>/telemetry/ (never changes the artifacts)" },
        Spec { name: "self-profile", takes_value: false, help: "sweep: sample this process's CPU/RSS into the telemetry dir (implies --telemetry)" },
        Spec { name: "verbose", takes_value: false, help: "sweep: print the phase-timing breakdown after the run" },
        Spec { name: "heartbeat", takes_value: true, help: "sweep worker: JSONL file to append progress heartbeats to" },
        Spec { name: "out-dir", takes_value: true, help: "CSV/JSON output directory (default results/)" },
        Spec { name: "advisor", takes_value: true, help: "real spot-advisor JSON (else synthetic)" },
        Spec { name: "help", takes_value: false, help: "show help" },
    ]
}

fn usage() -> String {
    format!(
        "usage: cloudmarket <quickstart|compare|sweep|trace|trace-analysis|advisor|tables> [flags]\n       cloudmarket sweep worker --shard <file> --out <file>\n       cloudmarket sweep merge <partial.json>... [--out-dir <dir>]\n       cloudmarket sweep status <out-dir>\n{}",
        render_help(&specs())
    )
}

fn run(argv: &[String]) -> Result<(), String> {
    let args = Args::parse(argv, &specs())?;
    if args.has("help") || args.positional.is_empty() {
        println!("{}", usage());
        return Ok(());
    }
    let out_dir = PathBuf::from(args.get_or("out-dir", "results"));
    match args.positional[0].as_str() {
        "quickstart" => cmd_quickstart(),
        "compare" => cmd_compare(&args, &out_dir),
        "sweep" => match args.positional.get(1).map(String::as_str) {
            None => cmd_sweep(&args, &out_dir),
            Some("worker") => cmd_sweep_worker(&args),
            Some("merge") => cmd_sweep_merge(&args, &out_dir),
            Some("status") => cmd_sweep_status(&args, &out_dir),
            Some(other) => Err(format!(
                "unknown sweep subcommand '{other}' (expected worker | merge | status, or \
                 flags only)"
            )),
        },
        "trace" => cmd_trace(&args, &out_dir),
        "trace-analysis" => cmd_trace_analysis(&args),
        "advisor" => cmd_advisor(&args),
        "tables" => {
            println!("{}", cloudmarket::config::catalog::host_table().render());
            println!("{}", cloudmarket::config::catalog::vm_table().render());
            Ok(())
        }
        other => Err(format!("unknown command '{other}'\n{}", usage())),
    }
}

/// The §VII-A minimal example: one host, one spot + one delayed on-demand
/// VM, hibernation and resumption.
fn cmd_quickstart() -> Result<(), String> {
    use cloudmarket::cloudlet::Cloudlet;
    use cloudmarket::engine::{Engine, EngineConfig};
    use cloudmarket::infra::HostSpec;
    use cloudmarket::metrics::tables;
    use cloudmarket::vm::{SpotConfig, Vm, VmSpec};

    let mut cfg = EngineConfig::default();
    cfg.min_dt = 0.5; // new CloudSim(0.5)
    cfg.vm_destruction_delay = 1.0; // setVmDestructionDelay(1)
    let mut engine = Engine::new(cfg, Box::new(HlemVmp::plain()));
    let dc = engine.add_datacenter("dc0", 1.0);
    engine.add_host(dc, HostSpec::new(2, 1000.0, 2_048.0, 10_000.0, 1_000_000.0));

    let spot_cfg = SpotConfig::hibernate()
        .with_min_running(0.0)
        .with_warning(0.0)
        .with_hibernation_timeout(100.0);
    let spot = engine.submit_vm(
        Vm::spot(0, VmSpec::new(1000.0, 2), spot_cfg).with_persistent(60.0),
    );
    engine.submit_cloudlet(Cloudlet::new(0, 20_000.0, 2).with_vm(spot));

    let od = engine.submit_vm(Vm::on_demand(0, VmSpec::new(1000.0, 2)).with_delay(10.0));
    engine.submit_cloudlet(Cloudlet::new(0, 20_000.0, 2).with_vm(od));

    engine.terminate_at(70.0); // simulation.terminateAt(70)
    let report = engine.run();

    let all: Vec<usize> = (0..engine.world.vms.len()).collect();
    println!("{}", tables::dynamic_vm_table(&engine.world, &all).render());
    println!("{}", tables::spot_vm_table(&engine.world, &all).render());
    println!("{}", tables::execution_table(&engine.world, &all).render());
    println!("{}", report.render());
    Ok(())
}

/// Build the PJRT-backed HLEM policy (requires `--features pjrt`).
#[cfg(feature = "pjrt")]
fn pjrt_hlem(cfg: HlemConfig) -> Result<Box<dyn AllocationPolicy>, String> {
    let engine = std::rc::Rc::new(
        cloudmarket::runtime::PjrtEngine::load_default()
            .map_err(|e| format!("loading artifacts: {e:#}"))?,
    );
    Ok(Box::new(HlemVmp::with_scorer(
        cfg,
        Box::new(cloudmarket::runtime::PjrtScorer::new(engine)),
    )))
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_hlem(_cfg: HlemConfig) -> Result<Box<dyn AllocationPolicy>, String> {
    Err("scorer 'pjrt' needs the PJRT runtime: add the `xla` and `anyhow` dependencies \
         from your toolchain checkout to rust/Cargo.toml (see the notes on the `pjrt` \
         feature there), then rebuild with `--features pjrt`"
        .into())
}

fn make_hlem(args: &Args, adjusted: bool) -> Result<Box<dyn AllocationPolicy>, String> {
    let alpha = args.get_f64("alpha", -0.5)?;
    let cfg = if adjusted {
        HlemConfig::adjusted().with_alpha(alpha)
    } else {
        HlemConfig::plain()
    };
    Ok(match args.get_or("scorer", "rust").as_str() {
        "rust" => Box::new(HlemVmp::new(cfg)),
        "pjrt" => pjrt_hlem(cfg)?,
        other => return Err(format!("unknown scorer '{other}'")),
    })
}

fn cmd_compare(args: &Args, out_dir: &std::path::Path) -> Result<(), String> {
    let seed = args.get_u64("seed", 20_250_710)?;
    let cfg = ComparisonConfig { seed, ..Default::default() };

    println!("{}", cloudmarket::config::catalog::host_table().render());
    println!("{}", cloudmarket::config::catalog::vm_table().render());

    let mut outcomes = Vec::new();
    let policies: Vec<(&str, Box<dyn AllocationPolicy>)> = vec![
        ("first-fit", Box::new(FirstFit::new())),
        ("hlem-vmp", make_hlem(args, false)?),
        ("hlem-vmp-adjusted", make_hlem(args, true)?),
    ];
    for (name, policy) in policies {
        eprintln!("running {name} ...");
        outcomes.push(compare::run_policy(move || policy, &cfg));
    }

    println!("{}", compare::fig14_table(&outcomes).render());
    println!("{}", compare::fig15_table(&outcomes).render());
    println!("{}", compare::shape_summary(&outcomes));
    for o in &outcomes {
        println!("\n[{}] {}", o.policy, o.report.render());
    }
    compare::fig13_csv(&outcomes)
        .write_file(&out_dir.join("fig13_active_instances.csv"))
        .map_err(|e| e.to_string())?;
    println!("\nwrote {}", out_dir.join("fig13_active_instances.csv").display());

    let runs = args.get_usize("runs", 1)?;
    if runs > 1 {
        eprintln!("aggregating over {runs} seeds ...");
        let aggs = compare::run_multi(&cfg, runs);
        println!("{}", compare::aggregate_table(&aggs).render());
    }
    Ok(())
}

/// `cloudmarket sweep`: fan a multi-axis scenario grid out over a worker
/// pool. One cell per (seed, scenario variant); merged output is
/// deterministic regardless of `--threads`.
fn cmd_sweep(args: &Args, out_dir: &std::path::Path) -> Result<(), String> {
    use cloudmarket::obs::telemetry as tel;
    use cloudmarket::sweep::{self, CellResult, PolicySpec, ScenarioAxis, SeriesFilter, Substrate, SweepSpec};

    let seed = args.get_u64("seed", 20_250_710)?;
    let seeds = args.get_positive_usize("seeds", 8)?;
    let threads = args.get_positive_usize("threads", sweep::default_threads())?;
    let alpha = args.get_f64("alpha", -0.5)?;
    let policies = match args.get("policies") {
        None => PolicySpec::paper_with_alpha(alpha),
        Some(list) => PolicySpec::parse_list(list, alpha)?,
    };
    if args.get_or("scorer", "rust") != "rust" {
        return Err("sweep cells build policies per worker thread; only the in-process \
                    'rust' scorer is supported (pjrt handles are not Send)"
            .into());
    }
    let mut axes: Vec<ScenarioAxis> = args
        .get_all("axis")
        .into_iter()
        .map(ScenarioAxis::parse)
        .collect::<Result<_, _>>()?;
    if let Some(subs) = args.get("substrate") {
        // Silently stacking a second substrate axis would duplicate every
        // variant (each cell would run once per copy).
        if axes.iter().any(|a| matches!(a, ScenarioAxis::Substrate(_))) {
            return Err("--substrate and --axis substrate=... declare the same axis; \
                        pass only one"
                .into());
        }
        axes.push(ScenarioAxis::Substrate(Substrate::parse_list(subs)?));
    }
    // A repeated axis name would silently overwrite the earlier values
    // (last expansion wins per field) or duplicate every variant.
    for (i, a) in axes.iter().enumerate() {
        if axes[..i].iter().any(|b| b.name() == a.name()) {
            return Err(format!(
                "axis '{}' declared more than once; merge its values into one --axis flag",
                a.name()
            ));
        }
    }
    // An alpha axis multiplies only alpha-sensitive policies; with none in
    // the list it would expand nothing and silently run a no-op "sweep".
    if axes.iter().any(|a| matches!(a, ScenarioAxis::HlemAlpha(_)))
        && !policies.iter().any(|p| p.alpha_sensitive())
    {
        return Err("--axis hlem.alpha requires an alpha-sensitive policy \
                    (hlem-vmp-adjusted) in --policies"
            .into());
    }
    let retain = match args.get("retain-series") {
        None => SeriesFilter::none(),
        Some(f) => SeriesFilter::parse(f)?,
    };

    let scenario = ComparisonConfig { seed, ..Default::default() };
    let n_policies = policies.len();
    let mut spec = SweepSpec::new(scenario)
        .with_seed_range(seed, seeds)
        .with_policies(policies)
        .with_axes(axes)
        .with_series_retention(retain);
    // Trace-substrate scale knobs shared with `cloudmarket trace`.
    spec.trace.synth.machines = args.get_usize("machines", spec.trace.synth.machines)?;
    spec.trace.synth.days = args.get_f64("days", spec.trace.synth.days)?;
    spec.trace.workload.spot_instances =
        args.get_usize("spots", spec.trace.workload.spot_instances)?;
    spec.trace.workload.max_trace_vms =
        args.get_usize("max-vms", spec.trace.workload.max_trace_vms)?;

    let n_variants = spec.variants().len();
    let total = spec.cell_count();
    let workers = match args.get("workers") {
        None => None,
        Some(_) => Some(args.get_positive_usize("workers", 1)?),
    };
    let mode = match workers {
        Some(w) => format!("{w} worker processes"),
        None => format!("{threads} threads"),
    };
    eprintln!(
        "sweep: {total} cells ({seeds} seeds x {n_variants} variants over {n_policies} \
         policies) on {mode} ..."
    );

    fn progress(done: usize, total: usize, r: &CellResult) {
        let status = if r.outcome.is_ok() { "ok" } else { "FAILED" };
        eprintln!(
            "  [{done:>3}/{total}] cell {:<3} {:<18} seed={} {:<12} {status}",
            r.cell.id,
            r.cell.policy().name(),
            r.cell.seed,
            r.cell.spec.variant_label(),
        );
    }

    // Observability sidecar (the two-channel rule: everything below goes
    // to <out-dir>/telemetry/ and never touches the artifact bytes).
    let telemetry = if args.has("telemetry") || args.has("self-profile") {
        Some(std::sync::Arc::new(
            cloudmarket::obs::Telemetry::create(out_dir)
                .map_err(|e| format!("creating telemetry sidecar: {e}"))?,
        ))
    } else {
        None
    };
    let profiler = args
        .has("self-profile")
        .then(|| cloudmarket::metrics::selfprof::SelfProfiler::start(
            std::time::Duration::from_millis(250),
        ));
    let run_started = std::time::Instant::now();
    if let Some(t) = &telemetry {
        t.emit(tel::run_start(
            &sweep::shard::spec_digest(&spec),
            total,
            n_variants,
            seeds,
            if workers.is_some() { "workers" } else { "threads" },
            workers.unwrap_or(threads),
        ));
    }

    let (report, timing) = match workers {
        Some(w) => {
            // Process-level fan-out: shard files + worker subprocesses in
            // out_dir, crashed workers' shards reassigned, merged by cell
            // id - byte-identical artifacts to the thread path below.
            let exe = std::env::current_exe()
                .map_err(|e| format!("locating the cloudmarket binary: {e}"))?;
            let mut opts = sweep::CoordinateOptions::new(w, out_dir, exe);
            opts.worker_threads = args.get_positive_usize("threads", 1)?;
            opts.verbose = true;
            opts.telemetry = telemetry.clone();
            if telemetry.is_some() {
                opts.heartbeat_dir = Some(cloudmarket::obs::telemetry_dir(out_dir));
            }
            let outcome = sweep::coordinate(&spec, &opts)?;
            eprintln!(
                "sweep: {} shard(s) done on {} worker process(es) spawned ({} reassigned)",
                outcome.shards, outcome.workers_spawned, outcome.shards_reassigned
            );
            (outcome.report, None)
        }
        None => {
            let (report, timing) =
                sweep::run_observed(&spec, threads, Some(&progress), telemetry.as_deref());
            (report, Some(timing))
        }
    };

    if args.has("verbose") {
        if let Some(t) = &timing {
            eprintln!("{}", phase_table(t).render());
            eprintln!("sweep: {} lazy prebuild(s) built", t.prebuilds_built);
        }
    }
    if let Some(prof) = profiler {
        let series = prof.stop();
        let path = cloudmarket::obs::telemetry_dir(out_dir).join("selfprofile.csv");
        series.to_csv().write_file(&path).map_err(|e| e.to_string())?;
        eprintln!(
            "sweep: self-profile cpu peak {:.0}%  rss peak {:.0} MB ({} samples) -> {}",
            series.max_of("cpu_pct").unwrap_or(0.0),
            series.max_of("rss_mb").unwrap_or(0.0),
            series.len(),
            path.display()
        );
    }
    if let Some(t) = &telemetry {
        use std::time::Duration;
        let ok = report.failed() == 0;
        t.emit(match timing {
            Some(ti) => tel::run_end(
                ok,
                ti.wall,
                ti.prebuild_busy,
                ti.cell_busy,
                ti.merge,
                ti.first_cell_done,
                ti.prebuilds_built,
            ),
            // The coordinator path has no in-process phase breakdown;
            // only end-to-end wall time is meaningful.
            None => tel::run_end(
                ok,
                run_started.elapsed(),
                Duration::ZERO,
                Duration::ZERO,
                Duration::ZERO,
                Duration::ZERO,
                0,
            ),
        });
    }

    finish_sweep(&report, out_dir)
}

/// Render a [`SweepTiming`](cloudmarket::sweep::SweepTiming) as the
/// `--verbose` phase table (also the shape `sweep status` prints from a
/// run log's `run_end` event).
fn phase_table(t: &cloudmarket::sweep::SweepTiming) -> cloudmarket::util::table::TextTable {
    use cloudmarket::util::table::{Align, TextTable};
    let mut table = TextTable::new("Sweep phase breakdown")
        .column("phase", Align::Left)
        .column("ms", Align::Right);
    let ms = |d: std::time::Duration| format!("{:.1}", d.as_secs_f64() * 1e3);
    table.push(vec!["wall".into(), ms(t.wall)]);
    table.push(vec!["prebuild busy (summed)".into(), ms(t.prebuild_busy)]);
    table.push(vec!["cell busy (summed)".into(), ms(t.cell_busy)]);
    table.push(vec!["merge".into(), ms(t.merge)]);
    table.push(vec!["first cell done".into(), ms(t.first_cell_done)]);
    table
}

/// Shared epilogue of `sweep`, `sweep --workers` and `sweep merge`:
/// render the aggregate table, write the artifacts, and turn cell
/// failures into a non-zero exit. Partial sweeps must not look like
/// clean successes to callers gating on the exit status; the artifacts
/// still record the completed cells and each failure's message.
fn finish_sweep(
    report: &cloudmarket::sweep::SweepReport,
    out_dir: &std::path::Path,
) -> Result<(), String> {
    println!("{}", report.aggregate_table().render());
    let cells_path = write_sweep_artifacts(report, out_dir)?;
    if report.failed() > 0 {
        return Err(format!(
            "{}/{} sweep cells failed (per-cell errors in {})",
            report.failed(),
            report.total(),
            cells_path.display()
        ));
    }
    Ok(())
}

/// Serialize a sweep report into `out_dir` (`sweep_cells.csv`,
/// `sweep_aggregate.json`, retained `sweep_series_cell*.csv`), removing
/// stale series files from a previous run into the same directory first.
/// Shared by the thread, `--workers` and `sweep merge` paths so every
/// mode writes identical bytes for identical reports. Returns the cells
/// CSV path (named in failure messages).
fn write_sweep_artifacts(
    report: &cloudmarket::sweep::SweepReport,
    out_dir: &std::path::Path,
) -> Result<PathBuf, String> {
    std::fs::create_dir_all(out_dir).map_err(|e| e.to_string())?;
    let cells_path = out_dir.join("sweep_cells.csv");
    report.cells_csv().write_file(&cells_path).map_err(|e| e.to_string())?;
    let agg_path = out_dir.join("sweep_aggregate.json");
    std::fs::write(&agg_path, report.aggregate_json().to_string_pretty())
        .map_err(|e| e.to_string())?;
    println!("wrote {} and {}", cells_path.display(), agg_path.display());
    // Series filenames depend on the grid and filter, so stale files from
    // a previous run into the same directory would otherwise survive and
    // masquerade as this run's output.
    if let Ok(entries) = std::fs::read_dir(out_dir) {
        for entry in entries.flatten() {
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if name.starts_with("sweep_series_cell") && name.ends_with(".csv") {
                std::fs::remove_file(entry.path()).map_err(|e| e.to_string())?;
            }
        }
    }
    let retained = report.retained_series_csvs();
    if !retained.is_empty() {
        for (id, csv) in &retained {
            csv.write_file(&out_dir.join(format!("sweep_series_cell{id:04}.csv")))
                .map_err(|e| e.to_string())?;
        }
        println!(
            "wrote {} retained series ({})",
            retained.len(),
            out_dir.join("sweep_series_cell*.csv").display()
        );
    }
    Ok(cells_path)
}

/// `cloudmarket sweep worker --shard <file> --out <file>`: run one shard
/// of a sweep in this process (via the shard file's embedded spec) and
/// write a self-contained partial artifact for `sweep merge` or the
/// `--workers` coordinator. Cell failures become error rows, not a
/// non-zero exit - the merge step decides what a failed cell means.
fn cmd_sweep_worker(args: &Args) -> Result<(), String> {
    use cloudmarket::sweep::{self, shard};

    let shard_path = PathBuf::from(
        args.get("shard").ok_or("sweep worker requires --shard <file>")?,
    );
    let out_path =
        PathBuf::from(args.get("out").ok_or("sweep worker requires --out <file>")?);
    let threads = args.get_positive_usize("threads", 1)?;
    // A shard file that fails to read/validate is a *permanent* problem -
    // corrupt bytes or a foreign spec digest stay wrong on every retry -
    // so tag the error for `main` to map onto EXIT_BAD_SHARD instead of
    // the generic runtime failure the coordinator would reassign.
    let (spec, job) = shard::read_shard_file(&shard_path)
        .map_err(|e| format!("{BAD_SHARD_PREFIX}{e}"))?;
    let cells = spec.cells();
    let selected: Vec<sweep::Cell> = job.cell_ids.iter().map(|&id| cells[id]).collect();

    // A stale partial from a crashed earlier run must not outlive this
    // attempt: if we die mid-run, the caller sees a missing file, never
    // an old one (writes below are atomic tmp+rename).
    let _ = std::fs::remove_file(&out_path);

    // Test-only fault injection (tests/sweep_process.rs): with
    // CLOUDMARKET_SWEEP_FAULT=<shard_index>:<marker_path> targeting this
    // shard and the marker not yet present, the marker is created and the
    // worker aborts right after its first completed cell - a real
    // mid-shard death for the coordinator's reassignment path. The
    // marker makes the fault one-shot: the reassigned attempt runs clean.
    let armed = match std::env::var("CLOUDMARKET_SWEEP_FAULT") {
        Ok(fault) => match fault.split_once(':') {
            Some((idx, marker)) if idx.parse::<usize>().ok() == Some(job.index) => {
                let marker = PathBuf::from(marker);
                !marker.exists() && std::fs::write(&marker, b"fault fired\n").is_ok()
            }
            _ => false,
        },
        Err(_) => false,
    };
    // Same-host workers die with their coordinator: `--workers` sets
    // CLOUDMARKET_SWEEP_PARENT to the coordinator's PID, and between
    // cells the worker checks it is still alive (via /proc on Linux; the
    // watchdog stays disarmed where that probe is unavailable, and for
    // manually-launched cluster workers, which have no such env). This
    // covers the abort paths no coordinator-side cleanup can - Ctrl-C or
    // SIGKILL of the coordinator - so orphans never run their full shard
    // or rename partials into a later run's work dir.
    let parent_probe: Option<PathBuf> = std::env::var("CLOUDMARKET_SWEEP_PARENT")
        .ok()
        .and_then(|pid| pid.parse::<u32>().ok())
        .map(|pid| PathBuf::from(format!("/proc/{pid}")))
        .filter(|probe| probe.exists());
    let watch_parent = parent_probe.is_some();
    // Sidecar heartbeats: one line at start, one per completed cell, one
    // at the end. A heartbeat failure never fails the shard.
    let heartbeat = match args.get("heartbeat") {
        Some(path) => {
            let path = PathBuf::from(path);
            match cloudmarket::obs::HeartbeatWriter::create(&path, job.index, selected.len()) {
                Ok(writer) => Some(writer),
                Err(e) => {
                    eprintln!(
                        "sweep worker: cannot create heartbeat file {} ({e}); running \
                         without heartbeats",
                        path.display()
                    );
                    None
                }
            }
        }
        None => None,
    };
    let hb = &heartbeat;
    let per_cell = move |done: usize, _total: usize, r: &sweep::CellResult| {
        if let Some(h) = hb {
            h.beat(done, Some(r.cell.id));
        }
        if armed && done >= 1 {
            eprintln!("sweep worker: injected fault firing (aborting mid-shard)");
            std::process::abort();
        }
        if let Some(probe) = &parent_probe {
            if !probe.exists() {
                eprintln!("sweep worker: coordinator is gone; exiting mid-shard");
                std::process::exit(cloudmarket::sweep::EXIT_PARENT_GONE);
            }
        }
    };

    eprintln!(
        "sweep worker: shard {}/{} ({} cells) on {threads} thread(s) ...",
        job.index,
        job.of,
        selected.len()
    );
    if let Some(h) = &heartbeat {
        h.beat(0, None);
    }
    let results = sweep::run_cells(
        &spec,
        &selected,
        threads,
        if armed || watch_parent || heartbeat.is_some() { Some(&per_cell) } else { None },
    );
    let failed = results.iter().filter(|r| r.outcome.is_err()).count();
    shard::write_partial(&out_path, &spec, job.index, &results)?;
    if let Some(h) = &heartbeat {
        h.beat(results.len(), None);
    }
    eprintln!(
        "sweep worker: shard {} done ({} cells, {failed} failed) -> {}",
        job.index,
        results.len(),
        out_path.display()
    );
    Ok(())
}

/// `cloudmarket sweep merge <partial.json>...`: recombine worker partials
/// (same host or copied in from a cluster) into the standard sweep
/// artifacts. Refuses partials from different specs and overlapping or
/// incomplete cell coverage; the merged bytes equal a single-process run.
fn cmd_sweep_merge(args: &Args, out_dir: &std::path::Path) -> Result<(), String> {
    use cloudmarket::sweep::shard;

    let inputs = &args.positional[2..];
    if inputs.is_empty() {
        return Err(
            "sweep merge requires partial files: cloudmarket sweep merge <partial.json>... \
             [--out-dir <dir>]"
                .into(),
        );
    }
    let partials = inputs
        .iter()
        .map(|p| shard::read_partial(std::path::Path::new(p)))
        .collect::<Result<Vec<_>, _>>()?;
    let (_spec, report) = shard::merge_partials(partials)?;
    finish_sweep(&report, out_dir)
}

/// `cloudmarket sweep status <out-dir>`: render a live or post-hoc run
/// summary from the telemetry sidecar - manifest, per-shard heartbeat
/// progress, slowest cells, phase breakdown and engine-counter totals.
/// Reads only the sidecar channel; works mid-run (a torn final JSONL line
/// is tolerated) and validates every complete line against the schema.
fn cmd_sweep_status(args: &Args, out_dir: &std::path::Path) -> Result<(), String> {
    use cloudmarket::obs::{self, telemetry as tel, EngineCounters};
    use cloudmarket::util::json::Json;
    use cloudmarket::util::table::{Align, TextTable};

    let dir = match args.positional.get(2) {
        Some(p) => PathBuf::from(p),
        None => out_dir.to_path_buf(),
    };
    let tdir = obs::telemetry_dir(&dir);
    let log = tdir.join(obs::RUN_LOG);
    if !log.exists() {
        return Err(format!(
            "no telemetry sidecar at {} (run `cloudmarket sweep --telemetry` first)",
            log.display()
        ));
    }
    let lines = obs::read_jsonl(&log).map_err(|e| e.to_string())?;

    let mut manifest: Option<String> = None;
    let mut cells_ok = 0usize;
    let mut cells_failed = 0usize;
    let mut totals = EngineCounters::default();
    let mut cell_ms: Vec<(usize, f64)> = Vec::new();
    let mut prebuilds = 0usize;
    let mut prebuild_ms = 0.0;
    let mut assigns = 0usize;
    let mut reassigns = 0usize;
    let mut stalls = 0usize;
    let mut run_end: Option<cloudmarket::sweep::SweepTiming> = None;
    let mut run_ok: Option<bool> = None;
    for (i, line) in lines.iter().enumerate() {
        let event = obs::validate_event(line)
            .map_err(|e| format!("{} line {}: {e}", log.display(), i + 1))?;
        let o = line.as_obj().expect("validated events are objects");
        let num = |key: &str| o.get(key).and_then(Json::as_f64).unwrap_or(0.0);
        match event {
            "run_start" => {
                manifest = Some(format!(
                    "spec {}  {} cells ({} variants x {} seeds), {} mode, parallelism {}",
                    o.get("spec_digest").and_then(Json::as_str).unwrap_or("?"),
                    num("cells"),
                    num("variants"),
                    num("seeds"),
                    o.get("mode").and_then(Json::as_str).unwrap_or("?"),
                    num("parallelism"),
                ));
            }
            "cell_end" => {
                if o.get("ok").and_then(Json::as_bool).unwrap_or(false) {
                    cells_ok += 1;
                } else {
                    cells_failed += 1;
                }
                if let Some(c) = o.get("counters").and_then(|c| EngineCounters::from_json(c)) {
                    totals.add(&c);
                }
                cell_ms.push((num("cell") as usize, num("ms")));
            }
            "prebuild" => {
                prebuilds += 1;
                prebuild_ms += num("ms");
            }
            "shard_assign" => assigns += 1,
            "shard_reassign" => reassigns += 1,
            "stall" => stalls += 1,
            "run_end" => {
                let d = |key: &str| std::time::Duration::from_secs_f64(num(key).max(0.0) / 1e3);
                run_ok = o.get("ok").and_then(Json::as_bool);
                run_end = Some(cloudmarket::sweep::SweepTiming {
                    wall: d("wall_ms"),
                    prebuild_busy: d("prebuild_busy_ms"),
                    cell_busy: d("cell_busy_ms"),
                    merge: d("merge_ms"),
                    first_cell_done: d("first_cell_done_ms"),
                    prebuilds_built: num("prebuilds_built") as usize,
                });
            }
            _ => {}
        }
    }

    println!("sweep status: {} ({} events)", log.display(), lines.len());
    if let Some(m) = manifest {
        println!("  {m}");
    }
    match run_ok {
        Some(true) => println!("  run finished: ok"),
        Some(false) => println!("  run finished: FAILED cells"),
        None => println!("  run in progress (no run_end event yet)"),
    }
    println!(
        "  cells: {cells_ok} ok, {cells_failed} failed; {prebuilds} prebuild(s) \
         ({prebuild_ms:.1} ms)"
    );
    if assigns > 0 {
        println!("  shards: {assigns} assigned, {reassigns} reassigned, {stalls} stall warning(s)");
    }

    // Per-shard last-known progress from the heartbeat files.
    let mut hb_paths: Vec<PathBuf> = std::fs::read_dir(&tdir)
        .map(|entries| {
            entries
                .flatten()
                .map(|e| e.path())
                .filter(|p| {
                    p.file_name()
                        .map(|n| {
                            let n = n.to_string_lossy();
                            n.starts_with("heartbeat-") && n.ends_with(".jsonl")
                        })
                        .unwrap_or(false)
                })
                .collect()
        })
        .unwrap_or_default();
    hb_paths.sort();
    if !hb_paths.is_empty() {
        let mut table = TextTable::new("Shard heartbeats")
            .column("shard", Align::Right)
            .column("progress", Align::Right)
            .column("last cell", Align::Right)
            .column("rss MB", Align::Right)
            .column("age s", Align::Right);
        for path in &hb_paths {
            if let Some(h) = obs::read_last_heartbeat(path) {
                let age = tel::now_ms().saturating_sub(h.ts_ms) as f64 / 1e3;
                table.push(vec![
                    h.shard.to_string(),
                    format!("{}/{}", h.done, h.total),
                    h.cell.map(|c| c.to_string()).unwrap_or_else(|| "-".into()),
                    h.rss_mb.map(|r| format!("{r:.0}")).unwrap_or_else(|| "-".into()),
                    format!("{age:.1}"),
                ]);
            }
        }
        if table.row_count() > 0 {
            println!("{}", table.render());
        }
    }

    // Slowest cells (wall time is sidecar-only data, so this exists only
    // here, never in the artifacts).
    if !cell_ms.is_empty() {
        cell_ms.sort_by(|a, b| b.1.total_cmp(&a.1));
        let mut table = TextTable::new("Slowest cells")
            .column("cell", Align::Right)
            .column("ms", Align::Right);
        for (cell, ms) in cell_ms.iter().take(5) {
            table.push(vec![cell.to_string(), format!("{ms:.1}")]);
        }
        println!("{}", table.render());
    }

    if let Some(t) = run_end {
        println!("{}", phase_table(&t).render());
    }

    let mut table = TextTable::new("Engine counter totals")
        .column("counter", Align::Left)
        .column("total", Align::Right);
    for (name, value) in [
        ("events popped", totals.events_popped),
        ("queue high-water (max)", totals.queue_high_water),
        ("placement probes", totals.placement_probes),
        ("placement hits", totals.placement_hits),
        ("preemption scans", totals.preemption_scans),
        ("chaos events", totals.chaos_events),
        ("market events", totals.market_events),
        ("recovery events", totals.recovery_events),
    ] {
        table.push(vec![name.into(), value.to_string()]);
    }
    println!("{}", table.render());
    Ok(())
}

fn cmd_trace(args: &Args, out_dir: &std::path::Path) -> Result<(), String> {
    let mut cfg = trace_sim::TraceSimConfig::default();
    cfg.synth.seed = args.get_u64("seed", 42)?;
    cfg.synth.machines = args.get_usize("machines", cfg.synth.machines)?;
    cfg.synth.days = args.get_f64("days", cfg.synth.days)?;
    cfg.workload.spot_instances = args.get_usize("spots", cfg.workload.spot_instances)?;
    cfg.workload.max_trace_vms = args.get_usize("max-vms", cfg.workload.max_trace_vms)?;
    cfg.profile = !args.has("no-profile");

    eprintln!(
        "simulating {} machines x {:.1} days, {} spots ...",
        cfg.synth.machines, cfg.synth.days, cfg.workload.spot_instances
    );
    let out = trace_sim::run(&cfg);
    println!("{}", trace_sim::results_table(&out).render());
    println!("{}", out.series.ascii_chart("spot_running", 100, 12));

    trace_sim::fig12_csv(&out)
        .write_file(&out_dir.join("fig12_active_instances.csv"))
        .map_err(|e| e.to_string())?;
    if let Some(prof) = &out.selfprof {
        prof.to_csv()
            .write_file(&out_dir.join("fig10_11_selfprofile.csv"))
            .map_err(|e| e.to_string())?;
        println!(
            "self-profile: cpu peak {:.0}%  rss peak {:.0} MB ({} samples)",
            prof.max_of("cpu_pct").unwrap_or(0.0),
            prof.max_of("rss_mb").unwrap_or(0.0),
            prof.len()
        );
    }
    println!("wrote {}", out_dir.join("fig12_active_instances.csv").display());
    Ok(())
}

fn cmd_trace_analysis(args: &Args) -> Result<(), String> {
    let seed = args.get_u64("seed", 42)?;
    let machines = args.get_usize("machines", 200)?;
    eprintln!("generating 30-day trace ({machines} machines) ...");
    let trace = trace_analysis::month_trace(seed, machines);
    println!("{}", trace_analysis::fig7_table(&trace).render());
    println!("{}", trace_analysis::fig8_table(&trace).render());
    println!("{}", trace_analysis::fig9_table(&trace).render());
    Ok(())
}

fn cmd_advisor(args: &Args) -> Result<(), String> {
    let seed = args.get_u64("seed", 7)?;
    let path = args.get("advisor").map(PathBuf::from);
    let ds = advisor::dataset(path.as_deref(), seed);
    println!("dataset: {} instance types, {} families", ds.rows.len(), ds.family_names.len());
    println!("{}", advisor::class_distribution_table(&ds).render());
    println!("{}", advisor::fig16_table(&ds).render());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    /// `sweep` help/usage smoke test: the subcommand is advertised and
    /// `--help` short-circuits before any cell runs.
    #[test]
    fn usage_mentions_sweep_and_its_flags() {
        let u = usage();
        assert!(u.contains("sweep"), "{u}");
        assert!(u.contains("sweep worker"), "{u}");
        assert!(u.contains("sweep merge"), "{u}");
        for flag in [
            "--threads",
            "--seeds",
            "--policies",
            "--out-dir",
            "--axis",
            "--substrate",
            "--retain-series",
            "--workers",
            "--shard",
            "--out",
        ] {
            assert!(u.contains(flag), "usage missing {flag}:\n{u}");
        }
    }

    #[test]
    fn sweep_help_smoke() {
        assert!(run(&argv(&["sweep", "--help"])).is_ok());
    }

    /// Bad sweep flags fail fast (before the grid fans out).
    #[test]
    fn sweep_rejects_bad_counts_and_policies() {
        let err = run(&argv(&["sweep", "--threads", "0"])).unwrap_err();
        assert!(err.contains("must be >= 1"), "{err}");
        let err = run(&argv(&["sweep", "--seeds", "0"])).unwrap_err();
        assert!(err.contains("must be >= 1"), "{err}");
        let err = run(&argv(&["sweep", "--threads", "abc"])).unwrap_err();
        assert!(err.contains("expects an integer"), "{err}");
        let err = run(&argv(&["sweep", "--policies", "bogus"])).unwrap_err();
        assert!(err.contains("unknown policy"), "{err}");
        assert!(run(&argv(&["sweep", "--scorer", "pjrt"])).is_err());
    }

    /// Bad axis/substrate/retention flags fail fast too.
    #[test]
    fn sweep_rejects_bad_axes_and_filters() {
        let err = run(&argv(&["sweep", "--axis", "spot.warning"])).unwrap_err();
        assert!(err.contains("must be <name>=<v1,v2,...>"), "{err}");
        let err = run(&argv(&["sweep", "--axis", "frobnicate=1"])).unwrap_err();
        assert!(err.contains("unknown axis"), "{err}");
        let err = run(&argv(&["sweep", "--axis", "spot.warning=-5"])).unwrap_err();
        assert!(err.contains("negative"), "{err}");
        let err = run(&argv(&["sweep", "--axis", "chaos.reclaim-storm=at100"])).unwrap_err();
        assert!(err.contains("chaos.reclaim-storm"), "{err}");
        let err = run(&argv(&["sweep", "--axis", "market.volatility=-0.1"])).unwrap_err();
        assert!(err.contains("negative"), "{err}");
        let err = run(&argv(&["sweep", "--axis", "market.bid-margin=0"])).unwrap_err();
        assert!(err.contains("must be > 0"), "{err}");
        let err = run(&argv(&["sweep", "--axis", "market.daily-amplitude=1.5"])).unwrap_err();
        assert!(err.contains("outside [0, 1]"), "{err}");
        let err = run(&argv(&["sweep", "--axis", "recovery.mode=teleport"])).unwrap_err();
        assert!(err.contains("recovery.mode"), "{err}");
        let err = run(&argv(&["sweep", "--axis", "recovery.bandwidth=0"])).unwrap_err();
        assert!(err.contains("must be > 0"), "{err}");
        let err =
            run(&argv(&["sweep", "--axis", "recovery.checkpoint-threshold=1.5"])).unwrap_err();
        assert!(err.contains("outside [0, 1]"), "{err}");
        let err = run(&argv(&["sweep", "--substrate", "cloud"])).unwrap_err();
        assert!(err.contains("unknown substrate"), "{err}");
        let err = run(&argv(&[
            "sweep", "--axis", "substrate=trace", "--substrate", "comparison",
        ]))
        .unwrap_err();
        assert!(err.contains("same axis"), "{err}");
        let err = run(&argv(&[
            "sweep", "--axis", "spot.warning=60", "--axis", "spot.warning=120",
        ]))
        .unwrap_err();
        assert!(err.contains("declared more than once"), "{err}");
        let err = run(&argv(&[
            "sweep", "--policies", "first-fit,hlem-vmp", "--axis", "hlem.alpha=-0.2,-0.8",
        ]))
        .unwrap_err();
        assert!(err.contains("alpha-sensitive"), "{err}");
        let err = run(&argv(&["sweep", "--retain-series", "bogus=1"])).unwrap_err();
        assert!(err.contains("unknown retain key"), "{err}");
    }

    #[test]
    fn unknown_subcommand_is_an_error() {
        assert!(run(&argv(&["frobnicate"])).is_err());
        let err = run(&argv(&["sweep", "frobnicate"])).unwrap_err();
        assert!(err.contains("unknown sweep subcommand"), "{err}");
    }

    fn test_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("cloudmarket_cli_{}_{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    /// `--workers 0` and malformed worker invocations exit non-zero with
    /// clear messages, before any process spawns.
    #[test]
    fn sweep_workers_and_worker_reject_bad_input() {
        let err = run(&argv(&["sweep", "--workers", "0"])).unwrap_err();
        assert!(err.contains("--workers must be >= 1"), "{err}");
        let err = run(&argv(&["sweep", "--workers", "abc"])).unwrap_err();
        assert!(err.contains("expects an integer"), "{err}");

        let err = run(&argv(&["sweep", "worker"])).unwrap_err();
        assert!(err.contains("--shard"), "{err}");
        let err = run(&argv(&["sweep", "worker", "--shard", "x.json"])).unwrap_err();
        assert!(err.contains("--out"), "{err}");

        // Missing shard file.
        let dir = test_dir("worker_errs");
        let missing = dir.join("nope.json");
        let out = dir.join("out.json");
        let err = run(&argv(&[
            "sweep",
            "worker",
            "--shard",
            missing.to_str().unwrap(),
            "--out",
            out.to_str().unwrap(),
        ]))
        .unwrap_err();
        assert!(err.contains("reading shard file"), "{err}");
        assert_eq!(
            exit_code_for(&err),
            cloudmarket::sweep::EXIT_BAD_SHARD,
            "unreadable shard files map to the permanent exit code"
        );

        // Corrupt shard file.
        let corrupt = dir.join("corrupt.json");
        std::fs::write(&corrupt, "{ this is not json").unwrap();
        let err = run(&argv(&[
            "sweep",
            "worker",
            "--shard",
            corrupt.to_str().unwrap(),
            "--out",
            out.to_str().unwrap(),
        ]))
        .unwrap_err();
        assert!(err.contains("shard file"), "{err}");
        assert_eq!(exit_code_for(&err), cloudmarket::sweep::EXIT_BAD_SHARD);
        assert!(!out.exists(), "no partial may be written on a bad shard file");

        // Ordinary errors stay on the generic runtime exit code.
        assert_eq!(exit_code_for("anything else"), cloudmarket::sweep::EXIT_RUNTIME);
        let _ = std::fs::remove_dir_all(&dir);
    }

    fn fake_cell_result(cell: cloudmarket::sweep::Cell) -> cloudmarket::sweep::CellResult {
        use cloudmarket::engine::{MarketStats, RecoveryStats, Report, ResilienceStats, SpotStats};
        cloudmarket::sweep::CellResult {
            cell,
            outcome: Ok(Report {
                policy: "first-fit",
                clock_end: 1.0,
                events_processed: 1,
                wall: std::time::Duration::ZERO,
                finished: 0,
                terminated: 0,
                failed: 0,
                still_active: 0,
                cloudlets_finished: 0,
                cloudlets_canceled: 0,
                alloc_attempts: 0,
                alloc_failures: 0,
                spot: SpotStats::default(),
                resilience: ResilienceStats::default(),
                market: MarketStats::default(),
                recovery: RecoveryStats::default(),
            }),
            series: None,
        }
    }

    /// `sweep merge` error paths: no inputs, unreadable input, partials
    /// with overlapping cell ids, and partials missing a shard.
    #[test]
    fn sweep_merge_rejects_bad_partial_sets() {
        use cloudmarket::sweep::{shard, PolicySpec, SweepSpec};

        let err = run(&argv(&["sweep", "merge"])).unwrap_err();
        assert!(err.contains("requires partial files"), "{err}");
        let err = run(&argv(&["sweep", "merge", "/nonexistent/partial.json"])).unwrap_err();
        assert!(err.contains("reading partial"), "{err}");

        let dir = test_dir("merge_errs");
        let spec = SweepSpec::new(ComparisonConfig::default())
            .with_seeds(vec![1, 2])
            .with_policies(vec![PolicySpec::FirstFit, PolicySpec::BestFit]);
        let cells = spec.cells();
        let shards = shard::partition(&spec, 2);
        for s in &shards {
            let results: Vec<_> =
                s.cell_ids.iter().map(|&id| fake_cell_result(cells[id])).collect();
            shard::write_partial(
                &dir.join(format!("sweep_partial{:04}.json", s.index)),
                &spec,
                s.index,
                &results,
            )
            .unwrap();
        }
        let p0 = dir.join("sweep_partial0000.json");
        let p1 = dir.join("sweep_partial0001.json");
        let out = dir.join("merged");

        // Overlap: shard 0 fed in twice alongside shard 1.
        let err = run(&argv(&[
            "sweep",
            "merge",
            p0.to_str().unwrap(),
            p0.to_str().unwrap(),
            p1.to_str().unwrap(),
            "--out-dir",
            out.to_str().unwrap(),
        ]))
        .unwrap_err();
        assert!(err.contains("overlapping cell id"), "{err}");

        // Missing: shard 1 absent.
        let err = run(&argv(&[
            "sweep",
            "merge",
            p0.to_str().unwrap(),
            "--out-dir",
            out.to_str().unwrap(),
        ]))
        .unwrap_err();
        assert!(err.contains("missing"), "{err}");

        // The full set merges and writes artifacts.
        run(&argv(&[
            "sweep",
            "merge",
            p0.to_str().unwrap(),
            p1.to_str().unwrap(),
            "--out-dir",
            out.to_str().unwrap(),
        ]))
        .unwrap();
        assert!(out.join("sweep_cells.csv").exists());
        assert!(out.join("sweep_aggregate.json").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Anti-drift check for `docs/cli.md`: every flag the CLI reference
    /// documents must appear in the live `--help` output, and every
    /// declared flag must be documented. Flags belonging to external
    /// tools (cargo, rustup) that the docs mention in passing are
    /// allowlisted.
    #[test]
    fn cli_docs_match_help_output() {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .expect("crate dir has a parent")
            .join("docs/cli.md");
        let docs = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()));
        let external = [
            "release", "features", "bench", "no-deps", "workspace", "bin", "quiet",
        ];
        // Collect `--flag` tokens from the docs.
        let mut documented: Vec<String> = Vec::new();
        for (i, _) in docs.match_indices("--") {
            let name: String = docs[i + 2..]
                .chars()
                .take_while(|c| c.is_ascii_lowercase() || *c == '-')
                .collect();
            let name = name.trim_end_matches('-').to_string();
            if !name.is_empty() && !documented.contains(&name) {
                documented.push(name);
            }
        }
        documented.retain(|n| !external.contains(&n.as_str()));
        assert!(!documented.is_empty(), "no flags found in {}", path.display());
        let u = usage();
        for flag in &documented {
            assert!(
                u.contains(&format!("--{flag}")),
                "docs/cli.md documents --{flag} but --help does not mention it"
            );
        }
        // The reverse: every declared flag is documented.
        for spec in specs() {
            assert!(
                documented.iter().any(|d| d == spec.name),
                "--{} is declared in specs() but missing from docs/cli.md",
                spec.name
            );
        }
        // And every subcommand is documented.
        for cmd in ["quickstart", "compare", "sweep", "trace", "trace-analysis", "advisor", "tables"]
        {
            assert!(docs.contains(cmd), "docs/cli.md missing subcommand {cmd}");
        }
    }

    /// `sweep status` renders a summary from a hand-built sidecar, rejects
    /// dirs without one, and hard-errors on mid-file schema violations.
    #[test]
    fn sweep_status_reads_sidecar_and_rejects_missing() {
        use cloudmarket::obs::{self, telemetry as tel, EngineCounters, HeartbeatWriter};
        use std::time::Duration;

        let empty = test_dir("status_missing");
        let err = run(&argv(&["sweep", "status", empty.to_str().unwrap()])).unwrap_err();
        assert!(err.contains("no telemetry sidecar"), "{err}");

        let dir = test_dir("status_smoke");
        let t = obs::Telemetry::create(&dir).unwrap();
        let c = EngineCounters { events_popped: 10, queue_high_water: 3, ..Default::default() };
        t.emit(tel::run_start("00bebfa81eefea11", 4, 2, 2, "workers", 2));
        t.emit(tel::shard_assign(0, 0, 4242));
        t.emit(tel::cell_start(0, 42, "policy=first-fit"));
        t.emit(tel::prebuild(0, 12.5));
        t.emit(tel::cell_end(0, true, 3.5, &c));
        t.emit(tel::cell_end(1, false, 9.0, &c));
        t.emit(tel::stall(0, 31_000, None));
        t.emit(tel::shard_exit(0, true, Some(0), "completed"));
        t.emit(tel::merge(1, 4, true));
        t.emit(tel::run_end(
            false,
            Duration::from_millis(900),
            Duration::from_millis(100),
            Duration::from_millis(700),
            Duration::from_millis(5),
            Duration::from_millis(40),
            1,
        ));
        drop(t);
        let hb_path = obs::heartbeat_file(&obs::telemetry_dir(&dir), 0);
        HeartbeatWriter::create(&hb_path, 0, 2).unwrap().beat(1, Some(0));
        run(&argv(&["sweep", "status", dir.to_str().unwrap()]))
            .expect("status renders a well-formed sidecar");

        // A schema violation on a complete line is a hard, line-numbered
        // error (only a torn *final* line is tolerated).
        let log = obs::telemetry_dir(&dir).join(obs::RUN_LOG);
        let text = std::fs::read_to_string(&log).unwrap();
        std::fs::write(&log, text.replacen("run_start", "not_an_event", 1)).unwrap();
        let err = run(&argv(&["sweep", "status", dir.to_str().unwrap()])).unwrap_err();
        assert!(err.contains("line 1"), "{err}");
        assert!(err.contains("unknown event"), "{err}");

        let _ = std::fs::remove_dir_all(&empty);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
