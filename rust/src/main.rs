//! `cloudmarket` CLI - the leader entrypoint.
//!
//! Subcommands map 1:1 onto the paper's evaluation artifacts (DESIGN.md §3):
//!
//! ```text
//! cloudmarket quickstart                     minimal spot lifecycle demo (SVII-A)
//! cloudmarket compare [...]                  Figs. 13-15 algorithm comparison
//! cloudmarket trace [...]                    Fig. 12 + SVII-D trace simulation
//! cloudmarket trace-analysis [...]           Figs. 7-9 concurrency analysis
//! cloudmarket advisor [...]                  Fig. 16 correlation analysis
//! cloudmarket tables                         Tables II-III
//! ```

use std::path::PathBuf;

use cloudmarket::allocation::{AllocationPolicy, FirstFit, HlemConfig, HlemVmp};
use cloudmarket::config::scenario::ComparisonConfig;
use cloudmarket::experiments::{advisor, compare, trace_analysis, trace_sim};
use cloudmarket::util::cli::{render_help, Args, Spec};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    };
    std::process::exit(code);
}

fn specs() -> Vec<Spec> {
    vec![
        Spec { name: "seed", takes_value: true, help: "rng seed (default 20250710)" },
        Spec { name: "runs", takes_value: true, help: "compare: aggregate over N seeds (default 1)" },
        Spec { name: "alpha", takes_value: true, help: "spot-load factor for adjusted HLEM (default -0.5)" },
        Spec { name: "scorer", takes_value: true, help: "hlem scorer backend: rust | pjrt" },
        Spec { name: "machines", takes_value: true, help: "trace machine count" },
        Spec { name: "days", takes_value: true, help: "trace horizon in days" },
        Spec { name: "spots", takes_value: true, help: "injected spot instances" },
        Spec { name: "max-vms", takes_value: true, help: "cap on trace VMs (scale knob)" },
        Spec { name: "no-profile", takes_value: false, help: "disable the /proc self-profiler" },
        Spec { name: "out-dir", takes_value: true, help: "CSV/JSON output directory (default results/)" },
        Spec { name: "advisor", takes_value: true, help: "real spot-advisor JSON (else synthetic)" },
        Spec { name: "help", takes_value: false, help: "show help" },
    ]
}

fn usage() -> String {
    format!(
        "usage: cloudmarket <quickstart|compare|trace|trace-analysis|advisor|tables> [flags]\n{}",
        render_help(&specs())
    )
}

fn run(argv: &[String]) -> Result<(), String> {
    let args = Args::parse(argv, &specs())?;
    if args.has("help") || args.positional.is_empty() {
        println!("{}", usage());
        return Ok(());
    }
    let out_dir = PathBuf::from(args.get_or("out-dir", "results"));
    match args.positional[0].as_str() {
        "quickstart" => cmd_quickstart(),
        "compare" => cmd_compare(&args, &out_dir),
        "trace" => cmd_trace(&args, &out_dir),
        "trace-analysis" => cmd_trace_analysis(&args),
        "advisor" => cmd_advisor(&args),
        "tables" => {
            println!("{}", cloudmarket::config::catalog::host_table().render());
            println!("{}", cloudmarket::config::catalog::vm_table().render());
            Ok(())
        }
        other => Err(format!("unknown command '{other}'\n{}", usage())),
    }
}

/// The §VII-A minimal example: one host, one spot + one delayed on-demand
/// VM, hibernation and resumption.
fn cmd_quickstart() -> Result<(), String> {
    use cloudmarket::cloudlet::Cloudlet;
    use cloudmarket::engine::{Engine, EngineConfig};
    use cloudmarket::infra::HostSpec;
    use cloudmarket::metrics::tables;
    use cloudmarket::vm::{SpotConfig, Vm, VmSpec};

    let mut cfg = EngineConfig::default();
    cfg.min_dt = 0.5; // new CloudSim(0.5)
    cfg.vm_destruction_delay = 1.0; // setVmDestructionDelay(1)
    let mut engine = Engine::new(cfg, Box::new(HlemVmp::plain()));
    let dc = engine.add_datacenter("dc0", 1.0);
    engine.add_host(dc, HostSpec::new(2, 1000.0, 2_048.0, 10_000.0, 1_000_000.0));

    let spot_cfg = SpotConfig::hibernate()
        .with_min_running(0.0)
        .with_warning(0.0)
        .with_hibernation_timeout(100.0);
    let spot = engine.submit_vm(
        Vm::spot(0, VmSpec::new(1000.0, 2), spot_cfg).with_persistent(60.0),
    );
    engine.submit_cloudlet(Cloudlet::new(0, 20_000.0, 2).with_vm(spot));

    let od = engine.submit_vm(Vm::on_demand(0, VmSpec::new(1000.0, 2)).with_delay(10.0));
    engine.submit_cloudlet(Cloudlet::new(0, 20_000.0, 2).with_vm(od));

    engine.terminate_at(70.0); // simulation.terminateAt(70)
    let report = engine.run();

    let all: Vec<usize> = (0..engine.world.vms.len()).collect();
    println!("{}", tables::dynamic_vm_table(&engine.world, &all).render());
    println!("{}", tables::spot_vm_table(&engine.world, &all).render());
    println!("{}", tables::execution_table(&engine.world, &all).render());
    println!("{}", report.render());
    Ok(())
}

/// Build the PJRT-backed HLEM policy (requires `--features pjrt`).
#[cfg(feature = "pjrt")]
fn pjrt_hlem(cfg: HlemConfig) -> Result<Box<dyn AllocationPolicy>, String> {
    let engine = std::rc::Rc::new(
        cloudmarket::runtime::PjrtEngine::load_default()
            .map_err(|e| format!("loading artifacts: {e:#}"))?,
    );
    Ok(Box::new(HlemVmp::with_scorer(
        cfg,
        Box::new(cloudmarket::runtime::PjrtScorer::new(engine)),
    )))
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_hlem(_cfg: HlemConfig) -> Result<Box<dyn AllocationPolicy>, String> {
    Err("scorer 'pjrt' needs the PJRT runtime: add the `xla` and `anyhow` dependencies \
         from your toolchain checkout to rust/Cargo.toml (see the notes on the `pjrt` \
         feature there), then rebuild with `--features pjrt`"
        .into())
}

fn make_hlem(args: &Args, adjusted: bool) -> Result<Box<dyn AllocationPolicy>, String> {
    let alpha = args.get_f64("alpha", -0.5)?;
    let cfg = if adjusted {
        HlemConfig::adjusted().with_alpha(alpha)
    } else {
        HlemConfig::plain()
    };
    Ok(match args.get_or("scorer", "rust").as_str() {
        "rust" => Box::new(HlemVmp::new(cfg)),
        "pjrt" => pjrt_hlem(cfg)?,
        other => return Err(format!("unknown scorer '{other}'")),
    })
}

fn cmd_compare(args: &Args, out_dir: &std::path::Path) -> Result<(), String> {
    let seed = args.get_u64("seed", 20_250_710)?;
    let cfg = ComparisonConfig { seed, ..Default::default() };

    println!("{}", cloudmarket::config::catalog::host_table().render());
    println!("{}", cloudmarket::config::catalog::vm_table().render());

    let mut outcomes = Vec::new();
    let policies: Vec<(&str, Box<dyn AllocationPolicy>)> = vec![
        ("first-fit", Box::new(FirstFit::new())),
        ("hlem-vmp", make_hlem(args, false)?),
        ("hlem-vmp-adjusted", make_hlem(args, true)?),
    ];
    for (name, policy) in policies {
        eprintln!("running {name} ...");
        outcomes.push(compare::run_policy(move || policy, &cfg));
    }

    println!("{}", compare::fig14_table(&outcomes).render());
    println!("{}", compare::fig15_table(&outcomes).render());
    println!("{}", compare::shape_summary(&outcomes));
    for o in &outcomes {
        println!("\n[{}] {}", o.policy, o.report.render());
    }
    compare::fig13_csv(&outcomes)
        .write_file(&out_dir.join("fig13_active_instances.csv"))
        .map_err(|e| e.to_string())?;
    println!("\nwrote {}", out_dir.join("fig13_active_instances.csv").display());

    let runs = args.get_usize("runs", 1)?;
    if runs > 1 {
        eprintln!("aggregating over {runs} seeds ...");
        let aggs = compare::run_multi(&cfg, runs);
        println!("{}", compare::aggregate_table(&aggs).render());
    }
    Ok(())
}

fn cmd_trace(args: &Args, out_dir: &std::path::Path) -> Result<(), String> {
    let mut cfg = trace_sim::TraceSimConfig::default();
    cfg.synth.seed = args.get_u64("seed", 42)?;
    cfg.synth.machines = args.get_usize("machines", cfg.synth.machines)?;
    cfg.synth.days = args.get_f64("days", cfg.synth.days)?;
    cfg.workload.spot_instances = args.get_usize("spots", cfg.workload.spot_instances)?;
    cfg.workload.max_trace_vms = args.get_usize("max-vms", cfg.workload.max_trace_vms)?;
    cfg.profile = !args.has("no-profile");

    eprintln!(
        "simulating {} machines x {:.1} days, {} spots ...",
        cfg.synth.machines, cfg.synth.days, cfg.workload.spot_instances
    );
    let out = trace_sim::run(&cfg);
    println!("{}", trace_sim::results_table(&out).render());
    println!("{}", out.series.ascii_chart("spot_running", 100, 12));

    trace_sim::fig12_csv(&out)
        .write_file(&out_dir.join("fig12_active_instances.csv"))
        .map_err(|e| e.to_string())?;
    if let Some(prof) = &out.selfprof {
        prof.to_csv()
            .write_file(&out_dir.join("fig10_11_selfprofile.csv"))
            .map_err(|e| e.to_string())?;
        println!(
            "self-profile: cpu peak {:.0}%  rss peak {:.0} MB ({} samples)",
            prof.max_of("cpu_pct").unwrap_or(0.0),
            prof.max_of("rss_mb").unwrap_or(0.0),
            prof.len()
        );
    }
    println!("wrote {}", out_dir.join("fig12_active_instances.csv").display());
    Ok(())
}

fn cmd_trace_analysis(args: &Args) -> Result<(), String> {
    let seed = args.get_u64("seed", 42)?;
    let machines = args.get_usize("machines", 200)?;
    eprintln!("generating 30-day trace ({machines} machines) ...");
    let trace = trace_analysis::month_trace(seed, machines);
    println!("{}", trace_analysis::fig7_table(&trace).render());
    println!("{}", trace_analysis::fig8_table(&trace).render());
    println!("{}", trace_analysis::fig9_table(&trace).render());
    Ok(())
}

fn cmd_advisor(args: &Args) -> Result<(), String> {
    let seed = args.get_u64("seed", 7)?;
    let path = args.get("advisor").map(PathBuf::from);
    let ds = advisor::dataset(path.as_deref(), seed);
    println!("dataset: {} instance types, {} families", ds.rows.len(), ds.family_names.len());
    println!("{}", advisor::class_distribution_table(&ds).render());
    println!("{}", advisor::fig16_table(&ds).render());
    Ok(())
}
