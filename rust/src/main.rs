//! `cloudmarket` CLI - the leader entrypoint.
//!
//! Subcommands map 1:1 onto the paper's evaluation artifacts (DESIGN.md §3):
//!
//! ```text
//! cloudmarket quickstart                     minimal spot lifecycle demo (SVII-A)
//! cloudmarket compare [...]                  Figs. 13-15 algorithm comparison
//! cloudmarket sweep [...]                    parallel multi-seed/policy sweep grid
//! cloudmarket trace [...]                    Fig. 12 + SVII-D trace simulation
//! cloudmarket trace-analysis [...]           Figs. 7-9 concurrency analysis
//! cloudmarket advisor [...]                  Fig. 16 correlation analysis
//! cloudmarket tables                         Tables II-III
//! ```
//!
//! `sweep` fans a multi-axis scenario grid out over worker threads
//! (`--threads`), one cell per (seed, scenario variant): `--seeds N` runs
//! seeds `--seed .. --seed+N-1` under every `--policies` entry, multiplied
//! by any `--axis <name>=<v1,v2,...>` dimensions (spot.warning,
//! spot.hibernation-timeout, spot.behavior, hlem.alpha, victim, substrate,
//! and the `chaos.*` fault families: chaos.host-mtbf, chaos.reclaim-storm,
//! chaos.broker-outage, chaos.demand-surge)
//! and the `--substrate` list (comparison | trace). Artifacts go to
//! `--out-dir`: `sweep_cells.csv`, `sweep_aggregate.json`, and - for cells
//! matching `--retain-series` - per-cell `sweep_series_cell*.csv` time
//! series. The merged output is bit-identical at any thread count. See
//! `docs/sweep-cookbook.md` for recipes and `docs/cli.md` for the full
//! flag reference.
//!
//! `sweep --workers N` runs the same grid as worker **subprocesses**
//! instead of threads (cost-weighted shards, crashed workers'
//! shards reassigned; identical artifact bytes), and `sweep worker
//! --shard <file> --out <file>` / `sweep merge <partial>...` expose the
//! shard protocol directly for cluster fan-out - see the "Cluster-scale
//! sweeps" section of the cookbook.

use std::path::PathBuf;

use cloudmarket::allocation::{AllocationPolicy, FirstFit, HlemConfig, HlemVmp};
use cloudmarket::config::scenario::ComparisonConfig;
use cloudmarket::experiments::{advisor, compare, trace_analysis, trace_sim};
use cloudmarket::util::cli::{render_help, Args, Spec};

/// Prefix `cmd_sweep_worker` puts on shard-file read/validation errors so
/// `main` can map them to the permanent-failure exit code without the
/// command functions calling `process::exit` (untestable in-process).
const BAD_SHARD_PREFIX: &str = "bad shard: ";

/// Exit-code taxonomy for a failed invocation (see
/// `sweep::shard::EXIT_*`): a rejected shard job file is permanent (the
/// coordinator must not reassign it); everything else is a runtime error.
fn exit_code_for(err: &str) -> i32 {
    if err.starts_with(BAD_SHARD_PREFIX) {
        cloudmarket::sweep::EXIT_BAD_SHARD
    } else {
        cloudmarket::sweep::EXIT_RUNTIME
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            exit_code_for(&e)
        }
    };
    std::process::exit(code);
}

fn specs() -> Vec<Spec> {
    vec![
        Spec { name: "seed", takes_value: true, help: "rng seed (default 20250710)" },
        Spec { name: "runs", takes_value: true, help: "compare: aggregate over N seeds (default 1)" },
        Spec { name: "seeds", takes_value: true, help: "sweep: number of seeds (default 8)" },
        Spec { name: "threads", takes_value: true, help: "sweep: worker threads (default: all CPUs; with --workers: threads per worker process, default 1)" },
        Spec { name: "workers", takes_value: true, help: "sweep: process-level fan-out - spawn N worker subprocesses instead of threads" },
        Spec { name: "shard", takes_value: true, help: "sweep worker: shard job file to run" },
        Spec { name: "out", takes_value: true, help: "sweep worker: partial artifact output path" },
        Spec { name: "policies", takes_value: true, help: "sweep: comma-separated policy list" },
        Spec { name: "axis", takes_value: true, help: "sweep: scenario axis <name>=<v1,v2,...>, repeatable (spot.warning | spot.hibernation-timeout | spot.behavior | hlem.alpha | victim | substrate | chaos.host-mtbf | chaos.reclaim-storm | chaos.broker-outage | chaos.demand-surge)" },
        Spec { name: "substrate", takes_value: true, help: "sweep: workload substrate list: comparison | trace (default comparison)" },
        Spec { name: "retain-series", takes_value: true, help: "sweep: keep per-cell time series: all | none | policy=<p>,seed=<s>,id=<n>,substrate=<s> (OR; default none)" },
        Spec { name: "alpha", takes_value: true, help: "spot-load factor for adjusted HLEM (default -0.5)" },
        Spec { name: "scorer", takes_value: true, help: "hlem scorer backend: rust | pjrt" },
        Spec { name: "machines", takes_value: true, help: "trace machine count" },
        Spec { name: "days", takes_value: true, help: "trace horizon in days" },
        Spec { name: "spots", takes_value: true, help: "injected spot instances" },
        Spec { name: "max-vms", takes_value: true, help: "cap on trace VMs (scale knob)" },
        Spec { name: "no-profile", takes_value: false, help: "disable the /proc self-profiler" },
        Spec { name: "out-dir", takes_value: true, help: "CSV/JSON output directory (default results/)" },
        Spec { name: "advisor", takes_value: true, help: "real spot-advisor JSON (else synthetic)" },
        Spec { name: "help", takes_value: false, help: "show help" },
    ]
}

fn usage() -> String {
    format!(
        "usage: cloudmarket <quickstart|compare|sweep|trace|trace-analysis|advisor|tables> [flags]\n       cloudmarket sweep worker --shard <file> --out <file>\n       cloudmarket sweep merge <partial.json>... [--out-dir <dir>]\n{}",
        render_help(&specs())
    )
}

fn run(argv: &[String]) -> Result<(), String> {
    let args = Args::parse(argv, &specs())?;
    if args.has("help") || args.positional.is_empty() {
        println!("{}", usage());
        return Ok(());
    }
    let out_dir = PathBuf::from(args.get_or("out-dir", "results"));
    match args.positional[0].as_str() {
        "quickstart" => cmd_quickstart(),
        "compare" => cmd_compare(&args, &out_dir),
        "sweep" => match args.positional.get(1).map(String::as_str) {
            None => cmd_sweep(&args, &out_dir),
            Some("worker") => cmd_sweep_worker(&args),
            Some("merge") => cmd_sweep_merge(&args, &out_dir),
            Some(other) => Err(format!(
                "unknown sweep subcommand '{other}' (expected worker | merge, or flags only)"
            )),
        },
        "trace" => cmd_trace(&args, &out_dir),
        "trace-analysis" => cmd_trace_analysis(&args),
        "advisor" => cmd_advisor(&args),
        "tables" => {
            println!("{}", cloudmarket::config::catalog::host_table().render());
            println!("{}", cloudmarket::config::catalog::vm_table().render());
            Ok(())
        }
        other => Err(format!("unknown command '{other}'\n{}", usage())),
    }
}

/// The §VII-A minimal example: one host, one spot + one delayed on-demand
/// VM, hibernation and resumption.
fn cmd_quickstart() -> Result<(), String> {
    use cloudmarket::cloudlet::Cloudlet;
    use cloudmarket::engine::{Engine, EngineConfig};
    use cloudmarket::infra::HostSpec;
    use cloudmarket::metrics::tables;
    use cloudmarket::vm::{SpotConfig, Vm, VmSpec};

    let mut cfg = EngineConfig::default();
    cfg.min_dt = 0.5; // new CloudSim(0.5)
    cfg.vm_destruction_delay = 1.0; // setVmDestructionDelay(1)
    let mut engine = Engine::new(cfg, Box::new(HlemVmp::plain()));
    let dc = engine.add_datacenter("dc0", 1.0);
    engine.add_host(dc, HostSpec::new(2, 1000.0, 2_048.0, 10_000.0, 1_000_000.0));

    let spot_cfg = SpotConfig::hibernate()
        .with_min_running(0.0)
        .with_warning(0.0)
        .with_hibernation_timeout(100.0);
    let spot = engine.submit_vm(
        Vm::spot(0, VmSpec::new(1000.0, 2), spot_cfg).with_persistent(60.0),
    );
    engine.submit_cloudlet(Cloudlet::new(0, 20_000.0, 2).with_vm(spot));

    let od = engine.submit_vm(Vm::on_demand(0, VmSpec::new(1000.0, 2)).with_delay(10.0));
    engine.submit_cloudlet(Cloudlet::new(0, 20_000.0, 2).with_vm(od));

    engine.terminate_at(70.0); // simulation.terminateAt(70)
    let report = engine.run();

    let all: Vec<usize> = (0..engine.world.vms.len()).collect();
    println!("{}", tables::dynamic_vm_table(&engine.world, &all).render());
    println!("{}", tables::spot_vm_table(&engine.world, &all).render());
    println!("{}", tables::execution_table(&engine.world, &all).render());
    println!("{}", report.render());
    Ok(())
}

/// Build the PJRT-backed HLEM policy (requires `--features pjrt`).
#[cfg(feature = "pjrt")]
fn pjrt_hlem(cfg: HlemConfig) -> Result<Box<dyn AllocationPolicy>, String> {
    let engine = std::rc::Rc::new(
        cloudmarket::runtime::PjrtEngine::load_default()
            .map_err(|e| format!("loading artifacts: {e:#}"))?,
    );
    Ok(Box::new(HlemVmp::with_scorer(
        cfg,
        Box::new(cloudmarket::runtime::PjrtScorer::new(engine)),
    )))
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_hlem(_cfg: HlemConfig) -> Result<Box<dyn AllocationPolicy>, String> {
    Err("scorer 'pjrt' needs the PJRT runtime: add the `xla` and `anyhow` dependencies \
         from your toolchain checkout to rust/Cargo.toml (see the notes on the `pjrt` \
         feature there), then rebuild with `--features pjrt`"
        .into())
}

fn make_hlem(args: &Args, adjusted: bool) -> Result<Box<dyn AllocationPolicy>, String> {
    let alpha = args.get_f64("alpha", -0.5)?;
    let cfg = if adjusted {
        HlemConfig::adjusted().with_alpha(alpha)
    } else {
        HlemConfig::plain()
    };
    Ok(match args.get_or("scorer", "rust").as_str() {
        "rust" => Box::new(HlemVmp::new(cfg)),
        "pjrt" => pjrt_hlem(cfg)?,
        other => return Err(format!("unknown scorer '{other}'")),
    })
}

fn cmd_compare(args: &Args, out_dir: &std::path::Path) -> Result<(), String> {
    let seed = args.get_u64("seed", 20_250_710)?;
    let cfg = ComparisonConfig { seed, ..Default::default() };

    println!("{}", cloudmarket::config::catalog::host_table().render());
    println!("{}", cloudmarket::config::catalog::vm_table().render());

    let mut outcomes = Vec::new();
    let policies: Vec<(&str, Box<dyn AllocationPolicy>)> = vec![
        ("first-fit", Box::new(FirstFit::new())),
        ("hlem-vmp", make_hlem(args, false)?),
        ("hlem-vmp-adjusted", make_hlem(args, true)?),
    ];
    for (name, policy) in policies {
        eprintln!("running {name} ...");
        outcomes.push(compare::run_policy(move || policy, &cfg));
    }

    println!("{}", compare::fig14_table(&outcomes).render());
    println!("{}", compare::fig15_table(&outcomes).render());
    println!("{}", compare::shape_summary(&outcomes));
    for o in &outcomes {
        println!("\n[{}] {}", o.policy, o.report.render());
    }
    compare::fig13_csv(&outcomes)
        .write_file(&out_dir.join("fig13_active_instances.csv"))
        .map_err(|e| e.to_string())?;
    println!("\nwrote {}", out_dir.join("fig13_active_instances.csv").display());

    let runs = args.get_usize("runs", 1)?;
    if runs > 1 {
        eprintln!("aggregating over {runs} seeds ...");
        let aggs = compare::run_multi(&cfg, runs);
        println!("{}", compare::aggregate_table(&aggs).render());
    }
    Ok(())
}

/// `cloudmarket sweep`: fan a multi-axis scenario grid out over a worker
/// pool. One cell per (seed, scenario variant); merged output is
/// deterministic regardless of `--threads`.
fn cmd_sweep(args: &Args, out_dir: &std::path::Path) -> Result<(), String> {
    use cloudmarket::sweep::{self, CellResult, PolicySpec, ScenarioAxis, SeriesFilter, Substrate, SweepSpec};

    let seed = args.get_u64("seed", 20_250_710)?;
    let seeds = args.get_positive_usize("seeds", 8)?;
    let threads = args.get_positive_usize("threads", sweep::default_threads())?;
    let alpha = args.get_f64("alpha", -0.5)?;
    let policies = match args.get("policies") {
        None => PolicySpec::paper_with_alpha(alpha),
        Some(list) => PolicySpec::parse_list(list, alpha)?,
    };
    if args.get_or("scorer", "rust") != "rust" {
        return Err("sweep cells build policies per worker thread; only the in-process \
                    'rust' scorer is supported (pjrt handles are not Send)"
            .into());
    }
    let mut axes: Vec<ScenarioAxis> = args
        .get_all("axis")
        .into_iter()
        .map(ScenarioAxis::parse)
        .collect::<Result<_, _>>()?;
    if let Some(subs) = args.get("substrate") {
        // Silently stacking a second substrate axis would duplicate every
        // variant (each cell would run once per copy).
        if axes.iter().any(|a| matches!(a, ScenarioAxis::Substrate(_))) {
            return Err("--substrate and --axis substrate=... declare the same axis; \
                        pass only one"
                .into());
        }
        axes.push(ScenarioAxis::Substrate(Substrate::parse_list(subs)?));
    }
    // A repeated axis name would silently overwrite the earlier values
    // (last expansion wins per field) or duplicate every variant.
    for (i, a) in axes.iter().enumerate() {
        if axes[..i].iter().any(|b| b.name() == a.name()) {
            return Err(format!(
                "axis '{}' declared more than once; merge its values into one --axis flag",
                a.name()
            ));
        }
    }
    // An alpha axis multiplies only alpha-sensitive policies; with none in
    // the list it would expand nothing and silently run a no-op "sweep".
    if axes.iter().any(|a| matches!(a, ScenarioAxis::HlemAlpha(_)))
        && !policies.iter().any(|p| p.alpha_sensitive())
    {
        return Err("--axis hlem.alpha requires an alpha-sensitive policy \
                    (hlem-vmp-adjusted) in --policies"
            .into());
    }
    let retain = match args.get("retain-series") {
        None => SeriesFilter::none(),
        Some(f) => SeriesFilter::parse(f)?,
    };

    let scenario = ComparisonConfig { seed, ..Default::default() };
    let n_policies = policies.len();
    let mut spec = SweepSpec::new(scenario)
        .with_seed_range(seed, seeds)
        .with_policies(policies)
        .with_axes(axes)
        .with_series_retention(retain);
    // Trace-substrate scale knobs shared with `cloudmarket trace`.
    spec.trace.synth.machines = args.get_usize("machines", spec.trace.synth.machines)?;
    spec.trace.synth.days = args.get_f64("days", spec.trace.synth.days)?;
    spec.trace.workload.spot_instances =
        args.get_usize("spots", spec.trace.workload.spot_instances)?;
    spec.trace.workload.max_trace_vms =
        args.get_usize("max-vms", spec.trace.workload.max_trace_vms)?;

    let n_variants = spec.variants().len();
    let total = spec.cell_count();
    let workers = match args.get("workers") {
        None => None,
        Some(_) => Some(args.get_positive_usize("workers", 1)?),
    };
    let mode = match workers {
        Some(w) => format!("{w} worker processes"),
        None => format!("{threads} threads"),
    };
    eprintln!(
        "sweep: {total} cells ({seeds} seeds x {n_variants} variants over {n_policies} \
         policies) on {mode} ..."
    );

    fn progress(done: usize, total: usize, r: &CellResult) {
        let status = if r.outcome.is_ok() { "ok" } else { "FAILED" };
        eprintln!(
            "  [{done:>3}/{total}] cell {:<3} {:<18} seed={} {:<12} {status}",
            r.cell.id,
            r.cell.policy().name(),
            r.cell.seed,
            r.cell.spec.variant_label(),
        );
    }
    let report = match workers {
        Some(w) => {
            // Process-level fan-out: shard files + worker subprocesses in
            // out_dir, crashed workers' shards reassigned, merged by cell
            // id - byte-identical artifacts to the thread path below.
            let exe = std::env::current_exe()
                .map_err(|e| format!("locating the cloudmarket binary: {e}"))?;
            let mut opts = sweep::CoordinateOptions::new(w, out_dir, exe);
            opts.worker_threads = args.get_positive_usize("threads", 1)?;
            opts.verbose = true;
            let outcome = sweep::coordinate(&spec, &opts)?;
            eprintln!(
                "sweep: {} shard(s) done on {} worker process(es) spawned ({} reassigned)",
                outcome.shards, outcome.workers_spawned, outcome.shards_reassigned
            );
            outcome.report
        }
        None => sweep::run_with_progress(&spec, threads, Some(&progress)),
    };

    finish_sweep(&report, out_dir)
}

/// Shared epilogue of `sweep`, `sweep --workers` and `sweep merge`:
/// render the aggregate table, write the artifacts, and turn cell
/// failures into a non-zero exit. Partial sweeps must not look like
/// clean successes to callers gating on the exit status; the artifacts
/// still record the completed cells and each failure's message.
fn finish_sweep(
    report: &cloudmarket::sweep::SweepReport,
    out_dir: &std::path::Path,
) -> Result<(), String> {
    println!("{}", report.aggregate_table().render());
    let cells_path = write_sweep_artifacts(report, out_dir)?;
    if report.failed() > 0 {
        return Err(format!(
            "{}/{} sweep cells failed (per-cell errors in {})",
            report.failed(),
            report.total(),
            cells_path.display()
        ));
    }
    Ok(())
}

/// Serialize a sweep report into `out_dir` (`sweep_cells.csv`,
/// `sweep_aggregate.json`, retained `sweep_series_cell*.csv`), removing
/// stale series files from a previous run into the same directory first.
/// Shared by the thread, `--workers` and `sweep merge` paths so every
/// mode writes identical bytes for identical reports. Returns the cells
/// CSV path (named in failure messages).
fn write_sweep_artifacts(
    report: &cloudmarket::sweep::SweepReport,
    out_dir: &std::path::Path,
) -> Result<PathBuf, String> {
    std::fs::create_dir_all(out_dir).map_err(|e| e.to_string())?;
    let cells_path = out_dir.join("sweep_cells.csv");
    report.cells_csv().write_file(&cells_path).map_err(|e| e.to_string())?;
    let agg_path = out_dir.join("sweep_aggregate.json");
    std::fs::write(&agg_path, report.aggregate_json().to_string_pretty())
        .map_err(|e| e.to_string())?;
    println!("wrote {} and {}", cells_path.display(), agg_path.display());
    // Series filenames depend on the grid and filter, so stale files from
    // a previous run into the same directory would otherwise survive and
    // masquerade as this run's output.
    if let Ok(entries) = std::fs::read_dir(out_dir) {
        for entry in entries.flatten() {
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if name.starts_with("sweep_series_cell") && name.ends_with(".csv") {
                std::fs::remove_file(entry.path()).map_err(|e| e.to_string())?;
            }
        }
    }
    let retained = report.retained_series_csvs();
    if !retained.is_empty() {
        for (id, csv) in &retained {
            csv.write_file(&out_dir.join(format!("sweep_series_cell{id:04}.csv")))
                .map_err(|e| e.to_string())?;
        }
        println!(
            "wrote {} retained series ({})",
            retained.len(),
            out_dir.join("sweep_series_cell*.csv").display()
        );
    }
    Ok(cells_path)
}

/// `cloudmarket sweep worker --shard <file> --out <file>`: run one shard
/// of a sweep in this process (via the shard file's embedded spec) and
/// write a self-contained partial artifact for `sweep merge` or the
/// `--workers` coordinator. Cell failures become error rows, not a
/// non-zero exit - the merge step decides what a failed cell means.
fn cmd_sweep_worker(args: &Args) -> Result<(), String> {
    use cloudmarket::sweep::{self, shard};

    let shard_path = PathBuf::from(
        args.get("shard").ok_or("sweep worker requires --shard <file>")?,
    );
    let out_path =
        PathBuf::from(args.get("out").ok_or("sweep worker requires --out <file>")?);
    let threads = args.get_positive_usize("threads", 1)?;
    // A shard file that fails to read/validate is a *permanent* problem -
    // corrupt bytes or a foreign spec digest stay wrong on every retry -
    // so tag the error for `main` to map onto EXIT_BAD_SHARD instead of
    // the generic runtime failure the coordinator would reassign.
    let (spec, job) = shard::read_shard_file(&shard_path)
        .map_err(|e| format!("{BAD_SHARD_PREFIX}{e}"))?;
    let cells = spec.cells();
    let selected: Vec<sweep::Cell> = job.cell_ids.iter().map(|&id| cells[id]).collect();

    // A stale partial from a crashed earlier run must not outlive this
    // attempt: if we die mid-run, the caller sees a missing file, never
    // an old one (writes below are atomic tmp+rename).
    let _ = std::fs::remove_file(&out_path);

    // Test-only fault injection (tests/sweep_process.rs): with
    // CLOUDMARKET_SWEEP_FAULT=<shard_index>:<marker_path> targeting this
    // shard and the marker not yet present, the marker is created and the
    // worker aborts right after its first completed cell - a real
    // mid-shard death for the coordinator's reassignment path. The
    // marker makes the fault one-shot: the reassigned attempt runs clean.
    let armed = match std::env::var("CLOUDMARKET_SWEEP_FAULT") {
        Ok(fault) => match fault.split_once(':') {
            Some((idx, marker)) if idx.parse::<usize>().ok() == Some(job.index) => {
                let marker = PathBuf::from(marker);
                !marker.exists() && std::fs::write(&marker, b"fault fired\n").is_ok()
            }
            _ => false,
        },
        Err(_) => false,
    };
    // Same-host workers die with their coordinator: `--workers` sets
    // CLOUDMARKET_SWEEP_PARENT to the coordinator's PID, and between
    // cells the worker checks it is still alive (via /proc on Linux; the
    // watchdog stays disarmed where that probe is unavailable, and for
    // manually-launched cluster workers, which have no such env). This
    // covers the abort paths no coordinator-side cleanup can - Ctrl-C or
    // SIGKILL of the coordinator - so orphans never run their full shard
    // or rename partials into a later run's work dir.
    let parent_probe: Option<PathBuf> = std::env::var("CLOUDMARKET_SWEEP_PARENT")
        .ok()
        .and_then(|pid| pid.parse::<u32>().ok())
        .map(|pid| PathBuf::from(format!("/proc/{pid}")))
        .filter(|probe| probe.exists());
    let watch_parent = parent_probe.is_some();
    let per_cell = move |done: usize, _total: usize, _r: &sweep::CellResult| {
        if armed && done >= 1 {
            eprintln!("sweep worker: injected fault firing (aborting mid-shard)");
            std::process::abort();
        }
        if let Some(probe) = &parent_probe {
            if !probe.exists() {
                eprintln!("sweep worker: coordinator is gone; exiting mid-shard");
                std::process::exit(cloudmarket::sweep::EXIT_PARENT_GONE);
            }
        }
    };

    eprintln!(
        "sweep worker: shard {}/{} ({} cells) on {threads} thread(s) ...",
        job.index,
        job.of,
        selected.len()
    );
    let results = sweep::run_cells(
        &spec,
        &selected,
        threads,
        if armed || watch_parent { Some(&per_cell) } else { None },
    );
    let failed = results.iter().filter(|r| r.outcome.is_err()).count();
    shard::write_partial(&out_path, &spec, job.index, &results)?;
    eprintln!(
        "sweep worker: shard {} done ({} cells, {failed} failed) -> {}",
        job.index,
        results.len(),
        out_path.display()
    );
    Ok(())
}

/// `cloudmarket sweep merge <partial.json>...`: recombine worker partials
/// (same host or copied in from a cluster) into the standard sweep
/// artifacts. Refuses partials from different specs and overlapping or
/// incomplete cell coverage; the merged bytes equal a single-process run.
fn cmd_sweep_merge(args: &Args, out_dir: &std::path::Path) -> Result<(), String> {
    use cloudmarket::sweep::shard;

    let inputs = &args.positional[2..];
    if inputs.is_empty() {
        return Err(
            "sweep merge requires partial files: cloudmarket sweep merge <partial.json>... \
             [--out-dir <dir>]"
                .into(),
        );
    }
    let partials = inputs
        .iter()
        .map(|p| shard::read_partial(std::path::Path::new(p)))
        .collect::<Result<Vec<_>, _>>()?;
    let (_spec, report) = shard::merge_partials(partials)?;
    finish_sweep(&report, out_dir)
}

fn cmd_trace(args: &Args, out_dir: &std::path::Path) -> Result<(), String> {
    let mut cfg = trace_sim::TraceSimConfig::default();
    cfg.synth.seed = args.get_u64("seed", 42)?;
    cfg.synth.machines = args.get_usize("machines", cfg.synth.machines)?;
    cfg.synth.days = args.get_f64("days", cfg.synth.days)?;
    cfg.workload.spot_instances = args.get_usize("spots", cfg.workload.spot_instances)?;
    cfg.workload.max_trace_vms = args.get_usize("max-vms", cfg.workload.max_trace_vms)?;
    cfg.profile = !args.has("no-profile");

    eprintln!(
        "simulating {} machines x {:.1} days, {} spots ...",
        cfg.synth.machines, cfg.synth.days, cfg.workload.spot_instances
    );
    let out = trace_sim::run(&cfg);
    println!("{}", trace_sim::results_table(&out).render());
    println!("{}", out.series.ascii_chart("spot_running", 100, 12));

    trace_sim::fig12_csv(&out)
        .write_file(&out_dir.join("fig12_active_instances.csv"))
        .map_err(|e| e.to_string())?;
    if let Some(prof) = &out.selfprof {
        prof.to_csv()
            .write_file(&out_dir.join("fig10_11_selfprofile.csv"))
            .map_err(|e| e.to_string())?;
        println!(
            "self-profile: cpu peak {:.0}%  rss peak {:.0} MB ({} samples)",
            prof.max_of("cpu_pct").unwrap_or(0.0),
            prof.max_of("rss_mb").unwrap_or(0.0),
            prof.len()
        );
    }
    println!("wrote {}", out_dir.join("fig12_active_instances.csv").display());
    Ok(())
}

fn cmd_trace_analysis(args: &Args) -> Result<(), String> {
    let seed = args.get_u64("seed", 42)?;
    let machines = args.get_usize("machines", 200)?;
    eprintln!("generating 30-day trace ({machines} machines) ...");
    let trace = trace_analysis::month_trace(seed, machines);
    println!("{}", trace_analysis::fig7_table(&trace).render());
    println!("{}", trace_analysis::fig8_table(&trace).render());
    println!("{}", trace_analysis::fig9_table(&trace).render());
    Ok(())
}

fn cmd_advisor(args: &Args) -> Result<(), String> {
    let seed = args.get_u64("seed", 7)?;
    let path = args.get("advisor").map(PathBuf::from);
    let ds = advisor::dataset(path.as_deref(), seed);
    println!("dataset: {} instance types, {} families", ds.rows.len(), ds.family_names.len());
    println!("{}", advisor::class_distribution_table(&ds).render());
    println!("{}", advisor::fig16_table(&ds).render());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    /// `sweep` help/usage smoke test: the subcommand is advertised and
    /// `--help` short-circuits before any cell runs.
    #[test]
    fn usage_mentions_sweep_and_its_flags() {
        let u = usage();
        assert!(u.contains("sweep"), "{u}");
        assert!(u.contains("sweep worker"), "{u}");
        assert!(u.contains("sweep merge"), "{u}");
        for flag in [
            "--threads",
            "--seeds",
            "--policies",
            "--out-dir",
            "--axis",
            "--substrate",
            "--retain-series",
            "--workers",
            "--shard",
            "--out",
        ] {
            assert!(u.contains(flag), "usage missing {flag}:\n{u}");
        }
    }

    #[test]
    fn sweep_help_smoke() {
        assert!(run(&argv(&["sweep", "--help"])).is_ok());
    }

    /// Bad sweep flags fail fast (before the grid fans out).
    #[test]
    fn sweep_rejects_bad_counts_and_policies() {
        let err = run(&argv(&["sweep", "--threads", "0"])).unwrap_err();
        assert!(err.contains("must be >= 1"), "{err}");
        let err = run(&argv(&["sweep", "--seeds", "0"])).unwrap_err();
        assert!(err.contains("must be >= 1"), "{err}");
        let err = run(&argv(&["sweep", "--threads", "abc"])).unwrap_err();
        assert!(err.contains("expects an integer"), "{err}");
        let err = run(&argv(&["sweep", "--policies", "bogus"])).unwrap_err();
        assert!(err.contains("unknown policy"), "{err}");
        assert!(run(&argv(&["sweep", "--scorer", "pjrt"])).is_err());
    }

    /// Bad axis/substrate/retention flags fail fast too.
    #[test]
    fn sweep_rejects_bad_axes_and_filters() {
        let err = run(&argv(&["sweep", "--axis", "spot.warning"])).unwrap_err();
        assert!(err.contains("must be <name>=<v1,v2,...>"), "{err}");
        let err = run(&argv(&["sweep", "--axis", "frobnicate=1"])).unwrap_err();
        assert!(err.contains("unknown axis"), "{err}");
        let err = run(&argv(&["sweep", "--axis", "spot.warning=-5"])).unwrap_err();
        assert!(err.contains("negative"), "{err}");
        let err = run(&argv(&["sweep", "--axis", "chaos.reclaim-storm=at100"])).unwrap_err();
        assert!(err.contains("chaos.reclaim-storm"), "{err}");
        let err = run(&argv(&["sweep", "--substrate", "cloud"])).unwrap_err();
        assert!(err.contains("unknown substrate"), "{err}");
        let err = run(&argv(&[
            "sweep", "--axis", "substrate=trace", "--substrate", "comparison",
        ]))
        .unwrap_err();
        assert!(err.contains("same axis"), "{err}");
        let err = run(&argv(&[
            "sweep", "--axis", "spot.warning=60", "--axis", "spot.warning=120",
        ]))
        .unwrap_err();
        assert!(err.contains("declared more than once"), "{err}");
        let err = run(&argv(&[
            "sweep", "--policies", "first-fit,hlem-vmp", "--axis", "hlem.alpha=-0.2,-0.8",
        ]))
        .unwrap_err();
        assert!(err.contains("alpha-sensitive"), "{err}");
        let err = run(&argv(&["sweep", "--retain-series", "bogus=1"])).unwrap_err();
        assert!(err.contains("unknown retain key"), "{err}");
    }

    #[test]
    fn unknown_subcommand_is_an_error() {
        assert!(run(&argv(&["frobnicate"])).is_err());
        let err = run(&argv(&["sweep", "frobnicate"])).unwrap_err();
        assert!(err.contains("unknown sweep subcommand"), "{err}");
    }

    fn test_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("cloudmarket_cli_{}_{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    /// `--workers 0` and malformed worker invocations exit non-zero with
    /// clear messages, before any process spawns.
    #[test]
    fn sweep_workers_and_worker_reject_bad_input() {
        let err = run(&argv(&["sweep", "--workers", "0"])).unwrap_err();
        assert!(err.contains("--workers must be >= 1"), "{err}");
        let err = run(&argv(&["sweep", "--workers", "abc"])).unwrap_err();
        assert!(err.contains("expects an integer"), "{err}");

        let err = run(&argv(&["sweep", "worker"])).unwrap_err();
        assert!(err.contains("--shard"), "{err}");
        let err = run(&argv(&["sweep", "worker", "--shard", "x.json"])).unwrap_err();
        assert!(err.contains("--out"), "{err}");

        // Missing shard file.
        let dir = test_dir("worker_errs");
        let missing = dir.join("nope.json");
        let out = dir.join("out.json");
        let err = run(&argv(&[
            "sweep",
            "worker",
            "--shard",
            missing.to_str().unwrap(),
            "--out",
            out.to_str().unwrap(),
        ]))
        .unwrap_err();
        assert!(err.contains("reading shard file"), "{err}");
        assert_eq!(
            exit_code_for(&err),
            cloudmarket::sweep::EXIT_BAD_SHARD,
            "unreadable shard files map to the permanent exit code"
        );

        // Corrupt shard file.
        let corrupt = dir.join("corrupt.json");
        std::fs::write(&corrupt, "{ this is not json").unwrap();
        let err = run(&argv(&[
            "sweep",
            "worker",
            "--shard",
            corrupt.to_str().unwrap(),
            "--out",
            out.to_str().unwrap(),
        ]))
        .unwrap_err();
        assert!(err.contains("shard file"), "{err}");
        assert_eq!(exit_code_for(&err), cloudmarket::sweep::EXIT_BAD_SHARD);
        assert!(!out.exists(), "no partial may be written on a bad shard file");

        // Ordinary errors stay on the generic runtime exit code.
        assert_eq!(exit_code_for("anything else"), cloudmarket::sweep::EXIT_RUNTIME);
        let _ = std::fs::remove_dir_all(&dir);
    }

    fn fake_cell_result(cell: cloudmarket::sweep::Cell) -> cloudmarket::sweep::CellResult {
        use cloudmarket::engine::{Report, ResilienceStats, SpotStats};
        cloudmarket::sweep::CellResult {
            cell,
            outcome: Ok(Report {
                policy: "first-fit",
                clock_end: 1.0,
                events_processed: 1,
                wall: std::time::Duration::ZERO,
                finished: 0,
                terminated: 0,
                failed: 0,
                still_active: 0,
                cloudlets_finished: 0,
                cloudlets_canceled: 0,
                alloc_attempts: 0,
                alloc_failures: 0,
                spot: SpotStats::default(),
                resilience: ResilienceStats::default(),
            }),
            series: None,
        }
    }

    /// `sweep merge` error paths: no inputs, unreadable input, partials
    /// with overlapping cell ids, and partials missing a shard.
    #[test]
    fn sweep_merge_rejects_bad_partial_sets() {
        use cloudmarket::sweep::{shard, PolicySpec, SweepSpec};

        let err = run(&argv(&["sweep", "merge"])).unwrap_err();
        assert!(err.contains("requires partial files"), "{err}");
        let err = run(&argv(&["sweep", "merge", "/nonexistent/partial.json"])).unwrap_err();
        assert!(err.contains("reading partial"), "{err}");

        let dir = test_dir("merge_errs");
        let spec = SweepSpec::new(ComparisonConfig::default())
            .with_seeds(vec![1, 2])
            .with_policies(vec![PolicySpec::FirstFit, PolicySpec::BestFit]);
        let cells = spec.cells();
        let shards = shard::partition(&spec, 2);
        for s in &shards {
            let results: Vec<_> =
                s.cell_ids.iter().map(|&id| fake_cell_result(cells[id])).collect();
            shard::write_partial(
                &dir.join(format!("sweep_partial{:04}.json", s.index)),
                &spec,
                s.index,
                &results,
            )
            .unwrap();
        }
        let p0 = dir.join("sweep_partial0000.json");
        let p1 = dir.join("sweep_partial0001.json");
        let out = dir.join("merged");

        // Overlap: shard 0 fed in twice alongside shard 1.
        let err = run(&argv(&[
            "sweep",
            "merge",
            p0.to_str().unwrap(),
            p0.to_str().unwrap(),
            p1.to_str().unwrap(),
            "--out-dir",
            out.to_str().unwrap(),
        ]))
        .unwrap_err();
        assert!(err.contains("overlapping cell id"), "{err}");

        // Missing: shard 1 absent.
        let err = run(&argv(&[
            "sweep",
            "merge",
            p0.to_str().unwrap(),
            "--out-dir",
            out.to_str().unwrap(),
        ]))
        .unwrap_err();
        assert!(err.contains("missing"), "{err}");

        // The full set merges and writes artifacts.
        run(&argv(&[
            "sweep",
            "merge",
            p0.to_str().unwrap(),
            p1.to_str().unwrap(),
            "--out-dir",
            out.to_str().unwrap(),
        ]))
        .unwrap();
        assert!(out.join("sweep_cells.csv").exists());
        assert!(out.join("sweep_aggregate.json").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Anti-drift check for `docs/cli.md`: every flag the CLI reference
    /// documents must appear in the live `--help` output, and every
    /// declared flag must be documented. Flags belonging to external
    /// tools (cargo, rustup) that the docs mention in passing are
    /// allowlisted.
    #[test]
    fn cli_docs_match_help_output() {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .expect("crate dir has a parent")
            .join("docs/cli.md");
        let docs = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()));
        let external = [
            "release", "features", "bench", "no-deps", "workspace", "bin", "quiet",
        ];
        // Collect `--flag` tokens from the docs.
        let mut documented: Vec<String> = Vec::new();
        for (i, _) in docs.match_indices("--") {
            let name: String = docs[i + 2..]
                .chars()
                .take_while(|c| c.is_ascii_lowercase() || *c == '-')
                .collect();
            let name = name.trim_end_matches('-').to_string();
            if !name.is_empty() && !documented.contains(&name) {
                documented.push(name);
            }
        }
        documented.retain(|n| !external.contains(&n.as_str()));
        assert!(!documented.is_empty(), "no flags found in {}", path.display());
        let u = usage();
        for flag in &documented {
            assert!(
                u.contains(&format!("--{flag}")),
                "docs/cli.md documents --{flag} but --help does not mention it"
            );
        }
        // The reverse: every declared flag is documented.
        for spec in specs() {
            assert!(
                documented.iter().any(|d| d == spec.name),
                "--{} is declared in specs() but missing from docs/cli.md",
                spec.name
            );
        }
        // And every subcommand is documented.
        for cmd in ["quickstart", "compare", "sweep", "trace", "trace-analysis", "advisor", "tables"]
        {
            assert!(docs.contains(cmd), "docs/cli.md missing subcommand {cmd}");
        }
    }
}
