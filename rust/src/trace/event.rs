//! Trace data model, mirroring the Google cluster trace 2011 schema
//! semantics (Reiss et al., paper ref [41]): MACHINE EVENTS and TASK
//! EVENTS tables.

/// Machine event types (ADD/REMOVE/UPDATE in the published schema).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MachineEventKind {
    Add,
    Remove,
    Update,
}

/// One machine-events row.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MachineEvent {
    /// Seconds since trace start (the real trace uses microseconds; the
    /// reader converts).
    pub time: f64,
    pub machine_id: u64,
    pub kind: MachineEventKind,
    /// Normalized CPU capacity in (0, 1] (trace convention). 0 = missing.
    pub cpu: f64,
    /// Normalized memory capacity in (0, 1]. 0 = missing.
    pub ram: f64,
}

/// Task event types (subset of the schema's 0-8 event codes that the
/// simulation consumes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskEventKind {
    Submit,
    Schedule,
    Evict,
    Fail,
    Finish,
    Kill,
}

/// One task-events row.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TaskEvent {
    pub time: f64,
    pub job_id: u64,
    pub task_index: u32,
    /// Machine the task was bound to; `None` in SUBMIT rows (the paper's
    /// reader revision binds tasks at submission when possible, §VII-C.2a).
    pub machine_id: Option<u64>,
    pub kind: TaskEventKind,
    /// Anonymized user id.
    pub user: u32,
    /// Priority (0-11 in the trace; >= 9 is "production" tier).
    pub priority: u8,
    /// Normalized resource requests in (0, 1].
    pub cpu_req: f64,
    pub ram_req: f64,
}

impl TaskEvent {
    /// Production-tier tasks are the non-preemptible services (Borg);
    /// lower priorities are preemptible batch (paper §VII-C.1a).
    pub fn is_production(&self) -> bool {
        self.priority >= 9
    }
}

/// A full trace: both tables, time-sorted.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub machines: Vec<MachineEvent>,
    pub tasks: Vec<TaskEvent>,
    /// Trace horizon in seconds.
    pub horizon: f64,
}

impl Trace {
    /// Number of distinct machines seen in machine events.
    pub fn machine_count(&self) -> usize {
        let mut ids: Vec<u64> = self.machines.iter().map(|m| m.machine_id).collect();
        ids.sort_unstable();
        ids.dedup();
        ids.len()
    }

    /// Number of distinct (job, task) pairs submitted.
    pub fn task_count(&self) -> usize {
        self.tasks.iter().filter(|t| t.kind == TaskEventKind::Submit).count()
    }

    /// Validate orderings and referential sanity; returns issue list.
    pub fn validate(&self) -> Vec<String> {
        let mut issues = Vec::new();
        for w in self.machines.windows(2) {
            if w[1].time < w[0].time {
                issues.push(format!("machine events out of order at t={}", w[1].time));
                break;
            }
        }
        for w in self.tasks.windows(2) {
            if w[1].time < w[0].time {
                issues.push(format!("task events out of order at t={}", w[1].time));
                break;
            }
        }
        let submit_count = self.task_count();
        let finish_count =
            self.tasks.iter().filter(|t| t.kind == TaskEventKind::Finish).count();
        if finish_count > submit_count {
            issues.push(format!("{finish_count} finishes > {submit_count} submissions"));
        }
        issues
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn production_tier_threshold() {
        let mut t = TaskEvent {
            time: 0.0,
            job_id: 1,
            task_index: 0,
            machine_id: None,
            kind: TaskEventKind::Submit,
            user: 0,
            priority: 9,
            cpu_req: 0.1,
            ram_req: 0.1,
        };
        assert!(t.is_production());
        t.priority = 2;
        assert!(!t.is_production());
    }

    #[test]
    fn validate_catches_disorder() {
        let mk = |time| MachineEvent {
            time,
            machine_id: 1,
            kind: MachineEventKind::Add,
            cpu: 0.5,
            ram: 0.5,
        };
        let trace = Trace { machines: vec![mk(5.0), mk(1.0)], tasks: vec![], horizon: 10.0 };
        assert!(!trace.validate().is_empty());
    }
}
